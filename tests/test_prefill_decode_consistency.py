"""Numerical consistency: token-by-token decode must reproduce the
full-sequence (training/prefill) forward pass — validates the KV cache,
RoPE offsets, ring-buffer masking and per-family decode recurrences
against the chunked-flash training path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core.shmap import shard_map
from repro.models.attention import KVCacheSpec
from repro.models.layers import rms_norm, vocab_parallel_logits
from repro.models.model import Model
from repro.models.parallel import ParallelCtx, init_params, param_specs

B, S = 2, 24
MESH = jax.make_mesh((1, 1), ("data", "model"))
CTX = ParallelCtx(tp_size=1, fsdp_size=1, dp_axes=("data",), remat="none")


def _forward_logits(model, params, tokens):
    """Training-path logits at every position (dense/ssm families)."""
    from repro.models.layers import embed_lookup

    h = embed_lookup(tokens, params["embed"], model.ctx)
    positions = jnp.arange(h.shape[1])
    h, _ = model._backbone(h, params, positions=positions)
    h = rms_norm(h, params["final_norm"], model.cfg.norm_eps)
    return vocab_parallel_logits(h, params["unembed"], model.ctx)


@pytest.mark.parametrize(
    "arch", ["minitron-8b", "mamba2-780m", "minicpm3-4b", "zamba2-2.7b"]
)
def test_decode_matches_prefill(arch):
    cfg = registry.get(arch, smoke=True)
    model = Model(cfg, CTX)
    defs = model.param_defs()
    params = init_params(defs, jax.random.key(2))
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    specs = param_specs(defs)

    fwd = jax.jit(shard_map(
        lambda p, t: _forward_logits(model, p, t),
        mesh=MESH, in_specs=(specs, P(None, None)),
        out_specs=P(None, None, None),
    ))
    want = np.asarray(fwd(params, tokens))  # (B, S, V)

    plan = KVCacheSpec(s_total=S, cp_axis=None, cp_size=1)
    shapes = model.cache_defs(B, plan)
    cache = {k: jnp.zeros(v, jnp.float32) for k, v in shapes.items()}
    cspecs = {k: P(*((None,) * len(v))) for k, v in shapes.items()}
    dstep = jax.jit(shard_map(
        lambda p, c, t, pos: model.decode_fn(p, c, t, pos[0], plan),
        mesh=MESH, in_specs=(specs, cspecs, P(None, None), P(None)),
        out_specs=(P(None, None, None), cspecs),
    ))
    got = []
    for i in range(S):
        logits, cache = dstep(params, cache, jnp.asarray(tokens[:, i : i + 1]),
                              jnp.asarray([i]))
        got.append(np.asarray(logits)[:, 0, :])
    got = np.stack(got, axis=1)  # (B, S, V)

    scale = np.abs(want).max()
    err = np.abs(got - want).max() / scale
    assert err < 0.05, f"decode/prefill mismatch: rel {err}"
