"""Hypothesis property test for the single-pass ring hop (ISSUE 2).

∀ (shape, error bounds, piece alignment, data distribution): the fused
``decompress_reduce_compress`` and the decompress_reduce ∘ compress
composition emit byte-identical wire streams and bitwise-identical f32
accumulators.  Deterministic spot checks of the same contract live in
tests/test_fused_hop.py (they run even without hypothesis installed).
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[dev])"
)
from hypothesis import given, settings, strategies as st

from test_fused_hop import QUANTUM, _assert_hop_identical


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=3 * QUANTUM + 511),
    eb_in=st.sampled_from([1e-2, 1e-3, 1e-4, 3e-4]),
    eb_out=st.sampled_from([1e-2, 1e-3, 1e-4, 3e-4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    kind=st.sampled_from(["smooth", "boundary", "spiky"]),
)
def test_property_fused_hop_byte_identical(n, eb_in, eb_out, seed, kind):
    _assert_hop_identical(n, eb_in, eb_out, seed, kind)
