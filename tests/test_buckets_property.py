"""Hypothesis property (ISSUE 9 satellite): bucket ledgers tile the tree
EXACTLY — every element of every leaf lands in exactly one bucket slice,
no gaps, no overlap — across random pytree shapes and bucket sizes, and
the stack/unstack roundtrip is the identity.

Kept in its own module because ``pytest.importorskip`` at module scope
skips the whole file — the deterministic mirrors live in
tests/test_buckets.py and run even without hypothesis.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[dev])"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core.buckets import build_ledger  # noqa: E402

SHAPES = st.lists(
    st.lists(st.integers(1, 9), min_size=0, max_size=3).map(tuple),
    min_size=1,
    max_size=8,
)


@settings(max_examples=60, deadline=None)
@given(shapes=SHAPES, bucket_elems=st.integers(1, 200))
def test_property_ledger_tiles_exactly(shapes, bucket_elems):
    total = sum(int(np.prod(s)) for s in shapes)
    led = build_ledger(shapes, 4 * bucket_elems)
    led.assert_tiles_exactly()
    assert led.total_elems == total
    assert led.bucket_elems == min(bucket_elems, total)
    assert led.n_buckets == -(-total // led.bucket_elems)
    # no overlap, no gap, full cover — element-count double entry
    covered = np.zeros(total, np.int32)
    starts = np.cumsum([0] + [int(np.prod(s)) for s in shapes])
    for b in led.buckets:
        for s in b.slices:
            covered[starts[s.leaf] + s.start: starts[s.leaf] + s.stop] += 1
    assert (covered == 1).all()


@settings(max_examples=30, deadline=None)
@given(shapes=SHAPES, bucket_elems=st.integers(1, 200), seed=st.integers(0, 99))
def test_property_stack_unstack_roundtrip(shapes, bucket_elems, seed):
    r = np.random.default_rng(seed)
    led = build_ledger(shapes, 4 * bucket_elems)
    leaves = [
        jnp.asarray(r.normal(size=int(np.prod(s))).astype(np.float32))
        for s in shapes
    ]
    back = led.unstack(led.stack_payloads(leaves))
    for a, b in zip(leaves, back):
        assert np.array_equal(np.asarray(a), np.asarray(b))
