"""Chunked vocab loss == one-shot loss (values and gradients)."""
import dataclasses

import numpy as np
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core.shmap import shard_map
from repro.models.model import Model
from repro.models.parallel import ParallelCtx, init_params, param_specs

B, S = 2, 48
MESH = jax.make_mesh((1, 1), ("data", "model"))
CTX = ParallelCtx(tp_size=1, fsdp_size=1, dp_axes=("data",), remat="none")


@pytest.mark.parametrize("chunk", [16, 17, 48, 1024])
def test_chunked_loss_matches_oneshot(chunk):
    cfg = registry.get("minitron-8b", smoke=True)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
    }
    # mask some positions to exercise the denominator
    batch["labels"][0, :5] = -1
    specs = param_specs(Model(cfg, CTX).param_defs())
    bspec = {k: P(None, None) for k in batch}

    def loss_of(c):
        model = Model(c, CTX)

        def body(p, b):
            return jax.value_and_grad(model.loss_fn)(p, b)

        return jax.jit(shard_map(body, mesh=MESH, in_specs=(specs, bspec),
                                 out_specs=(P(), specs)))

    params = init_params(Model(cfg, CTX).param_defs(), jax.random.key(0))
    l0, g0 = loss_of(cfg)(params, batch)
    l1, g1 = loss_of(dataclasses.replace(cfg, loss_chunk=chunk))(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-4 * max(float(l0), 1.0)
    worst = 0.0
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        worst = max(worst, np.abs(a - b).max() / max(np.abs(a).max(), 1e-6))
    assert worst < 0.02, worst
