"""Two-level topology planning (ISSUE 6): per-link Hardware terms, the
hierarchical cost model, HierPlan resolution + the full-topology-tuple
cache key, error-budget splitting across lossy stages, network-term
recovery from measured hop timings, and the acceptance invariant the
benchmark baseline pins.

Single-process: plan resolution and the simulator are pure Python over
static shapes.  Multi-device bitwise parity (hier vs composed per-axis
reference, flat fallback vs composite-axis schedule, 2x3-vs-3x2 replan)
lives in tests/_mp_hier_child.py.  The hypothesis sweeps are in
tests/test_hier_property.py; the fixed-seed mirrors here run even
without hypothesis installed.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core import error_budget, simulator
from repro.core.collectives import GZConfig
from repro.core.comm import (
    GZHierCommunicator,
    HierPlan,
    _resolve_hier_plan,
    clear_plan_cache,
    fit_network,
    plan_cache_stats,
)
from repro.launch.mesh import make_hier_mesh, mesh_axis_sizes


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _resolve(topology, n_elems=1 << 20, hw=cm.A100_SLINGSHOT, eb=1e-4,
             **kw):
    kw.setdefault("policy", "auto")
    kw.setdefault("requested_algo", None)
    kw.setdefault("requested_chunks", 0)
    kw.setdefault("capacity_factor", 0.6)
    kw.setdefault("worst_case_budget", True)
    kw.setdefault("fused", True)
    kw.setdefault("fused_hop", True)
    kw.setdefault("ratio", 20.0)
    return _resolve_hier_plan(
        "allreduce", n_elems, "float32", topology, eb, hw=hw, **kw
    )


# ---------------------------------------------------------------------------
# Per-link Hardware terms
# ---------------------------------------------------------------------------


def test_flat_fabric_inherits_inter_terms():
    # intra_gbps == 0 declares a flat fabric: the intra link IS the net
    # link, so existing single-level Hardware points keep their meaning.
    hw = cm.TPU_V5E
    assert hw.intra_gbps == 0.0
    assert hw.intra_terms() == (hw.net_gbps, hw.net_alpha_us)
    assert hw.link_asymmetry() == 1.0


def test_a100_point_is_asymmetric():
    hw = cm.A100_SLINGSHOT
    assert hw.intra_terms() == (hw.intra_gbps, hw.intra_alpha_us)
    # NVLink3 vs the paper's Slingshot fabric: the >= 4:1 regime the
    # acceptance invariant requires (actually ~48:1).
    assert hw.link_asymmetry() >= 4.0


def test_intra_stage_costs():
    hw = cm.A100_SLINGSHOT
    D, L = 1 << 20, 4
    rs = cm.reduce_scatter_uncompressed_intra(D, L, hw)
    ag = cm.allgather_uncompressed_intra(D, L, hw)
    # L-1 hops of D/L bytes each; the RS additionally reduces each hop.
    assert ag == pytest.approx((L - 1) * cm.t_net_intra(D / L, hw))
    assert rs == pytest.approx(ag + (L - 1) * cm.t_reduce(D / L, hw))
    # Degenerate single-rank node: no intra traffic at all.
    assert cm.reduce_scatter_uncompressed_intra(D, 1, hw) == 0.0
    assert cm.allgather_uncompressed_intra(D, 1, hw) == 0.0


def test_hier_cost_composes_stages():
    hw = cm.A100_SLINGSHOT
    D, n_nodes, L, R = 1 << 22, 4, 8, 20.0
    t = cm.allreduce_hier_gz(D, n_nodes, L, R, hw, inter_algo="redoub")
    want = (
        cm.reduce_scatter_uncompressed_intra(D, L, hw)
        + cm.allreduce_redoub_gz(D / L, n_nodes, R, hw, 0.7, fused_hop=True)
        + cm.allgather_uncompressed_intra(D, L, hw)
    )
    assert t == pytest.approx(want)
    # One node: the inter stage vanishes; only intra RS+AG remain.
    t1 = cm.allreduce_hier_gz(D, 1, L, R, hw)
    assert t1 == pytest.approx(
        cm.reduce_scatter_uncompressed_intra(D, L, hw)
        + cm.allgather_uncompressed_intra(D, L, hw)
    )


# ---------------------------------------------------------------------------
# Error-budget split across stages
# ---------------------------------------------------------------------------


def test_split_lossy_only_lossy_stages_share():
    # intra RS / inter allreduce / intra AG: only the middle is lossy, so
    # it carries the WHOLE budget — compression on the slow hop must not
    # pay an accuracy tax for exact stages.
    assert error_budget.split_lossy(1e-3, (False, True, False)) == \
        (0.0, 1e-3, 0.0)
    assert error_budget.split_lossy(1e-3, (True, True)) == (5e-4, 5e-4)
    assert error_budget.split_lossy(1e-3, (False, False)) == (0.0, 0.0)
    assert error_budget.split_lossy(1e-3, ()) == ()


def test_hier_plan_inter_carries_whole_budget():
    plan = _resolve((4, 8), eb=1e-3)
    assert not plan.flat
    assert plan.inter.eb == 1e-3


# ---------------------------------------------------------------------------
# HierPlan resolution + cache key
# ---------------------------------------------------------------------------


def test_flat_fabric_resolves_flat():
    plan = _resolve((2, 4), hw=cm.TPU_V5E)
    assert plan.flat and plan.inter is None
    # The flat sub-plan IS the ordinary single-axis plan over N ranks —
    # the execute layer runs it over the composite axis, so "hierarchy
    # off" is bitwise the pre-existing path.
    assert plan.flat_plan.axis_size == 8
    assert plan.inter_wire_bytes == plan.flat_plan.wire_bytes
    assert plan.intra_wire_bytes == 0
    assert plan.t_model == plan.t_flat


def test_single_rank_nodes_resolve_flat():
    plan = _resolve((8, 1))
    assert plan.flat, "L == 1: no fast link to exploit"


def test_asymmetric_fabric_resolves_hier():
    plan = _resolve((4, 8))
    assert not plan.flat
    n_nodes, L = plan.topology
    assert plan.inter.axis_size == n_nodes
    shard = -(-plan.n_elems // L)
    assert plan.inter.n_elems == shard
    assert plan.intra_wire_bytes == 2 * (L - 1) * shard * 4
    assert plan.inter_wire_bytes == plan.inter.wire_bytes
    assert plan.inter_wire_bytes < plan.flat_plan.wire_bytes
    assert plan.t_model < plan.t_flat


def test_cache_keys_on_full_topology_tuple():
    # Satellite 1 regression: 2x4 and 4x2 have the same rank product but
    # different shard sizes and inter fan-out — a product-keyed cache
    # would hand the 4x2 call the 2x4 schedule.
    a = _resolve((2, 4))
    b = _resolve((4, 2))
    assert a is not b
    assert a.topology == (2, 4) and b.topology == (4, 2)
    stats = plan_cache_stats()
    assert stats["hier_entries"] == 2
    assert {k[3] for k in stats["hier_keys"]} == {(2, 4), (4, 2)}
    # Different shard over local -> different inter payload.
    assert a.inter.n_elems != b.inter.n_elems
    # Memoized: same topology + knobs returns the same frozen object.
    assert _resolve((2, 4)) is a


def test_hier_communicator_memoized_and_replans_via_for_axes():
    cfg = GZConfig(eb=1e-4)
    c1 = GZHierCommunicator.for_axes("node", "local", config=cfg,
                                     hw=cm.A100_SLINGSHOT)
    c2 = GZHierCommunicator.for_axes("node", "local", config=cfg,
                                     hw=cm.A100_SLINGSHOT)
    assert c1 is c2, "one memoized instance per (axes, knobs)"
    # Explicit topologies bind distinct instances and distinct plans.
    pa = GZHierCommunicator.for_axes(
        "node", "local", config=cfg, hw=cm.A100_SLINGSHOT, topology=(2, 4)
    ).plan((1 << 20,))
    pb = GZHierCommunicator.for_axes(
        "node", "local", config=cfg, hw=cm.A100_SLINGSHOT, topology=(4, 2)
    ).plan((1 << 20,))
    assert pa.topology == (2, 4) and pb.topology == (4, 2) and pa is not pb


def test_hier_plan_rejects_non_allreduce():
    with pytest.raises(ValueError, match="allreduce"):
        _resolve_hier_plan(
            "scatter", 1024, "float32", (2, 4), 1e-4,
            policy="auto", requested_algo=None, requested_chunks=0,
            capacity_factor=0.6, worst_case_budget=True, fused=True,
            fused_hop=True, ratio=20.0, hw=cm.A100_SLINGSHOT,
        )


def test_hier_plan_is_frozen_and_hashable():
    plan = _resolve((2, 4))
    assert isinstance(plan, HierPlan)
    hash(plan)
    with pytest.raises(dataclasses.FrozenInstanceError):
        plan.flat = True


# ---------------------------------------------------------------------------
# Acceptance invariant (the quantities BENCH_hier.json pins)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology", [(2, 4), (3, 4), (4, 8)])
def test_acceptance_hier_beats_flat_on_wire_and_time(topology):
    # At the calibrated A100 point (intra:inter >= 4:1) and >= 8 devices,
    # the hierarchy strictly beats the flat compressed schedule on BOTH
    # the inter-node wire and the modeled clock.
    from benchmarks import hier_bench

    rec = hier_bench.plan_record(topology, int(64e6 / 4))
    assert not rec["flat"]
    assert rec["hier_inter_wire_bytes"] < rec["flat_inter_wire_bytes"]
    assert rec["t_hier_us"] < rec["t_flat_us"]


# ---------------------------------------------------------------------------
# Network-term recovery (satellite 3)
# ---------------------------------------------------------------------------


def _samples_from(gbps, alpha_us, sizes=(1 << 12, 1 << 16, 1 << 20)):
    bw = gbps * 1e9 / 8  # bytes/s
    return [(b, alpha_us * 1e-6 + b / bw) for b in sizes]


def test_fit_network_recovers_inter_terms():
    hw = cm.A100_SLINGSHOT
    fitted = fit_network(
        _samples_from(hw.net_gbps, hw.net_alpha_us), base=cm.TPU_V5E,
        link="inter",
    )
    # The model is t = alpha + bytes/bw — linear, so least squares on
    # noiseless samples recovers the generating terms (nearly) exactly.
    assert fitted.net_gbps == pytest.approx(hw.net_gbps, rel=1e-9)
    assert fitted.net_alpha_us == pytest.approx(hw.net_alpha_us, rel=1e-6)
    # Codec and intra terms are inherited from the base untouched.
    assert fitted.cmp_peak_gbps == cm.TPU_V5E.cmp_peak_gbps
    assert fitted.intra_gbps == cm.TPU_V5E.intra_gbps


def test_fit_network_intra_declares_two_level_fabric():
    hw = cm.A100_SLINGSHOT
    base = dataclasses.replace(cm.TPU_V5E, net_gbps=hw.net_gbps,
                               net_alpha_us=hw.net_alpha_us)
    assert base.link_asymmetry() == 1.0
    fitted = fit_network(
        _samples_from(hw.intra_gbps, hw.intra_alpha_us), base=base,
        link="intra",
    )
    assert fitted.intra_gbps == pytest.approx(hw.intra_gbps, rel=1e-9)
    assert fitted.intra_alpha_us == pytest.approx(hw.intra_alpha_us,
                                                 rel=1e-6)
    assert fitted.link_asymmetry() > 4.0, \
        "fitting the intra class must flip the fabric to two-level"


def test_fit_network_validates_inputs():
    with pytest.raises(ValueError, match="link class"):
        fit_network(_samples_from(100.0, 1.0), base=cm.TPU_V5E,
                    link="nvswitch")
    with pytest.raises(ValueError, match=">= 2"):
        fit_network([(1024, 1e-5)], base=cm.TPU_V5E)


def test_measure_ppermute_feeds_fit_network():
    # Single-host smoke of the full calibration pipeline: time real
    # ppermute hops over a 1-wide axis-pair mesh and fit both link
    # classes.  The numbers measure XLA's copy path, not a fabric — the
    # check is that the pipeline runs end to end and yields positive,
    # finite terms per link class.
    import jax

    from repro.core.comm import measure_ppermute

    mesh = make_hier_mesh(1, 1, devices=jax.devices()[:1])
    samples = measure_ppermute(mesh, "local", sizes=(1 << 10, 1 << 14),
                               reps=1)
    assert len(samples) == 2 and all(s > 0 for _, s in samples)
    fitted = fit_network(samples, base=cm.TPU_V5E, link="intra")
    assert np.isfinite(fitted.intra_gbps) and fitted.intra_gbps > 0


# ---------------------------------------------------------------------------
# Hier mesh construction
# ---------------------------------------------------------------------------


def test_make_hier_mesh_single_device():
    import jax

    mesh = make_hier_mesh(1, 1)
    assert mesh.axis_names == ("node", "local")
    assert mesh_axis_sizes(mesh) == {"node": 1, "local": 1}
    # Extent inference from the device count.
    mesh2 = make_hier_mesh(n_nodes=1, devices=jax.devices())
    assert mesh_axis_sizes(mesh2)["local"] == len(jax.devices())


def test_make_hier_mesh_validates():
    import jax

    with pytest.raises(ValueError, match="n_nodes and/or gpus_per_node"):
        make_hier_mesh()
    with pytest.raises(ValueError, match="devices"):
        make_hier_mesh(3, 2, devices=jax.devices()[:1])


# ---------------------------------------------------------------------------
# Simulator replay (fixed-seed mirror of the hypothesis property)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology", [(2, 3), (3, 2), (3, 4), (1, 4),
                                      (4, 1)])
@pytest.mark.parametrize("inter_algo", ["redoub", "ring"])
def test_sim_hier_within_budget(topology, inter_algo):
    n_nodes, L = topology
    rng = np.random.default_rng(7)
    d = 1001  # indivisible by any L here: exercises the shard padding
    xs = [np.cumsum(rng.normal(0, 0.01, d)).astype(np.float32)
          for _ in range(n_nodes * L)]
    eb = 1e-3
    cfg = GZConfig(eb=eb, capacity_factor=1.3, worst_case_budget=True)
    outs = simulator.sim_allreduce_hier(xs, topology, cfg,
                                        inter_algo=inter_algo)
    exact = np.sum(xs, axis=0, dtype=np.float32)
    slack = max(np.abs(exact).max(), 1.0) * 1e-6
    for o in outs:
        # The inter stage is the only lossy stage and carries the whole
        # budget, so the end-to-end bound is the single-axis bound.
        assert np.abs(o - exact).max() <= eb + slack
    # Ranks of the same node hold bitwise-identical results (the intra
    # allgather is an exact copy of the node's shards).
    for node in range(n_nodes):
        for j in range(1, L):
            assert np.array_equal(outs[node * L], outs[node * L + j])
