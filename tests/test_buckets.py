"""BucketLedger + overlap cost model (ISSUE 9): deterministic mirrors of
the hypothesis property in tests/test_buckets_property.py, plus the
co-planner's strict-overlap guarantees and the sim replay."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import cost_model as cm
from repro.core import simulator
from repro.core.buckets import (
    build_ledger,
    clear_ledger_cache,
    ledger_cache_stats,
    ledger_for,
)
from repro.core.collectives import GZConfig


def _random_shapes(seed):
    r = np.random.default_rng(seed)
    n_leaves = int(r.integers(1, 9))
    shapes = []
    for _ in range(n_leaves):
        nd = int(r.integers(0, 4))
        shapes.append(tuple(int(d) for d in r.integers(1, 9, nd)))
    return shapes


def test_ledger_tiles_exactly_random_sweep():
    """Deterministic mirror of the hypothesis property: across random
    pytree shapes (scalars, ragged tails, leaf-spanning buckets) the
    ledger covers every element exactly once and the gather/unstack
    roundtrip is the identity."""
    for seed in range(40):
        shapes = _random_shapes(seed)
        total = sum(int(np.prod(s)) for s in shapes)
        bucket_bytes = 4 * max(1, total // max(1, (seed % 5)))
        led = build_ledger(shapes, bucket_bytes)
        led.assert_tiles_exactly()  # also run at construction; explicit here
        leaves = [
            jnp.arange(int(np.prod(s)), dtype=jnp.float32).reshape(-1)
            + 1000.0 * i
            for i, s in enumerate(shapes)
        ]
        back = led.unstack(led.stack_payloads(leaves))
        for a, b in zip(leaves, back):
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_ledger_matches_whole_ravel_chunks():
    """Bucket i's payload is bitwise the whole-tree ravel's chunk i —
    the load-bearing half of the bitwise-identity contract."""
    shapes = [(7, 3), (100,), (2, 2)]
    leaves = [
        jnp.asarray(np.random.default_rng(i).normal(size=s), jnp.float32
                    ).reshape(-1)
        for i, s in enumerate(shapes)
    ]
    led = build_ledger(shapes, 4 * 16)
    flat = np.concatenate([np.asarray(x) for x in leaves])
    padded = np.zeros(led.n_buckets * led.bucket_elems, np.float32)
    padded[: flat.size] = flat
    want = padded.reshape(led.n_buckets, led.bucket_elems)
    stacked = np.asarray(led.stack_payloads(leaves))
    # stack_payloads is in ISSUE order (reversed); undo for comparison
    assert np.array_equal(stacked[::-1], want)


def test_ledger_validation_and_defaults():
    with pytest.raises(ValueError, match="zero elements"):
        build_ledger([(0, 5)], 4096)
    with pytest.raises(ValueError, match="holds no"):
        build_ledger([(4,)], 2)
    # small tree clamps to ONE bucket whatever the default bucket size
    led = build_ledger([(10,)], 16 * 1024 * 1024)
    assert led.n_buckets == 1 and led.bucket_elems == 10


def test_ledger_memoization():
    clear_ledger_cache()
    a = ledger_for([(3, 4), (5,)], 4096)
    b = ledger_for(((3, 4), (5,)), 4096)
    assert a is b
    stats = ledger_cache_stats()
    assert stats == {"hits": 1, "misses": 1, "entries": 1}
    ledger_for([(3, 4), (5,)], 8192)
    assert ledger_cache_stats()["entries"] == 2


def test_sync_config_bucket_bytes_validated():
    from repro.core.grad_sync import SyncConfig

    assert SyncConfig().bucket_bytes == 16 * 1024 * 1024  # the old CHUNK
    with pytest.raises(ValueError, match="bucket_bytes"):
        SyncConfig(bucket_bytes=6)
    with pytest.raises(ValueError, match="bucket_bytes"):
        SyncConfig(bucket_bytes=0)


# --- cost model: bucket size x pipeline depth co-planning -------------------


def test_best_bucket_plan_overlaps_at_a100():
    """With calibrated compute the overlapped schedule must beat serial
    strictly — the acceptance criterion BENCH_gradsync.json records."""
    hw = cm.A100_SLINGSHOT
    n_params = 350e6
    plan = cm.best_bucket_plan(hw, 4 * n_params, 4 * n_params * 4096, 8)
    assert plan.n_buckets >= 2
    assert plan.t_overlapped < plan.t_serial
    assert 0.0 < plan.overlap_efficiency < 1.0
    assert plan.speedup > 1.0
    # the chosen size must actually be the argmin over the candidates
    for cand in cm.BUCKET_BYTES_CANDIDATES:
        other = cm.best_bucket_plan(
            hw, 4 * n_params, 4 * n_params * 4096, 8,
            candidates=(cand,))
        assert plan.t_overlapped <= other.t_overlapped + 1e-12


def test_best_bucket_plan_degenerate_cases():
    hw = cm.A100_SLINGSHOT
    # single bucket -> nothing to overlap -> efficiency exactly 0
    plan = cm.best_bucket_plan(hw, 1 << 20, 1e12, 8,
                               candidates=(1 << 30,))
    assert plan.n_buckets == 1
    assert plan.overlap_efficiency == 0.0
    assert plan.t_overlapped == plan.t_serial
    # uncalibrated compute (compute_tflops=0): backward is free, overlap
    # cannot help, but the planner still returns a valid schedule
    import dataclasses
    hw0 = dataclasses.replace(hw, compute_tflops=0.0)
    plan0 = cm.best_bucket_plan(hw0, 4 * 350e6, 4 * 350e6 * 4096, 8)
    assert plan0.t_backward == 0.0
    assert plan0.t_overlapped >= plan0.t_sync_total
    # single rank: no wire at all
    plan1 = cm.best_bucket_plan(hw, 1 << 24, 1e12, 1)
    assert plan1.t_sync_total == 0.0
    with pytest.raises(ValueError):
        cm.best_bucket_plan(hw, 0, 1e12, 8)


def test_plan_cache_stats_by_op():
    """ISSUE 9 satellite: the plan cache reports hits/misses/entries per
    collective op, so per-bucket plan reuse is observable."""
    from repro.core.comm import (
        GZCommunicator, clear_plan_cache, plan_cache_stats,
    )

    clear_plan_cache()
    comm = GZCommunicator.for_config("data", GZConfig(eb=1e-4), axis_size=8)
    comm.plan("allreduce", 4096)
    comm.plan("allreduce", 4096)
    comm.plan("allgather", 4096)
    stats = plan_cache_stats()
    assert stats["by_op"]["allreduce"] == {
        "hits": 1, "misses": 1, "entries": 1, "hier_entries": 0}
    assert stats["by_op"]["allgather"]["misses"] == 1
    assert stats["hits"] == 1 and stats["misses"] == 2
    clear_plan_cache()
    assert plan_cache_stats()["by_op"] == {}


# --- simulator replay -------------------------------------------------------


def test_sim_allreduce_bucketed_matches_unbucketed():
    """Tiling through the ledger then reassembling must reproduce the
    whole-vector sim bitwise (intring: rank-consistent integer sums), and
    approximate the exact sum within the budget for the lossy sims."""
    r = np.random.default_rng(0)
    n = 4
    shapes = [(40,), (7, 9), (130,)]
    rank_leaves = [
        [r.normal(0, 1e-2, s).astype(np.float32) for s in shapes]
        for _ in range(n)
    ]
    cfg = GZConfig(eb=1e-5, algo="intring")
    outs = simulator.sim_allreduce_bucketed(rank_leaves, 4 * 64, cfg,
                                            algo="intring")
    # reference: one flat intring over the whole ravel
    flats = [np.concatenate([x.reshape(-1) for x in leaves])
             for leaves in rank_leaves]
    ref = simulator.sim_allreduce_intring(flats, cfg)
    for rank in range(n):
        got = np.concatenate([x.reshape(-1) for x in outs[rank]])
        assert np.array_equal(got, ref[rank])
    # hierarchical routing sanity: values near the exact sum
    outs_h = simulator.sim_allreduce_bucketed(
        rank_leaves, 4 * 64, GZConfig(eb=1e-5, algo="redoub"),
        topology=(2, 2))
    exact = [np.sum([rank_leaves[q][i] for q in range(n)], axis=0)
             for i in range(len(shapes))]
    for i in range(len(shapes)):
        assert np.abs(outs_h[0][i] - exact[i]).max() <= 1e-3
