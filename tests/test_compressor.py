"""Compressor-level invariants, incl. hypothesis property tests."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[dev])"
)
from hypothesis import given, settings, strategies as st

from repro.core.compressor import ErrorBoundedLorenzo, FixedRate

COMP = ErrorBoundedLorenzo(capacity_factor=1.1)


def test_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = np.cumsum(rng.normal(0, 0.01, 50_000)).astype(np.float32)
    for eb in [1e-2, 1e-3, 1e-4]:
        c = COMP.compress(jnp.asarray(x), eb)
        assert not bool(c.overflowed())
        y = np.asarray(COMP.decompress(c))
        assert np.abs(x - y).max() <= eb * (1 + 1e-3) + np.abs(x).max() * 2e-7


def test_compression_ratio_on_smooth_data():
    """Paper Table 1 regime: smooth fields at eb=1e-4 compress well."""
    rng = np.random.default_rng(1)
    x = np.cumsum(rng.normal(0, 1e-3, 500_000)).astype(np.float32)
    c = COMP.compress(jnp.asarray(x), 1e-4)
    ratio = x.nbytes / float(np.asarray(c.payload_bytes()))
    assert ratio > 4.0, ratio


def test_decompress_reduce_equals_decompress_then_add():
    rng = np.random.default_rng(2)
    x = np.cumsum(rng.normal(0, 0.01, 10_000)).astype(np.float32)
    acc = rng.normal(0, 1, 10_000).astype(np.float32)
    c = COMP.compress(jnp.asarray(x), 1e-4)
    fused = np.asarray(COMP.decompress_reduce(c, jnp.asarray(acc)))
    manual = acc + np.asarray(COMP.decompress(c))
    np.testing.assert_allclose(fused, manual, rtol=0, atol=1e-6)


def test_fixed_rate_error_unbounded():
    """The [30]-baseline flaw: clamped codes break the error bound."""
    rng = np.random.default_rng(3)
    x = rng.normal(0, 100.0, 4096).astype(np.float32)  # rough data
    eb = 1e-4
    fr = FixedRate(rate_bits=8)
    c = fr.compress(jnp.asarray(x), eb)
    y = np.asarray(fr.decompress(c))
    assert np.abs(x - y).max() > 10 * eb  # error blows way past the bound


def test_non_multiple_of_block_sizes():
    rng = np.random.default_rng(4)
    for n in [1, 7, 255, 256, 257, 1000, 4097]:
        x = rng.normal(0, 1, n).astype(np.float32)
        c = COMP.compress(jnp.asarray(x), 1e-3)
        y = np.asarray(COMP.decompress(c))
        assert y.shape == (n,)
        assert np.abs(x - y).max() <= 1e-3 * (1 + 1e-3)


def test_multidim_input_flattened():
    rng = np.random.default_rng(5)
    x = rng.normal(0, 1, (32, 48)).astype(np.float32)
    c = COMP.compress(jnp.asarray(x), 1e-3)
    y = np.asarray(COMP.decompress(c)).reshape(32, 48)
    assert np.abs(x - y).max() <= 1e-3 * (1 + 1e-3)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 5000),
    scale=st.floats(1e-3, 1e3),
    eb=st.sampled_from([1e-2, 1e-3, 1e-4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_error_bound(n, scale, eb, seed):
    """For any input within the int32 quantization envelope, the bound holds."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(0, scale, n)).astype(np.float32)
    # keep |x|/(2eb) inside int32 (the documented envelope)
    x = np.clip(x, -2e5 * eb * 2, 2e5 * eb * 2)
    c = COMP.compress(jnp.asarray(x), eb)
    y = np.asarray(COMP.decompress(c))
    # bound holds up to f32 relative rounding (~1e-7 * |x|), same as cuSZp
    assert np.abs(x - y).max() <= eb * (1 + 1e-3) + np.abs(x).max() * 2e-7


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), eb=st.sampled_from([1e-3, 1e-4]))
def test_property_idempotent_recompress(seed, eb):
    """compress(decompress(c)) at the same eb reproduces values within eb.

    (This is what bounds error accumulation per lossy hop in collectives.)
    """
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.normal(0, 0.01, 2048)).astype(np.float32)
    c1 = COMP.compress(jnp.asarray(x), eb)
    y1 = COMP.decompress(c1)
    c2 = COMP.compress(y1, eb)
    y2 = np.asarray(COMP.decompress(c2))
    assert np.abs(np.asarray(y1) - y2).max() <= eb * (1 + 1e-3)
