"""Device-count pinning for the multi-device child scripts.

Must be importable BEFORE jax (it only touches os.environ): the children
import it first, pin XLA_FLAGS, and only then import jax / the shared
check bodies.
"""
import os
import re


def pin_device_count(default: int) -> int:
    """Resolve the device count and pin XLA_FLAGS to it.

    An explicit GZ_CHILD_DEVICES (the pytest runners' parameter) always
    wins — an ambient XLA_FLAGS from the developer's shell must not
    silently change what a named test exercises; a pre-set XLA_FLAGS
    count is honored only when GZ_CHILD_DEVICES is absent (the CI leg).
    """
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
    env = os.environ.get("GZ_CHILD_DEVICES")
    n = int(env) if env is not None else (int(m.group(1)) if m else default)
    if m:
        flags = re.sub(r"--xla_force_host_platform_device_count=\d+",
                       f"--xla_force_host_platform_device_count={n}", flags)
    else:
        flags = (flags + f" --xla_force_host_platform_device_count={n}").strip()
    os.environ["XLA_FLAGS"] = flags
    return n
