"""Child: wire-codec equivalence on real multi-device shard_map runs
(ISSUE 8 acceptance).

Run in a subprocess by tests/test_collectives_multidevice.py at N=8 and
(via GZ_CHILD_DEVICES) N=6.  Proves, on actual compressed collective
executions:

  * the DEFAULT config (no codec named) is bitwise-identical — results
    AND provisioned wire bytes — to an explicit ``codec="lorenzo"``
    config: the registry changed nothing for existing callers;
  * ``codec="lorenzo+entropy"`` produces BITWISE the same allreduce
    results as ``codec="lorenzo"`` on both ring and redoub (identical
    quantization grid; the entropy stage is lossless on the codes and
    every reduce hop rounds through the same FMA kernels), and both stay
    within eb of the float64 exact sum;
  * the entropy plan provisions the SAME wire bytes as dense (shared
    capacity: the trimmed stream never exceeds the dense bitpack) while
    its TRUE payload (CollectiveResult-independent, measured via
    ``payload_bytes``) is strictly smaller on smooth data;
  * ``codec="lossless"`` and ``codec="passthrough"`` agree bitwise with
    each other (both exact, same schedule arithmetic) and match the
    uncompressed reference;
  * data movers (broadcast / scatter / allgather / all_to_all) stay
    within eb under the entropy codec;
  * a starved-capacity entropy stream still trips the overflow flag and
    ``on_overflow="fallback"`` recovers the exact psum.

Prints 'OK <name>' per check and an 'ALL OK' sentinel.
"""
from _child_env import pin_device_count

N = pin_device_count(8)

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import comm
from repro.core.collectives import GZConfig
from repro.core.shmap import shard_map

EB = 1e-4
D = 6144  # divisible by every child N (6, 8)
rng = np.random.default_rng(0)
BASE = jnp.asarray(np.cumsum(rng.normal(0, 0.01, (N, D)), axis=1),
                   jnp.float32)
EXACT = np.sum(np.asarray(BASE, np.float64), axis=0)
MESH = Mesh(np.array(jax.devices()[:N]), ("x",))


def _comm(codec="lorenzo", **cfg_kw):
    cfg = GZConfig(eb=EB, codec=codec, **cfg_kw)
    return comm.GZCommunicator("x", config=cfg, axis_size=N)


def _run(c, op, x=BASE, **kw):
    def body(v):
        r = getattr(c, op)(v[0], **kw)
        return r.value[None], r.overflow[None]

    f = jax.jit(shard_map(
        body, mesh=MESH, in_specs=(P("x", None),),
        out_specs=(P("x", None), P("x")),
    ))
    out, ovf = f(x)
    return np.asarray(out), bool(np.any(np.asarray(ovf)))


def ok(name):
    print(f"OK {name}")


# -- default config is bitwise the explicit lorenzo codec -------------------

c_default = comm.GZCommunicator(
    "x", config=GZConfig(eb=EB), axis_size=N
)
c_lorenzo = _comm("lorenzo")
out_d, _ = _run(c_default, "allreduce")
out_l, ovf_l = _run(c_lorenzo, "allreduce")
assert not ovf_l
assert np.array_equal(out_d, out_l), "default != explicit codec='lorenzo'"
pd = c_default.plan("allreduce", (D,))
pl = c_lorenzo.plan("allreduce", (D,))
assert pd is pl, "default and codec='lorenzo' must share one cache entry"
assert pd.codec == "lorenzo" and pd.notes == ()
ok("default-is-lorenzo")

# -- entropy == lorenzo bitwise on both allreduce algorithms ----------------

slack = max(np.abs(EXACT).max(), 1.0) * 1e-6
for algo in ("redoub", "ring"):
    out_a, ovf_a = _run(_comm("lorenzo", algo=algo), "allreduce")
    out_e, ovf_e = _run(_comm("lorenzo+entropy", algo=algo), "allreduce")
    assert not ovf_a and not ovf_e
    assert np.array_equal(out_a, out_e), (
        f"lorenzo+entropy diverged from lorenzo on {algo} "
        f"(maxdiff {np.max(np.abs(out_a - out_e))})"
    )
    err = np.max(np.abs(out_e[0].astype(np.float64) - EXACT))
    assert err <= N * EB + slack, f"{algo} entropy error {err} > bound"
    ok(f"entropy-bitwise-{algo}")

# -- shared provisioning, strictly smaller true payload ---------------------

pe = _comm("lorenzo+entropy").plan("allreduce", (D,))
assert pe.wire_bytes == pl.wire_bytes, (
    "entropy must share the dense provisioning (stream never longer)"
)
comp_l = GZConfig(eb=EB, codec="lorenzo").compressor()
comp_e = GZConfig(eb=EB, codec="lorenzo+entropy").compressor()
x0 = BASE[0]
payload_l = int(jax.device_get(comp_l.compress(x0, EB).payload_bytes()))
payload_e = int(jax.device_get(comp_e.compress(x0, EB).payload_bytes()))
assert payload_e < payload_l, (
    f"entropy payload {payload_e} not < dense {payload_l} on smooth data"
)
ok("entropy-payload-smaller")

# -- exact codecs agree with each other and the reference -------------------

out_x, ovf_x = _run(_comm("lossless"), "allreduce")
out_p, ovf_p = _run(_comm("passthrough"), "allreduce")
assert not ovf_x and not ovf_p
assert np.array_equal(out_x, out_p), "lossless != passthrough (both exact)"
err = np.max(np.abs(out_x[0].astype(np.float64) - EXACT))
assert err <= slack * N, f"exact-codec allreduce error {err}"
ok("exact-codecs-agree")

# -- data movers under the entropy codec ------------------------------------

c_e = _comm("lorenzo+entropy")

out, ovf = _run(c_e, "broadcast")
assert not ovf
assert np.max(np.abs(out - np.asarray(BASE[0])[None, :])) <= EB + slack
ok("entropy-broadcast")

out, ovf = _run(c_e, "scatter")
assert not ovf
chunk = D // N
src = np.asarray(BASE[0])
for r in range(N):
    got = out[r][:chunk]
    want = src[r * chunk:(r + 1) * chunk]
    assert np.max(np.abs(got - want)) <= EB + slack
ok("entropy-scatter")

xg = BASE[:, :2048]
out, ovf = _run(c_e, "allgather", x=xg)
assert not ovf
want = np.asarray(xg).reshape(-1)
assert np.max(np.abs(out[0][: want.size] - want)) <= EB + slack
ok("entropy-allgather")

xa = BASE[:, : (D // N) * N]
out, ovf = _run(c_e, "all_to_all", x=xa)
assert not ovf
want = np.asarray(xa).reshape(N, N, -1).transpose(1, 0, 2).reshape(N, -1)
assert np.max(np.abs(out - want)) <= EB + slack
ok("entropy-all-to-all")

# -- overflow detection + lossless fallback under entropy -------------------

rough = jnp.asarray(rng.normal(0, 100.0, (N, D)), jnp.float32)
c_starved = comm.GZCommunicator(
    "x",
    config=GZConfig(eb=1e-6, capacity_factor=0.02, codec="lorenzo+entropy",
                    on_overflow="fallback"),
    axis_size=N,
)
out, ovf = _run(c_starved, "allreduce", x=rough)
assert ovf, "starved entropy stream must flag overflow"
psum_ref = jax.jit(shard_map(
    lambda v: jax.lax.psum(v[0], "x")[None], mesh=MESH,
    in_specs=(P("x", None),), out_specs=P("x", None),
))(rough)
assert np.array_equal(out, np.asarray(psum_ref)), (
    "fallback must recover the bitwise lax.psum result"
)
ok("entropy-overflow-fallback")

print("ALL OK")
