"""Runs the 8-virtual-device shard_map collective validation.

XLA device count is fixed at first jax init, so this must run in a
subprocess (tests/_mp_collectives_child.py sets
--xla_force_host_platform_device_count=8 before importing jax).
"""
import os
import pathlib
import subprocess
import sys

import pytest

CHILD = pathlib.Path(__file__).parent / "_mp_collectives_child.py"
NONPOW2_CHILD = pathlib.Path(__file__).parent / "_mp_nonpow2_child.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


def _run_child(child, **env):
    proc = subprocess.run(
        [sys.executable, str(child)],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "PYTHONPATH": SRC, **env},
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout


@pytest.mark.slow
def test_collectives_on_8_devices():
    # Includes the non-power-of-two 3/5/6 submesh sweep (ISSUE 4).
    _run_child(CHILD)


@pytest.mark.slow
def test_nonpow2_collectives_on_12_devices():
    # Remainder stage at a full mesh above the 8-device grid: 12 ranks
    # fold 4 into the doubling; the trimmed-slab scatter ships 11 chunk
    # streams through the 16-slot virtual rank space (padding held, never
    # wired).
    _run_child(NONPOW2_CHILD, GZ_CHILD_DEVICES="12")


@pytest.mark.slow
def test_nonpow2_collectives_on_9_devices():
    # ISSUE 5 acceptance point: n=9 was the padded virtual tree's worst
    # case (7/16 slots padding).  The trimmed schedule ships 8 chunk
    # streams; execute-vs-sim byte parity is asserted in the child.
    _run_child(NONPOW2_CHILD, GZ_CHILD_DEVICES="9")
