"""Runs the 8-virtual-device shard_map collective validation.

XLA device count is fixed at first jax init, so this must run in a
subprocess (tests/_mp_collectives_child.py sets
--xla_force_host_platform_device_count=8 before importing jax).
"""
import os
import pathlib
import subprocess
import sys

import pytest

CHILD = pathlib.Path(__file__).parent / "_mp_collectives_child.py"
NONPOW2_CHILD = pathlib.Path(__file__).parent / "_mp_nonpow2_child.py"
HIER_CHILD = pathlib.Path(__file__).parent / "_mp_hier_child.py"
FAULTS_CHILD = pathlib.Path(__file__).parent / "_mp_faults_child.py"
CODECS_CHILD = pathlib.Path(__file__).parent / "_mp_codecs_child.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


def _run_child(child, **env):
    proc = subprocess.run(
        [sys.executable, str(child)],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "PYTHONPATH": SRC, **env},
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout


@pytest.mark.slow
def test_collectives_on_8_devices():
    # Includes the non-power-of-two 3/5/6 submesh sweep (ISSUE 4).
    _run_child(CHILD)


@pytest.mark.slow
def test_nonpow2_collectives_on_12_devices():
    # Remainder stage at a full mesh above the 8-device grid: 12 ranks
    # fold 4 into the doubling; the trimmed-slab scatter ships 11 chunk
    # streams through the 16-slot virtual rank space (padding held, never
    # wired).
    _run_child(NONPOW2_CHILD, GZ_CHILD_DEVICES="12")


@pytest.mark.slow
def test_hier_allreduce_2x3():
    # ISSUE 6 acceptance: the two-level schedule on a non-power-of-two
    # node x local mesh is bitwise the composed per-axis reference, the
    # flat fallback is bitwise the composite-axis schedule, and one
    # trace-read communicator replans across the 2x3 -> 3x2 reshape.
    _run_child(HIER_CHILD, GZ_HIER_TOPOLOGY="2x3")


@pytest.mark.slow
def test_hier_allreduce_3x2():
    # Same checks with the node/local extents swapped: 3 nodes of 2 GPUs
    # resolve a different inter fan-out and shard size than 2 nodes of 3.
    _run_child(HIER_CHILD, GZ_HIER_TOPOLOGY="3x2")


@pytest.mark.slow
def test_faults_child_on_8_devices():
    # ISSUE 7 acceptance: forced overflow / NaN poisoning / wire bitflips
    # are detected and the in-trace lossless fallback recovers bitwise;
    # undetected corruption is fatal inside the child.
    _run_child(FAULTS_CHILD)


@pytest.mark.slow
def test_nonpow2_collectives_on_9_devices():
    # ISSUE 5 acceptance point: n=9 was the padded virtual tree's worst
    # case (7/16 slots padding).  The trimmed schedule ships 8 chunk
    # streams; execute-vs-sim byte parity is asserted in the child.
    _run_child(NONPOW2_CHILD, GZ_CHILD_DEVICES="9")


@pytest.mark.slow
def test_codecs_child_on_8_devices():
    # ISSUE 8 acceptance: codec="lorenzo+entropy" collective results match
    # codec="lorenzo" (bitwise on allreduce — same quantization grid, FMA
    # hop kernels), the default config stays bitwise the pre-registry
    # lorenzo path, and exact codecs agree with the uncompressed schedule.
    _run_child(CODECS_CHILD)


@pytest.mark.slow
def test_codecs_child_on_6_devices():
    # Non-power-of-two leg of the same acceptance point: ring degenerates
    # differently and redoub takes the non-pow2 pre-fold, so the
    # entropy==lorenzo equivalence is re-proven at N=6.
    _run_child(CODECS_CHILD, GZ_CHILD_DEVICES="6")
