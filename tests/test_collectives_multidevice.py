"""Runs the 8-virtual-device shard_map collective validation.

XLA device count is fixed at first jax init, so this must run in a
subprocess (tests/_mp_collectives_child.py sets
--xla_force_host_platform_device_count=8 before importing jax).
"""
import os
import pathlib
import subprocess
import sys

import pytest

CHILD = pathlib.Path(__file__).parent / "_mp_collectives_child.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


@pytest.mark.slow
def test_collectives_on_8_devices():
    proc = subprocess.run(
        [sys.executable, str(CHILD)],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "PYTHONPATH": SRC},
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout
