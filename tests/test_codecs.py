"""Wire-codec registry + Pallas entropy stage (DESIGN.md §10, ISSUE 8).

Deterministic coverage of the codec subsystem:

  * registry contents/validation and the per-codec container protocol;
  * round-trip error <= eb for the lossy codecs, bit-exact round trips
    (NaN/Inf/-0.0 included) for lossless/passthrough, eb=0 semantics;
  * the entropy invariant: the per-sub-block trimmed stream is NEVER
    longer than the dense bitpack of the same codes, and strictly
    shorter on smooth data;
  * fused (Pallas) vs oracle byte identity for the entropy codec;
  * the `codec="lorenzo"` default resolves byte-identically to the
    pre-registry compressor, and `compressor.DEFAULT` still works as a
    deprecation shim;
  * plan-layer threading: Plan.codec/notes, per-codec wire accounting,
    fused-hop downgrade, intring forcing, auto selection from modeled
    and calibrated terms, cache keying + by_codec stats.

The hypothesis sweep over shapes x ebs x codecs lives in
tests/test_codecs_property.py (importorskip'd); the multi-device
equivalence legs live in tests/_mp_codecs_child.py.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import codecs, comm, compressor, cost_model, entropy
from repro.core.collectives import GZConfig
from repro.kernels import ops

EB = 1e-4
# Off-block, exact-block, ragged, multi-tile: the shapes that have caught
# every padding bug in this repo so far.
SHAPES = (100, 256, 1537, 2048, 5000)


@pytest.fixture(autouse=True)
def _fresh_cache():
    comm.clear_plan_cache()
    yield
    comm.clear_plan_cache()


def _smooth(n, seed=0, scale=0.01):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.cumsum(rng.normal(0, scale, n)), jnp.float32)


def _rough(n, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(0, 100.0, n), jnp.float32
    )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_contents():
    names = codecs.codec_names()
    for required in ("lorenzo", "lorenzo+entropy", "lossless", "passthrough"):
        assert required in names
    # passthrough is the explicit-opt-in control codec, never auto-picked.
    assert "passthrough" not in codecs.auto_codecs()
    assert "lorenzo" in codecs.auto_codecs()


def test_registry_validation():
    with pytest.raises(ValueError, match="unknown codec"):
        codecs.get_codec("zstd")
    with pytest.raises(ValueError, match="reserved"):
        codecs.register_codec(dataclasses.replace(
            codecs.get_codec("lorenzo"), name=codecs.AUTO))
    with pytest.raises(ValueError, match="labeled"):
        codecs.register_codec(dataclasses.replace(
            codecs.get_codec("lorenzo"), name="mislabeled"))
    with pytest.raises(TypeError):
        codecs.register_codec("not-a-spec")
    with pytest.raises(ValueError, match="GZConfig.codec"):
        GZConfig(codec="zstd")
    # "auto" is a legal config value (resolved by the plan layer)...
    GZConfig(codec="auto")
    # ...but never a buildable compressor.
    with pytest.raises(ValueError, match="plan layer"):
        codecs.build_compressor("auto", capacity_factor=0.6, fused=True)


def test_register_codec_extensible():
    spec = dataclasses.replace(
        codecs.get_codec("lorenzo"), name="lorenzo2",
        terms=cost_model.CodecTerms("lorenzo2"),
    )
    codecs.register_codec(spec)
    try:
        assert "lorenzo2" in codecs.codec_names()
        comp = codecs.build_compressor(
            "lorenzo2", capacity_factor=0.6, fused=True
        )
        assert isinstance(comp, compressor.ErrorBoundedLorenzo)
    finally:
        codecs._CODECS.pop("lorenzo2", None)


def test_default_shim_is_deprecated():
    with pytest.warns(DeprecationWarning, match="codecs.build_compressor"):
        d = compressor.DEFAULT
    assert isinstance(d, compressor.ErrorBoundedLorenzo)
    with pytest.raises(AttributeError):
        compressor.NO_SUCH_NAME


# ---------------------------------------------------------------------------
# Round trips + container protocol
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("name", ("lorenzo", "lorenzo+entropy"))
def test_lossy_roundtrip_within_eb(name, n):
    comp = codecs.build_compressor(name, capacity_factor=1.2, fused=True)
    x = _smooth(n, seed=n)
    c = comp.compress(x, EB)
    assert not bool(c.overflowed())
    y = comp.decompress(c)
    assert float(jnp.max(jnp.abs(y - x))) <= EB * (1 + 1e-6)
    # The receive side can rebuild the true stream size from metadata.
    assert int(comp.stream_nwords(c.bitwidth, n)) == int(c.nwords)


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("name", ("lossless", "passthrough"))
def test_exact_codecs_roundtrip_bitwise(name, n):
    comp = codecs.build_compressor(name, capacity_factor=1.25, fused=True)
    x = _rough(n, seed=n)
    # Exact codecs must survive every IEEE bit pattern, eb ignored.
    special = np.array([np.nan, np.inf, -np.inf, -0.0, 1e-38], np.float32)
    x = x.at[: special.size].set(jnp.asarray(special))
    c = comp.compress(x, 0.0)  # eb=0 semantics: no divide, no loss
    assert not bool(c.overflowed())
    y = comp.decompress(c)
    np.testing.assert_array_equal(
        np.asarray(x).view(np.uint32), np.asarray(y).view(np.uint32)
    )
    assert int(comp.stream_nwords(c.bitwidth, n)) == int(c.nwords)


@pytest.mark.parametrize("name", codecs.codec_names())
def test_decompress_reduce_matches_composition(name):
    comp = codecs.build_compressor(name, capacity_factor=1.25, fused=True)
    n = 1537
    x, acc = _smooth(n, seed=1), _smooth(n, seed=2)
    c = comp.compress(x, EB)
    got = comp.decompress_reduce(c, acc)
    want = acc + comp.decompress(c)
    # Fused reduce kernels fold acc + q*2eb into an FMA (one rounding);
    # the composition rounds twice — 1-ulp tolerance, not bitwise.
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6
    )


# ---------------------------------------------------------------------------
# The entropy invariant: trimmed stream <= dense bitpack, always
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("eb", (1e-3, 1e-4))
@pytest.mark.parametrize("rough", (False, True))
def test_entropy_never_longer_than_dense(n, eb, rough):
    x = _rough(n, seed=n) if rough else _smooth(n, seed=n)
    # 2.0 = MAX_CAPACITY_FACTOR: rough data at small n needs the headroom
    # (the dense pack of one 19-bit block already exceeds 1.5 * n words).
    dense = codecs.build_compressor("lorenzo", capacity_factor=2.0, fused=True)
    trim = codecs.build_compressor(
        "lorenzo+entropy", capacity_factor=2.0, fused=True
    )
    cd, ct = dense.compress(x, eb), trim.compress(x, eb)
    assert not bool(cd.overflowed()) and not bool(ct.overflowed())
    assert int(ct.nwords) <= int(cd.nwords), (
        "entropy stream longer than dense bitpack — the descriptor-in-"
        "bitwidth-slot invariant is broken"
    )
    if not rough:
        assert int(ct.nwords) < int(cd.nwords), (
            "entropy stage bought nothing on smooth data"
        )
    # Identical quantization: both decode to the same grid points.
    np.testing.assert_array_equal(
        np.asarray(dense.decompress(cd)), np.asarray(trim.decompress(ct))
    )


@pytest.mark.parametrize("n", (100, 1537, 5000))
def test_entropy_fused_matches_oracle_bytes(n):
    x = _smooth(n, seed=n)
    fused = codecs.build_compressor(
        "lorenzo+entropy", capacity_factor=1.2, fused=True
    )
    oracle = dataclasses.replace(fused, fused=False)
    cf, co = fused.compress(x, EB), oracle.compress(x, EB)
    assert int(cf.nwords) == int(co.nwords)
    k = int(cf.nwords)
    np.testing.assert_array_equal(
        np.asarray(cf.packed[:k]), np.asarray(co.packed[:k])
    )
    np.testing.assert_array_equal(
        np.asarray(cf.bitwidth), np.asarray(co.bitwidth)
    )
    np.testing.assert_array_equal(
        np.asarray(cf.anchor), np.asarray(co.anchor)
    )


def test_entropy_descriptor_words_authority():
    """packed_words(desc) (the wire metadata) equals the true scatter
    extent — the receive side's stream_nwords rebuilds exactly it."""
    x = _smooth(2048, seed=9)
    comp = codecs.build_compressor(
        "lorenzo+entropy", capacity_factor=1.2, fused=True
    )
    c = comp.compress(x, EB)
    assert int(entropy.packed_words(c.bitwidth)) == int(c.nwords)
    # And the oracle geometry agrees block by block.
    codes, anchor = entropy.encode_blocks(ops.to_blocks(x), jnp.float32(EB))
    desc = entropy.make_desc(entropy.sub_widths(codes))
    np.testing.assert_array_equal(np.asarray(desc), np.asarray(c.bitwidth))


# ---------------------------------------------------------------------------
# Default-codec identity with the pre-registry path
# ---------------------------------------------------------------------------


def test_default_codec_bytes_identical_to_pre_registry_compressor():
    cfg = GZConfig()
    assert cfg.codec == "lorenzo"
    comp = cfg.compressor()
    legacy = compressor.ErrorBoundedLorenzo(
        capacity_factor=cfg.capacity_factor, fused=cfg.fused
    )
    assert comp == legacy  # frozen dataclasses: same knobs, same kernels
    x = _smooth(4096, seed=4)
    c, cl = comp.compress(x, cfg.eb), legacy.compress(x, cfg.eb)
    np.testing.assert_array_equal(np.asarray(c.packed), np.asarray(cl.packed))
    np.testing.assert_array_equal(
        np.asarray(c.bitwidth), np.asarray(cl.bitwidth)
    )


def test_capacity_authority_shared_by_plan_and_compressor():
    for name in codecs.codec_names():
        for n in SHAPES:
            cap = codecs.codec_capacity_words(name, n, 0.6)
            comp = codecs.build_compressor(
                name, capacity_factor=0.6, fused=True
            )
            c = comp.compress(_smooth(n), EB)
            assert c.packed.shape[0] == cap, (
                f"codec {name!r} at n={n}: plan provisions {cap} words, "
                f"execute ships {c.packed.shape[0]}"
            )


def test_codec_capacity_overrides():
    # lossless provisions the structural worst case (whole blocks @ BLOCK
    # words each) regardless of the factor knob — overflow is impossible.
    assert codecs.codec_capacity_words("lossless", 4096, 0.1) == 4096
    assert codecs.codec_capacity_words("lossless", 100, 0.1) == 256
    assert codecs.codec_capacity_words("lossless", 257, 0.1) == 512
    # ...passthrough provisions structurally too: exactly n words (min 8).
    assert codecs.codec_capacity_words("passthrough", 4096, 0.1) == 4096
    assert codecs.codec_capacity_words("passthrough", 3, 2.0) == 8


# ---------------------------------------------------------------------------
# Plan-layer threading
# ---------------------------------------------------------------------------


def _comm(n=8, **kw):
    kw.setdefault("config", GZConfig(eb=EB))
    return comm.GZCommunicator("x", axis_size=n, **kw)


def test_plan_carries_codec_and_config_roundtrip():
    for name in codecs.codec_names():
        p = _comm(config=GZConfig(eb=EB, codec=name)).plan("allreduce", 8192)
        assert p.codec == name
        assert p.as_config().codec == name


def test_default_plan_unchanged_by_registry():
    p = _comm().plan("allreduce", 8192)
    assert p.codec == "lorenzo" and p.notes == ()
    assert p.fused_hop is True
    # Wire accounting through the codec path is the pre-registry number.
    cap, wire, raw = comm._wire_accounting(
        "allreduce", p.algo, 8192, 8, 0.6, p.pipeline_chunks
    )
    assert (p.capacity_words, p.wire_bytes) == (cap, wire)


def test_fused_hop_downgrade_noted():
    p = _comm(config=GZConfig(eb=EB, codec="lorenzo+entropy")).plan(
        "allreduce", 8192
    )
    assert p.fused_hop is False
    assert any("fused_hop off" in note for note in p.notes)
    assert p.as_config().fused_hop is False


def test_intring_forces_dense_codec():
    p = _comm(
        policy="accuracy", config=GZConfig(eb=EB, codec="lorenzo+entropy")
    ).plan("allreduce", 8192)
    assert p.algo == "intring" and p.codec == "lorenzo"
    assert any("integer wire format" in note for note in p.notes)


def test_auto_codec_concrete_on_plan():
    p = _comm(config=GZConfig(eb=EB, codec="auto")).plan("allreduce", 8192)
    assert p.codec in codecs.auto_codecs()
    assert any("codec auto->" in note for note in p.notes)
    p.as_config().compressor()  # never raises: plans are concrete


def test_auto_codec_under_paper_policy_defaults_dense():
    p = _comm(policy="paper", config=GZConfig(eb=EB, codec="auto")).plan(
        "allreduce", 8192
    )
    assert p.codec == "lorenzo"
    assert any("does not rank" in note for note in p.notes)


def _hw_with_terms(*terms):
    return dataclasses.replace(
        cost_model.TPU_V5E, codec_terms=tuple(terms), name="synthetic"
    )


def test_auto_codec_selects_entropy_when_its_model_wins():
    # Calibrated terms say the entropy wire is 50x smaller while lorenzo
    # barely compresses: the modeled collective time must pick entropy.
    hw = _hw_with_terms(
        cost_model.CodecTerms("lorenzo", ratio_abs=1.01),
        cost_model.CodecTerms("lorenzo+entropy", ratio_abs=50.0),
        cost_model.CodecTerms("lossless", ratio_abs=1.01),
    )
    p = _comm(hw=hw, config=GZConfig(eb=EB, codec="auto")).plan(
        "allreduce", 1 << 20
    )
    assert p.codec == "lorenzo+entropy"
    assert p.codec_ratio == 50.0


def test_auto_codec_selects_dense_when_entropy_model_loses():
    hw = _hw_with_terms(
        cost_model.CodecTerms("lorenzo+entropy", ratio_abs=1.01),
        cost_model.CodecTerms("lossless", ratio_abs=1.01),
    )
    p = _comm(hw=hw, config=GZConfig(eb=EB, codec="auto")).plan(
        "allreduce", 1 << 20
    )
    assert p.codec == "lorenzo"


def test_calibrated_terms_override_registry_defaults():
    hw = _hw_with_terms(cost_model.CodecTerms("lorenzo+entropy",
                                              ratio_abs=7.0))
    p = _comm(hw=hw, config=GZConfig(eb=EB, codec="lorenzo+entropy")).plan(
        "allreduce", 8192
    )
    assert p.codec_ratio == 7.0  # not the registry's ratio_scale model


# ---------------------------------------------------------------------------
# Cache keying + by_codec stats (satellite: one entry per (op, codec))
# ---------------------------------------------------------------------------


def test_one_cache_entry_per_op_codec():
    for name in ("lorenzo", "lorenzo+entropy", "lossless"):
        c = _comm(config=GZConfig(eb=EB, codec=name))
        for _ in range(3):
            c.plan("allreduce", 8192)
            c.plan("scatter", 8192)
    s = comm.plan_cache_stats()
    assert s["entries"] == 6  # 2 ops x 3 codecs
    per_op_codec = {(k[0], k[-1]) for k in s["keys"]}
    assert len(per_op_codec) == 6, "duplicate (op, codec) cache entries"
    for name in ("lorenzo", "lorenzo+entropy", "lossless"):
        rec = s["by_codec"][name]
        assert rec == {"hits": 4, "misses": 2, "entries": 2,
                       "hier_entries": 0}


def test_by_codec_includes_hier_cache():
    h = comm.GZHierCommunicator(
        "n", "l", topology=(2, 4), config=GZConfig(eb=EB, codec="lossless")
    )
    h.plan(1 << 14)
    h.plan(1 << 14)
    rec = comm.plan_cache_stats()["by_codec"]["lossless"]
    assert rec["hier_entries"] == 1
    assert rec["hits"] >= 1  # the second plan() call hit
    # Hier sub-plans resolve through the flat cache under the same codec.
    assert rec["entries"] >= 1


def test_codec_key_appended_last():
    """The child test pins key[:5]; the by_codec stats read key[-1]."""
    _comm(config=GZConfig(eb=EB, codec="lossless")).plan("allreduce", 8192)
    (k,) = comm.plan_cache_stats()["keys"]
    assert k[:5] == ("allreduce", 8192 * 4, "float32", 8, EB)
    assert k[-1] == "lossless"


def test_clear_resets_by_codec():
    _comm().plan("allreduce", 8192)
    comm.clear_plan_cache()
    assert comm.plan_cache_stats()["by_codec"] == {}


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_measure_and_fit_codec_terms():
    measured = comm.measure_codecs(
        GZConfig(eb=EB), sizes=(4096, 16384), reps=1
    )
    assert set(measured) == set(codecs.codec_names())
    for name, m in measured.items():
        assert m["ratio"] > 0
        assert len(m["samples_compress"]) == 2
    # Smooth data: the entropy trim must beat the dense bitpack.
    assert measured["lorenzo+entropy"]["ratio"] > measured["lorenzo"]["ratio"]
    hw = comm.fit_codec_terms(measured, base=cost_model.TPU_V5E)
    fitted = {t.codec for t in hw.codec_terms}
    assert fitted == set(codecs.codec_names())
    for t in hw.codec_terms:
        spec = codecs.get_codec(t.codec)
        if spec.eb_scaled:
            assert t.ratio_abs == 0.0 and t.ratio_scale > 0
        else:
            assert t.ratio_abs >= 1.0
    # The fitted entropy scale must exceed dense's (strictly better wire).
    scale = {t.codec: t.ratio_scale for t in hw.codec_terms}
    assert scale["lorenzo+entropy"] > scale["lorenzo"]
    # And the planner consumes them: terms_for resolves the fitted entry.
    assert hw.terms_for("lorenzo+entropy").ratio_scale == \
        scale["lorenzo+entropy"]
    assert hw.terms_for("nope") is None
