"""grad_sync: subprocess validation on a 2x4 mesh + 1-device fast paths."""
import os
import pathlib
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.grad_sync import SyncConfig, dp_allreduce_grads, fsdp_all_gather

CHILD = pathlib.Path(__file__).parent / "_mp_gradsync_child.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


@pytest.mark.slow
@pytest.mark.parametrize("n_devices", [3, 6, 8])
def test_grad_sync_multi_device(n_devices):
    """ISSUE 9 sweep: bucketed-vs-whole-tree bitwise equality (flat and
    2 x (N/2) hierarchical meshes, incl. a forced-overflow fallback
    bucket), fsdp vjp parity, mark_degraded poisoning and the overlap
    hooks — at N in {3, 6, 8} host devices (odd, even, power of two)."""
    proc = subprocess.run(
        [sys.executable, str(CHILD)],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "PYTHONPATH": SRC,
             "GZ_CHILD_DEVICES": str(n_devices)},
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout


def test_single_device_fast_paths():
    """axis size 1: collectives are identity, vjp is exact."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P
    from repro.core.shmap import shard_map

    g = {"a": jnp.ones((128,)), "b": jnp.arange(64, dtype=jnp.float32)}

    def body(g):
        return dp_allreduce_grads(g, ("data",), SyncConfig())

    out = jax.jit(
        shard_map(body, mesh=mesh, in_specs=({"a": P(None), "b": P(None)},),
                  out_specs={"a": P(None), "b": P(None)})
    )(g)
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(g["a"]))
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(g["b"]))


def test_global_rms_single_psum_and_value():
    """ISSUE 6 satellite: the global RMS over multiple DP axes is ONE
    multi-axis psum (a single reduction tree), not one round per axis —
    the element count is a static trace-time constant, so only the
    sum-of-squares travels.  Structural check on the jaxpr (robust where
    a wall-clock diff would be noise) + value parity vs numpy."""
    from jax.sharding import PartitionSpec as P
    from repro.core.grad_sync import _global_rms
    from repro.core.shmap import shard_map

    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    x = np.cumsum(np.random.default_rng(0).normal(0, 0.01, 512)).astype(
        np.float32)

    def body(v):
        return _global_rms(v, ("data", "pod"))

    jaxpr = str(jax.make_jaxpr(
        shard_map(body, mesh=mesh, in_specs=(P(None),), out_specs=P())
    )(x))
    assert jaxpr.count("psum") == 1, \
        f"expected ONE multi-axis psum, jaxpr has {jaxpr.count('psum')}"

    out = jax.jit(
        shard_map(body, mesh=mesh, in_specs=(P(None),), out_specs=P())
    )(x)
    want = np.sqrt((x.astype(np.float64) ** 2).mean())
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)


def test_multi_axis_sync_values_unchanged_vs_per_axis_loop():
    """Collapsing the sequential per-axis allreduce loop into one
    two-level plan must not change synced values: on a degenerate 1x1
    axis pair both are the identity, and the relative-eb scale (the
    single-psum RMS) must match the old per-axis computation exactly."""
    from jax.sharding import PartitionSpec as P
    from repro.core.shmap import shard_map

    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    g = {"w": jnp.asarray(
        np.random.default_rng(1).normal(0, 1e-3, (64, 32)).astype(
            np.float32)
    )}
    specs = {"w": P(None, None)}

    def body(g):
        return dp_allreduce_grads(g, ("data", "pod"), SyncConfig())

    out = jax.jit(
        shard_map(body, mesh=mesh, in_specs=(specs,), out_specs=specs)
    )(g)
    # One rank total: the sum IS the input; any drift would be a scale /
    # plan-routing bug in the hierarchical path.
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               rtol=1e-6, atol=1e-8)
