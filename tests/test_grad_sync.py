"""grad_sync: subprocess validation on a 2x4 mesh + 1-device fast paths."""
import os
import pathlib
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.grad_sync import SyncConfig, dp_allreduce_grads, fsdp_all_gather

CHILD = pathlib.Path(__file__).parent / "_mp_gradsync_child.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


@pytest.mark.slow
def test_grad_sync_on_2x4_mesh():
    proc = subprocess.run(
        [sys.executable, str(CHILD)],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "PYTHONPATH": SRC},
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL OK" in proc.stdout


def test_single_device_fast_paths():
    """axis size 1: collectives are identity, vjp is exact."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P
    from repro.core.shmap import shard_map

    g = {"a": jnp.ones((128,)), "b": jnp.arange(64, dtype=jnp.float32)}

    def body(g):
        return dp_allreduce_grads(g, ("data",), SyncConfig())

    out = jax.jit(
        shard_map(body, mesh=mesh, in_specs=({"a": P(None), "b": P(None)},),
                  out_specs={"a": P(None), "b": P(None)})
    )(g)
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(g["a"]))
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(g["b"]))
