"""Non-power-of-two axis support (ISSUE 4): remainder-stage layout and
error budget, construction-time knob validation, and the hypothesis
property that the remainder-stage redoub stays inside the end-to-end
error bound across shapes and axis sizes.

Single-process only — plan/budget math and the global-view simulator need
no devices.  The shard_map execute paths get the real multi-device
treatment on 3/5/6-rank submeshes in tests/_mp_collectives_child.py and
on 12 ranks in tests/_mp_nonpow2_child.py.
"""
import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core import error_budget, simulator
from repro.core.collectives import GZConfig, _redoub_layout
from repro.core.grad_sync import SyncConfig


# ---------------------------------------------------------------------------
# Remainder layout + step counts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,p,rem", [
    (2, 2, 0), (3, 2, 1), (4, 4, 0), (5, 4, 1), (6, 4, 2), (7, 4, 3),
    (8, 8, 0), (12, 8, 4), (33, 32, 1),
])
def test_redoub_layout(n, p, rem):
    got_p, got_rem, phys = _redoub_layout(n)
    assert (got_p, got_rem) == (p, rem)
    # phys is a bijection from virtual participants onto the physical
    # ranks that are NOT fold sources (the even halves of the first rem
    # pairs sit out).
    physical = sorted(phys(v) for v in range(p))
    fold_sources = [2 * i for i in range(rem)]
    assert physical == sorted(set(range(n)) - set(fold_sources))


def test_steps_for_values():
    assert [cm.steps_for("redoub", n) for n in (2, 3, 4, 5, 8, 9, 16, 17)] \
        == [1, 2, 2, 3, 3, 4, 4, 5]
    assert cm.steps_for("binomial", 6) == 3
    assert cm.steps_for("ring", 6) == 5
    assert cm.steps_for("intring", 6) == 10
    assert cm.steps_for("direct", 6) == 1
    with pytest.raises(ValueError, match="unknown algo"):
        cm.steps_for("nope", 8)


# ---------------------------------------------------------------------------
# Trimmed-slab binomial schedule (ISSUE 5)
# ---------------------------------------------------------------------------


def test_binomial_slab_table_n9():
    """The acceptance shape: at n=9 the root ships 1+4+2+1 = 8 chunk
    streams (one trimmed boundary exchange in the top round), not the
    padded virtual tree's 15."""
    assert cm.binomial_slab_table(9) == (
        (8, (), (0, 8, 1)),
        (4, (0,), None),
        (2, (0, 4), None),
        (1, (0, 2, 4, 6), None),
    )
    assert cm.scatter_root_chunk_streams(9) == 8


@pytest.mark.parametrize("n", list(range(2, 18)) + [24, 33, 96])
def test_binomial_slab_table_invariants(n):
    table = cm.binomial_slab_table(n)
    assert len(table) == cm.steps_for("binomial", n)
    receivers, total_chunks = [], 0
    trims = 0
    for span, full, trim in table:
        pairs = [(i, i + span, span) for i in full]
        if trim is not None:
            trims += 1
            assert 0 < trim[2] < span  # genuinely trimmed
            pairs.append(trim)
        for snd, rcv, slab in pairs:
            # slab == the real ranks of the receiver's virtual subtree
            assert slab == min(n, rcv + span) - rcv
            assert snd < n and rcv < n  # padding slots never exchange
            receivers.append(rcv)
            total_chunks += slab
    # every non-root rank receives exactly one slab
    assert sorted(receivers) == list(range(1, n))
    # root streams sum to exactly n-1 chunks (the provisioned wire)
    assert cm.scatter_root_chunk_streams(n) == n - 1
    # at most one trimmed exchange per round; none on power-of-two axes
    assert trims <= len(table)
    if n & (n - 1) == 0:
        assert trims == 0
        # pow2: the classic binomial tree, all-full rounds
        assert all(trim is None for _, _, trim in table)


def test_scatter_cost_prices_trimmed_slabs():
    """Non-pow2 scatter must cost LESS than the next pow2 up (it ships
    n-1 < 2**ceil-1 chunk streams of the same chunk size... modulo the
    chunk being D/N) and the pow2 points must be unchanged from the
    classic 2**k halving-slab pricing."""
    D, R, hw = 646e6, 60.0, cm.A100_SLINGSHOT
    for n in (8, 64, 512):  # pow2: identical to the pre-trim formula
        want = cm.t_compress(D, hw) + sum(
            cm.t_net(D * (2**k) / n / R, hw)
            for k in reversed(range(cm.steps_for("binomial", n)))
        ) + cm.t_decompress(D / n, hw)
        assert cm.scatter_binomial_gz(D, n, R, hw) == pytest.approx(want)
    # trimmed wire at fixed chunk size: per-chunk-stream cost comparison —
    # 9 ranks ship 8 streams of D/9, the padded tree shipped 15
    chunk = D / 9
    priced = cm.scatter_binomial_gz(D, 9, R, hw)
    padded = cm.t_compress(D, hw) + sum(
        cm.t_net((2**k) * chunk / R, hw) for k in reversed(range(4))
    ) + cm.t_decompress(chunk, hw)
    assert priced < padded


def test_best_scatter_pipeline_chunks_prefers_depth_on_big_payloads():
    assert cm.best_scatter_pipeline_chunks(646e6, 64, 20.0, cm.TPU_V5E) > 1
    # tiny payloads: per-piece overhead dominates -> sequential
    assert cm.best_scatter_pipeline_chunks(4096, 8, 20.0, cm.TPU_V5E) == 1


def test_lossy_hops_redoub_remainder():
    # pow2: n-1 merge events; non-pow2: n-1 merges + the unfold hop.
    assert error_budget.lossy_hops("allreduce_redoub", 8) == 7
    assert error_budget.lossy_hops("allreduce_redoub", 3) == 3
    assert error_budget.lossy_hops("allreduce_redoub", 6) == 6
    assert error_budget.lossy_hops("allreduce_redoub", 12) == 12
    # redoub never stacks worse than ring at the same n
    for n in range(2, 34):
        assert error_budget.lossy_hops("allreduce_redoub", n) <= \
            error_budget.lossy_hops("allreduce_ring", n)


def test_redoub_cost_charges_remainder_hop():
    """The remainder pre/post stage must make a non-pow2 redoub strictly
    more expensive than the pow2 axis just below it — that is what shifts
    the ring-vs-redoub crossover at non-pow2 N."""
    D = 64 << 20
    for fused in (True, False):
        t8 = cm.allreduce_redoub_gz(D, 8, 20.0, cm.TPU_V5E, fused_hop=fused)
        t12 = cm.allreduce_redoub_gz(D, 12, 20.0, cm.TPU_V5E, fused_hop=fused)
        t16 = cm.allreduce_redoub_gz(D, 16, 20.0, cm.TPU_V5E, fused_hop=fused)
        assert t8 < t12, "remainder stage not priced"
        assert t16 < t12, "non-pow2 must pay the unfold on top of ceil steps"


# ---------------------------------------------------------------------------
# Construction-time knob validation (satellites)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", [0, -1, 3, 6, 12])
def test_gzconfig_rejects_bad_pipeline_chunks(bad):
    with pytest.raises(ValueError, match="pipeline_chunks"):
        GZConfig(pipeline_chunks=bad)


@pytest.mark.parametrize("good", [1, 2, 4, 16])
def test_gzconfig_accepts_pow2_pipeline_chunks(good):
    assert GZConfig(pipeline_chunks=good).pipeline_chunks == good


def test_syncconfig_rejects_bad_pipeline_chunks():
    with pytest.raises(ValueError, match="pipeline_chunks"):
        SyncConfig(pipeline_chunks=3)
    with pytest.raises(ValueError, match="pipeline_chunks"):
        SyncConfig(pipeline_chunks=-2)
    assert SyncConfig(pipeline_chunks=0).pipeline_chunks == 0  # auto depth
    assert SyncConfig(pipeline_chunks=4).pipeline_chunks == 4


def test_dp_allreduce_grads_rejects_empty_axes():
    from repro.core.grad_sync import dp_allreduce_grads

    with pytest.raises(ValueError, match="axis_names is empty"):
        dp_allreduce_grads({"w": np.ones(4, np.float32)}, ())


# ---------------------------------------------------------------------------
# Simulator: remainder-stage redoub within budget — exhaustive small sweep
# over n AND a deterministic shape sweep (off-block / whole-block / ragged
# tails), so the budget soundness is exercised even where hypothesis is
# unavailable; the randomized property version lives in
# tests/test_nonpow2_property.py.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 3, 5, 6, 7, 8, 12])
def test_sim_remainder_redoub_within_budget(n):
    rng = np.random.default_rng(n)
    xs = [np.cumsum(rng.normal(0, 0.01, 2048)).astype(np.float32)
          for _ in range(n)]
    cfg = GZConfig(eb=1e-4, capacity_factor=1.3, worst_case_budget=True)
    outs = simulator.sim_allreduce_redoub(xs, cfg)
    exact = np.sum(xs, axis=0)
    slack = max(np.abs(exact).max(), 1.0) * 1e-6
    for o in outs:
        assert np.abs(o - exact).max() <= 1e-4 + slack


@pytest.mark.parametrize("d", [257, 1024, 1537])
@pytest.mark.parametrize("n", [3, 6, 13])
def test_sim_remainder_redoub_shape_sweep(n, d):
    rng = np.random.default_rng(d * n)
    xs = [np.cumsum(rng.normal(0, 0.01, d)).astype(np.float32)
          for _ in range(n)]
    cfg = GZConfig(eb=1e-3, capacity_factor=1.3, worst_case_budget=True)
    outs = simulator.sim_allreduce_redoub(xs, cfg)
    exact = np.sum(xs, axis=0)
    slack = max(np.abs(exact).max(), 1.0) * 1e-6
    for o in outs:
        assert np.abs(o - exact).max() <= 1e-3 + slack
