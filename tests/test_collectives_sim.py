"""Algorithm correctness on the single-device N-rank simulator.

Validates the collective algorithms' numerics and the error-budget
analysis without a multi-device runtime (the shard_map versions get the
real 8-device treatment in test_collectives_multidevice.py).
"""
import numpy as np
import pytest

from repro.core import error_budget, simulator
from repro.core.collectives import GZConfig

EB = 1e-4


def _ranks(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return [
        np.cumsum(rng.normal(0, 0.01, d)).astype(np.float32) for _ in range(n)
    ]


@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_sim_allreduce_redoub_within_budget(n):
    xs = _ranks(n, 4096)
    cfg = GZConfig(eb=EB, capacity_factor=1.2)
    outs = simulator.sim_allreduce_redoub(xs, cfg)
    exact = np.sum(xs, axis=0)
    slack = np.abs(exact).max() * 1e-6
    for o in outs:
        assert np.abs(o - exact).max() <= EB + slack


@pytest.mark.parametrize("n", [2, 4, 8])
def test_sim_allreduce_ring_within_budget(n):
    xs = _ranks(n, 4096, seed=1)
    cfg = GZConfig(eb=EB, capacity_factor=1.2)
    outs = simulator.sim_allreduce_ring(xs, cfg)
    exact = np.sum(xs, axis=0)
    slack = np.abs(exact).max() * 1e-6
    for o in outs:
        assert np.abs(o - exact).max() <= EB + slack
    # ring AG distributes the same decompressed chunks -> rank-identical
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


def test_sim_intring_error_model():
    n = 8
    xs = _ranks(n, 2048, seed=2)
    cfg = GZConfig(eb=EB)
    outs = simulator.sim_allreduce_intring(xs, cfg)
    exact = np.sum(xs, axis=0)
    for o in outs:
        assert np.abs(o - exact).max() <= n * EB + np.abs(exact).max() * 1e-6
        np.testing.assert_array_equal(o, outs[0])


@pytest.mark.parametrize("n", [4, 8])
def test_sim_reduce_scatter(n):
    xs = _ranks(n, n * 512, seed=3)
    cfg = GZConfig(eb=EB, capacity_factor=1.2)
    outs = simulator.sim_reduce_scatter_ring(xs, cfg)
    exact = np.sum(xs, axis=0)
    hops = error_budget.lossy_hops("reduce_scatter_ring", n)
    slack = np.abs(exact).max() * 1e-6
    for r, o in enumerate(outs):
        want = exact[r * 512 : (r + 1) * 512]
        assert np.abs(o - want).max() <= EB + slack


def test_sim_allgather_single_lossy_hop():
    n = 8
    xs = _ranks(n, 512, seed=4)
    cfg = GZConfig(eb=EB)
    outs = simulator.sim_allgather_ring(xs, cfg)
    want = np.concatenate(xs)
    for o in outs:
        assert np.abs(o - want).max() <= EB + np.abs(want).max() * 2e-7


def test_sim_scatter_and_broadcast():
    n = 8
    rng = np.random.default_rng(5)
    full = np.cumsum(rng.normal(0, 0.01, n * 512)).astype(np.float32)
    cfg = GZConfig(eb=EB)
    outs = simulator.sim_scatter_binomial(full, n, cfg)
    for i, o in enumerate(outs):
        want = full[i * 512 : (i + 1) * 512]
        assert np.abs(o - want).max() <= EB + np.abs(want).max() * 2e-7
    bc = simulator.sim_broadcast_binomial(full, n, cfg)
    for o in bc:
        assert np.abs(o - full).max() <= EB + np.abs(full).max() * 2e-7


@pytest.mark.parametrize("n", [3, 5, 6, 9, 12])
def test_sim_scatter_trimmed_tree_nonpow2(n):
    """The sim replays the trimmed-slab schedule (ISSUE 5): it must
    deliver every rank its chunk within eb at any n, and the trace must
    show each non-root rank receiving exactly its real subtree."""
    from repro.core import cost_model as cm

    rng = np.random.default_rng(n)
    full = np.cumsum(rng.normal(0, 0.01, n * 512)).astype(np.float32)
    cfg = GZConfig(eb=EB, capacity_factor=1.2)
    outs, trace = simulator.sim_scatter_binomial(full, n, cfg,
                                                 return_trace=True)
    for i, o in enumerate(outs):
        want = full[i * 512 : (i + 1) * 512]
        assert np.abs(o - want).max() <= EB + np.abs(want).max() * 2e-7
    assert sorted(trace) == list(range(1, n))  # everyone but root receives
    for rcv, (span, idxs) in trace.items():
        assert idxs == tuple(range(rcv, min(n, rcv + span)))
    # slab chunks shipped by the root == n-1 (the trimmed provisioning)
    assert cm.scatter_root_chunk_streams(n) == n - 1


def test_redoub_fewer_compression_events_than_ring():
    """The paper's performance metric: log N vs N events per rank."""
    for n in [8, 64, 256]:
        assert error_budget.compression_events(
            "allreduce_redoub", n
        ) < error_budget.compression_events("allreduce_ring", n)
