"""Child script: gZ collectives on a LARGE non-power-of-two axis.

The main child (tests/_mp_collectives_child.py) sweeps submesh sizes
3/5/6 inside its 8-device grid; this one covers the acceptance point the
8-device host cannot: a full mesh bigger than the largest power of two
below it (default N=12, override with GZ_CHILD_DEVICES — the CI N=9 leg
is the old padded tree's worst case, 7/16 virtual slots padded), where
the remainder stage folds ranks into the doubling and the trimmed-slab
scatter ships exactly N-1 chunk streams through the ceil(log2 N)-round
tree.  The check bodies are shared with the main child
(_nonpow2_checks.py): allreduce (all three algorithms) vs a lax.psum
oracle, scatter/broadcast vs exact oracles, plan-layer ceil-step wire
accounting.
"""
from _child_env import pin_device_count

N = pin_device_count(12)

import numpy as np
import jax

import _nonpow2_checks as npc

D = 4000  # indivisible by 12: exercises the ring tail padding
mesh = jax.make_mesh((N,), ("x",))
rng = np.random.default_rng(0)

npc.check_allreduce_vs_psum(mesh, "x", N, D, rng)
npc.check_scatter_broadcast(mesh, "x", N, D, rng)
npc.check_plan_accounting("x", N, D)
# ISSUE 5: execute-vs-sim byte parity for the trimmed-slab scatter at a
# large non-pow2 N (N=12 folds 4/16 virtual slots; the N=9 CI leg is the
# worst case, 7/16 padded under the old schedule).
npc.check_scatter_trimmed_parity(mesh, "x", N, rng)
npc.check_scatter_trimmed_parity(mesh, "x", N, rng, pipeline_chunks=2)

print("ALL OK")
