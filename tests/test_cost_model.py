"""Cost model reproduces the paper's qualitative findings (Figs 3,7,9,10)."""
from repro.core import cost_model as cm
from repro.core.selector import select_allreduce

HW = cm.A100_SLINGSHOT


def test_fig3_shape_small_inputs_underutilized():
    """Per-byte compression cost explodes as size shrinks (Fig. 3)."""
    per_byte = [cm.t_compress(s, HW) / s for s in [1e5, 1e6, 1e7, 1e8]]
    assert per_byte == sorted(per_byte, reverse=True)
    # 10 compressions of 1MB are much more expensive than 1 of 10MB
    assert 10 * cm.t_compress(1e6, HW) > 2 * cm.t_compress(1e7, HW)


def test_redoub_beats_ring_at_scale():
    """Paper Fig. 10: ReDoub scales; Ring's D/N chunks starve the GPU."""
    D = 646e6
    assert cm.allreduce_redoub_gz(D, 512, 60, HW) < cm.allreduce_ring_gz(D, 512, 60, HW)
    # and the selector picks it
    assert select_allreduce(int(D), 512, 60, HW) == "redoub"


def test_ring_competitive_when_saturated():
    """Small N keeps chunks big: ring beats NCCL there (Fig. 10, N<=32)."""
    D = 646e6
    ring = cm.allreduce_ring_gz(D, 8, 60, HW)
    nccl = cm.allreduce_uncompressed_ring(D, 8, HW)
    assert ring < nccl


def test_paper_headline_speedups_direction():
    """gZ-ReDoub beats the NCCL analog by >1x at 64-512 GPUs, 646MB."""
    D = 646e6
    for n in [64, 256, 512]:
        gz = cm.allreduce_redoub_gz(D, n, 60, HW)
        nccl = cm.allreduce_uncompressed_ring(D, n, HW)
        assert gz < nccl, (n, gz, nccl)


def test_cprp2p_and_ccoll_slower_than_gz():
    """Fig. 2: the prior-work baselines lose to the gZ designs."""
    D, n, R = 646e6, 64, 60
    gz_ring = cm.allreduce_ring_gz(D, n, R, HW)
    assert cm.allreduce_cprp2p(D, n, R, HW) > gz_ring
    assert cm.allreduce_ccoll(D, n, R, HW) > gz_ring


def test_scatter_speedup_positive():
    """Fig. 11/12: gZ-Scatter beats uncompressed binomial scatter."""
    D = 646e6
    for n in [8, 64, 512]:
        gz = cm.scatter_binomial_gz(D, n, 60, HW)
        base = cm.scatter_uncompressed_binomial(D, n, HW)
        assert gz < base, (n, gz, base)
