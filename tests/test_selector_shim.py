"""The retired core/selector.py shim (ISSUE 10 satellite): selection is
owned by comm's policy registry; the legacy module must warn and must
return bitwise the registry's own evaluators' output."""
import warnings

import pytest

from repro.core import comm, cost_model


def test_shim_warns_on_call():
    import repro.core.selector as selector

    with pytest.warns(DeprecationWarning, match="policy registry"):
        selector.select_allreduce(1 << 20, 8)
    with pytest.warns(DeprecationWarning, match="policy registry"):
        selector.select_allreduce_plan(1 << 20, 8)


def test_shim_output_pins_policy_output():
    import repro.core.selector as selector

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for d_bytes in (1 << 14, 1 << 20, 1 << 26):
            for n in (2, 4, 8, 16, 33):
                assert selector.select_allreduce(d_bytes, n) == \
                    comm.select_allreduce(d_bytes, n)
                assert selector.select_allreduce_plan(d_bytes, n) == \
                    comm.select_allreduce_plan(d_bytes, n)
                assert selector.select_allreduce(
                    d_bytes, n, allow_beyond_paper=True
                ) == comm.select_allreduce(d_bytes, n, allow_beyond_paper=True)


def test_shim_matches_paper_policy_through_plan():
    """The 'paper' policy resolves plans via the same evaluator the shim
    re-exports: a paper-policy plan's algo must equal the shim's pick."""
    import repro.core.selector as selector

    for d_elems in (4096, 1 << 18):
        c = comm.GZCommunicator("i", axis_size=8, policy="paper")
        plan = c.plan("allreduce", (d_elems,), "float32")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            want = selector.select_allreduce(
                d_elems * 4, 8, ratio=c.ratio, hw=c.hw)
        assert plan.algo == want


def test_shim_signature_defaults_unchanged():
    """The shim forwards verbatim: same defaults, same keyword surface
    (functools.wraps preserves the comm evaluators' signatures)."""
    import inspect

    import repro.core.selector as selector

    assert inspect.signature(selector.select_allreduce) == \
        inspect.signature(comm.select_allreduce)
    assert inspect.signature(selector.select_allreduce_plan) == \
        inspect.signature(comm.select_allreduce_plan)
    sig = inspect.signature(selector.select_allreduce)
    assert sig.parameters["ratio"].default == 20.0
    assert sig.parameters["hw"].default is cost_model.TPU_V5E
