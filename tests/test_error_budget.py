"""Property tests: error-budget allocation is a sound end-to-end bound."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[dev])"
)
from hypothesis import given, settings, strategies as st

from repro.core import error_budget, simulator
from repro.core.collectives import GZConfig


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([2, 3, 4, 6, 8, 12]),  # non-pow2: remainder stage
    eb=st.sampled_from([1e-3, 1e-4]),
    seed=st.integers(0, 1000),
)
def test_property_redoub_budget_sound(n, eb, seed):
    rng = np.random.default_rng(seed)
    xs = [
        np.cumsum(rng.normal(0, 0.01, 1024)).astype(np.float32) for _ in range(n)
    ]
    cfg = GZConfig(eb=eb, capacity_factor=1.3, worst_case_budget=True)
    outs = simulator.sim_allreduce_redoub(xs, cfg)
    exact = np.sum(xs, axis=0)
    slack = max(np.abs(exact).max(), 1.0) * 1e-6
    for o in outs:
        assert np.abs(o - exact).max() <= eb + slack


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([2, 4, 8]), seed=st.integers(0, 1000))
def test_property_ring_budget_sound(n, seed):
    eb = 1e-3
    rng = np.random.default_rng(seed)
    xs = [
        np.cumsum(rng.normal(0, 0.01, 1024)).astype(np.float32) for _ in range(n)
    ]
    cfg = GZConfig(eb=eb, capacity_factor=1.3, worst_case_budget=True)
    outs = simulator.sim_allreduce_ring(xs, cfg)
    exact = np.sum(xs, axis=0)
    slack = max(np.abs(exact).max(), 1.0) * 1e-6
    for o in outs:
        assert np.abs(o - exact).max() <= eb + slack


def test_statistical_budget_tighter_but_usually_fine():
    """sqrt-allocation (paper's statistical argument): empirically the
    error stays within eb_total even though the hard bound doesn't."""
    n, eb = 16, 1e-4
    rng = np.random.default_rng(0)
    xs = [
        np.cumsum(rng.normal(0, 0.01, 8192)).astype(np.float32) for _ in range(n)
    ]
    cfg = GZConfig(eb=eb, capacity_factor=1.3, worst_case_budget=False)
    outs = simulator.sim_allreduce_redoub(xs, cfg)
    exact = np.sum(xs, axis=0)
    err = max(np.abs(o - exact).max() for o in outs)
    # statistical allocation: per-stage eb = eb/sqrt(N-1); zero-mean errors
    # random-walk, so observed error ~ eb, far under the hard bound
    assert err <= 3 * eb, err


def test_hop_counts_monotone_and_documented():
    for algo in ["allreduce_redoub", "allreduce_ring", "reduce_scatter_ring"]:
        hops = [error_budget.lossy_hops(algo, n) for n in [2, 4, 8, 16]]
        assert hops == sorted(hops)
    for algo in ["allgather_ring", "scatter_binomial", "broadcast_binomial"]:
        assert error_budget.lossy_hops(algo, 64) == 1
    assert error_budget.allocate(1e-3, "allreduce_redoub", 8) == 1e-3 / 7
