"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import lorenzo, ops, ref

EBS = [1e-2, 1e-3, 1e-4]


def _field(rng, n):
    """Smooth 'scientific' field plus some rough noise and exact zeros."""
    smooth = np.cumsum(rng.normal(0, 0.02, n))
    rough = rng.normal(0, 1.0, n) * (rng.random(n) < 0.05)
    out = (smooth + rough).astype(np.float32)
    out[:: max(n // 13, 1)] = 0.0
    return out


@pytest.mark.parametrize("eb", EBS)
@pytest.mark.parametrize("rows", [8, 16, 64])
def test_quantize_matches_ref(eb, rows):
    rng = np.random.default_rng(rows)
    x = _field(rng, rows * lorenzo.BLOCK).reshape(rows, lorenzo.BLOCK)
    ck, bk, ak = ops.quantize(jnp.asarray(x), eb)
    cr, br, ar = ref.quantize_ref(jnp.asarray(x), jnp.float32(eb))
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(bk), np.asarray(br))
    np.testing.assert_array_equal(np.asarray(ak), np.asarray(ar))


@pytest.mark.parametrize("eb", EBS)
@pytest.mark.parametrize("rows", [8, 32])
def test_dequantize_matches_ref(eb, rows):
    rng = np.random.default_rng(rows + 1)
    x = _field(rng, rows * lorenzo.BLOCK).reshape(rows, lorenzo.BLOCK)
    codes, _, anchor = ref.quantize_ref(jnp.asarray(x), jnp.float32(eb))
    dk = ops.dequantize(codes, anchor, eb)
    dr = ref.dequantize_ref(codes, anchor, jnp.float32(eb))
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), rtol=0, atol=0)


@pytest.mark.parametrize("eb", EBS)
def test_fused_dequantize_reduce_matches_ref(eb):
    rows = 16
    rng = np.random.default_rng(7)
    x = _field(rng, rows * lorenzo.BLOCK).reshape(rows, lorenzo.BLOCK)
    acc = rng.normal(0, 1, x.shape).astype(np.float32)
    codes, _, anchor = ref.quantize_ref(jnp.asarray(x), jnp.float32(eb))
    got = ops.dequantize_reduce(codes, anchor, eb, jnp.asarray(acc))
    want = ref.dequantize_reduce_ref(codes, anchor, jnp.float32(eb), jnp.asarray(acc))
    # fused multiply-add ordering differs from the two-op oracle: 1-ulp slack
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-6)


@pytest.mark.parametrize("eb", EBS)
@pytest.mark.parametrize("rows", [8, 24])
def test_error_bound_holds_end_to_end(eb, rows):
    """The fundamental compressor invariant: |x - x'| <= eb."""
    rng = np.random.default_rng(rows)
    x = _field(rng, rows * lorenzo.BLOCK).reshape(rows, lorenzo.BLOCK)
    codes, _, anchor = ops.quantize(jnp.asarray(x), eb)
    x2 = np.asarray(ops.dequantize(codes, anchor, eb))
    # eb plus f32 relative rounding of q*2eb for large |x|
    assert np.abs(x - x2).max() <= eb * (1 + 1e-3) + np.abs(x).max() * 2e-7


def test_bitwidth_exact_at_powers_of_two():
    """Integer bitwidth computation has no float-log edge cases."""
    for v in [0, 1, 2, 3, 4, 7, 8, 255, 256, (1 << 30) - 1, 1 << 30, (1 << 31)]:
        got = int(ref.bitwidth_of(jnp.asarray([np.uint32(v)]))[0])
        want = v.bit_length()
        assert got == want, (v, got, want)
