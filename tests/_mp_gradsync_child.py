"""Child: grad_sync + FSDP gather/scatter on a 2x4 virtual mesh."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.collectives import GZConfig
from repro.core.grad_sync import (
    SyncConfig,
    dp_allreduce_grads,
    fsdp_all_gather,
    fsdp_reduce_scatter,
)
from repro.core.shmap import shard_map

mesh = jax.make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)

# --- dp_allreduce_grads over a pytree, hierarchical (data, pod) ---
grads = {
    "w": rng.normal(0, 1e-3, (8, 64, 128)).astype(np.float32),
    "b": rng.normal(0, 1e-3, (8, 128)).astype(np.float32),
}
exact = {k: v.sum(axis=0) for k, v in grads.items()}

sync = SyncConfig(
    gz=GZConfig(eb=1e-5, algo="redoub", capacity_factor=1.2),
    relative_eb=True,
    chunk=4096,
)


def body(g):
    g = jax.tree.map(lambda a: a[0], g)
    out = dp_allreduce_grads(g, ("data", "pod"), sync)
    return jax.tree.map(lambda a: a[None], out)


specs = {
    "w": P(("pod", "data"), None, None),
    "b": P(("pod", "data"), None),
}
f = jax.jit(shard_map(body, mesh=mesh, in_specs=(specs,), out_specs=specs))
out = jax.tree.map(np.asarray, f(grads))
for k in grads:
    rms = np.sqrt((exact[k] ** 2).mean())
    err = np.abs(out[k] - exact[k][None]).max()
    # relative eb: bound scales with the global grad RMS; statistical budget
    assert err <= 3 * 1e-5 * max(rms, 1e-3) * 8 + 1e-7, (k, err, rms)
    print(f"OK dp_allreduce {k} err={err:.3e} rms={rms:.3e}")

# --- fsdp gather fwd + custom vjp bwd ---
w_full = rng.normal(0, 0.02, (32, 256)).astype(np.float32)
sync_fsdp = SyncConfig(gz=GZConfig(eb=1e-6, capacity_factor=1.2), relative_eb=False)


def loss_fn(w_shard, t):
    w = fsdp_all_gather(w_shard, "data", sync_fsdp)
    return jnp.sum((w - t) ** 2)


def fsdp_body(w, t):
    l, g = jax.value_and_grad(loss_fn)(w, t)
    return l, g


t_full = rng.normal(0, 0.02, (32, 256)).astype(np.float32)
f = jax.jit(
    shard_map(
        fsdp_body,
        mesh=mesh,
        in_specs=(P("data", None), P(None, None)),
        out_specs=(P(), P("data", None)),
    )
)
l, g = f(w_full, t_full)
l = np.asarray(l)
g = np.asarray(g)
want_l = ((w_full - t_full) ** 2).sum()
# every data rank computes the same replicated loss, so the reduce-scatter
# sums 4 identical cotangents (standard FSDP semantics): grad = n_data * 2(w-t)
want_g = 4 * 2 * (w_full - t_full)
assert np.allclose(l, want_l, rtol=1e-3), (l, want_l)
err = np.abs(g - want_g).max()
assert err <= 5e-4, err


# equivalence vs the uncompressed lax path
def loss_fn_plain(w_shard, t):
    w = fsdp_all_gather(w_shard, "data", None)
    return jnp.sum((w - t) ** 2)


f_plain = jax.jit(
    shard_map(
        lambda w, t: jax.value_and_grad(loss_fn_plain)(w, t),
        mesh=mesh,
        in_specs=(P("data", None), P(None, None)),
        out_specs=(P(), P("data", None)),
    )
)
l2, g2 = f_plain(w_full, t_full)
assert np.allclose(np.asarray(l2), l, rtol=1e-4)
gerr = np.abs(np.asarray(g2) - g).max()
assert gerr <= 5e-4, gerr
print(f"OK fsdp gather/vjp grad_err={err:.3e} vs_plain={gerr:.3e}")

print("ALL OK")
