"""Child: grad_sync + FSDP gather/scatter on N virtual host devices.

Run with GZ_CHILD_DEVICES in {3, 6, 8} (default 8).  Checks, in order:

  1. dp_allreduce_grads error bound on a flat (N,) data mesh.
  2. ISSUE 9 bitwise contract: the bucketed ledger path equals the
     whole-tree ravel reference EXACTLY (np.array_equal) on a multi-leaf
     pytree spanning several buckets — flat mesh AND (for even N) the
     2 x (N/2) hierarchical mesh with the two-level communicator.
  3. Same bitwise contract under a forced capacity overflow with
     on_overflow="fallback" (the lossless recovery bucket).
  4. by-op plan-cache stats see the allreduce entries.
  5. FSDP gather forward + custom_vjp backward vs the plain lax path,
     plus the mark_degraded NaN poisoning satellite: a forced-overflow
     reduce-scatter cotangent arrives NaN-marked and the training loop's
     per-leaf nonfinite probe catches it.
  6. Overlap hooks: value_and_grad through _install_bucket_hooks on a
     psum-signature tree is bitwise the post-hoc _sync_grads result, and
     the token cotangent raises the degraded flag on a poisoned rank.
"""
import _child_env

N = _child_env.pin_device_count(8)

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.collectives import GZConfig
from repro.core.comm import clear_plan_cache, plan_cache_stats
from repro.core.grad_sync import (
    SyncConfig,
    _dp_allreduce_whole_tree_stats,
    dp_allreduce_grads,
    dp_allreduce_grads_stats,
    fsdp_all_gather,
)
from repro.core.shmap import shard_map
from repro.launch.training import _install_bucket_hooks, _sync_grads

rng = np.random.default_rng(0)
clear_plan_cache()

mesh = jax.make_mesh((N,), ("data",))

# --- 1. dp_allreduce_grads error bound, flat (N,) mesh ---
grads = {
    "w": rng.normal(0, 1e-3, (N, 64, 128)).astype(np.float32),
    "b": rng.normal(0, 1e-3, (N, 128)).astype(np.float32),
}
exact = {k: v.sum(axis=0) for k, v in grads.items()}

sync = SyncConfig(
    gz=GZConfig(eb=1e-5, algo="redoub", capacity_factor=1.2),
    relative_eb=True,
    bucket_bytes=16384,
)


def body(g):
    g = jax.tree.map(lambda a: a[0], g)
    out = dp_allreduce_grads(g, ("data",), sync)
    return jax.tree.map(lambda a: a[None], out)


specs = {"w": P("data", None, None), "b": P("data", None)}
f = jax.jit(shard_map(body, mesh=mesh, in_specs=(specs,), out_specs=specs))
out = jax.tree.map(np.asarray, f(grads))
for k in grads:
    rms = np.sqrt((exact[k] ** 2).mean())
    err = np.abs(out[k] - exact[k][None]).max()
    # relative eb: bound scales with the global grad RMS; statistical budget
    assert err <= 3 * 1e-5 * max(rms, 1e-3) * N + 1e-7, (k, err, rms)
    print(f"OK dp_allreduce {k} err={err:.3e} rms={rms:.3e}")


# --- 2. bitwise: bucketed ledger path == whole-tree ravel reference ---
# Multi-leaf tree spanning several 4096-element buckets, with a leaf
# boundary crossing a bucket boundary and a ragged padded tail.
tree_shapes = {"a": (3000,), "b": (50, 50), "c": (64, 17), "d": (5000,)}


def _mk_tree(seed):
    r = np.random.default_rng(seed)
    return {
        k: r.normal(0, 1e-3, (N,) + s).astype(np.float32)
        for k, s in tree_shapes.items()
    }


def _bitwise_check(mesh, axes, in_specs, sync_cfg, tree, tag):
    def both(g):
        g = jax.tree.map(lambda a: a[0], g)
        bk, st = dp_allreduce_grads_stats(g, axes, sync_cfg)
        wt, st_ref = _dp_allreduce_whole_tree_stats(g, axes, sync_cfg)
        side["stats"], side["stats_ref"] = st, st_ref
        pack = lambda t: jax.tree.map(lambda a: a[None], t)
        return pack(bk), pack(wt), st.overflow, st.nonfinite

    side = {}
    fb = jax.jit(shard_map(
        both, mesh=mesh, in_specs=(in_specs,),
        out_specs=(in_specs, in_specs, P(), P()),
    ))
    bk, wt, ovf, nf = fb(tree)
    for k in tree:
        a, b = np.asarray(bk[k]), np.asarray(wt[k])
        assert np.array_equal(a, b), (
            tag, k, np.abs(a - b).max(), "bucketed != whole-tree")
    st, st_ref = side["stats"], side["stats_ref"]
    assert st.n_buckets == st_ref.n_buckets > 1, (st, st_ref)
    assert st.wire_bytes == st_ref.wire_bytes > 0, (st, st_ref)
    print(f"OK bitwise {tag} n_buckets={st.n_buckets} "
          f"wire={st.wire_bytes} ovf={bool(np.asarray(ovf))}")
    return bool(np.asarray(ovf))


tree = _mk_tree(1)
tspecs = {k: P(("data",), *([None] * len(s)))
          for k, s in tree_shapes.items()}
ovf = _bitwise_check(mesh, ("data",), tspecs, sync, tree, f"flat N={N}")
assert not ovf

# hierarchical 2 x (N/2) mesh: same contract through the two-level plan
if N % 2 == 0 and N >= 4:
    hmesh = jax.make_mesh((2, N // 2), ("pod", "data"))
    htree = _mk_tree(2)
    hspecs = {k: P(("pod", "data"), *([None] * len(s)))
              for k, s in tree_shapes.items()}
    ovf = _bitwise_check(
        hmesh, ("data", "pod"), hspecs, sync, htree, f"hier 2x{N // 2}")
    assert not ovf
else:
    print(f"SKIP hier (N={N} odd)")

# --- 3. forced-overflow fallback bucket stays bitwise-identical ---
sync_ovf = SyncConfig(
    gz=GZConfig(eb=1e-9, algo="redoub", capacity_factor=0.02,
                on_overflow="fallback"),
    relative_eb=True,
    bucket_bytes=16384,
)
ovf = _bitwise_check(
    mesh, ("data",), tspecs, sync_ovf, _mk_tree(3), "fallback-overflow")
assert ovf, "capacity_factor=0.02 must force an overflow"

# --- 4. by-op plan cache stats ---
stats = plan_cache_stats()
assert stats["by_op"].get("allreduce", {}).get("misses", 0) > 0, stats
assert (stats["by_op"]["allreduce"]["entries"]
        + stats["by_op"]["allreduce"].get("hier_entries", 0)) > 0, stats
print("OK by_op stats", {k: v["misses"] for k, v in stats["by_op"].items()})

# --- 5. fsdp gather fwd + custom vjp bwd ---
w_full = rng.normal(0, 0.02, (8 * N, 256)).astype(np.float32)
sync_fsdp = SyncConfig(gz=GZConfig(eb=1e-6, capacity_factor=1.2),
                       relative_eb=False)


def loss_fn(w_shard, t):
    w = fsdp_all_gather(w_shard, "data", sync_fsdp)
    return jnp.sum((w - t) ** 2)


def fsdp_body(w, t):
    l, g = jax.value_and_grad(loss_fn)(w, t)
    return l, g


t_full = rng.normal(0, 0.02, (8 * N, 256)).astype(np.float32)
f = jax.jit(
    shard_map(
        fsdp_body,
        mesh=mesh,
        in_specs=(P("data", None), P(None, None)),
        out_specs=(P(), P("data", None)),
    )
)
l, g = f(w_full, t_full)
l = np.asarray(l)
g = np.asarray(g)
want_l = ((w_full - t_full) ** 2).sum()
# every data rank computes the same replicated loss, so the reduce-scatter
# sums N identical cotangents (standard FSDP semantics): grad = N * 2(w-t)
want_g = N * 2 * (w_full - t_full)
assert np.allclose(l, want_l, rtol=1e-3), (l, want_l)
err = np.abs(g - want_g).max()
assert err <= 5e-4 * N, err


# equivalence vs the uncompressed lax path
def loss_fn_plain(w_shard, t):
    w = fsdp_all_gather(w_shard, "data", None)
    return jnp.sum((w - t) ** 2)


f_plain = jax.jit(
    shard_map(
        lambda w, t: jax.value_and_grad(loss_fn_plain)(w, t),
        mesh=mesh,
        in_specs=(P("data", None), P(None, None)),
        out_specs=(P(), P("data", None)),
    )
)
l2, g2 = f_plain(w_full, t_full)
assert np.allclose(np.asarray(l2), l, rtol=1e-4)
gerr = np.abs(np.asarray(g2) - g).max()
assert gerr <= 5e-4 * N, gerr
print(f"OK fsdp gather/vjp grad_err={err:.3e} vs_plain={gerr:.3e}")

# mark_degraded satellite: a forced-overflow reduce-scatter cotangent is
# NaN-marked, and the _sync_grads per-leaf probe raises the degraded bit
sync_mark = SyncConfig(
    gz=GZConfig(eb=1e-9, capacity_factor=0.02, on_overflow="flag"),
    relative_eb=False, mark_degraded=True,
)


def degraded_body(w, t):
    def lf(w_shard):
        return jnp.sum((fsdp_all_gather(w_shard, "data", sync_mark) - t) ** 2)

    g = jax.grad(lf)(w)
    synced, flag = _sync_grads(
        {"w": g}, {"w": P("data", None)}, ("data",), {})
    return jnp.any(~jnp.isfinite(g)), flag


f_mark = jax.jit(shard_map(
    degraded_body, mesh=mesh,
    in_specs=(P("data", None), P(None, None)), out_specs=(P(), P()),
))
has_nan, flag = f_mark(w_full, t_full)
assert bool(np.asarray(has_nan)), "mark_degraded should NaN-poison the grad"
assert bool(np.asarray(flag)), "_sync_grads probe must catch the NaN mark"
print("OK mark_degraded NaN mark reaches the _sync_grads probe")

# --- 6. overlap hooks == post-hoc _sync_grads (psum signature, bitwise) ---
params = {
    "w1": rng.normal(0, 0.02, (300, 7)).astype(np.float32),
    "w2": rng.normal(0, 0.02, (41,)).astype(np.float32),
    "w3": rng.normal(0, 0.02, (9, 9)).astype(np.float32),
}
coef = {k: rng.normal(0, 1.0, v.shape).astype(np.float32) for k, v in params.items()}
pspecs = {k: P(*([None] * params[k].ndim)) for k in params}


def hook_body(p, c, r):
    # per-rank distinct loss so the psum'd grads are nontrivial
    def lf(p, tok):
        hooked, tok_out, _ = _install_bucket_hooks(
            p, pspecs, ("data",), {}, 1024, tok)
        loss = sum(jnp.sum(h * cc * (1.0 + r))
                   for h, cc in zip(jax.tree.leaves(hooked),
                                    jax.tree.leaves(c)))
        return loss + 0.0 * tok_out

    (g, g_tok) = jax.grad(lf, argnums=(0, 1))(p, jnp.zeros((), jnp.float32))
    ref, flag = _sync_grads(
        jax.tree.map(lambda cc: cc * (1.0 + r), c), pspecs, ("data",), {})
    return g, ref, g_tok, flag


rank_r = np.arange(N, dtype=np.float32)
f_hook = jax.jit(shard_map(
    hook_body, mesh=mesh,
    in_specs=(pspecs, pspecs, P("data")),
    out_specs=(pspecs, pspecs, P(), P()),
))
g, ref, g_tok, flag = f_hook(params, coef, rank_r)
for k in params:
    assert np.array_equal(np.asarray(g[k]), np.asarray(ref[k])), (
        k, "hooked grads != _sync_grads")
assert float(np.asarray(g_tok)) == 0.0
assert not bool(np.asarray(flag))
print("OK overlap hooks bitwise == _sync_grads, clean token")


def hook_poison_body(p, c):
    def lf(p, tok):
        hooked, tok_out, _ = _install_bucket_hooks(
            p, pspecs, ("data",), {}, 1024, tok)
        loss = sum(jnp.sum(h * cc)
                   for h, cc in zip(jax.tree.leaves(hooked),
                                    jax.tree.leaves(c)))
        return loss + 0.0 * tok_out

    _, g_tok = jax.grad(lf, argnums=(0, 1))(p, jnp.zeros((), jnp.float32))
    return g_tok


poisoned = dict(coef)
poisoned["w2"] = np.full_like(coef["w2"], np.nan)
g_tok = jax.jit(shard_map(
    hook_poison_body, mesh=mesh,
    in_specs=(pspecs, pspecs), out_specs=P(),
))(params, poisoned)
assert float(np.asarray(g_tok)) > 0, "NaN cotangent must raise the token"
print("OK overlap hooks token flags a poisoned cotangent")

print("ALL OK")
