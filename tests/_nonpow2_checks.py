"""Shared non-power-of-two check bodies for the multi-device children.

Imported (as a sibling module, sys.path[0] == tests/) by both
tests/_mp_collectives_child.py (3/5/6-rank submeshes inside the 8-device
grid, and the whole-mesh N=6 CI leg) and tests/_mp_nonpow2_child.py
(full 12-rank mesh), so the two subprocess legs cannot drift apart.
Import only AFTER the child has pinned XLA_FLAGS — this module imports
jax.  Every check prints one 'OK ...' line and raises on failure.
"""
import numpy as np
import jax
from jax.sharding import PartitionSpec as P

from repro.core import cost_model, error_budget
from repro.core.collectives import GZConfig, gz_allreduce, gz_broadcast, gz_scatter
from repro.core.comm import GZCommunicator, _stream_bytes
from repro.core.shmap import shard_map

EB = 1e-4
CAPACITY = 1.2


def _shmap(f, in_specs, out_specs, mesh):
    return jax.jit(
        shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    )


def _field(rng, shape):
    """Smooth per-rank fields (the paper's RTM-like regime)."""
    return np.cumsum(rng.normal(0, 0.01, shape), axis=-1).astype(np.float32)


def check_allreduce_vs_psum(mesh, axis, n, d, rng):
    """redoub (remainder stage) / ring / intring vs the lax.psum oracle,
    within the configured error bound, no capacity overflow."""
    data = _field(rng, (n, d))
    oracle = np.asarray(
        _shmap(lambda x: jax.lax.psum(x[0], axis)[None],
               (P(axis, None),), P(axis, None), mesh)(data)
    )[0]
    for algo, tol_hops in (("redoub", 1.05), ("ring", 1.05),
                           ("intring", n * 1.05)):
        cfg = GZConfig(eb=EB, algo=algo, capacity_factor=CAPACITY)

        def body(x, c=cfg):
            out, ovf = gz_allreduce(x[0], axis, c, return_info=True)
            return out[None], ovf[None]

        out, ovf = _shmap(
            body, (P(axis, None),), (P(axis, None), P(axis)), mesh
        )(data)
        out = np.asarray(out)
        assert not np.asarray(ovf).any(), f"{algo} n={n}: capacity overflow"
        err = np.abs(out - oracle[None]).max()
        bound = EB * tol_hops + np.abs(oracle).max() * 1e-6
        assert err <= bound, f"{algo} n={n}: err {err} > {bound}"
        print(f"OK nonpow2 allreduce_{algo} n={n} err={err:.2e}")


def check_scatter_broadcast(mesh, axis, n, d_bcast, rng):
    """Virtual-pow2-tree scatter and ceil-log broadcast vs exact oracles
    (one lossy hop each); broadcast additionally rank-identical."""
    cfg = GZConfig(eb=EB, capacity_factor=CAPACITY)
    full = _field(rng, n * 512)
    xin = np.zeros((n, n * 512), np.float32)
    xin[0] = full
    out = np.asarray(
        _shmap(lambda x: gz_scatter(x[0], axis, cfg),
               (P(axis, None),), P(axis), mesh)(xin)
    ).reshape(n, 512)
    err = np.abs(out - full.reshape(n, 512)).max()
    assert err <= EB * 1.001 + np.abs(full).max() * 2e-7, err
    print(f"OK nonpow2 scatter n={n} err={err:.2e}")

    xb = np.zeros((n, d_bcast), np.float32)
    xb[0] = _field(rng, d_bcast)
    out = np.asarray(
        _shmap(lambda x: gz_broadcast(x[0], axis, cfg)[None],
               (P(axis, None),), P(axis, None), mesh)(xb)
    )
    err = np.abs(out - xb[0][None]).max()
    assert err <= EB * 1.001 + np.abs(xb[0]).max() * 2e-7, err
    assert np.abs(out - out[0:1]).max() == 0.0
    print(f"OK nonpow2 broadcast n={n} err={err:.2e}")


def check_plan_accounting(axis, n, d):
    """Plan-side accounting: ceil step counts agreeing with the cost
    model's single authority (the floor-log2 regression), and the
    remainder hop charged to the per-stage budget."""
    comm = GZCommunicator(
        axis, config=GZConfig(eb=EB, algo="redoub", capacity_factor=CAPACITY),
        axis_size=n,
    )
    pl = comm.plan("allreduce", d)
    want_wire = cost_model.steps_for("redoub", n) * _stream_bytes(d, CAPACITY)
    assert pl.wire_bytes == want_wire, (pl.wire_bytes, want_wire)
    assert pl.eb_stage == EB / error_budget.lossy_hops("allreduce_redoub", n)
    print(f"OK nonpow2 plan accounting n={n} wire={pl.wire_bytes}B")
