"""Shared non-power-of-two check bodies for the multi-device children.

Imported (as a sibling module, sys.path[0] == tests/) by both
tests/_mp_collectives_child.py (3/5/6-rank submeshes inside the 8-device
grid, and the whole-mesh N=6 CI leg) and tests/_mp_nonpow2_child.py
(full 12-rank mesh), so the two subprocess legs cannot drift apart.
Import only AFTER the child has pinned XLA_FLAGS — this module imports
jax.  Every check prints one 'OK ...' line and raises on failure.
"""
import numpy as np
import jax
from jax.sharding import PartitionSpec as P

from repro.core import cost_model, error_budget, simulator
from repro.core.collectives import (
    GZConfig,
    _execute_scatter,
    gz_allreduce,
    gz_broadcast,
    gz_scatter,
)
from repro.core.comm import GZCommunicator, _stream_bytes
from repro.core.shmap import shard_map

EB = 1e-4
CAPACITY = 1.2


def _shmap(f, in_specs, out_specs, mesh):
    return jax.jit(
        shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    )


def _field(rng, shape):
    """Smooth per-rank fields (the paper's RTM-like regime)."""
    return np.cumsum(rng.normal(0, 0.01, shape), axis=-1).astype(np.float32)


def check_allreduce_vs_psum(mesh, axis, n, d, rng):
    """redoub (remainder stage) / ring / intring vs the lax.psum oracle,
    within the configured error bound, no capacity overflow."""
    data = _field(rng, (n, d))
    oracle = np.asarray(
        _shmap(lambda x: jax.lax.psum(x[0], axis)[None],
               (P(axis, None),), P(axis, None), mesh)(data)
    )[0]
    for algo, tol_hops in (("redoub", 1.05), ("ring", 1.05),
                           ("intring", n * 1.05)):
        cfg = GZConfig(eb=EB, algo=algo, capacity_factor=CAPACITY)

        def body(x, c=cfg):
            out, ovf = gz_allreduce(x[0], axis, c, return_info=True)
            return out[None], ovf[None]

        out, ovf = _shmap(
            body, (P(axis, None),), (P(axis, None), P(axis)), mesh
        )(data)
        out = np.asarray(out)
        assert not np.asarray(ovf).any(), f"{algo} n={n}: capacity overflow"
        err = np.abs(out - oracle[None]).max()
        bound = EB * tol_hops + np.abs(oracle).max() * 1e-6
        assert err <= bound, f"{algo} n={n}: err {err} > {bound}"
        print(f"OK nonpow2 allreduce_{algo} n={n} err={err:.2e}")


def check_scatter_broadcast(mesh, axis, n, d_bcast, rng):
    """Virtual-pow2-tree scatter and ceil-log broadcast vs exact oracles
    (one lossy hop each); broadcast additionally rank-identical."""
    cfg = GZConfig(eb=EB, capacity_factor=CAPACITY)
    full = _field(rng, n * 512)
    xin = np.zeros((n, n * 512), np.float32)
    xin[0] = full
    out = np.asarray(
        _shmap(lambda x: gz_scatter(x[0], axis, cfg),
               (P(axis, None),), P(axis), mesh)(xin)
    ).reshape(n, 512)
    err = np.abs(out - full.reshape(n, 512)).max()
    assert err <= EB * 1.001 + np.abs(full).max() * 2e-7, err
    print(f"OK nonpow2 scatter n={n} err={err:.2e}")

    xb = np.zeros((n, d_bcast), np.float32)
    xb[0] = _field(rng, d_bcast)
    out = np.asarray(
        _shmap(lambda x: gz_broadcast(x[0], axis, cfg)[None],
               (P(axis, None),), P(axis, None), mesh)(xb)
    )
    err = np.abs(out - xb[0][None]).max()
    assert err <= EB * 1.001 + np.abs(xb[0]).max() * 2e-7, err
    assert np.abs(out - out[0:1]).max() == 0.0
    print(f"OK nonpow2 broadcast n={n} err={err:.2e}")


def check_plan_accounting(axis, n, d):
    """Plan-side accounting: ceil step counts agreeing with the cost
    model's single authority (the floor-log2 regression), the remainder
    hop charged to the per-stage budget, and the scatter plan provisioning
    exactly n-1 trimmed chunk streams (not the padded virtual tree's
    2**ceil(log2 n) - 1)."""
    comm = GZCommunicator(
        axis, config=GZConfig(eb=EB, algo="redoub", capacity_factor=CAPACITY),
        axis_size=n,
    )
    pl = comm.plan("allreduce", d)
    want_wire = cost_model.steps_for("redoub", n) * _stream_bytes(d, CAPACITY)
    assert pl.wire_bytes == want_wire, (pl.wire_bytes, want_wire)
    assert pl.eb_stage == EB / error_budget.lossy_hops("allreduce_redoub", n)
    chunk = -(-d // n)
    ps = comm.plan("scatter", d)
    want_scatter = (n - 1) * _stream_bytes(chunk, CAPACITY)
    assert ps.wire_bytes == want_scatter, (ps.wire_bytes, want_scatter)
    assert ps.slab_table == cost_model.binomial_slab_table(n)
    print(f"OK nonpow2 plan accounting n={n} wire={pl.wire_bytes}B "
          f"scatter_streams={n - 1}")


def check_scatter_trimmed_parity(mesh, axis, n, rng, *, pipeline_chunks=1):
    """ISSUE 5 acceptance: the trimmed-slab scatter must deliver BYTE-
    identical payloads to (a) the PR 4 padded virtual-tree reference walk
    and (b) the global-view simulator's replay of the slab table, for
    every real rank — at any axis size, pow2 included."""
    cfg = GZConfig(eb=EB, capacity_factor=CAPACITY,
                   pipeline_chunks=pipeline_chunks)
    chunk = 512
    full = _field(rng, n * chunk)
    xin = np.zeros((n, n * chunk), np.float32)
    xin[0] = full

    def run(padded):
        f = _shmap(
            lambda x: _execute_scatter(
                x[0], axis, cfg, _padded_reference=padded)[0],
            (P(axis, None),), P(axis), mesh,
        )
        return np.asarray(f(xin)).reshape(n, chunk)

    trimmed, padded = run(False), run(True)
    assert np.array_equal(trimmed, padded), \
        f"trimmed scatter != padded reference at n={n}"
    sim = np.stack(simulator.sim_scatter_binomial(full, n, cfg))
    assert np.array_equal(trimmed, sim), f"execute != sim bytes at n={n}"
    err = np.abs(trimmed - full.reshape(n, chunk)).max()
    assert err <= EB * 1.001 + np.abs(full).max() * 2e-7, err
    print(f"OK scatter trimmed==padded==sim bitwise n={n} "
          f"P={pipeline_chunks} err={err:.2e}")
