"""hlo_stats: collective parser + roofline arithmetic on known inputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_stats


def test_parser_on_synthetic_hlo():
    text = """
  %ag = f32[16,1024]{1,0} all-gather(f32[1,1024] %x), replica_groups={}
  %ar.1 = bf16[4096]{0} all-reduce(bf16[4096] %y), to_apply=%add
  tuple.1 = (f32[512]{0}, f32[512]{0}) all-reduce-start(f32[512] %z)
  done.1 = f32[512]{0} all-reduce-done(f32[512] %w)
  %rs = f32[256]{0} reduce-scatter(f32[4096] %a), dimensions={0}
  %cp = u32[100]{0} collective-permute(u32[100] %b)
  %a2a = f32[8,32]{1,0} all-to-all(f32[8,32] %c)
"""
    out = hlo_stats.collective_bytes(text)
    assert out["all-gather"] == 16 * 1024 * 4
    assert out["all-reduce"] == 4096 * 2 + 2 * 512 * 4  # -done skipped
    assert out["reduce-scatter"] == 256 * 4
    assert out["collective-permute"] == 100 * 4
    assert out["all-to-all"] == 8 * 32 * 4
    assert out["total"] == sum(
        v for k, v in out.items() if k not in ("total", "_counts")
    )


def test_parser_on_real_compiled_module():
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import PartitionSpec as P
    from repro.core.shmap import shard_map

    f = jax.jit(shard_map(
        lambda x: jax.lax.psum(x, "x"), mesh=mesh,
        in_specs=(P("x"),), out_specs=P(),
    ))
    hlo = f.lower(jax.ShapeDtypeStruct((16,), jnp.float32)).compile().as_text()
    out = hlo_stats.collective_bytes(hlo)  # may be optimized away at n=1
    assert "total" in out


def test_roofline_terms_math():
    r = hlo_stats.roofline_terms(197e12, 819e9, 50e9, 1)
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["memory_s"] - 1.0) < 1e-9
    assert abs(r["collective_s"] - 1.0) < 1e-9
    r = hlo_stats.roofline_terms(1, 1e12, 1, 1)
    assert r["dominant"] == "memory"
