"""bitpack: vectorized pack/unpack vs a trivially-correct python loop."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bitpack


def _pack_loop(codes: np.ndarray, bitwidth: np.ndarray, capacity: int):
    """Bit-at-a-time python reference."""
    out = np.zeros(capacity, np.uint32)
    pos = 0
    for i in range(codes.shape[0]):
        b = int(bitwidth[i])
        for j in range(codes.shape[1]):
            v = int(codes[i, j]) & ((1 << b) - 1 if b < 32 else 0xFFFFFFFF)
            for k in range(b):
                if (v >> k) & 1:
                    w, s = divmod(pos + k, 32)
                    if w < capacity:
                        out[w] |= np.uint32(1 << s)
            pos += b
    return out, (pos + 31) // 32


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n_blocks,block", [(4, 32), (7, 16), (3, 256)])
def test_pack_matches_loop(seed, n_blocks, block):
    rng = np.random.default_rng(seed)
    bw = rng.integers(0, 18, n_blocks).astype(np.int32)
    codes = np.zeros((n_blocks, block), np.uint32)
    for i in range(n_blocks):
        codes[i] = rng.integers(0, 1 << max(int(bw[i]), 1), block)
        if bw[i] == 0:
            codes[i] = 0
    capacity = int(np.sum(bw) * block // 32 + 8)
    ref_packed, ref_words = _pack_loop(codes, bw, capacity)
    packed, nwords = bitpack.pack(jnp.asarray(codes), jnp.asarray(bw), capacity)
    assert int(nwords) == ref_words
    np.testing.assert_array_equal(np.asarray(packed), ref_packed)


@pytest.mark.parametrize("seed", range(5))
def test_roundtrip_random_bitwidths(seed):
    rng = np.random.default_rng(seed)
    n_blocks, block = 32, 64
    bw = rng.integers(0, 33, n_blocks).astype(np.int32)
    codes = np.zeros((n_blocks, block), np.uint32)
    for i in range(n_blocks):
        hi = (1 << int(bw[i])) if bw[i] < 32 else (1 << 32)
        codes[i] = rng.integers(0, max(hi, 1), block, dtype=np.uint64).astype(np.uint32)
        if bw[i] == 0:
            codes[i] = 0
    capacity = int(np.sum(bw.astype(np.int64)) * block // 32 + 8)
    packed, nwords = bitpack.pack(jnp.asarray(codes), jnp.asarray(bw), capacity)
    out = bitpack.unpack(packed, jnp.asarray(bw), block)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_full_width_32():
    block = 32
    codes = np.full((2, block), 0xFFFFFFFF, np.uint32)
    bw = np.full(2, 32, np.int32)
    packed, nwords = bitpack.pack(jnp.asarray(codes), jnp.asarray(bw), 2 * block + 4)
    assert int(nwords) == 2 * block
    out = bitpack.unpack(packed, jnp.asarray(bw), block)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_zero_width_blocks_cost_nothing():
    block = 128
    codes = np.zeros((8, block), np.uint32)
    bw = np.zeros(8, np.int32)
    packed, nwords = bitpack.pack(jnp.asarray(codes), jnp.asarray(bw), 16)
    assert int(nwords) == 0
    out = bitpack.unpack(packed, jnp.asarray(bw), block)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_overflow_detected_not_silent():
    """Capacity too small: nwords still reports the true requirement."""
    block = 32
    codes = np.full((4, block), 0xFFFF, np.uint32)
    bw = np.full(4, 16, np.int32)
    packed, nwords = bitpack.pack(jnp.asarray(codes), jnp.asarray(bw), 4)
    assert int(nwords) == 4 * block * 16 // 32
    assert int(nwords) > 4
