"""Communicator/Plan surface (core/comm.py): resolve-once semantics,
policy table, uniform CollectiveResult stats, hardware calibration fit,
and the SyncConfig.with_algo regression.

Everything here is single-process: plan resolution is pure Python over
static shapes, so caching/policy behavior is testable without devices.
Multi-device bitwise parity between the legacy ``gz_*`` wrappers and the
communicator methods lives in tests/_mp_collectives_child.py (8 virtual
devices)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import cost_model as cm
from repro.core.collectives import GZConfig
from repro.core.comm import (
    OPS,
    GZCommunicator,
    Plan,
    clear_plan_cache,
    fit_hardware,
    plan_cache_stats,
    policy_names,
    register_policy,
)
from repro.core.grad_sync import SyncConfig


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_plan_cache()
    yield
    clear_plan_cache()


def _comm(n=8, **kw):
    kw.setdefault("config", GZConfig(eb=1e-4))
    return GZCommunicator("x", axis_size=n, **kw)


# ---------------------------------------------------------------------------
# Memoization: the acceptance criterion — exactly one cache entry per
# distinct (op, nbytes, dtype, axis_size, eb)
# ---------------------------------------------------------------------------


def test_plan_resolved_once_per_key():
    comm = _comm()
    plans = [comm.plan("allreduce", (64, 128)) for _ in range(5)]
    assert all(p is plans[0] for p in plans), "plan must be memoized"
    s = plan_cache_stats()
    assert s["misses"] == 1 and s["hits"] == 4 and s["entries"] == 1


def test_one_entry_per_distinct_core_key():
    comm = _comm()
    for shape in [(8192,), (8192,), (64, 128), (4096,)]:
        comm.plan("allreduce", shape)
        comm.plan("reduce_scatter", shape)
    keys = plan_cache_stats()["keys"]
    core = [(k[0], k[1], k[2], k[3], k[4]) for k in keys]
    assert len(core) == len(set(core)), "duplicate core key in plan cache"
    # (8192,) and (64,128) are the same payload -> same entry
    assert len([k for k in core if k[0] == "allreduce"]) == 2


def test_cache_shared_across_communicator_instances():
    a, b = _comm(), _comm()
    pa, pb = a.plan("allgather", 4096), b.plan("allgather", 4096)
    assert pa is pb
    assert plan_cache_stats()["misses"] == 1


def test_distinct_knobs_distinct_entries():
    _comm().plan("allreduce", 8192)
    _comm(config=GZConfig(eb=1e-5)).plan("allreduce", 8192)
    _comm(n=4).plan("allreduce", 8192)
    assert plan_cache_stats()["entries"] == 3


# ---------------------------------------------------------------------------
# Plan contents
# ---------------------------------------------------------------------------


def test_plan_is_frozen_hashable_and_concrete():
    comm = _comm()
    for op in OPS:
        p = comm.plan(op, 8192)
        assert p.algo != "auto"
        assert p.pipeline_chunks >= 1
        assert {p: op}[p] == op  # hashable, usable as a dict key
        with pytest.raises(dataclasses.FrozenInstanceError):
            p.algo = "ring"
        cfg = p.as_config()
        assert cfg.algo == p.algo and cfg.eb == p.eb


def test_plan_eb_stage_matches_error_budget():
    from repro.core import error_budget

    comm = _comm(config=GZConfig(eb=1e-3, algo="redoub"))
    p = comm.plan("allreduce", 8192)
    assert p.eb_stage == error_budget.allocate(1e-3, "allreduce_redoub", 8)
    p = comm.plan("reduce_scatter", 8192)
    assert p.eb_stage == error_budget.allocate(1e-3, "reduce_scatter_ring", 8)
    # data movement: one lossy hop, full budget per stage
    assert comm.plan("allgather", 8192).eb_stage == 1e-3
    assert comm.plan("scatter", 8192).eb_stage == 1e-3


def test_plan_wire_accounting():
    comm = _comm(config=GZConfig(eb=1e-4, capacity_factor=0.6, algo="ring",
                                 pipeline_chunks=1))
    n_elems = 1 << 20
    p = comm.plan("allreduce", n_elems)
    raw = 2 * 7 * (n_elems // 8) * 4  # 2(N-1) hops of D/N uncompressed
    assert 0 < p.wire_bytes < raw
    assert p.ratio == pytest.approx(raw / p.wire_bytes)
    # provisioned ratio is bounded by ~1/capacity_factor
    assert 1.0 < p.ratio < 1.0 / 0.6 + 0.2


# ---------------------------------------------------------------------------
# Policy table
# ---------------------------------------------------------------------------


def test_registered_policies():
    assert {"auto", "paper", "throughput", "accuracy"} <= set(policy_names())


def test_policy_auto_matches_calibrated_selector_points():
    # Big saturated payload, N=8: the chunked fused model picks the
    # pipelined ring (test_fused_pipeline's calibrated point).
    comm = _comm(config=GZConfig(eb=1e-4))
    p = comm.plan("allreduce", int(646e6 / 4))
    assert p.algo == "ring" and p.pipeline_chunks > 1
    # Whatever the production selector picks at any (D, N), "auto" agrees.
    from repro.core.selector import select_allreduce_plan

    for n in (8, 64, 512):
        p = _comm(n=n).plan("allreduce", int(646e6 / 4))
        algo, _ = select_allreduce_plan(int(646e6), n, fused_hop=True)
        assert p.algo == algo, (n, p.algo, algo)


def test_policy_paper_is_sequential_two_kernel_crossover():
    from repro.core.selector import select_allreduce

    for n in (8, 512):
        p = _comm(n=n, policy="paper").plan("allreduce", int(646e6 / 4))
        assert p.algo == select_allreduce(int(646e6), n)
        assert p.pipeline_chunks == 1


def test_policy_accuracy_picks_bitwise_consistent_intring():
    p = _comm(policy="accuracy").plan("allreduce", 8192)
    assert p.algo == "intring"


def test_policy_throughput_allows_beyond_paper():
    from repro.core.selector import select_allreduce_plan

    n_elems = 1 << 22
    algo, _ = select_allreduce_plan(n_elems * 4, 8, allow_beyond_paper=True)
    p = _comm(policy="throughput").plan("allreduce", n_elems)
    assert p.algo == algo


def test_explicit_algo_and_depth_honored_by_every_policy():
    cfg = GZConfig(eb=1e-4, algo="ring", pipeline_chunks=4)
    for policy in policy_names():
        p = _comm(config=cfg, policy=policy).plan("allreduce", 1 << 20)
        assert (p.algo, p.pipeline_chunks) == ("ring", 4), policy


def test_explicit_sequential_ring_not_deepened():
    """pipeline_chunks=1 on an explicit ring means the sequential
    schedule under every policy (only chunks==0 asks for depth planning)."""
    cfg = GZConfig(eb=1e-4, algo="ring", pipeline_chunks=1)
    for policy in ("auto", "throughput"):
        p = _comm(config=cfg, policy=policy).plan("allreduce", int(646e6 / 4))
        assert p.pipeline_chunks == 1, policy


def test_pipelined_wire_accounting_matches_execute_padding():
    """The plan's capacity/wire numbers must price the tile-padded pieces
    the pipelined execute layer actually provisions (_pad_for_pipeline),
    not the unaligned ceil-division pieces."""
    from repro.core.collectives import PIECE_QUANTUM
    from repro.core.compressed import capacity_words_for
    from repro.kernels import ops

    n, chunks, n_elems = 8, 2, 8192  # unaligned: quantum forces padding
    cfg = GZConfig(eb=1e-4, algo="ring", pipeline_chunks=chunks)
    p = _comm(n=n, config=cfg).plan("allreduce", n_elems)
    quantum = n * chunks * PIECE_QUANTUM
    piece = (-(-n_elems // quantum) * quantum) // (n * chunks)
    assert p.capacity_words == capacity_words_for(piece, 0.6, ops.BLOCK)
    # raw side stays the unpadded uncompressed equivalent
    assert p.ratio == pytest.approx(
        (2 * (n - 1) * (n_elems // n) * 4) / p.wire_bytes
    )


def test_step_counts_agree_with_cost_model_for_all_axis_sizes():
    """PR 4 regression: _wire_accounting used floor(log2 n) where the cost
    model used ceil — plans under-reported wire bytes on non-power-of-two
    axes.  Both now read cost_model.steps_for; the authoritative check
    loop (n in 2..33, redoub/broadcast/scatter) lives next to the
    accounting it guards and is shared with benchmarks/regression_check."""
    import math

    from repro.core.comm import assert_step_count_consistency

    assert_step_count_consistency()
    # And it genuinely fires: reintroduce the floor-log2 bug and the
    # check must catch it at the first non-power-of-two axis.
    orig = cm.steps_for
    cm.steps_for = lambda algo, n: max(int(math.log2(max(n, 2))), 1)
    try:
        with pytest.raises(AssertionError):
            assert_step_count_consistency(n_range=(6,))
    finally:
        cm.steps_for = orig


def test_scatter_wire_prices_trimmed_slabs_not_virtual_tree():
    """ISSUE 5 acceptance: the scatter plan provisions exactly n-1 chunk
    streams at ANY axis size — at n=9 that is 8 streams, not the padded
    virtual tree's 2**ceil(log2 9) - 1 = 15 (7/16 slots were padding)."""
    from repro.core.comm import _stream_bytes
    from repro.core.compressed import capacity_words_for
    from repro.kernels import ops

    n_elems = 9 * 1024
    for n, streams in ((9, 8), (3, 2), (5, 4), (6, 5), (12, 11),
                       (8, 7), (16, 15)):
        p = _comm(n=n).plan("scatter", n_elems)
        chunk = -(-n_elems // n)
        assert p.wire_bytes == streams * _stream_bytes(chunk, 0.6), n
        assert p.capacity_words == capacity_words_for(chunk, 0.6, ops.BLOCK)
        # raw side: the n-1 real chunks an MPI scatter moves — provisioned
        # ratio no longer diluted by padding streams at non-pow2 n
        assert p.ratio == pytest.approx((n - 1) * chunk * 4 / p.wire_bytes)


def test_plan_carries_slab_table_for_tree_ops():
    """Binomial-tree plans expose the trimmed schedule the execute layer
    walks; per-round root slabs sum to n-1 (the provisioned streams)."""
    for n in (8, 9, 12):
        comm = _comm(n=n)
        for op in ("scatter", "broadcast"):
            p = comm.plan(op, 9 * 1024)
            assert p.slab_table == cm.binomial_slab_table(n), (op, n)
            assert {p: op}[p] == op  # still hashable with the table
        root_slabs = sum(
            (span if 0 in full else trim[2])
            for span, full, trim in comm.plan("scatter", 9 * 1024).slab_table
            if 0 in full or (trim is not None and trim[0] == 0)
        )
        assert root_slabs == n - 1
    # non-tree ops carry no table
    assert _comm().plan("allreduce", 8192).slab_table == ()
    assert _comm().plan("all_to_all", 8192).slab_table == ()


def test_scatter_auto_depth_planned_from_chunked_model():
    """ISSUE 5 satellite: scatter pipeline-depth planning is WIRED (the
    previously dead scatter_binomial_gz_chunked path) — requested_chunks
    == 0 resolves the depth best_scatter_pipeline_chunks models, while an
    explicit depth (>= 1, the default) is honored verbatim."""
    n_elems = int(646e6 / 4)
    p = GZCommunicator("x", axis_size=64, config=GZConfig(eb=1e-4),
                       _auto_depth=True).plan("scatter", n_elems)
    want = cm.best_scatter_pipeline_chunks(n_elems * 4, 64, 20.0, cm.TPU_V5E)
    assert p.pipeline_chunks == want and want > 1
    # explicit depths still honored (sequential default included)
    assert _comm(n=64).plan("scatter", n_elems).pipeline_chunks == 1
    cfg4 = GZConfig(eb=1e-4, pipeline_chunks=4)
    assert _comm(n=64, config=cfg4).plan("scatter", n_elems).pipeline_chunks == 4
    # the "paper" policy stays sequential for EVERY op, auto depth included
    p = GZCommunicator("x", axis_size=64, config=GZConfig(eb=1e-4),
                       policy="paper", _auto_depth=True).plan("scatter", n_elems)
    assert p.pipeline_chunks == 1


def test_plan_nonpow2_axis_resolves_and_prices_remainder():
    """Non-power-of-two axes plan cleanly: ceil step counts in the wire
    accounting and the remainder hop charged to the per-stage budget."""
    from repro.core import error_budget
    from repro.core.comm import _stream_bytes

    for n in (3, 5, 6, 12):
        comm = _comm(n=n, config=GZConfig(eb=1e-3, algo="redoub"))
        p = comm.plan("allreduce", 8192)
        assert p.wire_bytes == cm.steps_for("redoub", n) * _stream_bytes(8192, 0.6)
        assert p.eb_stage == error_budget.allocate(1e-3, "allreduce_redoub", n)
        assert p.eb_stage == 1e-3 / n  # non-pow2: n lossy hops (unfold included)


def test_policy_registry_extensible():
    register_policy("always-redoub", lambda req: ("redoub", 1))
    try:
        p = _comm(policy="always-redoub").plan("allreduce", 1 << 20)
        assert p.algo == "redoub"
    finally:
        from repro.core import comm as comm_mod

        del comm_mod._POLICIES["always-redoub"]


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown policy"):
        GZCommunicator("x", axis_size=8, policy="nope")


def test_data_movement_ops_take_no_algo_choice():
    comm = _comm(policy="accuracy")  # accuracy only affects allreduce
    assert comm.plan("reduce_scatter", 8192).algo == "ring"
    assert comm.plan("scatter", 8192).algo == "binomial"
    assert comm.plan("all_to_all", 8192).algo == "direct"


# ---------------------------------------------------------------------------
# CollectiveResult on the trivial (1-device) axis
# ---------------------------------------------------------------------------


def test_collective_result_single_device_identity():
    from jax.sharding import PartitionSpec as P
    from repro.core.shmap import shard_map

    mesh = jax.make_mesh((1,), ("x",))
    comm = GZCommunicator("x", config=GZConfig(eb=1e-4), axis_size=1)
    x = np.arange(256, dtype=np.float32)

    def body(v):
        r = comm.allreduce(v)
        return r.value, r.overflow[None]

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(None),),
                          out_specs=(P(None), P("x"))))
    out, ovf = f(x)
    np.testing.assert_array_equal(np.asarray(out), x)
    assert not np.asarray(ovf).any()
    # trivial-axis results report zero wire traffic
    assert comm.allreduce(jnp.asarray(x)).wire_bytes == 0


def test_collective_result_astuple():
    comm = _comm(n=1)
    r = comm.allreduce(jnp.ones((8,)))
    v, o, nf, w, ratio = r.astuple()
    assert w == 0 and ratio == 1.0


# ---------------------------------------------------------------------------
# SyncConfig.with_algo regression (satellite)
# ---------------------------------------------------------------------------


def test_with_algo_on_none_gz_raises_clear_error():
    sync = SyncConfig(gz=None)
    with pytest.raises(ValueError, match="gz=None"):
        sync.with_algo("ring")


def test_with_algo_replaces_algo():
    sync = SyncConfig()
    assert sync.with_algo("intring").gz.algo == "intring"
    assert sync.gz.algo == "redoub"  # original untouched (frozen)


# ---------------------------------------------------------------------------
# Calibration: fit_hardware recovers the codec terms of a known model
# ---------------------------------------------------------------------------


def test_fit_hardware_recovers_known_model():
    true_hw = cm.TPU_V5E
    sizes = [1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24]
    samples_c = [(s, cm.t_compress(s, true_hw)) for s in sizes]
    samples_d = [(s, cm.t_decompress(s, true_hw)) for s in sizes]
    fit = fit_hardware(samples_c, samples_d, base=true_hw)
    assert fit.cmp_peak_gbps == pytest.approx(true_hw.cmp_peak_gbps, rel=1e-3)
    assert fit.dec_peak_gbps == pytest.approx(true_hw.dec_peak_gbps, rel=1e-3)
    assert fit.cmp_overhead_us == pytest.approx(true_hw.cmp_overhead_us, rel=1e-2)
    # non-codec terms inherited from the base model
    assert fit.net_gbps == true_hw.net_gbps
    assert fit.name.endswith("-calibrated")


def test_fit_hardware_feeds_planning():
    """A fitted model with huge per-call overhead pushes the planner to the
    sequential schedule; a cheap-overhead fit allows pipelining."""
    base = cm.TPU_V5E
    slow = dataclasses.replace(base, cmp_overhead_us=50_000.0)
    fast = dataclasses.replace(base, cmp_overhead_us=1.0)
    cfg = GZConfig(eb=1e-4, algo="ring")
    n_elems = int(646e6 / 4)
    deep = GZCommunicator("x", axis_size=8, config=cfg, hw=fast,
                          _auto_depth=True).plan("allreduce", n_elems)
    shallow = GZCommunicator("x", axis_size=8, config=cfg, hw=slow,
                             _auto_depth=True).plan("allreduce", n_elems)
    assert deep.pipeline_chunks > shallow.pipeline_chunks


def test_fit_hardware_needs_two_samples():
    with pytest.raises(ValueError, match="samples"):
        fit_hardware([(1024, 1e-3)])


@pytest.mark.slow
def test_measure_and_calibrate_end_to_end():
    """The real timing path runs and yields a usable Hardware (values are
    host-dependent; only sanity is asserted)."""
    comm = _comm(n=8)
    cal = comm.calibrate(sizes=(1 << 12, 1 << 14), reps=1)
    assert cal.hw.cmp_peak_gbps > 0
    assert cal.plan("allreduce", 8192).algo != "auto"
