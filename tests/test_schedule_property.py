"""Hypothesis conservation property over the Schedule IR (ISSUE 10).

Samples (op, algo) × N ∈ 2..13 plus randomized plan knobs (payload
elems, pipeline depth) and asserts the table invariants the
deterministic mirror in tests/test_schedule.py enumerates exhaustively:
every chunk delivered exactly once (``schedule.validate``), per-round
payload sum equals ``Plan.wire_bytes`` exactly, ≤1 trimmed entry per
binomial round, and the redoub fold/unfold remainder appears iff N is
non-pow2.

Kept in its own module because ``pytest.importorskip`` at module scope
skips the whole file when hypothesis isn't installed — the mirrors in
tests/test_schedule.py run regardless.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[dev])"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import schedule, simulator  # noqa: E402
from repro.core.collectives import GZConfig  # noqa: E402
from repro.core.comm import GZCommunicator  # noqa: E402

BUILDS = st.sampled_from([
    ("allreduce", "ring"), ("allreduce", "redoub"),
    ("allreduce", "intring"), ("reduce_scatter", "ring"),
    ("allgather", "ring"), ("scatter", "binomial"),
    ("broadcast", "binomial"), ("all_to_all", "direct"),
])
NS = st.integers(2, 13)


@settings(max_examples=120, deadline=None)
@given(build=BUILDS, n=NS)
def test_property_conservation(build, n):
    op, algo = build
    sched = schedule.build(op, algo, n)
    schedule.validate(sched)


@settings(max_examples=40, deadline=None)
@given(n=NS)
def test_property_binomial_trim_and_redoub_remainder(n):
    for rnd in schedule.build("scatter", "binomial", n).rounds:
        slabs = [h.chunk_slab[1] for h in rnd]
        assert len([s for s in slabs if s != max(slabs)]) <= 1, (n, slabs)
    stages = [h.stage for rnd in schedule.build("allreduce", "redoub", n).rounds
              for h in rnd]
    assert ("unfold" in stages) == bool(n & (n - 1)), (n, stages)


@settings(max_examples=40, deadline=None)
@given(build=BUILDS, n=st.sampled_from([2, 3, 6, 8, 9, 13]),
       elems=st.integers(256, 9000), chunks=st.sampled_from([0, 1, 2, 4]))
def test_property_payload_sum_is_wire_bytes(build, n, elems, chunks):
    op, algo = build
    cfg = GZConfig(eb=1e-3, algo=algo if op == "allreduce" else "auto",
                   pipeline_chunks=chunks)
    plan = GZCommunicator("i", axis_size=n, config=cfg).plan(
        op, (elems,), "float32")
    assert simulator.sim_wire_bytes(plan) == plan.wire_bytes
