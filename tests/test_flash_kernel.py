"""Pallas flash-attention kernel vs dense oracle: shape/dtype/mask sweeps."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import flash_attn, ref


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(0, 1, shape), dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,sk", [(128, 128), (256, 256), (100, 300)])
@pytest.mark.parametrize("d", [64, 128])
def test_flash_matches_oracle_causal(dtype, sq, sk, d):
    rng = np.random.default_rng(0)
    b, h = 2, 2
    q = _rand(rng, (b, sq, h, d), dtype)
    k = _rand(rng, (b, sk, h, d), dtype)
    v = _rand(rng, (b, sk, h, d), dtype)
    got = flash_attn.flash_attention(q, k, v, causal=True)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("window", [64, 128])
def test_flash_sliding_window(window):
    rng = np.random.default_rng(1)
    b, h, s, d = 1, 2, 256, 64
    q = _rand(rng, (b, s, h, d), jnp.float32)
    k = _rand(rng, (b, s, h, d), jnp.float32)
    v = _rand(rng, (b, s, h, d), jnp.float32)
    got = flash_attn.flash_attention(q, k, v, causal=True, window=window)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
    )


def test_flash_non_causal():
    rng = np.random.default_rng(2)
    b, h, sq, sk, d = 1, 1, 130, 200, 64
    q = _rand(rng, (b, sq, h, d), jnp.float32)
    k = _rand(rng, (b, sk, h, d), jnp.float32)
    v = _rand(rng, (b, sk, h, d), jnp.float32)
    got = flash_attn.flash_attention(q, k, v, causal=False)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
    )


def test_flash_matches_model_chunked_path():
    """The Pallas kernel and the pure-jnp chunked flash used by the model
    (models/attention.py) agree — same math, two implementations."""
    from repro.models.attention import flash_attention as jnp_flash

    rng = np.random.default_rng(3)
    b, h, s, d = 1, 2, 192, 64
    q = _rand(rng, (b, s, h, d), jnp.float32)
    k = _rand(rng, (b, s, h, d), jnp.float32)
    v = _rand(rng, (b, s, h, d), jnp.float32)
    a = flash_attn.flash_attention(q, k, v, causal=True)
    c = jnp_flash(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=3e-5,
                               rtol=3e-5)


def test_model_with_flash_kernel_matches_default():
    """End-to-end: model loss with the Pallas kernel path == jnp path."""
    import dataclasses
    from jax.sharding import PartitionSpec as P
    from repro.configs import registry
    from repro.core.shmap import shard_map
    from repro.models.model import Model
    from repro.models.parallel import ParallelCtx, init_params, param_specs

    cfg = registry.get("minitron-8b", smoke=True)
    ctx = ParallelCtx(tp_size=1, fsdp_size=1, remat="none")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(0)
    B, S = 2, 128  # BQ-sized so the kernel grid is exercised
    batch = {
        "tokens": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
    }
    specs = param_specs(Model(cfg, ctx).param_defs())
    bspec = {k: P(None, None) for k in batch}
    params = init_params(Model(cfg, ctx).param_defs(), jax.random.key(0))

    def loss_for(c):
        m = Model(c, ctx)
        return jax.jit(shard_map(m.loss_fn, mesh=mesh,
                                 in_specs=(specs, bspec), out_specs=P()))

    l0 = float(loss_for(cfg)(params, batch))
    l1 = float(loss_for(dataclasses.replace(cfg, use_flash_kernel=True))(
        params, batch))
    assert abs(l0 - l1) < 2e-3 * max(abs(l0), 1.0), (l0, l1)
