"""Child script: validates shard_map gZ collectives on N virtual devices.

Run by tests/test_collectives_multidevice.py in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=<N> (must be set before
jax import, which is why this is a separate process).  N defaults to 8;
an explicit GZ_CHILD_DEVICES always wins, then a pre-set XLA_FLAGS
device count (_child_env.pin_device_count) — the CI non-power-of-two leg
runs the whole file at N=6.  Prints 'OK <name>' per passing check; any
assertion failure propagates as nonzero exit.
"""
from _child_env import pin_device_count

N = pin_device_count(8)

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.collectives import (
    GZConfig,
    gz_allgather,
    gz_allreduce,
    gz_broadcast,
    gz_reduce_scatter,
    gz_scatter,
)
from repro.core.shmap import shard_map

D = 1024 * N
mesh = jax.make_mesh((N,), ("x",))
rng = np.random.default_rng(0)
# smooth per-rank fields (paper's RTM-like regime)
base = np.cumsum(rng.normal(0, 0.01, (N, D)), axis=1).astype(np.float32)
exact_sum = base.sum(axis=0)

def shmap(f, in_specs, out_specs):
    return jax.jit(
        shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    )


def check_allreduce(algo, tol_hops):
    cfg = GZConfig(eb=1e-4, algo=algo, capacity_factor=1.2)
    def body(x):
        out, ovf = gz_allreduce(x[0], "x", cfg, return_info=True)
        return out[None], ovf[None]

    f = shmap(body, (P("x", None),), (P("x", None), P("x")))
    out, ovf = f(base)
    out = np.asarray(out)
    assert not np.asarray(ovf).any(), f"{algo}: capacity overflow"
    err = np.abs(out - exact_sum[None, :]).max()
    # worst-case budget guarantees <= eb total for redoub/ring;
    # intring is <= N*eb_total (single grid, N addends)
    bound = 1e-4 * tol_hops + np.abs(exact_sum).max() * 1e-6
    assert err <= bound, f"{algo}: err {err} > {bound}"
    spread = np.abs(out - out[0:1]).max()
    if algo == "intring":
        assert spread == 0.0, f"intring not bitwise consistent: {spread}"
    print(f"OK allreduce_{algo} err={err:.2e} spread={spread:.2e}")


check_allreduce("redoub", 1.05)
check_allreduce("ring", 1.05)
check_allreduce("intring", N * 1.05)

# reduce_scatter: rank r gets summed chunk r
cfg = GZConfig(eb=1e-4, capacity_factor=1.2)
f = shmap(lambda x: gz_reduce_scatter(x[0], "x", cfg), (P("x", None),), P("x"))
out = np.asarray(f(base)).reshape(N, D // N)
want = exact_sum.reshape(N, D // N)
err = np.abs(out - want).max()
assert err <= 1e-4 * 1.05 + np.abs(exact_sum).max() * 1e-6, err
print(f"OK reduce_scatter err={err:.2e}")

# allgather: every rank sees all chunks, one lossy hop
chunks = base[:, : D // N].copy()
f = shmap(lambda x: gz_allgather(x[0], "x", cfg)[None], (P("x", None),), P("x", None))
out = np.asarray(f(chunks)).reshape(N, N * (D // N))
want = chunks.reshape(-1)
err = np.abs(out - want[None]).max()
assert err <= 1e-4 * 1.001 + np.abs(want).max() * 2e-7, err
assert np.abs(out - out[0:1]).max() == 0.0  # identical on every rank
print(f"OK allgather err={err:.2e}")

# scatter from root 0: rank r gets chunk r within eb
full = np.cumsum(rng.normal(0, 0.01, N * D)).astype(np.float32)
xin = np.zeros((N, N * D), np.float32)
xin[0] = full  # root-significant input, replicated layout
f = shmap(lambda x: gz_scatter(x[0], "x", cfg), (P("x", None),), P("x"))
out = np.asarray(f(xin)).reshape(N, D)
err = np.abs(out - full.reshape(N, D)).max()
assert err <= 1e-4 * 1.001 + np.abs(full).max() * 2e-7, err
print(f"OK scatter err={err:.2e}")

# broadcast from root 0
xb = np.zeros((N, D), np.float32)
xb[0] = base[0]
f = shmap(lambda x: gz_broadcast(x[0], "x", cfg)[None], (P("x", None),), P("x", None))
out = np.asarray(f(xb))
err = np.abs(out - base[0][None]).max()
assert err <= 1e-4 * 1.001 + np.abs(base[0]).max() * 2e-7, err
assert np.abs(out - out[0:1]).max() == 0.0
print(f"OK broadcast err={err:.2e}")

# pipelined (chunked double-buffered) ring schedules: bitwise-identical to
# the sequential schedule when the sequential chunking is piece-aligned
# (DESIGN.md §4), and within budget always.
from repro.kernels import ops as _ops

D_ALIGNED = N * 2 * _ops.BLOCK * _ops.TILE_ROWS  # chunk = 2 whole-tile pieces
base_al = np.cumsum(rng.normal(0, 0.01, (N, D_ALIGNED)), axis=1).astype(np.float32)
outs = {}
for pc in (1, 2):
    cfg_p = GZConfig(eb=1e-4, algo="ring", capacity_factor=1.2, pipeline_chunks=pc)
    f = shmap(
        lambda x, c=cfg_p: gz_allreduce(x[0], "x", c, return_info=True)[0][None],
        (P("x", None),), P("x", None),
    )
    outs[pc] = np.asarray(f(base_al))
assert np.array_equal(outs[1], outs[2]), "pipelined ring != sequential (aligned)"
err = np.abs(outs[2] - base_al.sum(axis=0)[None]).max()
assert err <= 1e-4 * 1.05 + np.abs(base_al.sum(axis=0)).max() * 1e-6, err
print(f"OK allreduce_ring_pipelined bitwise==sequential, err={err:.2e}")

cfg_p = GZConfig(eb=1e-4, algo="ring", capacity_factor=1.2, pipeline_chunks=2)
f = shmap(lambda x: gz_reduce_scatter(x[0], "x", cfg_p), (P("x", None),), P("x"))
out = np.asarray(f(base)).reshape(N, D // N)
err = np.abs(out - exact_sum.reshape(N, D // N)).max()
assert err <= 1e-4 * 1.05 + np.abs(exact_sum).max() * 1e-6, err
print(f"OK reduce_scatter_pipelined err={err:.2e}")

f = shmap(
    lambda x: gz_allgather(x[0], "x", cfg_p)[None], (P("x", None),), P("x", None)
)
out = np.asarray(f(chunks)).reshape(N, N * (D // N))
err = np.abs(out - chunks.reshape(-1)[None]).max()
assert err <= 1e-4 * 1.001 + np.abs(chunks).max() * 2e-7, err
assert np.abs(out - out[0:1]).max() == 0.0
print(f"OK allgather_pipelined err={err:.2e}")

f = shmap(lambda x: gz_scatter(x[0], "x", cfg_p), (P("x", None),), P("x"))
out = np.asarray(f(xin)).reshape(N, D)
err = np.abs(out - full.reshape(N, D)).max()
assert err <= 1e-4 * 1.001 + np.abs(full).max() * 2e-7, err
print(f"OK scatter_pipelined err={err:.2e}")

# Single-pass fused hop (ISSUE 2): the fused_hop=True schedules must be
# bitwise identical to the PR 1 two-kernel hop composition — same wire
# bytes at every hop implies the same f32 at every rank.  Checked on the
# sequential ring, the pipelined ring, redoub, and reduce_scatter.

def _run_allreduce(data, algo, fused_hop, pc=1):
    c = GZConfig(eb=1e-4, algo=algo, capacity_factor=1.2,
                 pipeline_chunks=pc, fused_hop=fused_hop)
    f = shmap(lambda x: gz_allreduce(x[0], "x", c)[None],
              (P("x", None),), P("x", None))
    return np.asarray(f(data))

for algo, pc, data in (("ring", 1, base), ("redoub", 1, base),
                       ("ring", 2, base_al), ("ring", 4, base_al)):
    a = _run_allreduce(data, algo, True, pc)
    b = _run_allreduce(data, algo, False, pc)
    assert np.array_equal(a, b), f"fused hop != two-kernel: {algo} P={pc}"
    print(f"OK fused_hop bitwise == two-kernel ({algo}, P={pc})")

cfg_fh = {}
for fh in (True, False):
    c = GZConfig(eb=1e-4, capacity_factor=1.2, pipeline_chunks=2, fused_hop=fh)
    f = shmap(lambda x, c=c: gz_reduce_scatter(x[0], "x", c), (P("x", None),), P("x"))
    cfg_fh[fh] = np.asarray(f(base))
assert np.array_equal(cfg_fh[True], cfg_fh[False])
print("OK fused_hop bitwise == two-kernel (reduce_scatter pipelined)")

# Overflow-flag propagation (ISSUE 2 satellite): a starved capacity_factor
# must trip the overflow bit on SOME hop of the pipelined schedules, and
# return_info must OR it across pieces and hops on every rank.  Rough
# (incompressible) data guarantees the streams genuinely overflow.
rough = rng.normal(0, 100.0, (N, D_ALIGNED)).astype(np.float32)
for algo, pc in (("ring", 2), ("ring", 1), ("redoub", 1)):
    cfg_tiny = GZConfig(eb=1e-6, algo=algo, capacity_factor=0.02,
                        pipeline_chunks=pc)
    f = shmap(
        lambda x, c=cfg_tiny: gz_allreduce(x[0], "x", c, return_info=True)[1][None],
        (P("x", None),), P("x", None),
    )
    ovf = np.asarray(f(rough))
    assert ovf.all(), f"overflow not propagated: {algo} P={pc}"
    print(f"OK overflow propagated ({algo}, P={pc})")

cfg_tiny = GZConfig(eb=1e-6, capacity_factor=0.02, pipeline_chunks=2)
xin_rough = np.zeros((N, N * D), np.float32)
xin_rough[0] = rng.normal(0, 100.0, N * D).astype(np.float32)
f = shmap(
    lambda x: gz_scatter(x[0], "x", cfg_tiny, return_info=True)[1][None],
    (P("x", None),), P("x", None),
)
assert np.asarray(f(xin_rough)).all(), "scatter overflow not propagated"
print("OK overflow propagated (scatter pipelined)")

# all_to_all: compressed vs exact (one lossy hop)
from repro.core.collectives import gz_all_to_all
x_a2a = base[:, : N * 512].reshape(N, N * 512).copy()
f = shmap(
    lambda x: gz_all_to_all(x[0], "x", cfg)[None], (P("x", None),), P("x", None)
)
got = np.asarray(f(x_a2a)).reshape(N, N, 512)
# rank r receives rank p's chunk r: want[r, p] = x_a2a[p, r*512:(r+1)*512]
want = x_a2a.reshape(N, N, 512).transpose(1, 0, 2)
err = np.abs(got - want).max()
assert err <= 1e-4 * 1.001 + np.abs(want).max() * 2e-7, err
print(f"OK all_to_all err={err:.2e}")

# ---------------------------------------------------------------------------
# Schedule-IR single authority (ISSUE 10): the device mesh and the
# global-view table replay walk the SAME route table, so the
# deterministic ops must agree np.array_equal-BITWISE — any divergence
# means execute and sim stopped reading one schedule.
# ---------------------------------------------------------------------------
from repro.core import simulator

sim_bc = np.stack(simulator.sim_broadcast_binomial(xb[0], N, cfg))
f = shmap(lambda x: gz_broadcast(x[0], "x", cfg)[None],
          (P("x", None),), P("x", None))
assert np.array_equal(np.asarray(f(xb)), sim_bc), \
    "broadcast: device != table replay"
print("OK schedule-IR bitwise parity (broadcast device == sim)")

sim_ag = np.stack(simulator.sim_allgather_ring(list(chunks), cfg))
f = shmap(lambda x: gz_allgather(x[0], "x", cfg)[None],
          (P("x", None),), P("x", None))
assert np.array_equal(np.asarray(f(chunks)).reshape(N, -1), sim_ag), \
    "allgather: device != table replay"
print("OK schedule-IR bitwise parity (allgather device == sim)")

# intring: both sides are bitwise rank-consistent on their own mesh and
# share ONE integer code grid, but the sim quantizes/dequantizes in f64
# while the device kernels stay f32 — rint at a code boundary can shift
# each rank's code by one, so the summed codes agree to within N (the
# observed gap is a single code), not bitwise.
cfg_int = GZConfig(eb=1e-4, algo="intring", capacity_factor=1.2)
sim_int = np.stack(simulator.sim_allreduce_intring(list(base), cfg_int))
f = shmap(lambda x: gz_allreduce(x[0], "x", cfg_int)[None],
          (P("x", None),), P("x", None))
dev_int = np.asarray(f(base))
assert np.abs(dev_int - dev_int[0:1]).max() == 0.0
codes_dev = np.rint(dev_int.astype(np.float64) / (2 * cfg_int.eb))
codes_sim = np.rint(sim_int.astype(np.float64) / (2 * cfg_int.eb))
code_gap = np.abs(codes_dev - codes_sim).max()
assert code_gap <= N, \
    f"intring allreduce: device {code_gap} codes off the sim's grid"
print(f"OK schedule-IR parity (intring device == sim, code gap {code_gap:g} <= N)")

# ---------------------------------------------------------------------------
# Communicator/Plan surface (ISSUE 3): every legacy gz_* wrapper must be
# bitwise-identical to the corresponding GZCommunicator method, the plan
# cache must hold exactly one entry per distinct core key across repeated
# jitted calls AND re-traces, and no selector/planner call may run inside
# a traced body once the plan is cached.
# ---------------------------------------------------------------------------
import repro.core.collectives as coll
import repro.core.comm as comm_api
from repro.core.comm import GZCommunicator, clear_plan_cache, plan_cache_stats

clear_plan_cache()
comm = GZCommunicator("x", config=cfg, axis_size=N)
comm_p = GZCommunicator("x", config=cfg_p, axis_size=N)  # pipelined ring

parity = [
    ("allreduce",
     lambda x: gz_allreduce(x[0], "x", cfg)[None],
     lambda x: comm.allreduce(x[0]).value[None], base),
    ("allreduce_pipelined",
     lambda x: gz_allreduce(x[0], "x", cfg_p)[None],
     lambda x: comm_p.allreduce(x[0]).value[None], base_al),
    ("reduce_scatter",
     lambda x: gz_reduce_scatter(x[0], "x", cfg)[None],
     lambda x: comm.reduce_scatter(x[0]).value[None], base),
    ("allgather",
     lambda x: gz_allgather(x[0], "x", cfg)[None],
     lambda x: comm.allgather(x[0]).value[None], chunks),
    ("scatter",
     lambda x: gz_scatter(x[0], "x", cfg)[None],
     lambda x: comm.scatter(x[0]).value[None], xin),
    ("broadcast",
     lambda x: gz_broadcast(x[0], "x", cfg)[None],
     lambda x: comm.broadcast(x[0]).value[None], xb),
    ("all_to_all",
     lambda x: gz_all_to_all(x[0], "x", cfg)[None],
     lambda x: comm.all_to_all(x[0]).value[None], x_a2a),
]
for name, legacy, method, data in parity:
    a = np.asarray(shmap(legacy, (P("x", None),), P("x", None))(data))
    b = np.asarray(shmap(method, (P("x", None),), P("x", None))(data))
    assert np.array_equal(a, b), f"wrapper != communicator: {name}"
    print(f"OK parity gz vs comm ({name})")

# Exactly one cache entry per distinct (op, nbytes, dtype, axis_size, eb):
# the wrapper and the method above shared every plan.
keys = plan_cache_stats()["keys"]
core = [k[:5] for k in keys]
assert len(core) == len(set(core)), "duplicate core plan key"
n_ar = sum(1 for k in core
           if k[:5] == ("allreduce", base.shape[1] * 4, "float32", N, 1e-4))
assert n_ar == 1, f"expected 1 allreduce plan entry for the core key, {n_ar}"

# Re-tracing (a fresh jit wrapper) must hit the cache, and once cached no
# selector/planner call may execute — patch them to explode and re-trace.
# (ISSUE 10: comm hosts the selection authority; the legacy selector
# module is a shim over it, so comm's global is the one to intercept.)
auto_cfg = GZConfig(eb=1e-4, capacity_factor=1.2, algo="auto")
f1 = shmap(lambda x: gz_allreduce(x[0], "x", auto_cfg)[None],
           (P("x", None),), P("x", None))
np.asarray(f1(base))  # resolves + caches the auto plan
misses0 = plan_cache_stats()["misses"]


def _boom(*a, **k):
    raise AssertionError("plan resolution ran inside a traced body")


orig_sel, orig_plan = comm_api.select_allreduce_plan, coll.plan_ring_pipeline_chunks
comm_api.select_allreduce_plan = _boom
coll.plan_ring_pipeline_chunks = _boom
try:
    f2 = shmap(lambda x: gz_allreduce(x[0], "x", auto_cfg)[None],
               (P("x", None),), P("x", None))  # fresh jit -> full re-trace
    np.asarray(f2(base))
finally:
    comm_api.select_allreduce_plan = orig_sel
    coll.plan_ring_pipeline_chunks = orig_plan
assert plan_cache_stats()["misses"] == misses0, "re-trace re-resolved the plan"
print("OK plan cache: one entry per key; re-trace is selector-free")

# CollectiveResult stats channel out of a shard_map body: overflow is the
# global OR, wire accounting is static and beats the uncompressed payload.
def res_body(x):
    r = comm.allreduce(x[0])
    return r.value[None], r.overflow[None]


v, o = shmap(res_body, (P("x", None),), (P("x", None), P("x")))(base)
assert not np.asarray(o).any()
plan = comm.plan("allreduce", base.shape[1])
assert plan.wire_bytes > 0 and plan.ratio > 0
print(f"OK CollectiveResult wire={plan.wire_bytes}B ratio={plan.ratio:.2f}")

# Rebinding the same axis NAME to a different size must not reuse a stale
# resolved size from the memoized one-shot communicators: the wrapper path
# already ran "x" at size N above; now run "x" at size 2 in the same
# process and demand the true 2-rank sum.  (Needs the 8-device grid.)
if N == 8:
    mesh2 = jax.make_mesh((2, 4), ("x", "y"))
    f2ax = jax.jit(shard_map(
        lambda x: gz_allreduce(x[0], "x", cfg)[None],
        mesh=mesh2, in_specs=(P(("x", "y"), None),),
        out_specs=P(("x", "y"), None),
    ))
    x8 = base  # 8 rows -> 2 "x" groups of 4 "y" rows; sum over "x" pairs
    out2 = np.asarray(f2ax(x8))
    want2 = x8.reshape(2, 4, -1).sum(axis=0)  # true sum over the "x" axis
    err2 = np.abs(out2.reshape(2, 4, -1) - want2[None]).max()
    assert err2 <= 1e-4 * 1.05 + np.abs(want2).max() * 1e-6, \
        f"stale axis-size plan reused across meshes: err {err2}"
    print("OK same axis name at a different mesh size replans correctly")

# ---------------------------------------------------------------------------
# Non-power-of-two axes (ISSUE 4): the remainder-stage redoub, generalized
# ring and virtual-pow2 trees on 3/5/6-device submeshes vs lax.psum / exact
# oracles, within the configured error bound; the plan layer's wire
# accounting must price the ceil step counts the execute layer ships.
# The check bodies are shared with the 12-rank leg (_nonpow2_checks.py).
# ---------------------------------------------------------------------------
import _nonpow2_checks as npc

# Trimmed-slab scatter on the FULL mesh (pow2 at the default N=8): the
# trimmed schedule must be bitwise-unchanged vs the padded walk and the
# simulator replay — at pow2 they are the same classic binomial tree.
npc.check_scatter_trimmed_parity(mesh, "x", N, rng)
npc.check_scatter_trimmed_parity(mesh, "x", N, rng, pipeline_chunks=2)

if N >= 6:
    d_np = 4000  # indivisible by 3/5/6: exercises the ring tail padding
    for n_sub in (3, 5, 6):
        mesh_sub = Mesh(np.array(jax.devices()[:n_sub]), ("s",))
        npc.check_allreduce_vs_psum(mesh_sub, "s", n_sub, d_np, rng)
        npc.check_plan_accounting("s", n_sub, d_np)
    for n_sub in (3, 6):
        mesh_sub = Mesh(np.array(jax.devices()[:n_sub]), ("s",))
        npc.check_scatter_broadcast(mesh_sub, "s", n_sub, d_np, rng)
        # ISSUE 5: trimmed-slab scatter bitwise == padded reference == sim
        npc.check_scatter_trimmed_parity(mesh_sub, "s", n_sub, rng)
    npc.check_scatter_trimmed_parity(
        Mesh(np.array(jax.devices()[:6]), ("s",)), "s", 6, rng,
        pipeline_chunks=2,
    )

    # Remainder-stage redoub: fused single-pass hops must stay bitwise
    # identical to the two-kernel composition (pre-fold, doubling, unfold
    # all included), and the pipelined ring must stay within budget.
    mesh6 = Mesh(np.array(jax.devices()[:6]), ("s",))
    data6 = np.cumsum(rng.normal(0, 0.01, (6, d_np)), axis=1).astype(
        np.float32
    )
    outs_fh = {}
    for fh in (True, False):
        c6 = GZConfig(eb=1e-4, algo="redoub", capacity_factor=1.2,
                      fused_hop=fh)
        f = npc._shmap(
            lambda x, c=c6: gz_allreduce(x[0], "s", c)[None],
            (P("s", None),), P("s", None), mesh6,
        )
        outs_fh[fh] = np.asarray(f(data6))
    assert np.array_equal(outs_fh[True], outs_fh[False]), \
        "remainder redoub: fused hop != two-kernel"
    print("OK nonpow2 fused_hop bitwise == two-kernel (redoub, n=6)")

    c6p = GZConfig(eb=1e-4, algo="ring", capacity_factor=1.2,
                   pipeline_chunks=2)
    f = npc._shmap(
        lambda x: gz_allreduce(x[0], "s", c6p)[None],
        (P("s", None),), P("s", None), mesh6,
    )
    out = np.asarray(f(data6))
    want6 = data6.sum(axis=0)
    err = np.abs(out - want6[None]).max()
    assert err <= 1e-4 * 1.05 + np.abs(want6).max() * 1e-6, err
    print(f"OK nonpow2 pipelined ring n=6 err={err:.2e}")

# ---------------------------------------------------------------------------
# Guard rails (ISSUE 4 satellites): bad shapes / roots / knobs fail with
# actionable ValueErrors at trace (or construction) time — never a bare
# AssertionError from the execute layer.
# ---------------------------------------------------------------------------


def _expect_value_error(fn, *fragments):
    try:
        fn()
    except ValueError as e:
        for frag in fragments:
            assert frag in str(e), (frag, str(e))
    else:
        raise AssertionError(f"expected ValueError mentioning {fragments}")


_expect_value_error(
    lambda: shmap(
        lambda x: gz_reduce_scatter(x[0][: D - 1], "x", cfg),
        (P("x", None),), P("x"),
    )(base),
    "gz_reduce_scatter", f"size {N}", "divisible",
)
_expect_value_error(
    lambda: shmap(
        lambda x: gz_scatter(x[0], "x", cfg, root=1), (P("x", None),), P("x")
    )(xin),
    "gz_scatter", "root 0",
)
_expect_value_error(
    lambda: shmap(
        lambda x: gz_broadcast(x[0], "x", cfg, root=2)[None],
        (P("x", None),), P("x", None),
    )(xb),
    "gz_broadcast", "root 0",
)
_expect_value_error(
    lambda: shmap(
        lambda x: gz_scatter(x[0][: N * D - 1], "x", cfg),
        (P("x", None),), P("x"),
    )(xin),
    "gz_scatter", "divisible",
)
_expect_value_error(lambda: GZConfig(pipeline_chunks=3), "power of two")
_expect_value_error(lambda: GZConfig(pipeline_chunks=0), "power of two")
print("OK guard rails raise actionable ValueErrors")

print("ALL OK")
