"""Hypothesis properties for the two-level topology (ISSUE 6 satellite):
over arbitrary ``(n_nodes, gpus_per_node)`` — non-power-of-two factors
included — the hierarchical replay stays inside the error budget, and
with no link asymmetry the planner resolves FLAT with the sub-plan equal
to the single-axis plan over the rank product (the bitwise-equality
guarantee: the execute layer then runs the pre-existing composite-axis
code path, exercised on real devices in tests/_mp_hier_child.py).

Kept in its own module because ``pytest.importorskip`` at module scope
skips the whole file — the deterministic mirrors live in
tests/test_hier.py and run even without hypothesis.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[dev])"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cost_model as cm  # noqa: E402
from repro.core import simulator  # noqa: E402
from repro.core.collectives import GZConfig  # noqa: E402
from repro.core.comm import _resolve_plan, _resolve_hier_plan  # noqa: E402

TOPOLOGIES = st.one_of(
    st.sampled_from([(3, 2), (2, 3), (3, 4)]),  # the ISSUE-named factors
    st.tuples(st.integers(1, 4), st.integers(1, 4)),
)


@settings(max_examples=20, deadline=None)
@given(
    topology=TOPOLOGIES,
    d=st.sampled_from([257, 1024, 1537]),  # off-block, whole-block, ragged
    inter_algo=st.sampled_from(["redoub", "ring"]),
    seed=st.integers(0, 1000),
)
def test_property_hier_error_within_budget(topology, d, inter_algo, seed):
    """For ANY node x local factorization the end-to-end hierarchical
    error obeys the single-axis bound of its inter stage: the intra
    reduce-scatter/allgather are exact f32, and ``split_lossy`` hands the
    lone lossy stage the WHOLE budget."""
    n_nodes, L = topology
    rng = np.random.default_rng(seed)
    xs = [np.cumsum(rng.normal(0, 0.01, d)).astype(np.float32)
          for _ in range(n_nodes * L)]
    eb = 1e-3
    cfg = GZConfig(eb=eb, capacity_factor=1.3, worst_case_budget=True)
    outs = simulator.sim_allreduce_hier(xs, topology, cfg,
                                        inter_algo=inter_algo)
    exact = np.sum(xs, axis=0, dtype=np.float32)
    slack = max(np.abs(exact).max(), 1.0) * 1e-6
    for o in outs:
        assert np.abs(o - exact).max() <= eb + slack
    for node in range(n_nodes):  # intra allgather is an exact copy
        for j in range(1, L):
            assert np.array_equal(outs[node * L], outs[node * L + j])


@settings(max_examples=20, deadline=None)
@given(
    topology=st.tuples(st.integers(1, 6), st.integers(1, 6)).filter(
        lambda t: t[0] * t[1] >= 2
    ),
    n_elems=st.sampled_from([4096, 1 << 20]),
)
def test_property_no_asymmetry_resolves_flat(topology, n_elems):
    """intra == inter (a flat fabric) must resolve ``flat=True`` for
    EVERY topology, with the flat sub-plan IDENTICAL (same memoized
    object) to the ordinary single-axis plan over the rank product — so
    the composite-axis execution is bitwise the pre-hierarchy path."""
    knobs = dict(
        policy="auto", requested_algo=None, requested_chunks=0,
        capacity_factor=0.6, worst_case_budget=True, fused=True,
        fused_hop=True, ratio=20.0, hw=cm.TPU_V5E,
    )
    hplan = _resolve_hier_plan(
        "allreduce", n_elems, "float32", topology, 1e-4, **knobs
    )
    assert hplan.flat and hplan.inter is None
    flat = _resolve_plan(
        "allreduce", n_elems, "float32", topology[0] * topology[1], 1e-4,
        **knobs,
    )
    assert hplan.flat_plan is flat
    assert hplan.inter_wire_bytes == flat.wire_bytes
