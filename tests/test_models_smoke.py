"""Per-architecture smoke tests: reduced configs (2 layers, d<=512,
<=4 experts), one forward/train step + one decode step on CPU,
asserting output shapes and no NaNs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core.shmap import shard_map
from repro.models.attention import KVCacheSpec
from repro.models.model import Model
from repro.models.parallel import ParallelCtx, init_params, param_specs

B, S = 2, 64

MESH = jax.make_mesh((1, 1), ("data", "model"))
CTX = ParallelCtx(tp_size=1, fsdp_size=1, dp_axes=("data",), fsdp_sync=None,
                  remat="full")


def _batch(cfg, rng):
    s_text = S - (cfg.n_prefix if cfg.family in ("vlm", "audio") else 0)
    batch = {
        "tokens": rng.integers(0, cfg.vocab, (B, s_text)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (B, s_text)).astype(np.int32),
    }
    if cfg.family in ("vlm", "audio") and cfg.n_prefix:
        batch["prefix"] = rng.normal(0, 1, (B, cfg.n_prefix, cfg.d_model)).astype(
            np.float32
        )
    if cfg.family == "encdec":
        batch["enc_input"] = rng.normal(0, 1, (B, cfg.n_prefix, cfg.d_model)).astype(
            np.float32
        )
    return batch


def _batch_specs(batch):
    return jax.tree.map(lambda a: P(*((None,) * a.ndim)), batch)


@pytest.mark.parametrize("arch", registry.arch_ids())
def test_train_step_smoke(arch):
    cfg = registry.get(arch, smoke=True)
    model = Model(cfg, CTX)
    defs = model.param_defs()
    params = init_params(defs, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)

    def body(p, b):
        loss, grads = jax.value_and_grad(model.loss_fn)(p, b)
        return loss, grads

    specs = param_specs(defs)
    f = jax.jit(
        shard_map(
            body,
            mesh=MESH,
            in_specs=(specs, _batch_specs(batch)),
            out_specs=(P(), specs),
        )
    )
    loss, grads = f(params, batch)
    loss = float(loss)
    assert np.isfinite(loss), loss
    assert loss > 0
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, dtype=np.float32)).all() for g in flat)
    # at least most grads nonzero
    nz = sum(float(jnp.abs(g.astype(jnp.float32)).sum()) > 0 for g in flat)
    assert nz >= len(flat) * 0.6, f"{nz}/{len(flat)} grads nonzero"


@pytest.mark.parametrize("arch", registry.arch_ids())
def test_decode_step_smoke(arch):
    cfg = registry.get(arch, smoke=True)
    model = Model(cfg, CTX)
    defs = model.param_defs()
    params = init_params(defs, jax.random.key(1))
    spec = KVCacheSpec(s_total=32, cp_axis=None, cp_size=1)
    shapes = model.cache_defs(B, spec)
    rng = np.random.default_rng(1)
    cache = {
        k: jnp.zeros(v, jnp.float32 if k != "enc_out" else jnp.float32)
        for k, v in shapes.items()
    }
    if "enc_out" in cache:
        cache["enc_out"] = jnp.asarray(
            rng.normal(0, 1, shapes["enc_out"]).astype(np.float32)
        )
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)).astype(np.int32))

    def body(p, c, t):
        logits, nc = model.decode_fn(p, c, t, jnp.int32(3), spec)
        return logits, nc

    specs = param_specs(defs)
    cspecs = {k: P(*((None,) * len(v))) for k, v in shapes.items()}
    f = jax.jit(
        shard_map(
            body,
            mesh=MESH,
            in_specs=(specs, cspecs, P(None, None)),
            out_specs=(P(None, None, None), cspecs),
        )
    )
    logits, new_cache = f(params, cache, tokens)
    logits = np.asarray(logits)
    assert logits.shape == (B, 1, cfg.padded_vocab())
    assert np.isfinite(logits).all()
    # cache must actually change
    changed = any(
        not np.array_equal(np.asarray(cache[k]), np.asarray(new_cache[k]))
        for k in cache
    )
    assert changed
