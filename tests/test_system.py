"""End-to-end behaviour of the paper's system (single device, fast).

Full chain: synthetic data -> shard_map train step with gZ-compressed
gradient sync -> loss decreases -> greedy decode from the trained weights.
The multi-device versions of each stage live in the subprocess tests.
"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core.collectives import GZConfig
from repro.core.shmap import shard_map
from repro.data.pipeline import SyntheticStream
from repro.launch.shapes import InputShape, train_specs
from repro.launch.training import make_setup, make_train_step
from repro.models.attention import KVCacheSpec
from repro.models.parallel import init_params
from repro.optim.adamw import AdamWConfig, adamw_init


def test_train_then_decode_end_to_end():
    cfg = registry.get("internlm2-20b", smoke=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    steps = 10
    setup = make_setup(
        cfg, mesh,
        opt=AdamWConfig(lr=1e-3, total_steps=steps, warmup_steps=2),
        grad_gz=GZConfig(eb=1e-5, algo="redoub"),
    )
    _, bspecs = train_specs(cfg, InputShape("sys", 64, 4, "train"), mesh)
    step_fn = make_train_step(setup, bspecs)
    params = init_params(setup.defs, jax.random.key(0))
    opt_state = adamw_init(params)
    losses = []
    for _, batch in zip(range(steps), SyntheticStream(cfg, 4, 64, seed=0)):
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses

    # decode greedily from the trained params
    model = setup.model
    plan = KVCacheSpec(s_total=16, cp_axis=None, cp_size=1)
    shapes = model.cache_defs(2, plan)
    cache = {k: jnp.zeros(v, jnp.float32) for k, v in shapes.items()}
    specs = setup.specs
    cspecs = {k: P(*((None,) * len(v))) for k, v in shapes.items()}
    dstep = jax.jit(shard_map(
        lambda p, c, t, pos: model.decode_fn(p, c, t, pos[0], plan),
        mesh=mesh, in_specs=(specs, cspecs, P(None, None), P(None)),
        out_specs=(P(None, None, None), cspecs),
    ))
    tok = jnp.asarray([[1], [2]], jnp.int32)
    for i in range(8):
        logits, cache = dstep(params, cache, tok, jnp.asarray([i]))
        tok = jnp.argmax(logits[:, :, : cfg.vocab], -1).astype(jnp.int32)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(tok.max()) < cfg.vocab
