"""Tier-1 (single-process) coverage for the degradation layer (ISSUE 7):
config validation, the fault-injection harness, fallback plan resolution,
degradation pricing, health counters and the skip-step helper.  The
multi-device proof (fallback bitwise == psum under forced faults) lives
in tests/_mp_faults_child.py."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import comm, cost_model, faults
from repro.core.collectives import GZConfig
from repro.core.compressed import (
    MAX_CAPACITY_FACTOR,
    capacity_words_for,
    validate_capacity_factor,
)
from repro.core.grad_sync import SyncStats
from repro.core.simulator import sim_allreduce_guarded

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container ships without hypothesis; the
    HAVE_HYPOTHESIS = False  # deterministic shrink loop below still runs


# ---------------------------------------------------------------------------
# Knob validation at construction time (satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", [0.0, -0.5, MAX_CAPACITY_FACTOR + 0.01, 100.0])
def test_capacity_factor_rejected_at_construction(bad):
    with pytest.raises(ValueError, match="GZConfig.capacity_factor"):
        GZConfig(eb=1e-3, capacity_factor=bad)
    with pytest.raises(ValueError, match="capacity_factor"):
        capacity_words_for(1024, bad, 256)


def test_capacity_factor_legal_range_accepted():
    for ok in (1e-6, 0.5, 1.0, MAX_CAPACITY_FACTOR):
        GZConfig(eb=1e-3, capacity_factor=ok)
        validate_capacity_factor(ok, knob="x")


def test_capacity_words_for_rejects_degenerate_shapes():
    with pytest.raises(ValueError, match="n=0"):
        capacity_words_for(0, 0.5, 256)
    with pytest.raises(ValueError, match="block=-1"):
        capacity_words_for(16, 0.5, -1)


def test_on_overflow_validated():
    for ok in ("flag", "fallback", "raise"):
        GZConfig(eb=1e-3, on_overflow=ok)
    with pytest.raises(ValueError, match="on_overflow"):
        GZConfig(eb=1e-3, on_overflow="panic")


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(st.floats(allow_nan=True, allow_infinity=True, width=32))
    def test_capacity_factor_property(cf):
        legal = 0.0 < cf <= MAX_CAPACITY_FACTOR
        if legal:
            validate_capacity_factor(cf, knob="x")
        else:
            with pytest.raises(ValueError):
                validate_capacity_factor(cf, knob="x")


# ---------------------------------------------------------------------------
# FaultSpec + numpy twin
# ---------------------------------------------------------------------------


def test_faultspec_validates():
    with pytest.raises(ValueError, match="kind"):
        faults.FaultSpec(kind="gamma-ray")
    with pytest.raises(ValueError, match="n"):
        faults.FaultSpec(kind="nan", n=0)
    s = faults.FaultSpec(kind="nan", ranks=[2, 0])
    assert s.ranks == (2, 0)  # normalized to an int tuple (hashable)


def test_poison_np_deterministic_and_targeted():
    x = np.linspace(0.0, 1.0, 64, dtype=np.float32)
    spec = faults.FaultSpec(kind="nan", ranks=(1,), seed=9, n=4)
    a = faults.poison_np(x, 1, spec)
    b = faults.poison_np(x, 1, spec)
    assert np.array_equal(a, b, equal_nan=True)  # same seed, same holes
    assert np.isnan(a).sum() == 4
    # non-target rank untouched; bitflip never touches inputs
    assert np.array_equal(faults.poison_np(x, 0, spec), x)
    bf = faults.FaultSpec(kind="bitflip", ranks=(1,))
    assert np.array_equal(faults.poison_np(x, 1, bf), x)
    inf = faults.poison_np(x, 1, dataclasses.replace(spec, kind="inf"))
    assert np.isinf(inf).sum() == 4
    noisy = faults.poison_np(x, 1, faults.FaultSpec(kind="overflow", ranks=(1,)))
    assert np.abs(noisy).max() > 1e3  # full replacement with sigma-1e6 noise


def test_inject_scopes_the_active_spec():
    assert faults.active() is None
    spec = faults.FaultSpec(kind="inf")
    with faults.inject(spec) as s:
        assert faults.active() is spec and s is spec
    assert faults.active() is None


def test_hooks_are_identity_without_a_fault():
    x = jnp.arange(8.0)
    assert np.array_equal(np.asarray(faults.maybe_poison_input(x, "x")), np.asarray(x))
    tree = (jnp.zeros((4,), jnp.uint32), jnp.ones((2,), jnp.int32))
    out = faults.maybe_corrupt_wire(tree, "x")
    assert out is tree


# ---------------------------------------------------------------------------
# Plan resolution carries the fallback sub-plan + the new knobs
# ---------------------------------------------------------------------------


def _plan(cfg, n=4096, axis=8, op="allreduce"):
    c = comm.GZCommunicator("x", config=cfg, axis_size=axis)
    return c.plan(op, (n,), np.float32)


def test_plan_resolves_fallback_subplan():
    comm.clear_plan_cache()
    for op in ("allreduce", "reduce_scatter", "scatter", "broadcast"):
        n = 4096
        p = _plan(GZConfig(eb=1e-3), n=n, op=op)
        fb = p.fallback
        assert fb is not None and fb.op == op
        assert fb.kind == comm._FALLBACK_KIND[op]
        assert fb.axis_size == 8
        assert fb.wire_bytes == n * 4  # raw f32, no compression
        assert fb.t_model > 0.0


def test_plan_cache_keys_on_overflow_policy():
    comm.clear_plan_cache()
    p_flag = _plan(GZConfig(eb=1e-3, on_overflow="flag"))
    p_fb = _plan(GZConfig(eb=1e-3, on_overflow="fallback"))
    p_vs = _plan(GZConfig(eb=1e-3, verify_streams=True))
    assert p_flag is not p_fb and p_flag is not p_vs
    assert p_flag.on_overflow == "flag" and p_fb.on_overflow == "fallback"
    assert p_vs.verify_streams
    # same knobs -> same memoized object
    assert _plan(GZConfig(eb=1e-3, on_overflow="fallback")) is p_fb


def test_collective_result_nonfinite_field_and_degraded():
    z = jnp.zeros((), jnp.bool_)
    o = jnp.ones((), jnp.bool_)
    r = comm.CollectiveResult(jnp.zeros((4,)), z, o, 16, 2.0)
    v, ovf, nf, w, ratio = r.astuple()
    assert w == 16 and ratio == 2.0
    assert bool(r.degraded)
    r2 = comm.CollectiveResult(jnp.zeros((4,)), z, z, 16, 2.0)
    assert not bool(r2.degraded)


# ---------------------------------------------------------------------------
# Degradation pricing
# ---------------------------------------------------------------------------


def test_fallback_time_sanity():
    hw = cost_model.TPU_V5E
    D = 1 << 20
    for op in ("allreduce", "reduce_scatter", "allgather", "scatter",
               "broadcast", "all_to_all"):
        t = cost_model.fallback_time(op, D, 8, hw)
        assert t > 0.0, op
        assert cost_model.fallback_time(op, D, 1, hw) == 0.0, op
    # allreduce fallback is exactly the uncompressed-ring baseline
    assert cost_model.fallback_time("allreduce", D, 8, hw) == \
        cost_model.allreduce_uncompressed_ring(D, 8, hw)
    with pytest.raises(ValueError, match="unknown op"):
        cost_model.fallback_time("gossip", D, 8, hw)


def test_expected_collective_time_clamps_probability():
    assert cost_model.expected_collective_time(1.0, 2.0, 0.0) == 1.0
    assert cost_model.expected_collective_time(1.0, 2.0, 1.0) == 3.0
    assert cost_model.expected_collective_time(1.0, 2.0, -5.0) == 1.0
    assert cost_model.expected_collective_time(1.0, 2.0, 7.0) == 3.0
    # a degraded call pays BOTH schedules (overflow known post-exchange)
    assert cost_model.expected_collective_time(1.0, 2.0, 0.5) == 2.0


# ---------------------------------------------------------------------------
# Health counters (pure-python layer; the traced path is proven in the
# multi-device child)
# ---------------------------------------------------------------------------


def test_health_counter_masking_and_reset():
    comm.clear_health_stats()
    comm.enable_health_tracking(True)
    try:
        key = ("allreduce", "'x'")
        comm._health_cb(key, True, True, False, True)
        comm._health_cb(key, False, True, False, True)  # non-root: ignored
        comm._health_cb(key, True, False, True, False)
        stats = comm.health_stats()
        assert stats[key] == {
            "calls": 2, "overflow": 1, "nonfinite": 1, "fallbacks": 1,
        }
        # health_stats returns a snapshot, not the live dict
        stats[key]["calls"] = 99
        assert comm.health_stats()[key]["calls"] == 2
    finally:
        comm.enable_health_tracking(False)
    comm.clear_health_stats()
    assert comm.health_stats() == {}


# ---------------------------------------------------------------------------
# Guarded simulator replay (numpy twin of the device epilogue)
# ---------------------------------------------------------------------------


def _smooth(n, d=2048, seed=0):
    rng = np.random.default_rng(seed)
    return [np.cumsum(rng.normal(0, 0.01, d)).astype(np.float32)
            for _ in range(n)]


def test_sim_guarded_clean_path():
    xs = _smooth(4)
    outs, flags = sim_allreduce_guarded(xs, GZConfig(eb=1e-3))
    assert flags == {"overflow": False, "nonfinite": False, "fallback": False}
    assert np.allclose(outs[0], np.sum(xs, axis=0), atol=1e-2)


def test_sim_guarded_nan_recovers_exact_sanitized_sum():
    xs = _smooth(4)
    spec = faults.FaultSpec(kind="nan", ranks=(2,), seed=5, n=8)
    outs, flags = sim_allreduce_guarded(xs, GZConfig(eb=1e-3), spec=spec)
    assert flags["nonfinite"] and flags["fallback"] and not flags["overflow"]
    twins = [faults.poison_np(x, r, spec) for r, x in enumerate(xs)]
    want = np.sum([np.where(np.isfinite(t), t, 0.0) for t in twins],
                  axis=0, dtype=np.float32)
    assert np.array_equal(outs[0], want)
    assert all(np.array_equal(o, outs[0]) for o in outs)


def test_sim_guarded_overflow_fault():
    xs = _smooth(4)
    spec = faults.FaultSpec(kind="overflow", ranks=(0,), seed=2)
    outs, flags = sim_allreduce_guarded(
        xs, GZConfig(eb=1e-3, capacity_factor=0.8), spec=spec)
    assert flags["overflow"] and flags["fallback"] and not flags["nonfinite"]
    assert np.isfinite(outs[0]).all()


def test_shrink_capacity_until_overflow_fires():
    """Geometric shrink of capacity_factor to the first failing value —
    the hypothesis-style shrinking property, dependency-free: at every
    passing factor the flags stay down; at the first failing factor the
    sim recovers the exact sanitized sum."""
    xs = _smooth(3, d=4096, seed=1)
    factor, first_failing = 1.2, None
    while factor > 1e-3:
        outs, flags = sim_allreduce_guarded(
            xs, GZConfig(eb=1e-5, capacity_factor=factor))
        if flags["overflow"]:
            first_failing = factor
            want = np.sum(xs, axis=0, dtype=np.float32)
            assert np.array_equal(outs[0], want)
            break
        assert not flags["fallback"]
        factor /= 2.0
    assert first_failing is not None, \
        "no capacity_factor in (1e-3, 1.2] overflowed 1e-5-eb streams"


# ---------------------------------------------------------------------------
# SyncStats + the train-step skip merge
# ---------------------------------------------------------------------------


def test_sync_stats_degraded_property():
    t = jnp.ones((), jnp.bool_)
    f = jnp.zeros((), jnp.bool_)
    assert bool(SyncStats(overflow=t, nonfinite=f).degraded)
    assert bool(SyncStats(overflow=f, nonfinite=t).degraded)
    assert not bool(SyncStats(overflow=f, nonfinite=f).degraded)
    leaves, _ = jax.tree.flatten(SyncStats(overflow=t, nonfinite=f))
    assert len(leaves) == 2  # registered pytree: scan-carry compatible


def test_skip_merge_keeps_old_state_when_degraded():
    from repro.launch.training import _skip_merge

    old = {"w": jnp.zeros((4,)), "step": jnp.int32(7)}
    new = {"w": jnp.ones((4,)), "step": jnp.int32(8)}
    kept = _skip_merge(jnp.bool_(True), new, old)
    assert np.array_equal(np.asarray(kept["w"]), np.zeros(4))
    assert int(kept["step"]) == 7
    taken = _skip_merge(jnp.bool_(False), new, old)
    assert np.array_equal(np.asarray(taken["w"]), np.ones(4))
    assert int(taken["step"]) == 8


def test_sync_grads_accumulates_health_flags():
    from repro.launch.training import _sync_grads
    from jax.sharding import PartitionSpec as P

    class FakeComm:
        def __init__(self, ovf):
            self.ovf = ovf

        def allreduce(self, g):
            return comm.CollectiveResult(
                g * 2.0, jnp.bool_(self.ovf), jnp.zeros((), jnp.bool_), 0, 1.0
            )

    grads = {"a": jnp.ones((4,)), "b": jnp.ones((2,))}
    specs = {"a": P(), "b": P()}
    out, degraded = _sync_grads(grads, specs, ("data",),
                                {"data": FakeComm(ovf=True)})
    assert bool(degraded)
    assert np.array_equal(np.asarray(out["a"]), 2 * np.ones(4))
    _, clean = _sync_grads(grads, specs, ("data",), {"data": FakeComm(False)})
    assert not bool(clean)
    # no communicator bound -> plain psum path, flag stays down (trivial
    # here: no mesh axis matches, so leaves pass through untouched)
    _, none = _sync_grads(grads, specs, (), {})
    assert not bool(none)
