"""Child: two-level (node × local) hierarchical allreduce on a virtual
2D mesh (ISSUE 6 acceptance).

Topology comes from GZ_HIER_TOPOLOGY ("<n_nodes>x<gpus_per_node>",
default 2x3 — deliberately non-power-of-two on BOTH axes); the device
count is pinned to the product before jax import.  Checks:

  * hierarchical path (A100-style asymmetric hw) is BITWISE identical to
    the composed per-axis reference (exact psum_scatter over local ->
    single-axis gz allreduce of the shard over node -> all_gather), and
    within the error budget of its only lossy stage vs the exact sum;
  * flat fallback (flat-fabric hw) is BITWISE identical to the ordinary
    single-axis schedule over the composite ("node", "local") axis;
  * one memoized trace-read communicator replans across RESHAPED meshes
    (2x3 then 3x2 of the same 6 devices): distinct HierPlan cache entries
    keyed on the full topology tuple, correct sums on both (satellite 1 —
    a cache keyed on the rank product would reuse the wrong shard size);
  * overflow propagates as the global OR across BOTH axes;
  * dp_allreduce_grads over ("local", "node") syncs a pytree within
    bound through the single two-level plan;
  * _global_rms: the single multi-axis psum matches the numpy global RMS
    on every rank.

Prints 'OK <name>' per check; any assertion failure exits nonzero.
"""
import os

from _child_env import pin_device_count

TOPOLOGY = os.environ.get("GZ_HIER_TOPOLOGY", "2x3")
N_NODES, L = (int(s) for s in TOPOLOGY.split("x"))
N = N_NODES * L
os.environ["GZ_CHILD_DEVICES"] = str(N)
pin_device_count(N)

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import cost_model
from repro.core.collectives import (
    GZConfig,
    _pad_to_chunks,
    gz_allreduce,
    gz_allreduce_hier,
)
from repro.core.comm import (
    GZHierCommunicator,
    clear_plan_cache,
    plan_cache_stats,
)
from repro.core.shmap import shard_map

HW_ASYM = cost_model.A100_SLINGSHOT  # 48:1 intra:inter — hier territory
HW_FLAT = cost_model.TPU_V5E         # flat fabric — must resolve flat

D = 1000  # NOT divisible by L=3 (or 2): exercises the shard padding
mesh = jax.make_mesh((N_NODES, L), ("node", "local"))
rng = np.random.default_rng(0)
base = np.cumsum(rng.normal(0, 0.01, (N, D)), axis=1).astype(np.float32)
exact_sum = base.sum(axis=0)
cfg = GZConfig(eb=1e-4, capacity_factor=1.2)

AX = ("node", "local")  # node-major: rank = node * L + local


def shmap(f, in_specs, out_specs, m=mesh):
    return jax.jit(shard_map(f, mesh=m, in_specs=in_specs, out_specs=out_specs))


# --- hierarchical path vs composed per-axis reference (bitwise) ---
clear_plan_cache()
comm = GZHierCommunicator.for_axes(
    "node", "local", config=cfg, hw=HW_ASYM, topology=(N_NODES, L)
)
hplan = comm.plan((D,))
assert not hplan.flat, f"asymmetric hw must go hierarchical: {hplan}"
assert hplan.inter is not None and hplan.topology == (N_NODES, L)
assert hplan.inter.eb == cfg.eb, (
    "the inter stage is the ONLY lossy stage and must carry the whole "
    f"budget undiluted (split_lossy): {hplan.inter.eb} != {cfg.eb}"
)


def hier_body(x):
    r = comm.allreduce(x[0])
    return r.value[None], r.overflow[None]


def ref_body(x):
    """The composed per-axis reference: same three stages, but the inter
    stage goes through the ordinary SINGLE-AXIS wrapper on the resolved
    inter sub-plan's concrete config — the pre-existing code path."""
    x = x[0]
    flat = x.reshape(-1).astype(jnp.float32)
    padded, _ = _pad_to_chunks(flat, L)
    shard = lax.psum_scatter(padded, "local", scatter_dimension=0, tiled=True) \
        if L > 1 else padded
    if N_NODES > 1:
        shard = gz_allreduce(shard, "node", hplan.inter.as_config())
    full = lax.all_gather(shard, "local", tiled=True) if L > 1 else shard
    return full[: flat.shape[0]].reshape(x.shape).astype(x.dtype)[None]


out, ovf = shmap(hier_body, (P(AX, None),), (P(AX, None), P(AX)))(base)
out = np.asarray(out)
assert not np.asarray(ovf).any(), "hier: spurious capacity overflow"
ref = np.asarray(shmap(ref_body, (P(AX, None),), P(AX, None))(base))
assert np.array_equal(out, ref), \
    f"hier != composed per-axis reference (max diff {np.abs(out - ref).max()})"
err = np.abs(out - exact_sum[None]).max()
bound = cfg.eb * 1.05 + np.abs(exact_sum).max() * 1e-6
assert err <= bound, f"hier: err {err} > {bound}"
print(f"OK hier_{TOPOLOGY} bitwise == composed reference, err={err:.2e}")

# wrapper parity: gz_allreduce_hier is the same communicator one-shot
out_w = np.asarray(shmap(
    lambda x: gz_allreduce_hier(x[0], "node", "local",
                                cfg, return_info=False)[None],
    (P(AX, None),), P(AX, None),
)(base))
# default hw is the flat fabric -> composite-axis path; just bound-check
err_w = np.abs(out_w - exact_sum[None]).max()
assert err_w <= bound, f"gz_allreduce_hier: err {err_w} > {bound}"
print(f"OK gz_allreduce_hier wrapper err={err_w:.2e}")

# --- flat fallback (no link asymmetry) bitwise == composite-axis run ---
comm_flat = GZHierCommunicator.for_axes(
    "node", "local", config=cfg, hw=HW_FLAT, topology=(N_NODES, L)
)
hplan_flat = comm_flat.plan((D,))
assert hplan_flat.flat, f"flat fabric must resolve flat: {hplan_flat}"
out_h = np.asarray(shmap(
    lambda x: comm_flat.allreduce(x[0]).value[None],
    (P(AX, None),), P(AX, None),
)(base))
out_f = np.asarray(shmap(
    lambda x: gz_allreduce(x[0], AX, cfg)[None],
    (P(AX, None),), P(AX, None),
)(base))
assert np.array_equal(out_h, out_f), \
    "flat fallback != single-axis schedule over the composite axis"
print(f"OK flat fallback bitwise == composite-axis gz_allreduce")

# --- one trace-read communicator replans across reshaped meshes ---
if N == 6:
    comm_tr = GZHierCommunicator.for_axes("node", "local", config=cfg,
                                          hw=HW_ASYM)  # topology from trace
    outs = {}
    for shape in ((2, 3), (3, 2)):
        m = jax.make_mesh(shape, ("node", "local"))
        f = shmap(lambda x: comm_tr.allreduce(x[0]).value[None],
                  (P(AX, None),), P(AX, None), m)
        outs[shape] = np.asarray(f(base))
        err = np.abs(outs[shape] - exact_sum[None]).max()
        assert err <= bound, (
            f"{shape}: err {err} > {bound} — a stale plan from the other "
            "topology would ship the wrong shard size"
        )
    topos = {k[3] for k in plan_cache_stats()["hier_keys"]}
    assert {(2, 3), (3, 2)} <= topos, (
        "2x3 and 3x2 must be DISTINCT plan-cache entries (full axis-size "
        f"tuple key, not the rank product); cached topologies: {topos}"
    )
    print("OK 2x3 vs 3x2 replan: distinct plans, correct sums on both")

# --- overflow is the global OR across both axes ---
rough = rng.normal(0, 100.0, (N, D)).astype(np.float32)
cfg_tiny = GZConfig(eb=1e-6, capacity_factor=0.02)
comm_tiny = GZHierCommunicator.for_axes(
    "node", "local", config=cfg_tiny, hw=HW_ASYM, topology=(N_NODES, L)
)
ovf = np.asarray(shmap(
    lambda x: comm_tiny.allreduce(x[0]).overflow[None],
    (P(AX, None),), P(AX),
)(rough))
assert ovf.all(), "hier overflow not OR-propagated to every rank"
print("OK hier overflow propagated across node x local")

# --- grad sync through the single two-level plan ---
from repro.core.grad_sync import SyncConfig, _global_rms, dp_allreduce_grads

grads = {
    "w": rng.normal(0, 1e-3, (N, 64, 32)).astype(np.float32),
    "b": rng.normal(0, 1e-3, (N, 32)).astype(np.float32),
}
exact = {k: v.sum(axis=0) for k, v in grads.items()}
sync = SyncConfig(gz=GZConfig(eb=1e-5, algo="redoub", capacity_factor=1.2),
                  relative_eb=True, bucket_bytes=4096)
specs = {"w": P(AX, None, None), "b": P(AX, None)}


def gbody(g):
    g = jax.tree.map(lambda a: a[0], g)
    out = dp_allreduce_grads(g, ("local", "node"), sync)  # fast axes first
    return jax.tree.map(lambda a: a[None], out)


outg = jax.tree.map(np.asarray, shmap(gbody, (specs,), specs)(grads))
for k in grads:
    rms = np.sqrt((exact[k] ** 2).mean())
    err = np.abs(outg[k] - exact[k][None]).max()
    assert err <= 3 * 1e-5 * max(rms, 1e-3) * N + 1e-7, (k, err, rms)
    print(f"OK dp_allreduce hier {k} err={err:.3e}")

# --- _global_rms: single multi-axis psum, numpy parity on every rank ---
rms_out = np.asarray(shmap(
    lambda x: _global_rms(x[0], AX)[None], (P(AX, None),), P(AX),
)(base))
want_rms = np.sqrt((base.astype(np.float64) ** 2).mean())
assert np.allclose(rms_out, want_rms, rtol=1e-5), (rms_out, want_rms)
assert np.all(rms_out == rms_out[0]), "RMS differs across ranks"
print(f"OK _global_rms parity rms={want_rms:.3e}")

print("ALL OK")
