"""Child: parallel-correctness of the model zoo.

For each architecture family, runs the SAME smoke model + batch on a
(1,1) mesh and on a (2,4) (data, model) mesh — TP=4 exercises head
sharding / kv replication groups / expert parallel / vocab-parallel loss;
data=2 exercises FSDP gather + batch sharding.  Losses and gradients must
agree (up to bf16 reduction-order noise).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core.shmap import shard_map
from repro.models.model import Model
from repro.models.parallel import ParallelCtx, init_params, param_specs

B, S = 2, 32

ARCHS = [
    "internlm2-20b",       # dense GQA, kv < tp -> replication groups
    "minicpm3-4b",         # MLA
    "llama4-scout-17b-a16e",  # MoE top-1, expert parallel
    "phi3.5-moe-42b-a6.6b",   # MoE top-2
    "mamba2-780m",         # SSD
    "zamba2-2.7b",         # hybrid + shared block
    "seamless-m4t-medium",  # enc-dec
    "internvl2-26b",       # VLM prefix
]


def batch_for(cfg, rng):
    s_text = S - (cfg.n_prefix if cfg.family in ("vlm", "audio") else 0)
    b = {
        "tokens": rng.integers(0, cfg.vocab, (B, s_text)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (B, s_text)).astype(np.int32),
    }
    if cfg.family in ("vlm", "audio") and cfg.n_prefix:
        b["prefix"] = rng.normal(0, 1, (B, cfg.n_prefix, cfg.d_model)).astype(
            np.float32
        )
    if cfg.family == "encdec":
        b["enc_input"] = rng.normal(0, 1, (B, cfg.n_prefix, cfg.d_model)).astype(
            np.float32
        )
    return b


def run(cfg, mesh, tp, fsdp, batch, params_defs_params):
    defs, params = params_defs_params
    ctx = ParallelCtx(tp_size=tp, fsdp_size=fsdp,
                      dp_axes=("data",), fsdp_sync=None, remat="full")
    model = Model(cfg, ctx)
    specs = param_specs(defs)

    def bspec(a, batched):
        if batched:
            return P(*(("data",) + (None,) * (a.ndim - 1)))
        return P(*((None,) * a.ndim))

    bspecs = {k: bspec(v, True) for k, v in batch.items()}

    def body(p, b):
        # shard_map grad semantics: d(sum over ranks of per-rank loss); the
        # loss is replicated over TP (x tp) and a local mean per data rank
        # (x n_data vs the global mean) -> scale the differentiated loss.
        scale = 1.0 / (tp * fsdp)

        def scaled(p, b):
            return model.loss_fn(p, b) * scale

        loss, grads = jax.value_and_grad(scaled)(p, b)
        loss = jax.lax.pmean(loss / scale, "data")

        # Grad-sync rule: psum over every mesh axis ABSENT from the leaf's
        # spec (axes in the spec are either sharded-and-consumed locally or
        # already summed by the FSDP gather's vjp).
        def sync(g, s):
            present = set(jax.tree.leaves(tuple(s)))
            for ax in ("data", "model"):
                if ax not in present:
                    g = jax.lax.psum(g, ax)
            return g

        grads = jax.tree.map(sync, grads, specs)
        return loss, grads

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(specs, bspecs),
                          out_specs=(P(), specs)))
    loss, grads = f(params, batch)
    return np.asarray(loss), jax.tree.map(np.asarray, grads)


import dataclasses

for arch in ARCHS:
    cfg = registry.get(arch, smoke=True)
    if cfg.family == "moe":
        # capacity is a per-shard quantity; different meshes drop different
        # tokens.  Equivalence requires a no-drop capacity.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    rng = np.random.default_rng(7)
    batch = batch_for(cfg, rng)
    # single-device reference
    mesh1 = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model")
    )
    ctx1 = ParallelCtx(tp_size=1, fsdp_size=1, dp_axes=("data",))
    defs = Model(cfg, ctx1).param_defs()
    params = init_params(defs, jax.random.key(0))
    l1, g1 = run(cfg, mesh1, 1, 1, batch, (defs, params))
    # 2x4 mesh — same GLOBAL params (defs are identical global shapes)
    mesh8 = jax.make_mesh((2, 4), ("data", "model"))
    ctx8 = ParallelCtx(tp_size=4, fsdp_size=2, dp_axes=("data",))
    defs8 = Model(cfg, ctx8).param_defs()
    shapes1 = jax.tree.map(lambda d: d.shape, defs,
                           is_leaf=lambda x: hasattr(x, "spec"))
    shapes8 = jax.tree.map(lambda d: d.shape, defs8,
                           is_leaf=lambda x: hasattr(x, "spec"))
    assert shapes1 == shapes8, f"{arch}: global shapes differ between meshes"
    l8, g8 = run(cfg, mesh8, 4, 2, batch, (defs8, params))

    rtol = 0.05 if cfg.family == "moe" else 0.02
    assert np.allclose(l1, l8, rtol=rtol), f"{arch}: loss {l1} vs {l8}"
    worst = 0.0
    for k1, k8 in zip(jax.tree.leaves(g1), jax.tree.leaves(g8)):
        a, b = np.asarray(k1, np.float32), np.asarray(k8, np.float32)
        scale = max(np.abs(a).max(), 1e-6)
        worst = max(worst, float(np.abs(a - b).max() / scale))
    lim = 0.35 if cfg.family == "moe" else 0.1  # moe: capacity-drop noise
    assert worst <= lim, f"{arch}: grad rel err {worst}"
    print(f"OK {arch} loss={float(l1):.4f} dloss={abs(float(l1-l8)):.2e} "
          f"grad_rel={worst:.3f}")

print("ALL OK")
