"""Child: overflow-safe degradation + deterministic fault injection
(DESIGN.md §9 acceptance).

Run in a subprocess by tests/test_collectives_multidevice.py (8 virtual
devices; the CI faults leg re-runs the whole file at N=6 via
GZ_CHILD_DEVICES).  Proves, on real multi-device shard_map executions:

  * FORCED capacity overflow (rough data x starved capacity_factor) with
    ``on_overflow="fallback"``: the in-trace lossless re-execute returns
    BITWISE the uncompressed reference for allreduce (redoub/ring/
    intring), reduce_scatter, allgather, scatter and broadcast, across
    non-power-of-two submeshes — and the overflow bit still reports the
    event;
  * ``on_overflow="flag"`` on the same inputs only flags (back-compat);
  * the two-level (node x local) hierarchical allreduce degrades to the
    same exact composite-axis psum;
  * seeded NaN/Inf input poisoning (core/faults.py) trips the distinct
    ``nonfinite`` health bit and recovers the exact psum of the
    SANITIZED inputs (bitwise vs a device psum of the numpy-twin
    poisoned arrays — faults.poison_np embeds identical constants);
  * the seeded "overflow" fault kind forces a genuine capacity overflow
    on otherwise-compressible data;
  * seeded wire bitflips are SILENT corruption with
    ``verify_streams=False`` (output differs from the clean run, no flag
    raised — the undetected-corruption hazard this leg exists to make
    fatal) and are detected + losslessly recovered with
    ``verify_streams=True`` + fallback;
  * per-communicator health counters record calls/overflow/nonfinite/
    fallbacks outside the trace;
  * dp_allreduce_grads_stats surfaces the OR-ed flags (satellite:
    the old wrapper dropped them on the scan floor);
  * a no-hypothesis shrink loop: starting from a passing
    capacity_factor, geometrically shrink until overflow fires, then
    verify the minimal failing factor still recovers exactly;
  * LAST (it poisons the runtime with an intentional raise):
    ``on_overflow="raise"`` propagates out of the jitted call.

Prints 'OK <name>' per check and an 'ALL OK' sentinel; exits via
os._exit(0) after flushing so the raise-check's dead callback tokens
cannot turn a passing run into atexit noise.
"""
from _child_env import pin_device_count

N = pin_device_count(8)

import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import comm, faults
from repro.core.collectives import GZConfig
from repro.core.grad_sync import SyncConfig, dp_allreduce_grads_stats
from repro.core.shmap import shard_map

rng = np.random.default_rng(0)
D = 512  # per-rank elements; multiple of every submesh size used below

# Rough high-entropy data + starved capacity: every rank's stream
# genuinely overflows the pack kernel (nothing is faked).
CFG_OVF = GZConfig(eb=1e-6, capacity_factor=0.02, on_overflow="fallback")
# Smooth compressible data + roomy capacity: never overflows.
CFG_OK = GZConfig(eb=1e-3, capacity_factor=1.2, on_overflow="fallback")

SUBMESH_NS = sorted({3, 4, N})


def submesh(n):
    return Mesh(np.array(jax.devices()[:n]), ("x",))


def shmap(f, in_specs, out_specs, m):
    return jax.jit(shard_map(f, mesh=m, in_specs=in_specs, out_specs=out_specs))


def rough(n, d=D):
    return rng.normal(0, 100.0, (n, d)).astype(np.float32)


def smooth(n, d=D):
    return np.cumsum(rng.normal(0, 0.01, (n, d)), axis=1).astype(np.float32)


def run_allreduce(xs, n, cfg):
    def body(x):
        r = comm.GZCommunicator("x", config=cfg).allreduce(x[0])
        return r.value[None], r.overflow[None], r.nonfinite[None]

    f = shmap(body, (P("x", None),), (P("x", None), P("x"), P("x")),
              submesh(n))
    v, o, nf = f(xs)
    return np.asarray(v), np.asarray(o), np.asarray(nf)


def psum_ref(xs, n):
    f = shmap(lambda x: lax.psum(x[0], "x")[None], (P("x", None),),
              P("x", None), submesh(n))
    return np.asarray(f(xs))


# --- forced overflow -> fallback bitwise == uncompressed, all allreduce
# algorithms, non-power-of-two submeshes included ---
for n in SUBMESH_NS:
    xs = rough(n)
    ref = psum_ref(xs, n)
    for algo in ("redoub", "ring", "intring"):
        cfg = GZConfig(eb=1e-6, capacity_factor=0.02, algo=algo,
                       on_overflow="fallback")
        v, o, nf = run_allreduce(xs, n, cfg)
        assert o.all(), f"allreduce {algo} n={n}: overflow not reported"
        assert not nf.any(), f"allreduce {algo} n={n}: spurious nonfinite"
        assert np.array_equal(v, ref), \
            f"allreduce {algo} n={n}: fallback not bitwise psum"
    print(f"OK allreduce_fallback n={n} (redoub/ring/intring)")

# flag mode: same inputs only raise the bit, no lossless rerun promised
xs = rough(N)
v, o, nf = run_allreduce(
    xs, N, GZConfig(eb=1e-6, capacity_factor=0.02, on_overflow="flag"))
assert o.all() and not nf.any()
print("OK flag_mode_reports_only")

# clean data through the fallback policy: flags stay down, values are the
# ordinary compressed result (the cond must not perturb the happy path)
xs = smooth(N)
v, o, nf = run_allreduce(xs, N, CFG_OK)
assert not o.any() and not nf.any()
assert np.allclose(v[0], xs.sum(axis=0), atol=1e-1)
print("OK clean_path_unperturbed")


# --- the other collectives under forced overflow ---
def check_op_fallback(op, n):
    m = submesh(n)
    if op == "reduce_scatter":
        xs = rough(n, n * 128)  # payload must divide by the axis size

        def body(x):
            r = comm.GZCommunicator("x", config=CFG_OVF).reduce_scatter(x[0])
            return r.value[None], r.overflow[None]

        f = shmap(body, (P("x", None),), (P("x", None), P("x")), m)
        v, o = f(xs)
        ref = shmap(
            lambda x: lax.psum_scatter(
                x[0], "x", scatter_dimension=0, tiled=True)[None],
            (P("x", None),), (P("x", None)), m)(xs)
    elif op == "allgather":
        xs = rough(n, D // n)

        def body(x):
            r = comm.GZCommunicator("x", config=CFG_OVF).allgather(x[0])
            return r.value[None], r.overflow[None]

        f = shmap(body, (P("x", None),), (P("x", None), P("x")), m)
        v, o = f(xs)
        ref = shmap(lambda x: lax.all_gather(x[0], "x", tiled=True)[None],
                    (P("x", None),), (P("x", None)), m)(xs)
    elif op == "scatter":
        full = rng.normal(0, 100.0, n * D).astype(np.float32)
        xs = np.zeros((n, n * D), np.float32)
        xs[0] = full  # root-significant input

        def body(x):
            r = comm.GZCommunicator("x", config=CFG_OVF).scatter(x[0])
            return r.value[None], r.overflow[None]

        f = shmap(body, (P("x", None),), (P("x", None), P("x")), m)
        v, o = f(xs)
        ref = full.reshape(n, D)  # exact root chunks, rank r -> chunk r
    elif op == "broadcast":
        xs = np.zeros((n, D), np.float32)
        xs[0] = rng.normal(0, 100.0, D).astype(np.float32)

        def body(x):
            r = comm.GZCommunicator("x", config=CFG_OVF).broadcast(x[0])
            return r.value[None], r.overflow[None]

        f = shmap(body, (P("x", None),), (P("x", None), P("x")), m)
        v, o = f(xs)
        ref = np.tile(xs[0], (n, 1))  # exact root payload everywhere
    assert np.asarray(o).all(), f"{op} n={n}: overflow not reported"
    assert np.array_equal(np.asarray(v), np.asarray(ref)), \
        f"{op} n={n}: fallback not bitwise the lossless reference"


for op in ("reduce_scatter", "allgather", "scatter", "broadcast"):
    for n in (4, N) if N != 4 else (4,):
        check_op_fallback(op, n)
    print(f"OK {op}_fallback")

# --- hierarchical (node x local) allreduce degradation ---
if N % 2 == 0 and N >= 4:
    hmesh = Mesh(np.array(jax.devices()[:N]).reshape(2, N // 2),
                 ("node", "local"))
    xs = rough(N)

    def hbody(x):
        c = comm.GZHierCommunicator.for_axes("node", "local", config=CFG_OVF)
        r = c.allreduce(x[0, 0])
        return r.value[None, None], r.overflow[None, None]

    f = jax.jit(shard_map(hbody, mesh=hmesh,
                          in_specs=(P(("node", "local"), None),),
                          out_specs=(P(("node", "local"), None),
                                     P("node", "local"))))
    v, o = f(xs.reshape(2, N // 2, D).reshape(N, D))
    ref = xs.sum(axis=0, dtype=np.float32)
    g = jax.jit(shard_map(
        lambda x: lax.psum(x[0, 0], ("node", "local"))[None, None],
        mesh=hmesh, in_specs=(P(("node", "local"), None),),
        out_specs=P(("node", "local"), None)))
    assert np.asarray(o).all(), "hier: overflow not reported"
    assert np.array_equal(np.asarray(v), np.asarray(g(xs))), \
        "hier fallback not bitwise the composite psum"
    print("OK hier_fallback 2x%d" % (N // 2))

# --- seeded NaN / Inf input poisoning ---
for kind in ("nan", "inf"):
    spec = faults.FaultSpec(kind=kind, ranks=(1,), seed=7, n=5)
    xs = smooth(N)
    with faults.inject(spec):
        v, o, nf = run_allreduce(xs, N, CFG_OK)
    assert nf.all(), f"{kind}: nonfinite bit not set"
    assert not o.any(), f"{kind}: nonfinite misreported as overflow"
    assert np.isfinite(v).all(), f"{kind}: non-finite output escaped"
    twins = np.stack([faults.poison_np(xs[r], r, spec) for r in range(N)])
    san = np.where(np.isfinite(twins), twins, 0.0).astype(np.float32)
    assert np.array_equal(v, psum_ref(san, N)), \
        f"{kind}: recovery not bitwise psum of sanitized twins"
    print(f"OK poison_{kind}_recovered")

# the "overflow" fault kind: compressible data and a capacity that fits
# it with headroom — only the injected incompressible noise (32-bit
# codes > 0.8x capacity) can overflow, and it must
spec = faults.FaultSpec(kind="overflow", ranks=(0, 2), seed=11)
cfg_noise = GZConfig(eb=1e-3, capacity_factor=0.8, on_overflow="fallback")
xs = smooth(N)
v_clean, o_clean, _ = run_allreduce(xs, N, cfg_noise)
assert not o_clean.any()
with faults.inject(spec):
    v, o, nf = run_allreduce(xs, N, cfg_noise)
assert o.all(), "overflow fault kind did not trip the capacity check"
twins = np.stack([faults.poison_np(xs[r], r, spec) for r in range(N)])
assert np.array_equal(v, psum_ref(twins, N)), \
    "overflow-fault fallback not bitwise psum of the poisoned inputs"
print("OK fault_kind_overflow")

# --- wire bitflips: silent without verify_streams, caught with it ---
xs = smooth(N)
clean, _, _ = run_allreduce(xs, N, GZConfig(eb=1e-3, capacity_factor=0.6))
corrupting_seed = None
for seed in range(24):
    spec = faults.FaultSpec(kind="bitflip", ranks=(1,), seed=seed, n=16)
    with faults.inject(spec):
        v, o, nf = run_allreduce(
            xs, N, GZConfig(eb=1e-3, capacity_factor=0.6))
    if not np.array_equal(v, clean):
        assert not o.any() and not nf.any(), \
            "bitflip raised a flag without verify_streams (seed %d)" % seed
        corrupting_seed = seed
        break
assert corrupting_seed is not None, \
    "no bitflip seed corrupted the wire — injector is not reaching streams"
print(f"OK bitflip_silent_without_verify (seed={corrupting_seed})")

spec = faults.FaultSpec(kind="bitflip", ranks=(1,), seed=corrupting_seed,
                        n=16)
with faults.inject(spec):
    v, o, nf = run_allreduce(
        xs, N,
        GZConfig(eb=1e-3, capacity_factor=0.6, verify_streams=True,
                 on_overflow="fallback"))
assert np.asarray(o).all(), "verify_streams did not detect the bitflip"
assert np.array_equal(v, psum_ref(xs, N)), \
    "bitflip fallback not bitwise the clean psum"
print("OK bitflip_detected_and_recovered")

# --- round-targeted bitflips (ISSUE 10): a FaultSpec aimed at schedule
# round k corrupts the bit-identical wire hop in the table replay and on
# the real mesh — the detection bit of sim_allreduce_guarded must equal
# the device's, both for rounds inside the table and for rounds past its
# end (which can never match an exchange). ---
from repro.core import schedule, simulator

cfg_rt = GZConfig(eb=1e-3, capacity_factor=0.6, algo="redoub",
                  verify_streams=True, on_overflow="fallback")
sched_rt = schedule.build("allreduce", "redoub", N)
for rounds in ((1,), (0, sched_rt.n_rounds - 1), (sched_rt.n_rounds + 7,)):
    spec = faults.FaultSpec(kind="bitflip", ranks=(1,), seed=corrupting_seed,
                            n=16, rounds=rounds)
    with faults.inject(spec):
        v, o, nf = run_allreduce(xs, N, cfg_rt)
    dev_bit = bool(np.asarray(o).any())
    _, fl = simulator.sim_allreduce_guarded(list(xs), cfg_rt, algo="redoub",
                                            spec=spec)
    assert dev_bit == fl["overflow"] == fl["fallback"], \
        f"rounds={rounds}: device detection {dev_bit} != sim flags {fl}"
    if dev_bit:
        assert np.array_equal(v, psum_ref(xs, N)), \
            f"rounds={rounds}: detected but not losslessly recovered"
    print(f"OK bitflip_round_targeted rounds={rounds} detected={dev_bit}")

# --- health counters (outside-trace observability) ---
comm.clear_plan_cache()
comm.clear_health_stats()
comm.enable_health_tracking(True)
run_allreduce(rough(N), N, CFG_OVF)
run_allreduce(smooth(N), N, CFG_OK)
jax.effects_barrier()
stats = comm.health_stats()
key = ("allreduce", "'x'")
assert stats[key]["calls"] == 2, stats
assert stats[key]["overflow"] == 1, stats
assert stats[key]["fallbacks"] == 1, stats
assert stats[key]["nonfinite"] == 0, stats
comm.enable_health_tracking(False)
print("OK health_counters")

# --- grad_sync surfaces the OR-ed flags (satellite) ---
mesh = submesh(N)
sync = SyncConfig(gz=GZConfig(eb=1e-6, capacity_factor=0.02,
                              on_overflow="fallback"))
grads = {"w": rough(N, 64).reshape(N, 8, 8), "b": rough(N, 8)}


def gbody(g):
    g = jax.tree.map(lambda a: a[0], g)
    out, st = dp_allreduce_grads_stats(g, ("x",), sync)
    return (jax.tree.map(lambda a: a[None], out),
            st.overflow[None], st.nonfinite[None])


f = jax.jit(shard_map(
    gbody, mesh=mesh,
    in_specs=({"w": P("x", None, None), "b": P("x", None)},),
    out_specs=({"w": P("x", None, None), "b": P("x", None)},
               P("x"), P("x"))))
out, o, nf = f(grads)
assert np.asarray(o).all(), "grad sync dropped the overflow flag"
ww = np.asarray(out["w"])[0]
# fallback + relative_eb: sum is exact up to the scale fold (f32 mul/div)
assert np.allclose(ww, grads["w"].sum(axis=0), rtol=1e-5), \
    "grad fallback values wrong"
print("OK grad_sync_stats")

# --- shrink loop: geometrically shrink capacity_factor to the minimal
# failing value, then verify exact recovery right at the boundary ---
xs = smooth(4)
factor, failing = 1.2, None
while factor > 1e-3:
    cfg = GZConfig(eb=1e-5, capacity_factor=factor, on_overflow="fallback")
    v, o, nf = run_allreduce(xs, 4, cfg)
    if o.any():
        failing = factor
        assert np.array_equal(v, psum_ref(xs, 4)), \
            f"shrunk factor {factor}: fallback not bitwise psum"
        break
    factor /= 2.0
assert failing is not None, "no capacity_factor small enough to overflow"
print(f"OK capacity_shrink_property (first failing factor={failing:g})")

# --- raise policy LAST: the debug-callback raise propagates, and the
# dead runtime tokens it leaves must not poison the exit path ---
raised = False
try:
    run_allreduce(rough(N), N,
                  GZConfig(eb=1e-6, capacity_factor=0.02,
                           on_overflow="raise"))
    jax.effects_barrier()
except Exception as e:  # XlaRuntimeError wrapping the RuntimeError
    raised = "degraded" in str(e) or "overflow" in str(e)
assert raised, "on_overflow='raise' did not propagate"
print("OK raise_policy")

print("ALL OK")
sys.stdout.flush()
os._exit(0)
