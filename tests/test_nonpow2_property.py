"""Hypothesis property: the remainder-stage redoub stays inside the
end-to-end error bound across shapes, axis sizes and bounds (ISSUE 4).

Kept in its own module because ``pytest.importorskip`` at module scope
skips the whole file — the deterministic non-pow2 tests live in
tests/test_nonpow2.py and must run even without hypothesis.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[dev])"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cost_model as cm  # noqa: E402
from repro.core import simulator  # noqa: E402
from repro.core.collectives import GZConfig  # noqa: E402
from repro.core.comm import GZCommunicator, _stream_bytes  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 13),
    d=st.sampled_from([257, 1024, 1537]),  # off-block, whole-block, ragged
    eb=st.sampled_from([1e-3, 1e-4]),
    seed=st.integers(0, 1000),
)
def test_property_remainder_redoub_budget_sound(n, d, eb, seed):
    """For ANY axis size (remainder folds included) the end-to-end redoub
    error stays <= eb under worst-case allocation: the fold pre-hops keep
    the n-1 merge-tree count and the unfold post-hop is the one extra
    quantization lossy_hops charges."""
    rng = np.random.default_rng(seed)
    xs = [np.cumsum(rng.normal(0, 0.01, d)).astype(np.float32)
          for _ in range(n)]
    cfg = GZConfig(eb=eb, capacity_factor=1.3, worst_case_budget=True)
    outs = simulator.sim_allreduce_redoub(xs, cfg)
    exact = np.sum(xs, axis=0)
    slack = max(np.abs(exact).max(), 1.0) * 1e-6
    for o in outs:
        assert np.abs(o - exact).max() <= eb + slack


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(2, 16),
    chunk=st.sampled_from([257, 512, 1537]),  # off-block / aligned / ragged
    seed=st.integers(0, 1000),
)
def test_property_trimmed_scatter_schedule_sound(n, chunk, seed):
    """ISSUE 5 property: for ANY axis size the trimmed scatter schedule
    (a) sums to exactly n-1 root chunk streams, (b) delivers every real
    rank the slab ``sim_scatter_binomial`` replays (its real virtual
    subtree, exactly once, within eb), and (c) the plan's reported
    ``CollectiveResult.wire_bytes``/``ratio`` match the trimmed
    accounting — not the padded virtual tree's."""
    table = cm.binomial_slab_table(n)
    assert cm.scatter_root_chunk_streams(n) == n - 1
    receivers = []
    for span, full, trim in table:
        for rcv, slab in [(i + span, span) for i in full] + (
                [(trim[1], trim[2])] if trim else []):
            receivers.append(rcv)
            assert slab == min(n, rcv + span) - rcv
    assert sorted(receivers) == list(range(1, n))

    rng = np.random.default_rng(seed)
    full_payload = np.cumsum(rng.normal(0, 0.01, n * chunk)).astype(
        np.float32)
    cfg = GZConfig(eb=1e-3, capacity_factor=1.3)
    outs, trace = simulator.sim_scatter_binomial(full_payload, n, cfg,
                                                 return_trace=True)
    for r, o in enumerate(outs):
        want = full_payload[r * chunk : (r + 1) * chunk]
        assert np.abs(o - want).max() <= 1e-3 + np.abs(want).max() * 2e-7
    for rcv, (span, idxs) in trace.items():
        assert idxs == tuple(range(rcv, min(n, rcv + span)))

    plan = GZCommunicator(
        "x", axis_size=n, config=cfg
    ).plan("scatter", n * chunk)
    assert plan.wire_bytes == (n - 1) * _stream_bytes(chunk, 1.3)
    assert plan.ratio == (n - 1) * chunk * 4 / plan.wire_bytes
    assert plan.slab_table == table
