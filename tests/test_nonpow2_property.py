"""Hypothesis property: the remainder-stage redoub stays inside the
end-to-end error bound across shapes, axis sizes and bounds (ISSUE 4).

Kept in its own module because ``pytest.importorskip`` at module scope
skips the whole file — the deterministic non-pow2 tests live in
tests/test_nonpow2.py and must run even without hypothesis.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[dev])"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import simulator  # noqa: E402
from repro.core.collectives import GZConfig  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 13),
    d=st.sampled_from([257, 1024, 1537]),  # off-block, whole-block, ragged
    eb=st.sampled_from([1e-3, 1e-4]),
    seed=st.integers(0, 1000),
)
def test_property_remainder_redoub_budget_sound(n, d, eb, seed):
    """For ANY axis size (remainder folds included) the end-to-end redoub
    error stays <= eb under worst-case allocation: the fold pre-hops keep
    the n-1 merge-tree count and the unfold post-hop is the one extra
    quantization lossy_hops charges."""
    rng = np.random.default_rng(seed)
    xs = [np.cumsum(rng.normal(0, 0.01, d)).astype(np.float32)
          for _ in range(n)]
    cfg = GZConfig(eb=eb, capacity_factor=1.3, worst_case_budget=True)
    outs = simulator.sim_allreduce_redoub(xs, cfg)
    exact = np.sum(xs, axis=0)
    slack = max(np.abs(exact).max(), 1.0) * 1e-6
    for o in outs:
        assert np.abs(o - exact).max() <= eb + slack
