"""Fused quantize->pack pipeline + chunked pipelined ring collectives.

Three contracts (ISSUE 1 acceptance criteria):

  1. ``quantize_pack`` produces a BYTE-IDENTICAL packed stream to the
     unfused ``quantize`` + ``bitpack.pack`` composition (oracle test),
     including when the stream overflows the provisioned capacity.
  2. ``unpack_dequantize_reduce`` matches its unfused oracle and the
     fused/unfused compressors interoperate on the same wire format.
  3. The pipelined (chunked double-buffered) ring schedules return the
     same results as the sequential ones — bitwise when piece boundaries
     align with the sequential chunking, within the documented error
     budget otherwise — and ``intring`` stays bitwise rank-identical.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bitpack
from repro.core.compressed import capacity_words_for
from repro.core.compressor import ErrorBoundedLorenzo
from repro.kernels import lorenzo, ops, ref

EB = 1e-3


def _field(rng, n):
    smooth = np.cumsum(rng.normal(0, 0.02, n))
    rough = rng.normal(0, 1.0, n) * (rng.random(n) < 0.05)
    out = (smooth + rough).astype(np.float32)
    out[:: max(n // 13, 1)] = 0.0
    return out


# ---------------------------------------------------------------------------
# 1. Fused pack vs oracle — byte identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("eb", [1e-2, 1e-3, 1e-4])
@pytest.mark.parametrize("rows", [8, 16, 64])
def test_quantize_pack_byte_identical_to_unfused(eb, rows):
    rng = np.random.default_rng(rows)
    x = _field(rng, rows * lorenzo.BLOCK).reshape(rows, lorenzo.BLOCK)
    cap = capacity_words_for(x.size, 1.2, lorenzo.BLOCK)
    pk_f, bw_f, an_f = ops.quantize_pack(jnp.asarray(x), eb, cap)
    pk_r, bw_r, an_r = ref.quantize_pack_ref(jnp.asarray(x), jnp.float32(eb), cap)
    np.testing.assert_array_equal(np.asarray(bw_f), np.asarray(bw_r))
    np.testing.assert_array_equal(np.asarray(an_f), np.asarray(an_r))
    np.testing.assert_array_equal(np.asarray(pk_f), np.asarray(pk_r))


def test_quantize_pack_byte_identical_under_overflow():
    """Capacity overflow: valid words stay byte-identical, the overflowing
    tail is dropped in both paths, and nwords flags the condition."""
    rng = np.random.default_rng(7)
    rows = 32
    x = rng.normal(0, 100.0, (rows, lorenzo.BLOCK)).astype(np.float32)  # rough
    cap = 64  # far too small on purpose
    pk_f, bw_f, _ = ops.quantize_pack(jnp.asarray(x), EB, cap)
    pk_r, bw_r, _ = ref.quantize_pack_ref(jnp.asarray(x), jnp.float32(EB), cap)
    np.testing.assert_array_equal(np.asarray(pk_f), np.asarray(pk_r))
    nwords = int(bitpack.packed_words(jnp.asarray(bw_f), lorenzo.BLOCK))
    assert nwords > cap  # genuinely overflowed
    assert pk_f.shape == (cap,)  # never silently grows


@pytest.mark.parametrize("eb", [1e-2, 1e-4])
def test_unpack_dequantize_reduce_matches_oracle(eb):
    rng = np.random.default_rng(3)
    rows = 24
    x = _field(rng, rows * lorenzo.BLOCK).reshape(rows, lorenzo.BLOCK)
    acc = rng.normal(0, 1, x.shape).astype(np.float32)
    cap = capacity_words_for(x.size, 1.2, lorenzo.BLOCK)
    pk, bw, an = ops.quantize_pack(jnp.asarray(x), eb, cap)
    got = ops.unpack_dequantize_reduce(pk, bw, an, eb, jnp.asarray(acc))
    want = ref.unpack_dequantize_reduce_ref(
        pk, bw, an, jnp.float32(eb), jnp.asarray(acc)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-6)
    # end-to-end compressor invariant through the fused pipeline
    err = np.abs(np.asarray(got) - acc - x).max()
    assert err <= eb * (1 + 1e-3) + np.abs(x).max() * 2e-7


@pytest.mark.parametrize("eb", [1e-2, 1e-4])
def test_unpack_dequantize_no_acc_matches_dequantize(eb):
    """The accumulator-free fused decompress equals unpack+dequantize
    exactly (it is the allgather/scatter receive path)."""
    rng = np.random.default_rng(11)
    rows = 16
    x = _field(rng, rows * lorenzo.BLOCK).reshape(rows, lorenzo.BLOCK)
    cap = capacity_words_for(x.size, 1.2, lorenzo.BLOCK)
    pk, bw, an = ops.quantize_pack(jnp.asarray(x), eb, cap)
    got = ops.unpack_dequantize(pk, bw, an, eb)
    codes = bitpack.unpack(pk, bw, lorenzo.BLOCK)
    want = ref.dequantize_ref(codes, an, jnp.float32(eb))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", [1, 255, 4097, 50_000])
def test_fused_and_unfused_compressors_interoperate(n):
    """Same wire container either way: fused-compressed payloads decompress
    identically through the unfused path and vice versa."""
    rng = np.random.default_rng(n)
    x = jnp.asarray(np.cumsum(rng.normal(0, 0.01, n)).astype(np.float32))
    fused = ErrorBoundedLorenzo(capacity_factor=1.2, fused=True)
    unfused = ErrorBoundedLorenzo(capacity_factor=1.2, fused=False)
    c_f, c_u = fused.compress(x, EB), unfused.compress(x, EB)
    np.testing.assert_array_equal(np.asarray(c_f.packed), np.asarray(c_u.packed))
    assert int(c_f.nwords) == int(c_u.nwords)
    np.testing.assert_array_equal(
        np.asarray(unfused.decompress(c_f)), np.asarray(fused.decompress(c_u))
    )
    acc = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(fused.decompress_reduce(c_u, acc)),
        np.asarray(unfused.decompress_reduce(c_f, acc)),
        rtol=0, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# 2. Pipelined vs sequential ring schedules (single-device piece simulator)
# ---------------------------------------------------------------------------


def _sim_rs_ring(xs, eb_stage, piece_splits, comp):
    """Global-view ring reduce-scatter with each chunk in `piece_splits`
    pieces — the schedule of _reduce_scatter_ring_pipelined (owner_offset=0,
    piece order within a step preserved)."""
    n = len(xs)
    d = xs[0].shape[0]
    assert d % (n * piece_splits) == 0
    chunk = d // n
    piece = chunk // piece_splits

    def rt(v):
        c = comp.compress(jnp.asarray(v), eb_stage)
        return np.asarray(comp.decompress(c))

    acc = [x.astype(np.float32).copy() for x in xs]
    for s in range(n - 1):
        for p in range(piece_splits):
            sends = [
                rt(acc[r][((r - s) % n) * chunk + p * piece:][:piece])
                for r in range(n)
            ]
            for r in range(n):
                lo = ((r - s - 1) % n) * chunk + p * piece
                acc[r][lo : lo + piece] += sends[(r - 1) % n]
    return acc, chunk, piece


@pytest.mark.parametrize("n", [4, 8])
def test_pipelined_rs_bitwise_equals_sequential_when_aligned(n):
    """Piece boundaries are whole compressor tiles, so the quantization grid
    — and hence every intermediate value — matches the sequential schedule
    exactly when the sequential chunking is piece-aligned."""
    P = 2
    quantum = lorenzo.BLOCK * lorenzo.TILE_ROWS
    d = n * P * quantum
    rng = np.random.default_rng(n)
    xs = [np.cumsum(rng.normal(0, 0.01, d)).astype(np.float32) for _ in range(n)]
    comp = ErrorBoundedLorenzo(capacity_factor=1.2)
    eb_stage = EB / n
    seq, _, _ = _sim_rs_ring(xs, eb_stage, 1, comp)
    pip, _, _ = _sim_rs_ring(xs, eb_stage, P, comp)
    for a, b in zip(seq, pip):
        np.testing.assert_array_equal(a, b)


def test_pipelined_rs_within_budget_when_unaligned():
    n, P = 4, 4
    quantum = lorenzo.BLOCK * lorenzo.TILE_ROWS
    d = n * P * quantum
    rng = np.random.default_rng(0)
    xs = [np.cumsum(rng.normal(0, 0.01, d)).astype(np.float32) for _ in range(n)]
    comp = ErrorBoundedLorenzo(capacity_factor=1.2)
    eb_stage = EB / n
    pip, chunk, _ = _sim_rs_ring(xs, eb_stage, P, comp)
    exact = np.sum(xs, axis=0)
    for r in range(n):
        lo = ((r + 1) % n) * chunk
        got = pip[r][lo : lo + chunk]
        err = np.abs(got - exact[lo : lo + chunk]).max()
        assert err <= (n - 1) * eb_stage + np.abs(exact).max() * 1e-6


# ---------------------------------------------------------------------------
# 3. Cost model + selector acceptance (pipelined dominates above saturation)
# ---------------------------------------------------------------------------


def test_pipelined_ring_dominates_above_saturation_and_selected():
    from repro.core import cost_model as cm
    from repro.core.selector import select_allreduce_plan

    for hw in (cm.A100_SLINGSHOT, cm.TPU_V5E):
        D, N, R = 646e6, 8, 20
        assert D / N / 1e6 > hw.cmp_saturation_mb  # chunks stay saturated
        best = cm.best_pipeline_chunks(D, N, R, hw)
        assert best > 1
        assert cm.allreduce_ring_gz_chunked(D, N, R, hw, best) < \
            cm.allreduce_ring_gz_chunked(D, N, R, hw, 1)
        algo, chunks = select_allreduce_plan(int(D), N, R, hw)
        assert (algo, chunks) == ("ring", best)


def test_chunked_model_degrades_to_sequential_below_saturation():
    from repro.core import cost_model as cm

    for hw in (cm.A100_SLINGSHOT, cm.TPU_V5E):
        D, N = 1e6, 64  # 16 KB chunks: overhead-dominated
        assert cm.best_pipeline_chunks(D, N, 20, hw) == 1
