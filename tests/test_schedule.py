"""Schedule IR conservation + single-authority checks (ISSUE 10).

Deterministic, exhaustive mirror of tests/test_schedule_property.py: the
route tables are tiny pure-python artifacts, so every op × algo × N in
2..13 is enumerated outright (the hypothesis file samples the same space
plus randomized plan knobs and runs only when hypothesis is installed).

What a table must satisfy (schedule.validate encodes the structural
part; the pricing and error pins close the loop to the plan layer):

  * conservation — reduce ops deliver every chunk's full sum, movement
    ops deliver every chunk to its destination exactly once;
  * binomial trim — at most ONE trimmed (partial-slab) entry per round;
  * redoub remainder — fold/unfold rounds appear iff N is non-pow2;
  * pricing — the busiest sender's summed per-entry payload equals
    ``Plan.wire_bytes`` bit-for-bit (simulator.sim_wire_bytes measures
    entries with jax.eval_shape of the real compressor; the plan prices
    the same table through independent container arithmetic);
  * error — ``lossy_hop_count`` (abstract replay of the table) equals
    ``error_budget.lossy_hops``'s contract for every algo key.
"""
import numpy as np
import pytest

from repro.core import error_budget, schedule, simulator
from repro.core.collectives import GZConfig
from repro.core.comm import GZCommunicator

NS = range(2, 14)

FLAT_BUILDS = [("allreduce", a) for a in ("ring", "redoub", "intring")] + [
    ("reduce_scatter", "ring"),
    ("allgather", "ring"),
    ("scatter", "binomial"),
    ("broadcast", "binomial"),
    ("all_to_all", "direct"),
]


@pytest.mark.parametrize("op,algo", FLAT_BUILDS)
@pytest.mark.parametrize("n", NS)
def test_conservation_all_builders(op, algo, n):
    sched = schedule.build(op, algo, n)
    schedule.validate(sched)  # raises with a diagnostic on any violation
    assert sched.op == op and sched.n == n
    assert len(sched.combine) == sched.n_rounds


@pytest.mark.parametrize("n", NS)
def test_binomial_at_most_one_trim_per_round(n):
    sched = schedule.build("scatter", "binomial", n)
    chunk_counts = {}
    for rnd in sched.rounds:
        slabs = sorted(h.chunk_slab[1] for h in rnd)
        # full slabs share one span length; at most one shorter (trimmed)
        assert len([s for s in slabs if s != max(slabs)]) <= 1, (n, slabs)
        for h in rnd:
            for c in range(h.chunk_slab[0],
                           h.chunk_slab[0] + h.chunk_slab[1]):
                chunk_counts[c] = chunk_counts.get(c, 0) + 1
    # every non-root chunk shipped at least once, nothing out of range
    assert set(chunk_counts) <= set(range(n))


@pytest.mark.parametrize("n", NS)
def test_redoub_fold_unfold_iff_nonpow2(n):
    sched = schedule.build("allreduce", "redoub", n)
    stages = [h.stage for rnd in sched.rounds for h in rnd]
    pow2 = n & (n - 1) == 0
    assert ("unfold" in stages) == (not pow2), (n, stages)
    if not pow2:
        # fold is the FIRST round (lossy reduce into even peers), unfold
        # the LAST (install back to the odd peers)
        assert sched.combine[0] == "reduce"
        assert sched.combine[-1] == "install"
        assert all(h.stage == "unfold" for h in sched.rounds[-1])


@pytest.mark.parametrize("op,algo", FLAT_BUILDS)
@pytest.mark.parametrize("n", [2, 3, 6, 8, 9, 13])
def test_payload_sum_equals_plan_wire_bytes(op, algo, n):
    """Single authority: replaying the table for bytes reproduces the
    plan's provisioned wire_bytes EXACTLY (not approximately)."""
    cfg = GZConfig(eb=1e-3, algo=algo if op == "allreduce" else "auto")
    c = GZCommunicator("i", axis_size=n, config=cfg)
    plan = c.plan(op, (5000,), "float32")
    assert plan.route_table == schedule.build(op, plan.algo, n)
    assert simulator.sim_wire_bytes(plan) == plan.wire_bytes


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("algo_key", [
    "allreduce_redoub", "allreduce_ring", "allreduce_intring",
    "reduce_scatter_ring", "allgather_ring", "scatter_binomial",
    "broadcast_binomial",
])
def test_lossy_hops_from_table_replay(algo_key, n):
    """error_budget.lossy_hops == the table's abstract error replay."""
    assert error_budget.lossy_hops(algo_key, n) == \
        schedule.lossy_hops_for(algo_key, n)


def test_perm_is_the_ppermute_authority():
    """Schedule.perm(k) produces exactly the (src, dst) pairs of round k
    — the single source collectives' lax.ppermute calls draw from."""
    sched = schedule.build("allreduce", "ring", 5)
    for k, rnd in enumerate(sched.rounds):
        assert sched.perm(k) == tuple((h.sender, h.receiver) for h in rnd)
    assert schedule.ring_perm(5) == tuple(
        (i, (i + 1) % 5) for i in range(5))


def test_hier_table_stages():
    """build_hier: raw exact intra rounds sandwich the lifted compressed
    inter rounds; pricing sees uniform per-round payload kinds."""
    sched = schedule.build_hier(3, 2, "redoub")
    assert sched.n == 6
    kinds = [{h.payload_kind for h in rnd} for rnd in sched.rounds]
    assert kinds[0] == {"raw"} and kinds[-1] == {"raw"}
    assert any("compressed" in ks for ks in kinds[1:-1])
    # NOTE: validate() applies to FLAT tables only — build_hier's lifted
    # inter rounds keep the inter schedule's own chunk space over the
    # shard (the documented asymmetry), so conservation is checked per
    # stage by the flat builders it composes.


def test_build_rejects_unknown():
    with pytest.raises(ValueError):
        schedule.build("allreduce", "nope", 4)
    with pytest.raises(ValueError):
        schedule.build("nope", "ring", 4)
