"""End-to-end training integration: loss decreases, checkpoint round-trips."""
import numpy as np
import jax
import pytest

from repro.checkpoint import checkpoint
from repro.configs import registry
from repro.core.collectives import GZConfig
from repro.data.pipeline import SyntheticStream
from repro.launch.shapes import InputShape, train_specs
from repro.launch.training import make_setup, make_train_step
from repro.models.parallel import init_params
from repro.optim.adamw import AdamWConfig, adamw_init

STEPS, BATCH, SEQ = 12, 4, 64


def _train(arch, grad_gz=None, steps=STEPS, **setup_kwargs):
    cfg = registry.get(arch, smoke=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt = AdamWConfig(lr=1e-3, total_steps=steps, warmup_steps=2)
    setup = make_setup(cfg, mesh, opt=opt, grad_gz=grad_gz, **setup_kwargs)
    _, bspecs = train_specs(cfg, InputShape("t", SEQ, BATCH, "train"), mesh)
    step_fn = make_train_step(setup, bspecs)
    params = init_params(setup.defs, jax.random.key(0))
    opt_state = adamw_init(params)
    stream = SyntheticStream(cfg, BATCH, SEQ, seed=0)
    losses = []
    for _, batch in zip(range(steps), stream):
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    return losses, params, opt_state


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["minitron-8b", "mamba2-780m",
                                  "phi3.5-moe-42b-a6.6b"])
def test_loss_decreases(arch):
    losses, _, _ = _train(arch)
    assert losses[-1] < losses[0] - 0.2, losses


@pytest.mark.slow
def test_gz_grad_sync_trains():
    """Training with compressed gradient sync still learns (1-device mesh
    degenerates the collectives to identity; the multi-device version is
    exercised by examples/compressed_training.py and the gradsync child)."""
    losses, _, _ = _train(
        "minitron-8b", GZConfig(eb=1e-5, algo="redoub")
    )
    assert losses[-1] < losses[0] - 0.2


@pytest.mark.slow
def test_overlap_sync_trains_identically():
    """ISSUE 9: the per-bucket backward hooks are value-neutral — on a
    1-device mesh every reduction degenerates to identity in BOTH paths,
    so overlapped and post-hoc training must produce bitwise-equal
    params.  (Multi-device hook/value parity is asserted in
    tests/_mp_gradsync_child.py.)"""
    gz = GZConfig(eb=1e-5, algo="redoub")
    losses, params, _ = _train("minitron-8b", gz, steps=3)
    losses_ov, params_ov, _ = _train(
        "minitron-8b", gz, steps=3, overlap_sync=True,
        bucket_bytes=256 * 1024,  # force several buckets per group
    )
    assert losses == losses_ov, (losses, losses_ov)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params_ov)):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.name == "bfloat16":
            a, b = a.view(np.uint16), b.view(np.uint16)
        np.testing.assert_array_equal(a, b)


def test_checkpoint_roundtrip(tmp_path):
    losses, params, opt_state = _train("minitron-8b", steps=3)
    tree = {"params": params, "opt": opt_state}
    d = checkpoint.save(str(tmp_path), 3, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 3
    restored = checkpoint.restore(str(tmp_path), 3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.name == "bfloat16":
            a, b = a.view(np.uint16), b.view(np.uint16)
        np.testing.assert_array_equal(a, b)
