"""Substrate units: data pipeline, input shapes/plans, optimizer math."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.data.pipeline import SyntheticStream, make_batch
from repro.launch.shapes import INPUT_SHAPES, decode_plan
from repro.models.parallel import ParallelCtx
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule


def test_stream_shapes_and_determinism():
    cfg = registry.get("minitron-8b", smoke=True)
    s1 = iter(SyntheticStream(cfg, 4, 32, seed=7))
    s2 = iter(SyntheticStream(cfg, 4, 32, seed=7))
    b1, b2 = next(s1), next(s2)
    assert b1["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    full = np.concatenate([b1["tokens"], b1["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full[:, 1:], b1["labels"])


def test_vlm_audio_encdec_batches_have_frontend_stubs():
    for arch in ["internvl2-26b", "seamless-m4t-medium"]:
        cfg = registry.get(arch, smoke=True)
        b = next(iter(SyntheticStream(cfg, 2, 32)))
        key = "prefix" if cfg.family == "vlm" else "enc_input"
        assert key in b and b[key].shape[2] == cfg.d_model


def test_decode_plan_rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # long_500k + attention arch -> sliding window
    cfg = registry.get("internlm2-20b")
    p = decode_plan(cfg, INPUT_SHAPES["long_500k"], mesh)
    assert p.window == 8192
    # long_500k + pure SSM -> no window (state recurrence)
    cfg = registry.get("mamba2-780m")
    p = decode_plan(cfg, INPUT_SHAPES["long_500k"], mesh)
    assert p.window == 0
    # decode_32k big batch -> batch-sharded (no context parallel)
    p = decode_plan(cfg, INPUT_SHAPES["decode_32k"], mesh)
    assert p.cp_axis is None


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=100)
    p = {"w": jnp.asarray([3.0, -2.0])}
    st = adamw_init(p)
    for _ in range(60):
        g = {"w": 2 * p["w"]}  # grad of ||w||^2
        p, st, _ = adamw_update(p, g, st, cfg)
    assert float(jnp.abs(p["w"]).max()) < 0.5


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]  # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]  # decay
    assert abs(lrs[2] - 1.0) < 1e-6


def test_param_counts_sane():
    """Config param_count should be within 20% of the actual tree size."""
    for arch in ["minitron-8b", "mamba2-780m", "phi3.5-moe-42b-a6.6b"]:
        cfg = registry.get(arch, smoke=True)
        ctx = ParallelCtx(tp_size=1, fsdp_size=1)
        defs = Model(cfg, ctx).param_defs()
        actual = sum(
            int(np.prod(d.shape))
            for d in jax.tree.leaves(defs, is_leaf=lambda x: hasattr(x, "spec"))
        )
        est = cfg.param_count()
        assert 0.5 < est / actual < 1.6, (arch, est, actual)
