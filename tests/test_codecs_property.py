"""Hypothesis property: the codec subsystem over shapes x ebs x codecs
(ISSUE 8 satellite).

Kept in its own module because ``pytest.importorskip`` at module scope
skips the whole file — the deterministic codec tests live in
tests/test_codecs.py and must run even without hypothesis.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e .[dev])"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import codecs, compressor  # noqa: E402

LOSSY = ("lorenzo", "lorenzo+entropy")
EXACT = ("lossless", "passthrough")


def _data(n, seed, smooth):
    rng = np.random.default_rng(seed)
    if smooth:
        return jnp.asarray(np.cumsum(rng.normal(0, 0.01, n)), jnp.float32)
    return jnp.asarray(rng.normal(0, 100.0, n), jnp.float32)


@settings(max_examples=30, deadline=None)
@given(
    n=st.sampled_from([64, 100, 256, 1537, 2048, 5000]),
    eb=st.sampled_from([1e-2, 1e-3, 1e-4]),
    codec=st.sampled_from(LOSSY + EXACT),
    smooth=st.booleans(),
    seed=st.integers(0, 1000),
)
def test_property_roundtrip_error_bounded(n, eb, codec, smooth, seed):
    """Round-trip error <= eb for every lossy codec, bit-exact for the
    exact codecs, at ANY shape/eb/data roughness."""
    comp = codecs.build_compressor(codec, capacity_factor=2.0, fused=True)
    x = _data(n, seed, smooth)
    c = comp.compress(x, eb)
    if bool(c.overflowed()):
        return  # starved capacity is flagged, not silently wrong
    y = comp.decompress(c)
    if codec in EXACT:
        np.testing.assert_array_equal(
            np.asarray(x).view(np.uint32), np.asarray(y).view(np.uint32)
        )
    else:
        assert float(jnp.max(jnp.abs(y - x))) <= eb * (1 + 1e-6)


@settings(max_examples=30, deadline=None)
@given(
    n=st.sampled_from([64, 100, 256, 1537, 2048, 5000]),
    eb=st.sampled_from([1e-2, 1e-3, 1e-4]),
    smooth=st.booleans(),
    seed=st.integers(0, 1000),
)
def test_property_entropy_wire_never_longer_than_dense(n, eb, smooth, seed):
    """The per-sub-block trimmed stream is <= the dense bitpack of the
    SAME quantized codes for any input — the descriptor lives in the
    existing bitwidth slot, so there is no header to amortize — and
    strictly shorter on smooth data."""
    x = _data(n, seed, smooth)
    dense = codecs.build_compressor("lorenzo", capacity_factor=2.0, fused=True)
    trim = codecs.build_compressor(
        "lorenzo+entropy", capacity_factor=2.0, fused=True
    )
    cd, ct = dense.compress(x, eb), trim.compress(x, eb)
    if bool(cd.overflowed()) or bool(ct.overflowed()):
        return
    assert int(ct.nwords) <= int(cd.nwords)
    if smooth:
        assert int(ct.nwords) < int(cd.nwords)
    # Same quantization grid: decoded values identical across wires.
    np.testing.assert_array_equal(
        np.asarray(dense.decompress(cd)), np.asarray(trim.decompress(ct))
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([100, 1537, 4096]),
    eb=st.sampled_from([1e-3, 1e-4]),
    seed=st.integers(0, 1000),
)
def test_property_default_codec_bytes_unchanged(n, eb, seed):
    """codec='lorenzo' through the registry is byte-identical to the
    pre-registry compressor path on any input."""
    x = _data(n, seed, smooth=True)
    via_registry = codecs.build_compressor(
        "lorenzo", capacity_factor=0.6, fused=True
    )
    direct = compressor.ErrorBoundedLorenzo(capacity_factor=0.6, fused=True)
    a, b = via_registry.compress(x, eb), direct.compress(x, eb)
    np.testing.assert_array_equal(np.asarray(a.packed), np.asarray(b.packed))
    np.testing.assert_array_equal(
        np.asarray(a.bitwidth), np.asarray(b.bitwidth)
    )
    np.testing.assert_array_equal(np.asarray(a.anchor), np.asarray(b.anchor))
    assert int(a.nwords) == int(b.nwords)
