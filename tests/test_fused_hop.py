"""Single-pass ring hop (ISSUE 2): unpack→reduce→repack in one kernel.

Contracts:

  1. The fused ``unpack_reduce_repack`` kernel is BYTE-IDENTICAL to the
     PR 1 two-kernel composition (``unpack_dequantize_reduce`` then
     ``quantize_pack``) — wire words, bitwidths, anchors, and the f32
     intermediate — including under capacity overflow of the output.
  2. ``ErrorBoundedLorenzo.decompress_reduce_compress`` fused vs the
     decompress_reduce ∘ compress composition: byte-identical Compressed
     payloads across shapes, error bounds and piece alignments (hypothesis
     property test + deterministic sweep), and the overflow flag agrees.
  3. The fused-hop cost model: one ``cmp_overhead_us`` per piece-hop
     instead of two ⇒ ``best_pipeline_chunks`` selects STRICTLY deeper
     pipelines at calibrated (D, N) points, and the selector's ring plan
     picks it up.  (Planner defaults are fused_hop=True, matching
     GZConfig — the two-kernel model is requested explicitly.)

(The 8-device bitwise-equality of the fused-hop ring/redoub schedules vs
the PR 1 two-kernel path lives in tests/_mp_collectives_child.py.)
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.compressed import capacity_words_for
from repro.core.compressor import ErrorBoundedLorenzo
from repro.kernels import lorenzo, ops

B = lorenzo.BLOCK
QUANTUM = lorenzo.BLOCK * lorenzo.TILE_ROWS


def _field(rng, n, kind):
    if kind == "smooth":
        return np.cumsum(rng.normal(0, 0.02, n)).astype(np.float32)
    if kind == "boundary":  # values near quantization half-grid points
        k = rng.integers(-1000, 1000, n)
        return ((k + 0.5) * 2e-3 + rng.normal(0, 1e-9, n)).astype(np.float32)
    return (rng.normal(0, 1.0, n) * (rng.random(n) < 0.2)).astype(np.float32)


# ---------------------------------------------------------------------------
# 1. Kernel-level byte identity vs the two-kernel composition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["smooth", "boundary", "spiky"])
@pytest.mark.parametrize("eb_in,eb_out", [(1e-3, 1e-3), (1e-2, 1e-4)])
def test_fused_hop_kernel_byte_identical_to_composition(kind, eb_in, eb_out):
    # deterministic per-parametrization seed (hash() is salted per process)
    seed = ["smooth", "boundary", "spiky"].index(kind) * 10 + int(eb_in * 1e4)
    rng = np.random.default_rng(seed)
    rows = 24
    x2 = jnp.asarray(_field(rng, rows * B, kind).reshape(rows, B))
    a2 = jnp.asarray(rng.normal(0, 1, (rows, B)).astype(np.float32))
    cap = capacity_words_for(rows * B, 1.3, B)
    pk, bw, an = ops.quantize_pack(x2, eb_in, cap)
    fp, fb, fa, fx = ops.unpack_reduce_repack(
        pk, bw, an, eb_in, a2, eb_out, cap, emit_f32=True
    )
    ux = ops.unpack_dequantize_reduce(pk, bw, an, eb_in, a2)
    cp, cb, ca = ops.quantize_pack(ux, eb_out, cap)
    np.testing.assert_array_equal(np.asarray(fx), np.asarray(ux))
    np.testing.assert_array_equal(np.asarray(fp), np.asarray(cp))
    np.testing.assert_array_equal(np.asarray(fb), np.asarray(cb))
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(ca))
    # no-f32 variant emits the same stream
    gp, gb, ga = ops.unpack_reduce_repack(pk, bw, an, eb_in, a2, eb_out, cap)
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(cp))
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(cb))
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(ca))


def test_fused_hop_kernel_byte_identical_under_output_overflow():
    """A starved OUTPUT capacity truncates both paths identically: the
    valid prefix stays byte-identical, the overflow lands in the dump
    tail, and the stream never silently grows."""
    rng = np.random.default_rng(5)
    rows = 32
    x2 = jnp.asarray(rng.normal(0, 100.0, (rows, B)).astype(np.float32))
    a2 = jnp.asarray(rng.normal(0, 1, (rows, B)).astype(np.float32))
    cap_in = capacity_words_for(rows * B, 1.3, B)
    pk, bw, an = ops.quantize_pack(x2, 1e-3, cap_in)
    small = 64
    fp, fb, _ = ops.unpack_reduce_repack(pk, bw, an, 1e-3, a2, 1e-3, small)
    ux = ops.unpack_dequantize_reduce(pk, bw, an, 1e-3, a2)
    cp, _, _ = ops.quantize_pack(ux, 1e-3, small)
    np.testing.assert_array_equal(np.asarray(fp), np.asarray(cp))
    assert fp.shape == (small,)
    from repro.core import bitpack

    assert int(bitpack.packed_words(fb, B)) > small  # genuinely overflowed


# ---------------------------------------------------------------------------
# 2. Compressor-level: decompress_reduce_compress fused == composition
# ---------------------------------------------------------------------------


def _assert_hop_identical(n, eb_in, eb_out, seed, kind="smooth"):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(_field(rng, n, kind))
    acc = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
    fused = ErrorBoundedLorenzo(capacity_factor=1.3, fused=True)
    unfused = ErrorBoundedLorenzo(capacity_factor=1.3, fused=False)
    c = fused.compress(x, eb_in)
    cf, uf = fused.decompress_reduce_compress(
        c, acc, eb_out, return_updated=True
    )
    cu, uu = unfused.decompress_reduce_compress(
        c, acc, eb_out, return_updated=True
    )
    np.testing.assert_array_equal(np.asarray(cf.packed), np.asarray(cu.packed))
    np.testing.assert_array_equal(np.asarray(cf.bitwidth), np.asarray(cu.bitwidth))
    np.testing.assert_array_equal(np.asarray(cf.anchor), np.asarray(cu.anchor))
    assert int(cf.nwords) == int(cu.nwords)
    np.testing.assert_array_equal(np.asarray(uf), np.asarray(uu))
    # the emitted stream is what compress(updated) would have produced
    c2 = fused.compress(uu, eb_out)
    np.testing.assert_array_equal(np.asarray(cf.packed), np.asarray(c2.packed))


@pytest.mark.parametrize("n", [1, 255, B, QUANTUM - 7, QUANTUM, 3 * QUANTUM + 513])
def test_decompress_reduce_compress_fused_equals_composition(n):
    """Byte identity across piece alignments: whole tiles, partial blocks,
    single elements — the padded-tail values reconstruct to exact 0.0 in
    both paths, so the quantization grid never diverges."""
    _assert_hop_identical(n, 1e-3, 1e-3, seed=n)
    _assert_hop_identical(n, 1e-2, 1e-4, seed=n + 1, kind="spiky")


def test_decompress_reduce_compress_overflow_flag_agrees():
    rng = np.random.default_rng(11)
    n = 2 * QUANTUM
    x = jnp.asarray(rng.normal(0, 100.0, n).astype(np.float32))
    acc = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
    for fused in (True, False):
        comp = ErrorBoundedLorenzo(capacity_factor=0.02, fused=fused)
        c = comp.compress(x, 1e-6)
        c_out, _ = comp.decompress_reduce_compress(c, acc)
        assert bool(c_out.overflowed()), f"fused={fused}"


# ---------------------------------------------------------------------------
# 3. Cost model: the fused hop buys strictly deeper pipelines
# ---------------------------------------------------------------------------


def test_fused_hop_cheaper_at_fixed_depth():
    from repro.core import cost_model as cm

    for hw in (cm.TPU_V5E, cm.A100_SLINGSHOT):
        for chunks in (1, 2, 4, 8):
            f = cm.allreduce_ring_gz_chunked(646e6, 8, 20, hw, chunks,
                                             fused_hop=True)
            u = cm.allreduce_ring_gz_chunked(646e6, 8, 20, hw, chunks,
                                             fused_hop=False)
            assert f < u, (hw.name, chunks)


def test_t_hop_fused_single_overhead():
    from repro.core import cost_model as cm

    for hw in (cm.TPU_V5E, cm.A100_SLINGSHOT):
        size = 1e6
        two_kernel = (cm.t_compress(size, hw) + cm.t_decompress(size, hw)
                      + cm.t_reduce(size, hw))
        fused = cm.t_hop_fused(size, hw)
        assert fused < two_kernel
        # exactly one per-invocation overhead in the fused hop
        work = fused - hw.cmp_overhead_us * 1e-6
        assert work > 0
        assert two_kernel - fused >= hw.cmp_overhead_us * 1e-6


def test_fused_hop_strictly_deeper_at_calibrated_points():
    """Acceptance: the halved per-piece overhead moves the overhead-vs-
    overlap break-even, so ``best_pipeline_chunks`` selects a STRICTLY
    deeper pipeline at calibrated (D, N) points on both hardware models —
    and at those points the deeper schedule is a real win under the fused
    model (not a tie broken differently)."""
    from repro.core import cost_model as cm

    strictly = {cm.TPU_V5E.name: 0, cm.A100_SLINGSHOT.name: 0}
    for hw in (cm.TPU_V5E, cm.A100_SLINGSHOT):
        for D in (64e6, 323e6, 646e6, 1.3e9):
            for N in (8, 16, 32, 64):
                for R in (3, 6, 20):
                    u = cm.best_pipeline_chunks(D, N, R, hw, fused_hop=False)
                    f = cm.best_pipeline_chunks(D, N, R, hw, fused_hop=True)
                    if f > u:
                        strictly[hw.name] += 1
                        assert cm.allreduce_ring_gz_chunked(
                            D, N, R, hw, f, fused_hop=True
                        ) < cm.allreduce_ring_gz_chunked(
                            D, N, R, hw, u, fused_hop=True
                        )
    assert all(v > 0 for v in strictly.values()), strictly


def test_selector_plan_picks_deeper_fused_ring():
    """At a calibrated point where the fused optimum is strictly deeper,
    the selector's ring plan follows the fused model."""
    from repro.core import cost_model as cm
    from repro.core.selector import select_allreduce_plan

    D, N, R, hw = 646e6, 16, 20, cm.A100_SLINGSHOT
    u = cm.best_pipeline_chunks(D, N, R, hw, fused_hop=False)
    f = cm.best_pipeline_chunks(D, N, R, hw, fused_hop=True)
    assert f > u
    algo_f, chunks_f = select_allreduce_plan(int(D), N, R, hw, fused_hop=True)
    if algo_f == "ring":
        assert chunks_f == f


def test_planner_respects_fused_hop_flag():
    from repro.core.collectives import plan_ring_pipeline_chunks

    # big payloads so the fill cap never binds
    n_elems = int(646e6 / 4)
    for n_ranks in (8, 16, 32):
        u = plan_ring_pipeline_chunks(n_elems, n_ranks, fused_hop=False)
        f = plan_ring_pipeline_chunks(n_elems, n_ranks, fused_hop=True)
        assert f >= u
