"""shard_map wrapper used framework-wide.

``check_vma=False`` because Pallas calls inside shard_map bodies cannot
declare varying-mesh-axes on their ShapeDtypeStruct outputs (JAX 0.8.x);
the collectives and model layers are written rank-centric and manage
replication explicitly.
"""
from __future__ import annotations

import functools

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs):
    return jax.shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )
