"""shard_map wrapper used framework-wide.

Replication checking is disabled (``check_vma``/``check_rep`` depending on
the JAX version) because Pallas calls inside shard_map bodies cannot
declare varying-mesh-axes on their ShapeDtypeStruct outputs; the
collectives and model layers are written rank-centric and manage
replication explicitly.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):  # JAX >= 0.6
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
