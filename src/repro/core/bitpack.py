"""Dense variable-bitwidth bit packing/unpacking (vectorized jnp).

This is the wire-format half of the cuSZp-adapted compressor: each block of
``B`` zigzag-encoded uint32 codes is packed at its own per-block bitwidth
``b_i`` into a single dense uint32 word stream.  Block *i*'s element *j*
occupies bits ``[off_i + j*b_i, off_i + (j+1)*b_i)`` where
``off_i = sum_{k<i} B*b_k``.

The pack target is a *statically provisioned* capacity buffer (see
DESIGN.md §2.1): XLA SPMD cannot move ragged payloads, so the true
compressed size travels alongside as ``nwords`` and overflow is detected,
never silent.

All routines are shape-polymorphic pure functions of jnp arrays and are
used both by the Pallas ``ops`` wrappers and by the pure-jnp reference
oracle, so they are themselves oracle-tested against a python loop in
``tests/test_bitpack.py``.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["pack", "unpack", "packed_words"]


def _u32(x):
    return x.astype(jnp.uint32)


def packed_words(bitwidth: jnp.ndarray, block: int) -> jnp.ndarray:
    """Total uint32 words needed for dense packing (int32 scalar)."""
    total_bits = jnp.sum(bitwidth.astype(jnp.int32)) * block
    return ((total_bits + 31) // 32).astype(jnp.int32)


# Bit positions are int32: a single pack() call is limited to 2**31 bits of
# packed stream (== 64M fully-incompressible f32 elements, 256 MiB).  The
# collective layer always chunks payloads far below this (grad_sync chunks
# at <= 4M elements); asserted in ``pack``.
def _positions(bitwidth: jnp.ndarray, block: int):
    """Per-element absolute bit position, word index and intra-word shift.

    Returns (word, shift, bw) each of shape (n_blocks, block), where ``bw``
    is the per-element copy of its block bitwidth.
    """
    bits_per_block = bitwidth.astype(jnp.int32) * block
    block_off = jnp.cumsum(bits_per_block) - bits_per_block  # exclusive
    j = jnp.arange(block, dtype=jnp.int32)
    bitpos = block_off[:, None] + j[None, :] * bitwidth.astype(jnp.int32)[:, None]
    word = (bitpos >> 5).astype(jnp.int32)
    shift = (bitpos & 31).astype(jnp.uint32)
    bw = jnp.broadcast_to(bitwidth[:, None], bitpos.shape).astype(jnp.uint32)
    return word, shift, bw


def pack(codes: jnp.ndarray, bitwidth: jnp.ndarray, capacity_words: int):
    """Pack per-block-bitwidth codes densely into a uint32 buffer.

    Args:
      codes: uint32 (n_blocks, block), each value < 2**bitwidth[i].
      bitwidth: int32 (n_blocks,), in [0, 32].
      capacity_words: static capacity of the output buffer.

    Returns:
      (packed uint32[capacity_words], nwords int32 scalar).  If
      ``nwords > capacity_words`` the overflowing words are dropped (callers
      must check the returned size; see ``Compressed.overflowed``).
    """
    n_blocks, block = codes.shape
    assert n_blocks * block <= (1 << 26), (
        "single pack() call limited to 64M elements; chunk the payload"
    )
    word, shift, bw = _positions(bitwidth, block)
    mask = jnp.where(
        bw == 0,
        jnp.uint32(0),
        jnp.uint32(0xFFFFFFFF) >> jnp.minimum(32 - bw, jnp.uint32(31)),
    )
    u = _u32(codes) & mask  # defensive: stray high bits would corrupt neighbours
    # A value of width b at intra-word shift s straddles at most two words
    # (b <= 32): low part u<<s, high part u>>(32-s) (only when s>0).
    lo = u << shift
    safe = jnp.minimum(32 - shift, jnp.uint32(31))
    hi = jnp.where(shift == 0, jnp.uint32(0), u >> safe)
    packed = jnp.zeros((capacity_words,), jnp.uint32)
    flat_word = word.reshape(-1)
    # Disjoint bit-ranges ==> OR == ADD; scatter-add is a single XLA op.
    packed = packed.at[flat_word].add(lo.reshape(-1), mode="drop")
    packed = packed.at[flat_word + 1].add(hi.reshape(-1), mode="drop")
    return packed, packed_words(bitwidth, block)


def unpack(packed: jnp.ndarray, bitwidth: jnp.ndarray, block: int) -> jnp.ndarray:
    """Inverse of :func:`pack`.  Returns uint32 (n_blocks, block)."""
    n_words = packed.shape[0]
    word, shift, bw = _positions(bitwidth, block)
    w0 = jnp.clip(word, 0, n_words - 1)
    w1 = jnp.clip(word + 1, 0, n_words - 1)
    lo = packed[w0] >> shift
    safe = jnp.minimum(32 - shift, jnp.uint32(31))
    hi = jnp.where(shift == 0, jnp.uint32(0), packed[w1] << safe)
    mask = jnp.where(
        bw == 0,
        jnp.uint32(0),
        jnp.uint32(0xFFFFFFFF) >> jnp.minimum(32 - bw, jnp.uint32(31)),
    )
    # bw==32 -> full mask; the >> above yields 0xFFFFFFFF for bw==32 already
    # (32-bw==0). bw==0 handled explicitly.
    return (lo | hi) & mask
