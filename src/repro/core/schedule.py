"""Schedule IR: the ONE per-round route table every layer walks (ISSUE 10).

gZCCL's core claim is that compression-enabled collectives must be
*planned* — schedule, pipeline depth and error budget resolved together
(paper §3).  Through PR 9 the per-round routes were still authored in
four independent places: ``collectives._execute_*`` built ``ppermute``
perms inline, ``simulator.py`` re-derived its own replays,
``comm._wire_accounting`` priced via step counts, and ``faults.py``
injected per-hop by convention.  That duplication produced real drift
(PR 4's floor-vs-ceil step count, PR 5's schedule-less scatter sim).
PR 5's ``binomial_slab_table`` proved the fix for the tree ops; this
module makes it the architecture for every algorithm.

A :class:`Schedule` is a frozen route table: ``rounds[k]`` is a tuple of
:class:`Hop` entries ``(sender, receiver, chunk_slab, stage,
payload_kind)`` — who ships which chunk slab to whom in wire round
``k``, whether the hop re-quantizes (``stage``) and what travels
(``payload_kind``).  Builders exist for every algorithm the stack runs:

  * ring reduce-scatter / allgather (both the fused-into-allreduce and
    the standalone owner conventions),
  * recursive doubling including the non-power-of-two fold/unfold
    remainder stage,
  * the integer ring (``intring`` — exact hops over one quantization
    grid),
  * the trimmed-slab binomial tree (scatter / broadcast — the slab
    combinatorics moved here from ``cost_model``),
  * the single-exchange all_to_all,
  * the two-level hierarchical composition (raw exact intra rounds
    around a lifted compressed inter schedule).

The table is authored ONCE here, resolved by the plan layer (carried on
``Plan.route_table`` / ``HierPlan.route_table``) and *walked* by the
four consumers: ``collectives`` takes every perm from it,
``simulator._replay_table`` re-executes it hop by hop,
``comm._wire_accounting`` prices it by summing per-entry payload bytes,
and ``faults.FaultSpec(rounds=...)`` targets its round indices so an
injected corruption lands on the identical wire exchange in the sim and
on a real mesh.  ``error_budget.lossy_hops`` is derived from it too, by
the abstract error replay in :func:`lossy_hop_count` — the worst-case
multiplier now holds by construction for any future algorithm instead
of by per-algo string dispatch.

Everything here is pure Python over ints — no jax, no repro imports —
so every other core module may depend on it without cycles.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import NamedTuple, Optional, Tuple

__all__ = [
    "Hop",
    "Schedule",
    "STAGES",
    "PAYLOAD_KINDS",
    "build",
    "build_hier",
    "ring_perm",
    "redoub_layout",
    "binomial_slab_table",
    "scatter_root_chunk_streams",
    "tree_plan",
    "lossy_hop_count",
    "lossy_hops_for",
    "validate",
    "sender_entry_counts",
]

STAGES = ("lossy", "exact", "unfold")
PAYLOAD_KINDS = ("compressed", "raw", "checksum")

OPS = ("allreduce", "reduce_scatter", "allgather", "scatter", "broadcast",
       "all_to_all")


class Hop(NamedTuple):
    """One wire exchange inside a round.

    ``chunk_slab = (start, length)`` indexes the schedule's chunk space
    (``Schedule.n_chunks`` chunks; chunk indices are taken mod
    ``n_chunks`` so ring arithmetic can stay in rank space).  ``stage``
    says whether the hop carries a FRESH quantization ("lossy"), an
    already-quantized stream forwarded bit-exactly ("exact"), or the
    remainder unfold install ("unfold" — lossy, but structurally the
    post-hop).  ``payload_kind`` is what travels: a compressed stream, a
    raw f32 slab (exact intra-node stages, lossless fallback) or a
    checksum sidecar.
    """

    sender: int
    receiver: int
    chunk_slab: Tuple[int, int]
    stage: str
    payload_kind: str


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Frozen per-round route table for one collective.

    ``rounds[k]`` is the tuple of hops of wire round ``k`` (all shipped
    concurrently — payloads are computed from the pre-round state).
    ``combine[k]`` says how a receiver folds what arrives: ``"reduce"``
    (accumulate into the slab) or ``"install"`` (overwrite the slab).
    ``initial_lossy`` charges quantizations that happen BEFORE any wire
    round (intring's single up-front grid).
    """

    op: str
    algo: str
    n: int
    n_chunks: int
    rounds: Tuple[Tuple[Hop, ...], ...]
    combine: Tuple[str, ...]
    initial_lossy: int = 0

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    def perm(self, k: int) -> tuple:
        """The ``lax.ppermute`` perm of round ``k`` — (sender, receiver)
        pairs in entry order.  THE one place execute-layer perms come
        from (enforced by scripts/check_schedule_authority.py)."""
        return tuple((h.sender, h.receiver) for h in self.rounds[k])


# ---------------------------------------------------------------------------
# Shared combinatorics (moved here from cost_model / collectives — this
# module is the bottom of the import graph)
# ---------------------------------------------------------------------------


def ceil_log2(n: int) -> int:
    """ceil(log2 n) for n >= 1 (0 for n == 1)."""
    return max(int(n) - 1, 1).bit_length() if n > 1 else 0


@lru_cache(maxsize=None)
def ring_perm(n: int) -> tuple:
    """The uniform ring perm rank i -> i+1 every ring round uses."""
    return tuple((i, (i + 1) % n) for i in range(n))


def redoub_layout(n: int):
    """(p, rem, phys) of the non-power-of-two recursive-doubling layout:
    ``p = 2**floor(log2 n)`` participants, ``rem = n - p`` folded pairs,
    ``phys(v)`` the physical rank of virtual participant ``v``."""
    n = max(int(n), 1)
    p = 1 << (n.bit_length() - 1)
    rem = n - p

    def phys(v: int) -> int:
        return 2 * v + 1 if v < rem else v + rem

    return p, rem, phys


@lru_cache(maxsize=None)
def binomial_slab_table(n: int) -> tuple:
    """Trimmed-slab binomial-tree schedule over ``n`` ranks (top-down).

    One entry per ``ceil(log2 n)`` tree round, largest span first:
    ``(span, full_senders, trim)``.  Senders ``i`` in ``full_senders``
    ship a full ``span``-chunk slab to ``i + span`` (the receiver's
    whole virtual subtree ``[i+span, i+2*span)`` is real ranks);
    ``trim`` is the at-most-one boundary exchange ``(sender, receiver,
    slab)`` per round whose virtual subtree straddles ``n`` — it ships
    only the ``slab = n - receiver`` real chunks.  Exchanges whose
    receiver is ``>= n`` do not appear.  On power-of-two axes every
    round is all-full (``trim is None``).

    Moved here from ``cost_model`` (which now delegates): the slab
    combinatorics are schedule authority, not pricing.
    """
    n = int(n)
    steps = ceil_log2(max(n, 2))
    n_virt = 1 << steps
    rounds = []
    for k in reversed(range(steps)):
        span = 1 << k
        full, trim = [], None
        for i in range(0, n_virt, 2 * span):
            recv = i + span
            if recv >= n:
                continue
            slab = min(n, recv + span) - recv
            if slab == span:
                full.append(i)
            else:  # at most one straddling subtree per round
                trim = (i, recv, slab)
        rounds.append((span, tuple(full), trim))
    return tuple(rounds)


def scatter_root_chunk_streams(n: int) -> int:
    """Chunk streams the scatter root ships under the trimmed-slab
    schedule — exactly ``n - 1`` at ANY axis size."""
    total = 0
    for span, full, trim in binomial_slab_table(n):
        if 0 in full:
            total += span
        elif trim is not None and trim[0] == 0:
            total += trim[2]
    return total


def tree_plan(n: int):
    """Per-round ``(span, full_senders, trim, perm)`` of the binomial
    tree, with the perm taken from the scatter schedule builder — the
    walking surface ``collectives`` uses so tree perms never get
    re-derived inline."""
    sched = build("scatter", "binomial", n)
    table = binomial_slab_table(n)
    return tuple(
        (span, full, trim, sched.perm(k))
        for k, (span, full, trim) in enumerate(table)
    )


# ---------------------------------------------------------------------------
# Builders — one per algorithm; all memoized
# ---------------------------------------------------------------------------


def _ring_rs_rounds(n: int, owner_offset: int, stage: str, payload: str):
    """Ring reduce-scatter rounds: at round ``s`` rank ``i`` ships chunk
    ``(i - s + owner_offset) % n`` to ``i + 1``, which accumulates it.
    ``owner_offset = 0`` is the fused-into-allreduce convention (rank r
    ends owning chunk ``(r+1) % n``); ``owner_offset = -1`` the
    standalone reduce_scatter one (rank r ends owning chunk ``r``)."""
    return tuple(
        tuple(
            Hop(i, (i + 1) % n, ((i - s + owner_offset) % n, 1),
                stage, payload)
            for i in range(n)
        )
        for s in range(n - 1)
    )


def _ring_ag_rounds(n: int, own_offset: int, stage0: str, payload: str):
    """Ring allgather rounds: at round ``s`` rank ``r`` installs chunk
    ``(r - s + own_offset) % n`` from rank ``r - 1``.  Round 0 carries
    the sender's freshly compressed own chunk (``stage0``); later rounds
    forward that stream bit-exactly ("exact")."""
    return tuple(
        tuple(
            Hop((r - 1) % n, r, ((r - s + own_offset) % n, 1),
                stage0 if s == 0 else "exact", payload)
            for r in range(n)
        )
        for s in range(n - 1)
    )


@lru_cache(maxsize=None)
def _build_allreduce_ring(n: int) -> Schedule:
    rs = _ring_rs_rounds(n, 0, "lossy", "compressed")
    ag = _ring_ag_rounds(n, 0, "lossy", "compressed")
    return Schedule(
        op="allreduce", algo="ring", n=n, n_chunks=n,
        rounds=rs + ag,
        combine=("reduce",) * len(rs) + ("install",) * len(ag),
    )


@lru_cache(maxsize=None)
def _build_allreduce_intring(n: int) -> Schedule:
    # Same routes as the float ring, but every hop is EXACT: the single
    # up-front quantization grid is charged via initial_lossy and the
    # integer codes ride the ring losslessly.
    rs = _ring_rs_rounds(n, 0, "exact", "compressed")
    ag = _ring_ag_rounds(n, 0, "exact", "compressed")
    return Schedule(
        op="allreduce", algo="intring", n=n, n_chunks=n,
        rounds=rs + ag,
        combine=("reduce",) * len(rs) + ("install",) * len(ag),
        initial_lossy=1,
    )


@lru_cache(maxsize=None)
def _build_allreduce_redoub(n: int) -> Schedule:
    p, rem, phys = redoub_layout(n)
    rounds, combine = [], []
    if rem:
        rounds.append(tuple(
            Hop(2 * i, 2 * i + 1, (0, 1), "lossy", "compressed")
            for i in range(rem)
        ))
        combine.append("reduce")
    for k in range(p.bit_length() - 1):
        dist = 1 << k
        rounds.append(tuple(
            Hop(phys(v), phys(v ^ dist), (0, 1), "lossy", "compressed")
            for v in range(p)
        ))
        combine.append("reduce")
    if rem:
        rounds.append(tuple(
            Hop(2 * i + 1, 2 * i, (0, 1), "unfold", "compressed")
            for i in range(rem)
        ))
        combine.append("install")
    return Schedule(
        op="allreduce", algo="redoub", n=n, n_chunks=1,
        rounds=tuple(rounds), combine=tuple(combine),
    )


@lru_cache(maxsize=None)
def _build_reduce_scatter_ring(n: int) -> Schedule:
    rs = _ring_rs_rounds(n, -1, "lossy", "compressed")
    return Schedule(
        op="reduce_scatter", algo="ring", n=n, n_chunks=n,
        rounds=rs, combine=("reduce",) * len(rs),
    )


@lru_cache(maxsize=None)
def _build_allgather_ring(n: int) -> Schedule:
    # Standalone convention: chunk c is rank c's own payload; at round s
    # rank r installs chunk (r - s - 1) % n — its sender's own chunk at
    # round 0, then forwarded streams.
    ag = _ring_ag_rounds(n, -1, "lossy", "compressed")
    return Schedule(
        op="allgather", algo="ring", n=n, n_chunks=n,
        rounds=ag, combine=("install",) * len(ag),
    )


def _tree_rounds(n: int, root_only_payload: bool):
    """Binomial-tree install rounds from the slab table.  A hop is
    "lossy" iff the ROOT is the sender — every stream is compressed
    exactly once at the root; mid-rank forwards are bit-exact.  With
    ``root_only_payload`` (broadcast) each hop ships the whole message
    (chunk space 1); otherwise (scatter) the receiver's real-subtree
    slab ``[receiver, receiver + slab)``."""
    rounds = []
    for span, full, trim in binomial_slab_table(n):
        entries = []
        for i in full:
            slab = (0, 1) if root_only_payload else (i + span, span)
            entries.append(Hop(i, i + span, slab,
                               "lossy" if i == 0 else "exact", "compressed"))
        if trim is not None:
            snd, rcv, slab_len = trim
            slab = (0, 1) if root_only_payload else (rcv, slab_len)
            entries.append(Hop(snd, rcv, slab,
                               "lossy" if snd == 0 else "exact",
                               "compressed"))
        rounds.append(tuple(entries))
    return tuple(rounds)


@lru_cache(maxsize=None)
def _build_scatter_binomial(n: int) -> Schedule:
    rounds = _tree_rounds(n, root_only_payload=False)
    return Schedule(
        op="scatter", algo="binomial", n=n, n_chunks=n,
        rounds=rounds, combine=("install",) * len(rounds),
    )


@lru_cache(maxsize=None)
def _build_broadcast_binomial(n: int) -> Schedule:
    rounds = _tree_rounds(n, root_only_payload=True)
    return Schedule(
        op="broadcast", algo="binomial", n=n, n_chunks=1,
        rounds=rounds, combine=("install",) * len(rounds),
    )


@lru_cache(maxsize=None)
def _build_all_to_all(n: int) -> Schedule:
    # One exchange: rank i ships its j-th chunk to rank j (self-send
    # included — lax.all_to_all moves the diagonal through the same
    # buffer, and the wire accounting has always priced n streams).
    rounds = (tuple(
        Hop(i, j, (j, 1), "lossy", "compressed")
        for i in range(n) for j in range(n)
    ),)
    return Schedule(
        op="all_to_all", algo="direct", n=n, n_chunks=n,
        rounds=rounds, combine=("install",),
    )


_BUILDERS = {
    ("allreduce", "ring"): _build_allreduce_ring,
    ("allreduce", "intring"): _build_allreduce_intring,
    ("allreduce", "redoub"): _build_allreduce_redoub,
    ("reduce_scatter", "ring"): _build_reduce_scatter_ring,
    ("allgather", "ring"): _build_allgather_ring,
    ("scatter", "binomial"): _build_scatter_binomial,
    ("broadcast", "binomial"): _build_broadcast_binomial,
    ("all_to_all", "direct"): _build_all_to_all,
}


def build(op: str, algo: str, n: int) -> Schedule:
    """THE route-table authority: the memoized schedule for one
    collective over ``n`` ranks.  Raises ValueError for unknown
    (op, algo) pairs."""
    try:
        builder = _BUILDERS[(op, algo)]
    except KeyError:
        raise ValueError(f"no schedule builder for op={op!r} algo={algo!r}")
    return builder(int(n))


@lru_cache(maxsize=None)
def build_hier(n_nodes: int, local: int, inter_algo: str = "redoub") -> Schedule:
    """Two-level hierarchical allreduce composition over ``n_nodes * local``
    node-major ranks (rank = node*local + l — the layout
    ``launch.mesh.make_hier_mesh`` carves).

    Three stages concatenated: exact RAW intra-node reduce-scatter rounds
    (the canonical local ring — models ``lax.psum_scatter``'s 2(L-1)
    shard movement, which is what ``HierPlan`` prices), the compressed
    ``inter_algo`` allreduce lifted to every local index (hop
    ``s -> r`` of the inter table becomes ``s*L + l -> r*L + l`` for
    each ``l``), then exact RAW intra-node allgather rounds.  Intra
    rounds index the L-shard chunk space; the lifted inter rounds keep
    the inter schedule's own chunk space over the shard (documented
    asymmetry — pricing and fault targeting only need senders, stages
    and payload kinds, which are uniform).
    """
    L = int(local)
    n = int(n_nodes) * L
    rounds, combine = [], []
    if L > 1:
        for s in range(L - 1):
            rounds.append(tuple(
                Hop(m * L + j, m * L + (j + 1) % L, ((j - s - 1) % L, 1),
                    "exact", "raw")
                for m in range(n_nodes) for j in range(L)
            ))
            combine.append("reduce")
    if n_nodes > 1:
        inter = build("allreduce", inter_algo, n_nodes)
        for k, rnd in enumerate(inter.rounds):
            rounds.append(tuple(
                Hop(h.sender * L + l, h.receiver * L + l, h.chunk_slab,
                    h.stage, h.payload_kind)
                for h in rnd for l in range(L)
            ))
            combine.append(inter.combine[k])
    if L > 1:
        for s in range(L - 1):
            rounds.append(tuple(
                Hop(m * L + (j - 1) % L, m * L + j, ((j - s - 1) % L, 1),
                    "exact", "raw")
                for m in range(n_nodes) for j in range(L)
            ))
            combine.append("install")
    return Schedule(
        op="allreduce", algo=f"hier_{inter_algo}", n=n, n_chunks=L,
        rounds=tuple(rounds), combine=tuple(combine),
    )


# ---------------------------------------------------------------------------
# Derived analyses: error replay, conservation validation, entry counts
# ---------------------------------------------------------------------------


def _slab_chunks(h: Hop, n_chunks: int):
    start, length = h.chunk_slab
    return [(start + j) % n_chunks for j in range(length)]


def lossy_hop_count(sched: Schedule) -> int:
    """Worst-case error multiplier by ABSTRACT REPLAY of the table.

    Track an error multiplier ``e[rank][chunk]`` (how many fresh
    quantization errors of magnitude ``eb_stage`` the held value embeds,
    worst case).  A "reduce" hop merges the sender's accumulated error
    plus one fresh quantization if the hop re-quantizes; an "install"
    hop replaces with the stream's error (plus one if fresh).  The
    maximum over all (rank, chunk) at the end is the bound — this
    reproduces every closed form ``error_budget`` used to hard-code
    (redoub ``n-1``/``n``, ring ``n``, reduce-scatter ``n-1``, intring
    ``n``, movement ops ``1``) and holds by construction for any new
    builder.
    """
    n, C = sched.n, sched.n_chunks
    err = [[sched.initial_lossy] * C for _ in range(n)]
    for k, rnd in enumerate(sched.rounds):
        snap = [row[:] for row in err]
        mode = sched.combine[k]
        for h in rnd:
            add = 1 if h.stage in ("lossy", "unfold") else 0
            for c in _slab_chunks(h, C):
                if mode == "reduce":
                    err[h.receiver][c] += snap[h.sender][c] + add
                else:
                    err[h.receiver][c] = snap[h.sender][c] + add
    return max(max(row) for row in err)


_ALGO_KEYS = {
    "allreduce_redoub": ("allreduce", "redoub"),
    "allreduce_ring": ("allreduce", "ring"),
    "allreduce_intring": ("allreduce", "intring"),
    "reduce_scatter_ring": ("reduce_scatter", "ring"),
    "allgather_ring": ("allgather", "ring"),
    "scatter_binomial": ("scatter", "binomial"),
    "broadcast_binomial": ("broadcast", "binomial"),
}


@lru_cache(maxsize=None)
def lossy_hops_for(algo_key: str, n: int) -> int:
    """``error_budget.lossy_hops`` backend: the abstract replay of the
    resolved schedule table (n is floored at 2, preserving the historic
    degenerate-axis budgets)."""
    try:
        op, algo = _ALGO_KEYS[algo_key]
    except KeyError:
        raise ValueError(f"unknown algo {algo_key!r}")
    return lossy_hop_count(build(op, algo, max(int(n), 2)))


def sender_entry_counts(sched: Schedule):
    """Per-rank count of table entries sent (all rounds) — the busiest
    rank drives the wire accounting."""
    counts = [0] * sched.n
    for rnd in sched.rounds:
        for h in rnd:
            counts[h.sender] += 1
    return tuple(counts)


def validate(sched: Schedule) -> None:
    """Conservation + structural invariants of one table.  Raises
    AssertionError naming the violated invariant.

    * every hop names live ranks, a legal stage/payload kind, a slab
      inside the chunk space;
    * reduce ops: contributor-set replay — every rank's addend reaches
      every delivered chunk EXACTLY once (no duplicate, no loss);
    * movement ops: held-set replay — a sender must hold what it ships,
      every destination receives its payload exactly once;
    * binomial rounds carry at most one trimmed entry; redoub carries
      fold/unfold rounds iff n is non-power-of-two.
    """
    n, C = sched.n, sched.n_chunks

    def _require(cond, msg):
        if not cond:
            raise AssertionError(f"{sched.op}/{sched.algo} n={n}: {msg}")

    _require(len(sched.combine) == len(sched.rounds),
             "combine/rounds length mismatch")
    for k, rnd in enumerate(sched.rounds):
        _require(sched.combine[k] in ("reduce", "install"),
                 f"bad combine tag {sched.combine[k]!r}")
        seen_pairs = set()
        for h in rnd:
            _require(0 <= h.sender < n and 0 <= h.receiver < n,
                     f"rank out of range in round {k}: {h}")
            _require(h.sender != h.receiver or sched.op == "all_to_all",
                     f"self-send in round {k}: {h}")
            _require(h.stage in STAGES, f"bad stage {h.stage!r}")
            _require(h.payload_kind in PAYLOAD_KINDS,
                     f"bad payload kind {h.payload_kind!r}")
            start, length = h.chunk_slab
            _require(0 <= start < C and 1 <= length <= C,
                     f"slab out of range in round {k}: {h}")
            _require((h.sender, h.receiver) not in seen_pairs,
                     f"duplicate (sender, receiver) in round {k}")
            seen_pairs.add((h.sender, h.receiver))

    if sched.op in ("allreduce", "reduce_scatter"):
        _validate_reduce(sched, _require)
    elif sched.op in ("allgather", "scatter", "broadcast"):
        _validate_movement(sched, _require)
    elif sched.op == "all_to_all":
        pairs = {(h.sender, h.receiver) for h in sched.rounds[0]}
        _require(len(sched.rounds) == 1, "all_to_all is a single exchange")
        _require(pairs == {(i, j) for i in range(n) for j in range(n)},
                 "all_to_all must cover every (src, dst) pair exactly once")

    if sched.algo == "binomial":
        for k, (span, full, trim) in enumerate(binomial_slab_table(n)):
            trims = [h for h in sched.rounds[k]
                     if sched.op == "scatter" and h.chunk_slab[1] < span]
            _require(len(trims) <= 1,
                     f"round {k} has {len(trims)} trimmed entries")
            if n & (n - 1) == 0:
                _require(trim is None and not trims,
                         f"power-of-two n must have no trim (round {k})")
    if sched.algo == "redoub":
        has_unfold = any(h.stage == "unfold"
                         for rnd in sched.rounds for h in rnd)
        pow2 = n & (n - 1) == 0
        _require(has_unfold == (not pow2 and n > 1),
                 "fold/unfold rounds must appear iff n is non-power-of-two")


def _validate_reduce(sched: Schedule, _require) -> None:
    """Contributor-set replay: every addend delivered exactly once."""
    n, C = sched.n, sched.n_chunks
    contrib = [[{r} for _ in range(C)] for r in range(n)]
    for k, rnd in enumerate(sched.rounds):
        snap = [[s.copy() for s in row] for row in contrib]
        mode = sched.combine[k]
        for h in rnd:
            for c in _slab_chunks(h, C):
                if mode == "reduce":
                    dup = contrib[h.receiver][c] & snap[h.sender][c]
                    _require(not dup,
                             f"round {k}: contributors {sorted(dup)} merged "
                             f"twice into rank {h.receiver} chunk {c}")
                    contrib[h.receiver][c] |= snap[h.sender][c]
                else:
                    contrib[h.receiver][c] = snap[h.sender][c].copy()
    full = set(range(n))
    if sched.op == "allreduce":
        for r in range(n):
            for c in range(C):
                _require(contrib[r][c] == full,
                         f"rank {r} chunk {c} holds contributors "
                         f"{sorted(contrib[r][c])}, not all {n}")
    else:  # reduce_scatter: standalone owner convention — rank r owns chunk r
        for r in range(n):
            _require(contrib[r][r] == full,
                     f"rank {r}'s own chunk holds contributors "
                     f"{sorted(contrib[r][r])}, not all {n}")


def _validate_movement(sched: Schedule, _require) -> None:
    """Held-set replay: senders must hold what they ship; every
    destination receives exactly once."""
    n, C = sched.n, sched.n_chunks
    if sched.op == "allgather":
        held = [{r} for r in range(n)]
        expected_recv = {r: n - 1 for r in range(n)}
    else:  # scatter / broadcast: root 0 holds everything
        held = [set(range(C)) if r == 0 else set() for r in range(n)]
        expected_recv = {r: 1 for r in range(1, n)}
    received = {r: 0 for r in range(n)}
    for k, rnd in enumerate(sched.rounds):
        snap = [s.copy() for s in held]
        for h in rnd:
            chunks = set(_slab_chunks(h, C))
            missing = chunks - snap[h.sender]
            _require(not missing,
                     f"round {k}: sender {h.sender} ships chunks "
                     f"{sorted(missing)} it does not hold")
            if sched.op == "allgather":
                dup = chunks & held[h.receiver]
                _require(not dup,
                         f"round {k}: rank {h.receiver} receives chunks "
                         f"{sorted(dup)} twice")
            held[h.receiver] |= chunks
            received[h.receiver] += 1
    for r, want in expected_recv.items():
        if sched.op in ("scatter", "broadcast"):
            _require(received[r] == want,
                     f"rank {r} received {received[r]} slabs, expected "
                     f"{want}")
    if sched.op == "allgather":
        for r in range(n):
            _require(held[r] == set(range(n)),
                     f"rank {r} ends holding {sorted(held[r])}, not all "
                     f"{n} chunks")
    elif sched.op == "scatter":
        for r in range(n):
            _require(r in held[r], f"rank {r} never received its chunk")
    else:  # broadcast
        for r in range(n):
            _require(0 in held[r],
                     f"rank {r} never received the root payload")
