"""Accuracy-aware error-budget allocation (paper §3.3.3).

Every lossy hop in a compression-enabled collective re-quantizes data, so
per-hop bounds stack.  Getting the worst case right requires tracking how
*accumulated* error merges, not just counting compression events:

  * ReDoub allreduce: each of the log2(N) rounds computes
        acc' = acc + D(C(partner_acc)),
    so e_{k+1} = 2*e_k + eb_stage  (the partner's accumulated error merges
    in as well) => worst case e = (2**log2(N) - 1)*eb_stage = (N-1)*eb_stage.
    Equivalently: a rank's final value embeds one fresh quantization per
    merge event in its merge tree, and a tree over N leaves has N-1
    internal nodes.  That count is INVARIANT under the non-power-of-two
    remainder stage (the fold pre-hops are merge events like any other:
    r fold merges + 2**floor(log2 N) - 1 doubling merges = N - 1), so the
    only extra charge on a remainder axis is the unfold post-hop — one
    more quantization on the folded pairs => N*eb_stage worst case.
  * Ring allreduce: the reduce-scatter running chunk sum absorbs one fresh
    quantization error per hop, (N-1) hops, plus one more lossy hop in the
    allgather stage => N*eb_stage.
  * Ring allgather / binomial scatter / binomial bcast: data-movement
    collectives compress exactly once at the endpoints => 1 hop.

So in the WORST case both computation algorithms stack linearly in N —
the paper's "log N vs N-1" compares compression *events per rank* (which
is what costs time and compressor utilization), not the adversarial error
bound.  Statistically the story is the one the paper tells: the final
value embeds ~N zero-mean independent quantization errors under either
algorithm (a merge tree has N-1 internal nodes), so errors random-walk as
sqrt(N)*eb_stage, and ReDoub's fewer sequential requantizations of any
single element path give it the better constant (validated empirically in
tests/test_error_budget.py and the image-stacking example).

``allocate(worst_case=True)`` divides by the hard-bound hop count;
``worst_case=False`` divides by sqrt(hops) — the paper's statistical
argument, which is the practical choice for gradient sync.
"""
from __future__ import annotations

import math

from repro.core import schedule

__all__ = ["lossy_hops", "allocate", "split_lossy"]


def lossy_hops(algo: str, n: int) -> int:
    """Worst-case multiplier: end-to-end error <= lossy_hops * eb_stage.

    Counted from the RESOLVED schedule table (``schedule.build``) by the
    abstract error replay in ``schedule.lossy_hop_count`` — the per-algo
    closed forms this function used to hard-code (redoub's ``n-1``
    merge-tree bound plus the non-pow2 unfold, ring's ``n``, intring's
    shared-grid ``n``, the movement ops' single endpoint hop; see the
    module docstring for the derivations) now fall out of the same route
    table the execute layer walks, so the ≤-eb property holds by
    construction for any future algorithm instead of by string dispatch
    (ISSUE 10 satellite; the PR 4 drift class).  Still raises ValueError
    for unknown algo keys.
    """
    return schedule.lossy_hops_for(algo, int(n))


def compression_events(algo: str, n: int) -> int:
    """Sequential compression invocations per rank (the paper's log-N vs
    N-1 *performance* metric — what drives compressor utilization cost)."""
    if algo == "allreduce_redoub":
        # ceil(log2 n) also under the remainder stage: the busiest rank
        # (a fold destination) compresses floor(log2 n) doubling rounds
        # plus the unfold send; it *receives* in the fold pre-hop.
        return max(int(math.ceil(math.log2(max(n, 2)))), 1)
    if algo == "allreduce_ring":
        return max(n - 1, 1) + 1
    if algo == "reduce_scatter_ring":
        return max(n - 1, 1)
    if algo == "allreduce_intring":
        return 1  # quantize once; ring repacks are lossless
    if algo in ("allgather_ring", "scatter_binomial", "broadcast_binomial"):
        return 1
    raise ValueError(f"unknown algo {algo!r}")


def allocate(eb_total: float, algo: str, n: int, *, worst_case: bool = True) -> float:
    """Per-stage eb such that the end-to-end error stays within eb_total."""
    hops = lossy_hops(algo, n)
    if worst_case:
        return eb_total / hops
    return eb_total / math.sqrt(hops)


def split_lossy(eb_total: float, lossy_flags) -> tuple:
    """Split an end-to-end budget across composed stages, charging ONLY
    the lossy ones (two-level collectives: the uncompressed intra-node
    reduce-scatter/allgather stages contribute exact f32 arithmetic, so
    they get 0.0 and the inter-node compressed stage keeps the whole
    budget undiluted — splitting evenly across all stages would shrink
    eb by the stage count for no accuracy gain).

    Returns one eb per stage, in order.  Multiple lossy stages share
    ``eb_total`` evenly (each stage's own ``allocate`` then divides its
    share by its hop count).
    """
    flags = tuple(bool(f) for f in lossy_flags)
    n_lossy = sum(flags)
    if n_lossy == 0:
        return tuple(0.0 for _ in flags)
    share = eb_total / n_lossy
    return tuple(share if f else 0.0 for f in flags)
