"""Deterministic bucket ledger for gradient pytrees (ISSUE 9).

``grad_sync`` used to ravel the whole gradient tree into one vector and
scan a module-global fixed-size chunk schedule over it.  The ledger is
that schedule made explicit, reusable and *orderable*: built once per
(leaf shapes, bucket_bytes), it tiles the tree's ravel order into K
equal-payload buckets (the last zero-padded) and records, for every
bucket, exactly which slices of which leaves it carries.

Two properties the rest of the stack leans on:

  * **Exact tiling.**  Every element of every leaf lands in exactly one
    bucket slice, with no gaps and no overlap — ``assert_tiles_exactly``
    is the invariant the hypothesis property test sweeps over random
    pytrees, and ``scatter`` relies on it to reassemble leaves.
  * **Bitwise equivalence to the whole-tree chunk scan.**  Bucket ``i``'s
    payload is element-for-element the old path's chunk ``i`` (slicing a
    concatenation == concatenating slices; padding is zeros either way),
    and every bucket's collective is independent of the others, so the
    bucketed sync can issue in ANY order — last-layer-first, matching
    backward completion order — and still produce bitwise-identical
    values (asserted on multi-device meshes in
    tests/_mp_gradsync_child.py).

Ledgers are memoized: training steps rebuild the same (tree, SyncConfig)
every trace, and construction walks every leaf.  ``ledger_cache_stats``
mirrors the plan-cache observability convention of core/comm.py.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

__all__ = [
    "LeafSlice",
    "Bucket",
    "BucketLedger",
    "build_ledger",
    "ledger_for",
    "ledger_cache_stats",
    "clear_ledger_cache",
]


@dataclasses.dataclass(frozen=True)
class LeafSlice:
    """One contiguous run of a leaf's ravel order inside one bucket."""

    leaf: int    # index into the flattened leaf list
    start: int   # element range within the leaf's own ravel order
    stop: int
    offset: int  # where the run sits inside the bucket payload

    @property
    def size(self) -> int:
        return self.stop - self.start


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One wire quantum: ``n_elems`` real elements, zero-padded to the
    ledger's uniform ``bucket_elems`` payload (uniform payloads mean one
    frozen Plan serves every bucket — one communicator-cache entry per
    (op, bucket shape), however many buckets are in flight)."""

    index: int     # position in ravel order (0 == first elements)
    n_elems: int   # real elements; payload[n_elems:] is padding
    slices: tuple  # LeafSlice runs, in ravel order


@dataclasses.dataclass(frozen=True)
class BucketLedger:
    """Frozen tiling of a fixed leaf structure into equal buckets."""

    shapes: tuple        # per-leaf shapes (the construction identity)
    bucket_elems: int    # payload length of EVERY bucket
    total_elems: int
    buckets: tuple       # Bucket..., in ravel order

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def issue_order(self) -> tuple:
        """Buckets last-layer-first: the reverse of ravel order, i.e. the
        order backward *completes* gradients in (the loss-side leaves sit
        at the end of the tree), so bucket ``issue_order()[0]`` can hit
        the wire while earlier layers are still differentiating."""
        return tuple(reversed(self.buckets))

    # -- flatten / unflatten ------------------------------------------------

    def gather(self, flat_leaves, bucket: Bucket):
        """Assemble one bucket's padded payload from 1-D leaf views.

        Concatenating the recorded leaf runs reproduces the whole-tree
        ravel's slice ``[index*B, index*B + n_elems)`` bitwise; the pad is
        zeros, exactly like the old scan's padded tail.
        """
        parts = [flat_leaves[s.leaf][s.start:s.stop] for s in bucket.slices]
        vec = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        if bucket.n_elems < self.bucket_elems:
            vec = jnp.zeros(
                (self.bucket_elems,), vec.dtype
            ).at[:bucket.n_elems].set(vec)
        return vec

    def stack_payloads(self, flat_leaves):
        """(n_buckets, bucket_elems) payload stack in ISSUE order —
        the `lax.scan` input of the bucketed allreduce."""
        return jnp.stack(
            [self.gather(flat_leaves, b) for b in self.issue_order()]
        )

    def unstack(self, stacked):
        """Invert :meth:`stack_payloads`: (n_buckets, bucket_elems) in
        issue order -> per-leaf 1-D vectors (padding dropped)."""
        pieces: list = [[] for _ in self.shapes]
        for pos, bucket in enumerate(self.issue_order()):
            vec = stacked[pos]
            for s in bucket.slices:
                pieces[s.leaf].append((s.start, vec[s.offset:s.offset + s.size]))
        out = []
        for runs in pieces:
            runs.sort(key=lambda r: r[0])
            parts = [v for _, v in runs]
            out.append(parts[0] if len(parts) == 1 else jnp.concatenate(parts))
        return out

    # -- invariants ---------------------------------------------------------

    def assert_tiles_exactly(self) -> None:
        """Every leaf element covered exactly once, in ravel order, with
        per-bucket offsets forming a gapless run of n_elems."""
        sizes = [int(math.prod(s)) for s in self.shapes]
        cursor = {i: 0 for i in range(len(sizes))}
        global_off = 0
        for bucket in self.buckets:
            assert 0 < bucket.n_elems <= self.bucket_elems, bucket
            off = 0
            for s in bucket.slices:
                assert s.offset == off, (s, off)
                assert s.start == cursor[s.leaf], (s, cursor[s.leaf])
                assert s.stop <= sizes[s.leaf], (s, sizes[s.leaf])
                cursor[s.leaf] = s.stop
                off += s.size
            assert off == bucket.n_elems, (bucket, off)
            global_off += bucket.n_elems
        assert global_off == self.total_elems == sum(sizes), (
            global_off, self.total_elems, sum(sizes))
        assert all(cursor[i] == sizes[i] for i in cursor), (cursor, sizes)


def build_ledger(shapes, bucket_bytes: int, *, elem_bytes: int = 4
                 ) -> BucketLedger:
    """Tile leaves of ``shapes`` (ravel order) into equal-payload buckets.

    ``bucket_elems = min(bucket_bytes // elem_bytes, total)`` — clamped
    exactly like the old ``chunk = min(sync.chunk, n)``, so a small tree
    is one bucket and the default 16 MiB bucket reproduces the historic
    4 Mi-element chunk payload bit for bit.
    """
    shapes = tuple(tuple(int(d) for d in s) for s in shapes)
    sizes = [int(math.prod(s)) for s in shapes]
    total = sum(sizes)
    if total == 0:
        raise ValueError(
            "build_ledger: the leaf structure has zero elements — an "
            "empty gradient tree cannot be bucketed (and silently "
            "skipping gradient sync would be a correctness bug)"
        )
    if bucket_bytes < elem_bytes:
        raise ValueError(
            f"build_ledger: bucket_bytes={bucket_bytes!r} holds no "
            f"{elem_bytes}-byte element"
        )
    bucket_elems = min(bucket_bytes // elem_bytes, total)
    n_buckets = -(-total // bucket_elems)

    buckets = []
    leaf, leaf_off = 0, 0
    for index in range(n_buckets):
        lo = index * bucket_elems
        hi = min(lo + bucket_elems, total)
        slices, off = [], 0
        while off < hi - lo:
            take = min(sizes[leaf] - leaf_off, (hi - lo) - off)
            if take > 0:
                slices.append(LeafSlice(
                    leaf=leaf, start=leaf_off, stop=leaf_off + take,
                    offset=off,
                ))
                leaf_off += take
                off += take
            if leaf_off == sizes[leaf] and leaf < len(sizes) - 1:
                leaf, leaf_off = leaf + 1, 0
        buckets.append(Bucket(index=index, n_elems=hi - lo,
                              slices=tuple(slices)))
    ledger = BucketLedger(shapes=shapes, bucket_elems=bucket_elems,
                          total_elems=total, buckets=tuple(buckets))
    ledger.assert_tiles_exactly()
    return ledger


# ---------------------------------------------------------------------------
# Memoization (one ledger per (leaf shapes, bucket_bytes))
# ---------------------------------------------------------------------------

_LEDGER_CACHE: dict = {}
_LEDGER_STATS = {"hits": 0, "misses": 0}


def ledger_for(shapes, bucket_bytes: int) -> BucketLedger:
    """Memoized :func:`build_ledger` — the once-per-(param-tree,
    SyncConfig) construction the training loop leans on."""
    key = (tuple(tuple(int(d) for d in s) for s in shapes),
           int(bucket_bytes))
    hit = _LEDGER_CACHE.get(key)
    if hit is not None:
        _LEDGER_STATS["hits"] += 1
        return hit
    _LEDGER_STATS["misses"] += 1
    ledger = build_ledger(shapes, bucket_bytes)
    _LEDGER_CACHE[key] = ledger
    return ledger


def ledger_cache_stats() -> dict:
    return {
        "hits": _LEDGER_STATS["hits"],
        "misses": _LEDGER_STATS["misses"],
        "entries": len(_LEDGER_CACHE),
    }


def clear_ledger_cache() -> None:
    _LEDGER_CACHE.clear()
    _LEDGER_STATS["hits"] = 0
    _LEDGER_STATS["misses"] = 0
