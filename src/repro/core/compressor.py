"""Compressor implementations.

``ErrorBoundedLorenzo`` is the gZCCL compressor (cuSZp adapted to TPU —
Pallas quantize/dequantize kernels + dense bitpack).  ``FixedRate`` is the
[30]-style 1D fixed-rate baseline whose flaw (unbounded error under
clamping) the paper calls out; it exists so the benchmarks can reproduce
that comparison.  Both share the ``Compressed`` wire container so the
collective layer is compressor-agnostic.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import bitpack
from repro.core.compressed import Compressed, capacity_words_for
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class ErrorBoundedLorenzo:
    """Error-bounded block-Lorenzo compressor (the gZCCL default).

    Guarantee: |x - decompress(compress(x, eb))| <= eb element-wise, as long
    as |x|/(2*eb) < 2**30 (pre-quantization fits int32 — same envelope as
    cuSZp; asserted in tests).

    ``fused=True`` (default) runs the single-pass Pallas pipeline
    (quantize_pack / unpack_dequantize_reduce, DESIGN.md §3): the uint32
    codes array never materializes and the separate jnp bitpack pass is
    gone.  ``fused=False`` is the two-pass composition kept as the oracle
    path; both produce byte-identical wire streams.
    """

    capacity_factor: float = 0.5
    block: int = ops.BLOCK
    fused: bool = True

    def compress(self, x: jnp.ndarray, eb) -> Compressed:
        n = int(x.size)
        eb = jnp.asarray(eb, jnp.float32)
        x2d = ops.to_blocks(x)
        cap = capacity_words_for(n, self.capacity_factor, self.block)
        if self.fused:
            packed, bw, anchor = ops.quantize_pack(x2d, eb, cap)
            nwords = bitpack.packed_words(bw, self.block)
        else:
            codes, bw, anchor = ops.quantize(x2d, eb)
            packed, nwords = bitpack.pack(codes, bw, cap)
        return Compressed(
            packed=packed, bitwidth=bw, anchor=anchor, nwords=nwords, eb=eb,
            n=n, block=self.block,
        )

    def decompress(self, c: Compressed) -> jnp.ndarray:
        if self.fused:
            x2d = ops.unpack_dequantize(c.packed, c.bitwidth, c.anchor, c.eb)
        else:
            codes = bitpack.unpack(c.packed, c.bitwidth, c.block)
            x2d = ops.dequantize(codes, c.anchor, c.eb)
        return ops.from_blocks(x2d, c.n)

    def decompress_reduce(self, c: Compressed, acc: jnp.ndarray) -> jnp.ndarray:
        """acc + decompress(c) without materializing the decompressed array.

        ``acc`` is flat (n,); fused Pallas kernel works on the padded block
        view.
        """
        acc2d = ops.to_blocks(acc)
        if self.fused:
            out2d = ops.unpack_dequantize_reduce(
                c.packed, c.bitwidth, c.anchor, c.eb, acc2d
            )
        else:
            codes = bitpack.unpack(c.packed, c.bitwidth, c.block)
            out2d = ops.dequantize_reduce(codes, c.anchor, c.eb, acc2d)
        return ops.from_blocks(out2d, c.n)

    def decompress_reduce_compress(
        self, c: Compressed, acc: jnp.ndarray, eb_out=None, *,
        return_updated: bool = False,
    ):
        """Single-pass ring hop: ``compress(acc + decompress(c))`` in ONE
        Pallas kernel (DESIGN.md §3.1) — the received wire stream plus the
        local f32 chunk go in, the *next hop's* wire stream comes out, and
        the updated f32 chunk never leaves VMEM.

        ``acc`` is flat (n,) with ``n == c.n``; ``eb_out`` defaults to the
        incoming stream's bound (ring/redoub hops reuse one stage budget).
        Returns ``(Compressed, updated | None)``: ``updated`` (the plain
        f32 accumulator) is materialized only when ``return_updated`` —
        the recursive-doubling carry needs it; ring hops do not.

        ``fused=False`` runs the decompress_reduce ∘ compress composition
        (the PR 1 two-kernel path, kept as the oracle); both produce
        byte-identical wire streams.
        """
        assert int(acc.size) == c.n, (acc.size, c.n)
        eb_out = c.eb if eb_out is None else jnp.asarray(eb_out, jnp.float32)
        if not self.fused:
            updated = self.decompress_reduce(c, acc)
            return self.compress(updated, eb_out), (
                updated if return_updated else None
            )
        cap = capacity_words_for(c.n, self.capacity_factor, self.block)
        acc2d = ops.to_blocks(acc)
        res = ops.unpack_reduce_repack(
            c.packed, c.bitwidth, c.anchor, c.eb, acc2d, eb_out, cap,
            emit_f32=return_updated,
        )
        packed, bw, anchor = res[:3]
        c_out = Compressed(
            packed=packed, bitwidth=bw, anchor=anchor,
            nwords=bitpack.packed_words(bw, self.block), eb=eb_out,
            n=c.n, block=self.block,
        )
        updated = ops.from_blocks(res[3], c.n) if return_updated else None
        return c_out, updated


@dataclasses.dataclass(frozen=True)
class FixedRate:
    """1D fixed-rate baseline (ZFP-in-[30] analog): constant bits/element.

    Codes that exceed the rate are CLAMPED, so the error is unbounded —
    exactly the failure mode the paper's accuracy-aware design avoids.  The
    wire size is pre-known (the one advantage of fixed-rate).
    """

    rate_bits: int = 8
    block: int = ops.BLOCK

    def compress(self, x: jnp.ndarray, eb) -> Compressed:
        n = int(x.size)
        eb = jnp.asarray(eb, jnp.float32)
        x2d = ops.to_blocks(x)
        codes, _, anchor = ops.quantize(x2d, eb)
        limit = jnp.uint32((1 << self.rate_bits) - 1)
        codes = jnp.minimum(codes, limit)  # CLAMP -> unbounded error
        bw = jnp.full((codes.shape[0],), self.rate_bits, jnp.int32)
        cap = capacity_words_for(n, self.rate_bits / 32.0 + 1e-9, self.block)
        packed, nwords = bitpack.pack(codes, bw, cap)
        return Compressed(
            packed=packed, bitwidth=bw, anchor=anchor, nwords=nwords, eb=eb,
            n=n, block=self.block,
        )

    def decompress(self, c: Compressed) -> jnp.ndarray:
        codes = bitpack.unpack(c.packed, c.bitwidth, c.block)
        x2d = ops.dequantize(codes, c.anchor, c.eb)
        return ops.from_blocks(x2d, c.n)

    def decompress_reduce(self, c: Compressed, acc: jnp.ndarray) -> jnp.ndarray:
        return acc + self.decompress(c)

    def decompress_reduce_compress(
        self, c: Compressed, acc: jnp.ndarray, eb_out=None, *,
        return_updated: bool = False,
    ):
        """Composition fallback (fixed-rate has no fused hop kernel)."""
        eb_out = c.eb if eb_out is None else jnp.asarray(eb_out, jnp.float32)
        updated = self.decompress_reduce(c, acc)
        return self.compress(updated, eb_out), (
            updated if return_updated else None
        )


DEFAULT = ErrorBoundedLorenzo()
