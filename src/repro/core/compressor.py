"""Compressor implementations.

``ErrorBoundedLorenzo`` is the gZCCL compressor (cuSZp adapted to TPU —
Pallas quantize/dequantize kernels + dense bitpack).  ``EntropyLorenzo``
keeps the same quantizer but entropy-codes the codes at per-sub-block
widths (DESIGN.md §10); with ``lossless=True`` the quantizer becomes a
bit-exact int32 bitcast (eb=0 semantics).  ``Passthrough`` ships raw f32
bit patterns in the same wire container.  ``FixedRate`` is the [30]-style
1D fixed-rate baseline whose flaw (unbounded error under clamping) the
paper calls out; it exists so the benchmarks can reproduce that
comparison.  All share the ``Compressed`` wire container so the
collective layer is compressor-agnostic.

Compressor instances are resolved from the plan's codec entry via
``repro.core.codecs`` — the old mutable module global ``DEFAULT`` is
deprecated (see module ``__getattr__``).
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core import entropy
from repro.core.compressed import Compressed, capacity_words_for
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class ErrorBoundedLorenzo:
    """Error-bounded block-Lorenzo compressor (the gZCCL default).

    Guarantee: |x - decompress(compress(x, eb))| <= eb element-wise, as long
    as |x|/(2*eb) < 2**30 (pre-quantization fits int32 — same envelope as
    cuSZp; asserted in tests).

    ``fused=True`` (default) runs the single-pass Pallas pipeline
    (quantize_pack / unpack_dequantize_reduce, DESIGN.md §3): the uint32
    codes array never materializes and the separate jnp bitpack pass is
    gone.  ``fused=False`` is the two-pass composition kept as the oracle
    path; both produce byte-identical wire streams.
    """

    capacity_factor: float = 0.5
    block: int = ops.BLOCK
    fused: bool = True

    def compress(self, x: jnp.ndarray, eb) -> Compressed:
        n = int(x.size)
        eb = jnp.asarray(eb, jnp.float32)
        x2d = ops.to_blocks(x)
        cap = capacity_words_for(n, self.capacity_factor, self.block)
        if self.fused:
            packed, bw, anchor = ops.quantize_pack(x2d, eb, cap)
            nwords = bitpack.packed_words(bw, self.block)
        else:
            codes, bw, anchor = ops.quantize(x2d, eb)
            packed, nwords = bitpack.pack(codes, bw, cap)
        return Compressed(
            packed=packed, bitwidth=bw, anchor=anchor, nwords=nwords, eb=eb,
            n=n, block=self.block,
        )

    def stream_nwords(self, bitwidth: jnp.ndarray, n: int) -> jnp.ndarray:
        """True stream words implied by wire metadata (receive-side rebuild)."""
        del n
        return bitpack.packed_words(bitwidth, self.block)

    def decompress(self, c: Compressed) -> jnp.ndarray:
        if self.fused:
            x2d = ops.unpack_dequantize(c.packed, c.bitwidth, c.anchor, c.eb)
        else:
            codes = bitpack.unpack(c.packed, c.bitwidth, c.block)
            x2d = ops.dequantize(codes, c.anchor, c.eb)
        return ops.from_blocks(x2d, c.n)

    def decompress_reduce(self, c: Compressed, acc: jnp.ndarray) -> jnp.ndarray:
        """acc + decompress(c) without materializing the decompressed array.

        ``acc`` is flat (n,); fused Pallas kernel works on the padded block
        view.
        """
        acc2d = ops.to_blocks(acc)
        if self.fused:
            out2d = ops.unpack_dequantize_reduce(
                c.packed, c.bitwidth, c.anchor, c.eb, acc2d
            )
        else:
            codes = bitpack.unpack(c.packed, c.bitwidth, c.block)
            out2d = ops.dequantize_reduce(codes, c.anchor, c.eb, acc2d)
        return ops.from_blocks(out2d, c.n)

    def decompress_reduce_compress(
        self, c: Compressed, acc: jnp.ndarray, eb_out=None, *,
        return_updated: bool = False,
    ):
        """Single-pass ring hop: ``compress(acc + decompress(c))`` in ONE
        Pallas kernel (DESIGN.md §3.1) — the received wire stream plus the
        local f32 chunk go in, the *next hop's* wire stream comes out, and
        the updated f32 chunk never leaves VMEM.

        ``acc`` is flat (n,) with ``n == c.n``; ``eb_out`` defaults to the
        incoming stream's bound (ring/redoub hops reuse one stage budget).
        Returns ``(Compressed, updated | None)``: ``updated`` (the plain
        f32 accumulator) is materialized only when ``return_updated`` —
        the recursive-doubling carry needs it; ring hops do not.

        ``fused=False`` runs the decompress_reduce ∘ compress composition
        (the PR 1 two-kernel path, kept as the oracle); both produce
        byte-identical wire streams.
        """
        assert int(acc.size) == c.n, (acc.size, c.n)
        eb_out = c.eb if eb_out is None else jnp.asarray(eb_out, jnp.float32)
        if not self.fused:
            updated = self.decompress_reduce(c, acc)
            return self.compress(updated, eb_out), (
                updated if return_updated else None
            )
        cap = capacity_words_for(c.n, self.capacity_factor, self.block)
        acc2d = ops.to_blocks(acc)
        res = ops.unpack_reduce_repack(
            c.packed, c.bitwidth, c.anchor, c.eb, acc2d, eb_out, cap,
            emit_f32=return_updated,
        )
        packed, bw, anchor = res[:3]
        c_out = Compressed(
            packed=packed, bitwidth=bw, anchor=anchor,
            nwords=bitpack.packed_words(bw, self.block), eb=eb_out,
            n=c.n, block=self.block,
        )
        updated = ops.from_blocks(res[3], c.n) if return_updated else None
        return c_out, updated


@dataclasses.dataclass(frozen=True)
class FixedRate:
    """1D fixed-rate baseline (ZFP-in-[30] analog): constant bits/element.

    Codes that exceed the rate are CLAMPED, so the error is unbounded —
    exactly the failure mode the paper's accuracy-aware design avoids.  The
    wire size is pre-known (the one advantage of fixed-rate).
    """

    rate_bits: int = 8
    block: int = ops.BLOCK

    def compress(self, x: jnp.ndarray, eb) -> Compressed:
        n = int(x.size)
        eb = jnp.asarray(eb, jnp.float32)
        x2d = ops.to_blocks(x)
        codes, _, anchor = ops.quantize(x2d, eb)
        limit = jnp.uint32((1 << self.rate_bits) - 1)
        codes = jnp.minimum(codes, limit)  # CLAMP -> unbounded error
        bw = jnp.full((codes.shape[0],), self.rate_bits, jnp.int32)
        cap = capacity_words_for(n, self.rate_bits / 32.0 + 1e-9, self.block)
        packed, nwords = bitpack.pack(codes, bw, cap)
        return Compressed(
            packed=packed, bitwidth=bw, anchor=anchor, nwords=nwords, eb=eb,
            n=n, block=self.block,
        )

    def stream_nwords(self, bitwidth: jnp.ndarray, n: int) -> jnp.ndarray:
        del n
        return bitpack.packed_words(bitwidth, self.block)

    def decompress(self, c: Compressed) -> jnp.ndarray:
        codes = bitpack.unpack(c.packed, c.bitwidth, c.block)
        x2d = ops.dequantize(codes, c.anchor, c.eb)
        return ops.from_blocks(x2d, c.n)

    def decompress_reduce(self, c: Compressed, acc: jnp.ndarray) -> jnp.ndarray:
        return acc + self.decompress(c)

    def decompress_reduce_compress(
        self, c: Compressed, acc: jnp.ndarray, eb_out=None, *,
        return_updated: bool = False,
    ):
        """Composition fallback (fixed-rate has no fused hop kernel)."""
        eb_out = c.eb if eb_out is None else jnp.asarray(eb_out, jnp.float32)
        updated = self.decompress_reduce(c, acc)
        return self.compress(updated, eb_out), (
            updated if return_updated else None
        )


def lossless_capacity_words(n: int, block: int = ops.BLOCK) -> int:
    """Worst-case entropy-stream words for ``n`` elements: every real
    block at its ceiling of ``2 * SUBS * 32 = block`` words (tile-padding
    blocks are all-zero and pack to 0 words).  The structural provisioning
    of the ``lossless`` codec — overflow is impossible by construction."""
    return max(-(-n // block) * block, 8)


@dataclasses.dataclass(frozen=True)
class EntropyLorenzo:
    """Lorenzo quantizer + per-sub-block entropy-coded wire (DESIGN.md §10).

    Quantization is IDENTICAL to ``ErrorBoundedLorenzo`` (the entropy
    stage acts after it, on the zigzag codes), so the error bound is
    untouched; only the wire format changes — each 256-block packs its
    four 64-element sub-blocks at their own widths, descriptor in the
    container's ``bitwidth`` slot.  The stream is never longer than the
    dense bitpack of the same codes, so the dense capacity provisioning
    carries over unchanged.

    ``lossless=True`` swaps the quantizer for a bit-exact
    ``bitcast(f32)->int32`` front end (eb ignored, decompress reproduces
    the input bit-for-bit) — the "lossless" registry entry.  Its capacity
    is STRUCTURAL, not factor-based: each block's four sub-streams total
    at most ``2 * 4 * 32 = BLOCK`` words, so provisioning every real
    block at BLOCK words (``lossless_capacity_words``) can never
    overflow, even on incompressible IEEE bit patterns.

    There is no fused single-pass hop kernel for this format yet, so
    ``decompress_reduce_compress`` is the two-kernel composition (the plan
    layer downgrades ``fused_hop`` with a recorded reason).
    """

    capacity_factor: float = 0.5
    block: int = ops.BLOCK
    fused: bool = True
    lossless: bool = False

    def compress(self, x: jnp.ndarray, eb) -> Compressed:
        n = int(x.size)
        eb = jnp.asarray(eb, jnp.float32)
        x2d = ops.to_blocks(x)
        if self.lossless:
            cap = lossless_capacity_words(n, self.block)
        else:
            cap = capacity_words_for(n, self.capacity_factor, self.block)
        if self.fused:
            packed, desc, anchor = ops.entropy_quantize_pack(
                x2d, eb, cap, lossless=self.lossless
            )
            nwords = entropy.packed_words(desc)
        else:
            codes, anchor = entropy.encode_blocks(x2d, eb, lossless=self.lossless)
            packed, desc, nwords = entropy.pack(codes, cap)
        return Compressed(
            packed=packed, bitwidth=desc, anchor=anchor, nwords=nwords, eb=eb,
            n=n, block=self.block,
        )

    def stream_nwords(self, bitwidth: jnp.ndarray, n: int) -> jnp.ndarray:
        del n
        return entropy.packed_words(bitwidth)

    def decompress(self, c: Compressed) -> jnp.ndarray:
        if self.fused:
            x2d = ops.entropy_unpack_dequantize(
                c.packed, c.bitwidth, c.anchor, c.eb, lossless=self.lossless
            )
        else:
            codes = entropy.unpack(c.packed, c.bitwidth, c.block)
            x2d = entropy.decode_blocks(
                codes, c.anchor, c.eb, lossless=self.lossless
            )
        return ops.from_blocks(x2d, c.n)

    def decompress_reduce(self, c: Compressed, acc: jnp.ndarray) -> jnp.ndarray:
        acc2d = ops.to_blocks(acc)
        if self.fused:
            out2d = ops.entropy_unpack_dequantize_reduce(
                c.packed, c.bitwidth, c.anchor, c.eb, acc2d,
                lossless=self.lossless,
            )
        else:
            codes = entropy.unpack(c.packed, c.bitwidth, c.block)
            out2d = acc2d + entropy.decode_blocks(
                codes, c.anchor, c.eb, lossless=self.lossless
            )
        return ops.from_blocks(out2d, c.n)

    def decompress_reduce_compress(
        self, c: Compressed, acc: jnp.ndarray, eb_out=None, *,
        return_updated: bool = False,
    ):
        """Composition hop (no fused entropy hop kernel yet)."""
        assert int(acc.size) == c.n, (acc.size, c.n)
        eb_out = c.eb if eb_out is None else jnp.asarray(eb_out, jnp.float32)
        updated = self.decompress_reduce(c, acc)
        return self.compress(updated, eb_out), (
            updated if return_updated else None
        )


@dataclasses.dataclass(frozen=True)
class Passthrough:
    """Identity codec: raw f32 bit patterns in the ``Compressed`` container.

    The baseline end of the codec registry — wire bytes equal the payload
    (plus container metadata), compression cost is a bitcast copy.  Useful
    when the planner decides compression cannot pay (tiny messages) and as
    the control in codec benchmarks.
    """

    block: int = ops.BLOCK

    def compress(self, x: jnp.ndarray, eb) -> Compressed:
        n = int(x.size)
        eb = jnp.asarray(eb, jnp.float32)
        flat = x.reshape(-1).astype(jnp.float32)
        cap = max(n, 8)
        words = jax.lax.bitcast_convert_type(flat, jnp.int32).astype(jnp.uint32)
        packed = jnp.zeros((cap,), jnp.uint32).at[:n].set(words)
        nb = ops.n_blocks_for(n)
        return Compressed(
            packed=packed,
            bitwidth=jnp.full((nb,), 32, jnp.int32),
            anchor=jnp.zeros((nb,), jnp.int32),
            nwords=jnp.int32(n), eb=eb, n=n, block=self.block,
        )

    def stream_nwords(self, bitwidth: jnp.ndarray, n: int) -> jnp.ndarray:
        del bitwidth
        return jnp.int32(n)

    def decompress(self, c: Compressed) -> jnp.ndarray:
        return jax.lax.bitcast_convert_type(
            c.packed[: c.n].astype(jnp.int32), jnp.float32
        )

    def decompress_reduce(self, c: Compressed, acc: jnp.ndarray) -> jnp.ndarray:
        return acc + self.decompress(c)

    def decompress_reduce_compress(
        self, c: Compressed, acc: jnp.ndarray, eb_out=None, *,
        return_updated: bool = False,
    ):
        eb_out = c.eb if eb_out is None else jnp.asarray(eb_out, jnp.float32)
        updated = self.decompress_reduce(c, acc)
        return self.compress(updated, eb_out), (
            updated if return_updated else None
        )


def __getattr__(name: str):
    # PR 8 satellite: the mutable module-global DEFAULT let two configs
    # with different codecs alias one compressor.  Kept as an import-time
    # shim only; resolve instances from the plan's codec entry instead.
    if name == "DEFAULT":
        warnings.warn(
            "compressor.DEFAULT is deprecated: resolve the compressor from "
            "the plan's codec entry via repro.core.codecs.build_compressor "
            "(or GZConfig.compressor()).",
            DeprecationWarning, stacklevel=2,
        )
        return ErrorBoundedLorenzo()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
