"""Per-block entropy stage over the quantized Lorenzo codes (jnp oracle).

The dense bitpack (``bitpack.py``) prices a whole 256-element block at the
bitwidth of its *worst* zigzag delta.  The entropy stage is a bitplane
trim at finer granularity: each block splits into ``SUBS`` sub-blocks of
``SUB`` elements, and each sub-block is packed at its own width.  Because
``SUB`` is a multiple of 32, every sub-block payload is a whole number of
uint32 words (``SUB_WORDS_PER_BIT * bw`` words), so sub-block boundaries
stay word-aligned and the single-pass Pallas packer
(``kernels/entropy.py``) keeps the exact SMEM-carry structure of the dense
one.

Wire format (per block of ``BLOCK`` elements):

  * the four 6-bit sub-widths travel packed into ONE int32 descriptor
    (``bw0 | bw1<<6 | bw2<<12 | bw3<<18``) stored in the ``Compressed``
    container's ``bitwidth`` slot — same metadata bytes as the dense
    format, no extra header word;
  * sub-block ``k``'s payload is ``SUB_WORDS_PER_BIT * bw_k`` words, laid
    out in sub order inside the block's word segment.

Size invariant: a block's entropy payload is ``2 * sum_k bw_k`` words
versus the dense ``8 * max_k bw_k`` — entropy-coded wire bytes are <= the
dense bitpack bytes for EVERY input, with equality only when all four
sub-widths equal the block max (asserted as a hypothesis property in
tests/test_codecs.py).

``lossless`` mode replaces the error-bounded quantizer with a bit-exact
``bitcast(f32) -> int32`` front end (the UCCL-Zip point): the Lorenzo
delta + zigzag + entropy pack then act on raw IEEE bit patterns, and
int32 wraparound makes the delta chain exact, so decompress reproduces
the input bit-for-bit (NaN payloads included).

Everything here is pure jnp — it is both the unfused compressor path and
the oracle the Pallas kernels are byte-identity-tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

__all__ = [
    "SUBS", "SUB", "SUB_WORDS_PER_BIT",
    "sub_widths", "make_desc", "split_desc", "packed_words",
    "pack", "unpack", "encode_blocks", "decode_blocks",
]

SUBS = 4
SUB = ops.BLOCK // SUBS  # 64: sub payloads stay word-aligned (SUB % 32 == 0)
SUB_WORDS_PER_BIT = SUB // 32  # 2 words per bit of sub-width
_DESC_BITS = 6  # sub-widths are 0..32, 6 bits each; 4 of them fit one int32


def _bitwidth_of(umax: jnp.ndarray) -> jnp.ndarray:
    """Elementwise bits needed for uint32 maxima (same table as lorenzo)."""
    powers = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum((umax[..., None] >= powers).astype(jnp.int32), axis=-1)


def sub_widths(codes: jnp.ndarray) -> jnp.ndarray:
    """uint32 (n_blocks, BLOCK) -> int32 (n_blocks, SUBS) per-sub bitwidths."""
    n_blocks, block = codes.shape
    umax = jnp.max(codes.reshape(n_blocks, SUBS, block // SUBS), axis=2)
    return _bitwidth_of(umax)


def make_desc(sub_bw: jnp.ndarray) -> jnp.ndarray:
    """int32 (n_blocks, SUBS) sub-widths -> packed int32 (n_blocks,) descriptor."""
    desc = jnp.zeros((sub_bw.shape[0],), jnp.int32)
    for k in range(SUBS):
        desc = desc | (sub_bw[:, k] << (_DESC_BITS * k))
    return desc


def split_desc(desc: jnp.ndarray) -> jnp.ndarray:
    """Packed descriptor (n_blocks,) -> int32 (n_blocks, SUBS) sub-widths."""
    mask = (1 << _DESC_BITS) - 1
    return jnp.stack(
        [(desc >> (_DESC_BITS * k)) & mask for k in range(SUBS)], axis=1
    )


def packed_words(desc: jnp.ndarray) -> jnp.ndarray:
    """True entropy-coded stream size in uint32 words (int32 scalar)."""
    return (jnp.sum(split_desc(desc)) * SUB_WORDS_PER_BIT).astype(jnp.int32)


def _positions(desc: jnp.ndarray, block: int):
    """Per-element absolute word index / shift / width for the entropy layout.

    Mirrors ``bitpack._positions`` with sub-block granularity: element ``j``
    of block ``i`` lives in sub ``j // SUB`` at that sub's own width, at a
    word offset of (blocks before i) + (subs before it inside i).
    """
    sub_bw = split_desc(desc)  # (nb, SUBS)
    words_per_sub = sub_bw * SUB_WORDS_PER_BIT
    words_per_block = jnp.sum(words_per_sub, axis=1)
    block_off = jnp.cumsum(words_per_block) - words_per_block  # exclusive
    sub_off = jnp.cumsum(words_per_sub, axis=1) - words_per_sub  # exclusive
    j = jnp.arange(block, dtype=jnp.int32)
    sub_idx = j // SUB
    jj = j - sub_idx * SUB
    bw = sub_bw[:, sub_idx]  # (nb, block)
    off = block_off[:, None] + sub_off[:, sub_idx]
    bitpos = off * 32 + jj[None, :] * bw
    word = (bitpos >> 5).astype(jnp.int32)
    shift = (bitpos & 31).astype(jnp.uint32)
    return word, shift, bw.astype(jnp.uint32)


def _width_mask(bw: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(
        bw == 0,
        jnp.uint32(0),
        jnp.uint32(0xFFFFFFFF) >> jnp.minimum(32 - bw, jnp.uint32(31)),
    )


def pack(codes: jnp.ndarray, capacity_words: int):
    """Entropy-pack zigzag codes at per-sub-block widths.

    Args:
      codes: uint32 (n_blocks, BLOCK).
      capacity_words: static output capacity (same provisioning as dense —
        the entropy stream can only be shorter).

    Returns:
      (packed uint32[capacity_words], desc int32 (n_blocks,), nwords int32).
    """
    n_blocks, block = codes.shape
    assert block % SUBS == 0 and (block // SUBS) % 32 == 0, block
    desc = make_desc(sub_widths(codes))
    word, shift, bw = _positions(desc, block)
    u = codes.astype(jnp.uint32) & _width_mask(bw)
    lo = u << shift
    hi = jnp.where(shift == 0, jnp.uint32(0),
                   u >> jnp.minimum(32 - shift, jnp.uint32(31)))
    packed = jnp.zeros((capacity_words,), jnp.uint32)
    flat_word = word.reshape(-1)
    # Disjoint bit ranges within a stream ==> OR == ADD (bitpack argument).
    packed = packed.at[flat_word].add(lo.reshape(-1), mode="drop")
    packed = packed.at[flat_word + 1].add(hi.reshape(-1), mode="drop")
    return packed, desc, packed_words(desc)


def unpack(packed: jnp.ndarray, desc: jnp.ndarray, block: int) -> jnp.ndarray:
    """Inverse of :func:`pack`.  Returns uint32 (n_blocks, block)."""
    n_words = packed.shape[0]
    word, shift, bw = _positions(desc, block)
    w0 = jnp.clip(word, 0, n_words - 1)
    w1 = jnp.clip(word + 1, 0, n_words - 1)
    lo = packed[w0] >> shift
    hi = jnp.where(shift == 0, jnp.uint32(0),
                   packed[w1] << jnp.minimum(32 - shift, jnp.uint32(31)))
    return (lo | hi) & _width_mask(bw)


def encode_blocks(x2d: jnp.ndarray, eb, *, lossless: bool = False):
    """f32 (nb, B) -> (zigzag codes uint32 (nb, B), anchor int32 (nb,)).

    Same quantize + Lorenzo-delta + zigzag math as the Pallas quantize
    kernel; with ``lossless`` the quantizer is a bit-exact int32 bitcast
    (wraparound deltas reconstruct exactly under two's complement).
    """
    if lossless:
        q = jax.lax.bitcast_convert_type(x2d.astype(jnp.float32), jnp.int32)
    else:
        recip = (1.0 / (2.0 * jnp.asarray(eb, jnp.float32))).astype(jnp.float32)
        q = jnp.rint(x2d * recip).astype(jnp.int32)
    col = jax.lax.broadcasted_iota(jnp.int32, q.shape, 1)
    prev = jnp.where(col == 0, q, jnp.roll(q, 1, axis=1))
    d = q - prev
    zig = ((d << 1) ^ (d >> 31)).astype(jnp.uint32)
    return zig, q[:, 0]


def decode_blocks(
    codes: jnp.ndarray, anchor: jnp.ndarray, eb, *, lossless: bool = False
) -> jnp.ndarray:
    """Inverse of :func:`encode_blocks`: codes + anchor -> f32 (nb, B)."""
    u = codes
    d = (u >> 1).astype(jnp.int32) ^ (-(u & 1).astype(jnp.int32))
    q = anchor[:, None] + jnp.cumsum(d, axis=1)
    if lossless:
        return jax.lax.bitcast_convert_type(q, jnp.float32)
    twoeb = (2.0 * jnp.asarray(eb, jnp.float32)).astype(jnp.float32)
    return q.astype(jnp.float32) * twoeb
