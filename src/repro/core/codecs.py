"""Pluggable wire-codec registry (DESIGN.md §10).

Mirrors the policy registry in ``comm``: a codec is a named entry that
says how payload bytes become wire bytes — which compressor class to
build, whether it has a fused single-pass hop kernel, how its capacity is
provisioned, and how the planner should price it before calibration has
measured it.  ``GZConfig.codec`` names an entry (or ``"auto"`` to let the
plan layer pick per tensor class from modeled collective time), the plan
cache keys on it, and the execute layer resolves the compressor instance
from the frozen plan — there is no module-global compressor anymore
(``compressor.DEFAULT`` is a deprecation shim).

Built-in entries:

  * ``lorenzo``          — today's dense per-block bitpack, the bitwise-
                           unchanged default;
  * ``lorenzo+entropy``  — the same quantizer with a per-sub-block
                           entropy trim on the wire (strictly smaller
                           streams, error bound untouched);
  * ``lossless``         — the entropy stage over bitcast IEEE words
                           (eb=0 semantics, exact round trip);
  * ``passthrough``      — raw f32 words in the same container.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core import compressor as compressor_lib
from repro.core import cost_model
from repro.core.compressed import capacity_words_for
from repro.kernels import ops

__all__ = [
    "AUTO",
    "CodecSpec",
    "register_codec",
    "codec_names",
    "get_codec",
    "auto_codecs",
    "validate_codec",
    "codec_capacity_words",
    "build_compressor",
]

# Sentinel config value: the plan layer resolves a concrete codec from
# modeled collective time.  Never a registry key.
AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class CodecSpec:
    """One wire-codec registry entry.

    Attributes:
      name: registry key (``GZConfig.codec`` value, plan-cache key part).
      factory: ``(capacity_factor, fused) -> compressor`` — builds the
        instance the execute layer uses; must honor the ``Compressed``
        container protocol (compress/decompress/decompress_reduce/
        decompress_reduce_compress).
      fused_hop: whether the codec has a single-pass fused hop kernel
        (unpack+reduce+repack).  When False the plan layer downgrades
        ``fused_hop`` to the two-pass composition and records why.
      lossy: bounded-lossy (the error bound applies) vs bit-exact.
      eb_scaled: the achievable ratio tracks the caller's assumed dense
        ratio (quantized codecs) vs being data-intrinsic (lossless /
        passthrough ship the same bytes whatever the bound).
      capacity_factor: provisioning override (None = the config knob; the
        entropy stream is never longer than dense, so it shares the dense
        provisioning).
      capacity_words: structural provisioning hook ``n_elems -> words``
        that bypasses factor-based sizing entirely (passthrough).
      terms: modeled default ``CodecTerms`` used by the planner until
        ``comm.calibrate()`` measures this codec on this machine.
      auto_selectable: legal candidate for ``codec="auto"``.
      description: one-liner for docs/benchmarks.
    """

    name: str
    factory: Callable
    fused_hop: bool
    lossy: bool
    eb_scaled: bool
    terms: cost_model.CodecTerms
    description: str
    auto_selectable: bool = True
    capacity_factor: Optional[float] = None
    capacity_words: Optional[Callable] = None


_CODECS: dict = {}


def register_codec(spec: CodecSpec) -> None:
    """Register (or replace) a wire codec."""
    if not isinstance(spec, CodecSpec):
        raise TypeError(f"register_codec needs a CodecSpec, got {spec!r}")
    if spec.name == AUTO:
        raise ValueError(f"codec name {AUTO!r} is reserved for planner selection")
    if spec.terms.codec != spec.name:
        raise ValueError(
            f"codec {spec.name!r}: terms are labeled {spec.terms.codec!r}"
        )
    _CODECS[spec.name] = spec


def codec_names() -> tuple:
    return tuple(_CODECS)


def get_codec(name: str) -> CodecSpec:
    try:
        return _CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; registered: {sorted(_CODECS)} "
            f"(or {AUTO!r} for planner selection)"
        ) from None


def auto_codecs() -> tuple:
    """Candidate codecs the planner may pick for ``codec='auto'``."""
    return tuple(n for n, s in _CODECS.items() if s.auto_selectable)


def validate_codec(name: str, *, knob: str) -> None:
    """Constructor-time validation for codec knobs (``auto`` allowed)."""
    if name != AUTO:
        try:
            get_codec(name)
        except ValueError as e:
            raise ValueError(f"{knob}={name!r}: {e}") from None


def codec_capacity_words(
    name: str, n_elems: int, capacity_factor: float, block: int = ops.BLOCK
) -> int:
    """Provisioned packed-stream words for ``n_elems`` f32 under ``name``.

    The single provisioning authority shared by the compressor factories
    and the plan layer's wire accounting, so the bytes a plan prices are
    the bytes the execute layer ships.
    """
    spec = get_codec(name)
    if spec.capacity_words is not None:
        return int(spec.capacity_words(n_elems))
    factor = (
        spec.capacity_factor if spec.capacity_factor is not None
        else capacity_factor
    )
    return capacity_words_for(n_elems, factor, block)


def build_compressor(name: str, *, capacity_factor: float, fused: bool):
    """Resolve a compressor instance from a codec entry.

    This replaces the old module-global ``compressor.DEFAULT``: the
    instance is derived from the (frozen) plan/config, so two configs with
    different codecs can never alias one global.
    """
    if name == AUTO:
        raise ValueError(
            "codec='auto' must be resolved by the plan layer before the "
            "execute layer builds a compressor (Plan.codec is always "
            "concrete); construct the config from plan.as_config()."
        )
    spec = get_codec(name)
    factor = (
        spec.capacity_factor if spec.capacity_factor is not None
        else capacity_factor
    )
    return spec.factory(factor, fused)


register_codec(CodecSpec(
    name="lorenzo",
    factory=lambda cf, fused: compressor_lib.ErrorBoundedLorenzo(
        capacity_factor=cf, fused=fused
    ),
    fused_hop=True,
    lossy=True,
    eb_scaled=True,
    terms=cost_model.CodecTerms("lorenzo"),
    description="dense per-block bitpack over Lorenzo-quantized codes "
                "(the gZCCL default)",
))

register_codec(CodecSpec(
    name="lorenzo+entropy",
    factory=lambda cf, fused: compressor_lib.EntropyLorenzo(
        capacity_factor=cf, fused=fused
    ),
    fused_hop=False,  # no fused unpack+reduce+repack kernel (yet)
    lossy=True,
    eb_scaled=True,
    # Modeled default until calibration: the per-sub-block trim buys
    # ~25-40% on smooth tensors (BENCH_codec.json), at slightly more
    # pack-side arithmetic which the measured terms capture when fitted.
    terms=cost_model.CodecTerms("lorenzo+entropy", ratio_scale=1.3),
    description="same quantizer, per-sub-block entropy-coded wire "
                "(smaller streams, identical error bound)",
))

register_codec(CodecSpec(
    name="lossless",
    factory=lambda cf, fused: compressor_lib.EntropyLorenzo(
        capacity_factor=cf, fused=fused, lossless=True
    ),
    fused_hop=False,
    lossy=False,
    eb_scaled=False,
    # Structural worst case (each block's sub-streams total <= BLOCK
    # words): overflow is impossible by construction, and the bound is
    # tighter than any factor-based provisioning.
    capacity_words=compressor_lib.lossless_capacity_words,
    terms=cost_model.CodecTerms("lossless", ratio_abs=1.3),
    description="entropy stage over bitcast IEEE words: eb=0 semantics, "
                "bit-exact round trip",
))

register_codec(CodecSpec(
    name="passthrough",
    factory=lambda cf, fused: compressor_lib.Passthrough(),
    fused_hop=False,
    lossy=False,
    eb_scaled=False,
    capacity_words=lambda n: max(int(n), 8),
    terms=cost_model.CodecTerms(
        "passthrough", ratio_abs=1.0, cmp_overhead_us=1.0
    ),
    auto_selectable=False,  # explicit-opt-in control codec
    description="raw f32 words in the compressed container (control / "
                "compression-never-pays escape hatch)",
))
