"""Plan-then-execute communicator surface for the gZ collectives.

The paper's §3 premise is that compression-accelerated collectives are a
*framework*: one place coordinates algorithm choice, overlap depth, and
accuracy-aware per-stage error budgets.  Before this module that
coordination was smeared across call sites — every ``gz_*`` call
re-derived its plan at trace time and callers hand-assembled ``GZConfig``
knob-bags.  ZCCL frames exactly this as a communicator-level concern, and
NCCLZ argues for a plan-then-execute surface rather than per-call knobs;
this module is that surface for the shard_map collectives:

  * :class:`GZCommunicator` binds ONE mesh axis (name + size) and the
    static knobs (eb, capacity, policy, hardware model) once.
  * ``comm.plan(op, shape, dtype)`` resolves a frozen, hashable
    :class:`Plan` — concrete algorithm, pipeline depth, per-stage eb,
    capacity words, provisioned wire bytes — OUTSIDE the traced region,
    memoized module-wide per ``(op, nbytes, dtype, axis_size, eb)`` plus
    the policy knobs.  Repeated jitted calls (and re-traces) hit the
    cache; the cost model runs exactly once per distinct key.
  * The collectives are methods (``allreduce``/``reduce_scatter``/
    ``allgather``/``scatter``/``broadcast``/``all_to_all``) that dispatch
    on the Plan with zero in-trace selector logic, and every one of them
    returns the same :class:`CollectiveResult` stats channel — no more
    ``return_info: bool`` tuple convention.

Static vs traced (DESIGN.md §5): everything in a ``Plan`` is static
Python — algorithm strings, chunk counts, byte counts, floats.  The only
traced values are the payload itself and the ``CollectiveResult.overflow``
flag (a global OR across the axis, one scalar psum).  Plans can therefore
be resolved eagerly outside ``jit``, closed over, or resolved lazily at
trace time — either way the resolution is a dict lookup after the first
call.

Policies (the registry is extensible via :func:`register_policy`):

  ``auto``        cost-model selection under the production (fused-hop,
                  chunked double-buffered) schedules; ring gets its
                  pipeline depth from ``best_pipeline_chunks`` capped by
                  what the payload can fill.  The default, and exactly
                  what ``gz_allreduce(algo="auto")`` always did.
  ``paper``       the paper's §3.3.3 selector: ring vs recursive doubling
                  under the two-kernel multi-stream cost models,
                  sequential schedule — reproduces the published
                  crossover.
  ``throughput``  like ``auto`` but also allowed to pick the
                  beyond-paper integer ring when it models fastest.
  ``accuracy``    the bitwise-rank-consistent integer ring (single
                  quantization grid, no stacked requantization noise)
                  regardless of modeled speed.

Calibration: :func:`fit_hardware` fits ``cost_model.Hardware`` codec
parameters (throughput + per-invocation overhead) from measured
``(size, seconds)`` samples — ``measure_codec`` produces them with the
same timing discipline as the microbenchmark suite — and
``comm.calibrate()`` returns a communicator whose plans use the fitted
model.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import codecs, cost_model, error_budget, faults, schedule
from repro.core.compressed import capacity_words_for
from repro.kernels import ops

__all__ = [
    "Plan",
    "HierPlan",
    "FallbackPlan",
    "CollectiveResult",
    "GZCommunicator",
    "GZHierCommunicator",
    "select_allreduce",
    "select_allreduce_plan",
    "assert_step_count_consistency",
    "register_policy",
    "policy_names",
    "plan_cache_stats",
    "clear_plan_cache",
    "enable_health_tracking",
    "health_stats",
    "clear_health_stats",
    "fit_hardware",
    "fit_network",
    "fit_codec_terms",
    "measure_codec",
    "measure_codecs",
    "measure_ppermute",
]

OPS = (
    "allreduce",
    "reduce_scatter",
    "allgather",
    "scatter",
    "broadcast",
    "all_to_all",
)

# Fixed algorithm per data-movement op (only allreduce has a real choice).
_OP_ALGO = {
    "reduce_scatter": "ring",
    "allgather": "ring",
    "scatter": "binomial",
    "broadcast": "binomial",
    "all_to_all": "direct",
}


# ---------------------------------------------------------------------------
# Plan & CollectiveResult
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FallbackPlan:
    """The lossless degradation target of a compressed plan (DESIGN.md §9).

    Every resolved :class:`Plan`/:class:`HierPlan` carries one: the
    uncompressed schedule over the SAME axis/topology that
    ``on_overflow="fallback"`` re-executes through when a stream
    overflowed, a verified hop failed its checksum, or an input held
    NaN/Inf.  Static and hashable like every other plan field.
    """

    op: str
    kind: str          # lossless primitive: psum | psum_scatter | ...
    axis_size: int
    wire_bytes: int    # raw uncompressed bytes the fallback moves per rank
    t_model: float     # modeled seconds of one fallback execution


# Lossless primitive each op degrades to (FallbackPlan.kind).
_FALLBACK_KIND = {
    "allreduce": "psum",
    "reduce_scatter": "psum_scatter",
    "allgather": "all_gather",
    "scatter": "raw_slab_tree",
    "broadcast": "raw_tree_forward",
    "all_to_all": "all_to_all",
}


def _fallback_plan(op, n_elems, axis_size, hw) -> FallbackPlan:
    return FallbackPlan(
        op=op, kind=_FALLBACK_KIND[op], axis_size=axis_size,
        wire_bytes=n_elems * 4,
        t_model=cost_model.fallback_time(op, n_elems * 4, axis_size, hw),
    )


@dataclasses.dataclass(frozen=True)
class Plan:
    """A frozen, hashable execution plan for one collective call.

    Every field is static Python (hashable — the plan is a valid
    ``custom_vjp`` nondiff argument and a valid dict key).  ``eb_stage``,
    ``capacity_words``, ``wire_bytes`` and ``ratio`` are *derived*
    observability fields: execution re-derives the same quantities from
    the same inputs (single source of truth is ``error_budget`` /
    ``capacity_words_for``), so a Plan can never disagree with what runs.
    """

    op: str               # one of OPS
    algo: str             # concrete algorithm — never "auto"
    n_elems: int          # flat f32 element count of the per-rank payload
    nbytes: int           # n_elems * 4 (collectives run on the f32 view)
    dtype: str            # caller dtype (cast back on the way out)
    axis_size: int
    eb: float             # end-to-end absolute error bound
    eb_stage: float       # per-stage bound from error_budget.allocate
    pipeline_chunks: int  # concrete depth (>= 1)
    fused: bool
    fused_hop: bool
    capacity_factor: float
    worst_case_budget: bool
    capacity_words: int   # provisioned uint32 words per wire stream
    wire_bytes: int       # provisioned bytes shipped per rank (upper bound)
    ratio: float          # uncompressed-equivalent bytes / wire_bytes
    policy: str
    # Binomial-tree ops only: derived observability field like eb_stage /
    # wire_bytes above — a frozen copy of the trimmed-slab schedule
    # (cost_model.binomial_slab_table(axis_size): per-round
    # (span, full_senders, (sender, receiver, slab)|None), top-down).
    # The execute layer and simulator re-derive the same table from the
    # same single authority, so this can never disagree with what runs.
    # Static and hashable like every other field; () for non-tree ops.
    slab_table: tuple = ()
    # Degradation policy (DESIGN.md §9): what the communicator does when
    # overflow/NaN/Inf/corruption fires, and whether hops ship checksums.
    on_overflow: str = "flag"   # flag | fallback | raise
    verify_streams: bool = False
    # The resolved lossless degradation target — always present (the
    # fallback schedule exists whether or not the policy executes it).
    fallback: Optional[FallbackPlan] = None
    # Wire codec (DESIGN.md §10): always a CONCRETE registry name, never
    # "auto" — the planner resolves selection before freezing the plan.
    # ``codec_ratio`` is the measured-or-modeled payload ratio the codec
    # was priced at (calibrated ``Hardware.codec_terms`` win over the
    # registry's modeled defaults); ``ratio`` above stays the provisioned
    # wire reduction.  ``notes`` records resolution decisions a caller
    # would otherwise have to re-derive (codec forcing, fused-hop
    # downgrades, auto selection).
    codec: str = "lorenzo"
    codec_ratio: float = 1.0
    notes: tuple = ()
    # The resolved Schedule IR (ISSUE 10): the frozen per-round route
    # table the execute layer walks, the simulator replays, the wire
    # accounting sums and the fault injector targets — authored once by
    # ``schedule.build`` at plan resolution.  None only on plans built
    # by hand in tests.
    route_table: Optional[schedule.Schedule] = None

    def as_config(self):
        """The concrete GZConfig the execute layer dispatches on."""
        from repro.core.collectives import GZConfig

        return GZConfig(
            eb=self.eb,
            capacity_factor=self.capacity_factor,
            algo=self.algo,
            worst_case_budget=self.worst_case_budget,
            pipeline_chunks=self.pipeline_chunks,
            fused=self.fused,
            fused_hop=self.fused_hop,
            on_overflow=self.on_overflow,
            verify_streams=self.verify_streams,
            codec=self.codec,
        )


@dataclasses.dataclass(frozen=True)
class HierPlan:
    """A frozen, hashable plan for one TWO-LEVEL collective call.

    Composes per-axis sub-:class:`Plan`s over a ``(n_nodes, local)``
    topology (the FULL axis-size tuple — 2×4 and 4×2 are different plans
    with different schedules, which is why the cache below keys on the
    tuple, not the product).  ``flat`` picks which sub-plan executes
    (``flat_plan`` is always resolved — the flat alternative is the
    comparison baseline benchmarks record; ``inter`` exists only on the
    hierarchical path):

      * ``flat=True``: run the ordinary single-axis schedule
        (``flat_plan``) over the composite ``(node, *local)`` axis — the
        resolution when the fabric has no link asymmetry (or only one
        rank per node), so "hierarchy off" is bitwise the pre-existing
        path.
      * ``flat=False``: uncompressed intra-node reduce-scatter →
        compressed ``inter`` allreduce of the ceil(D/L) shard across
        nodes (the only lossy stage; it carries the WHOLE error budget —
        ``error_budget.split_lossy`` gives the exact intra stages 0) →
        uncompressed intra-node allgather.

    ``inter_wire_bytes`` is the per-rank payload crossing node
    boundaries: the hierarchical path ships only the inter sub-plan's
    provisioned streams; the flat path's node-major ring makes EVERY send
    of a node-boundary rank cross, so its inter wire is the full
    single-axis ``wire_bytes`` — the quantity ``benchmarks/hier_bench.py``
    records and ``regression_check.py`` pins.  ``t_model``/``t_flat`` are
    the modeled seconds of the chosen path and the flat alternative
    (per-link terms: ``cost_model.allreduce_hier_gz`` vs the flat model).
    """

    op: str
    topology: tuple        # (n_nodes, gpus_per_node) — full axis-size tuple
    n_elems: int
    nbytes: int
    dtype: str
    eb: float
    flat: bool
    inter: Optional[Plan]       # compressed inter-node stage (hier path)
    flat_plan: Optional[Plan]   # composite-axis plan (flat path)
    intra_wire_bytes: int  # uncompressed intra-node bytes per rank (RS+AG)
    inter_wire_bytes: int  # provisioned bytes crossing node boundaries/rank
    t_model: float         # modeled seconds of the chosen path
    t_flat: float          # modeled seconds of the flat alternative
    policy: str
    # Degradation policy + the composite-axis lossless target (§9); the
    # sub-plans carry their own fallback/verify knobs via as_config().
    on_overflow: str = "flag"
    verify_streams: bool = False
    fallback: Optional[FallbackPlan] = None
    # Wire codec of the path that executes (the flat sub-plan's, or the
    # inter stage's on the hierarchical path — the intra stages are
    # uncompressed and carry no codec).
    codec: str = "lorenzo"
    # The resolved Schedule IR of the path that executes: the flat
    # sub-plan's table, or the two-level composition from
    # ``schedule.build_hier`` (raw exact intra rounds around the lifted
    # compressed inter rounds) on the hierarchical path.
    route_table: Optional[schedule.Schedule] = None

    @property
    def ratio(self) -> float:
        """Inter-node wire reduction vs what the flat path would cross."""
        if self.flat:
            return self.flat_plan.ratio
        if not self.inter_wire_bytes:
            return 1.0
        return self.inter.ratio


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CollectiveResult:
    """Uniform result-and-stats channel of every communicator method.

    ``value``/``overflow``/``nonfinite`` are traced; ``wire_bytes``/
    ``ratio`` are static (pytree aux data) so the container flows through
    ``jit``/``shard_map`` like a 3-leaf pytree.

    ``overflow`` is the global OR across the axis ("did any piece of any
    hop anywhere exceed its provisioned capacity, or fail stream
    verification") — the per-rank local flag alone can be silently False
    on a rank whose *received* data was truncated elsewhere.

    ``nonfinite`` is the distinct health bit for NaN/Inf detected in the
    INPUT before compression (a non-finite value entering the quantizer
    poisons the packed stream undetectably, so it is checked up front) —
    global OR across the axis, root-masked for scatter/broadcast where
    only the root's payload is significant.  Under
    ``on_overflow="fallback"`` either bit routes the call through the
    lossless schedule (``overflow | nonfinite`` is the re-execute
    predicate; the ``degraded`` property).

    ``wire_bytes`` is the statically provisioned payload a rank ships for
    the whole collective (XLA moves provisioned capacity, not the ragged
    true stream — DESIGN.md §2.1); ``ratio`` is the uncompressed
    equivalent divided by that, i.e. the wire reduction this plan achieves
    on the static-shape transport.
    """

    value: jnp.ndarray
    overflow: jnp.ndarray
    nonfinite: jnp.ndarray
    wire_bytes: int = dataclasses.field(metadata=dict(static=True))
    ratio: float = dataclasses.field(metadata=dict(static=True))

    @property
    def degraded(self) -> jnp.ndarray:
        """True iff this call could not complete losslessly-bounded
        compressed (the fallback predicate)."""
        return self.overflow | self.nonfinite

    def astuple(self):
        return (self.value, self.overflow, self.nonfinite,
                self.wire_bytes, self.ratio)


# ---------------------------------------------------------------------------
# Provisioned wire accounting (static, from the plan inputs alone)
# ---------------------------------------------------------------------------


def _stream_bytes(n_elems: int, capacity_factor: float,
                  codec: str = "lorenzo") -> int:
    """Wire bytes of one provisioned ``Compressed`` stream for n f32.

    Capacity comes from :func:`codecs.codec_capacity_words` — the same
    provisioning authority the compressor factories use — so per-codec
    overrides (lossless' 1.25 factor, passthrough's structural n words)
    price exactly the buffers the execute layer ships.  The metadata
    sidecar (per-block bitwidth/descriptor + anchor) is the same
    container shape for every codec.
    """
    cap = codecs.codec_capacity_words(codec, n_elems, capacity_factor)
    n_blocks = ops.n_blocks_for(n_elems)
    return cap * 4 + 2 * n_blocks * 4 + 8  # packed + bitwidth + anchor + meta


def _int_stream_bytes(n_elems_padded: int, capacity_factor: float) -> int:
    """intring hop payload: packed codes + per-block bitwidth + anchor.

    ``n_elems_padded`` must already be whole blocks (the execute layer
    pads each chunk to whole row-tiles before quantizing)."""
    cap = capacity_words_for(n_elems_padded, capacity_factor, ops.BLOCK)
    rows = n_elems_padded // ops.BLOCK
    return cap * 4 + 2 * rows * 4


# Elements per compressor row-tile — the pipelined schedules' piece quantum
# (same constant as collectives.PIECE_QUANTUM; duplicated here to keep the
# module import-cycle-free).
_PIECE_QUANTUM = ops.BLOCK * ops.TILE_ROWS


def _ring_piece_sizes(n_elems, n, chunks):
    """(chunk, piece) the ring schedules actually run: pipelined rings pad
    the payload so each of the n chunks is `chunks` whole-tile pieces
    (collectives._pad_for_pipeline)."""
    p = max(chunks, 1)
    if p > 1:
        quantum = n * p * _PIECE_QUANTUM
        total = -(-n_elems // quantum) * quantum
        return total // n, total // (n * p)
    chunk = -(-n_elems // n)
    return chunk, chunk


def _wire_accounting(op, algo, n_elems, n, capacity_factor, chunks,
                     codec: str = "lorenzo"):
    """(capacity_words, wire_bytes, uncompressed_bytes) for one call.

    Per-rank send bytes, upper bound: SUM the resolved route table
    (``schedule.build(op, algo, n)`` — the same table the execute layer
    walks and the simulator replays, ISSUE 10).  Every entry is priced by
    the payload it ships at the op's transport granularity (full message,
    padded ring piece, tree chunk slab, integer code rows — the
    ``_entry_pricers`` closures mirror the execute layer's padding), the
    per-sender totals are accumulated, and the busiest rank's total is
    the provisioned wire.  Because perms, replay and pricing all read
    ONE table, step drift (the PR 4 floor-vs-ceil class) is structurally
    impossible.  ``raw`` sums the same entries' uncompressed-equivalent
    (unpadded) payloads: what the lax.* collective would move.
    """
    cap, entry_wire, entry_raw = _entry_pricers(
        op, algo, n_elems, n, capacity_factor, chunks, codec)
    if n < 2:
        # Degenerate axis: the route table has no wire rounds.  Preserve
        # the historic provisioning: one full stream for the log-depth
        # ops (steps_for floors n at 2), zero for the rings.
        if (op == "allreduce" and algo == "redoub") or op == "broadcast":
            return cap, _stream_bytes(n_elems, capacity_factor, codec), \
                n_elems * 4
        if op == "all_to_all":
            h = schedule.Hop(0, 0, (0, 1), "lossy", "compressed")
            return cap, entry_wire(h), entry_raw(h)
        return cap, 0, 0
    table = schedule.build(op, algo, n)
    send = [0] * n
    send_raw = [0] * n
    for rnd in table.rounds:
        for h in rnd:
            send[h.sender] += entry_wire(h)
            send_raw[h.sender] += entry_raw(h)
    return cap, max(send), max(send_raw)


def _entry_pricers(op, algo, n_elems, n, capacity_factor, chunks, codec):
    """Per-table-entry pricing closures for one op's transport.

    Returns ``(capacity_words, entry_wire(h), entry_raw(h))``: the
    provisioned capacity of one wire stream, and the compressed /
    uncompressed-equivalent bytes one :class:`schedule.Hop` ships —
    including the execute layer's padding (pipelined rings pad to
    whole-tile pieces, intring pads chunks to whole code rows).
    """
    p = max(chunks, 1)
    if op == "allreduce" and algo == "redoub" or op == "broadcast":
        cap = codecs.codec_capacity_words(codec, n_elems, capacity_factor)
        stream = _stream_bytes(n_elems, capacity_factor, codec)
        return cap, (lambda h: stream), (lambda h: n_elems * 4)
    if op == "allreduce" and algo == "intring":
        # execute pads each chunk to whole row-tiles of int codes
        chunk = ops.n_blocks_for(-(-n_elems // max(n, 1))) * ops.BLOCK
        cap = capacity_words_for(chunk, capacity_factor, ops.BLOCK)
        stream = _int_stream_bytes(chunk, capacity_factor)
        chunk_in = -(-n_elems // max(n, 1))
        return cap, (lambda h: stream), (lambda h: chunk_in * 4)
    if op == "allreduce":  # float ring
        chunk, piece = _ring_piece_sizes(n_elems, n, chunks)
        cap = codecs.codec_capacity_words(codec, piece, capacity_factor)
        stream = p * _stream_bytes(piece, capacity_factor, codec)
        chunk_in = -(-n_elems // max(n, 1))
        return cap, (lambda h: stream), (lambda h: chunk_in * 4)
    if op == "reduce_scatter":
        chunk_in = -(-n_elems // max(n, 1))
        if p > 1:  # execute pads each chunk to p whole-tile pieces
            quantum = p * _PIECE_QUANTUM
            piece = (-(-chunk_in // quantum) * quantum) // p
        else:
            piece = chunk_in
        cap = codecs.codec_capacity_words(codec, piece, capacity_factor)
        stream = p * _stream_bytes(piece, capacity_factor, codec)
        return cap, (lambda h: stream), (lambda h: chunk_in * 4)
    if op == "allgather":
        if p > 1:  # execute pads the own chunk to p whole-tile pieces
            quantum = p * _PIECE_QUANTUM
            piece = (-(-n_elems // quantum) * quantum) // p
        else:
            piece = n_elems
        cap = codecs.codec_capacity_words(codec, piece, capacity_factor)
        stream = p * _stream_bytes(piece, capacity_factor, codec)
        return cap, (lambda h: stream), (lambda h: n_elems * 4)
    if op == "scatter":
        # Trimmed-slab schedule: each entry ships one compressed stream
        # per REAL chunk in its slab, so the root's entries sum to
        # exactly n-1 chunk streams at ANY axis size (the padded virtual
        # tree's zero-padding chunks never appear in the table).
        chunk = -(-n_elems // max(n, 1))
        cap = codecs.codec_capacity_words(codec, chunk, capacity_factor)
        stream = _stream_bytes(chunk, capacity_factor, codec)
        return cap, (lambda h: h.chunk_slab[1] * stream), \
            (lambda h: h.chunk_slab[1] * chunk * 4)
    if op == "all_to_all":
        chunk = -(-n_elems // max(n, 1))
        cap = codecs.codec_capacity_words(codec, chunk, capacity_factor)
        stream = _stream_bytes(chunk, capacity_factor, codec)
        return cap, (lambda h: stream), (lambda h: chunk * 4)
    raise ValueError(f"unknown op {op!r}")


def assert_step_count_consistency(n_range=range(2, 34), n_elems: int = 4096,
                                  capacity_factor: float = 0.6) -> None:
    """Structural self-check: the wire accounting's implied step counts
    equal ``cost_model.steps_for`` for every axis size in ``n_range`` —
    the PR 4 floor-vs-ceil regression (plans silently under-reported
    non-power-of-two wire bytes while the cost model used ceil, so
    planning could mis-rank algorithms) — and the trimmed-slab schedule
    is well-formed (ISSUE 5): for every n the slab table's root streams
    sum to exactly n-1 chunks, every non-root rank receives exactly once,
    each exchanged slab is exactly the real ranks of the receiver's
    virtual subtree, at most one trimmed exchange per round (the "one
    extra ppermute shape"), none at power-of-two n, and the scatter wire
    accounting prices exactly those root slabs.  Raises AssertionError
    naming the first disagreeing (op, n).  Called by tests/test_comm.py
    and, on every CI run, by benchmarks/regression_check.py.  Raises
    explicitly (not via ``assert`` statements, which vanish under
    ``python -O`` — this is the check that must never silently pass).
    """
    def _require(cond, msg):
        if not cond:
            raise AssertionError(msg)

    stream = _stream_bytes(n_elems, capacity_factor)
    for n in n_range:
        ceil_steps = max(n - 1, 1).bit_length()
        for algo in ("redoub", "binomial"):
            _require(cost_model.steps_for(algo, n) == ceil_steps,
                     f"steps_for({algo!r}, {n}) != ceil(log2 n)")
        _, wire, raw = _wire_accounting(
            "allreduce", "redoub", n_elems, n, capacity_factor, 1)
        _require(wire == ceil_steps * stream,
                 f"redoub wire accounting disagrees with the cost model at n={n}")
        _require(raw == ceil_steps * n_elems * 4, f"redoub raw bytes at n={n}")
        _, wire, _ = _wire_accounting(
            "broadcast", "binomial", n_elems, n, capacity_factor, 1)
        _require(wire == ceil_steps * stream,
                 f"broadcast wire accounting disagrees with the cost model at n={n}")

        # Trimmed-slab schedule well-formedness (the scatter tree).
        table = cost_model.binomial_slab_table(n)
        _require(len(table) == ceil_steps,
                 f"slab table has {len(table)} rounds != ceil(log2 {n})")
        receivers = []
        for span, full, trim in table:
            _require(trim is None or 0 < trim[2] < span,
                     f"trimmed slab out of range at n={n}, span={span}")
            if n & (n - 1) == 0:
                _require(trim is None,
                         f"power-of-two n={n} must have no trimmed exchange")
            pairs = [(i, i + span, span) for i in full]
            if trim is not None:
                pairs.append(trim)
            for snd, rcv, slab in pairs:
                receivers.append(rcv)
                _require(
                    slab == max(0, min(n, rcv + span) - rcv),
                    f"slab != real ranks of subtree [{rcv},{rcv + span}) "
                    f"at n={n}")
        _require(sorted(receivers) == list(range(1, n)),
                 f"slab table receivers != every non-root rank at n={n}")
        root_streams = cost_model.scatter_root_chunk_streams(n)
        _require(root_streams == n - 1,
                 f"root slab-sum {root_streams} != n-1 chunks at n={n}")
        chunk = -(-n_elems // n)
        _, wire, _ = _wire_accounting(
            "scatter", "binomial", n_elems, n, capacity_factor, 1)
        _require(
            wire == root_streams * _stream_bytes(chunk, capacity_factor),
            f"scatter wire accounting disagrees with the trimmed slab "
            f"table at n={n}")


def _eb_stage(op, algo, eb, n, worst_case):
    if op == "allreduce":
        if algo == "intring":
            return eb  # single quantization grid; n addends share it
        key = f"allreduce_{algo}"
        return error_budget.allocate(eb, key, n, worst_case=worst_case)
    if op == "reduce_scatter":
        return error_budget.allocate(
            eb, "reduce_scatter_ring", n, worst_case=worst_case
        )
    return eb  # data-movement ops: exactly one lossy hop


# ---------------------------------------------------------------------------
# Algorithm selection (the paper's §3.3.3 design framework)
#
# Moved here from core/selector.py (now a deprecation shim): the policy
# registry below is the ONLY selection authority, and these are its cost
# evaluators.
# ---------------------------------------------------------------------------


def select_allreduce(
    d_bytes: int,
    n_ranks: int,
    ratio: float = 20.0,
    hw: cost_model.Hardware = cost_model.TPU_V5E,
    *,
    allow_beyond_paper: bool = False,
) -> str:
    """Return 'ring' | 'redoub' (| 'intring' when beyond-paper allowed).

    The PAPER's selector (§3.3.3): with GPU compression in the loop the
    classic "ring for large messages" rule inverts once the per-chunk
    size D/N falls below the compressor's saturation point; recursive
    doubling's log2(N) *saturated* compressions then win despite moving
    more bytes.  Both algorithms are costed under the paper's two-kernel
    multi-stream-overlap models (no fused hop on either side —
    ``allreduce_ring_gz`` has none, so redoub must not get one either or
    the crossover is biased).  The production planner with the fused-hop
    schedule is :func:`select_allreduce_plan`.  A conservative default
    compression ratio of 20x (paper Table 1 sees 46-94x on RTM data) is
    used unless the caller passes a measured one.
    """
    costs = {
        "ring": cost_model.allreduce_ring_gz(d_bytes, n_ranks, ratio, hw),
        "redoub": cost_model.allreduce_redoub_gz(
            d_bytes, n_ranks, ratio, hw, fused_hop=False
        ),
    }
    if allow_beyond_paper:
        costs["intring"] = cost_model.allreduce_intring_gz(
            d_bytes, n_ranks, ratio, hw)
    return min(costs, key=costs.get)


def select_allreduce_plan(
    d_bytes: int,
    n_ranks: int,
    ratio: float = 20.0,
    hw: cost_model.Hardware = cost_model.TPU_V5E,
    *,
    allow_beyond_paper: bool = False,
    chunk_candidates=cost_model.PIPELINE_CHUNK_CANDIDATES,
    fused_hop: bool = True,
) -> tuple:
    """Pick (algo, pipeline_chunks) from the explicit per-chunk cost model.

    Ring is costed under the chunked double-buffered schedule at its best
    chunk count (DESIGN.md §4): above the compressor saturation size the
    pipelined ring strictly dominates the sequential one, so the plan
    comes back with chunks > 1; below it, per-piece overhead wins and the
    plan degrades to the sequential schedule (chunks == 1).  ReDoub
    compresses full messages — its overlap is already a single long
    chain, so it takes no chunk knob (returned chunks apply to ring
    only).  ``fused_hop`` costs BOTH algorithms' hops as single-pass
    ``t_hop_fused`` kernels and pushes the ring's best chunk count
    deeper.
    """
    ring_chunks = cost_model.best_pipeline_chunks(
        d_bytes, n_ranks, ratio, hw, chunk_candidates, fused_hop=fused_hop
    )
    costs = {
        ("ring", ring_chunks): cost_model.allreduce_ring_gz_chunked(
            d_bytes, n_ranks, ratio, hw, ring_chunks, fused_hop=fused_hop
        ),
        ("redoub", 1): cost_model.allreduce_redoub_gz(
            d_bytes, n_ranks, ratio, hw, fused_hop=fused_hop
        ),
    }
    if allow_beyond_paper:
        costs[("intring", 1)] = cost_model.allreduce_intring_gz(
            d_bytes, n_ranks, ratio, hw
        )
    return min(costs, key=costs.get)


# ---------------------------------------------------------------------------
# Policy registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanRequest:
    """Everything a policy may inspect when choosing (algo, chunks)."""

    op: str
    n_elems: int
    nbytes: int
    axis_size: int
    requested_algo: Optional[str]  # None == "pick for me"
    requested_chunks: int          # 0 == "plan the ring depth for me"
    fused_hop: bool
    ratio: float                   # assumed compression ratio for costing
    hw: cost_model.Hardware


PolicyFn = Callable[[PlanRequest], tuple]
_POLICIES: dict = {}


def register_policy(name: str, fn: PolicyFn) -> None:
    """Add/replace a named plan policy: fn(PlanRequest) -> (algo, chunks)."""
    _POLICIES[name] = fn


def policy_names() -> tuple:
    return tuple(sorted(_POLICIES))


def _ring_depth(req: PlanRequest) -> int:
    from repro.core.collectives import plan_ring_pipeline_chunks

    return plan_ring_pipeline_chunks(
        req.n_elems, req.axis_size, ratio=req.ratio, hw=req.hw,
        fused_hop=req.fused_hop,
    )


def _data_movement_plan(req: PlanRequest):
    """(algo, chunks) for the fixed-algorithm data-movement ops — shared
    by every policy (the algorithm choice only exists for allreduce).

    ``requested_chunks == 0`` asks for planned depth (the grad-sync
    routing convention): the scatter gets it from
    ``cost_model.best_scatter_pipeline_chunks`` (the previously dead
    ``scatter_binomial_gz_chunked`` path — ISSUE 5 satellite); the other
    data movers have no modeled pipelined schedule and stay sequential.
    """
    chunks = req.requested_chunks
    if req.op == "scatter" and chunks == 0:
        chunks = cost_model.best_scatter_pipeline_chunks(
            req.nbytes, req.axis_size, req.ratio, req.hw
        )
    return _OP_ALGO[req.op], max(chunks, 1)


def _policy_auto(req: PlanRequest):
    """Production default — the selection gz_allreduce(algo="auto") ran.

    Algorithm from the fused-hop chunked cost model; ring pipeline depth
    from ``best_pipeline_chunks`` capped by whole-tile fill.  An explicit
    requested algo or depth is always honored; ``requested_chunks == 0``
    asks for the planned ring depth even under an explicit ring (the
    grad-sync routing convention).
    """
    if req.op != "allreduce":
        return _data_movement_plan(req)
    algo, chunks = req.requested_algo, req.requested_chunks
    if algo is None:
        algo, _ = select_allreduce_plan(
            req.nbytes, req.axis_size, req.ratio, req.hw,
            fused_hop=req.fused_hop,
        )
        if algo == "ring" and chunks in (0, 1):
            chunks = _ring_depth(req)
    elif algo == "ring" and chunks == 0:
        chunks = _ring_depth(req)
    return algo, max(chunks, 1)


def _policy_paper(req: PlanRequest):
    """The paper's §3.3.3 crossover: two-kernel cost models, sequential
    schedule — what the published figures compare.  Sequential applies to
    every op: unlike the other policies, an auto-depth request
    (``requested_chunks == 0``) does NOT resolve a pipelined scatter."""
    if req.op != "allreduce":
        return _OP_ALGO[req.op], max(req.requested_chunks, 1)
    algo = req.requested_algo
    if algo is None:
        algo = select_allreduce(req.nbytes, req.axis_size, req.ratio, req.hw)
    return algo, max(req.requested_chunks, 1)


def _policy_throughput(req: PlanRequest):
    """Fastest modeled plan, beyond-paper algorithms allowed.

    Same explicit-knob contract as ``auto``: a requested algorithm or
    depth is honored verbatim; only ``requested_chunks == 0`` (or an
    auto-resolved ring at the default depth) triggers depth planning.
    """
    if req.op != "allreduce":
        return _data_movement_plan(req)
    algo, chunks = req.requested_algo, req.requested_chunks
    if algo is None:
        algo, _ = select_allreduce_plan(
            req.nbytes, req.axis_size, req.ratio, req.hw,
            allow_beyond_paper=True, fused_hop=req.fused_hop,
        )
        if algo == "ring" and chunks in (0, 1):
            chunks = _ring_depth(req)
    elif algo == "ring" and chunks == 0:
        chunks = _ring_depth(req)
    return algo, max(chunks, 1)


def _policy_accuracy(req: PlanRequest):
    """Bitwise rank-consistent integer ring: one quantization grid, no
    stacked requantization noise (core/collectives.py consistency note)."""
    if req.op != "allreduce":
        return _data_movement_plan(req)
    return req.requested_algo or "intring", max(req.requested_chunks, 1)


register_policy("auto", _policy_auto)
register_policy("paper", _policy_paper)
register_policy("throughput", _policy_throughput)
register_policy("accuracy", _policy_accuracy)


# ---------------------------------------------------------------------------
# Memoized plan resolution
# ---------------------------------------------------------------------------

_PLAN_CACHE: dict = {}
_PLAN_STATS = {"hits": 0, "misses": 0}
# Per-codec-key hit/miss counters ("auto" is its own bucket: the REQUESTED
# codec is the cache identity; the resolved one lives on the Plan).
_PLAN_STATS_BY_CODEC: dict = {}


def _codec_stat(codec: str, field: str) -> None:
    rec = _PLAN_STATS_BY_CODEC.setdefault(codec, {"hits": 0, "misses": 0})
    rec[field] += 1


# Per-op hit/miss counters (op is key[0] of both caches).  The bucketed
# grad sync resolves one plan per (op, bucket shape) and re-hits it every
# step — by_op is how tests pin "K buckets -> K allreduce entries, all
# later traces pure hits" without parsing raw key tuples (ISSUE 9).
_PLAN_STATS_BY_OP: dict = {}


def _op_stat(op: str, field: str) -> None:
    rec = _PLAN_STATS_BY_OP.setdefault(op, {"hits": 0, "misses": 0})
    rec[field] += 1


def plan_cache_stats() -> dict:
    """{'hits', 'misses', 'entries', 'keys', 'by_codec', ...} —
    observability for tests and the acceptance criterion "exactly one
    cache entry per distinct (op, nbytes, dtype, axis_size, eb, codec)".

    ``by_codec`` breaks hits/misses AND entry counts (both the flat and
    the hier plan cache — the codec is the last key component of each)
    down by the requested codec key, so a test can pin
    one-entry-per-(op, codec) without parsing raw key tuples.

    ``by_op`` is the same breakdown keyed on the op (key[0] of both
    caches) — the bucketed grad sync's cache-growth contract ("one entry
    per bucket shape, every later step a hit") reads directly off it.
    """
    by_codec = {}
    for c, rec in _PLAN_STATS_BY_CODEC.items():
        by_codec[c] = {
            "hits": rec["hits"],
            "misses": rec["misses"],
            "entries": sum(1 for k in _PLAN_CACHE if k[-1] == c),
            "hier_entries": sum(1 for k in _HIER_PLAN_CACHE if k[-1] == c),
        }
    by_op = {}
    for o, rec in _PLAN_STATS_BY_OP.items():
        by_op[o] = {
            "hits": rec["hits"],
            "misses": rec["misses"],
            "entries": sum(1 for k in _PLAN_CACHE if k[0] == o),
            "hier_entries": sum(1 for k in _HIER_PLAN_CACHE if k[0] == o),
        }
    return {
        "hits": _PLAN_STATS["hits"],
        "misses": _PLAN_STATS["misses"],
        "entries": len(_PLAN_CACHE),
        "keys": tuple(_PLAN_CACHE),
        "hier_entries": len(_HIER_PLAN_CACHE),
        "hier_keys": tuple(_HIER_PLAN_CACHE),
        "by_codec": by_codec,
        "by_op": by_op,
    }


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _HIER_PLAN_CACHE.clear()
    _COMM_CACHE.clear()  # the memoized one-shot communicators, too
    _PLAN_STATS["hits"] = 0
    _PLAN_STATS["misses"] = 0
    _PLAN_STATS_BY_CODEC.clear()
    _PLAN_STATS_BY_OP.clear()


def _codec_adjusted(codec, ratio, hw):
    """(effective_ratio, adjusted_hw, codec_fused_hop) for pricing a codec.

    Calibrated per-codec terms on the Hardware (``hw.terms_for``, fitted
    by :func:`fit_codec_terms`) win over the registry's modeled defaults.
    Identity terms short-circuit to the caller's own (ratio, hw) — the
    default ``lorenzo`` entry ships identity terms, so an uncalibrated
    default plan prices bit-for-bit as it did before the registry.
    """
    spec = codecs.get_codec(codec)
    terms = hw.terms_for(codec) or spec.terms
    if terms == cost_model.CodecTerms(codec):
        return ratio, hw, spec.fused_hop
    return terms.effective_ratio(ratio), terms.apply(hw), spec.fused_hop


def _op_model_time(op, algo, nbytes, n, ratio, hw, chunks, fused_hop):
    """Modeled seconds of one collective under (algo, ratio, hw) — the
    per-op comparator ``codec='auto'`` ranks candidates with.  Allreduce
    and the modeled data movers use the cost model's own functions; the
    remaining ops are priced from the primitive compress/net/decompress
    terms (coarse, but the comparison only needs to order codecs whose
    ratio and throughput terms differ)."""
    if n <= 1:
        return 0.0
    if op == "allreduce":
        return _allreduce_model_time(algo, nbytes, n, ratio, hw, chunks,
                                     fused_hop)
    if op == "scatter":
        return cost_model.scatter_binomial_gz_chunked(
            nbytes, n, ratio, hw, max(chunks, 1)
        )
    if op == "allgather":
        return cost_model.allgather_ring_gz(nbytes, n, ratio, hw)
    if op == "broadcast":
        steps = cost_model.steps_for("binomial", n)
        return (cost_model.t_compress(nbytes, hw)
                + steps * cost_model.t_net(nbytes / ratio, hw)
                + cost_model.t_decompress(nbytes, hw))
    chunk = nbytes / n
    if op == "reduce_scatter":
        return (n - 1) * (cost_model.t_compress(chunk, hw)
                          + cost_model.t_net(chunk / ratio, hw)
                          + cost_model.t_decompress(chunk, hw))
    # all_to_all: compress/decompress the whole payload, n exchange lanes.
    return (cost_model.t_compress(nbytes, hw)
            + n * cost_model.t_net(chunk / ratio, hw)
            + cost_model.t_decompress(nbytes, hw))


# Policies that rank algorithms by modeled time — the only ones where
# ranking CODECS by the same model is meaningful (paper reproduces the
# published selector; accuracy pins the integer ring).
_CODEC_AUTO_POLICIES = ("auto", "throughput")


def _resolve_codec(op, policy, policy_fn, req, codec):
    """(codec, algo, chunks, codec_ratio, fused_hop, notes) — one place
    owns every codec-resolution rule so ``_resolve_plan`` stays linear:

      * explicit codec: price the policy under its adjusted (ratio, hw);
      * ``auto`` under an auto/throughput policy: run the policy per
        candidate and argmin the per-op modeled time;
      * ``auto`` under other policies: default codec, with a note;
      * ``intring`` ships its own integer wire format: codec forced back
        to ``lorenzo`` (noted);
      * codecs without a fused hop kernel downgrade ``fused_hop`` (noted).
    """
    notes = []
    if codec == codecs.AUTO:
        if policy in _CODEC_AUTO_POLICIES:
            best = None
            for cand in codecs.auto_codecs():
                eff_ratio, hw_c, cand_fh = _codec_adjusted(
                    cand, req.ratio, req.hw
                )
                fh = req.fused_hop and cand_fh
                req_c = dataclasses.replace(
                    req, fused_hop=fh, ratio=eff_ratio, hw=hw_c
                )
                algo_c, chunks_c = policy_fn(req_c)
                t = _op_model_time(
                    op, algo_c, req.nbytes, req.axis_size, eff_ratio, hw_c,
                    chunks_c, fh,
                )
                if best is None or t < best[0]:
                    best = (t, cand, algo_c, chunks_c, eff_ratio)
            _, codec, algo, chunks, codec_ratio = best
            notes.append(
                f"codec auto->{codec!r} (fastest modeled {op} of "
                f"{codecs.auto_codecs()})"
            )
        else:
            codec = "lorenzo"
            notes.append(
                f"codec auto->'lorenzo' (policy {policy!r} does not rank "
                "codecs by modeled time)"
            )
            codec_ratio, hw_c, cand_fh = _codec_adjusted(
                codec, req.ratio, req.hw
            )
            req_c = dataclasses.replace(
                req, fused_hop=req.fused_hop and cand_fh, ratio=codec_ratio,
                hw=hw_c,
            )
            algo, chunks = policy_fn(req_c)
    else:
        codec_ratio, hw_c, cand_fh = _codec_adjusted(codec, req.ratio, req.hw)
        req_c = dataclasses.replace(
            req, fused_hop=req.fused_hop and cand_fh, ratio=codec_ratio,
            hw=hw_c,
        )
        algo, chunks = policy_fn(req_c)
    if algo == "intring" and codec != "lorenzo":
        notes.append(
            f"codec {codec!r}->'lorenzo' (intring ships its own integer "
            "wire format)"
        )
        codec = "lorenzo"
        codec_ratio, _, _ = _codec_adjusted(codec, req.ratio, req.hw)
    spec = codecs.get_codec(codec)
    fused_hop = req.fused_hop and spec.fused_hop
    if req.fused_hop and not spec.fused_hop:
        notes.append(
            f"fused_hop off (codec {codec!r} has no fused "
            "unpack+reduce+repack kernel; hops run the two-pass "
            "composition)"
        )
    return codec, algo, max(chunks, 1), codec_ratio, fused_hop, tuple(notes)


def _resolve_plan(
    op, n_elems, dtype, axis_size, eb, *, policy, requested_algo,
    requested_chunks, capacity_factor, worst_case_budget, fused, fused_hop,
    ratio, hw, on_overflow="flag", verify_streams=False, codec="lorenzo",
) -> Plan:
    key = (
        # The canonical identity of a plan...
        op, n_elems * 4, str(dtype), axis_size, eb,
        # ...plus the communicator knobs that parameterize resolution.
        policy, requested_algo, requested_chunks, capacity_factor,
        worst_case_budget, fused, fused_hop, ratio, hw,
        on_overflow, verify_streams,
        # The codec is appended LAST: existing tests pin key prefixes, and
        # plan_cache_stats' by_codec breakdown reads key[-1].
        codec,
    )
    hit = _PLAN_CACHE.get(key)
    if hit is not None:
        _PLAN_STATS["hits"] += 1
        _codec_stat(codec, "hits")
        _op_stat(op, "hits")
        return hit
    _PLAN_STATS["misses"] += 1
    _codec_stat(codec, "misses")
    _op_stat(op, "misses")
    if op not in OPS:
        raise ValueError(f"unknown collective op {op!r}")
    try:
        policy_fn = _POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown policy {policy!r}; registered: {policy_names()}"
        ) from None
    req = PlanRequest(
        op=op, n_elems=n_elems, nbytes=n_elems * 4, axis_size=axis_size,
        requested_algo=requested_algo, requested_chunks=requested_chunks,
        fused_hop=fused_hop, ratio=ratio, hw=hw,
    )
    codec, algo, chunks, codec_ratio, fused_hop, notes = _resolve_codec(
        op, policy, policy_fn, req, codec
    )
    cap, wire, raw = _wire_accounting(
        op, algo, n_elems, axis_size, capacity_factor, chunks, codec
    )
    plan = Plan(
        op=op, algo=algo, n_elems=n_elems, nbytes=n_elems * 4,
        dtype=str(dtype), axis_size=axis_size, eb=eb,
        eb_stage=_eb_stage(op, algo, eb, axis_size, worst_case_budget),
        pipeline_chunks=chunks, fused=fused, fused_hop=fused_hop,
        capacity_factor=capacity_factor, worst_case_budget=worst_case_budget,
        capacity_words=cap, wire_bytes=wire,
        ratio=(raw / wire) if wire else 1.0, policy=policy,
        slab_table=(cost_model.binomial_slab_table(axis_size)
                    if algo == "binomial" else ()),
        on_overflow=on_overflow, verify_streams=verify_streams,
        fallback=_fallback_plan(op, n_elems, axis_size, hw),
        codec=codec, codec_ratio=codec_ratio, notes=notes,
        route_table=(schedule.build(op, algo, axis_size)
                     if axis_size >= 2 else None),
    )
    _PLAN_CACHE[key] = plan
    return plan


# ---------------------------------------------------------------------------
# Two-level (node × intra-node) plan resolution
# ---------------------------------------------------------------------------

_HIER_PLAN_CACHE: dict = {}


def _allreduce_model_time(algo, nbytes, n, ratio, hw, chunks, fused_hop):
    """Modeled seconds of one single-axis compressed allreduce — the same
    cost functions the policies rank, evaluated for a resolved plan."""
    if n <= 1:
        return 0.0
    if algo == "redoub":
        return cost_model.allreduce_redoub_gz(
            nbytes, n, ratio, hw, fused_hop=fused_hop
        )
    if algo == "intring":
        return cost_model.allreduce_intring_gz(nbytes, n, ratio, hw)
    return cost_model.allreduce_ring_gz_chunked(
        nbytes, n, ratio, hw, chunks, fused_hop=fused_hop
    )


def _resolve_hier_plan(
    op, n_elems, dtype, topology, eb, *, policy, requested_algo,
    requested_chunks, capacity_factor, worst_case_budget, fused, fused_hop,
    ratio, hw, on_overflow="flag", verify_streams=False, codec="lorenzo",
) -> HierPlan:
    """Resolve the frozen two-level plan for ``topology = (n_nodes, L)``.

    The cache keys on the FULL topology tuple: the same composite axis
    names over a reshaped mesh (2×4 vs 4×2) resolve different schedules —
    different shard sizes, different inter fan-out — so they must replan
    (the PR 3 multi-mesh lesson, extended to 2D).

    Resolution rule:

      * ``L == 1`` (one rank per node) or no link asymmetry
        (``hw.link_asymmetry() <= 1``): FLAT — there is no fast link to
        exploit, and running the composite-axis single-axis schedule
        keeps the result bitwise-identical to the pre-hierarchy path (the
        degenerate-topology property tests pin exactly this).
      * Otherwise compare modeled times: the flat compressed allreduce
        over N ranks (every link priced at the inter terms — a flat plan
        is topology-blind, and its node-boundary ranks really do cross on
        every send in node-major order) vs
        ``cost_model.allreduce_hier_gz``.  The policy picks the inter
        stage's algorithm/depth by resolving an ordinary sub-plan at the
        shard size over ``n_nodes`` ranks.
    """
    topology = (int(topology[0]), int(topology[1]))
    key = (
        op, n_elems * 4, str(dtype), topology, eb,
        policy, requested_algo, requested_chunks, capacity_factor,
        worst_case_budget, fused, fused_hop, ratio, hw,
        on_overflow, verify_streams,
        codec,  # appended LAST, like the flat cache (by_codec reads k[-1])
    )
    hit = _HIER_PLAN_CACHE.get(key)
    if hit is not None:
        _PLAN_STATS["hits"] += 1
        _codec_stat(codec, "hits")
        _op_stat(op, "hits")
        return hit
    _PLAN_STATS["misses"] += 1
    _codec_stat(codec, "misses")
    _op_stat(op, "misses")
    if op != "allreduce":
        raise ValueError(
            f"hierarchical plans support op='allreduce' only; got {op!r}"
        )
    n_nodes, L = topology
    N = n_nodes * L
    nbytes = n_elems * 4
    knobs = dict(
        policy=policy, requested_algo=requested_algo,
        requested_chunks=requested_chunks, capacity_factor=capacity_factor,
        worst_case_budget=worst_case_budget, fused=fused,
        fused_hop=fused_hop, ratio=ratio, hw=hw,
        on_overflow=on_overflow, verify_streams=verify_streams,
        codec=codec,
    )
    flat_plan = _resolve_plan(op, n_elems, dtype, N, eb, **knobs)
    # Price the flat-vs-hier comparison at the RESOLVED codec's terms
    # (identity for the default, so the pre-registry comparison is
    # bit-for-bit unchanged).
    flat_ratio, flat_hw, _ = _codec_adjusted(flat_plan.codec, ratio, hw)
    t_flat = _allreduce_model_time(
        flat_plan.algo, nbytes, N, flat_ratio, flat_hw,
        flat_plan.pipeline_chunks, flat_plan.fused_hop,
    )

    inter = None
    t_hier = float("inf")
    shard_elems = -(-n_elems // L)
    if L > 1 and hw.link_asymmetry() > 1.0:
        # Only the inter-node stage is lossy; the exact intra stages get 0.
        eb_inter = error_budget.split_lossy(
            eb, (False, n_nodes > 1, False)
        )[1]
        if n_nodes > 1:
            inter = _resolve_plan(
                op, shard_elems, dtype, n_nodes, eb_inter, **knobs
            )
        inter_ratio, inter_hw, _ = _codec_adjusted(
            inter.codec if inter else "lorenzo", ratio, hw
        )
        t_hier = cost_model.allreduce_hier_gz(
            nbytes, n_nodes, L, inter_ratio, inter_hw,
            inter_algo=inter.algo if inter else "ring",
            chunks=inter.pipeline_chunks if inter else 1,
            fused_hop=inter.fused_hop if inter else fused_hop,
        )

    flat = t_flat <= t_hier
    if flat:
        inter = None
        intra_wire = 0
        inter_wire = flat_plan.wire_bytes  # boundary rank: every send crosses
        t_model = t_flat
    else:
        intra_wire = 2 * (L - 1) * shard_elems * 4
        inter_wire = inter.wire_bytes if inter else 0
        t_model = t_hier
    route = (flat_plan.route_table if flat else schedule.build_hier(
        n_nodes, L, inter.algo if inter else "ring"))
    plan = HierPlan(
        op=op, topology=topology, n_elems=n_elems, nbytes=nbytes,
        dtype=str(dtype), eb=eb, flat=flat,
        inter=inter, flat_plan=flat_plan,
        intra_wire_bytes=0 if flat else intra_wire,
        inter_wire_bytes=inter_wire, t_model=t_model, t_flat=t_flat,
        policy=policy,
        on_overflow=on_overflow, verify_streams=verify_streams,
        fallback=_fallback_plan(op, n_elems, N, hw),
        codec=(flat_plan.codec if flat
               else (inter.codec if inter else "lorenzo")),
        route_table=route,
    )
    _HIER_PLAN_CACHE[key] = plan
    return plan


# ---------------------------------------------------------------------------
# Differentiable all-to-all on a frozen plan
# ---------------------------------------------------------------------------
#
# The rank-exchange layout is self-inverse (chunk r of rank p lands at rank
# r, slot p), so the transpose is the same exchange applied to the
# cotangent — compressed too, straight-through the quantizer.  The Plan is
# hashable, hence a valid nondiff argument.


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _a2a_planned(x, axis_name, plan: Plan):
    from repro.core.collectives import _execute_all_to_all

    return _execute_all_to_all(x, axis_name, plan.as_config())


def _a2a_planned_fwd(x, axis_name, plan):
    return _a2a_planned(x, axis_name, plan), None


def _a2a_planned_bwd(axis_name, plan, _, g):
    g_out, _g_ovf = g
    return (_a2a_planned(g_out, axis_name, plan)[0],)


_a2a_planned.defvjp(_a2a_planned_fwd, _a2a_planned_bwd)


# ---------------------------------------------------------------------------
# Health counters (observable outside the trace, like the plan-cache stats)
# ---------------------------------------------------------------------------
#
# Per-(op, axis) counts of calls / overflow events / non-finite events /
# fallback executions, accumulated host-side via jax.debug.callback from
# rank 0 of each collective (once per call, not once per rank).  OFF by
# default: the enable flag is read at TRACE time, so traces built while
# tracking is disabled carry no callback at all (zero overhead), and
# functions jitted under `enable_health_tracking()` keep emitting until
# re-traced.  Call `jax.effects_barrier()` before reading if the enclosing
# computation may still be in flight.

_HEALTH: dict = {}
_HEALTH_ENABLED = False


def enable_health_tracking(enabled: bool = True) -> None:
    """Toggle per-communicator health counters (trace-time gate)."""
    global _HEALTH_ENABLED
    _HEALTH_ENABLED = enabled


def health_stats() -> dict:
    """{(op, axis_repr): {'calls', 'overflow', 'nonfinite', 'fallbacks'}}"""
    return {k: dict(v) for k, v in _HEALTH.items()}


def clear_health_stats() -> None:
    _HEALTH.clear()


def _health_cb(key, is_r0, ovf, nonfinite, fell_back):
    if not bool(is_r0):
        return
    rec = _HEALTH.setdefault(
        key, {"calls": 0, "overflow": 0, "nonfinite": 0, "fallbacks": 0}
    )
    rec["calls"] += 1
    rec["overflow"] += int(bool(ovf))
    rec["nonfinite"] += int(bool(nonfinite))
    rec["fallbacks"] += int(bool(fell_back))


def _emit_health(op, axis_name, overflow, nonfinite, fell_back) -> None:
    if not _HEALTH_ENABLED:
        return
    from repro.core.collectives import _axis_rank

    jax.debug.callback(
        partial(_health_cb, (op, repr(axis_name))),
        _axis_rank(axis_name) == 0, overflow, nonfinite, fell_back,
    )


def _raise_degraded(what, ovf, nonfinite):
    if bool(ovf) or bool(nonfinite):
        raise RuntimeError(
            f"gZ collective degraded ({what}): overflow={bool(ovf)} "
            f"nonfinite={bool(nonfinite)} — a compressed stream exceeded "
            "its provisioned capacity (or failed verification) or the "
            "input held NaN/Inf.  Use on_overflow='fallback' for in-trace "
            "lossless recovery, or 'flag' to only report."
        )


# ---------------------------------------------------------------------------
# The communicator
# ---------------------------------------------------------------------------


class GZCommunicator:
    """Resolve-once communicator bound to one mesh axis.

    Construct OUTSIDE the traced region with the static knobs; call the
    collective methods inside shard_map bodies.  ``axis_size`` may be
    passed explicitly (e.g. from the mesh shape) or left None to be read
    from the surrounding shard_map trace on first use — axis sizes are
    static either way, so plan resolution never touches a tracer.

    ``config`` is the same knob dataclass the legacy wrappers take
    (``GZConfig``): eb, capacity_factor, algo (``"auto"`` delegates to
    the policy), worst_case_budget, pipeline_chunks, fused, fused_hop.
    """

    def __init__(
        self,
        axis_name,
        *,
        config=None,
        policy: str = "auto",
        hw: cost_model.Hardware = cost_model.TPU_V5E,
        ratio: float = 20.0,
        axis_size: Optional[int] = None,
        _auto_depth: bool = False,
    ):
        from repro.core.collectives import GZConfig

        self.axis_name = axis_name
        self.config = config if config is not None else GZConfig()
        if policy not in _POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; registered: {policy_names()}"
            )
        self.policy = policy
        self.hw = hw
        self.ratio = ratio
        self._axis_size = axis_size
        # grad-sync routing convention: ring depth is planned even when the
        # algorithm was requested explicitly (requested_chunks == 0).
        self._auto_depth = _auto_depth

    # -- construction helpers ------------------------------------------------

    @classmethod
    def for_config(cls, axis_name, config, *, policy: str = "auto",
                   hw: cost_model.Hardware = cost_model.TPU_V5E,
                   ratio: float = 20.0, axis_size: Optional[int] = None,
                   auto_depth: bool = False) -> "GZCommunicator":
        """Memoized one-shot communicator — the legacy ``gz_*`` wrappers'
        entry point (one instance per distinct (axis, knobs))."""
        return _communicator_cache(
            cls, axis_name, config, policy, hw, ratio, axis_size, auto_depth
        )

    def calibrate(self, *, sizes=(1 << 16, 1 << 18, 1 << 20), reps: int = 3,
                  interpret: Optional[bool] = None,
                  network: Optional[dict] = None,
                  fit_codecs: bool = True) -> "GZCommunicator":
        """Return a communicator whose cost model is fitted to THIS host.

        Times the actual codec (``measure_codec``) at ``sizes`` elements
        and least-squares-fits the Hardware throughput/overhead terms the
        planner evaluates.  Network terms are kept from the current model
        unless ``network`` supplies measured ppermute timings per link
        class — ``{'inter': [(bytes, seconds), ...], 'intra': [...]}``
        (see :func:`measure_ppermute`) — in which case each named link's
        alpha-beta terms are least-squares-fitted too
        (:func:`fit_network`).

        With ``fit_codecs`` (the default) every registered wire codec is
        additionally timed on the same sample tensors
        (:func:`measure_codecs`) and its measured ratio/throughput written
        into per-codec ``Hardware.codec_terms`` — the terms
        ``codec='auto'`` ranks candidates with, so after calibration the
        auto/throughput policies pick the codec per tensor class from
        MEASURED collective time, not the registry's modeled defaults.
        """
        samples_c, samples_d = measure_codec(
            self.config, sizes=sizes, reps=reps, interpret=interpret
        )
        hw = fit_hardware(samples_c, samples_d, base=self.hw)
        for link, samples in (network or {}).items():
            hw = fit_network(samples, base=hw, link=link)
        if fit_codecs:
            hw = fit_codec_terms(
                measure_codecs(self.config, sizes=sizes, reps=reps), base=hw
            )
        return GZCommunicator(
            self.axis_name, config=self.config, policy=self.policy, hw=hw,
            ratio=self.ratio, axis_size=self._axis_size,
            _auto_depth=self._auto_depth,
        )

    # -- plan resolution -----------------------------------------------------

    def axis_size(self) -> int:
        """Static axis size: the bound value, or — when constructed with
        ``axis_size=None`` — the size read fresh from the surrounding
        shard_map trace at every call.  Never cached on the instance: a
        memoized ``for_config`` communicator outlives any one mesh, and
        the same axis name can be bound to different sizes across traces
        in one process."""
        if self._axis_size is not None:
            return self._axis_size
        from repro.core.collectives import _axis_size

        return int(_axis_size(self.axis_name))

    def plan(self, op: str, shape, dtype=jnp.float32) -> Plan:
        """Resolve the frozen Plan for ``op`` over a payload of ``shape``.

        ``shape`` is a shape tuple or an element count; resolution is a
        cache lookup after the first call with a given key (see
        :func:`plan_cache_stats`).
        """
        n_elems = int(np.prod(shape)) if not isinstance(shape, int) else shape
        cfg = self.config
        requested_algo = None if cfg.algo == "auto" else cfg.algo
        requested_chunks = cfg.pipeline_chunks
        if self._auto_depth and requested_chunks == 1:
            requested_chunks = 0
        return _resolve_plan(
            op, n_elems, jnp.dtype(dtype).name, self.axis_size(), cfg.eb,
            policy=self.policy, requested_algo=requested_algo,
            requested_chunks=requested_chunks,
            capacity_factor=cfg.capacity_factor,
            worst_case_budget=cfg.worst_case_budget, fused=cfg.fused,
            fused_hop=cfg.fused_hop, ratio=self.ratio, hw=self.hw,
            on_overflow=cfg.on_overflow, verify_streams=cfg.verify_streams,
            codec=cfg.codec,
        )

    # -- collectives ---------------------------------------------------------

    def _trivial(self, x) -> CollectiveResult:
        zero = jnp.zeros((), jnp.bool_)
        return CollectiveResult(x, zero, zero, 0, 1.0)

    def _finish(self, op, x, out, ovf, plan: Plan, *,
                root: int = 0) -> CollectiveResult:
        """Shared epilogue: global-OR the health bits, apply the plan's
        degradation policy (DESIGN.md §9), emit health counters.

        ``x`` is the (possibly poisoned) input the compressed schedule
        consumed — the fallback branch re-executes the LOSSLESS schedule
        over exactly that payload inside ``lax.cond`` (the predicate is
        psum-derived, hence replicated and cond-safe), so the recovered
        result is bitwise the uncompressed collective of the sanitized
        input.
        """
        from repro.core.collectives import (
            _axis_rank, _execute_lossless, _flags_across, _nonfinite_local,
        )

        nf_loc = _nonfinite_local(x)
        if op in ("scatter", "broadcast"):
            # Only the root's payload is significant; non-root junk must
            # not trip the non-finite guard.
            nf_loc &= _axis_rank(self.axis_name) == root
        overflow, nonfinite = _flags_across(ovf, nf_loc, self.axis_name)
        degraded = overflow | nonfinite
        fell_back = jnp.zeros((), jnp.bool_)
        if plan.on_overflow == "fallback":
            cfg = plan.as_config()
            out = lax.cond(
                degraded,
                lambda: _execute_lossless(
                    op, x, self.axis_name, cfg, root=root
                ),
                lambda: out,
            )
            fell_back = degraded
        elif plan.on_overflow == "raise":
            jax.debug.callback(
                partial(_raise_degraded, f"{op} over {self.axis_name!r}"),
                overflow, nonfinite,
            )
        _emit_health(op, self.axis_name, overflow, nonfinite, fell_back)
        return CollectiveResult(
            out, overflow, nonfinite, plan.wire_bytes, plan.ratio
        )

    def allreduce(self, x, *, plan: Optional[Plan] = None) -> CollectiveResult:
        """Compressed sum-allreduce of ``x`` over the bound axis."""
        if self.axis_size() == 1:
            return self._trivial(x)
        x = faults.maybe_poison_input(x, self.axis_name)
        plan = plan or self.plan("allreduce", x.shape, x.dtype)
        from repro.core.collectives import _execute_allreduce

        out, ovf = _execute_allreduce(x, self.axis_name, plan.as_config())
        return self._finish("allreduce", x, out, ovf, plan)

    def reduce_scatter(self, x, *, plan: Optional[Plan] = None) -> CollectiveResult:
        """Ring reduce-scatter: rank r returns summed chunk r (flat view)."""
        if self.axis_size() == 1:
            return self._trivial(x)
        x = faults.maybe_poison_input(x, self.axis_name)
        plan = plan or self.plan("reduce_scatter", x.shape, x.dtype)
        from repro.core.collectives import _execute_reduce_scatter

        out, ovf = _execute_reduce_scatter(x, self.axis_name, plan.as_config())
        return self._finish("reduce_scatter", x, out, ovf, plan)

    def allgather(self, x, *, plan: Optional[Plan] = None) -> CollectiveResult:
        """Ring allgather: compress once, forward compressed N-1 times."""
        if self.axis_size() == 1:
            return self._trivial(x)
        x = faults.maybe_poison_input(x, self.axis_name)
        plan = plan or self.plan("allgather", x.shape, x.dtype)
        from repro.core.collectives import _execute_allgather

        out, ovf = _execute_allgather(x, self.axis_name, plan.as_config())
        return self._finish("allgather", x, out, ovf, plan)

    def scatter(self, x_full, *, root: int = 0,
                plan: Optional[Plan] = None) -> CollectiveResult:
        """Binomial-tree compressed scatter from ``root`` (root 0 only)."""
        if self.axis_size() == 1:
            return self._trivial(x_full)
        x_full = faults.maybe_poison_input(x_full, self.axis_name)
        plan = plan or self.plan("scatter", x_full.shape, x_full.dtype)
        from repro.core.collectives import _execute_scatter

        out, ovf = _execute_scatter(
            x_full, self.axis_name, plan.as_config(), root=root
        )
        return self._finish("scatter", x_full, out, ovf, plan, root=root)

    def broadcast(self, x, *, root: int = 0,
                  plan: Optional[Plan] = None) -> CollectiveResult:
        """Binomial-tree broadcast: compress once at root."""
        if self.axis_size() == 1:
            return self._trivial(x)
        x = faults.maybe_poison_input(x, self.axis_name)
        plan = plan or self.plan("broadcast", x.shape, x.dtype)
        from repro.core.collectives import _execute_broadcast

        out, ovf = _execute_broadcast(
            x, self.axis_name, plan.as_config(), root=root
        )
        return self._finish("broadcast", x, out, ovf, plan, root=root)

    def all_to_all(self, x, *, plan: Optional[Plan] = None) -> CollectiveResult:
        """Compressed rank-exchange; differentiable (straight-through the
        quantizer, compressed cotangent — see ``_a2a_planned``)."""
        if self.axis_size() == 1:
            return self._trivial(x)
        x = faults.maybe_poison_input(x, self.axis_name)
        plan = plan or self.plan("all_to_all", x.shape, x.dtype)
        out, ovf = _a2a_planned(x, self.axis_name, plan)
        return self._finish("all_to_all", x, out, ovf, plan)

    def __repr__(self):
        return (
            f"GZCommunicator(axis={self.axis_name!r}, n={self._axis_size}, "
            f"policy={self.policy!r}, eb={self.config.eb}, hw={self.hw.name})"
        )


class GZHierCommunicator:
    """Resolve-once communicator bound to a two-level ``node × local``
    topology (DESIGN.md §8).

    ``node_axis`` is the slow (inter-node fabric) mesh axis; ``local_axis``
    is the fast intra-node axis — or a TUPLE of axes, all collapsed into
    "local" (grad-sync folds every non-node data-parallel axis in).
    ``topology`` may be passed explicitly as ``(n_nodes, gpus_per_node)``
    or left None to be read from the surrounding shard_map trace per call
    (sizes are static either way).

    ``allreduce`` dispatches on a frozen :class:`HierPlan`: per-link cost
    comparison decides flat vs hierarchical, the policy picks the inter
    stage's algorithm/compression depth, and the execute layer
    (``collectives._execute_allreduce_hier``) contains zero selector
    logic.  ``CollectiveResult.wire_bytes`` reports the INTER-NODE wire —
    the scarce resource this communicator exists to spend well.
    """

    def __init__(
        self,
        node_axis,
        local_axis,
        *,
        config=None,
        policy: str = "auto",
        hw: cost_model.Hardware = cost_model.TPU_V5E,
        ratio: float = 20.0,
        topology: Optional[tuple] = None,
        _auto_depth: bool = False,
    ):
        from repro.core.collectives import GZConfig

        self.node_axis = node_axis
        self.local_axis = (
            tuple(local_axis) if isinstance(local_axis, (tuple, list))
            else local_axis
        )
        self.config = config if config is not None else GZConfig()
        if policy not in _POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; registered: {policy_names()}"
            )
        self.policy = policy
        self.hw = hw
        self.ratio = ratio
        self._topology = tuple(topology) if topology is not None else None
        self._auto_depth = _auto_depth

    @classmethod
    def for_axes(cls, node_axis, local_axis, *, config=None,
                 policy: str = "auto",
                 hw: cost_model.Hardware = cost_model.TPU_V5E,
                 ratio: float = 20.0, topology: Optional[tuple] = None,
                 auto_depth: bool = False) -> "GZHierCommunicator":
        """Memoized one-shot hier communicator (one instance per distinct
        (axes, knobs) — cleared with :func:`clear_plan_cache`)."""
        local = (tuple(local_axis) if isinstance(local_axis, (tuple, list))
                 else local_axis)
        topo = tuple(topology) if topology is not None else None
        key = (cls, node_axis, local, config, policy, hw, ratio, topo,
               auto_depth)
        comm = _COMM_CACHE.get(key)
        if comm is None:
            comm = cls(
                node_axis, local, config=config, policy=policy, hw=hw,
                ratio=ratio, topology=topo, _auto_depth=auto_depth,
            )
            _COMM_CACHE[key] = comm
        return comm

    def topology(self) -> tuple:
        """Static ``(n_nodes, gpus_per_node)``: the bound tuple, or the
        sizes read fresh from the surrounding shard_map trace (never
        cached on the instance — a memoized communicator outlives any one
        mesh, and the same axis names can be bound to different shapes
        across traces: the 2×4-vs-4×2 replan case)."""
        if self._topology is not None:
            return self._topology
        from repro.core.collectives import _axis_size

        return (int(_axis_size(self.node_axis)),
                int(_axis_size(self.local_axis)))

    def _composite_axes(self) -> tuple:
        local = (self.local_axis if isinstance(self.local_axis, tuple)
                 else (self.local_axis,))
        return (self.node_axis,) + local

    def plan(self, shape, dtype=jnp.float32) -> HierPlan:
        """Resolve the frozen :class:`HierPlan` for an allreduce of
        ``shape`` over the bound topology (memoized on the full topology
        tuple plus the knob set)."""
        n_elems = int(np.prod(shape)) if not isinstance(shape, int) else shape
        cfg = self.config
        requested_algo = None if cfg.algo == "auto" else cfg.algo
        requested_chunks = cfg.pipeline_chunks
        if self._auto_depth and requested_chunks == 1:
            requested_chunks = 0
        return _resolve_hier_plan(
            "allreduce", n_elems, jnp.dtype(dtype).name, self.topology(),
            cfg.eb, policy=self.policy, requested_algo=requested_algo,
            requested_chunks=requested_chunks,
            capacity_factor=cfg.capacity_factor,
            worst_case_budget=cfg.worst_case_budget, fused=cfg.fused,
            fused_hop=cfg.fused_hop, ratio=self.ratio, hw=self.hw,
            on_overflow=cfg.on_overflow, verify_streams=cfg.verify_streams,
            codec=cfg.codec,
        )

    def allreduce(self, x, *, plan: Optional[HierPlan] = None) -> CollectiveResult:
        """Two-level compressed sum-allreduce over ``node × local``."""
        n_nodes, L = self.topology()
        if n_nodes * L == 1:
            zero = jnp.zeros((), jnp.bool_)
            return CollectiveResult(x, zero, zero, 0, 1.0)
        axes = self._composite_axes()
        x = faults.maybe_poison_input(x, axes)
        hplan = plan or self.plan(x.shape, x.dtype)
        from repro.core.collectives import (
            _execute_allreduce_hier, _execute_lossless, _flags_across,
            _nonfinite_local,
        )

        out, ovf = _execute_allreduce_hier(
            x, self.node_axis, self.local_axis, hplan
        )
        overflow, nonfinite = _flags_across(ovf, _nonfinite_local(x), axes)
        degraded = overflow | nonfinite
        fell_back = jnp.zeros((), jnp.bool_)
        if hplan.on_overflow == "fallback":
            # The lossless twin of either branch (flat or hierarchical) is
            # the exact psum over the composite axes.
            cfg = (hplan.flat_plan or hplan.inter).as_config()
            out = lax.cond(
                degraded,
                lambda: _execute_lossless("allreduce", x, axes, cfg),
                lambda: out,
            )
            fell_back = degraded
        elif hplan.on_overflow == "raise":
            jax.debug.callback(
                partial(_raise_degraded, f"allreduce over {axes!r}"),
                overflow, nonfinite,
            )
        _emit_health("allreduce", axes, overflow, nonfinite, fell_back)
        return CollectiveResult(
            out, overflow, nonfinite,
            hplan.inter_wire_bytes, hplan.ratio,
        )

    def calibrate(self, *, sizes=(1 << 16, 1 << 18, 1 << 20), reps: int = 3,
                  network: Optional[dict] = None,
                  fit_codecs: bool = True) -> "GZHierCommunicator":
        """Codec-fitted (and optionally network-fitted) communicator: like
        ``GZCommunicator.calibrate`` plus per-link-class network terms via
        ``network={'inter': samples, 'intra': samples}`` (measured
        ``(bytes, seconds)`` ppermute timings, e.g. from
        :func:`measure_ppermute` over each axis)."""
        samples_c, samples_d = measure_codec(
            self.config, sizes=sizes, reps=reps
        )
        hw = fit_hardware(samples_c, samples_d, base=self.hw)
        for link, samples in (network or {}).items():
            hw = fit_network(samples, base=hw, link=link)
        if fit_codecs:
            hw = fit_codec_terms(
                measure_codecs(self.config, sizes=sizes, reps=reps), base=hw
            )
        return GZHierCommunicator(
            self.node_axis, self.local_axis, config=self.config,
            policy=self.policy, hw=hw, ratio=self.ratio,
            topology=self._topology, _auto_depth=self._auto_depth,
        )

    def __repr__(self):
        return (
            f"GZHierCommunicator(node={self.node_axis!r}, "
            f"local={self.local_axis!r}, topology={self._topology}, "
            f"policy={self.policy!r}, eb={self.config.eb}, hw={self.hw.name})"
        )


def _communicator_cache(cls, axis_name, config, policy, hw, ratio, axis_size,
                        auto_depth):
    key = (cls, axis_name, config, policy, hw, ratio, axis_size, auto_depth)
    comm = _COMM_CACHE.get(key)
    if comm is None:
        comm = cls(
            axis_name, config=config, policy=policy, hw=hw, ratio=ratio,
            axis_size=axis_size, _auto_depth=auto_depth,
        )
        _COMM_CACHE[key] = comm
    return comm


_COMM_CACHE: dict = {}


# ---------------------------------------------------------------------------
# Calibration: fit cost_model.Hardware from measured codec timings
# ---------------------------------------------------------------------------
#
# t(size) = overhead + size / (peak * util(size)), util(s) = s/(s+sat)
#         = (overhead + sat_bytes/peak) + size/peak            [linear!]
# so a least-squares line through (size, seconds) gives peak = 1/slope and
# overhead = intercept - sat_bytes/peak, with the saturation knee kept
# from the base model (separating knee from overhead needs sub-knee
# resolution that timing noise on small inputs does not give).


def fit_hardware(samples_compress, samples_decompress=None, *,
                 base: cost_model.Hardware = cost_model.TPU_V5E,
                 name: Optional[str] = None) -> cost_model.Hardware:
    """Fit codec throughput/overhead from ``[(size_bytes, seconds), ...]``.

    Returns a new ``Hardware`` with ``cmp_peak_gbps``/``cmp_overhead_us``
    (and ``dec_peak_gbps`` when decompress samples are given) replaced by
    the fitted values; network/reduce terms are inherited from ``base``.
    """
    def _fit(samples):
        pts = np.asarray(sorted(samples), dtype=np.float64)
        if pts.ndim != 2 or pts.shape[0] < 2:
            raise ValueError("need >= 2 (size_bytes, seconds) samples")
        slope, intercept = np.polyfit(pts[:, 0], pts[:, 1], 1)
        peak = 1.0 / max(slope, 1e-18)  # bytes/s
        sat_bytes = base.cmp_saturation_mb * 1e6
        overhead_s = max(intercept - sat_bytes / peak, 0.0)
        return peak * 8 / 1e9, overhead_s * 1e6  # (gbps, us)

    cmp_gbps, cmp_us = _fit(samples_compress)
    kw = dict(cmp_peak_gbps=cmp_gbps, cmp_overhead_us=cmp_us)
    if samples_decompress:
        dec_gbps, _ = _fit(samples_decompress)
        kw["dec_peak_gbps"] = dec_gbps
    return dataclasses.replace(
        base, name=name or f"{base.name}-calibrated", **kw
    )


def fit_network(samples, *, base: cost_model.Hardware,
                link: str = "inter",
                name: Optional[str] = None) -> cost_model.Hardware:
    """Fit one link class's alpha-beta terms from measured hop timings.

    ``samples`` is ``[(bytes_on_wire, seconds), ...]`` from timed
    ``ppermute`` hops over ONE mesh axis (:func:`measure_ppermute`).  The
    model is the cost model's own ``t = alpha + bytes / bw`` — linear in
    bytes, so a least-squares line gives ``bw = 1/slope`` and
    ``alpha = intercept`` directly (the recovery is exact on noiseless
    samples; tests/test_hier.py pins it).

    ``link='inter'`` replaces ``net_gbps``/``net_alpha_us``;
    ``link='intra'`` replaces ``intra_gbps``/``intra_alpha_us`` — fitting
    the intra class on a flat-fabric base thereby DECLARES the fabric
    two-level (``Hardware.intra_terms`` stops inheriting the inter
    terms).
    """
    pts = np.asarray(sorted(samples), dtype=np.float64)
    if pts.ndim != 2 or pts.shape[0] < 2:
        raise ValueError("need >= 2 (bytes, seconds) samples")
    slope, intercept = np.polyfit(pts[:, 0], pts[:, 1], 1)
    bw = 1.0 / max(slope, 1e-18)  # bytes/s
    gbps = bw * 8 / 1e9
    alpha_us = max(intercept, 0.0) * 1e6
    if link == "inter":
        kw = dict(net_gbps=gbps, net_alpha_us=alpha_us)
    elif link == "intra":
        kw = dict(intra_gbps=gbps, intra_alpha_us=alpha_us)
    else:
        raise ValueError(f"unknown link class {link!r}: 'inter' or 'intra'")
    return dataclasses.replace(
        base, name=name or f"{base.name}-net", **kw
    )


def measure_ppermute(mesh, axis_name, *, sizes=(1 << 14, 1 << 17, 1 << 20),
                     reps: int = 3):
    """Time one ring-shift ``ppermute`` hop over ``axis_name`` of ``mesh``
    at each payload size (f32 elements).  Returns ``[(bytes, seconds),
    ...]`` — feed to :func:`fit_network` per link class (the intra-node
    axis times the fast link, the node axis the fabric).  Min-of-reps
    discipline like ``measure_codec``.  On a single-host mesh the numbers
    measure XLA's copy path, not a real fabric — useful for exercising
    the fitting pipeline, not for production calibration.
    """
    import time

    from jax.sharding import PartitionSpec as P

    from repro.core.shmap import shard_map

    sizes_of = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = sizes_of[axis_name]
    perm = schedule.ring_perm(n)

    samples = []
    for n_elems in sizes:
        def body(x):
            return jax.lax.ppermute(x, axis_name, perm)

        fn = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P(),), out_specs=P(),
        ))
        x = jnp.ones((int(n_elems),), jnp.float32)
        jax.block_until_ready(fn(x))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            best = min(best, time.perf_counter() - t0)
        samples.append((int(n_elems) * 4, best))
    return samples


def measure_codec(config=None, *, sizes=(1 << 16, 1 << 18, 1 << 20),
                  reps: int = 3, interpret: Optional[bool] = None):
    """Time compress/decompress at ``sizes`` elements on this host.

    Returns ``(samples_compress, samples_decompress)`` as
    ``[(size_bytes, seconds), ...]`` — feed to :func:`fit_hardware`.  Uses
    the min-of-reps discipline of benchmarks/benchutil.py (noise only ever
    adds time).  ``interpret`` is accepted for symmetry with the kernel
    entry points; the compressor picks its own mode per backend.
    """
    import time

    from repro.core.collectives import GZConfig

    cfg = config if config is not None else GZConfig()
    if cfg.codec == codecs.AUTO:
        # Only a concrete codec can be timed; the default is the dense
        # reference every auto candidate is compared against anyway.
        cfg = dataclasses.replace(cfg, codec="lorenzo")
    comp = cfg.compressor()
    del interpret  # kernels select interpret mode from the backend

    def _time(fn):
        jax.block_until_ready(fn())
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    samples_c, samples_d = [], []
    for n in sizes:
        x = jnp.asarray(
            np.cumsum(np.random.default_rng(0).normal(0, 0.01, n)),
            jnp.float32,
        )
        compress = jax.jit(lambda v: comp.compress(v, cfg.eb))
        c = compress(x)
        samples_c.append((n * 4, _time(lambda: compress(x))))
        decompress = jax.jit(comp.decompress)
        samples_d.append((n * 4, _time(lambda: decompress(c))))
    return samples_c, samples_d


def measure_codecs(config=None, *, sizes=(1 << 16, 1 << 18, 1 << 20),
                   reps: int = 3, names=None) -> dict:
    """Time EVERY registered wire codec on this host's smooth sample data.

    Returns ``{codec: {'samples_compress': [(bytes, s), ...],
    'samples_decompress': [...], 'ratio': float}}`` — the input of
    :func:`fit_codec_terms`.  ``ratio`` is the measured payload reduction
    (uncompressed bytes over the TRUE stream bytes, ``payload_bytes``) at
    the largest size — the quantity ``benchmarks/codec_bench.py`` records
    and ``codec='auto'`` ranks with after calibration.  Same smooth-tensor
    and min-of-reps discipline as :func:`measure_codec`.
    """
    from repro.core.collectives import GZConfig

    cfg = config if config is not None else GZConfig()
    measured = {}
    for name in (names if names is not None else codecs.codec_names()):
        cfg_c = dataclasses.replace(cfg, codec=name)
        samples_c, samples_d = measure_codec(cfg_c, sizes=sizes, reps=reps)
        comp = cfg_c.compressor()
        n = max(sizes)
        x = jnp.asarray(
            np.cumsum(np.random.default_rng(0).normal(0, 0.01, n)),
            jnp.float32,
        )
        c = jax.jit(lambda v: comp.compress(v, cfg_c.eb))(x)
        payload = float(jax.device_get(c.payload_bytes()))
        measured[name] = {
            "samples_compress": samples_c,
            "samples_decompress": samples_d,
            "ratio": (n * 4) / max(payload, 1.0),
        }
    return measured


def fit_codec_terms(measured: dict, *,
                    base: cost_model.Hardware,
                    name: Optional[str] = None) -> cost_model.Hardware:
    """Fit per-codec ``CodecTerms`` from :func:`measure_codecs` output.

    Each codec gets its measured compress/decompress throughput (the same
    linear fit as :func:`fit_hardware`) and its measured ratio — recorded
    as a SCALE relative to the dense ``lorenzo`` ratio for eb-scaled
    codecs (their achievable ratio tracks the caller's assumed dense
    ratio across tensor classes) and as an absolute ratio for
    data-intrinsic codecs (lossless/passthrough ship the same bytes
    whatever the bound).  Returns a ``Hardware`` whose ``codec_terms``
    the planner's :func:`_codec_adjusted` resolves ahead of the registry
    defaults.
    """
    def _peak_gbps(samples):
        pts = np.asarray(sorted(samples), dtype=np.float64)
        if pts.ndim != 2 or pts.shape[0] < 2:
            return 0.0
        slope, _ = np.polyfit(pts[:, 0], pts[:, 1], 1)
        return (1.0 / max(slope, 1e-18)) * 8 / 1e9

    dense = measured.get("lorenzo", {}).get("ratio", 1.0)
    terms = []
    for codec in sorted(measured):
        m = measured[codec]
        spec = codecs.get_codec(codec)
        kw = dict(
            cmp_peak_gbps=_peak_gbps(m["samples_compress"]),
            dec_peak_gbps=_peak_gbps(m["samples_decompress"]),
        )
        if spec.eb_scaled:
            kw["ratio_scale"] = m["ratio"] / max(dense, 1e-9)
        else:
            kw["ratio_abs"] = max(m["ratio"], 1.0)
        terms.append(cost_model.CodecTerms(codec, **kw))
    return dataclasses.replace(
        base, codec_terms=tuple(terms),
        name=name or f"{base.name}-codecs",
    )
