"""DEPRECATED shim — algorithm selection lives in :mod:`repro.core.comm`.

This module was the paper's §3.3.3 standalone selector.  ISSUE 10 made
the communicator's policy registry the ONLY selection authority: the
cost evaluators moved verbatim to ``comm.select_allreduce`` /
``comm.select_allreduce_plan`` (where the ``auto``/``paper``/
``throughput``/``accuracy`` policies call them), and this module merely
re-exports them with a :class:`DeprecationWarning`.  Import from
``repro.core.comm`` instead; this shim will be removed once nothing
imports it.

The re-exports are thin ``functools.wraps`` wrappers (not bare aliases)
so every CALL warns too — a cached module import would otherwise warn
only once per process.  tests/test_selector_shim.py pins that the shim's
output is bitwise the policy registry's.
"""
from __future__ import annotations

import functools
import warnings

from repro.core import comm as _comm

__all__ = ["select_allreduce", "select_allreduce_plan"]

_MSG = (
    "repro.core.selector is deprecated: algorithm selection is owned by "
    "the repro.core.comm policy registry — import "
    "select_allreduce/select_allreduce_plan from repro.core.comm"
)

warnings.warn(_MSG, DeprecationWarning, stacklevel=2)


def _deprecated(fn):
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        warnings.warn(_MSG, DeprecationWarning, stacklevel=2)
        return fn(*args, **kwargs)

    return wrapper


select_allreduce = _deprecated(_comm.select_allreduce)
select_allreduce_plan = _deprecated(_comm.select_allreduce_plan)
