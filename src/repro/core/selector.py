"""Algorithm auto-selection — the paper's design framework as a policy.

The paper's conclusion (§3.3.3): with GPU compression in the loop, the
classic "ring for large messages" rule inverts once the per-chunk size
D/N falls below the compressor's saturation point; recursive doubling's
log2(N) *saturated* compressions then win despite moving more bytes.

``select_allreduce`` evaluates the calibrated cost model for both
algorithms at the actual (D, N) and picks the cheaper — reproducing the
paper's crossover (ring wins at small N / huge D; ReDoub wins at scale).
A conservative default compression ratio of 20x (paper Table 1 sees
46-94x on RTM data) is used unless the caller passes a measured one.
"""
from __future__ import annotations

from repro.core import cost_model as cm

__all__ = ["select_allreduce", "select_allreduce_plan"]


def select_allreduce(
    d_bytes: int,
    n_ranks: int,
    ratio: float = 20.0,
    hw: cm.Hardware = cm.TPU_V5E,
    *,
    allow_beyond_paper: bool = False,
) -> str:
    """Return 'ring' | 'redoub' (| 'intring' when beyond-paper allowed).

    This is the PAPER's selector: both algorithms are costed under the
    paper's two-kernel multi-stream-overlap models (no fused hop on
    either side — `allreduce_ring_gz` has none, so redoub must not get
    one either or the crossover is biased).  The production planner with
    the fused-hop schedule is :func:`select_allreduce_plan`.
    """
    costs = {
        "ring": cm.allreduce_ring_gz(d_bytes, n_ranks, ratio, hw),
        "redoub": cm.allreduce_redoub_gz(
            d_bytes, n_ranks, ratio, hw, fused_hop=False
        ),
    }
    if allow_beyond_paper:
        costs["intring"] = cm.allreduce_intring_gz(d_bytes, n_ranks, ratio, hw)
    return min(costs, key=costs.get)


def select_allreduce_plan(
    d_bytes: int,
    n_ranks: int,
    ratio: float = 20.0,
    hw: cm.Hardware = cm.TPU_V5E,
    *,
    allow_beyond_paper: bool = False,
    chunk_candidates=cm.PIPELINE_CHUNK_CANDIDATES,
    fused_hop: bool = True,
) -> tuple[str, int]:
    """Pick (algo, pipeline_chunks) from the explicit per-chunk cost model.

    Ring is costed under the chunked double-buffered schedule at its best
    chunk count (DESIGN.md §4): above the compressor saturation size the
    pipelined ring strictly dominates the sequential one, so the plan comes
    back with chunks > 1; below it, per-piece overhead wins and the plan
    degrades to the sequential schedule (chunks == 1).  ReDoub compresses
    full messages — its overlap is already a single long chain, so it takes
    no chunk knob (returned chunks apply to ring only).

    ``fused_hop`` costs BOTH algorithms' hops as single-pass
    ``t_hop_fused`` kernels (one ``cmp_overhead_us`` per hop instead of
    two — the collectives run fused hops for ring and redoub alike), and
    pushes the ring's best chunk count deeper.
    """
    ring_chunks = cm.best_pipeline_chunks(
        d_bytes, n_ranks, ratio, hw, chunk_candidates, fused_hop=fused_hop
    )
    costs = {
        ("ring", ring_chunks): cm.allreduce_ring_gz_chunked(
            d_bytes, n_ranks, ratio, hw, ring_chunks, fused_hop=fused_hop
        ),
        ("redoub", 1): cm.allreduce_redoub_gz(
            d_bytes, n_ranks, ratio, hw, fused_hop=fused_hop
        ),
    }
    if allow_beyond_paper:
        costs[("intring", 1)] = cm.allreduce_intring_gz(
            d_bytes, n_ranks, ratio, hw
        )
    return min(costs, key=costs.get)
