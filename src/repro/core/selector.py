"""Algorithm auto-selection — the paper's design framework as a policy.

The paper's conclusion (§3.3.3): with GPU compression in the loop, the
classic "ring for large messages" rule inverts once the per-chunk size
D/N falls below the compressor's saturation point; recursive doubling's
log2(N) *saturated* compressions then win despite moving more bytes.

``select_allreduce`` evaluates the calibrated cost model for both
algorithms at the actual (D, N) and picks the cheaper — reproducing the
paper's crossover (ring wins at small N / huge D; ReDoub wins at scale).
A conservative default compression ratio of 20x (paper Table 1 sees
46-94x on RTM data) is used unless the caller passes a measured one.
"""
from __future__ import annotations

from repro.core import cost_model as cm

__all__ = ["select_allreduce"]


def select_allreduce(
    d_bytes: int,
    n_ranks: int,
    ratio: float = 20.0,
    hw: cm.Hardware = cm.TPU_V5E,
    *,
    allow_beyond_paper: bool = False,
) -> str:
    """Return 'ring' | 'redoub' (| 'intring' when beyond-paper allowed)."""
    costs = {
        "ring": cm.allreduce_ring_gz(d_bytes, n_ranks, ratio, hw),
        "redoub": cm.allreduce_redoub_gz(d_bytes, n_ranks, ratio, hw),
    }
    if allow_beyond_paper:
        costs["intring"] = cm.allreduce_intring_gz(d_bytes, n_ranks, ratio, hw)
    return min(costs, key=costs.get)
