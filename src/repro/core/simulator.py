"""Global-view N-rank simulator of the gZCCL collective algorithms.

Runs the *same* algorithm step structure as core/collectives.py — same
number and order of compress/decompress operations, same ring/tree/XOR
communication patterns — but over a python list of per-rank arrays on one
device.  Used by tests to validate:

  * numerical results vs the exact (numpy) collective,
  * error accumulation vs the error_budget hop counts,
  * rank-consistency properties (intring bitwise-equal; redoub/ring within
    the accumulated bound),

without needing a multi-device runtime.  The shard_map versions are
additionally validated on 8 virtual host devices in
tests/test_collectives_multidevice.py (subprocess).
"""
from __future__ import annotations

import math
from typing import List

import numpy as np
import jax.numpy as jnp

from repro.core.collectives import GZConfig
from repro.core import cost_model, error_budget, faults

__all__ = [
    "sim_allreduce_redoub",
    "sim_allreduce_ring",
    "sim_allreduce_intring",
    "sim_allreduce_hier",
    "sim_allreduce_bucketed",
    "sim_allreduce_guarded",
    "sim_allgather_ring",
    "sim_reduce_scatter_ring",
    "sim_scatter_binomial",
    "sim_broadcast_binomial",
]


def _roundtrip(comp, x, eb):
    return np.asarray(comp.decompress(comp.compress(jnp.asarray(x), eb)))


def sim_allreduce_redoub(xs: List[np.ndarray], cfg: GZConfig):
    """Recursive doubling with the non-power-of-two remainder stage.

    Mirrors collectives._allreduce_redoub exactly: the n - 2**floor(log2 n)
    surplus ranks fold into their odd neighbour in a compressed pre-hop,
    the XOR doubling runs over the power-of-two participants, and a
    compressed post-hop unfolds the result back to the folded ranks —
    same number and order of lossy events, so error_budget.lossy_hops
    ("allreduce_redoub") applies verbatim.
    """
    n = len(xs)
    comp = cfg.compressor()
    eb = error_budget.allocate(cfg.eb, "allreduce_redoub", n,
                               worst_case=cfg.worst_case_budget)
    p = 1 << (n.bit_length() - 1)
    rem = n - p
    phys = lambda v: 2 * v + 1 if v < rem else v + rem
    acc = [x.astype(np.float32).copy() for x in xs]
    for i in range(rem):  # fold pre-hop: even -> odd neighbour
        acc[2 * i + 1] = acc[2 * i + 1] + _roundtrip(comp, acc[2 * i], eb)
    virt = {phys(v): v for v in range(p)}  # physical -> virtual participant
    for k in range(int(math.log2(p))):
        dist = 1 << k
        sent = {pr: _roundtrip(comp, acc[pr], eb) for pr in virt}
        acc = [
            acc[r] + sent[phys(virt[r] ^ dist)] if r in virt else acc[r]
            for r in range(n)
        ]
    for i in range(rem):  # unfold post-hop: odd -> even neighbour
        acc[2 * i] = _roundtrip(comp, acc[2 * i + 1], eb)
    return acc


def sim_allreduce_ring(xs: List[np.ndarray], cfg: GZConfig):
    """Ring RS + ring AG with identical chunk schedule to collectives.py."""
    n = len(xs)
    comp = cfg.compressor()
    hops = error_budget.lossy_hops("allreduce_ring", n)
    eb = cfg.eb / hops if cfg.worst_case_budget else cfg.eb / math.sqrt(hops)
    d = xs[0].shape[0]
    chunk = -(-d // n)
    acc = [np.zeros(n * chunk, np.float32) for _ in range(n)]
    for r in range(n):
        acc[r][:d] = xs[r]
    ch = lambda a, i: a[i * chunk : (i + 1) * chunk]
    # reduce-scatter: step s, rank r sends chunk (r-s)%n to r+1
    for s in range(n - 1):
        sends = [_roundtrip(comp, ch(acc[r], (r - s) % n), eb) for r in range(n)]
        for r in range(n):
            ch(acc[r], (r - s - 1) % n)[:] += sends[(r - 1) % n]
    # allgather: owner (r+1)%n compresses once; forward compressed
    cur = []
    for r in range(n):
        own = (r + 1) % n
        rt = _roundtrip(comp, ch(acc[r], own), eb)
        ch(acc[r], own)[:] = rt
        cur.append(rt)  # stands for the compressed payload being forwarded
    for s in range(n - 1):
        cur = [cur[(r - 1) % n] for r in range(n)]
        for r in range(n):
            ch(acc[r], (r - s) % n)[:] = cur[r]
    return [a[:d] for a in acc]


def sim_allreduce_intring(xs: List[np.ndarray], cfg: GZConfig):
    """Integer-domain ring: quantize once, exact int sums (global view)."""
    eb = cfg.eb
    qs = [np.rint(x.astype(np.float64) / (2 * eb)).astype(np.int64) for x in xs]
    qsum = np.sum(qs, axis=0)
    out = (qsum.astype(np.float64) * 2 * eb).astype(np.float32)
    return [out.copy() for _ in xs]


def sim_allreduce_hier(xs: List[np.ndarray], topology, cfg: GZConfig,
                       *, inter_algo: str = "redoub"):
    """Two-level allreduce replay over ``topology = (n_nodes, L)`` with
    node-major rank ordering (rank = node*L + local) — the same layout
    ``launch.mesh.make_hier_mesh`` carves and the composite-axis flat
    path flattens to.

    Mirrors ``collectives._execute_allreduce_hier``'s hierarchical branch
    stage for stage: EXACT f32 intra-node reduce-scatter (pad to L equal
    shards, shard l = sum of the node's ranks' shard-l slices — no codec,
    no error), the compressed ``inter_algo`` allreduce of each shard
    index across the n_nodes node peers via the single-axis sims (the
    only lossy stage: ``cfg.eb`` applies to it UNDILUTED, exactly
    ``error_budget.split_lossy``'s allocation), then the exact allgather
    copy back to every rank of the node.  End-to-end error therefore
    obeys the inter stage's own budget bound — the property
    tests/test_hier_property.py pins across non-pow2 topologies.
    """
    n_nodes, L = topology
    assert len(xs) == n_nodes * L, (len(xs), topology)
    d = xs[0].shape[0]
    shard = -(-d // L)
    padded = [
        np.zeros((L * shard,), np.float32) for _ in xs
    ]
    for r, x in enumerate(xs):
        padded[r][:d] = x.astype(np.float32)
    # Intra reduce-scatter: node n's shard l (exact f32 sum).
    node_shards = [
        [
            np.sum(
                [padded[n * L + j][l * shard:(l + 1) * shard]
                 for j in range(L)],
                axis=0, dtype=np.float32,
            )
            for l in range(L)
        ]
        for n in range(n_nodes)
    ]
    # Inter allreduce of each shard index across nodes (the lossy stage).
    if n_nodes > 1:
        sim = {
            "redoub": sim_allreduce_redoub,
            "ring": sim_allreduce_ring,
            "intring": sim_allreduce_intring,
        }[inter_algo]
        for l in range(L):
            outs = sim([node_shards[n][l] for n in range(n_nodes)], cfg)
            for n in range(n_nodes):
                node_shards[n][l] = outs[n].astype(np.float32)
    # Intra allgather: exact copy of the node's shards to all its ranks.
    return [
        np.concatenate(node_shards[r // L])[:d] for r in range(len(xs))
    ]


def sim_allreduce_guarded(xs: List[np.ndarray], cfg: GZConfig,
                          *, algo: str = "redoub", spec=None):
    """Global-view replay of the ``on_overflow="fallback"`` allreduce
    epilogue (DESIGN.md §9), optionally under an injected fault.

    Mirrors the device path stage for stage: poison the per-rank inputs
    through the SAME seeded injector the communicators consult
    (``faults.poison_np`` — bitwise identical constants), detect
    non-finite input and capacity overflow (per-rank compressor probe
    with the plan's own capacity factor; skipped when the input is
    already non-finite, matching the device path where a poisoned stream
    never reaches a meaningful pack), then either run the requested
    compressed algorithm sim or the exact lossless recovery — the sum of
    sanitized (NaN/Inf → 0) inputs, identical on every rank.

    Returns ``(outs, flags)`` with ``flags = {"overflow", "nonfinite",
    "fallback"}`` (python bools — the sim is the observable twin of the
    device health counters).  Recovery sums in f32 on one host, so
    device-vs-sim comparisons should use allclose, not bitwise: a psum's
    reduction order differs from ``np.sum``.
    """
    n = len(xs)
    poisoned = [
        faults.poison_np(np.asarray(x, np.float32), r, spec)
        for r, x in enumerate(xs)
    ]
    nonfinite = any(not np.isfinite(p).all() for p in poisoned)
    overflow = False
    if not nonfinite:
        comp = cfg.compressor()
        for p in poisoned:
            c = comp.compress(jnp.asarray(p), cfg.eb)
            if bool(np.asarray(c.overflowed())):
                overflow = True
                break
    fallback = overflow or nonfinite
    if fallback:
        san = [np.where(np.isfinite(p), p, 0.0) for p in poisoned]
        out = np.sum(san, axis=0, dtype=np.float32)
        outs = [out.copy() for _ in range(n)]
    else:
        sim = {
            "redoub": sim_allreduce_redoub,
            "ring": sim_allreduce_ring,
            "intring": sim_allreduce_intring,
        }[algo]
        outs = sim(poisoned, cfg)
    return outs, {
        "overflow": overflow, "nonfinite": nonfinite, "fallback": fallback,
    }


def sim_reduce_scatter_ring(xs: List[np.ndarray], cfg: GZConfig):
    n = len(xs)
    comp = cfg.compressor()
    eb = error_budget.allocate(cfg.eb, "reduce_scatter_ring", n,
                               worst_case=cfg.worst_case_budget)
    d = xs[0].shape[0]
    assert d % n == 0
    chunk = d // n
    acc = [x.astype(np.float32).copy() for x in xs]
    ch = lambda a, i: a[i * chunk : (i + 1) * chunk]
    for s in range(n - 1):
        sends = [_roundtrip(comp, ch(acc[r], (r - s - 1) % n), eb) for r in range(n)]
        for r in range(n):
            ch(acc[r], (r - s - 2) % n)[:] += sends[(r - 1) % n]
    return [ch(acc[r], r).copy() for r in range(n)]


def sim_allgather_ring(xs: List[np.ndarray], cfg: GZConfig):
    n = len(xs)
    comp = cfg.compressor()
    rts = [_roundtrip(comp, x, cfg.eb) for x in xs]  # single lossy hop each
    return [np.concatenate(rts) for _ in range(n)]


def sim_scatter_binomial(x_full: np.ndarray, n: int, cfg: GZConfig,
                         *, return_trace: bool = False):
    """Trimmed-slab binomial-tree scatter (global view).

    PR 4 grew the execute layer a virtual power-of-two tree while this sim
    kept modeling a bare per-chunk roundtrip with no schedule at all
    (sim/plan drift — ISSUE 5).  Now it replays the exact trimmed-slab
    schedule from ``cost_model.binomial_slab_table`` — the same authority
    ``collectives._execute_scatter`` walks and ``comm._wire_accounting``
    prices: the root compresses each chunk once, slabs of compressed
    streams (real-rank chunks only) travel sender -> receiver down the
    tree, and each rank decompresses its own chunk on arrival.  Schedule
    validity is asserted as it replays: a sender must hold every chunk it
    ships, and every rank must end up holding its own chunk.

    Returns the per-rank decompressed chunks — byte-identical to the
    multi-device execute layer (asserted at n=6/9 in the subprocess
    children).  With ``return_trace=True`` also returns
    ``{rank: (round_span, received chunk indices)}`` — each non-root rank
    receives exactly one slab, covering the real ranks of its subtree.
    """
    comp = cfg.compressor()
    chunk = x_full.shape[0] // n
    streams = {
        i: comp.compress(jnp.asarray(x_full[i * chunk : (i + 1) * chunk]),
                         cfg.eb)
        for i in range(n)
    }
    held = {r: set() for r in range(n)}
    held[0] = set(range(n))  # root holds every chunk stream
    trace = {}
    for span, full, trim in cost_model.binomial_slab_table(n):
        exchanges = [(i, i + span, span) for i in full]
        if trim is not None:
            exchanges.append(trim)
        for snd, rcv, slab in exchanges:
            idxs = range(rcv, rcv + slab)  # the receiver's real subtree
            missing = [i for i in idxs if i not in held[snd]]
            assert not missing, (
                f"schedule invalid: sender {snd} ships chunks {missing} "
                f"it does not hold (n={n}, span={span})")
            assert rcv not in trace, f"rank {rcv} received twice (n={n})"
            held[rcv].update(idxs)
            trace[rcv] = (span, tuple(idxs))
    for r in range(n):
        assert r in held[r], f"rank {r} never received its chunk (n={n})"
    outs = [np.asarray(comp.decompress(streams[r])) for r in range(n)]
    return (outs, trace) if return_trace else outs


def sim_broadcast_binomial(x: np.ndarray, n: int, cfg: GZConfig):
    comp = cfg.compressor()
    rt = _roundtrip(comp, x, cfg.eb)
    return [rt.copy() for _ in range(n)]


def sim_allreduce_bucketed(rank_leaves, bucket_bytes: int, cfg: GZConfig,
                           *, algo: str = "redoub", topology=None):
    """Global-view replay of the bucketed gradient sync (ISSUE 9).

    ``rank_leaves`` is a per-rank list of leaf-array lists (the same leaf
    structure on every rank).  The tree is tiled by the SAME
    ``core.buckets`` ledger the device path resolves (uniform payloads,
    last bucket zero-padded), each bucket runs through the matching
    single-axis / hierarchical allreduce sim in issue order
    (last-layer-first), and the leaf lists are reassembled from the
    bucket outputs — so bucket boundaries, padding and issue order are
    observable on one host exactly as ``dp_allreduce_grads`` schedules
    them.  Pass ``topology=(n_nodes, L)`` to route buckets through
    ``sim_allreduce_hier`` instead of the flat ``algo`` sim.

    RMS scaling (``relative_eb``) is NOT replayed here: feed pre-scaled
    leaves when comparing against a relative-eb device run.
    """
    from repro.core.buckets import ledger_for

    n = len(rank_leaves)
    shapes = tuple(np.asarray(x).shape for x in rank_leaves[0])
    ledger = ledger_for(shapes, bucket_bytes)
    flats = [
        [np.asarray(x, np.float32).reshape(-1) for x in leaves]
        for leaves in rank_leaves
    ]
    outs = [[np.zeros(s, np.float32).reshape(-1) for s in shapes]
            for _ in range(n)]
    sim = {
        "redoub": sim_allreduce_redoub,
        "ring": sim_allreduce_ring,
        "intring": sim_allreduce_intring,
    }[algo]
    for bucket in ledger.issue_order():
        payloads = []
        for r in range(n):
            vec = np.zeros(ledger.bucket_elems, np.float32)
            for s in bucket.slices:
                vec[s.offset:s.offset + s.size] = flats[r][s.leaf][s.start:s.stop]
            payloads.append(vec)
        if topology is not None:
            reduced = sim_allreduce_hier(payloads, topology, cfg)
        else:
            reduced = sim(payloads, cfg)
        for r in range(n):
            for s in bucket.slices:
                outs[r][s.leaf][s.start:s.stop] = (
                    reduced[r][s.offset:s.offset + s.size])
    return [
        [v.reshape(s) for v, s in zip(leaves, shapes)] for leaves in outs
    ]
