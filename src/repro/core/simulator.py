"""Global-view N-rank simulator of the gZCCL collective algorithms.

ONE table replayer (ISSUE 10): every ``sim_*`` walks the SAME frozen
route table the execute layer runs (``core/schedule.py`` —
``Schedule.rounds[k]`` hop entries), via :func:`_replay_table`.  The
per-op closures only say what a hop's payload *is* (a compressed
roundtrip for "lossy"/"unfold" stages, the held bytes for "exact"
forwards) and how a receiver folds it (the table's ``combine`` tag), so
the sims cannot drift from the device schedules — same number and order
of compress/decompress operations, same ring/tree/XOR patterns, over a
python list of per-rank arrays on one device.  Used by tests to
validate:

  * numerical results vs the exact (numpy) collective,
  * error accumulation vs the error_budget hop counts,
  * rank-consistency properties (intring bitwise-equal; redoub/ring within
    the accumulated bound),

without needing a multi-device runtime.  The shard_map versions are
additionally validated on 8 virtual host devices in
tests/test_collectives_multidevice.py (subprocess).
:func:`sim_wire_bytes` replays the table for BYTES instead of values —
measuring each entry's payload with ``jax.eval_shape`` of the real
compressor container — giving an accounting cross-check that shares no
arithmetic with ``comm._wire_accounting``.
"""
from __future__ import annotations

import math
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.collectives import GZConfig, PIECE_QUANTUM
from repro.core.compressed import capacity_words_for
from repro.core import error_budget, faults, schedule
from repro.kernels import ops

__all__ = [
    "sim_allreduce_redoub",
    "sim_allreduce_ring",
    "sim_allreduce_intring",
    "sim_allreduce_hier",
    "sim_allreduce_bucketed",
    "sim_allreduce_guarded",
    "sim_allgather_ring",
    "sim_reduce_scatter_ring",
    "sim_scatter_binomial",
    "sim_broadcast_binomial",
    "sim_wire_bytes",
]


def _roundtrip(comp, x, eb):
    return np.asarray(comp.decompress(comp.compress(jnp.asarray(x), eb)))


def _replay_table(sched: schedule.Schedule, snapshot_fn, payload_fn,
                  deliver_fn):
    """THE generic table replayer: walk ``sched.rounds`` in order.

    Per round: take a pre-round snapshot (wire rounds are concurrent —
    every payload is computed from state BEFORE the round applies), then
    for each hop entry compute ``payload_fn(hop, k, snap)`` and apply
    ``deliver_fn(hop, k, payload)``.  The closures carry the op's value
    semantics; the routes, stages and combine tags come only from the
    table.
    """
    for k, rnd in enumerate(sched.rounds):
        snap = snapshot_fn()
        for h in rnd:
            deliver_fn(h, k, payload_fn(h, k, snap))


def sim_allreduce_redoub(xs: List[np.ndarray], cfg: GZConfig):
    """Recursive doubling with the non-power-of-two remainder stage.

    Replays ``schedule.build("allreduce", "redoub", n)`` — the identical
    table ``collectives._allreduce_redoub`` walks: the fold pre-hop
    round, the XOR doubling rounds, the unfold post-hop round.  A
    "lossy"/"unfold" hop's payload is the compressed roundtrip of the
    sender's pre-round accumulator — same number and order of lossy
    events as the device path, so error_budget.lossy_hops
    ("allreduce_redoub") applies verbatim.
    """
    n = len(xs)
    comp = cfg.compressor()
    eb = error_budget.allocate(cfg.eb, "allreduce_redoub", n,
                               worst_case=cfg.worst_case_budget)
    sched = schedule.build("allreduce", "redoub", n)
    acc = [x.astype(np.float32).copy() for x in xs]

    def payload(h, k, snap):
        val = snap[h.sender]
        if h.stage in ("lossy", "unfold"):
            val = _roundtrip(comp, val, eb)
        return val

    def deliver(h, k, val):
        if sched.combine[k] == "reduce":
            acc[h.receiver] = acc[h.receiver] + val
        else:  # unfold install
            acc[h.receiver] = val.copy()

    _replay_table(sched, lambda: [a.copy() for a in acc], payload, deliver)
    return acc


def sim_allreduce_ring(xs: List[np.ndarray], cfg: GZConfig):
    """Ring RS + ring AG replaying ``schedule.build("allreduce", "ring",
    n)`` — the identical chunk schedule collectives.py runs.  RS rounds
    accumulate a fresh roundtrip of the sender's chunk; AG round 0
    carries the owner's single compression (the owner installs the same
    decompressed bytes locally), later AG rounds forward those bytes
    exactly."""
    n = len(xs)
    comp = cfg.compressor()
    hops = error_budget.lossy_hops("allreduce_ring", n)
    eb = cfg.eb / hops if cfg.worst_case_budget else cfg.eb / math.sqrt(hops)
    d = xs[0].shape[0]
    chunk = -(-d // n)
    acc = [np.zeros(n * chunk, np.float32) for _ in range(n)]
    for r in range(n):
        acc[r][:d] = xs[r]
    ch = lambda a, i: a[i * chunk : (i + 1) * chunk]
    if n == 1:  # degenerate axis: the owner's AG compression still runs
        return [_roundtrip(comp, acc[0], eb)[:d]]
    sched = schedule.build("allreduce", "ring", n)

    def payload(h, k, snap):
        c = h.chunk_slab[0]
        val = ch(snap[h.sender], c)
        if h.stage == "lossy":
            val = _roundtrip(comp, val, eb)
            if sched.combine[k] == "install":
                # AG round 0: the owner keeps the decompressed copy of
                # its own chunk — every rank sees the same bytes.
                ch(acc[h.sender], c)[:] = val
        return val

    def deliver(h, k, val):
        c = h.chunk_slab[0]
        if sched.combine[k] == "reduce":
            ch(acc[h.receiver], c)[:] += val
        else:
            ch(acc[h.receiver], c)[:] = val

    _replay_table(sched, lambda: [a.copy() for a in acc], payload, deliver)
    return [a[:d] for a in acc]


def sim_allreduce_intring(xs: List[np.ndarray], cfg: GZConfig):
    """Integer-domain ring: quantize once, exact int sums (global view)."""
    eb = cfg.eb
    qs = [np.rint(x.astype(np.float64) / (2 * eb)).astype(np.int64) for x in xs]
    qsum = np.sum(qs, axis=0)
    out = (qsum.astype(np.float64) * 2 * eb).astype(np.float32)
    return [out.copy() for _ in xs]


def sim_allreduce_hier(xs: List[np.ndarray], topology, cfg: GZConfig,
                       *, inter_algo: str = "redoub"):
    """Two-level allreduce replay over ``topology = (n_nodes, L)`` with
    node-major rank ordering (rank = node*L + local) — the same layout
    ``launch.mesh.make_hier_mesh`` carves and the composite-axis flat
    path flattens to.

    Mirrors ``collectives._execute_allreduce_hier``'s hierarchical branch
    stage for stage: EXACT f32 intra-node reduce-scatter (pad to L equal
    shards, shard l = sum of the node's ranks' shard-l slices — no codec,
    no error), the compressed ``inter_algo`` allreduce of each shard
    index across the n_nodes node peers via the single-axis sims (the
    only lossy stage: ``cfg.eb`` applies to it UNDILUTED, exactly
    ``error_budget.split_lossy``'s allocation), then the exact allgather
    copy back to every rank of the node.  End-to-end error therefore
    obeys the inter stage's own budget bound — the property
    tests/test_hier_property.py pins across non-pow2 topologies.
    """
    n_nodes, L = topology
    assert len(xs) == n_nodes * L, (len(xs), topology)
    d = xs[0].shape[0]
    shard = -(-d // L)
    padded = [
        np.zeros((L * shard,), np.float32) for _ in xs
    ]
    for r, x in enumerate(xs):
        padded[r][:d] = x.astype(np.float32)
    # Intra reduce-scatter: node n's shard l (exact f32 sum).
    node_shards = [
        [
            np.sum(
                [padded[n * L + j][l * shard:(l + 1) * shard]
                 for j in range(L)],
                axis=0, dtype=np.float32,
            )
            for l in range(L)
        ]
        for n in range(n_nodes)
    ]
    # Inter allreduce of each shard index across nodes (the lossy stage).
    if n_nodes > 1:
        sim = {
            "redoub": sim_allreduce_redoub,
            "ring": sim_allreduce_ring,
            "intring": sim_allreduce_intring,
        }[inter_algo]
        for l in range(L):
            outs = sim([node_shards[n][l] for n in range(n_nodes)], cfg)
            for n in range(n_nodes):
                node_shards[n][l] = outs[n].astype(np.float32)
    # Intra allgather: exact copy of the node's shards to all its ranks.
    return [
        np.concatenate(node_shards[r // L])[:d] for r in range(len(xs))
    ]


def sim_allreduce_guarded(xs: List[np.ndarray], cfg: GZConfig,
                          *, algo: str = "redoub", spec=None):
    """Global-view replay of the ``on_overflow="fallback"`` allreduce
    epilogue (DESIGN.md §9), optionally under an injected fault.

    Mirrors the device path stage for stage: poison the per-rank inputs
    through the SAME seeded injector the communicators consult
    (``faults.poison_np`` — bitwise identical constants), detect
    non-finite input and capacity overflow (per-rank compressor probe
    with the plan's own capacity factor; skipped when the input is
    already non-finite, matching the device path where a poisoned stream
    never reaches a meaningful pack), then either run the requested
    compressed algorithm sim or the exact lossless recovery — the sum of
    sanitized (NaN/Inf → 0) inputs, identical on every rank.

    Wire bitflips are replayed against the SAME schedule table the
    device walks: a ``kind="bitflip"`` spec is detected iff
    ``cfg.verify_streams`` ships checksums, some target rank exists on
    the axis, and some targeted round index lands inside
    ``schedule.build("allreduce", algo, n).rounds`` (``rounds=None``
    targets every round).  Detection ORs into the ``overflow`` flag —
    exactly how the device epilogue reports a checksum mismatch — and
    recovery is the clean lossless sum (bitflips corrupt the wire, not
    the inputs).

    Returns ``(outs, flags)`` with ``flags = {"overflow", "nonfinite",
    "fallback"}`` (python bools — the sim is the observable twin of the
    device health counters).  Recovery sums in f32 on one host, so
    device-vs-sim comparisons should use allclose, not bitwise: a psum's
    reduction order differs from ``np.sum``.
    """
    n = len(xs)
    poisoned = [
        faults.poison_np(np.asarray(x, np.float32), r, spec)
        for r, x in enumerate(xs)
    ]
    nonfinite = any(not np.isfinite(p).all() for p in poisoned)
    overflow = False
    if not nonfinite:
        comp = cfg.compressor()
        for p in poisoned:
            c = comp.compress(jnp.asarray(p), cfg.eb)
            if bool(np.asarray(c.overflowed())):
                overflow = True
                break
    if (spec is not None and spec.kind == "bitflip" and cfg.verify_streams):
        sched = schedule.build("allreduce", algo, n)
        targeted = (spec.rounds if spec.rounds is not None
                    else range(sched.n_rounds))
        corrupted = (
            any(0 <= r < n for r in spec.ranks)
            and any(0 <= k < sched.n_rounds for k in targeted)
        )
        overflow = overflow or corrupted
    fallback = overflow or nonfinite
    if fallback:
        san = [np.where(np.isfinite(p), p, 0.0) for p in poisoned]
        out = np.sum(san, axis=0, dtype=np.float32)
        outs = [out.copy() for _ in range(n)]
    else:
        sim = {
            "redoub": sim_allreduce_redoub,
            "ring": sim_allreduce_ring,
            "intring": sim_allreduce_intring,
        }[algo]
        outs = sim(poisoned, cfg)
    return outs, {
        "overflow": overflow, "nonfinite": nonfinite, "fallback": fallback,
    }


def sim_reduce_scatter_ring(xs: List[np.ndarray], cfg: GZConfig):
    """Standalone ring reduce-scatter replaying ``schedule.build(
    "reduce_scatter", "ring", n)`` (owner convention: rank r ends
    owning chunk r)."""
    n = len(xs)
    comp = cfg.compressor()
    eb = error_budget.allocate(cfg.eb, "reduce_scatter_ring", n,
                               worst_case=cfg.worst_case_budget)
    d = xs[0].shape[0]
    assert d % n == 0
    chunk = d // n
    acc = [x.astype(np.float32).copy() for x in xs]
    ch = lambda a, i: a[i * chunk : (i + 1) * chunk]
    sched = schedule.build("reduce_scatter", "ring", n)

    def payload(h, k, snap):
        return _roundtrip(comp, ch(snap[h.sender], h.chunk_slab[0]), eb)

    def deliver(h, k, val):
        ch(acc[h.receiver], h.chunk_slab[0])[:] += val

    _replay_table(sched, lambda: [a.copy() for a in acc], payload, deliver)
    return [ch(acc[r], r).copy() for r in range(n)]


def sim_allgather_ring(xs: List[np.ndarray], cfg: GZConfig):
    """Ring allgather replaying ``schedule.build("allgather", "ring",
    n)``: round 0 carries each owner's single compression (one lossy hop
    per element — the owner installs the decompressed copy too), later
    rounds forward those bytes exactly."""
    n = len(xs)
    comp = cfg.compressor()
    if n == 1:
        return [_roundtrip(comp, xs[0], cfg.eb)]
    acc = [np.zeros((n,) + xs[0].shape, np.float32) for _ in range(n)]
    for r in range(n):
        acc[r][r] = xs[r].astype(np.float32)
    sched = schedule.build("allgather", "ring", n)

    def payload(h, k, snap):
        c = h.chunk_slab[0]
        val = snap[h.sender][c]
        if h.stage == "lossy":  # round 0: the sender's own fresh stream
            val = _roundtrip(comp, val, cfg.eb)
            acc[h.sender][c] = val  # owner keeps the decompressed copy
        return val

    def deliver(h, k, val):
        acc[h.receiver][h.chunk_slab[0]] = val

    _replay_table(sched, lambda: [a.copy() for a in acc], payload, deliver)
    return [np.concatenate(list(a), axis=0) for a in acc]


def sim_scatter_binomial(x_full: np.ndarray, n: int, cfg: GZConfig,
                         *, return_trace: bool = False):
    """Trimmed-slab binomial-tree scatter (global view).

    PR 4 grew the execute layer a virtual power-of-two tree while this sim
    kept modeling a bare per-chunk roundtrip with no schedule at all
    (sim/plan drift — ISSUE 5).  Now it replays the route table
    ``schedule.build("scatter", "binomial", n)`` — the same authority
    ``collectives._execute_scatter`` walks and ``comm._wire_accounting``
    prices: the root compresses each chunk once, slabs of compressed
    streams (real-rank chunks only) travel sender -> receiver down the
    tree, and each rank decompresses its own chunk on arrival.  Schedule
    validity is asserted as it replays: a sender must hold every chunk it
    ships, and every rank must end up holding its own chunk.

    Returns the per-rank decompressed chunks — byte-identical to the
    multi-device execute layer (asserted at n=6/9 in the subprocess
    children).  With ``return_trace=True`` also returns
    ``{rank: (round_span, received chunk indices)}`` — each non-root rank
    receives exactly one slab, covering the real ranks of its subtree.
    """
    comp = cfg.compressor()
    chunk = x_full.shape[0] // n
    streams = {
        i: comp.compress(jnp.asarray(x_full[i * chunk : (i + 1) * chunk]),
                         cfg.eb)
        for i in range(n)
    }
    held = {r: set() for r in range(n)}
    held[0] = set(range(n))  # root holds every chunk stream
    trace = {}
    sched = schedule.build("scatter", "binomial", n)
    spans = [span for span, _, _ in schedule.binomial_slab_table(n)]

    def payload(h, k, snap):
        start, slab = h.chunk_slab
        idxs = range(start, start + slab)  # the receiver's real subtree
        missing = [i for i in idxs if i not in snap[h.sender]]
        assert not missing, (
            f"schedule invalid: sender {h.sender} ships chunks {missing} "
            f"it does not hold (n={n}, span={spans[k]})")
        return idxs

    def deliver(h, k, idxs):
        assert h.receiver not in trace, f"rank {h.receiver} received twice (n={n})"
        held[h.receiver].update(idxs)
        trace[h.receiver] = (spans[k], tuple(idxs))

    _replay_table(sched, lambda: {r: s.copy() for r, s in held.items()},
                  payload, deliver)
    for r in range(n):
        assert r in held[r], f"rank {r} never received its chunk (n={n})"
    outs = [np.asarray(comp.decompress(streams[r])) for r in range(n)]
    return (outs, trace) if return_trace else outs


def sim_broadcast_binomial(x: np.ndarray, n: int, cfg: GZConfig):
    """Binomial broadcast replaying ``schedule.build("broadcast",
    "binomial", n)``: the root's single compressed stream travels down
    the table's tree rounds (forwards are bit-exact, so every rank ends
    with the same roundtripped bytes — asserted by coverage replay)."""
    comp = cfg.compressor()
    rt = _roundtrip(comp, x, cfg.eb)
    sched = schedule.build("broadcast", "binomial", n)
    has = {0}

    def payload(h, k, snap):
        assert h.sender in snap, (
            f"round {k}: sender {h.sender} forwards a stream it never "
            f"received (n={n})")
        return rt

    def deliver(h, k, val):
        has.add(h.receiver)

    _replay_table(sched, lambda: set(has), payload, deliver)
    assert has == set(range(n)), f"broadcast coverage {sorted(has)} != {n}"
    return [rt.copy() for _ in range(n)]


def _measured_entry_bytes(plan):
    """Per-:class:`schedule.Hop` wire-bytes closure for one flat plan.

    The container size of a compressed hop is MEASURED, not computed:
    ``jax.eval_shape`` of the plan's real compressor (the exact factory
    the execute layer ships through) gives the abstract ``Compressed``
    pytree, and the hop costs the sum of its leaves' nbytes.  Only the
    execute layer's payload geometry (pipelined rings pad chunks to
    whole-tile pieces; intring pads to whole code rows) is restated here
    — none of ``comm._stream_bytes``'s container arithmetic is.
    """
    op, algo, n = plan.op, plan.algo, plan.axis_size
    n_elems = plan.n_elems
    p = max(plan.pipeline_chunks, 1)
    comp = plan.as_config().compressor()

    def stream_nbytes(m):
        out = jax.eval_shape(
            lambda x: comp.compress(x, plan.eb),
            jax.ShapeDtypeStruct((int(m),), jnp.float32))
        return sum(int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree.leaves(out))

    if (op == "allreduce" and algo == "redoub") or op == "broadcast":
        per = stream_nbytes(n_elems)
        return lambda h: per
    if op == "allreduce" and algo == "intring":
        # integer wire: packed codes + per-row bitwidth + anchor (no
        # float container to eval_shape — the int pack has no factory)
        chunk = ops.n_blocks_for(-(-n_elems // n)) * ops.BLOCK
        cap = capacity_words_for(chunk, plan.capacity_factor, ops.BLOCK)
        rows = chunk // ops.BLOCK
        per = cap * 4 + rows * 4 + rows * 4
        return lambda h: per
    if op == "allreduce":  # float ring
        if p > 1:
            quantum = n * p * PIECE_QUANTUM
            piece = (-(-n_elems // quantum) * quantum) // (n * p)
        else:
            piece = -(-n_elems // n)
        per = p * stream_nbytes(piece)
        return lambda h: per
    if op in ("reduce_scatter", "allgather"):
        base = -(-n_elems // n) if op == "reduce_scatter" else n_elems
        if p > 1:
            quantum = p * PIECE_QUANTUM
            piece = (-(-base // quantum) * quantum) // p
        else:
            piece = base
        per = p * stream_nbytes(piece)
        return lambda h: per
    if op == "scatter":
        per = stream_nbytes(-(-n_elems // n))
        return lambda h: h.chunk_slab[1] * per
    if op == "all_to_all":
        per = stream_nbytes(-(-n_elems // n))
        return lambda h: per
    raise ValueError(f"unknown op {op!r}")


def sim_wire_bytes(plan) -> int:
    """Replay ``plan.route_table`` for BYTES: the busiest sender's total
    over the same per-round hop entries the execute layer walks, each
    hop measured via :func:`_measured_entry_bytes`.  Must agree EXACTLY
    with the plan's provisioned ``wire_bytes`` (``comm._wire_accounting``
    sums the same table with independently-derived container arithmetic)
    — `benchmarks/regression_check.py` makes any disagreement fatal.

    Accepts flat :class:`comm.Plan` and two-level :class:`comm.HierPlan`
    (flat-resolved hier delegates to its flat plan; true hier prices raw
    intra hops at shard f32 bytes and lifted inter hops via the inter
    sub-plan).  A degenerate axis (``route_table is None``) has no wire
    rounds — the plan's own provisioning is returned unchanged.
    """
    sched = getattr(plan, "route_table", None)
    if sched is None:
        return plan.wire_bytes
    if hasattr(plan, "topology"):  # HierPlan
        if plan.flat:
            return sim_wire_bytes(plan.flat_plan)
        shard = -(-plan.n_elems // plan.topology[1])
        inter_entry = (_measured_entry_bytes(plan.inter)
                       if plan.inter is not None else None)
        entry = lambda h: (shard * 4 if h.payload_kind == "raw"
                           else inter_entry(h))
    else:
        entry = _measured_entry_bytes(plan)
    send = [0] * sched.n
    for rnd in sched.rounds:
        for h in rnd:
            send[h.sender] += entry(h)
    return max(send)


def sim_allreduce_bucketed(rank_leaves, bucket_bytes: int, cfg: GZConfig,
                           *, algo: str = "redoub", topology=None):
    """Global-view replay of the bucketed gradient sync (ISSUE 9).

    ``rank_leaves`` is a per-rank list of leaf-array lists (the same leaf
    structure on every rank).  The tree is tiled by the SAME
    ``core.buckets`` ledger the device path resolves (uniform payloads,
    last bucket zero-padded), each bucket runs through the matching
    single-axis / hierarchical allreduce sim in issue order
    (last-layer-first), and the leaf lists are reassembled from the
    bucket outputs — so bucket boundaries, padding and issue order are
    observable on one host exactly as ``dp_allreduce_grads`` schedules
    them.  Pass ``topology=(n_nodes, L)`` to route buckets through
    ``sim_allreduce_hier`` instead of the flat ``algo`` sim.

    RMS scaling (``relative_eb``) is NOT replayed here: feed pre-scaled
    leaves when comparing against a relative-eb device run.
    """
    from repro.core.buckets import ledger_for

    n = len(rank_leaves)
    shapes = tuple(np.asarray(x).shape for x in rank_leaves[0])
    ledger = ledger_for(shapes, bucket_bytes)
    flats = [
        [np.asarray(x, np.float32).reshape(-1) for x in leaves]
        for leaves in rank_leaves
    ]
    outs = [[np.zeros(s, np.float32).reshape(-1) for s in shapes]
            for _ in range(n)]
    sim = {
        "redoub": sim_allreduce_redoub,
        "ring": sim_allreduce_ring,
        "intring": sim_allreduce_intring,
    }[algo]
    for bucket in ledger.issue_order():
        payloads = []
        for r in range(n):
            vec = np.zeros(ledger.bucket_elems, np.float32)
            for s in bucket.slices:
                vec[s.offset:s.offset + s.size] = flats[r][s.leaf][s.start:s.stop]
            payloads.append(vec)
        if topology is not None:
            reduced = sim_allreduce_hier(payloads, topology, cfg)
        else:
            reduced = sim(payloads, cfg)
        for r in range(n):
            for s in bucket.slices:
                outs[r][s.leaf][s.start:s.stop] = (
                    reduced[r][s.offset:s.offset + s.size])
    return [
        [v.reshape(s) for v, s in zip(leaves, shapes)] for leaves in outs
    ]
