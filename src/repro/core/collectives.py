"""gZCCL compressed collectives as shard_map-level JAX primitives.

Every collective here is written *rank-centric*: it is per-device code that
runs inside a ``jax.shard_map`` body over a named mesh axis, moving
``Compressed`` pytrees with ``jax.lax.ppermute``.  This is the TPU-native
translation of the paper's MPI send/recv patterns (DESIGN.md §2).

Layering (DESIGN.md §5): this module holds the EXECUTE layer — the
``_execute_*`` functions run a fully-resolved schedule (concrete
algorithm, concrete pipeline depth) and contain zero selector logic.
Plan resolution (algorithm choice, pipeline depth, per-stage budgets,
wire accounting) lives in :mod:`repro.core.comm` behind
``GZCommunicator.plan`` and is memoized outside the traced region.  The
public ``gz_*`` functions below are thin back-compat wrappers over a
one-shot communicator; new code should hold a ``GZCommunicator`` and use
its methods, which return the uniform ``CollectiveResult`` stats channel
instead of the legacy ``return_info`` tuple convention.

Algorithms:

  gz_allreduce  algo="redoub"   recursive doubling — log2(N) full-message
                                 compressions (paper's headline gZ-Allreduce)
                algo="ring"      ring reduce-scatter + ring allgather —
                                 (N-1)+1 chunk compressions (paper's
                                 gZ-Allreduce (Ring))
                algo="intring"   BEYOND-PAPER: quantize once, ring-allreduce
                                 the integer codes losslessly — single lossy
                                 hop, bitwise rank-consistent, error <= eb
                                 per addend
                algo="auto"      cost-model selection (core/selector.py)
  gz_reduce_scatter / gz_allgather   the two ring stages standalone
  gz_scatter    binomial tree, per-chunk compression (paper's gZ-Scatter;
                the batched quantize over all chunks is the multi-stream
                analog — one pallas_call covers what N CUDA streams did)
  gz_broadcast  binomial tree, compress once at root

Axis sizes are ARBITRARY (paper §3.2.3, DESIGN.md §7).  The ring schedules
generalize to any N directly; the log-depth schedules handle
non-power-of-two axes with the paper's remainder stage: recursive doubling
folds the n - 2**floor(log2 n) extra ranks into a partner in a compressed
pre-hop, runs the doubling over the remaining power-of-two participants,
and unfolds the result in a compressed post-hop; the binomial
scatter/broadcast trees run ceil(log2 n) rounds on the trimmed-slab
schedule (cost_model.binomial_slab_table): each exchange ships only the
real ranks of the receiver's subtree, so the scatter root wires exactly
n-1 chunk streams at any axis size and out-of-range exchanges never
exist.  The remainder hops are lossy and are charged to the per-stage
error budget (core/error_budget.py: redoub's worst-case hop count is n-1
on power-of-two axes and n otherwise).

Consistency note (recorded in DESIGN.md): like the paper's gZ-Allreduce,
"redoub" and "ring" produce rank-wise results that agree only within the
accumulated error bound (each rank adds *its partner's* requantized data).
"intring" is exact-sum-of-quantized, hence bitwise identical on every rank
— that property is why it exists.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import bitpack, codecs, cost_model, error_budget, faults, \
    schedule
from repro.core.compressed import (
    Compressed, capacity_words_for, validate_capacity_factor,
)
from repro.kernels import ops
from repro.kernels.ref import bitwidth_of as _ref_bitwidth

__all__ = [
    "GZConfig",
    "gz_allreduce",
    "gz_allreduce_hier",
    "gz_reduce_scatter",
    "gz_allgather",
    "gz_scatter",
    "gz_broadcast",
    "gz_all_to_all",
    "plan_ring_pipeline_chunks",
]


@dataclasses.dataclass(frozen=True)
class GZConfig:
    """Knobs for the compressed-collective layer.

    eb is the *end-to-end* absolute error bound; per-stage budgets are
    derived via core.error_budget (accuracy-aware design, paper §3.3.3).

    ``pipeline_chunks`` (power of two) splits every ring chunk into that
    many pieces and software-pipelines the ring: piece k+1 is compressed
    while piece k is in flight on ``ppermute`` — the shard_map analog of
    the paper's multi-stream overlap (§3.2/§3.3, DESIGN.md §4).  1 means
    the sequential schedule; ``algo="auto"`` also auto-selects the chunk
    count from the cost model.  Piece boundaries stay aligned to whole
    compressor row-tiles, so the quantization grid — and therefore the
    error bound and the per-element lossy-hop count — is identical to the
    unpipelined schedule.

    ``fused`` routes compression through the single-pass Pallas pipeline
    (kernels/lorenzo.py quantize_pack); False keeps the two-pass oracle
    composition.  Wire bytes are identical either way.

    ``fused_hop`` runs every intermediate ring/redoub reduce hop as ONE
    ``unpack_reduce_repack`` kernel (DESIGN.md §3.1): the hop's received
    piece is decompressed, reduced and re-compressed into the *next* hop's
    wire stream in a single pass, so the updated f32 chunk never
    round-trips HBM and each hop pays one kernel dispatch instead of two.
    False keeps the PR 1 two-kernel hop schedule (decompress_reduce then a
    separate compress).  Wire streams and results are bitwise identical
    either way; only the kernel count and the cost model's pipeline-depth
    planning differ (``t_hop_fused`` sees one ``cmp_overhead_us``, so
    "auto" picks deeper pipelines when the fused hop is on).

    ``on_overflow`` is the degradation policy (DESIGN.md §9): "flag"
    only reports the global-OR flags in ``CollectiveResult`` (today's
    behaviour); "fallback" re-executes the collective through the
    uncompressed lossless schedule inside the trace (``lax.cond``) when
    any stream overflowed or any input held NaN/Inf, so the result is
    exact whenever compression failed; "raise" raises from a debug
    callback on the host (debugging aid — aborts the computation).

    ``verify_streams`` ships a per-hop XOR checksum alongside every
    compressed ppermute and treats a mismatch exactly like overflow
    (the stream is unusable either way) — detects in-flight wire
    corruption at the cost of one extra scalar ppermute per hop.

    ``codec`` names a wire-codec registry entry (``repro.core.codecs``,
    DESIGN.md §10): how payload bytes become wire bytes.  "lorenzo" (the
    default) is the dense bitpack — bitwise-unchanged pre-registry
    behavior; "lorenzo+entropy" adds the per-sub-block entropy trim;
    "lossless" / "passthrough" are the eb-free endpoints.  "auto" defers
    the choice to the plan layer, which prices every auto-selectable
    codec through the cost model (calibrated per-codec terms when
    available) and freezes the winner into ``Plan.codec``.
    """

    eb: float = 1e-4
    capacity_factor: float = 0.6
    algo: str = "auto"  # auto | redoub | ring | intring
    worst_case_budget: bool = True
    pipeline_chunks: int = 1
    fused: bool = True
    fused_hop: bool = True
    on_overflow: str = "flag"  # flag | fallback | raise
    verify_streams: bool = False
    codec: str = "lorenzo"  # registry entry name, or "auto"

    def __post_init__(self):
        # Fail at construction time with an actionable message, not via a
        # bare assert buried in an execute-layer tree loop (which would
        # also vanish under `python -O`).
        if self.pipeline_chunks < 1 or not _is_pow2(self.pipeline_chunks):
            raise ValueError(
                "GZConfig.pipeline_chunks must be a power of two >= 1 "
                "(the chunked double-buffered schedules split ring chunks "
                f"and tree slabs in half repeatedly); got "
                f"{self.pipeline_chunks!r}"
            )
        validate_capacity_factor(
            self.capacity_factor, knob="GZConfig.capacity_factor"
        )
        if self.on_overflow not in ("flag", "fallback", "raise"):
            raise ValueError(
                "GZConfig.on_overflow must be one of 'flag' (report only), "
                "'fallback' (in-trace lossless re-execute) or 'raise' "
                f"(host-side error); got {self.on_overflow!r}"
            )
        codecs.validate_codec(self.codec, knob="GZConfig.codec")

    def compressor(self):
        """The wire compressor this config's codec entry resolves to.

        ``codec="auto"`` has no compressor — the plan layer must freeze a
        concrete codec first (``Plan.as_config()`` always does).
        """
        return codecs.build_compressor(
            self.codec, capacity_factor=self.capacity_factor, fused=self.fused
        )


def _axis_size(axis_name) -> int:
    # Composite (tuple/list) axis names — collectives over a flattened 2D
    # mesh ("node", "local") — multiply out; jax.core.axis_frame only
    # resolves single names.
    if isinstance(axis_name, (tuple, list)):
        n = 1
        for ax in axis_name:
            n *= _axis_size(ax)
        return n
    if hasattr(lax, "axis_size"):  # JAX >= 0.6
        return lax.axis_size(axis_name)
    from jax import core

    return int(core.axis_frame(axis_name))


def _ppermute(tree, axis_name, perm):
    return jax.tree.map(lambda a: lax.ppermute(a, axis_name, perm), tree)


def _ring_perm(n: int):
    """Ring perm, sourced from the schedule authority (core/schedule.py)."""
    return schedule.ring_perm(n)


def _or_across(ovf, axis_name):
    """OR a per-rank overflow flag across the axis (one scalar psum).

    Every collective's per-rank result embeds wire streams compressed on
    OTHER ranks (ring hops, tree forwards, the scatter/broadcast root), so
    a local flag alone can be silently False on a rank whose received data
    was truncated elsewhere.  ``return_info=True`` therefore reports the
    global OR: "did any piece of any hop anywhere overflow".
    """
    return lax.psum(ovf.astype(jnp.int32), axis_name) > 0


def _axis_rank(axis_name):
    """Flattened rank over a (possibly composite) axis, major-to-minor —
    matches the rank order ppermute sees over a tuple axis name."""
    if isinstance(axis_name, (tuple, list)):
        r = jnp.zeros((), jnp.int32)
        for ax in axis_name:
            r = r * _axis_size(ax) + lax.axis_index(ax)
        return r
    return lax.axis_index(axis_name)


def _flags_across(ovf, nonfinite, axis_name):
    """Global-OR both health bits in ONE psum (stacked int32 pair), so the
    psum count per collective is unchanged vs the old single-flag
    ``_or_across``.  Both results are replicated (psum-derived), hence
    safe as ``lax.cond`` predicates."""
    pair = jnp.stack(
        [ovf.astype(jnp.int32), nonfinite.astype(jnp.int32)]
    )
    both = lax.psum(pair, axis_name) > 0
    return both[0], both[1]


def _nonfinite_local(x) -> jnp.ndarray:
    """Per-rank NaN/Inf presence (False scalar for non-float payloads)."""
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return jnp.zeros((), jnp.bool_)
    return jnp.any(~jnp.isfinite(x))


def _sanitize(x):
    """Replace NaN/Inf with 0 (identity on finite data, so an
    overflow-only fallback stays bitwise equal to the plain lossless
    collective of the original input)."""
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    return jnp.where(jnp.isfinite(x), x, jnp.zeros((), x.dtype))


def _tree_checksum(tree) -> jnp.ndarray:
    """XOR-fold every leaf's bits into one uint32.

    All wire leaves are 32-bit (packed uint32, bitwidth/anchor/nwords
    int32, eb f32), so a same-width bitcast view is exact; any other
    width falls back to a value cast (still a valid checksum).  A single
    bit flip anywhere in the payload flips exactly one checksum bit.
    """
    total = jnp.zeros((), jnp.uint32)
    for leaf in jax.tree.leaves(tree):
        if leaf.dtype.itemsize == 4:
            words = lax.bitcast_convert_type(leaf, jnp.uint32)
        else:
            words = leaf.astype(jnp.uint32)
        total = total ^ lax.reduce(
            words.reshape(-1), jnp.uint32(0), lax.bitwise_xor, (0,)
        )
    return total


def _ppermute_guarded(tree, axis_name, perm, guard, round_idx=None):
    """``_ppermute`` + optional end-to-end stream verification.

    The fault-injection wire hook (core/faults.py) applies to the
    received payload unconditionally (identity when no fault is
    installed).  ``round_idx`` is the schedule-table round this exchange
    implements (may be a traced loop index) — a round-targeted
    ``FaultSpec(rounds=...)`` corrupts only matching rounds, so an
    injected bitflip lands on the identical wire exchange in the table
    replay and on a real mesh.  With ``guard`` a whole-buffer XOR
    checksum of the SENT tree travels on the same perm as a separate
    scalar ppermute and is compared against a recomputed checksum of the
    received tree; ranks unaddressed by ``perm`` receive zero streams
    AND a zero checksum, so they can never false-positive.  Returns
    ``(recv, bad)``.
    """
    recv = _ppermute(tree, axis_name, perm)
    recv = faults.maybe_corrupt_wire(recv, axis_name, round_idx=round_idx)
    if not guard:
        return recv, jnp.zeros((), jnp.bool_)
    chk_sent = lax.ppermute(_tree_checksum(tree), axis_name, perm)
    return recv, chk_sent != _tree_checksum(recv)


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


# ---------------------------------------------------------------------------
# Lossless fallback schedules (DESIGN.md §9)
# ---------------------------------------------------------------------------
#
# Every op has an uncompressed twin over the same axis/topology.  The
# fallback sanitizes NaN/Inf to 0 first (identity on finite data), so an
# overflow-only degradation recovers the EXACT lossless result and a
# poisoned input recovers the lossless result of the sanitized input.
# The reduction ops lean on XLA's native collectives; scatter/broadcast
# re-walk the SAME trimmed-slab schedule tables with raw f32 payloads
# (the fault-injection wire hook skips non-uint32 trees, so a lossless
# re-execute is immune to the packed-word bit-flip injector).


def _lossless_scatter(x_full, axis_name, cfg: GZConfig, n):
    r = lax.axis_index(axis_name)
    chunk_n = x_full.shape[0] // n
    n_virt = 1 << cost_model.steps_for("binomial", n)
    chunks = _sanitize(x_full.astype(jnp.float32)).reshape(n, chunk_n)
    held = jnp.zeros((n_virt, chunk_n), jnp.float32).at[:n].set(chunks)
    held, _ = _scatter_tree_trimmed(held, axis_name, r, n, n_virt, cfg)
    return jnp.take(held, r, axis=0).astype(x_full.dtype)


def _lossless_broadcast(x, axis_name, cfg: GZConfig, n):
    r = lax.axis_index(axis_name)
    buf = _sanitize(x.reshape(-1).astype(jnp.float32))
    for span, _full, _trim, perm in schedule.tree_plan(n):
        recv = lax.ppermute(buf, axis_name, perm)
        has = (r % (span * 2)) == span
        buf = jnp.where(has, recv, buf)
    return buf.reshape(x.shape).astype(x.dtype)


def _execute_lossless(op, x, axis_name, cfg: GZConfig, *, root: int = 0):
    """Uncompressed re-execute of ``op`` over the same axis (exact)."""
    n = _axis_size(axis_name)
    single = axis_name if not isinstance(axis_name, (tuple, list)) \
        else (axis_name if len(axis_name) > 1 else axis_name[0])
    if op == "allreduce":
        return lax.psum(
            _sanitize(x.astype(jnp.float32)), axis_name
        ).astype(x.dtype)
    if op == "reduce_scatter":
        out = lax.psum_scatter(
            _sanitize(x.astype(jnp.float32)), single,
            scatter_dimension=0, tiled=True,
        )
        return out.astype(x.dtype)
    if op == "allgather":
        v = _sanitize(x)
        if x.ndim == 0:
            return lax.all_gather(v[None], single, tiled=True)
        return lax.all_gather(v, single, tiled=True)
    if op == "scatter":
        return _lossless_scatter(x, axis_name, cfg, n)
    if op == "broadcast":
        return _lossless_broadcast(x, axis_name, cfg, n)
    if op == "all_to_all":
        return lax.all_to_all(
            _sanitize(x), single, split_axis=0, concat_axis=0, tiled=True
        )
    raise ValueError(f"no lossless fallback for op {op!r}")


# ---------------------------------------------------------------------------
# Allreduce — collective computation (paper §3.3.3 / Fig. 4)
# ---------------------------------------------------------------------------


def _redoub_layout(n: int):
    """Remainder-stage layout for recursive doubling over ``n`` ranks
    (paper §3.2.3, DESIGN.md §7).

    ``p = 2**floor(log2 n)`` ranks participate in the XOR doubling; the
    ``rem = n - p`` surplus ranks pair up with a neighbour in a pre-hop:
    each even physical rank ``2i < 2*rem`` folds its data into ``2i + 1``
    and sits out, and gets the result back in a post-hop.  ``phys`` maps a
    virtual participant rank to its physical rank (the odd halves of the
    folded pairs first, then the untouched tail).  Delegates to the
    schedule authority (the same layout the route-table builder uses).
    """
    return schedule.redoub_layout(n)


def _allreduce_redoub(x, axis_name, cfg: GZConfig):
    """Recursive-doubling gZ-Allreduce: ~log2(N) full-message compressions.

    Per step: compress local running sum, exchange with the XOR partner,
    fused decompress+reduce into the local sum.  Full-message compression
    keeps the compressor saturated — the paper's core scalability insight.

    Non-power-of-two axes run the paper's remainder stage around the
    doubling (``_redoub_layout``): a compressed pre-hop folds each surplus
    rank into its partner, the doubling runs over the power-of-two
    participants (idle ranks ride along SPMD-style: their ``ppermute``
    slots are unaddressed, so they receive zero streams that decompress to
    0.0 and leave their accumulator untouched), and a compressed post-hop
    unfolds the result.  Both remainder hops are ordinary lossy exchanges
    charged to the stage budget (``error_budget.lossy_hops`` counts n
    instead of n-1), and overflow flags are masked to streams that
    actually travel so an idle rank's dead compression can never trip the
    global OR.

    With ``cfg.fused_hop`` every intermediate step runs as a single
    ``decompress_reduce_compress`` pass: the received partner stream and
    the local sum go in, the *next* step's outgoing stream comes out
    (plus the updated f32 carry, which redoub genuinely needs); the last
    step emits the plain f32 accumulator — except on a remainder axis,
    where the last step's fused kernel directly emits the post-hop's
    outgoing stream alongside the carry (the unfold payload IS the
    compressed updated accumulator).  ceil(log2 N)+1 kernels instead of
    2·ceil(log2 N) (+1 on remainder axes), bitwise-identical results.
    """
    n = _axis_size(axis_name)
    comp = cfg.compressor()
    eb_stage = error_budget.allocate(
        cfg.eb, "allreduce_redoub", n, worst_case=cfg.worst_case_budget
    )
    p, rem, _phys = _redoub_layout(n)
    steps = p.bit_length() - 1  # == log2(p)
    r = lax.axis_index(axis_name)
    # Remainder-stage masks (all False / trivially true when rem == 0).
    in_pair = r < 2 * rem
    is_fold_src = in_pair & (r % 2 == 0)   # folds into partner, then idles
    is_fold_dst = in_pair & (r % 2 == 1)   # absorbs partner, sends back
    is_participant = ~is_fold_src
    # Every perm comes from the route table: round 0 is the fold pre-hop
    # (remainder axes only), rounds base..base+steps-1 the XOR doubling,
    # round base+steps the unfold post-hop.
    sched = schedule.build("allreduce", "redoub", n)
    base = 1 if rem else 0
    pre_perm = sched.perm(0) if rem else ()
    step_perms = [sched.perm(base + k) for k in range(steps)]
    post_perm = sched.perm(base + steps) if rem else ()
    acc = x
    overflow = jnp.zeros((), jnp.bool_)

    guard = cfg.verify_streams

    if cfg.fused_hop:
        c = comp.compress(acc, eb_stage)
        # The initial stream travels on the pre-hop (fold sources) on a
        # remainder axis, on step 0 (everyone) otherwise.
        overflow |= c.overflowed() & (is_fold_src if rem else True)
        if rem:
            c_recv, bad = _ppermute_guarded(
                c, axis_name, pre_perm, guard, round_idx=0
            )
            overflow |= bad
            c, acc = comp.decompress_reduce_compress(
                c_recv, acc, eb_stage, return_updated=True
            )
            overflow |= c.overflowed() & is_participant
        for k in range(steps):
            c_recv, bad = _ppermute_guarded(
                c, axis_name, step_perms[k], guard, round_idx=base + k
            )
            overflow |= bad
            if k < steps - 1:
                c, acc = comp.decompress_reduce_compress(
                    c_recv, acc, eb_stage, return_updated=True
                )
                overflow |= c.overflowed() & is_participant
            elif rem:
                # Last hop + post-stage compress in one fused pass: the
                # unfold payload is the stream of the updated accumulator.
                c, acc = comp.decompress_reduce_compress(
                    c_recv, acc, eb_stage, return_updated=True
                )
                overflow |= c.overflowed() & is_fold_dst
            else:  # last hop: emit the plain f32 accumulator
                acc = comp.decompress_reduce(c_recv, acc)
        if rem:
            c_back, bad = _ppermute_guarded(
                c, axis_name, post_perm, guard, round_idx=base + steps
            )
            overflow |= bad
            acc = jnp.where(is_fold_src, comp.decompress(c_back), acc)
        return acc, overflow

    if rem:
        c = comp.compress(acc, eb_stage)
        overflow |= c.overflowed() & is_fold_src
        c_recv, bad = _ppermute_guarded(
            c, axis_name, pre_perm, guard, round_idx=0
        )
        overflow |= bad
        acc = comp.decompress_reduce(c_recv, acc)
    for k in range(steps):
        c = comp.compress(acc, eb_stage)
        overflow |= c.overflowed() & is_participant
        c_recv, bad = _ppermute_guarded(
            c, axis_name, step_perms[k], guard, round_idx=base + k
        )
        overflow |= bad
        acc = comp.decompress_reduce(c_recv, acc)
    if rem:
        c = comp.compress(acc, eb_stage)
        overflow |= c.overflowed() & is_fold_dst
        c_back, bad = _ppermute_guarded(
            c, axis_name, post_perm, guard, round_idx=base + steps
        )
        overflow |= bad
        acc = jnp.where(is_fold_src, comp.decompress(c_back), acc)
    return acc, overflow


def _chunk(x, idx, chunk_n):
    return lax.dynamic_slice(x, (idx * chunk_n,), (chunk_n,))


def _set_chunk(x, val, idx, chunk_n):
    return lax.dynamic_update_slice(x, val, (idx * chunk_n,))


def _pad_to_chunks(x, n):
    total = -(-x.shape[0] // n) * n
    return jnp.zeros((total,), x.dtype).at[: x.shape[0]].set(x), total // n


def _reduce_scatter_ring(x, axis_name, cfg: GZConfig, eb_stage, *, owner_offset=0):
    """Ring reduce-scatter with per-hop compression of the running chunk sum.

    Returns (acc, chunk_n, overflow): rank r's fully-reduced chunk is at
    index (r + 1 + owner_offset) % N of its local acc.  (N-1) compressions
    of size D/N each — the regime where the paper shows compressor
    under-utilization.

    Single-pass hop schedule (``cfg.fused_hop``): the chunk a hop reduces
    into IS the chunk the next hop sends, so each intermediate hop runs ONE
    ``decompress_reduce_compress`` kernel that turns the received stream +
    the local chunk directly into the next outgoing stream — the updated
    f32 never lands in ``acc`` (nothing ever reads it back; callers only
    read the final chunk).  The LAST hop emits the plain f32 accumulator.
    N kernels total instead of 2(N-1), byte-identical wire streams.
    """
    n = _axis_size(axis_name)
    comp = cfg.compressor()
    r = lax.axis_index(axis_name)
    acc, chunk_n = _pad_to_chunks(x, n)
    perm = _ring_perm(n)
    overflow = jnp.zeros((), jnp.bool_)
    t = owner_offset

    guard = cfg.verify_streams

    if cfg.fused_hop:
        c = comp.compress(_chunk(acc, (r + t) % n, chunk_n), eb_stage)
        overflow |= c.overflowed()

        def body(s, carry):
            c, overflow = carry
            c_recv, bad = _ppermute_guarded(c, axis_name, perm, guard,
                                            round_idx=s)
            recv_idx = (r - s - 1 + t) % n
            c_next, _ = comp.decompress_reduce_compress(
                c_recv, _chunk(acc, recv_idx, chunk_n), eb_stage
            )
            return c_next, overflow | bad | c_next.overflowed()

        c, overflow = lax.fori_loop(0, n - 2, body, (c, overflow))
        c_recv, bad = _ppermute_guarded(c, axis_name, perm, guard,
                                        round_idx=n - 2)
        overflow |= bad
        recv_idx = (r - (n - 2) - 1 + t) % n
        updated = comp.decompress_reduce(c_recv, _chunk(acc, recv_idx, chunk_n))
        return _set_chunk(acc, updated, recv_idx, chunk_n), chunk_n, overflow

    def body(s, carry):
        acc, overflow = carry
        send_idx = (r - s + t) % n
        recv_idx = (r - s - 1 + t) % n
        c = comp.compress(_chunk(acc, send_idx, chunk_n), eb_stage)
        overflow |= c.overflowed()
        c_recv, bad = _ppermute_guarded(c, axis_name, perm, guard,
                                        round_idx=s)
        overflow |= bad
        updated = comp.decompress_reduce(c_recv, _chunk(acc, recv_idx, chunk_n))
        return _set_chunk(acc, updated, recv_idx, chunk_n), overflow

    acc, overflow = lax.fori_loop(0, n - 1, body, (acc, overflow))
    return acc, chunk_n, overflow


# ---------------------------------------------------------------------------
# Chunked double-buffered (pipelined) ring schedule — DESIGN.md §4
# ---------------------------------------------------------------------------
#
# Each ring chunk is split into P = cfg.pipeline_chunks pieces, each a whole
# number of compressor row-tiles so the quantization grid matches the
# sequential schedule exactly.  The (step, piece) loop is flattened to
# t = s*P + p and software-pipelined with one piece of double buffering:
# the body at iteration t ppermutes the *already compressed* piece t while
# compressing piece t+1 from the pre-update accumulator.  For P >= 2 the
# piece compressed at t is never the piece reduced at t (next step's piece
# 0 was received P-1 iterations earlier), so the compress has no data
# dependency on the in-flight ppermute — XLA's scheduler is free to overlap
# them, which is the shard_map translation of the paper's multi-stream
# compress/communicate overlap.

PIECE_QUANTUM = ops.BLOCK * ops.TILE_ROWS  # elements per compressor row-tile


def _piece(x, chunk_idx, piece_idx, chunk_n, piece_n):
    return lax.dynamic_slice(
        x, (chunk_idx * chunk_n + piece_idx * piece_n,), (piece_n,)
    )


def _set_piece(x, val, chunk_idx, piece_idx, chunk_n, piece_n):
    return lax.dynamic_update_slice(
        x, val, (chunk_idx * chunk_n + piece_idx * piece_n,)
    )


def _pad_for_pipeline(x, n, p):
    """Pad flat x so each of n chunks is p pieces of whole row-tiles."""
    quantum = n * p * PIECE_QUANTUM
    total = -(-x.shape[0] // quantum) * quantum
    padded = jnp.zeros((total,), x.dtype).at[: x.shape[0]].set(x)
    return padded, total // n, total // (n * p)


def plan_ring_pipeline_chunks(n_elems: int, n_ranks: int, *, ratio: float = 20.0,
                              hw=None, fused_hop: bool = True) -> int:
    """Cost-model pipeline depth for a ring over `n_elems` f32 elements,
    capped at what the payload can actually fill with whole-tile pieces.

    The one planner every entry point (gz_allreduce auto, grad_sync
    routing) shares, so identical messages get identical schedules.
    ``fused_hop`` must match the schedule the collective will actually run
    (GZConfig.fused_hop): the single-pass hop halves the per-piece kernel
    overhead, so its optimum is deeper.
    """
    chunks = cost_model.best_pipeline_chunks(
        n_elems * 4, n_ranks, ratio,
        hw if hw is not None else cost_model.TPU_V5E, fused_hop=fused_hop,
    )
    fill = n_elems // (n_ranks * PIECE_QUANTUM)
    while chunks > 1 and chunks > fill:
        chunks //= 2
    return chunks


def _stack_trees(trees):
    """Stack a list of identical pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _index_tree(tree, i):
    return jax.tree.map(
        lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False), tree
    )


def _update_tree(tree, val, i):
    return jax.tree.map(
        lambda a, v: lax.dynamic_update_index_in_dim(a, v, i, 0), tree, val
    )


def _reduce_scatter_ring_pipelined(x, axis_name, cfg: GZConfig, eb_stage, *,
                                   owner_offset=0):
    """Chunked double-buffered ring reduce-scatter.

    Same hop structure and error budget as :func:`_reduce_scatter_ring`
    (every element is still requantized once per hop); only the schedule
    changes: compress(piece t+1) runs concurrently with ppermute(piece t).
    Returns (acc, chunk_n, overflow) with the same ownership convention.

    With ``cfg.fused_hop`` the schedule keeps the same overlap shape but
    each intermediate hop is ONE kernel: the fused hop that consumed piece
    p at step s already produced the stream piece p sends at step s+1, so
    the body only issues the next piece's ppermute (independent — its
    stream was produced P-1 hops ago) alongside the current hop's fused
    kernel.  The pending streams ride the loop carry as a stacked
    ``Compressed`` (leading axis = piece); the last step's P hops drain to
    the plain f32 accumulator.
    """
    n = _axis_size(axis_name)
    p_chunks = cfg.pipeline_chunks
    assert p_chunks >= 2, "pipelined schedule needs >= 2 pieces per chunk"
    comp = cfg.compressor()
    r = lax.axis_index(axis_name)
    acc, chunk_n, piece_n = _pad_for_pipeline(x, n, p_chunks)
    perm = _ring_perm(n)
    t0 = owner_offset
    T = (n - 1) * p_chunks

    guard = cfg.verify_streams

    if cfg.fused_hop:
        # Pipeline fill: step 0's send chunk, compressed as P pieces.
        send0 = (r + t0) % n
        overflow = jnp.zeros((), jnp.bool_)
        pend = []
        for p in range(p_chunks):
            c = comp.compress(_piece(acc, send0, p, chunk_n, piece_n), eb_stage)
            overflow |= c.overflowed()
            pend.append(c)
        pend = _stack_trees(pend)
        c_fly, bad0 = _ppermute_guarded(
            _index_tree(pend, 0), axis_name, perm, guard, round_idx=0
        )
        overflow |= bad0

        def body(u, carry):
            pend, c_fly, overflow = carry
            # Wire the NEXT hop's stream while this hop's fused kernel
            # runs: pend[(u+1) % P] was produced by hop u+1-P (or the
            # fill), so the ppermute has no dependency on this hop.
            c_fly_next, bad = _ppermute_guarded(
                _index_tree(pend, (u + 1) % p_chunks), axis_name, perm,
                guard, round_idx=(u + 1) // p_chunks,
            )
            s, p = u // p_chunks, u % p_chunks
            recv_idx = (r - s - 1 + t0) % n
            c_next, _ = comp.decompress_reduce_compress(
                c_fly, _piece(acc, recv_idx, p, chunk_n, piece_n), eb_stage
            )
            pend = _update_tree(pend, c_next, p)
            return pend, c_fly_next, overflow | bad | c_next.overflowed()

        # Fused hops cover steps 0..n-3; the last step drains below.
        pend, c_fly, overflow = lax.fori_loop(
            0, T - p_chunks, body, (pend, c_fly, overflow)
        )
        recv_last = (r - (n - 2) - 1 + t0) % n
        for p in range(p_chunks):
            if p + 1 < p_chunks:
                c_fly_next, bad = _ppermute_guarded(
                    _index_tree(pend, p + 1), axis_name, perm, guard,
                    round_idx=n - 2,
                )
                overflow |= bad
            updated = comp.decompress_reduce(
                c_fly, _piece(acc, recv_last, p, chunk_n, piece_n)
            )
            acc = _set_piece(acc, updated, recv_last, p, chunk_n, piece_n)
            if p + 1 < p_chunks:
                c_fly = c_fly_next
        return acc, chunk_n, overflow

    def send_piece(acc, t):
        s, p = t // p_chunks, t % p_chunks
        send_idx = (r - s + t0) % n
        return comp.compress(
            _piece(acc, send_idx, p, chunk_n, piece_n), eb_stage
        )

    c0 = send_piece(acc, 0)  # pipeline fill: piece 0 compressed up front
    overflow = c0.overflowed()

    def body(t, carry):
        acc, c_in, overflow = carry
        # Compress the NEXT piece from the pre-update accumulator: for
        # P >= 2 that piece was last touched at least P-1 iterations ago,
        # so this op is independent of the ppermute below (the overlap).
        c_next = send_piece(acc, t + 1)
        overflow |= c_next.overflowed()
        c_recv, bad = _ppermute_guarded(c_in, axis_name, perm, guard,
                                        round_idx=t // p_chunks)
        overflow |= bad
        s, p = t // p_chunks, t % p_chunks
        recv_idx = (r - s - 1 + t0) % n
        updated = comp.decompress_reduce(
            c_recv, _piece(acc, recv_idx, p, chunk_n, piece_n)
        )
        acc = _set_piece(acc, updated, recv_idx, p, chunk_n, piece_n)
        return acc, c_next, overflow

    acc, c_last, overflow = lax.fori_loop(0, T - 1, body, (acc, c0, overflow))
    # Pipeline drain: the final piece's hop.
    c_recv, bad = _ppermute_guarded(c_last, axis_name, perm, guard,
                                    round_idx=n - 2)
    overflow |= bad
    recv_idx = (r - (n - 2) - 1 + t0) % n
    updated = comp.decompress_reduce(
        c_recv, _piece(acc, recv_idx, p_chunks - 1, chunk_n, piece_n)
    )
    acc = _set_piece(acc, updated, recv_idx, p_chunks - 1, chunk_n, piece_n)
    return acc, chunk_n, overflow


def _compress_own_pieces(buf, own_idx, eb, cfg: GZConfig, chunk_n, piece_n,
                         overflow):
    """Compress chunk `own_idx` of `buf` as P independent pieces, installing
    the decompressed copy in place (owner sees the same values everyone
    else will).  Returns (buf, pieces tuple, overflow)."""
    comp = cfg.compressor()
    pieces = []
    for p in range(cfg.pipeline_chunks):
        c = comp.compress(_piece(buf, own_idx, p, chunk_n, piece_n), eb)
        overflow |= c.overflowed()
        buf = _set_piece(buf, comp.decompress(c), own_idx, p, chunk_n, piece_n)
        pieces.append(c)
    return buf, tuple(pieces), overflow


def _forward_pieces_ring(buf, pieces, axis_name, cfg: GZConfig, recv_idx_fn,
                         chunk_n, piece_n, round_offset=0):
    """Forward P compressed pieces around the ring for n-1 steps, installing
    decompressed copies at chunk ``recv_idx_fn(s)`` each step.

    Each piece rides its own ppermute chain, so decompress(piece p) can
    overlap the wire time of piece p+1 at every step — the chunked
    double-buffered allgather schedule.  Exactly one lossy hop per element
    (the compression happened once, at the owner).
    """
    n = _axis_size(axis_name)
    comp = cfg.compressor()
    perm = _ring_perm(n)
    guard = cfg.verify_streams

    def body(s, carry):
        buf, pieces, bad = carry
        recv_idx = recv_idx_fn(s)
        new_pieces = []
        for p, c_p in enumerate(pieces):
            c_new, b = _ppermute_guarded(c_p, axis_name, perm, guard,
                                         round_idx=round_offset + s)
            bad |= b
            buf = _set_piece(
                buf, comp.decompress(c_new), recv_idx, p, chunk_n, piece_n
            )
            new_pieces.append(c_new)
        return buf, tuple(new_pieces), bad

    buf, _, bad = lax.fori_loop(
        0, n - 1, body, (buf, pieces, jnp.zeros((), jnp.bool_))
    )
    return buf, bad


def _allgather_forward_pipelined(acc, axis_name, cfg: GZConfig, eb_stage,
                                 chunk_n, piece_n, overflow):
    """Pipelined ring-allgather forwarding stage over an RS-reduced acc."""
    n = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    acc, pieces, overflow = _compress_own_pieces(
        acc, (r + 1) % n, eb_stage, cfg, chunk_n, piece_n, overflow
    )
    acc, bad = _forward_pieces_ring(
        acc, pieces, axis_name, cfg,
        lambda s: (r - s) % n,  # chunk owned by rank (r - 1 - s)
        chunk_n, piece_n,
        round_offset=n - 1,  # allgather rounds follow the n-1 RS rounds
    )
    return acc, overflow | bad


def _allreduce_ring(x, axis_name, cfg: GZConfig):
    """Ring gZ-Allreduce: reduce-scatter stage + allgather-forwarding stage.

    The allgather stage compresses exactly once (owner) and forwards the
    *compressed* payload N-1 times (no recompression — the paper's
    data-movement framework), so it adds exactly one lossy hop.  With
    ``cfg.pipeline_chunks > 1`` both stages run the chunked
    double-buffered schedule (same lossy-hop count, overlapped pipeline).
    """
    n = _axis_size(axis_name)
    comp = cfg.compressor()
    hops = error_budget.lossy_hops("allreduce_ring", n)
    eb_stage = cfg.eb / hops if cfg.worst_case_budget else cfg.eb / math.sqrt(hops)
    r = lax.axis_index(axis_name)

    if cfg.pipeline_chunks > 1:
        acc, chunk_n, overflow = _reduce_scatter_ring_pipelined(
            x, axis_name, cfg, eb_stage
        )
        acc, overflow = _allgather_forward_pipelined(
            acc, axis_name, cfg, eb_stage, chunk_n,
            chunk_n // cfg.pipeline_chunks, overflow,
        )
        return acc[: x.shape[0]], overflow

    acc, chunk_n, overflow = _reduce_scatter_ring(x, axis_name, cfg, eb_stage)
    own_idx = (r + 1) % n

    # Allgather stage: compress own reduced chunk once; every rank (owner
    # included) uses the decompressed version so all ranks see the same
    # values for this chunk.
    c_own = comp.compress(_chunk(acc, own_idx, chunk_n), eb_stage)
    overflow |= c_own.overflowed()
    acc = _set_chunk(acc, comp.decompress(c_own), own_idx, chunk_n)
    perm = _ring_perm(n)
    guard = cfg.verify_streams

    def body(s, carry):
        acc, c_cur, bad = carry
        c_new, b = _ppermute_guarded(c_cur, axis_name, perm, guard,
                                     round_idx=(n - 1) + s)
        recv_idx = (r - s) % n  # chunk owned by rank (r - 1 - s)
        acc_new = _set_chunk(acc, comp.decompress(c_new), recv_idx, chunk_n)
        return acc_new, c_new, bad | b

    acc, _, bad = lax.fori_loop(
        0, n - 1, body, (acc, c_own, jnp.zeros((), jnp.bool_))
    )
    return acc[: x.shape[0]], overflow | bad


def _allreduce_intring(x, axis_name, cfg: GZConfig):
    """BEYOND-PAPER integer-domain ring allreduce.

    Quantize once (the only lossy step), then ring-reduce-scatter +
    ring-allgather the *integer Lorenzo-delta codes* with lossless
    repacking.  Lorenzo deltas are linear (delta(a+b) = delta(a)+delta(b))
    and anchors add, so summation happens entirely in the delta domain and
    reconstruction (anchor + cumsum) is done once at the end.  Properties
    the paper's algorithms lack:

      * bitwise-identical result on every rank (int sums are exact), and
      * a single quantization grid — error vs the true sum is the sum of N
        independent initial quantization errors (<= N*eb worst case,
        ~sqrt(N)*eb statistically) with NO stacked requantization noise.

    Wire width grows by at most log2(step) bits per block over the ring.
    """
    n = _axis_size(axis_name)
    r = lax.axis_index(axis_name)
    eb = jnp.float32(cfg.eb)
    n_orig = x.shape[0]
    B = ops.BLOCK
    # Pad so each of the n chunks is a whole number of kernel row-tiles.
    rows_per_chunk = ops.n_blocks_for(-(-n_orig // n))
    chunk_n = rows_per_chunk * B
    xf = jnp.zeros((n * chunk_n,), jnp.float32).at[:n_orig].set(x)
    # One lossy step: quantize everything (batched over all chunks).
    zig, _, anchor = ops.quantize(xf.reshape(-1, B), eb)
    d = (zig >> 1).astype(jnp.int32) ^ (-(zig & 1).astype(jnp.int32))
    state = (d, anchor)  # delta codes (nrows, B) + anchors (nrows,)

    cap = capacity_words_for(chunk_n, cfg.capacity_factor, B)
    perm = _ring_perm(n)

    def getc(t, idx):
        d, a = t
        return (
            lax.dynamic_slice(d, (idx * rows_per_chunk, 0), (rows_per_chunk, B)),
            lax.dynamic_slice(a, (idx * rows_per_chunk,), (rows_per_chunk,)),
        )

    def setc(t, val, idx):
        d, a = t
        dv, av = val
        return (
            lax.dynamic_update_slice(d, dv, (idx * rows_per_chunk, 0)),
            lax.dynamic_update_slice(a, av, (idx * rows_per_chunk,)),
        )

    def addc(a, b):
        return (a[0] + b[0], a[1] + b[1])

    def pack_codes(dc):
        dd, aa = dc
        z = ((dd << 1) ^ (dd >> 31)).astype(jnp.uint32)
        bw = _ref_bitwidth(jnp.max(z, axis=1))
        packed, nwords = bitpack.pack(z, bw, cap)
        return (packed, bw, aa), nwords

    def unpack_codes(w):
        packed, bw, aa = w
        u = bitpack.unpack(packed, bw, B)
        return ((u >> 1).astype(jnp.int32) ^ (-(u & 1).astype(jnp.int32)), aa)

    overflow = jnp.zeros((), jnp.bool_)
    guard = cfg.verify_streams

    def rs_body(s, carry):
        state, overflow = carry
        send_idx = (r - s) % n
        recv_idx = (r - s - 1) % n
        wire, nwords = pack_codes(getc(state, send_idx))
        overflow |= nwords > cap
        wire, bad = _ppermute_guarded(wire, axis_name, perm, guard,
                                      round_idx=s)
        state = setc(state, addc(getc(state, recv_idx), unpack_codes(wire)), recv_idx)
        return state, overflow | bad

    state, overflow = lax.fori_loop(0, n - 1, rs_body, (state, overflow))
    own_idx = (r + 1) % n
    wire, nwords = pack_codes(getc(state, own_idx))
    overflow |= nwords > cap

    def ag_body(s, carry):
        state, cur, bad = carry
        nxt, b = _ppermute_guarded(cur, axis_name, perm, guard,
                                   round_idx=(n - 1) + s)
        recv_idx = (r - s) % n
        state = setc(state, unpack_codes(nxt), recv_idx)
        return state, nxt, bad | b

    state, _, bad = lax.fori_loop(
        0, n - 1, ag_body, (state, wire, jnp.zeros((), jnp.bool_))
    )
    overflow |= bad
    d, anchor = state
    q = anchor[:, None] + jnp.cumsum(d, axis=1)
    out = (q.astype(jnp.float32) * (2.0 * eb)).reshape(-1)
    return out[:n_orig], overflow


def _execute_allreduce(x, axis_name, cfg: GZConfig):
    """EXECUTE layer: run a fully-resolved allreduce schedule.

    ``cfg.algo`` must be concrete — ``"auto"`` is a plan-time concern and
    lives in core/comm.py (``GZCommunicator.plan``); nothing in here may
    consult the selector or the cost model.  Returns
    ``(out, local_overflow)``; the caller owns the cross-axis OR.
    """
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    if cfg.algo == "redoub":
        out, ovf = _allreduce_redoub(flat, axis_name, cfg)
    elif cfg.algo == "ring":
        out, ovf = _allreduce_ring(flat, axis_name, cfg)
    elif cfg.algo == "intring":
        out, ovf = _allreduce_intring(flat, axis_name, cfg)
    else:
        raise ValueError(
            f"unresolved allreduce algo {cfg.algo!r} reached the execute "
            "layer — resolve a Plan via GZCommunicator.plan first"
        )
    return out.reshape(shape).astype(dtype), ovf


def _execute_allreduce_hier(x, node_axis, local_axis, hplan):
    """EXECUTE layer for the two-level (node × intra-node) allreduce.

    ``hplan`` is a fully-resolved ``comm.HierPlan``.  The flat branch runs
    the ordinary single-axis schedule over the COMPOSITE axis
    ``(node_axis, *local)`` — ppermute/psum accept tuple axis names, with
    ranks flattened node-major — so "hierarchy off" is literally the
    pre-existing code path, not a reimplementation (the bitwise-equality
    guarantee the degenerate-topology property test relies on).

    The hierarchical branch composes three stages (DESIGN.md §8):

      1. UNCOMPRESSED ``lax.psum_scatter`` over the local axis — exact
         f32 sums on the fast intra-node link; each local rank ends up
         with one fully node-reduced shard of ceil(D/L) elements.
      2. The compressed single-axis allreduce of that shard across the
         node axis (``hplan.inter`` — the ONLY lossy stage, carrying the
         whole error budget via ``error_budget.split_lossy``).
      3. UNCOMPRESSED ``lax.all_gather`` over the local axis to
         rematerialize the full message.

    ``local_axis`` may itself be a tuple of mesh axes (grad-sync collapses
    every non-node data-parallel axis into "local").
    """
    local = tuple(local_axis) if isinstance(local_axis, (tuple, list)) \
        else (local_axis,)
    if hplan.flat:
        return _execute_allreduce(
            x, (node_axis,) + local, hplan.flat_plan.as_config()
        )
    n_nodes, L = hplan.topology
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    padded, _shard_n = _pad_to_chunks(flat, L)
    if L > 1:
        shard = lax.psum_scatter(
            padded, local if len(local) > 1 else local[0],
            scatter_dimension=0, tiled=True,
        )
    else:
        shard = padded
    ovf = jnp.zeros((), jnp.bool_)
    if n_nodes > 1:
        shard, ovf = _execute_allreduce(
            shard, node_axis, hplan.inter.as_config()
        )
    if L > 1:
        padded = lax.all_gather(
            shard, local if len(local) > 1 else local[0], tiled=True
        )
    else:
        padded = shard
    return padded[: flat.shape[0]].reshape(shape).astype(dtype), ovf


def gz_allreduce_hier(
    x: jnp.ndarray,
    node_axis,
    local_axis,
    cfg: GZConfig = GZConfig(),
    *,
    return_info: bool = False,
):
    """Two-level topology-aware allreduce (back-compat-style wrapper over
    a one-shot :class:`~repro.core.comm.GZHierCommunicator`).  New code
    should hold the communicator and use its ``allreduce`` method."""
    from repro.core.comm import GZHierCommunicator

    res = GZHierCommunicator.for_axes(node_axis, local_axis, config=cfg) \
        .allreduce(x)
    return (res.value, res.overflow) if return_info else res.value


def _comm_for(axis_name, cfg: GZConfig):
    from repro.core.comm import GZCommunicator

    return GZCommunicator.for_config(axis_name, cfg)


def gz_allreduce(
    x: jnp.ndarray,
    axis_name,
    cfg: GZConfig = GZConfig(),
    *,
    return_info: bool = False,
):
    """Compression-accelerated allreduce (sum) over a mesh axis.

    Call inside a shard_map body.  ``x`` may have any shape/float dtype;
    compression runs on the f32 flat view and the result is cast back.

    Back-compat wrapper over a one-shot :class:`~repro.core.comm.
    GZCommunicator` (bitwise-identical to ``comm.allreduce(x).value``);
    ``return_info=True`` unpacks the ``CollectiveResult`` into the legacy
    ``(value, overflow)`` tuple.  New code should hold a communicator.
    """
    res = _comm_for(axis_name, cfg).allreduce(x)
    return (res.value, res.overflow) if return_info else res.value


# ---------------------------------------------------------------------------
# Reduce_scatter / Allgather — the ring stages standalone
# ---------------------------------------------------------------------------


def _execute_reduce_scatter(x, axis_name, cfg: GZConfig):
    """EXECUTE layer for the ring reduce-scatter (concrete schedule)."""
    n = _axis_size(axis_name)
    if x.ndim != 1 or x.shape[0] % n != 0:
        raise ValueError(
            f"gz_reduce_scatter over axis {axis_name!r} (size {n}): the "
            "payload must be flat with length divisible by the axis size "
            f"(rank r returns summed chunk r); got shape {tuple(x.shape)}"
        )
    eb_stage = error_budget.allocate(
        cfg.eb, "reduce_scatter_ring", n, worst_case=cfg.worst_case_budget
    )
    r = lax.axis_index(axis_name)
    flat = x.astype(jnp.float32)
    chunk_in = x.shape[0] // n
    if cfg.pipeline_chunks > 1:
        # Chunk boundaries are caller semantics: pad each chunk (not the
        # flat tail) so every chunk is pipeline_chunks whole-tile pieces.
        quantum = cfg.pipeline_chunks * PIECE_QUANTUM
        chunk_pad = -(-chunk_in // quantum) * quantum
        flat = (
            jnp.zeros((n, chunk_pad), jnp.float32)
            .at[:, :chunk_in]
            .set(flat.reshape(n, chunk_in))
            .reshape(-1)
        )
        acc, chunk_n, ovf = _reduce_scatter_ring_pipelined(
            flat, axis_name, cfg, eb_stage, owner_offset=-1
        )
    else:
        # owner_offset=-1 makes rank r end owning chunk r (see derivation in
        # _reduce_scatter_ring docstring).
        acc, chunk_n, ovf = _reduce_scatter_ring(
            flat, axis_name, cfg, eb_stage, owner_offset=-1
        )
    return _chunk(acc, r % n, chunk_n)[:chunk_in].astype(x.dtype), ovf


def gz_reduce_scatter(
    x: jnp.ndarray, axis_name, cfg: GZConfig = GZConfig(), *, return_info: bool = False
):
    """Ring reduce-scatter: rank r returns the summed chunk r (flat view).

    x: (n*chunk,) per rank (same on-wire layout as lax.psum_scatter with
    tiled=True over a flat array).  Back-compat wrapper over the one-shot
    communicator — ``comm.reduce_scatter`` returns the full
    ``CollectiveResult``.
    """
    res = _comm_for(axis_name, cfg).reduce_scatter(x)
    return (res.value, res.overflow) if return_info else res.value


def _execute_allgather(x, axis_name, cfg: GZConfig):
    """EXECUTE layer for the ring allgather (concrete schedule)."""
    n = _axis_size(axis_name)
    comp = cfg.compressor()
    r = lax.axis_index(axis_name)
    dtype = x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    n_orig = flat.shape[0]

    if cfg.pipeline_chunks > 1:
        quantum = cfg.pipeline_chunks * PIECE_QUANTUM
        chunk_n = -(-n_orig // quantum) * quantum
        piece_n = chunk_n // cfg.pipeline_chunks
        own_chunk = jnp.zeros((chunk_n,), jnp.float32).at[:n_orig].set(flat)
        padded = lax.dynamic_update_slice(
            jnp.zeros((n * chunk_n,), jnp.float32), own_chunk, (r * chunk_n,)
        )
        out, pieces, ovf = _compress_own_pieces(
            padded, r, cfg.eb, cfg, chunk_n, piece_n, jnp.zeros((), jnp.bool_)
        )
        out, bad = _forward_pieces_ring(
            out, pieces, axis_name, cfg,
            lambda s: (r - s - 1) % n,  # piece sent by rank (r - 1 - s)
            chunk_n, piece_n,
        )
        ovf |= bad
        out = out.reshape(n, chunk_n)[:, :n_orig].reshape(-1)
        out = out.reshape((n * x.shape[0],) + x.shape[1:]) if x.ndim else out
        return out.astype(dtype), ovf

    chunk_n = n_orig
    out = jnp.zeros((n * chunk_n,), jnp.float32)
    c_own = comp.compress(flat, cfg.eb)
    ovf = c_own.overflowed()
    out = _set_chunk(out, comp.decompress(c_own), r, chunk_n)
    perm = _ring_perm(n)
    guard = cfg.verify_streams

    def body(s, carry):
        out, c_cur, bad = carry
        c_new, b = _ppermute_guarded(c_cur, axis_name, perm, guard,
                                     round_idx=s)
        src = (r - s - 1) % n
        out = _set_chunk(out, comp.decompress(c_new), src, chunk_n)
        return out, c_new, bad | b

    out, _, bad = lax.fori_loop(
        0, n - 1, body, (out, c_own, jnp.zeros((), jnp.bool_))
    )
    out = out.reshape((n * x.shape[0],) + x.shape[1:]) if x.ndim else out
    return out.astype(dtype), ovf | bad


def gz_allgather(
    x: jnp.ndarray, axis_name, cfg: GZConfig = GZConfig(), *, return_info: bool = False
):
    """Ring allgather: compress once, forward compressed N-1 times.

    x: (chunk,) per rank -> returns (n*chunk,) with rank j's data at slot j.
    Exactly one lossy hop end-to-end (data-movement framework): the returned
    slot j holds decompress(compress(x_j)) on *every* rank including j.
    Back-compat wrapper over the one-shot communicator.
    """
    res = _comm_for(axis_name, cfg).allgather(x)
    return (res.value, res.overflow) if return_info else res.value


# ---------------------------------------------------------------------------
# Scatter / Broadcast — collective data movement (paper §3.3.4 / Fig. 5)
# ---------------------------------------------------------------------------


def _wire_container(comp, packed, bitwidth, anchor, eb, n) -> Compressed:
    """Rebuild a ``Compressed`` from bare wire parts on the receive side
    (the batched scatter/all-to-all paths ship the leaves, not the pytree);
    the true stream size is recomputed from the codec's own metadata."""
    return Compressed(
        packed=packed, bitwidth=bitwidth, anchor=anchor,
        nwords=comp.stream_nwords(bitwidth, n),
        eb=jnp.asarray(eb, jnp.float32), n=n, block=ops.BLOCK,
    )


def _scatter_held_buffers(x_full, n, cfg: GZConfig):
    """Batched per-chunk compression into the tree's held buffers.

    Each chunk is padded to whole row-tiles so chunk boundaries align with
    block boundaries, then ONE quantize call covers all chunks (the
    multi-stream analog: what N CUDA streams did in the paper, one grid
    does here).  Held buffers live in a virtual ``2**ceil(log2 n)`` rank
    space (zero streams in the padding slots) so slab indexing is uniform;
    under the trimmed schedule the padding slots never travel and are never
    read — they exist only to keep the ``dynamic_slice`` extents static.
    Returns ``(held (packed, bw, anchor), rows, chunk_n, n_virt, ovf)``.
    """
    chunk_n = x_full.shape[0] // n
    rows = ops.n_blocks_for(chunk_n)
    B = ops.BLOCK
    chunks = x_full.astype(jnp.float32).reshape(n, chunk_n)
    n_virt = 1 << cost_model.steps_for("binomial", n)
    if cfg.codec != "lorenzo":
        # Non-default codecs go through the compressor interface per chunk
        # (their pack kernels are not batched across chunk boundaries);
        # the held-buffer layout (packed, bitwidth, anchor) is identical.
        comp = cfg.compressor()
        ovf = jnp.zeros((), jnp.bool_)
        cs = []
        for i in range(n):
            c = comp.compress(chunks[i], cfg.eb)
            cs.append(c)
            ovf |= c.overflowed()
        packed0 = jnp.stack([c.packed for c in cs])
        bw = jnp.stack([c.bitwidth for c in cs])
        anchor = jnp.stack([c.anchor for c in cs])
    else:
        x2d = (
            jnp.zeros((n, rows * B), jnp.float32).at[:, :chunk_n].set(chunks)
        ).reshape(n * rows, B)
        codes, bw, anchor = ops.quantize(x2d, cfg.eb)
        cap = capacity_words_for(chunk_n, cfg.capacity_factor, B)
        ovf = jnp.zeros((), jnp.bool_)
        pk_list = []
        for i in range(n):
            pk, nw = bitpack.pack(
                codes[i * rows : (i + 1) * rows],
                bw[i * rows : (i + 1) * rows], cap
            )
            pk_list.append(pk)
            ovf |= nw > cap
        packed0 = jnp.stack(pk_list)  # (n, cap)
        bw = bw.reshape(n, rows)
        anchor = anchor.reshape(n, rows)
    held = (
        jnp.zeros((n_virt,) + packed0.shape[1:], packed0.dtype).at[:n].set(
            packed0),
        jnp.zeros((n_virt, rows), bw.dtype).at[:n].set(bw),
        jnp.zeros((n_virt, rows), anchor.dtype).at[:n].set(anchor),
    )
    return held, rows, chunk_n, n_virt, ovf


def _slab_exchange(held, axis_name, r, perm, start, slab, n_virt, is_recv,
                   guard=False, round_idx=None):
    """Ship a ``slab``-chunk window of the held buffers along ``perm`` and
    install it at the receiver's own rank index (everyone else keeps its
    buffer).  One static ppermute shape per call.  Returns
    ``(held, bad)`` — ``bad`` is the receive-side stream-verification
    flag (always False when ``guard`` is off), masked to actual
    receivers."""
    piece = jax.tree.map(
        lambda h: lax.dynamic_slice(
            h, (start % n_virt,) + (0,) * (h.ndim - 1),
            (slab,) + h.shape[1:],
        ),
        held,
    )
    recv, bad = _ppermute_guarded(piece, axis_name, perm, guard,
                                  round_idx=round_idx)
    installed = jax.tree.map(
        lambda h, rv: lax.dynamic_update_slice(
            h, rv, (r,) + (0,) * (h.ndim - 1)
        ),
        held,
        recv,
    )
    held = jax.tree.map(
        lambda new, old: jnp.where(is_recv, new, old), installed, held
    )
    return held, bad & is_recv


def _scatter_tree_trimmed(held, axis_name, r, n, n_virt, cfg: GZConfig):
    """Trimmed-slab binomial tree (DESIGN.md §7): each round ships only
    the real ranks of the receiver's subtree.

    The schedule comes from ``schedule.tree_plan`` — the route table the
    plan layer prices and the simulator replays, with each round's
    ``ppermute`` perm taken verbatim from the table's hop entries.  Per
    round: the full-span exchanges (receiver subtree entirely real) run
    as today, split into ``cfg.pipeline_chunks`` piece-permute chains;
    the at most one boundary exchange ships its ``n - receiver`` real
    chunks as ONE extra ppermute shape (its slab size is not a power of
    two, so it is not piece-split).  The padding slots of the held
    buffers never travel: the root ships exactly n-1 chunk streams at
    any axis size.
    """
    guard = cfg.verify_streams
    corrupt = jnp.zeros((), jnp.bool_)
    for k, (span, full_senders, trim, perm) in enumerate(
        schedule.tree_plan(n)
    ):
        start = r + span  # sender's outgoing slab start (own subtree's right half)
        # The table lists the full-span entries first, then the at most
        # one trimmed boundary entry — slice, don't re-derive.
        perm_full = perm[: len(full_senders)]
        if full_senders:
            # Full receivers: the span-aligned odd subtree heads whose
            # whole virtual subtree is real.
            is_recv = ((r % (span * 2)) == span) & (r + span <= n)
            groups = min(max(cfg.pipeline_chunks, 1), span)
            sub = span // groups
            for g in range(groups):
                held, bad = _slab_exchange(
                    held, axis_name, r + g * sub, perm_full,
                    start + g * sub, sub, n_virt, is_recv, guard,
                    round_idx=k,
                )
                corrupt |= bad
        if trim is not None:
            snd, rcv, slab = trim
            held, bad = _slab_exchange(
                held, axis_name, r, perm[len(full_senders):], start, slab,
                n_virt, r == rcv, guard, round_idx=k,
            )
            corrupt |= bad
    return held, corrupt


def _scatter_tree_padded_reference(held, axis_name, r, n, n_virt,
                                   cfg: GZConfig):
    """The PR 4 padded virtual-tree walk, kept verbatim as the byte-parity
    ORACLE for the trimmed schedule (tests only — every real rank must
    decode identical bytes from both walks; see the multi-device children).
    Round k ships a full 2**k-chunk slab — padding chunks included — from
    each sender ``i % 2**(k+1) == 0`` to ``i + 2**k``.
    """
    steps = n_virt.bit_length() - 1
    corrupt = jnp.zeros((), jnp.bool_)
    for k in reversed(range(steps)):
        span = 1 << k
        # schedule-authority: allow — PR 4 byte-parity oracle, kept verbatim
        perm = [(i, i + span) for i in range(0, n_virt, span * 2)
                if i + span < n]
        is_recv = (r % (span * 2)) == span
        groups = min(max(cfg.pipeline_chunks, 1), span)
        sub = span // groups
        for g in range(groups):
            held, bad = _slab_exchange(
                held, axis_name, r + g * sub, perm, r + span + g * sub,
                sub, n_virt, is_recv, cfg.verify_streams,
            )
            corrupt |= bad
    return held, corrupt


def _execute_scatter(x_full, axis_name, cfg: GZConfig, *, root: int = 0,
                     _padded_reference: bool = False):
    """EXECUTE layer for the binomial-tree scatter (concrete schedule).

    Arbitrary axis sizes run the TRIMMED-SLAB schedule (DESIGN.md §7):
    ``ceil(log2 n)`` rounds over a virtual power-of-two rank space, but
    each exchange ships only the real ranks of the receiver's subtree
    (``schedule.tree_plan``), so the root's provisioned wire
    is exactly n-1 chunk streams at any n — the virtual tree's padding
    chunks are held locally (zero streams keeping slab arithmetic static)
    and never travel.  On power-of-two axes the schedule is identical to
    the classic binomial tree.  ``_padded_reference=True`` runs the PR 4
    padded walk instead (test oracle; same bytes at every real rank).
    """
    n = _axis_size(axis_name)
    if root != 0:
        raise ValueError(
            f"gz_scatter over axis {axis_name!r} (size {n}): only root 0 "
            f"is supported (the binomial tree is rooted at rank 0); got "
            f"root={root}.  Roll the payload so the source rank is 0."
        )
    if x_full.shape[0] % n != 0:
        raise ValueError(
            f"gz_scatter over axis {axis_name!r} (size {n}): the full "
            "payload's leading dim must be divisible by the axis size "
            f"(each rank receives one chunk); got shape "
            f"{tuple(x_full.shape)}"
        )
    r = lax.axis_index(axis_name)
    dtype = x_full.dtype
    held, rows, chunk_n, n_virt, ovf = _scatter_held_buffers(x_full, n, cfg)
    tree = (_scatter_tree_padded_reference if _padded_reference
            else _scatter_tree_trimmed)
    (held_packed, held_bw, held_anchor), corrupt = tree(
        held, axis_name, r, n, n_virt, cfg
    )

    # Only the root compresses significant data; the SPMD packs of the
    # other ranks' local buffers are meaningless and must not pollute the
    # global overflow OR below.  Wire corruption is a receive-side event
    # and is NOT root-masked: a corrupted stream is unusable wherever it
    # lands.
    ovf = (ovf & (r == 0)) | corrupt

    # Decompress own chunk (the single lossy hop).
    my_pk = jnp.take(held_packed, r, axis=0)
    my_bw = jnp.take(held_bw, r, axis=0)
    my_anchor = jnp.take(held_anchor, r, axis=0)
    if cfg.codec != "lorenzo":
        comp = cfg.compressor()
        c = _wire_container(comp, my_pk, my_bw, my_anchor, cfg.eb, chunk_n)
        return comp.decompress(c).astype(dtype), ovf
    if cfg.fused:
        x2d = ops.unpack_dequantize(my_pk, my_bw, my_anchor, cfg.eb)
    else:
        my_codes = bitpack.unpack(my_pk, my_bw, ops.BLOCK)
        x2d = ops.dequantize(my_codes, my_anchor, cfg.eb)
    return ops.from_blocks(x2d, chunk_n).astype(dtype), ovf


def gz_scatter(
    x_full: jnp.ndarray,
    axis_name,
    cfg: GZConfig = GZConfig(),
    *,
    root: int = 0,
    return_info: bool = False,
):
    """Binomial-tree compressed scatter (gZ-Scatter).

    ``x_full``: (n*chunk,) — significant on the root rank only.  Each of the
    N chunks is compressed *individually* (compressed streams are not
    splittable — paper §3.3.4), in ONE batched quantize call: the
    multi-stream analog.  Blocks travel compressed through the tree and are
    decompressed exactly once by their final owner.  Back-compat wrapper
    over the one-shot communicator.
    """
    res = _comm_for(axis_name, cfg).scatter(x_full, root=root)
    return (res.value, res.overflow) if return_info else res.value


def gz_all_to_all(x: jnp.ndarray, axis_name, cfg: GZConfig = GZConfig()):
    """Compressed all-to-all (beyond-paper; motivated by the MoE-dispatch
    ablation in benchmarks/moe_a2a_ablation.py).

    x: (n*chunk, ...) per rank — slot buffers grouped by destination rank
    along the leading dim.  Each destination chunk is compressed
    individually (ONE batched quantize — the multi-stream analog), the
    packed buffers travel through ``lax.all_to_all``, and each rank
    decompresses what it received.  Exactly one lossy hop per element.
    Returns (n*chunk, ...) with the received chunks stacked in rank order.

    Differentiable (straight-through the quantizer): the rank-exchange
    layout is self-inverse, so the transpose is the same compressed
    exchange applied to the cotangent — the custom_vjp lives on the
    plan-dispatched ``comm._a2a_planned``.  Back-compat wrapper over the
    one-shot communicator; ``comm.all_to_all`` also reports overflow/wire
    stats via ``CollectiveResult``.
    """
    return _comm_for(axis_name, cfg).all_to_all(x).value


def _execute_all_to_all(x, axis_name, cfg: GZConfig):
    """EXECUTE layer for the compressed rank exchange (one lossy hop)."""
    n = _axis_size(axis_name)
    if x.shape[0] % n != 0:
        raise ValueError(
            f"gz_all_to_all over axis {axis_name!r} (size {n}): the leading "
            "dim must be divisible by the axis size (slot buffers grouped "
            f"by destination rank); got shape {tuple(x.shape)}"
        )
    shape, dtype = x.shape, x.dtype
    chunk_rows = x.shape[0] // n
    chunk_n = chunk_rows * int(np.prod(shape[1:])) if len(shape) > 1 else chunk_rows
    B = ops.BLOCK
    rows = ops.n_blocks_for(chunk_n)
    flat = x.reshape(n, chunk_n).astype(jnp.float32)
    if cfg.codec != "lorenzo":
        comp = cfg.compressor()
        ovf = jnp.zeros((), jnp.bool_)
        cs = []
        for i in range(n):
            c = comp.compress(flat[i], cfg.eb)
            cs.append(c)
            ovf |= c.overflowed()
        packed = jnp.stack([c.packed for c in cs])
        bw = jnp.stack([c.bitwidth for c in cs])
        anchor = jnp.stack([c.anchor for c in cs])
    else:
        x2d = (
            jnp.zeros((n, rows * B), jnp.float32).at[:, :chunk_n].set(flat)
        ).reshape(n * rows, B)
        codes, bw, anchor = ops.quantize(x2d, cfg.eb)
        cap = capacity_words_for(chunk_n, cfg.capacity_factor, B)
        ovf = jnp.zeros((), jnp.bool_)
        pk = []
        for i in range(n):
            p, nw = bitpack.pack(
                codes[i * rows : (i + 1) * rows],
                bw[i * rows : (i + 1) * rows], cap
            )
            pk.append(p)
            ovf |= nw > cap
        packed = jnp.stack(pk)  # (n, cap)
        bw = bw.reshape(n, rows)
        anchor = anchor.reshape(n, rows)
    # ship: tiled=False removes the leading (== axis size) dim and stacks
    # the received peers' chunks back at position 0
    recv = jax.tree.map(
        lambda a: lax.all_to_all(a, axis_name, split_axis=0, concat_axis=0,
                                 tiled=False),
        (packed, bw, anchor),
    )
    rp, rb, ra = recv
    out = []
    if cfg.codec != "lorenzo":
        comp = cfg.compressor()
        for i in range(n):
            c = _wire_container(comp, rp[i], rb[i], ra[i], cfg.eb, chunk_n)
            out.append(comp.decompress(c))
    else:
        for i in range(n):
            if cfg.fused:
                x2d = ops.unpack_dequantize(rp[i], rb[i], ra[i], cfg.eb)
            else:
                c = bitpack.unpack(rp[i], rb[i], B)
                x2d = ops.dequantize(c, ra[i], cfg.eb)
            out.append(ops.from_blocks(x2d, chunk_n))
    out = jnp.stack(out).reshape(shape).astype(dtype)
    return out, ovf


def _execute_broadcast(x, axis_name, cfg: GZConfig, *, root: int = 0):
    """EXECUTE layer for the binomial-tree broadcast (concrete schedule).

    Arbitrary axis sizes: ``ceil(log2 n)`` rounds of halving spans whose
    forwarding pairs come from the SAME trimmed schedule authority as the
    scatter (``schedule.tree_plan`` — the full-span pairs plus the
    at-most-one trimmed boundary pair per round; exchanges whose
    receiver does not exist never appear).  The payload is the one full
    compressed message either way, so trimming changes no bytes here — it
    guarantees schedule/accounting cannot drift (DESIGN.md §7): every real
    rank's sender chain stays inside the real ranks, coverage and the
    one-lossy-hop property are unchanged.
    """
    n = _axis_size(axis_name)
    if root != 0:
        raise ValueError(
            f"gz_broadcast over axis {axis_name!r} (size {n}): only root 0 "
            f"is supported (the binomial tree is rooted at rank 0); got "
            f"root={root}."
        )
    comp = cfg.compressor()
    r = lax.axis_index(axis_name)
    shape, dtype = x.shape, x.dtype
    c = comp.compress(x.reshape(-1).astype(jnp.float32), cfg.eb)
    # Non-root ranks compress their (insignificant) local x in SPMD; only
    # the root's stream travels, so only its flag is meaningful.
    ovf = c.overflowed() & (r == 0)
    guard = cfg.verify_streams
    for k, (span, _full, _trim, perm) in enumerate(schedule.tree_plan(n)):
        c_recv, bad = _ppermute_guarded(c, axis_name, perm, guard,
                                        round_idx=k)
        has = (r % (span * 2)) == span
        ovf |= bad & has
        c = jax.tree.map(lambda new, old: jnp.where(has, new, old), c_recv, c)
    return comp.decompress(c).reshape(shape).astype(dtype), ovf


def gz_broadcast(
    x: jnp.ndarray,
    axis_name,
    cfg: GZConfig = GZConfig(),
    *,
    root: int = 0,
    return_info: bool = False,
):
    """Binomial-tree compressed broadcast: compress once at root, forward
    the compressed stream down the tree, decompress once per rank.
    Back-compat wrapper over the one-shot communicator."""
    res = _comm_for(axis_name, cfg).broadcast(x, root=root)
    return (res.value, res.overflow) if return_info else res.value
