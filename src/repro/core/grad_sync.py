"""Training-loop integration of the gZCCL collectives.

Three entry points, all rank-centric (call inside shard_map bodies):

  * ``dp_allreduce_grads``   — gradient sync across data-parallel axes
    (the paper's headline Allreduce, applied where a training framework
    actually spends its collective bytes).  Multiple axes resolve ONE
    two-level plan (``GZHierCommunicator``): exact uncompressed sums on
    the fast intra-node axes, compression only on the slow inter-node
    hop — or a single flat composite-axis schedule when the fabric has
    no link asymmetry (DESIGN.md §8).
  * ``fsdp_all_gather``      — ZeRO-3 parameter gather, differentiable:
    forward is a (optionally compressed) allgather, backward is the
    matching (optionally compressed) reduce-scatter — the [29] pattern,
    with gZ error control.
  * ``fsdp_reduce_scatter``  — the standalone gradient-shard path.

All compressed traffic goes through per-axis ``GZCommunicator``s
(core/comm.py): the plan — algorithm, ring pipeline depth, per-stage eb —
is resolved once per (op, bytes, axis) and memoized, so the scan body
below contains zero selector logic.  ``SyncConfig.pipeline_chunks == 0``
(the default) asks the communicator to plan the ring depth from the cost
model; > 0 forces that depth.

Gradients are scale-free, so the error bound can be made *relative*: with
``relative_eb=True`` the absolute eb is eb * global RMS of the tensor
(one scalar psum — cheap, and identical on every rank so quantization
grids agree).

Large pytrees are tiled by a deterministic ``BucketLedger``
(core/buckets.py) into equal ``bucket_bytes`` payloads, issued
last-layer-first under ``lax.scan`` — the compiled HLO stays small, each
compression call is big enough to saturate the device (the paper's
utilization argument), and the bucket boundary is exactly where
``launch/training.py`` cuts its backward-overlap ``custom_vjp`` hooks.
The bucketed path is bitwise-identical to the retained whole-tree
reference (``_dp_allreduce_whole_tree_stats``): bucket payloads are the
old chunk scan's rows, the RMS scale comes from one shared per-leaf
sum-of-squares, and each bucket's collective is independent, so issue
order cannot change values (asserted on multi-device meshes in
tests/_mp_gradsync_child.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.buckets import ledger_for
from repro.core.collectives import GZConfig, _axis_size
from repro.core.comm import GZCommunicator, GZHierCommunicator

__all__ = [
    "SyncConfig",
    "SyncStats",
    "dp_allreduce_grads",
    "dp_allreduce_grads_stats",
    "fsdp_all_gather",
    "fsdp_reduce_scatter",
    "fsdp_reduce_scatter_stats",
]


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """How gradients cross the wire.

    ``bucket_bytes``: f32 payload of one compressed collective call — the
    BucketLedger's wire quantum (the historic module-global ``CHUNK`` of
    4 Mi elements, now a validated per-config knob).  Small trees clamp
    to one bucket.

    ``pipeline_chunks``: 0 (default) lets the communicator plan the ring
    pipeline depth from the cost model per (bucket bytes, axis size) — the
    chunked double-buffered schedule of DESIGN.md §4; > 0 forces that
    depth; the knob is ignored by non-ring algorithms (redoub/intring
    take no chunk schedule).

    ``mark_degraded``: GradScaler-style poisoning of the FSDP backward —
    a reduce-scatter that overflowed or saw non-finite input returns a
    NaN-marked cotangent instead of silently corrupted values.  The only
    dataflow out of a ``custom_vjp`` backward is the cotangent itself, so
    this is how the sharded-axis reduce-scatter's health bit reaches
    ``skip_on_overflow`` (launch/training.py threads it via the per-leaf
    nonfinite check in ``_sync_grads``).  Off by default: without a skip
    handler downstream, a NaN step is worse than a flagged lossy one.
    """

    gz: GZConfig | None = GZConfig(eb=1e-4, algo="redoub", worst_case_budget=False)
    relative_eb: bool = True
    bucket_bytes: int = 16 * 1024 * 1024
    pipeline_chunks: int = 0
    mark_degraded: bool = False

    def __post_init__(self):
        # Fail at construction time, not inside a traced scan body.
        if self.pipeline_chunks < 0 or (
            self.pipeline_chunks > 0
            and self.pipeline_chunks & (self.pipeline_chunks - 1)
        ):
            raise ValueError(
                "SyncConfig.pipeline_chunks must be 0 (plan the ring depth "
                "from the cost model) or a power of two >= 1 (forced "
                f"depth); got {self.pipeline_chunks!r}"
            )
        if (not isinstance(self.bucket_bytes, int)
                or self.bucket_bytes < 4 or self.bucket_bytes % 4):
            raise ValueError(
                "SyncConfig.bucket_bytes must be a positive multiple of 4 "
                "(whole f32 elements per bucket payload); got "
                f"{self.bucket_bytes!r}"
            )

    def with_algo(self, algo: str) -> "SyncConfig":
        if self.gz is None:
            raise ValueError(
                "SyncConfig.with_algo: this SyncConfig has gz=None "
                "(uncompressed psum sync) — there is no GZConfig to set an "
                "algorithm on; construct one explicitly, e.g. "
                "SyncConfig(gz=GZConfig(algo=...))"
            )
        return dataclasses.replace(
            self, gz=dataclasses.replace(self.gz, algo=algo)
        )


# The shared default: dataclass instances are frozen but a mutable-default
# in the signature (`sync=SyncConfig()`) still evaluates ONCE at import and
# aliases every call — callers pass None and the functions resolve it here.
DEFAULT_SYNC = SyncConfig()


def _resolve_sync(sync: "SyncConfig | None") -> "SyncConfig":
    return DEFAULT_SYNC if sync is None else sync


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SyncStats:
    """Health flags of one gradient sync, OR-ed across every bucket.

    ``overflow``/``nonfinite`` are replicated bool scalars (they come out
    of ``CollectiveResult`` already psum-combined across the axes), so
    they are safe predicates for a skip-step ``jnp.where`` and identical
    on every rank.  The old single-return ``dp_allreduce_grads`` used to
    DROP these flags on the scan floor — a silent-corruption hazard when
    ``on_overflow="flag"`` — hence the ``_stats`` entry point.

    ``wire_bytes``/``n_buckets`` are STATIC provisioning facts aggregated
    across the ledger (pytree aux data, safe through jit): the per-rank
    bytes the resolved plans ship for the whole tree, and how many bucket
    collectives carried them.
    """

    overflow: jnp.ndarray
    nonfinite: jnp.ndarray
    wire_bytes: int = dataclasses.field(
        default=0, metadata=dict(static=True))
    n_buckets: int = dataclasses.field(
        default=0, metadata=dict(static=True))

    @property
    def degraded(self) -> jnp.ndarray:
        """True iff this sync overflowed or saw non-finite input (the
        GradScaler-style skip predicate)."""
        return self.overflow | self.nonfinite


def _comm(axis_name, sync: "SyncConfig") -> GZCommunicator:
    """The per-axis communicator for this sync policy (memoized).

    A forced ``sync.pipeline_chunks`` is written into the knobs; otherwise
    ``auto_depth`` asks the plan to pick the ring depth even when the
    algorithm was requested explicitly (the grad-sync routing rule).
    """
    cfg = sync.gz
    if sync.pipeline_chunks > 0:
        cfg = dataclasses.replace(cfg, pipeline_chunks=sync.pipeline_chunks)
        return GZCommunicator.for_config(axis_name, cfg)
    return GZCommunicator.for_config(axis_name, cfg, auto_depth=True)


def _hier_comm(axis_names, sync: "SyncConfig") -> GZHierCommunicator:
    """The two-level communicator for a multi-axis sync (memoized).

    Axis convention (matching the callers' inner-fast-first ordering):
    the LAST axis is the slow inter-node hop ("pod"/"node" — outermost in
    the mesh), everything before it is collapsed into the fast local
    level.  The topology is read from the shard_map trace per call, so
    one memoized communicator replans across reshaped meshes.
    """
    node = axis_names[-1]
    local = axis_names[0] if len(axis_names) == 2 else tuple(axis_names[:-1])
    cfg = sync.gz
    if sync.pipeline_chunks > 0:
        cfg = dataclasses.replace(cfg, pipeline_chunks=sync.pipeline_chunks)
        return GZHierCommunicator.for_axes(node, local, config=cfg)
    return GZHierCommunicator.for_axes(node, local, config=cfg,
                                       auto_depth=True)


def _global_rms(flat: jnp.ndarray, axis_names) -> jnp.ndarray:
    # ONE multi-axis psum (a single reduction tree) instead of one round
    # per axis; the element count is static (axis sizes are trace-time
    # constants), so only the sum-of-squares travels.
    ss = lax.psum(jnp.sum(flat.astype(jnp.float32) ** 2), tuple(axis_names))
    cnt = float(flat.size)
    for ax in axis_names:
        cnt *= _axis_size(ax)
    return jnp.sqrt(ss / max(cnt, 1.0))


def _tree_scale(leaves_f32, axis_names) -> jnp.ndarray:
    """The relative-eb scale for a LIST of 1-D f32 leaves.

    Per-leaf sums of squares accumulated in leaf order, then ONE
    multi-axis psum — the single scale authority shared by the bucketed
    path and the whole-tree reference: f32 summation order changes last
    bits, so both paths computing it the same way is a precondition of
    their bitwise-identity contract.
    """
    ss = jnp.zeros((), jnp.float32)
    cnt = 0.0
    for leaf in leaves_f32:
        ss = ss + jnp.sum(leaf ** 2)
        cnt += float(leaf.size)
    ss = lax.psum(ss, tuple(axis_names))
    for ax in axis_names:
        cnt *= _axis_size(ax)
    scale = jnp.maximum(jnp.sqrt(ss / max(cnt, 1.0)), 1e-30)
    # A non-finite gradient poisons the RMS too; pin the scale so the
    # fallback's sanitized sum still rescales to something finite.
    return jnp.where(jnp.isfinite(scale), scale, jnp.ones_like(scale))


def _scan_allreduce(payloads: jnp.ndarray, axis_names, sync: SyncConfig):
    """allreduce each row of ``payloads`` ((K, B), any row order) through
    the per-axis / two-level communicator under one ``lax.scan``.

    Returns ``(synced_rows, ovf, nf, wire_bytes_per_row)`` — each row's
    collective is independent (same frozen Plan, same quantization grid
    per row content), which is exactly why the bucketed caller may feed
    rows last-layer-first and stay bitwise-identical to the ravel-order
    reference.
    """
    no = jnp.zeros((), jnp.bool_)
    wires: list = []
    if len(axis_names) == 1:
        comm = _comm(axis_names[0], sync)

        def body(carry, xc):
            o, f = carry
            res = comm.allreduce(xc)
            wires.append(res.wire_bytes)
            return (o | res.overflow, f | res.nonfinite), res.value
    else:
        # ONE two-level plan over node × local replaces the sequential
        # per-axis allreduce loop: compression runs only on the slow
        # inter-node hop (or the planner falls back to a single flat
        # composite-axis schedule when the fabric has no asymmetry).
        hcomm = _hier_comm(axis_names, sync)

        def body(carry, xc):
            o, f = carry
            res = hcomm.allreduce(xc)
            wires.append(res.wire_bytes)
            return (o | res.overflow, f | res.nonfinite), res.value

    (ovf, nf), synced = lax.scan(body, (no, no), payloads)
    # The scan body traces ONCE; its static wire provision applies to
    # every row (uniform payload shape -> one frozen Plan).
    return synced, ovf, nf, int(wires[0]) if wires else 0


def _psum_tree_stats(leaves, axis_names):
    """The gz=None path: plain per-leaf psum (elementwise — identical to
    the historic whole-ravel psum) + one nonfinite probe."""
    axes = tuple(axis_names)
    out = [lax.psum(leaf, axes) for leaf in leaves]
    bad = jnp.zeros((), jnp.bool_)
    for leaf in leaves:
        bad = bad | jnp.any(~jnp.isfinite(leaf))
    nf = lax.psum(bad.astype(jnp.int32), axes) > 0
    no = jnp.zeros((), jnp.bool_)
    raw = 4 * sum(int(leaf.size) for leaf in leaves)
    return out, SyncStats(overflow=no, nonfinite=nf,
                          wire_bytes=raw, n_buckets=0)


def _flatten_grads(grads):
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        raise ValueError(
            "dp_allreduce_grads: empty gradient pytree — nothing to sync "
            "(a silent no-op here would skip gradient sync)"
        )
    return leaves, treedef


def dp_allreduce_grads_stats(
    grads, axis_names: Sequence[str], sync: SyncConfig | None = None
):
    """Sum a gradient pytree across data-parallel mesh axes (gZ-accelerated).

    Returns ``(summed_pytree, SyncStats)`` — callers divide by the DP
    degree for a mean, and should consult ``stats.degraded`` before
    applying the update when running ``on_overflow="flag"`` (with
    ``"fallback"`` the values are already exact; the flags then just say
    the lossless path ran).  Mesh axes may have ANY size (non-power-of-two
    data-parallel degrees route through the remainder-stage redoub /
    generalized ring schedules — DESIGN.md §7); an empty axis list is a
    config error, not a no-op.

    Dispatch is per-BUCKET: the tree's ravel order is tiled by a memoized
    ``BucketLedger`` into equal ``sync.bucket_bytes`` payloads issued
    last-layer-first, each resolving (once) its own frozen Plan through
    the communicator cache.  Values are bitwise-identical to the
    whole-tree reference path — see the module docstring.
    """
    sync = _resolve_sync(sync)
    axis_names = tuple(axis_names)
    if not axis_names:
        raise ValueError(
            "dp_allreduce_grads: axis_names is empty — pass the mesh axes "
            "to sum over (a silent no-op here would skip gradient sync)"
        )
    leaves, treedef = _flatten_grads(grads)
    dtypes = [leaf.dtype for leaf in leaves]
    shapes = [leaf.shape for leaf in leaves]
    f32 = [leaf.astype(jnp.float32).reshape(-1) for leaf in leaves]
    if sync.gz is None:
        out, stats = _psum_tree_stats(f32, axis_names)
        out = [o.reshape(s).astype(dt)
               for o, s, dt in zip(out, shapes, dtypes)]
        return jax.tree.unflatten(treedef, out), stats
    if sync.relative_eb:
        scale = _tree_scale(f32, axis_names)
        # eb must be a static trace-time constant; keep it relative by
        # folding the scale into the data: normalize, sync, rescale.
        f32 = [leaf / scale for leaf in f32]
    ledger = ledger_for(shapes, sync.bucket_bytes)
    payloads = ledger.stack_payloads(f32)
    synced, ovf, nf, wire = _scan_allreduce(payloads, axis_names, sync)
    out = ledger.unstack(synced)
    if sync.relative_eb:
        out = [o * scale for o in out]
    out = [o.reshape(s).astype(dt) for o, s, dt in zip(out, shapes, dtypes)]
    stats = SyncStats(overflow=ovf, nonfinite=nf,
                      wire_bytes=wire * ledger.n_buckets,
                      n_buckets=ledger.n_buckets)
    return jax.tree.unflatten(treedef, out), stats


def _dp_allreduce_whole_tree_stats(
    grads, axis_names: Sequence[str], sync: SyncConfig | None = None
):
    """REFERENCE: the pre-bucketing whole-tree ravel + fixed-size chunk
    scan, kept for the bitwise-equality contract the multi-device children
    assert.  Shares ``_tree_scale`` and ``_scan_allreduce`` with the
    bucketed path — the ONLY differences are the flatten/unflatten
    mechanics and the row order, neither of which touches values.
    """
    sync = _resolve_sync(sync)
    axis_names = tuple(axis_names)
    leaves, treedef = _flatten_grads(grads)
    dtypes = [leaf.dtype for leaf in leaves]
    shapes = [leaf.shape for leaf in leaves]
    f32 = [leaf.astype(jnp.float32).reshape(-1) for leaf in leaves]
    if sync.gz is None:
        out, stats = _psum_tree_stats(f32, axis_names)
        out = [o.reshape(s).astype(dt)
               for o, s, dt in zip(out, shapes, dtypes)]
        return jax.tree.unflatten(treedef, out), stats
    if sync.relative_eb:
        scale = _tree_scale(f32, axis_names)
        f32 = [leaf / scale for leaf in f32]
    flat = f32[0] if len(f32) == 1 else jnp.concatenate(f32)
    n = flat.shape[0]
    chunk = min(sync.bucket_bytes // 4, n)
    n_chunks = -(-n // chunk)
    padded = jnp.zeros((n_chunks * chunk,), flat.dtype).at[:n].set(flat)
    synced, ovf, nf, wire = _scan_allreduce(
        padded.reshape(n_chunks, chunk), axis_names, sync
    )
    out_flat = synced.reshape(-1)[:n]
    if sync.relative_eb:
        out_flat = out_flat * scale
    out, off = [], 0
    for s, dt in zip(shapes, dtypes):
        size = 1
        for d in s:
            size *= int(d)
        out.append(out_flat[off:off + size].reshape(s).astype(dt))
        off += size
    stats = SyncStats(overflow=ovf, nonfinite=nf,
                      wire_bytes=wire * n_chunks, n_buckets=n_chunks)
    return jax.tree.unflatten(treedef, out), stats


def dp_allreduce_grads(
    grads, axis_names: Sequence[str], sync: SyncConfig | None = None
):
    """Back-compat single-return wrapper over :func:`dp_allreduce_grads_stats`
    (drops the health flags — prefer the ``_stats`` form in new code)."""
    return dp_allreduce_grads_stats(grads, axis_names, sync)[0]


# ---------------------------------------------------------------------------
# FSDP gather / scatter with autodiff
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fsdp_all_gather(x: jnp.ndarray, axis_name: str, sync: SyncConfig | None = None):
    """All-gather a parameter shard along its leading (FSDP) axis.

    x: (s, ...) local shard -> (n*s, ...) full parameter.  With a gz
    SyncConfig, the forward wire payload is compressed (gZ-Allgather: one
    lossy hop) and the backward is a gZ reduce-scatter.
    """
    return _fsdp_gather_impl(x, axis_name, sync)


def _fsdp_gather_impl(x, axis_name, sync):
    if sync is None or sync.gz is None:
        return lax.all_gather(x, axis_name, tiled=True)
    shape = x.shape
    flat = x.reshape(-1)
    res = _comm(axis_name, sync).allgather(flat.astype(jnp.float32))
    out = res.value
    if sync.mark_degraded:
        # A degraded gather already corrupted the parameter values; NaN
        # makes that LOUD (loss -> grads -> the skip predicate) instead
        # of silent.
        bad = res.overflow | res.nonfinite
        out = jnp.where(bad, jnp.full_like(out, jnp.nan), out)
    n = _axis_size(axis_name)
    return out.astype(x.dtype).reshape((n * shape[0],) + shape[1:])


def _fsdp_gather_fwd(x, axis_name, sync):
    return _fsdp_gather_impl(x, axis_name, sync), None


def _fsdp_gather_bwd(axis_name, sync, _, g):
    out, stats = fsdp_reduce_scatter_stats(g, axis_name, sync)
    if sync is not None and sync.mark_degraded:
        # The cotangent is the only dataflow out of a custom_vjp backward:
        # mark a degraded reduce-scatter in-band (GradScaler-style) so the
        # training loop's per-leaf nonfinite probe sees it.
        out = jnp.where(stats.degraded, jnp.full_like(out, jnp.nan), out)
    return (out,)


fsdp_all_gather.defvjp(_fsdp_gather_fwd, _fsdp_gather_bwd)


def fsdp_reduce_scatter_stats(
    g: jnp.ndarray, axis_name: str, sync: SyncConfig | None = None
):
    """Sum-and-shard along the leading axis with health flags:
    (n*s, ...) -> ((s, ...), SyncStats)."""
    if sync is None or sync.gz is None:
        out = lax.psum_scatter(g, axis_name, scatter_dimension=0, tiled=True)
        nf = lax.psum(
            jnp.any(~jnp.isfinite(g)).astype(jnp.int32), axis_name
        ) > 0
        no = jnp.zeros((), jnp.bool_)
        return out, SyncStats(overflow=no, nonfinite=nf,
                              wire_bytes=int(g.size) * 4, n_buckets=0)
    n = _axis_size(axis_name)
    shape = g.shape
    flat = g.astype(jnp.float32).reshape(n, -1).reshape(-1)
    res = _comm(axis_name, sync).reduce_scatter(flat)
    out = res.value.astype(g.dtype).reshape((shape[0] // n,) + shape[1:])
    return out, SyncStats(overflow=res.overflow, nonfinite=res.nonfinite,
                          wire_bytes=res.wire_bytes, n_buckets=1)


def fsdp_reduce_scatter(
    g: jnp.ndarray, axis_name: str, sync: SyncConfig | None = None
) -> jnp.ndarray:
    """Sum-and-shard along the leading axis: (n*s, ...) -> (s, ...)."""
    return fsdp_reduce_scatter_stats(g, axis_name, sync)[0]
