"""Training-loop integration of the gZCCL collectives.

Three entry points, all rank-centric (call inside shard_map bodies):

  * ``dp_allreduce_grads``   — gradient sync across data-parallel axes
    (the paper's headline Allreduce, applied where a training framework
    actually spends its collective bytes).  Multiple axes resolve ONE
    two-level plan (``GZHierCommunicator``): exact uncompressed sums on
    the fast intra-node axes, compression only on the slow inter-node
    hop — or a single flat composite-axis schedule when the fabric has
    no link asymmetry (DESIGN.md §8).
  * ``fsdp_all_gather``      — ZeRO-3 parameter gather, differentiable:
    forward is a (optionally compressed) allgather, backward is the
    matching (optionally compressed) reduce-scatter — the [29] pattern,
    with gZ error control.
  * ``fsdp_reduce_scatter``  — the standalone gradient-shard path.

All compressed traffic goes through per-axis ``GZCommunicator``s
(core/comm.py): the plan — algorithm, ring pipeline depth, per-stage eb —
is resolved once per (op, bytes, axis) and memoized, so the scan body
below contains zero selector logic.  ``SyncConfig.pipeline_chunks == 0``
(the default) asks the communicator to plan the ring depth from the cost
model; > 0 forces that depth.

Gradients are scale-free, so the error bound can be made *relative*: with
``relative_eb=True`` the absolute eb is eb * global RMS of the tensor
(one scalar psum — cheap, and identical on every rank so quantization
grids agree).

Large pytrees are flattened to one vector and processed in fixed-size
chunks under ``lax.scan`` so the compiled HLO stays small and each
compression call is big enough to saturate the device — exactly the
paper's utilization argument applied to the framework's own internals.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.flatten_util import ravel_pytree

from repro.core.collectives import GZConfig, _axis_size
from repro.core.comm import GZCommunicator, GZHierCommunicator

__all__ = [
    "SyncConfig",
    "SyncStats",
    "dp_allreduce_grads",
    "dp_allreduce_grads_stats",
    "fsdp_all_gather",
    "fsdp_reduce_scatter",
]

CHUNK = 4 * 1024 * 1024  # elements per compression call (f32: 16 MiB)


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """How gradients cross the wire.

    ``pipeline_chunks``: 0 (default) lets the communicator plan the ring
    pipeline depth from the cost model per (chunk bytes, axis size) — the
    chunked double-buffered schedule of DESIGN.md §4; > 0 forces that
    depth; the knob is ignored by non-ring algorithms (redoub/intring
    take no chunk schedule).
    """

    gz: GZConfig | None = GZConfig(eb=1e-4, algo="redoub", worst_case_budget=False)
    relative_eb: bool = True
    chunk: int = CHUNK
    pipeline_chunks: int = 0

    def __post_init__(self):
        # Fail at construction time, not inside a traced scan body.
        if self.pipeline_chunks < 0 or (
            self.pipeline_chunks > 0
            and self.pipeline_chunks & (self.pipeline_chunks - 1)
        ):
            raise ValueError(
                "SyncConfig.pipeline_chunks must be 0 (plan the ring depth "
                "from the cost model) or a power of two >= 1 (forced "
                f"depth); got {self.pipeline_chunks!r}"
            )
        if self.chunk < 1:
            raise ValueError(
                f"SyncConfig.chunk must be >= 1 element; got {self.chunk!r}"
            )

    def with_algo(self, algo: str) -> "SyncConfig":
        if self.gz is None:
            raise ValueError(
                "SyncConfig.with_algo: this SyncConfig has gz=None "
                "(uncompressed psum sync) — there is no GZConfig to set an "
                "algorithm on; construct one explicitly, e.g. "
                "SyncConfig(gz=GZConfig(algo=...))"
            )
        return dataclasses.replace(
            self, gz=dataclasses.replace(self.gz, algo=algo)
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SyncStats:
    """Health flags of one gradient sync, OR-ed across every scan chunk.

    ``overflow``/``nonfinite`` are replicated bool scalars (they come out
    of ``CollectiveResult`` already psum-combined across the axes), so
    they are safe predicates for a skip-step ``jnp.where`` and identical
    on every rank.  The old single-return ``dp_allreduce_grads`` used to
    DROP these flags on the scan floor — a silent-corruption hazard when
    ``on_overflow="flag"`` — hence the ``_stats`` entry point.
    """

    overflow: jnp.ndarray
    nonfinite: jnp.ndarray

    @property
    def degraded(self) -> jnp.ndarray:
        """True iff this sync overflowed or saw non-finite input (the
        GradScaler-style skip predicate)."""
        return self.overflow | self.nonfinite


def _comm(axis_name, sync: "SyncConfig") -> GZCommunicator:
    """The per-axis communicator for this sync policy (memoized).

    A forced ``sync.pipeline_chunks`` is written into the knobs; otherwise
    ``auto_depth`` asks the plan to pick the ring depth even when the
    algorithm was requested explicitly (the grad-sync routing rule).
    """
    cfg = sync.gz
    if sync.pipeline_chunks > 0:
        cfg = dataclasses.replace(cfg, pipeline_chunks=sync.pipeline_chunks)
        return GZCommunicator.for_config(axis_name, cfg)
    return GZCommunicator.for_config(axis_name, cfg, auto_depth=True)


def _hier_comm(axis_names, sync: "SyncConfig") -> GZHierCommunicator:
    """The two-level communicator for a multi-axis sync (memoized).

    Axis convention (matching the callers' inner-fast-first ordering):
    the LAST axis is the slow inter-node hop ("pod"/"node" — outermost in
    the mesh), everything before it is collapsed into the fast local
    level.  The topology is read from the shard_map trace per call, so
    one memoized communicator replans across reshaped meshes.
    """
    node = axis_names[-1]
    local = axis_names[0] if len(axis_names) == 2 else tuple(axis_names[:-1])
    cfg = sync.gz
    if sync.pipeline_chunks > 0:
        cfg = dataclasses.replace(cfg, pipeline_chunks=sync.pipeline_chunks)
        return GZHierCommunicator.for_axes(node, local, config=cfg)
    return GZHierCommunicator.for_axes(node, local, config=cfg,
                                       auto_depth=True)


def _global_rms(flat: jnp.ndarray, axis_names) -> jnp.ndarray:
    # ONE multi-axis psum (a single reduction tree) instead of one round
    # per axis; the element count is static (axis sizes are trace-time
    # constants), so only the sum-of-squares travels.
    ss = lax.psum(jnp.sum(flat.astype(jnp.float32) ** 2), tuple(axis_names))
    cnt = float(flat.size)
    for ax in axis_names:
        cnt *= _axis_size(ax)
    return jnp.sqrt(ss / max(cnt, 1.0))


def _allreduce_flat(flat: jnp.ndarray, axis_names, sync: SyncConfig):
    """Sync one flat vector; returns ``(out, SyncStats)``."""
    no = jnp.zeros((), jnp.bool_)
    if sync.gz is None:
        out = lax.psum(flat, tuple(axis_names))
        nf = lax.psum(
            jnp.any(~jnp.isfinite(flat)).astype(jnp.int32), tuple(axis_names)
        ) > 0
        return out, SyncStats(overflow=no, nonfinite=nf)
    if sync.relative_eb:
        scale = jnp.maximum(_global_rms(flat, axis_names), 1e-30)
        # A non-finite gradient poisons the RMS too; pin the scale so the
        # fallback's sanitized sum still rescales to something finite.
        scale = jnp.where(jnp.isfinite(scale), scale, jnp.ones_like(scale))
        # eb must be a static trace-time constant shape; keep it as a traced
        # scalar by folding into the data instead: normalize, sync, rescale.
        flat = flat / scale
    n = flat.shape[0]
    chunk = min(sync.chunk, n)
    n_chunks = -(-n // chunk)
    padded = jnp.zeros((n_chunks * chunk,), flat.dtype).at[:n].set(flat)

    if len(axis_names) == 1:
        comm = _comm(axis_names[0], sync)

        def body(carry, xc):
            o, f = carry
            res = comm.allreduce(xc)
            return (o | res.overflow, f | res.nonfinite), res.value
    else:
        # ONE two-level plan over node × local replaces the sequential
        # per-axis allreduce loop: compression runs only on the slow
        # inter-node hop (or the planner falls back to a single flat
        # composite-axis schedule when the fabric has no asymmetry).
        hcomm = _hier_comm(axis_names, sync)

        def body(carry, xc):
            o, f = carry
            res = hcomm.allreduce(xc)
            return (o | res.overflow, f | res.nonfinite), res.value

    (ovf, nf), synced = lax.scan(body, (no, no), padded.reshape(n_chunks, chunk))
    out = synced.reshape(-1)[:n]
    if sync.relative_eb:
        out = out * scale
    return out, SyncStats(overflow=ovf, nonfinite=nf)


def dp_allreduce_grads_stats(
    grads, axis_names: Sequence[str], sync: SyncConfig = SyncConfig()
):
    """Sum a gradient pytree across data-parallel mesh axes (gZ-accelerated).

    Returns ``(summed_pytree, SyncStats)`` — callers divide by the DP
    degree for a mean, and should consult ``stats.degraded`` before
    applying the update when running ``on_overflow="flag"`` (with
    ``"fallback"`` the values are already exact; the flags then just say
    the lossless path ran).  Mesh axes may have ANY size (non-power-of-two
    data-parallel degrees route through the remainder-stage redoub /
    generalized ring schedules — DESIGN.md §7); an empty axis list is a
    config error, not a no-op.
    """
    axis_names = tuple(axis_names)
    if not axis_names:
        raise ValueError(
            "dp_allreduce_grads: axis_names is empty — pass the mesh axes "
            "to sum over (a silent no-op here would skip gradient sync)"
        )
    flat, unravel = ravel_pytree(grads)
    dtype = flat.dtype
    out, stats = _allreduce_flat(flat.astype(jnp.float32), axis_names, sync)
    return unravel(out.astype(dtype)), stats


def dp_allreduce_grads(grads, axis_names: Sequence[str], sync: SyncConfig = SyncConfig()):
    """Back-compat single-return wrapper over :func:`dp_allreduce_grads_stats`
    (drops the health flags — prefer the ``_stats`` form in new code)."""
    return dp_allreduce_grads_stats(grads, axis_names, sync)[0]


# ---------------------------------------------------------------------------
# FSDP gather / scatter with autodiff
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fsdp_all_gather(x: jnp.ndarray, axis_name: str, sync: SyncConfig | None = None):
    """All-gather a parameter shard along its leading (FSDP) axis.

    x: (s, ...) local shard -> (n*s, ...) full parameter.  With a gz
    SyncConfig, the forward wire payload is compressed (gZ-Allgather: one
    lossy hop) and the backward is a gZ reduce-scatter.
    """
    return _fsdp_gather_impl(x, axis_name, sync)


def _fsdp_gather_impl(x, axis_name, sync):
    if sync is None or sync.gz is None:
        return lax.all_gather(x, axis_name, tiled=True)
    shape = x.shape
    flat = x.reshape(-1)
    out = _comm(axis_name, sync).allgather(flat.astype(jnp.float32)).value
    n = _axis_size(axis_name)
    return out.astype(x.dtype).reshape((n * shape[0],) + shape[1:])


def _fsdp_gather_fwd(x, axis_name, sync):
    return _fsdp_gather_impl(x, axis_name, sync), None


def _fsdp_gather_bwd(axis_name, sync, _, g):
    return (fsdp_reduce_scatter(g, axis_name, sync),)


fsdp_all_gather.defvjp(_fsdp_gather_fwd, _fsdp_gather_bwd)


def fsdp_reduce_scatter(
    g: jnp.ndarray, axis_name: str, sync: SyncConfig | None = None
) -> jnp.ndarray:
    """Sum-and-shard along the leading axis: (n*s, ...) -> (s, ...)."""
    if sync is None or sync.gz is None:
        return lax.psum_scatter(g, axis_name, scatter_dimension=0, tiled=True)
    n = _axis_size(axis_name)
    shape = g.shape
    flat = g.astype(jnp.float32).reshape(n, -1).reshape(-1)
    out = _comm(axis_name, sync).reduce_scatter(flat).value
    return out.astype(g.dtype).reshape((shape[0] // n,) + shape[1:])
