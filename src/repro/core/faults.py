"""Deterministic fault injection for the gZ collectives (DESIGN.md §9).

The degradation layer (overflow detection, non-finite guards, stream
verification, lossless fallback) is only trustworthy if its detection
paths can be DRIVEN: this module provides seeded injectors that force
each failure mode on chosen ranks, usable both in the numpy replays
(``simulator.sim_allreduce_guarded``) and in real multi-device shard_map
children (``tests/_mp_faults_child.py``), proving detection fires and
the fallback recovers exactly.

Fault kinds (:class:`FaultSpec.kind`):

  ``"nan"`` / ``"inf"``  poison ``n`` seeded positions of the INPUT with
                         NaN/Inf on the target ranks (pre-compression —
                         exercises the non-finite guard).
  ``"overflow"``         replace the target ranks' input with seeded
                         high-entropy noise (sigma 1e6) that no capacity
                         factor <= 1 can pack — forces a genuine
                         capacity overflow through the real kernels, no
                         flag is faked.
  ``"bitflip"``          XOR ``n`` seeded bits into the first uint32
                         leaf (the packed stream) of every compressed
                         wire payload RECEIVED on the target ranks —
                         in-flight corruption; detected only when
                         ``verify_streams`` ships checksums.  Raw f32
                         (lossless-fallback) trees are never touched,
                         so a fallback re-execute is immune.

Injection is TRACE-TIME gated: the collectives consult the installed
spec while being traced, so a function jitted under ``inject(...)``
keeps its faults until re-traced, and a function traced without faults
stays clean (zero overhead — the hooks are identity).  Build the jit
inside the ``with inject(spec):`` block.

The injected values come from ``numpy.random.default_rng(spec.seed)``
and are embedded as constants at trace time — ``poison_np`` produces
bitwise the same poisoned array for host-side twins/references.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FaultSpec",
    "install",
    "clear",
    "active",
    "inject",
    "poison_np",
    "maybe_poison_input",
    "maybe_corrupt_wire",
]

KINDS = ("nan", "inf", "overflow", "bitflip")

# Sigma of the "overflow" replacement noise: a seeded N(0, 1e6) payload
# needs ~all 32 bits per code at any practical eb, so every capacity
# factor < 1 genuinely overflows the pack kernel.
OVERFLOW_SIGMA = 1e6


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: what, where, and from which seed.

    ``rounds`` (bitflip only) targets wire rounds BY SCHEDULE-TABLE
    INDEX (``core/schedule.py`` — the same ``rounds[k]`` the execute
    layer walks and the simulator replays): ``None`` corrupts every
    received compressed payload on the target ranks (the historic
    behaviour); ``(k, ...)`` corrupts only exchanges implementing those
    table rounds, so an injected corruption lands on the bit-identical
    wire hop in ``simulator.sim_allreduce_guarded`` and on a real mesh.
    """

    kind: str
    ranks: tuple = (0,)
    seed: int = 0
    n: int = 1  # poisoned positions (nan/inf) or flipped bits (bitflip)
    rounds: Optional[tuple] = None  # schedule-table round indices, or all

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"FaultSpec.kind must be one of {KINDS}; got {self.kind!r}"
            )
        object.__setattr__(
            self, "ranks", tuple(int(r) for r in self.ranks)
        )
        if self.n < 1:
            raise ValueError(f"FaultSpec.n must be >= 1; got {self.n!r}")
        if self.rounds is not None:
            if self.kind != "bitflip":
                raise ValueError(
                    "FaultSpec.rounds targets wire rounds and only applies "
                    f"to kind='bitflip'; got kind={self.kind!r}"
                )
            rr = tuple(int(k) for k in self.rounds)
            if not rr or any(k < 0 for k in rr):
                raise ValueError(
                    f"FaultSpec.rounds must be non-empty, non-negative "
                    f"schedule round indices; got {self.rounds!r}"
                )
            object.__setattr__(self, "rounds", rr)


_ACTIVE: Optional[FaultSpec] = None


def install(spec: FaultSpec) -> None:
    """Arm ``spec`` process-wide (until :func:`clear`)."""
    global _ACTIVE
    _ACTIVE = spec


def clear() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[FaultSpec]:
    return _ACTIVE


@contextlib.contextmanager
def inject(spec: FaultSpec):
    """Arm ``spec`` for the duration of the block (trace-time gate: jit
    the faulty function INSIDE the block)."""
    install(spec)
    try:
        yield spec
    finally:
        clear()


# ---------------------------------------------------------------------------
# Seeded fault material (shared by the device hooks and the numpy twins)
# ---------------------------------------------------------------------------


def _poison_positions(size: int, spec: FaultSpec) -> np.ndarray:
    rng = np.random.default_rng(spec.seed)
    k = max(1, min(spec.n, size))
    return np.sort(rng.choice(size, size=k, replace=False))


def _overflow_noise(shape, spec: FaultSpec) -> np.ndarray:
    rng = np.random.default_rng(spec.seed)
    return rng.normal(0.0, OVERFLOW_SIGMA, size=shape).astype(np.float32)


def poison_np(x, rank: int, spec: Optional[FaultSpec]):
    """Numpy twin of :func:`maybe_poison_input`: what rank ``rank``'s
    input looks like under ``spec`` — bitwise identical to the device
    path (same seeded constants), for building host-side references."""
    x = np.array(x, copy=True)
    if (
        spec is None
        or spec.kind == "bitflip"
        or rank not in spec.ranks
        or not np.issubdtype(x.dtype, np.floating)
    ):
        return x
    if spec.kind == "overflow":
        return _overflow_noise(x.shape, spec).astype(x.dtype)
    flat = x.reshape(-1)
    flat[_poison_positions(flat.size, spec)] = (
        np.nan if spec.kind == "nan" else np.inf
    )
    return flat.reshape(x.shape)


# ---------------------------------------------------------------------------
# Device-side hooks (identity when no fault is armed)
# ---------------------------------------------------------------------------


def _rank_mask(axis_name, ranks):
    from repro.core.collectives import _axis_rank

    r = _axis_rank(axis_name)
    m = jnp.zeros((), jnp.bool_)
    for k in ranks:
        m = m | (r == jnp.int32(k))
    return m


def maybe_poison_input(x, axis_name):
    """Input-poisoning hook, called by every communicator method on the
    payload before detection/compression.  Identity unless a nan/inf/
    overflow fault is armed AT TRACE TIME."""
    spec = _ACTIVE
    if spec is None or spec.kind == "bitflip":
        return x
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    on = _rank_mask(axis_name, spec.ranks)
    if spec.kind == "overflow":
        noise = jnp.asarray(_overflow_noise(x.shape, spec)).astype(x.dtype)
        return jnp.where(on, noise, x)
    val = np.nan if spec.kind == "nan" else np.inf
    flat = x.reshape(-1)
    idx = _poison_positions(flat.shape[0], spec)
    vals = jnp.where(on, jnp.asarray(val, flat.dtype), flat[idx])
    return flat.at[idx].set(vals).reshape(x.shape)


def maybe_corrupt_wire(tree, axis_name, round_idx=None):
    """Wire-corruption hook, applied by ``collectives._ppermute_guarded``
    to every RECEIVED compressed payload.  Flips ``spec.n`` seeded bits
    of the first uint32 leaf (the packed stream) on the target ranks;
    identity for non-bitflip faults and for raw (non-uint32-first)
    trees — the lossless fallback's f32 slabs never corrupt.

    ``round_idx`` is the schedule-table round this exchange implements
    (a python int or a traced loop index).  A spec with ``rounds``
    corrupts only matching rounds — exchanges that pass no index can
    never match a round-targeted spec."""
    spec = _ACTIVE
    if spec is None or spec.kind != "bitflip":
        return tree
    if spec.rounds is not None and round_idx is None:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves or leaves[0].dtype != jnp.uint32 or leaves[0].size == 0:
        return tree
    leaf = leaves[0]
    rng = np.random.default_rng(spec.seed)
    on = _rank_mask(axis_name, spec.ranks)
    if spec.rounds is not None:
        # round_idx may be traced (ring fori_loop bodies) — gate with a
        # jnp comparison, not python `in`.
        ri = jnp.asarray(round_idx, jnp.int32)
        hit = jnp.zeros((), jnp.bool_)
        for k in spec.rounds:
            hit = hit | (ri == jnp.int32(k))
        on = on & hit
    flat = leaf.reshape(-1)
    for _ in range(spec.n):
        word = int(rng.integers(flat.shape[0]))
        bit = int(rng.integers(32))
        flipped = flat.at[word].set(flat[word] ^ jnp.uint32(1 << bit))
        flat = jnp.where(on, flipped, flat)
    leaves[0] = flat.reshape(leaf.shape)
    return jax.tree.unflatten(treedef, leaves)
