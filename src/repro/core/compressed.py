"""Static-capacity compressed payload container.

cuSZp emits an *unknown-size* byte stream; MPI can ship ragged buffers but
XLA SPMD cannot (every ``ppermute`` operand needs a static shape).  The
``Compressed`` pytree is the TPU-native adaptation (DESIGN.md §2.1): a
provisioned ``packed`` capacity buffer + per-block bitwidths + the true
size.  Error-bounded semantics are untouched; only the wire format is
padded.

The container is a pytree, so it can flow through ``lax.ppermute``,
``lax.scan`` carries, ``jax.jit`` and ``custom_vjp`` unchanged.

Wire codecs reinterpret the slots, not the shape (DESIGN.md §10): under
``codec="lorenzo+entropy"``/``"lossless"`` the per-block ``bitwidth``
slot carries the packed 4x6-bit sub-block width descriptor instead of a
single dense width — same container pytree, same provisioned capacity,
different stream layout inside ``packed``.  Only the codec that wrote a
container may read it; the plan layer guarantees that pairing.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Compressed:
    """An error-bounded-compressed float payload with static wire shape.

    Attributes:
      packed: uint32[capacity_words] dense bitstream (valid prefix ``nwords``).
      bitwidth: int32[n_blocks] per-block code width in bits (0..32).
      anchor: int32[n_blocks] absolute quantized first element per block.
      nwords: int32 scalar, true number of valid words in ``packed``.
      eb: f32 scalar absolute error bound the stream was quantized at.
      n: static original element count (pytree aux data).
      block: static block size.
    """

    packed: jnp.ndarray
    bitwidth: jnp.ndarray
    anchor: jnp.ndarray
    nwords: jnp.ndarray
    eb: jnp.ndarray
    n: int = dataclasses.field(metadata=dict(static=True))
    block: int = dataclasses.field(metadata=dict(static=True))

    @property
    def capacity_words(self) -> int:
        return self.packed.shape[0]

    @property
    def n_blocks(self) -> int:
        return self.bitwidth.shape[0]

    def overflowed(self) -> jnp.ndarray:
        """True iff the stream did not fit the provisioned capacity."""
        return self.nwords > jnp.int32(self.capacity_words)

    def wire_bytes(self) -> int:
        """Bytes XLA actually moves for this payload (static provisioning)."""
        return int(
            self.packed.size * 4 + self.bitwidth.size * 4 + self.anchor.size * 4 + 8
        )

    def payload_bytes(self) -> jnp.ndarray:
        """True compressed bytes (what a ragged transport would move)."""
        meta = self.bitwidth.size * 4 + self.anchor.size * 4 + 8
        return self.nwords.astype(jnp.int32) * 4 + meta


MAX_CAPACITY_FACTOR = 2.0


def validate_capacity_factor(capacity_factor: float, *, knob: str) -> None:
    """Reject capacity factors that would fail deep in the pack kernel.

    Non-positive factors provision a zero/negative buffer (shape error at
    trace time); factors beyond ``MAX_CAPACITY_FACTOR`` over-provision past
    the worst incompressible stream (32-bit codes + per-block metadata fit
    comfortably under 2x the raw f32 size) and usually indicate a units
    mistake (bytes vs fraction).
    """
    if not (0.0 < float(capacity_factor) <= MAX_CAPACITY_FACTOR):
        raise ValueError(
            f"{knob}={capacity_factor!r} is outside the legal range "
            f"(0.0, {MAX_CAPACITY_FACTOR}]: it is the fraction of the raw "
            "f32 byte size to provision for the packed stream."
        )


def capacity_words_for(n: int, capacity_factor: float, block: int) -> int:
    """Provisioned uint32 words for an ``n``-element f32 payload.

    ``capacity_factor`` is the fraction of the *original* f32 byte size to
    provision (paper's user-sized buffer pool).  Always at least one word
    per block so a pathological incompressible block cannot overflow by
    construction when factor >= 1.0.
    """
    if n <= 0:
        raise ValueError(f"capacity_words_for: n={n} must be positive")
    if block <= 0:
        raise ValueError(f"capacity_words_for: block={block} must be positive")
    validate_capacity_factor(capacity_factor, knob="capacity_factor")
    n_blocks = -(-n // block)
    words = int(n * capacity_factor)  # n f32 == n 4-byte words
    return max(words, n_blocks, 8)
