"""Analytic performance model for compression-enabled collectives.

This is the executable form of the paper's §3.2/§3.3 analysis and the
engine behind both the algorithm selector and the benchmark figures
(Figs. 3, 7, 9, 10, 11, 12 analogs).  On this CPU-only container wall-clock
GPU/TPU numbers cannot be measured, so the model is calibrated to the
paper's published A100/Slingshot-10 data and re-parameterized for TPU v5e
(EXPERIMENTS.md reports both parameter sets).

Model pieces:

  t_comp(size)  = overhead + size / (peak * util(size))        [Fig. 3]
      util(s)   = s / (s + saturation)   — the under-utilization curve:
                  halves at the saturation size (~5 MB for cuSZp/A100,
                  paper §3.2.2), the root cause of ring's poor scaling.
  t_net(bytes)  = alpha + bytes / bw     — classic alpha-beta term per hop.

Collective compositions mirror the step counts in §3.2.3/§3.3.3 exactly;
``overlap`` discounts the portion of compression hidden behind
communication (the paper's multi-stream/async optimization), applied only
to the gZ (optimized) variants, not to CPRP2P/C-Coll baselines.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import schedule as _schedule

__all__ = [
    "Hardware",
    "CodecTerms",
    "A100_SLINGSHOT",
    "TPU_V5E",
    "steps_for",
    "binomial_slab_table",
    "scatter_root_chunk_streams",
    "t_compress",
    "t_decompress",
    "t_hop_fused",
    "allreduce_ring_gz",
    "allreduce_redoub_gz",
    "allreduce_intring_gz",
    "allreduce_uncompressed_ring",
    "t_net_intra",
    "reduce_scatter_uncompressed_intra",
    "allgather_uncompressed_intra",
    "allreduce_hier_gz",
    "allreduce_cprp2p",
    "allreduce_ccoll",
    "allreduce_ring_gz_chunked",
    "scatter_binomial_gz",
    "scatter_binomial_gz_chunked",
    "scatter_uncompressed_binomial",
    "allgather_ring_gz",
    "best_pipeline_chunks",
    "best_scatter_pipeline_chunks",
    "BucketPlan",
    "best_bucket_plan",
    "BUCKET_BYTES_CANDIDATES",
    "fallback_time",
    "expected_collective_time",
]


@dataclasses.dataclass(frozen=True)
class CodecTerms:
    """Per-codec pricing terms for the planner (DESIGN.md §10).

    Every field except ``codec`` is optional-by-sentinel so a terms entry
    only overrides what was actually measured or modeled:

      * ``ratio_scale``  — wire-ratio multiplier applied to the caller's
        assumed dense-Lorenzo ratio (eb-scaled codecs: the achievable
        ratio tracks the data/eb regime, only the *relative* win is
        codec-intrinsic);
      * ``ratio_abs``    — absolute wire ratio (> 0 overrides the scale;
        eb-independent codecs: lossless / passthrough ship the same bytes
        whatever the bound);
      * ``cmp_peak_gbps`` / ``dec_peak_gbps`` / ``cmp_overhead_us`` —
        codec-specific compressor terms (sentinels: 0 / 0 / negative mean
        "inherit the Hardware point's dense-Lorenzo terms").

    Instances live in ``Hardware.codec_terms`` (a tuple, so the Hardware
    point stays hashable for the plan-cache key) and are produced either
    by the registry's modeled defaults (``codecs.get_codec(...).terms``)
    or by ``comm.fit_codec_terms`` from measured samples.
    """

    codec: str
    ratio_scale: float = 1.0
    ratio_abs: float = 0.0
    cmp_peak_gbps: float = 0.0
    dec_peak_gbps: float = 0.0
    cmp_overhead_us: float = -1.0

    def effective_ratio(self, assumed_ratio: float) -> float:
        if self.ratio_abs > 0.0:
            return self.ratio_abs
        # Entropy trim cannot make the wire worse than raw (ratio < 1).
        return max(assumed_ratio * self.ratio_scale, 1.0)

    def apply(self, hw: "Hardware") -> "Hardware":
        """Hardware point with this codec's compressor terms swapped in."""
        kw = {}
        if self.cmp_peak_gbps > 0.0:
            kw["cmp_peak_gbps"] = self.cmp_peak_gbps
        if self.dec_peak_gbps > 0.0:
            kw["dec_peak_gbps"] = self.dec_peak_gbps
        if self.cmp_overhead_us >= 0.0:
            kw["cmp_overhead_us"] = self.cmp_overhead_us
        return dataclasses.replace(hw, **kw) if kw else hw


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    cmp_peak_gbps: float      # compressor throughput at full utilization
    dec_peak_gbps: float
    cmp_saturation_mb: float  # input size at which utilization = 50%
    cmp_overhead_us: float    # per-invocation fixed cost (kernel launch /
                              # pallas dispatch + pipeline fill)
    net_gbps: float           # INTER-node per-link bandwidth (the slow hop)
    net_alpha_us: float       # inter-node per-hop latency
    reduce_gbps: float        # on-device reduction bandwidth
    pcie_gbps: float = 0.0    # host staging penalty (CPU-centric designs)
    # Per-link-class terms for the two-level (node x intra-node) topology:
    # NVLink/ICI-class links inside a node vs the fabric between nodes.
    # intra_gbps == 0.0 declares a FLAT fabric (every link priced at
    # net_gbps/net_alpha_us) — the pre-hierarchy behavior, and the default
    # so every existing Hardware point keeps its meaning.
    intra_gbps: float = 0.0       # intra-node per-link bandwidth
    intra_alpha_us: float = 0.0   # intra-node per-hop latency
    # Dense matmul throughput of one accelerator (TFLOP/s) — the term the
    # bucketed-overlap planner prices backward compute with.  0.0 means
    # "uncalibrated": best_bucket_plan then treats backward as free and
    # degenerates to pure wire-serialization planning.
    compute_tflops: float = 0.0
    # Measured per-codec pricing (tuple of CodecTerms so the point stays
    # hashable for plan-cache keys).  Empty means "no codec was calibrated
    # here": the planner falls back to the registry's modeled defaults.
    codec_terms: tuple = ()

    def terms_for(self, codec: str):
        """The calibrated CodecTerms for ``codec``, or None."""
        for t in self.codec_terms:
            if t.codec == codec:
                return t
        return None

    def intra_terms(self) -> tuple:
        """(gbps, alpha_us) of the intra-node link class; falls back to
        the inter-node terms on a flat fabric (intra_gbps == 0)."""
        if self.intra_gbps > 0.0:
            return self.intra_gbps, self.intra_alpha_us
        return self.net_gbps, self.net_alpha_us

    def link_asymmetry(self) -> float:
        """intra / inter bandwidth ratio (1.0 on a flat fabric) — the
        quantity that decides whether two-level planning can pay."""
        return self.intra_terms()[0] / self.net_gbps


# Calibrated to paper Fig. 3 (cuSZp on A100: ~5 MB saturation; ~100 GB/s
# class compression at saturation) and Slingshot-10 (100 Gbps).  The
# intra-node link is NVLink3 (~600 GB/s per GPU): the ~48:1 asymmetry is
# exactly the regime where the paper's 512-GPU numbers live — compression
# only pays on the slow inter-node hop.
A100_SLINGSHOT = Hardware(
    name="a100-slingshot10",
    cmp_peak_gbps=140.0 * 8,
    dec_peak_gbps=200.0 * 8,
    cmp_saturation_mb=5.0,
    cmp_overhead_us=30.0,
    net_gbps=100.0,
    net_alpha_us=5.0,
    reduce_gbps=1300.0 * 8,
    pcie_gbps=64.0 * 8,
    intra_gbps=600.0 * 8,
    intra_alpha_us=2.0,
    compute_tflops=312.0,  # A100 dense bf16 tensor-core peak
)

# TPU v5e: 819 GB/s HBM, ~50 GB/s/link ICI; Pallas dispatch overhead is
# smaller than a CUDA launch but the pipeline-fill penalty for small grids
# plays the same role (DESIGN.md §2.2).
TPU_V5E = Hardware(
    name="tpu-v5e",
    cmp_peak_gbps=400.0 * 8,
    dec_peak_gbps=500.0 * 8,
    cmp_saturation_mb=2.0,
    cmp_overhead_us=8.0,
    net_gbps=50.0 * 8,
    net_alpha_us=1.0,
    reduce_gbps=819.0 * 8,
    compute_tflops=197.0,  # v5e dense bf16 peak
)


def steps_for(algo: str, n: int) -> int:
    """Wire-exchange count per (busiest) rank, exactly as the execute
    layer schedules it — the ONE step-count authority shared by this cost
    model, the plan layer's wire accounting (``comm._wire_accounting``)
    and the policy selectors, so floor-vs-ceil drift between planning and
    costing cannot recur (PR 4 regression; checked over n in 2..33 by
    tests/test_comm.py and benchmarks/regression_check.py).

    * ``redoub``:   ceil(log2 n) — floor(log2 n) doubling rounds plus the
                    non-power-of-two remainder fold exchange (the unfold
                    send comes from the *other* half of each folded pair,
                    so the busiest rank still ships ceil(log2 n) full
                    streams: fold-destination ranks send every doubling
                    round plus the unfold).
    * ``binomial``: ceil(log2 n) tree rounds (scatter / broadcast; the
                    root sends every round).
    * ``ring``:     n - 1 hops per ring stage.
    * ``intring``:  2(n - 1) lossless integer hops (RS + AG rings).
    * ``direct``:   1 (the all_to_all single exchange).
    """
    n = max(int(n), 2)
    if algo in ("redoub", "binomial"):
        return max(n - 1, 1).bit_length()  # == ceil(log2 n)
    if algo == "ring":
        return n - 1
    if algo == "intring":
        return 2 * (n - 1)
    if algo == "direct":
        return 1
    raise ValueError(f"unknown algo {algo!r}")


# The trimmed-slab binomial-tree combinatorics moved to core/schedule.py
# (the Schedule IR is the one route authority since ISSUE 10); these
# names stay importable here because the pricing models and a wide test
# surface address the schedule through the cost model.
binomial_slab_table = _schedule.binomial_slab_table
scatter_root_chunk_streams = _schedule.scatter_root_chunk_streams


def _root_slab_chunks(round_entry) -> tuple:
    """(slab_chunks, is_full) of the ROOT's outgoing exchange in one
    ``binomial_slab_table`` round (the root sends every round — the
    busiest rank the scatter models price)."""
    span, full, trim = round_entry
    if 0 in full:
        return span, True
    return trim[2], False  # root's subtree straddles n: trimmed slab


def _util(size_bytes: float, hw: Hardware) -> float:
    s_mb = size_bytes / 1e6
    return s_mb / (s_mb + hw.cmp_saturation_mb)


def t_compress(size_bytes: float, hw: Hardware) -> float:
    """Seconds for one compression call of `size_bytes` input."""
    if size_bytes <= 0:
        return 0.0
    eff = hw.cmp_peak_gbps * 1e9 / 8 * _util(size_bytes, hw)
    return hw.cmp_overhead_us * 1e-6 + size_bytes / eff


def t_decompress(size_bytes: float, hw: Hardware) -> float:
    if size_bytes <= 0:
        return 0.0
    eff = hw.dec_peak_gbps * 1e9 / 8 * _util(size_bytes, hw)
    return hw.cmp_overhead_us * 1e-6 + size_bytes / eff


def t_hop_fused(size_bytes: float, hw: Hardware) -> float:
    """One single-pass unpack→reduce→repack hop over a `size_bytes` piece.

    The fused kernel streams the piece through VMEM once: decode + re-encode
    at the piece size's utilization, ONE per-invocation overhead instead of
    the two the decoupled composition pays, and no separate reduce term —
    the add rides the same pass, so the f32 intermediate's HBM round-trip
    (what ``t_reduce`` models) is gone.
    """
    if size_bytes <= 0:
        return 0.0
    u = _util(size_bytes, hw)
    dec_eff = hw.dec_peak_gbps * 1e9 / 8 * u
    cmp_eff = hw.cmp_peak_gbps * 1e9 / 8 * u
    return hw.cmp_overhead_us * 1e-6 + size_bytes / dec_eff + size_bytes / cmp_eff


def t_net(bytes_on_wire: float, hw: Hardware) -> float:
    return hw.net_alpha_us * 1e-6 + bytes_on_wire / (hw.net_gbps * 1e9 / 8)


def t_reduce(size_bytes: float, hw: Hardware) -> float:
    return size_bytes / (hw.reduce_gbps * 1e9 / 8)


def _overlapped(compute: float, comm: float, overlap: float) -> float:
    """Combine a compute and a comm phase with fractional overlap."""
    hidden = min(compute, comm) * overlap
    return compute + comm - hidden


# --- Allreduce variants (message D bytes, N ranks, compression ratio R) ---


def allreduce_ring_gz(D, N, R, hw: Hardware, overlap: float = 0.7) -> float:
    """gZ-Allreduce (Ring): (N-1) RS steps of chunk D/N + AG forwarding."""
    ch = D / N
    step_rs = _overlapped(
        t_compress(ch, hw) + t_decompress(ch, hw) + t_reduce(ch, hw),
        t_net(ch / R, hw),
        overlap,
    )
    step_ag = _overlapped(t_decompress(ch, hw), t_net(ch / R, hw), overlap)
    return (N - 1) * step_rs + t_compress(ch, hw) + (N - 1) * step_ag


def allreduce_redoub_gz(
    D, N, R, hw: Hardware, overlap: float = 0.7, *, fused_hop: bool = True
) -> float:
    """gZ-Allreduce (ReDoub): ~log2(N) full-message exchanges.

    ``fused_hop`` models the single-pass schedule (one fill compression,
    then one ``t_hop_fused`` kernel per step instead of the decoupled
    compress + decompress+reduce pair) — keep it in sync with the ring's
    fused costing so auto-selection compares like with like.

    Non-power-of-two N is priced with the paper's remainder stage
    (§3.2.3): the fold pre-hop rides the same per-step cost (it is one
    more full-message compressed exchange, hence ``steps_for`` returns
    ceil(log2 N)), and the unfold post-hop adds one compressed send plus
    a decompress on the folded pairs — the extra term that shifts the
    ring-vs-redoub crossover at non-power-of-two N.
    """
    N = max(int(N), 2)
    steps = steps_for("redoub", N)
    remainder = N & (N - 1) != 0
    post = (t_net(D / R, hw) + t_decompress(D, hw)) if remainder else 0.0
    if fused_hop:
        # The unfold stream falls out of the last doubling step's fused
        # kernel (decompress_reduce_compress instead of decompress_reduce
        # — already charged as one t_hop_fused like every step), so the
        # post-hop adds only wire + the folded ranks' decompress.
        one = _overlapped(t_hop_fused(D, hw), t_net(D / R, hw), overlap)
        return t_compress(D, hw) + steps * one + post
    if remainder:
        # Two-kernel schedule: the unfold payload needs its own explicit
        # compression of the final accumulator before the post-hop.
        post += t_compress(D, hw)
    one = _overlapped(
        t_compress(D, hw) + t_decompress(D, hw) + t_reduce(D, hw),
        t_net(D / R, hw),
        overlap,
    )
    return steps * one + post


def allreduce_intring_gz(D, N, R, hw: Hardware, overlap: float = 0.7) -> float:
    """Beyond-paper integer ring: one quantize + lossless int repack hops.

    Repacking costs ~a decompress+compress of the (compressed-size) codes;
    wire width grows ~log2(step)/32 per hop (modeled via a 15% inflation).
    """
    ch = D / N
    wire = ch / R * 1.15
    quant = t_compress(D, hw)  # single full-size quantize (saturated)
    step = _overlapped(
        t_compress(ch / R, hw) + t_decompress(ch / R, hw) + t_reduce(ch / R, hw),
        t_net(wire, hw),
        overlap,
    )
    return quant + (2 * N - 2) * step


def allreduce_uncompressed_ring(D, N, hw: Hardware) -> float:
    """NCCL-class baseline: 2(N-1) hops of D/N, no compression."""
    return 2 * (N - 1) * t_net(D / N, hw)


# --- Two-level (node x intra-node) topology (DESIGN.md §8) ---


def t_net_intra(bytes_on_wire: float, hw: Hardware) -> float:
    """Alpha-beta term for one intra-node hop (NVLink/ICI link class);
    identical to ``t_net`` on a flat fabric (``intra_gbps == 0``)."""
    gbps, alpha_us = hw.intra_terms()
    return alpha_us * 1e-6 + bytes_on_wire / (gbps * 1e9 / 8)


def reduce_scatter_uncompressed_intra(D, L, hw: Hardware) -> float:
    """Uncompressed ring reduce-scatter over the L intra-node ranks:
    (L-1) hops of D/L on the fast link, no codec anywhere — at NVLink
    bandwidth the compressor would be the bottleneck, which is the whole
    point of placing codec work only on the slow hop."""
    L = max(int(L), 1)
    if L == 1:
        return 0.0
    return (L - 1) * (t_net_intra(D / L, hw) + t_reduce(D / L, hw))


def allgather_uncompressed_intra(D, L, hw: Hardware) -> float:
    """Uncompressed ring allgather of the L node-local shards (D total)."""
    L = max(int(L), 1)
    if L == 1:
        return 0.0
    return (L - 1) * t_net_intra(D / L, hw)


def allreduce_hier_gz(
    D, n_nodes, L, R, hw: Hardware, *,
    inter_algo: str = "ring", chunks: int = 1,
    fused_hop: bool = True, overlap: float = 0.7,
) -> float:
    """Two-level allreduce: uncompressed intra-node reduce-scatter
    (fast link, D/L shards) → compressed ``inter_algo`` allreduce of the
    D/L shard across the n_nodes node peers (slow link — the only place
    the codec runs) → uncompressed intra-node allgather.

    Each stage reuses the exact single-axis model it composes, so the
    hier-vs-flat comparison in the planner prices both sides with the
    same machinery.  The inter stage dominates whenever
    ``hw.link_asymmetry()`` is large: the flat compressed ring ships
    ~2(N-1) chunk streams across node boundaries, the hierarchy ships
    the inter pattern on a 1/L-size shard.
    """
    n_nodes = max(int(n_nodes), 1)
    L = max(int(L), 1)
    total = reduce_scatter_uncompressed_intra(D, L, hw)
    shard = D / L
    if n_nodes > 1:
        if inter_algo == "redoub":
            total += allreduce_redoub_gz(
                shard, n_nodes, R, hw, overlap, fused_hop=fused_hop
            )
        elif inter_algo == "intring":
            total += allreduce_intring_gz(shard, n_nodes, R, hw, overlap)
        else:
            total += allreduce_ring_gz_chunked(
                shard, n_nodes, R, hw, chunks, fused_hop=fused_hop
            )
    total += allgather_uncompressed_intra(D, L, hw)
    return total


def allreduce_cprp2p(D, N, R, hw: Hardware) -> float:
    """CPRP2P [30]: compress+decompress around EVERY hop, no overlap."""
    ch = D / N
    per_hop = t_compress(ch, hw) + t_net(ch / R, hw) + t_decompress(ch, hw) + t_reduce(ch, hw)
    return 2 * (N - 1) * per_hop


def allreduce_ccoll(D, N, R, hw: Hardware) -> float:
    """C-Coll [12]: compression-optimized but CPU-centric — adds host
    staging (PCIe both ways per hop) and no GPU-side overlap."""
    ch = D / N
    stage = 2 * ch / (hw.pcie_gbps * 1e9 / 8) if hw.pcie_gbps else 0.0
    step_rs = t_compress(ch, hw) + t_net(ch / R, hw) + t_decompress(ch, hw) \
        + t_reduce(ch, hw) + stage
    step_ag = t_net(ch / R, hw) + t_decompress(ch, hw) + stage
    return (N - 1) * step_rs + t_compress(ch, hw) + (N - 1) * step_ag


# --- Chunked double-buffered pipeline (DESIGN.md §4) ---
#
# The explicit per-chunk overlap model of the pipelined schedules in
# core/collectives.py.  Unlike the fractional ``overlap`` discount above
# (which credits an *assumed* multi-stream engine), this models the
# schedule the implementation actually runs, over TWO resources: the
# device (where every codec kernel serializes — compress and
# decompress+reduce cannot overlap each other) and the wire.  Each ring
# chunk is split into ``chunks`` pieces double-buffered through the
# [device, wire] chain, so steady-state throughput is set by the slower
# resource and the ends pay a fill + drain of one piece.  chunks=1 is the
# sequential schedule (zero overlap) — what the unpipelined code paths do.
# The cost of pipelining is explicit too: every piece-hop pays the
# per-invocation device overhead (TWO ``cmp_overhead_us`` on the
# decoupled two-kernel hop, ONE on the fused single-pass hop) and runs at
# the *piece* size's utilization — which is why the best chunk count
# falls back to 1 below the saturation size, and why fusing the hop
# moves the overhead-vs-overlap break-even toward deeper pipelines.


def _pipeline_phase(stage_times, chunks: int) -> float:
    """Fill/drain + steady-state time of `chunks` pieces through serial,
    double-buffered stages: sum(stages) + (chunks-1) * max(stages)."""
    return sum(stage_times) + (chunks - 1) * max(stage_times)


def allreduce_ring_gz_chunked(
    D, N, R, hw: Hardware, chunks: int = 1, *, fused_hop: bool = True
) -> float:
    """gZ-Allreduce (Ring) under the chunked double-buffered schedule.

    Each of the (N-1) RS steps pipelines `chunks` pieces of D/(N*chunks)
    bytes over the [device, wire] resource pair; the AG stage does the
    same with the forwarding decompress, plus the owner's one-off
    piece-wise compression.

    Per piece-hop the device stage is:

      two-kernel hop (PR 1):  t_compress + t_decompress + t_reduce
                              — TWO ``cmp_overhead_us`` plus the f32
                              intermediate's HBM round-trip, every hop;
      ``fused_hop``:          ``t_hop_fused`` — ONE overhead, one VMEM
                              pass, preceded by a one-off pipeline fill
                              (step 0's P piece compressions).

    Pipelining hides wire time behind device time (or vice versa); its
    price is the per-piece device overhead times depth.  Halving that
    overhead via the fused hop is what moves ``best_pipeline_chunks``
    deeper (DESIGN.md §4).
    """
    piece = D / N / chunks
    wire = t_net(piece / R, hw)
    if fused_hop:
        fill = chunks * t_compress(piece, hw)  # step 0's sends, up front
        rs = fill + (N - 1) * _pipeline_phase(
            [t_hop_fused(piece, hw), wire], chunks
        )
    else:
        dev = t_compress(piece, hw) + t_decompress(piece, hw) + t_reduce(piece, hw)
        rs = (N - 1) * _pipeline_phase([dev, wire], chunks)
    own = chunks * t_compress(piece, hw)  # owner compress, not overlappable
    step_ag = _pipeline_phase([wire, t_decompress(piece, hw)], chunks)
    return rs + own + (N - 1) * step_ag


def scatter_binomial_gz_chunked(D, N, R, hw: Hardware, chunks: int = 1) -> float:
    """gZ-Scatter with each tree round's full-span slab split into
    `chunks` piece chains: the receiver-side install (buffer copy at
    reduce bandwidth) overlaps the next piece's wire time.  Rounds and
    slab sizes follow the trimmed-slab schedule the execute layer runs at
    any N (``binomial_slab_table``): only real-rank chunks are priced,
    and a trimmed boundary slab ships as one piece (its size is not a
    power of two, so the execute layer does not split it)."""
    chunk = D / N
    total = t_compress(D, hw)  # batched root compression, saturated
    for entry in binomial_slab_table(N):
        span = entry[0]
        slab, is_full = _root_slab_chunks(entry)
        g = min(chunks, span) if (is_full and span > 1) else 1
        piece = slab * chunk / R / g
        total += _pipeline_phase(
            [t_net(piece, hw), t_reduce(piece, hw)], g
        )
    total += t_decompress(D / N, hw)
    return total


# Single source of truth for every planner entry point (selector plan,
# gz_allreduce auto, grad_sync routing) — keep them agreeing.
PIPELINE_CHUNK_CANDIDATES = (1, 2, 4, 8, 16)


def best_pipeline_chunks(
    D, N, R, hw: Hardware, candidates=PIPELINE_CHUNK_CANDIDATES, *,
    fused_hop: bool = True,
) -> int:
    """Chunk count minimizing the chunked-ring model (1 == don't pipeline).

    With ``fused_hop`` the per-piece fixed cost is one kernel overhead
    instead of two, so the optimum is deeper (or equal) at every (D, N).
    """
    return min(
        candidates,
        key=lambda c: allreduce_ring_gz_chunked(
            D, N, R, hw, c, fused_hop=fused_hop
        ),
    )


def best_scatter_pipeline_chunks(
    D, N, R, hw: Hardware, candidates=PIPELINE_CHUNK_CANDIDATES
) -> int:
    """Per-round piece count minimizing the chunked scatter model — the
    depth ``comm.plan("scatter", ...)`` resolves when the caller asks for
    auto depth (``requested_chunks == 0``), closing the ISSUE 5 dead path
    where ``scatter_binomial_gz_chunked`` existed but no planner ever
    selected a chunked scatter schedule."""
    return min(
        candidates,
        key=lambda c: scatter_binomial_gz_chunked(D, N, R, hw, c),
    )


# --- Bucketed backward overlap (ISSUE 9) ---

BUCKET_BYTES_CANDIDATES = tuple(
    (1 << 20) * m for m in (1, 2, 4, 8, 16, 32, 64)
)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Frozen co-plan of bucket size and ring pipeline depth for the
    backward-overlapped gradient sync.

    ``overlap_efficiency`` is the fraction of the total sync time hidden
    under backward compute by the greedy schedule: 0.0 means fully serial
    (one bucket, or no calibrated compute term), values approach 1.0 when
    the wire is completely hidden.  All fields are static model outputs —
    BENCH_gradsync.json pins them exactly in CI.
    """

    bucket_bytes: int        # payload per compressed allreduce
    n_buckets: int
    pipeline_chunks: int     # ring depth each bucket's plan resolves
    t_backward: float        # seconds of backward compute (model)
    t_bucket: float          # seconds per bucket allreduce (model)
    t_sync_total: float      # n_buckets * t_bucket
    t_serial: float          # backward THEN sync (the pre-ISSUE 9 shape)
    t_overlapped: float      # greedy last-layer-first schedule finish
    overlap_efficiency: float

    @property
    def speedup(self) -> float:
        return self.t_serial / self.t_overlapped if self.t_overlapped else 1.0


def _overlap_schedule(t_backward: float, n_buckets: int,
                      t_bucket: float) -> float:
    """Finish time of the greedy last-layer-first schedule.

    Backward produces gradients in reverse layer order at a uniform
    modeled rate, so bucket ``i`` (issue order) is ready at
    ``(i+1) * t_backward / K``; the wire is a single serial resource, so
    each bucket starts at ``max(ready_i, prev_finish)``.  Compute-bound
    regimes finish at ``t_backward + t_bucket`` (all but the last bucket
    fully hidden); wire-bound regimes at ``t_backward/K + K*t_bucket``
    (the wire never idles after the first bucket lands).
    """
    finish = 0.0
    for i in range(n_buckets):
        ready = (i + 1) * t_backward / n_buckets
        finish = max(finish, ready) + t_bucket
    return finish


def best_bucket_plan(
    hw: Hardware, tree_bytes: float, backward_flops: float, n: int,
    R: float = 20.0, *, candidates=BUCKET_BYTES_CANDIDATES,
    fused_hop: bool = True,
) -> BucketPlan:
    """Co-plan bucket size with ring pipeline depth so codec work hides
    under both ppermute AND backward FLOPs.

    The tension the search resolves: big buckets keep the compressor on
    its saturation plateau (``_util``) and amortize per-hop alphas, but
    the first bucket cannot launch before ``t_backward / K`` — small
    buckets start the wire earlier and drain it in parallel with the
    remaining backward, at worse codec utilization.  Each candidate
    prices its per-bucket allreduce through the SAME chunked-ring model
    the plan layer uses (``best_pipeline_chunks`` →
    ``allreduce_ring_gz_chunked``), so the depth the bucket's frozen Plan
    will actually resolve is the depth being priced.
    """
    n = int(n)
    tree_bytes = float(tree_bytes)
    if tree_bytes <= 0:
        raise ValueError(f"best_bucket_plan: tree_bytes={tree_bytes!r}")
    t_backward = (
        float(backward_flops) / (hw.compute_tflops * 1e12)
        if hw.compute_tflops > 0 else 0.0
    )
    best = None
    for cand in candidates:
        b = int(min(cand, tree_bytes))
        k = int(math.ceil(tree_bytes / b))
        if n > 1:
            depth = best_pipeline_chunks(b, n, R, hw, fused_hop=fused_hop)
            t_bucket = allreduce_ring_gz_chunked(
                b, n, R, hw, depth, fused_hop=fused_hop
            )
        else:
            depth, t_bucket = 1, 0.0
        t_sync = k * t_bucket
        t_serial = t_backward + t_sync
        t_over = _overlap_schedule(t_backward, k, t_bucket)
        eff = (t_serial - t_over) / t_sync if t_sync > 0 else 0.0
        plan = BucketPlan(
            bucket_bytes=b, n_buckets=k, pipeline_chunks=depth,
            t_backward=t_backward, t_bucket=t_bucket, t_sync_total=t_sync,
            t_serial=t_serial, t_overlapped=t_over,
            overlap_efficiency=eff,
        )
        if best is None or plan.t_overlapped < best.t_overlapped:
            best = plan
    return best


# --- Data movement ---


def allgather_ring_gz(D_chunk, N, R, hw: Hardware, overlap: float = 0.7) -> float:
    """gZ-Allgather: 1 compression + (N-1) forward hops w/ overlapped dec."""
    one = _overlapped(t_decompress(D_chunk, hw), t_net(D_chunk / R, hw), overlap)
    return t_compress(D_chunk, hw) + (N - 1) * one


def scatter_binomial_gz(D, N, R, hw: Hardware, overlap: float = 0.7) -> float:
    """gZ-Scatter: batched root compression of N chunks (ONE saturated call
    — the multi-stream analog) + ceil(log2 N) tree rounds of trimmed
    slabs + one decompression at each leaf.  Per-round payloads are the
    root's real-rank slab sizes from ``binomial_slab_table`` (summing to
    N-1 chunks at any N) — identical to the classic 2**k halving slabs on
    power-of-two axes, strictly smaller otherwise."""
    chunk = D / N
    total = t_compress(D, hw)  # batched: full-size utilization
    for entry in binomial_slab_table(N):
        slab, _ = _root_slab_chunks(entry)
        total += t_net(slab * chunk / R, hw)
    total += t_decompress(D / N, hw)
    return total


def scatter_uncompressed_binomial(D, N, hw: Hardware) -> float:
    """Cray-MPI-model binomial scatter: same trimmed-slab round structure
    (a real MPI scatter ships exactly N-1 chunks too), uncompressed."""
    chunk = D / N
    return sum(
        t_net(_root_slab_chunks(entry)[0] * chunk, hw)
        for entry in binomial_slab_table(N)
    )


# --- Degradation pricing (DESIGN.md §9) ---


def fallback_time(op: str, D, N, hw: Hardware) -> float:
    """Seconds the LOSSLESS fallback schedule of ``op`` costs: the price
    of one degraded call (``collectives._execute_lossless``), recorded on
    every ``Plan.fallback`` so the planner can expose what an overflow /
    non-finite event will cost at runtime.

    ``D`` is the raw f32 byte size of the op's input payload.  The
    fallback is algorithm-UNIFORM — the same uncompressed schedule runs
    regardless of which compressed algo the plan picked — so this is
    informational/observable, never a re-ranking input for the selector
    (a fallback should be rare; pricing it into the ranking would just
    bias against compression everywhere).
    """
    N = int(N)
    if N <= 1:
        return 0.0
    if op == "allreduce":
        return allreduce_uncompressed_ring(D, N, hw)
    if op == "reduce_scatter":
        return (N - 1) * (t_net(D / N, hw) + t_reduce(D / N, hw))
    if op == "allgather":
        return (N - 1) * t_net(D, hw)
    if op == "scatter":
        return scatter_uncompressed_binomial(D, N, hw)
    if op == "broadcast":
        return steps_for("binomial", N) * t_net(D, hw)
    if op == "all_to_all":
        return t_net(D, hw)
    raise ValueError(f"fallback_time: unknown op {op!r}")


def expected_collective_time(
    t_compressed: float, t_fallback: float, p_degraded: float
) -> float:
    """Expected wall time when a fraction ``p_degraded`` of calls degrade:
    a degraded call pays the compressed schedule (the overflow is only
    known once the streams have been exchanged) AND the lossless
    re-execute on top."""
    p = min(max(float(p_degraded), 0.0), 1.0)
    return float(t_compressed) + p * float(t_fallback)
