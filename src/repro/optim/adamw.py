"""AdamW with sharded optimizer state + cosine LR schedule.

Optimizer states inherit each parameter's sharding (moments are elementwise)
so ZeRO-style memory scaling falls out of the param specs for free.  All
moments are f32 regardless of param dtype (bf16-safe).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, state, cfg: AdamWConfig, *, grad_norm=None):
    """One AdamW step.  ``grad_norm`` may be passed in when the true global
    norm requires cross-rank reduction (the caller psums it)."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gn = grad_norm if grad_norm is not None else _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"lr": lr, "gnorm": gn}
