"""deepseek-67b [dense GQA, llama-arch]  [arXiv:2401.02954]

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.  The largest
assigned config — FSDP over "data" is what makes it fit 16 GB/chip.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-67b",
        family="dense",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab=102400,
        source="arXiv:2401.02954",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        source="arXiv:2401.02954",
    )
