"""minicpm3-4b [dense, MLA]  [hf:openbmb/MiniCPM3-4B]

62L d_model=2560 40H (kv=40 i.e. MHA within MLA) d_ff=6400 vocab=73448.
Real Multi-head Latent Attention: q_lora=768, kv_lora=256, qk_nope=64,
qk_rope=32, v=64 (per the MiniCPM3 card).  40 heads pad to 48 for tp=16.
"""
from repro.models.config import MLAConfig, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        head_dim=64,
        d_ff=6400,
        vocab=73448,
        mla=MLAConfig(
            q_lora_rank=768,
            kv_lora_rank=256,
            qk_nope_head_dim=64,
            qk_rope_head_dim=32,
            v_head_dim=64,
        ),
        source="hf:openbmb/MiniCPM3-4B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="minicpm3-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        mla=MLAConfig(
            q_lora_rank=64,
            kv_lora_rank=32,
            qk_nope_head_dim=32,
            qk_rope_head_dim=16,
            v_head_dim=32,
        ),
        source="hf:openbmb/MiniCPM3-4B",
    )
