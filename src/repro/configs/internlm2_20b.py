"""internlm2-20b [dense GQA]  [arXiv:2403.17297]

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="internlm2-20b",
        family="dense",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=92544,
        source="arXiv:2403.17297",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="internlm2-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        source="arXiv:2403.17297",
    )
