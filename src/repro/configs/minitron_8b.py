"""minitron-8b [dense GQA, pruned nemotron]  [arXiv:2407.14679]

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        vocab=256000,
        source="arXiv:2407.14679",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="minitron-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        source="arXiv:2407.14679",
    )
