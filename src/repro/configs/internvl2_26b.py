"""internvl2-26b [VLM: InternViT + InternLM2]  [arXiv:2404.16821]

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  The assigned
backbone is the language decoder; the InternViT vision encoder +
projector frontend is STUBBED — input_specs() provides 256 projected
patch embeddings (B, 256, d_model) prepended to the text sequence.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=92553,
        n_prefix=256,
        source="arXiv:2404.16821",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2-smoke",
        family="vlm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        n_prefix=16,
        source="arXiv:2404.16821",
    )
