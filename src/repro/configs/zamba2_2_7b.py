"""zamba2-2.7b [hybrid: Mamba2 + shared attention]  [arXiv:2411.15242]

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Mamba2 backbone with ONE shared attention+MLP block applied every 6
layers (the zamba2 shared-block design).  long_500k runs with a sliding
window on the shared attention block.
"""
from repro.models.config import ModelConfig, SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab=32000,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2),
        attn_every=6,
        shared_attn=True,
        source="arXiv:2411.15242",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-smoke",
        family="hybrid",
        n_layers=4,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab=512,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2),
        attn_every=2,
        shared_attn=True,
        source="arXiv:2411.15242",
    )
