"""seamless-m4t-medium [audio enc-dec]  [arXiv:2308.11596]

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.  Interpreted as a
12-layer speech encoder + 12-layer text decoder (the assigned backbone);
the mel-spectrogram + conv feature extractor frontend is STUBBED —
input_specs() provides precomputed frame embeddings (B, S_enc, d_model).
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="seamless-m4t-medium",
        family="encdec",
        n_layers=12,
        n_enc_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab=256206,
        n_prefix=1024,  # encoder frame positions fed by the frontend stub
        source="arXiv:2308.11596",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="seamless-m4t-medium-smoke",
        family="encdec",
        n_layers=2,
        n_enc_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab=512,
        n_prefix=16,
        source="arXiv:2308.11596",
    )
