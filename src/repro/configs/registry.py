"""Architecture registry: --arch <id> resolution for launchers/tests."""
from __future__ import annotations

from repro.configs import (
    deepseek_67b,
    internlm2_20b,
    internvl2_26b,
    llama4_scout_17b_a16e,
    mamba2_780m,
    minicpm3_4b,
    minitron_8b,
    phi3_5_moe_42b,
    seamless_m4t_medium,
    zamba2_2_7b,
)

ARCHS = {
    "seamless-m4t-medium": seamless_m4t_medium,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "zamba2-2.7b": zamba2_2_7b,
    "minitron-8b": minitron_8b,
    "minicpm3-4b": minicpm3_4b,
    "mamba2-780m": mamba2_780m,
    "internlm2-20b": internlm2_20b,
    "deepseek-67b": deepseek_67b,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe_42b,
    "internvl2-26b": internvl2_26b,
}


def get(arch_id: str, *, smoke: bool = False):
    mod = ARCHS[arch_id]
    return mod.smoke() if smoke else mod.full()


def arch_ids():
    return list(ARCHS)
