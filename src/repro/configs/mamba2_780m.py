"""mamba2-780m [SSM / SSD]  [arXiv:2405.21060]

48L d_model=1536 (attention-free) vocab=50280, ssm_state=128.  Pure SSD
(state-space duality) stack; head_dim=64, expand=2 -> d_inner=3072,
48 SSD heads.
"""
from repro.models.config import ModelConfig, SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2),
        source="arXiv:2405.21060",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=128,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=512,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2),
        source="arXiv:2405.21060",
    )
