"""llama4-scout-17b-a16e [MoE]  [hf:meta-llama/Llama-4-Scout-17B-16E]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, 16 experts top-1,
early fusion.  40 q heads are padded to 48 for the tp=16 mesh (zero-init
extras — DESIGN.md hardware-adaptation notes).
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=202048,
        n_experts=16,
        top_k=1,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="llama4-scout-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        n_experts=4,
        top_k=1,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
