"""Pallas TPU kernels for the entropy-coded wire stage (DESIGN.md §10).

Same single-pass structure as the dense kernels in ``lorenzo.py`` — one
``(TILE_ROWS, BLOCK)`` tile per grid step, a resident packed window, and
an SMEM word-offset carry across the sequential grid — but each block's
payload is packed at FOUR per-sub-block widths instead of one: block
``i`` splits into ``entropy.SUBS`` sub-blocks of ``entropy.SUB`` elements
and sub ``k`` occupies exactly ``SUB_WORDS_PER_BIT * bw_k`` words (SUB is
a multiple of 32, so sub boundaries stay word-aligned and the dense
packer's alignment argument carries over unchanged).

The four 6-bit sub-widths travel packed into one int32 descriptor in the
``Compressed.bitwidth`` slot, so the tile's worst case is still
``TILE_ROWS * BLOCK`` words and the dense kernels' PACK_PAD window and
dump-tail overflow clamp apply verbatim.

Per-element widths/offsets are computed with a static unroll over the
``SUBS`` sub indices (one-hot sums) rather than a gather: TPU vector
lanes hate data-dependent gathers, and with SUBS=4 the unroll is four
masked adds.

A static ``lossless`` flag swaps the error-bounded quantizer for a
bit-exact ``bitcast(f32)->int32`` front end; everything downstream
(delta, zigzag, entropy pack) is shared, and int32 wraparound makes the
delta chain reconstruct exactly.

Byte streams are IDENTICAL to the jnp oracle in ``core/entropy.py``
(asserted in tests/test_codecs.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lorenzo import (
    BLOCK,
    PACK_PAD_WORDS,
    TILE_ROWS,
    _row_spec,
    _scalar_spec,
    _width_mask,
)

SUBS = 4
SUB = BLOCK // SUBS
SUB_WORDS_PER_BIT = SUB // 32
_DESC_BITS = 6


def _codes_tile(x, recip, lossless):
    """f32 tile -> (zigzag codes, anchor col); lossless bitcasts instead of
    quantizing so the delta chain acts on raw IEEE bit patterns."""
    if lossless:
        q = jax.lax.bitcast_convert_type(x, jnp.int32)
    else:
        q = jnp.rint(x * recip).astype(jnp.int32)
    col = jax.lax.broadcasted_iota(jnp.int32, q.shape, 1)
    prev = jnp.where(col == 0, q, jnp.roll(q, 1, axis=1))
    d = q - prev
    zig = ((d << 1) ^ (d >> 31)).astype(jnp.uint32)
    return zig, q[:, :1]


def _sub_widths_tile(zig):
    """(TILE_ROWS, BLOCK) zigzag codes -> (TILE_ROWS, SUBS) int32 widths.

    Masked per-sub maxima via a static unroll — no reshape of the lane
    dimension, no gather.
    """
    j = jax.lax.broadcasted_iota(jnp.int32, (TILE_ROWS, BLOCK), 1)
    sub_idx = j // SUB
    powers = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    widths = []
    for k in range(SUBS):
        umax_k = jnp.max(jnp.where(sub_idx == k, zig, jnp.uint32(0)), axis=1)
        widths.append(
            jnp.sum((umax_k[:, None] >= powers[None, :]).astype(jnp.int32), axis=1)
        )
    return jnp.stack(widths, axis=1)


def _make_desc_col(sub_bw):
    desc = sub_bw[:, 0]
    for k in range(1, SUBS):
        desc = desc | (sub_bw[:, k] << (_DESC_BITS * k))
    return desc[:, None]


def _split_desc_col(desc_col):
    mask = (1 << _DESC_BITS) - 1
    return jnp.concatenate(
        [(desc_col >> (_DESC_BITS * k)) & mask for k in range(SUBS)], axis=1
    )


def _entropy_tile_geometry(sub_bw):
    """Tile-local per-element word / shift / width for the entropy layout.

    ``sub_bw``: (TILE_ROWS, SUBS) int32.  Word offsets are exclusive
    cumsums at sub then block granularity; per-element selection is a
    one-hot sum over the SUBS static sub indices.
    """
    words_per_sub = sub_bw * SUB_WORDS_PER_BIT
    words_per_block = jnp.sum(words_per_sub, axis=1)
    block_off = jnp.cumsum(words_per_block) - words_per_block  # exclusive
    sub_off = jnp.cumsum(words_per_sub, axis=1) - words_per_sub  # exclusive
    j = jax.lax.broadcasted_iota(jnp.int32, (TILE_ROWS, BLOCK), 1)
    sub_idx = j // SUB
    jj = j - sub_idx * SUB
    bw_el = jnp.zeros((TILE_ROWS, BLOCK), jnp.int32)
    off_el = jnp.zeros((TILE_ROWS, BLOCK), jnp.int32)
    for k in range(SUBS):
        m = (sub_idx == k).astype(jnp.int32)
        bw_el = bw_el + m * sub_bw[:, k:k + 1]
        off_el = off_el + m * sub_off[:, k:k + 1]
    bitpos = (block_off[:, None] + off_el) * 32 + jj * bw_el
    word = bitpos >> 5
    shift = (bitpos & 31).astype(jnp.uint32)
    return word, shift, bw_el.astype(jnp.uint32), words_per_block


def _entropy_pack_tile(zig, sub_bw, packed_ref, off_ref):
    """Pack one tile at per-sub widths into the resident packed window,
    advancing the SMEM word-offset carry (same clamp/dump-tail overflow
    handling as the dense ``_pack_tile``)."""
    word, shift, bwu, words_per_block = _entropy_tile_geometry(sub_bw)
    u = zig & _width_mask(bwu)
    lo = u << shift
    hi = jnp.where(shift == 0, jnp.uint32(0),
                   u >> jnp.minimum(32 - shift, jnp.uint32(31)))
    fw = word.reshape(-1)
    local = jnp.zeros((PACK_PAD_WORDS,), jnp.uint32)
    local = local.at[fw].add(lo.reshape(-1))
    local = local.at[fw + 1].add(hi.reshape(-1))

    start = off_ref[0]
    capacity = packed_ref.shape[0] - PACK_PAD_WORDS
    s = jnp.minimum(start, capacity)
    window = packed_ref[pl.ds(s, PACK_PAD_WORDS)]
    packed_ref[pl.ds(s, PACK_PAD_WORDS)] = window | local
    off_ref[0] = start + jnp.sum(words_per_block)


def _entropy_unpack_tile(packed_ref, desc_col, off_ref):
    """Gather + unpack one tile's segment at per-sub widths from the
    resident packed window, advancing the SMEM carry."""
    sub_bw = _split_desc_col(desc_col)
    word, shift, bwu, words_per_block = _entropy_tile_geometry(sub_bw)
    start = off_ref[0]
    capacity = packed_ref.shape[0] - PACK_PAD_WORDS
    s = jnp.minimum(start, capacity)
    window = packed_ref[pl.ds(s, PACK_PAD_WORDS)]
    lo = window[word] >> shift
    hi = jnp.where(shift == 0, jnp.uint32(0),
                   window[word + 1] << jnp.minimum(32 - shift, jnp.uint32(31)))
    off_ref[0] = start + jnp.sum(words_per_block)
    return (lo | hi) & _width_mask(bwu)


def _reconstruct(u, anchor_col, twoeb, lossless):
    d = (u >> 1).astype(jnp.int32) ^ (-(u & 1).astype(jnp.int32))
    q = anchor_col + jnp.cumsum(d, axis=1)
    if lossless:
        return jax.lax.bitcast_convert_type(q, jnp.float32)
    return q.astype(jnp.float32) * twoeb


def _quantize_pack_kernel(lossless, x_ref, recip_ref, packed_ref, desc_ref,
                          anchor_ref, off_ref):
    """quantize (or bitcast) + zigzag + entropy pack in one pass."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        packed_ref[...] = jnp.zeros_like(packed_ref[...])
        off_ref[0] = 0

    zig, anchor = _codes_tile(x_ref[...], recip_ref[0, 0], lossless)
    sub_bw = _sub_widths_tile(zig)
    desc_ref[...] = _make_desc_col(sub_bw)
    anchor_ref[...] = anchor
    _entropy_pack_tile(zig, sub_bw, packed_ref, off_ref)


def _unpack_dequantize_kernel(lossless, packed_ref, desc_ref, anchor_ref,
                              twoeb_ref, out_ref, off_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        off_ref[0] = 0

    u = _entropy_unpack_tile(packed_ref, desc_ref[...], off_ref)
    out_ref[...] = _reconstruct(u, anchor_ref[...], twoeb_ref[0, 0], lossless)


def _unpack_dequantize_reduce_kernel(lossless, packed_ref, desc_ref,
                                     anchor_ref, twoeb_ref, acc_ref, out_ref,
                                     off_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        off_ref[0] = 0

    u = _entropy_unpack_tile(packed_ref, desc_ref[...], off_ref)
    out_ref[...] = acc_ref[...] + _reconstruct(
        u, anchor_ref[...], twoeb_ref[0, 0], lossless
    )


def _eb_scalars(eb, lossless):
    """(recip, twoeb) (1,1) f32 operands; inert ones in lossless mode so an
    eb of zero can't divide by zero on a path that never reads it."""
    if lossless:
        one = jnp.ones((1, 1), jnp.float32)
        return one, one
    recip = (1.0 / (2.0 * eb)).reshape(1, 1).astype(jnp.float32)
    twoeb = (2.0 * eb).reshape(1, 1).astype(jnp.float32)
    return recip, twoeb


@functools.partial(
    jax.jit, static_argnames=("capacity_words", "lossless", "interpret")
)
def quantize_pack(
    x2d: jnp.ndarray, eb: jnp.ndarray, capacity_words: int, *,
    lossless: bool = False, interpret: bool = True,
):
    """f32 (n_blocks, BLOCK) -> (packed uint32[capacity_words], desc int32
    (n_blocks,), anchor int32 (n_blocks,)) at per-sub-block widths.

    Byte stream identical to ``core.entropy.pack(encode_blocks(x2d, eb))``.
    """
    n_blocks = x2d.shape[0]
    recip, _ = _eb_scalars(eb, lossless)
    cap_pad = capacity_words + PACK_PAD_WORDS
    packed, desc, anchor = pl.pallas_call(
        functools.partial(_quantize_pack_kernel, lossless),
        grid=(n_blocks // TILE_ROWS,),
        in_specs=[_row_spec(BLOCK), _scalar_spec()],
        out_specs=[
            pl.BlockSpec((cap_pad,), lambda i: (0,)),
            _row_spec(1),
            _row_spec(1),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap_pad,), jnp.uint32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(x2d, recip)
    return packed[:capacity_words], desc[:, 0], anchor[:, 0]


@functools.partial(jax.jit, static_argnames=("lossless", "interpret"))
def unpack_dequantize(
    packed: jnp.ndarray, desc: jnp.ndarray, anchor: jnp.ndarray,
    eb: jnp.ndarray, *, lossless: bool = False, interpret: bool = True,
):
    """Entropy stream -> f32 (n_blocks, BLOCK), no accumulator."""
    n_blocks = desc.shape[0]
    _, twoeb = _eb_scalars(eb, lossless)
    cap_pad = packed.shape[0] + PACK_PAD_WORDS
    packed_pad = jnp.zeros((cap_pad,), jnp.uint32).at[: packed.shape[0]].set(packed)
    return pl.pallas_call(
        functools.partial(_unpack_dequantize_kernel, lossless),
        grid=(n_blocks // TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((cap_pad,), lambda i: (0,)),
            _row_spec(1),
            _row_spec(1),
            _scalar_spec(),
        ],
        out_specs=_row_spec(BLOCK),
        out_shape=jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(packed_pad, desc[:, None], anchor[:, None], twoeb)


@functools.partial(jax.jit, static_argnames=("lossless", "interpret"))
def unpack_dequantize_reduce(
    packed: jnp.ndarray, desc: jnp.ndarray, anchor: jnp.ndarray,
    eb: jnp.ndarray, acc: jnp.ndarray, *,
    lossless: bool = False, interpret: bool = True,
):
    """Entropy stream + acc -> acc + decompressed f32 (n_blocks, BLOCK)."""
    n_blocks = acc.shape[0]
    _, twoeb = _eb_scalars(eb, lossless)
    cap_pad = packed.shape[0] + PACK_PAD_WORDS
    packed_pad = jnp.zeros((cap_pad,), jnp.uint32).at[: packed.shape[0]].set(packed)
    return pl.pallas_call(
        functools.partial(_unpack_dequantize_reduce_kernel, lossless),
        grid=(n_blocks // TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((cap_pad,), lambda i: (0,)),
            _row_spec(1),
            _row_spec(1),
            _scalar_spec(),
            _row_spec(BLOCK),
        ],
        out_specs=_row_spec(BLOCK),
        out_shape=jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(packed_pad, desc[:, None], anchor[:, None], twoeb, acc)
