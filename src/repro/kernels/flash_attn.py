"""Pallas TPU flash-attention kernel (canonical revisited-output pattern).

Grid: (batch*heads, q_blocks, kv_blocks), kv innermost.  The output block
is revisited across the kv dimension; running (max, sumexp, acc) live in
VMEM scratch that persists across the kv grid steps.  On the last kv step
the normalized block is written out.

Tiling: BQ=128 q rows x D lanes (D 64/128 aligns the MXU); BK=128 kv rows.
VMEM per grid cell ~ (BQ*D + 2*BK*D + BQ*D + 2*BQ) f32 — ~260 KB at
D=128, comfortably inside VMEM with double-buffered pipelines.

Causal/sliding-window masks are applied from absolute block offsets; fully
masked kv blocks still execute under interpret mode (a TPU deployment
would skip them via the grid's index_map — noted as the next kernel-level
optimization in EXPERIMENTS.md).

Validated in interpret mode against kernels/ref.py::attention_ref
(tests/test_flash_kernel.py sweeps shapes, dtypes, causal, window).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30

BQ = 128
BK = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, causal,
            window, sq, sk, n_kv):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (BQ, D)
    k = k_ref[0].astype(jnp.float32)  # (BK, D)
    v = v_ref[0].astype(jnp.float32)
    d = q.shape[-1]
    scale = 1.0 / (d ** 0.5)
    s = jnp.dot(q * scale, k.T, preferred_element_type=jnp.float32)  # (BQ, BK)

    q_pos = q_i * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
    k_pos = kv_i * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
    mask = (k_pos < sk) & (q_pos < sq)
    if causal:
        mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]  # (BQ, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "interpret")
)
def flash_attention_bhsd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    interpret: bool = True,
):
    """q/k/v: (BH, S, D) — batch*heads flattened.  Returns (BH, Sq, D)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    nq = -(-sq // BQ)
    nk = -(-sk // BK)
    qp = jnp.pad(q, ((0, 0), (0, nq * BQ - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * BK - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * BK - sk), (0, 0)))
    kernel = functools.partial(
        _kernel, causal=causal, window=window, sq=sq, sk=sk, n_kv=nk
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, BQ, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BK, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BK, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nq * BQ, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq]


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    interpret: bool = True,
):
    """q: (B, Sq, H, D); k/v: (B, Sk, H, D) (kv already head-repeated)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    to_bhsd = lambda x, s: x.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    out = flash_attention_bhsd(
        to_bhsd(q, sq), to_bhsd(k, sk), to_bhsd(v, sk),
        causal=causal, window=window, interpret=interpret,
    )
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
