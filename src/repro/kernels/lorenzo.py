"""Pallas TPU kernels for the cuSZp-adapted block compressor.

Three kernels, each tiled ``(TILE_ROWS, BLOCK)`` over a grid of block-rows:

  * ``quantize``          f32 -> zigzag codes + per-block bitwidth
  * ``dequantize``        codes -> f32 (per-block prefix-sum reconstruct)
  * ``dequantize_reduce`` codes + accumulator -> accumulator + f32
    (the paper's on-device reduction kernel, fused with decompression so the
    decompressed tensor never round-trips HBM)

TPU tiling notes (DESIGN.md §2): BLOCK=256 keeps each Lorenzo block two
128-lane vregs wide; TILE_ROWS=8 gives an (8, 256) f32 tile = 8 KiB VMEM in,
8 KiB out, well under VMEM while a multiple of the (8, 128) f32 native tile.
The per-block cumsum is a lane-wise prefix sum on the VPU; blocks are
independent so there is no cross-tile carry — this is what replaces cuSZp's
per-warp layout on the MXU-less part of the chip.

The scalar error bound arrives as a (1, 1) operand mapped to every grid
cell (index_map -> (0, 0)) rather than a closure constant, so one compiled
kernel serves every error budget the collective layer allocates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256
TILE_ROWS = 8


def _bitwidth(umax_keepdims: jnp.ndarray) -> jnp.ndarray:
    powers = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum((umax_keepdims >= powers[None, :]).astype(jnp.int32), axis=-1,
                   keepdims=True)


def _quantize_kernel(x_ref, recip_ref, codes_ref, bw_ref, anchor_ref):
    x = x_ref[...]
    recip = recip_ref[0, 0]
    q = jnp.rint(x * recip).astype(jnp.int32)
    col = jax.lax.broadcasted_iota(jnp.int32, q.shape, 1)
    prev = jnp.where(col == 0, q, jnp.roll(q, 1, axis=1))
    d = q - prev  # first column is 0; absolute value goes out via anchor
    zig = ((d << 1) ^ (d >> 31)).astype(jnp.uint32)
    codes_ref[...] = zig
    umax = jnp.max(zig, axis=1)  # (TILE_ROWS,)
    bw_ref[...] = _bitwidth(umax[:, None])
    anchor_ref[...] = q[:, :1]


def _dequantize_kernel(codes_ref, anchor_ref, twoeb_ref, x_ref):
    u = codes_ref[...]
    d = (u >> 1).astype(jnp.int32) ^ (-(u & 1).astype(jnp.int32))
    q = anchor_ref[...] + jnp.cumsum(d, axis=1)
    x_ref[...] = q.astype(jnp.float32) * twoeb_ref[0, 0]


def _dequantize_reduce_kernel(codes_ref, anchor_ref, twoeb_ref, acc_ref, out_ref):
    u = codes_ref[...]
    d = (u >> 1).astype(jnp.int32) ^ (-(u & 1).astype(jnp.int32))
    q = anchor_ref[...] + jnp.cumsum(d, axis=1)
    out_ref[...] = acc_ref[...] + q.astype(jnp.float32) * twoeb_ref[0, 0]


def _scalar_spec():
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


def _row_spec(width):
    return pl.BlockSpec((TILE_ROWS, width), lambda i: (i, 0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x2d: jnp.ndarray, eb: jnp.ndarray, *, interpret: bool = True):
    """f32 (n_blocks, BLOCK) -> (codes uint32, bitwidth int32 (n_blocks,)).

    n_blocks must be a multiple of TILE_ROWS (ops.py pads).
    """
    n_blocks = x2d.shape[0]
    recip = (1.0 / (2.0 * eb)).reshape(1, 1).astype(jnp.float32)
    grid = (n_blocks // TILE_ROWS,)
    codes, bw, anchor = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[_row_spec(BLOCK), _scalar_spec()],
        out_specs=[_row_spec(BLOCK), _row_spec(1), _row_spec(1)],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.uint32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x2d, recip)
    return codes, bw[:, 0], anchor[:, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize(
    codes: jnp.ndarray, anchor: jnp.ndarray, eb: jnp.ndarray, *, interpret: bool = True
):
    """codes uint32 (n_blocks, BLOCK) + anchor (n_blocks,) -> f32 (n_blocks, BLOCK)."""
    n_blocks = codes.shape[0]
    twoeb = (2.0 * eb).reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(n_blocks // TILE_ROWS,),
        in_specs=[_row_spec(BLOCK), _row_spec(1), _scalar_spec()],
        out_specs=_row_spec(BLOCK),
        out_shape=jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
        interpret=interpret,
    )(codes, anchor[:, None], twoeb)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_reduce(
    codes: jnp.ndarray,
    anchor: jnp.ndarray,
    eb: jnp.ndarray,
    acc: jnp.ndarray,
    *,
    interpret: bool = True,
):
    """Fused decompress-and-add: acc + dequantize(codes, anchor)."""
    n_blocks = codes.shape[0]
    twoeb = (2.0 * eb).reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        _dequantize_reduce_kernel,
        grid=(n_blocks // TILE_ROWS,),
        in_specs=[_row_spec(BLOCK), _row_spec(1), _scalar_spec(), _row_spec(BLOCK)],
        out_specs=_row_spec(BLOCK),
        out_shape=jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
        interpret=interpret,
    )(codes, anchor[:, None], twoeb, acc)
