"""Pallas TPU kernels for the cuSZp-adapted block compressor.

Six kernels, each tiled ``(TILE_ROWS, BLOCK)`` over a grid of block-rows:

  * ``quantize``          f32 -> zigzag codes + per-block bitwidth
  * ``dequantize``        codes -> f32 (per-block prefix-sum reconstruct)
  * ``dequantize_reduce`` codes + accumulator -> accumulator + f32
    (the paper's on-device reduction kernel, fused with decompression so the
    decompressed tensor never round-trips HBM)
  * ``quantize_pack``     f32 -> packed uint32 words directly (DESIGN.md §3):
    the full compression pipeline in ONE pass — the intermediate codes
    array never exists and the separate jnp bitpack scatter pass (with its
    global cumsum sync) is gone.
  * ``unpack_dequantize_reduce``  packed words + acc -> reduced f32, the
    exact inverse fusion for the receive side of a collective.
  * ``unpack_dequantize``  the accumulator-free variant for pure
    decompression (allgather/scatter receive paths).
  * ``unpack_reduce_repack``  the single-pass ring hop: received packed
    words + local f32 chunk -> the NEXT hop's packed words, in one pass —
    the updated f32 chunk never leaves VMEM (DESIGN.md §3.1).

Fused-pack layout invariant: BLOCK is a multiple of 32, so every block's
``BLOCK * bw_i`` bit payload is a whole number of uint32 words — block
boundaries are always word-aligned.  That is what makes single-pass
packing possible on a block-parallel grid: a tile of TILE_ROWS blocks
emits exactly ``8 * sum(bw)`` words at a word offset carried across the
sequential TPU grid in SMEM scratch (no global cumsum, no second pass).
The byte stream is IDENTICAL to ``bitpack.pack(quantize(x))`` — oracle-
tested in tests/test_fused_pipeline.py.

TPU tiling notes (DESIGN.md §2): BLOCK=256 keeps each Lorenzo block two
128-lane vregs wide; TILE_ROWS=8 gives an (8, 256) f32 tile = 8 KiB VMEM in,
8 KiB out, well under VMEM while a multiple of the (8, 128) f32 native tile.
The per-block cumsum is a lane-wise prefix sum on the VPU; blocks are
independent so there is no cross-tile carry — this is what replaces cuSZp's
per-warp layout on the MXU-less part of the chip.

The scalar error bound arrives as a (1, 1) operand mapped to every grid
cell (index_map -> (0, 0)) rather than a closure constant, so one compiled
kernel serves every error budget the collective layer allocates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 256
TILE_ROWS = 8
# Fused-pack geometry: BLOCK % 32 == 0 makes every block's packed payload a
# whole number of words (bw words per 32 elements), so one (TILE_ROWS, BLOCK)
# tile emits at most TILE_ROWS * BLOCK words (all blocks at bw=32).
WORDS_PER_BIT = BLOCK // 32
TILE_WORDS = TILE_ROWS * BLOCK
# Window slack: a tile's clamped read-modify-write window is TILE_WORDS + 1
# words (the +1 absorbs the always-zero straddle word of the last element).
PACK_PAD_WORDS = TILE_WORDS + 1


def _bitwidth(umax_keepdims: jnp.ndarray) -> jnp.ndarray:
    powers = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum((umax_keepdims >= powers[None, :]).astype(jnp.int32), axis=-1,
                   keepdims=True)


def _quantize_tile(x, recip):
    """Shared quantization math: f32 tile -> (zigzag codes, bw col, anchor col)."""
    q = jnp.rint(x * recip).astype(jnp.int32)
    col = jax.lax.broadcasted_iota(jnp.int32, q.shape, 1)
    prev = jnp.where(col == 0, q, jnp.roll(q, 1, axis=1))
    d = q - prev  # first column is 0; absolute value goes out via anchor
    zig = ((d << 1) ^ (d >> 31)).astype(jnp.uint32)
    umax = jnp.max(zig, axis=1)  # (TILE_ROWS,)
    return zig, _bitwidth(umax[:, None]), q[:, :1]


def _quantize_kernel(x_ref, recip_ref, codes_ref, bw_ref, anchor_ref):
    zig, bw, anchor = _quantize_tile(x_ref[...], recip_ref[0, 0])
    codes_ref[...] = zig
    bw_ref[...] = bw
    anchor_ref[...] = anchor


def _dequantize_kernel(codes_ref, anchor_ref, twoeb_ref, x_ref):
    u = codes_ref[...]
    d = (u >> 1).astype(jnp.int32) ^ (-(u & 1).astype(jnp.int32))
    q = anchor_ref[...] + jnp.cumsum(d, axis=1)
    x_ref[...] = q.astype(jnp.float32) * twoeb_ref[0, 0]


def _dequantize_reduce_kernel(codes_ref, anchor_ref, twoeb_ref, acc_ref, out_ref):
    u = codes_ref[...]
    d = (u >> 1).astype(jnp.int32) ^ (-(u & 1).astype(jnp.int32))
    q = anchor_ref[...] + jnp.cumsum(d, axis=1)
    out_ref[...] = acc_ref[...] + q.astype(jnp.float32) * twoeb_ref[0, 0]


def _scalar_spec():
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


def _row_spec(width):
    return pl.BlockSpec((TILE_ROWS, width), lambda i: (i, 0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x2d: jnp.ndarray, eb: jnp.ndarray, *, interpret: bool = True):
    """f32 (n_blocks, BLOCK) -> (codes uint32, bitwidth int32 (n_blocks,)).

    n_blocks must be a multiple of TILE_ROWS (ops.py pads).
    """
    n_blocks = x2d.shape[0]
    recip = (1.0 / (2.0 * eb)).reshape(1, 1).astype(jnp.float32)
    grid = (n_blocks // TILE_ROWS,)
    codes, bw, anchor = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[_row_spec(BLOCK), _scalar_spec()],
        out_specs=[_row_spec(BLOCK), _row_spec(1), _row_spec(1)],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.uint32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x2d, recip)
    return codes, bw[:, 0], anchor[:, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize(
    codes: jnp.ndarray, anchor: jnp.ndarray, eb: jnp.ndarray, *, interpret: bool = True
):
    """codes uint32 (n_blocks, BLOCK) + anchor (n_blocks,) -> f32 (n_blocks, BLOCK)."""
    n_blocks = codes.shape[0]
    twoeb = (2.0 * eb).reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(n_blocks // TILE_ROWS,),
        in_specs=[_row_spec(BLOCK), _row_spec(1), _scalar_spec()],
        out_specs=_row_spec(BLOCK),
        out_shape=jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
        interpret=interpret,
    )(codes, anchor[:, None], twoeb)


# ---------------------------------------------------------------------------
# Fused compression pipeline (DESIGN.md §3)
# ---------------------------------------------------------------------------


def _tile_pack_geometry(bw_col):
    """Per-element word index / shift / width for one tile, tile-local.

    ``bw_col``: (TILE_ROWS, 1) int32.  Returns (word, shift, bwu, words_per
    _block) where ``word`` indexes into the tile's own word segment (blocks
    are word-aligned, so the segment starts at word 0 of the tile).
    """
    bwf = bw_col[:, 0]
    words_per_block = bwf * WORDS_PER_BIT
    local_off = jnp.cumsum(words_per_block) - words_per_block  # exclusive
    j = jax.lax.broadcasted_iota(jnp.int32, (TILE_ROWS, BLOCK), 1)
    bitpos = local_off[:, None] * 32 + j * bwf[:, None]
    word = bitpos >> 5
    shift = (bitpos & 31).astype(jnp.uint32)
    bwu = jnp.broadcast_to(bwf[:, None], (TILE_ROWS, BLOCK)).astype(jnp.uint32)
    return word, shift, bwu, words_per_block


def _width_mask(bwu):
    return jnp.where(
        bwu == 0,
        jnp.uint32(0),
        jnp.uint32(0xFFFFFFFF) >> jnp.minimum(32 - bwu, jnp.uint32(31)),
    )


def _pack_tile(zig, bw, packed_ref, off_ref):
    """Pack one tile's zigzag codes into the resident packed-output window,
    advancing the SMEM word-offset carry.

    The word offset of the current tile is carried in SMEM scratch across
    the sequential grid; the packed output block has a constant index map,
    so it stays resident while every tile ORs its word-aligned segment in
    (disjoint bit ranges => OR == ADD, same argument as bitpack.pack).
    Overflow past the true capacity lands in the PACK_PAD_WORDS dump tail,
    which the wrapper slices off — never silent corruption of valid words.
    """
    word, shift, bwu, words_per_block = _tile_pack_geometry(bw)
    u = zig & _width_mask(bwu)
    lo = u << shift
    hi = jnp.where(shift == 0, jnp.uint32(0),
                   u >> jnp.minimum(32 - shift, jnp.uint32(31)))
    # Tile-local dense segment: scatter-add over <= TILE_WORDS words.  The
    # +1 slot absorbs the last element's always-zero straddle word.
    fw = word.reshape(-1)
    local = jnp.zeros((PACK_PAD_WORDS,), jnp.uint32)
    local = local.at[fw].add(lo.reshape(-1))
    local = local.at[fw + 1].add(hi.reshape(-1))

    start = off_ref[0]
    capacity = packed_ref.shape[0] - PACK_PAD_WORDS
    s = jnp.minimum(start, capacity)  # overflowing tiles write the dump tail
    window = packed_ref[pl.ds(s, PACK_PAD_WORDS)]
    packed_ref[pl.ds(s, PACK_PAD_WORDS)] = window | local
    off_ref[0] = start + jnp.sum(words_per_block)


def _quantize_pack_kernel(x_ref, recip_ref, packed_ref, bw_ref, anchor_ref,
                          off_ref):
    """quantize + zigzag + bitpack in one pass over the tile."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        packed_ref[...] = jnp.zeros_like(packed_ref[...])
        off_ref[0] = 0

    zig, bw, anchor = _quantize_tile(x_ref[...], recip_ref[0, 0])
    bw_ref[...] = bw
    anchor_ref[...] = anchor
    _pack_tile(zig, bw, packed_ref, off_ref)


def _unpack_tile(packed_ref, bw, off_ref):
    """Gather + unpack one tile's word-aligned segment from the resident
    packed window, advancing the SMEM word-offset carry.  Returns the
    tile's zigzag codes (TILE_ROWS, BLOCK) without materializing them in
    HBM."""
    word, shift, bwu, words_per_block = _tile_pack_geometry(bw)
    start = off_ref[0]
    capacity = packed_ref.shape[0] - PACK_PAD_WORDS
    s = jnp.minimum(start, capacity)
    window = packed_ref[pl.ds(s, PACK_PAD_WORDS)]
    lo = window[word] >> shift
    hi = jnp.where(shift == 0, jnp.uint32(0),
                   window[word + 1] << jnp.minimum(32 - shift, jnp.uint32(31)))
    off_ref[0] = start + jnp.sum(words_per_block)
    return (lo | hi) & _width_mask(bwu)


def _reconstruct(u, anchor_col, twoeb):
    d = (u >> 1).astype(jnp.int32) ^ (-(u & 1).astype(jnp.int32))
    q = anchor_col + jnp.cumsum(d, axis=1)
    return q.astype(jnp.float32) * twoeb


def _unpack_dequantize_reduce_kernel(packed_ref, bw_ref, anchor_ref, twoeb_ref,
                                     acc_ref, out_ref, off_ref):
    """Inverse fusion: packed words + acc -> acc + dequantize(unpack(words)).

    Same SMEM word-offset carry as the pack kernel; the tile gathers its
    word-aligned segment from a resident window, so the uint32 codes array
    never materializes in HBM on the receive side either.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        off_ref[0] = 0

    u = _unpack_tile(packed_ref, bw_ref[...], off_ref)
    out_ref[...] = acc_ref[...] + _reconstruct(u, anchor_ref[...],
                                               twoeb_ref[0, 0])


def _unpack_dequantize_kernel(packed_ref, bw_ref, anchor_ref, twoeb_ref,
                              out_ref, off_ref):
    """Pure fused decompress (no accumulator): the allgather/scatter receive
    path, which would otherwise pay a zero-accumulator materialization."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        off_ref[0] = 0

    u = _unpack_tile(packed_ref, bw_ref[...], off_ref)
    out_ref[...] = _reconstruct(u, anchor_ref[...], twoeb_ref[0, 0])


def _unpack_reduce_repack_kernel(emit_f32, packed_in_ref, bw_in_ref,
                                 anchor_in_ref, twoeb_ref, acc_ref, recip_ref,
                                 *refs):
    """The single-pass ring hop (DESIGN.md §3.1): per tile, gather the
    received packed segment from the resident input window, unpack +
    un-zigzag + prefix-sum + dequantize, add the local accumulator chunk,
    then immediately re-quantize, zigzag and pack the updated chunk into
    the resident outgoing wire window.  The f32 intermediate lives only in
    VMEM (unless ``emit_f32`` — the redoub carry needs it); the outgoing
    per-block bitwidths/anchors come out of the same pass.  Two SMEM
    word-offset carries: one walking the received stream, one walking the
    outgoing stream.
    """
    if emit_f32:
        (packed_out_ref, bw_out_ref, anchor_out_ref, x_out_ref,
         off_in_ref, off_out_ref) = refs
    else:
        (packed_out_ref, bw_out_ref, anchor_out_ref,
         off_in_ref, off_out_ref) = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        packed_out_ref[...] = jnp.zeros_like(packed_out_ref[...])
        off_in_ref[0] = 0
        off_out_ref[0] = 0

    u = _unpack_tile(packed_in_ref, bw_in_ref[...], off_in_ref)
    x = acc_ref[...] + _reconstruct(u, anchor_in_ref[...], twoeb_ref[0, 0])
    zig, bw, anchor = _quantize_tile(x, recip_ref[0, 0])
    bw_out_ref[...] = bw
    anchor_out_ref[...] = anchor
    if emit_f32:
        x_out_ref[...] = x
    _pack_tile(zig, bw, packed_out_ref, off_out_ref)


@functools.partial(
    jax.jit, static_argnames=("capacity_words", "emit_f32", "interpret")
)
def unpack_reduce_repack(
    packed: jnp.ndarray,
    bitwidth: jnp.ndarray,
    anchor: jnp.ndarray,
    eb_in: jnp.ndarray,
    acc: jnp.ndarray,
    eb_out: jnp.ndarray,
    capacity_words: int,
    *,
    emit_f32: bool = False,
    interpret: bool = True,
):
    """Fused unpack + dequantize + reduce + re-quantize + re-pack.

    One ``pallas_call`` per ring hop: consumes the received wire stream
    (``packed``/``bitwidth``/``anchor`` at ``eb_in``) plus the local f32
    chunk ``acc`` (n_blocks, BLOCK), and emits the *next hop's* wire stream
    at ``eb_out`` — byte-identical to
    ``quantize_pack(unpack_dequantize_reduce(...))`` without the f32
    intermediate ever leaving VMEM.  With ``emit_f32`` the updated f32
    chunk is also written out (the recursive-doubling carry).

    Returns (packed_out uint32[capacity_words], bw_out, anchor_out[,
    updated f32 (n_blocks, BLOCK)]).
    """
    n_blocks = acc.shape[0]
    twoeb = (2.0 * eb_in).reshape(1, 1).astype(jnp.float32)
    recip = (1.0 / (2.0 * eb_out)).reshape(1, 1).astype(jnp.float32)
    cap_in_pad = packed.shape[0] + PACK_PAD_WORDS
    packed_pad = jnp.zeros((cap_in_pad,), jnp.uint32).at[: packed.shape[0]].set(packed)
    cap_out_pad = capacity_words + PACK_PAD_WORDS
    out_specs = [
        pl.BlockSpec((cap_out_pad,), lambda i: (0,)),
        _row_spec(1),
        _row_spec(1),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((cap_out_pad,), jnp.uint32),
        jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
    ]
    if emit_f32:
        out_specs.append(_row_spec(BLOCK))
        out_shape.append(jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32))
    res = pl.pallas_call(
        functools.partial(_unpack_reduce_repack_kernel, emit_f32),
        grid=(n_blocks // TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((cap_in_pad,), lambda i: (0,)),
            _row_spec(1),
            _row_spec(1),
            _scalar_spec(),
            _row_spec(BLOCK),
            _scalar_spec(),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32), pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(packed_pad, bitwidth[:, None], anchor[:, None], twoeb, acc, recip)
    if emit_f32:
        packed_out, bw, anchor_out, x = res
        return packed_out[:capacity_words], bw[:, 0], anchor_out[:, 0], x
    packed_out, bw, anchor_out = res
    return packed_out[:capacity_words], bw[:, 0], anchor_out[:, 0]


@functools.partial(jax.jit, static_argnames=("capacity_words", "interpret"))
def quantize_pack(
    x2d: jnp.ndarray, eb: jnp.ndarray, capacity_words: int, *,
    interpret: bool = True,
):
    """f32 (n_blocks, BLOCK) -> (packed uint32[capacity_words], bw, anchor).

    Single pallas_call; byte stream identical to
    ``bitpack.pack(*quantize(x2d, eb))`` on the first capacity_words words.
    n_blocks must be a multiple of TILE_ROWS (ops.py pads).
    """
    n_blocks = x2d.shape[0]
    recip = (1.0 / (2.0 * eb)).reshape(1, 1).astype(jnp.float32)
    cap_pad = capacity_words + PACK_PAD_WORDS
    packed, bw, anchor = pl.pallas_call(
        _quantize_pack_kernel,
        grid=(n_blocks // TILE_ROWS,),
        in_specs=[_row_spec(BLOCK), _scalar_spec()],
        out_specs=[
            pl.BlockSpec((cap_pad,), lambda i: (0,)),
            _row_spec(1),
            _row_spec(1),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap_pad,), jnp.uint32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(x2d, recip)
    return packed[:capacity_words], bw[:, 0], anchor[:, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def unpack_dequantize(
    packed: jnp.ndarray,
    bitwidth: jnp.ndarray,
    anchor: jnp.ndarray,
    eb: jnp.ndarray,
    *,
    interpret: bool = True,
):
    """Fused unpack + dequantize: packed stream -> f32 (n_blocks, BLOCK)."""
    n_blocks = bitwidth.shape[0]
    twoeb = (2.0 * eb).reshape(1, 1).astype(jnp.float32)
    cap_pad = packed.shape[0] + PACK_PAD_WORDS
    packed_pad = jnp.zeros((cap_pad,), jnp.uint32).at[: packed.shape[0]].set(packed)
    return pl.pallas_call(
        _unpack_dequantize_kernel,
        grid=(n_blocks // TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((cap_pad,), lambda i: (0,)),
            _row_spec(1),
            _row_spec(1),
            _scalar_spec(),
        ],
        out_specs=_row_spec(BLOCK),
        out_shape=jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(packed_pad, bitwidth[:, None], anchor[:, None], twoeb)


@functools.partial(jax.jit, static_argnames=("interpret",))
def unpack_dequantize_reduce(
    packed: jnp.ndarray,
    bitwidth: jnp.ndarray,
    anchor: jnp.ndarray,
    eb: jnp.ndarray,
    acc: jnp.ndarray,
    *,
    interpret: bool = True,
):
    """Fused unpack + dequantize + reduce: acc + decompress(packed stream).

    ``packed``: uint32[capacity_words]; ``acc``: f32 (n_blocks, BLOCK).
    """
    n_blocks = acc.shape[0]
    twoeb = (2.0 * eb).reshape(1, 1).astype(jnp.float32)
    cap_pad = packed.shape[0] + PACK_PAD_WORDS
    packed_pad = jnp.zeros((cap_pad,), jnp.uint32).at[: packed.shape[0]].set(packed)
    return pl.pallas_call(
        _unpack_dequantize_reduce_kernel,
        grid=(n_blocks // TILE_ROWS,),
        in_specs=[
            pl.BlockSpec((cap_pad,), lambda i: (0,)),
            _row_spec(1),
            _row_spec(1),
            _scalar_spec(),
            _row_spec(BLOCK),
        ],
        out_specs=_row_spec(BLOCK),
        out_shape=jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(packed_pad, bitwidth[:, None], anchor[:, None], twoeb, acc)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_reduce(
    codes: jnp.ndarray,
    anchor: jnp.ndarray,
    eb: jnp.ndarray,
    acc: jnp.ndarray,
    *,
    interpret: bool = True,
):
    """Fused decompress-and-add: acc + dequantize(codes, anchor)."""
    n_blocks = codes.shape[0]
    twoeb = (2.0 * eb).reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        _dequantize_reduce_kernel,
        grid=(n_blocks // TILE_ROWS,),
        in_specs=[_row_spec(BLOCK), _row_spec(1), _scalar_spec(), _row_spec(BLOCK)],
        out_specs=_row_spec(BLOCK),
        out_shape=jax.ShapeDtypeStruct((n_blocks, BLOCK), jnp.float32),
        interpret=interpret,
    )(codes, anchor[:, None], twoeb, acc)
