"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are allclose-tested against
(``tests/test_kernels.py`` sweeps shapes/dtypes).  They are also what the
single-device simulator uses, so algorithm-level tests never depend on
Pallas at all.

Compression scheme (cuSZp [14] adapted to TPU, DESIGN.md §2):
  q      = rint(x / (2*eb))               # error-bounded pre-quantization
  anchor = q[0]                            # per-block absolute, 32-bit raw
  d[j]   = q[j] - q[j-1]  (d[0] := 0)      # 1D Lorenzo within each block
  code   = zigzag(d)                       # non-negative uint32
  bw_i   = bits(max(code in block i))      # per-block fixed width
Reconstruction is the exact inverse; the only loss is the initial
quantization, hence |x - x'| <= eb element-wise (integer Lorenzo+zigzag are
lossless, up to f32 rounding of q*2eb which is relative ~1e-7·|x|).

The *anchor* is the TPU twist on cuSZp: cuSZp's first-in-block element
predicts from 0, so one large absolute value inflates the whole block's
fixed width.  Storing the absolute quantized anchor out-of-band (4 B per
256-element block = 1.6% overhead) keeps the packed width equal to the
*delta* dynamic range, which is what actually compresses on smooth fields.
Blocks stay independent, which is what makes block-parallel TPU tiling
possible.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "quantize_ref",
    "dequantize_ref",
    "dequantize_reduce_ref",
    "quantize_pack_ref",
    "unpack_dequantize_reduce_ref",
    "unpack_reduce_repack_ref",
    "bitwidth_of",
]


def bitwidth_of(umax: jnp.ndarray) -> jnp.ndarray:
    """Exact integer ceil(log2(u+1)) via 32 comparisons (no float log)."""
    powers = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum(
        (umax[..., None] >= powers).astype(jnp.int32), axis=-1
    )


def quantize_ref(x2d: jnp.ndarray, eb: jnp.ndarray):
    """f32 (n_blocks, B) -> (codes uint32 (nb, B), bitwidth int32 (nb,), anchor int32 (nb,))."""
    recip = 1.0 / (2.0 * eb)
    q = jnp.rint(x2d.astype(jnp.float32) * recip).astype(jnp.int32)
    prev = jnp.concatenate([q[:, :1], q[:, :-1]], axis=1)
    d = q - prev  # d[:, 0] == 0 by construction
    zig = ((d << 1) ^ (d >> 31)).astype(jnp.uint32)
    bw = bitwidth_of(jnp.max(zig, axis=1))
    return zig, bw, q[:, 0]


def _unzigzag(u: jnp.ndarray) -> jnp.ndarray:
    return (u >> 1).astype(jnp.int32) ^ (-(u & 1).astype(jnp.int32))


def dequantize_ref(
    codes: jnp.ndarray, anchor: jnp.ndarray, eb: jnp.ndarray
) -> jnp.ndarray:
    """codes uint32 (nb, B) + anchor int32 (nb,) -> f32 (nb, B)."""
    d = _unzigzag(codes)
    q = anchor[:, None] + jnp.cumsum(d, axis=1)
    return q.astype(jnp.float32) * (2.0 * eb)


def dequantize_reduce_ref(
    codes: jnp.ndarray, anchor: jnp.ndarray, eb: jnp.ndarray, acc: jnp.ndarray
) -> jnp.ndarray:
    """Fused decompress + elementwise reduce (paper's on-device reduction)."""
    return acc + dequantize_ref(codes, anchor, eb)


def quantize_pack_ref(x2d: jnp.ndarray, eb: jnp.ndarray, capacity_words: int):
    """Oracle for the fused quantize_pack kernel: the unfused composition.

    -> (packed uint32 (capacity_words,), bw (nb,), anchor (nb,)); the fused
    kernel must reproduce this byte stream exactly.
    """
    from repro.core import bitpack

    codes, bw, anchor = quantize_ref(x2d, eb)
    packed, _ = bitpack.pack(codes, bw, capacity_words)
    return packed, bw, anchor


def unpack_dequantize_reduce_ref(
    packed: jnp.ndarray,
    bitwidth: jnp.ndarray,
    anchor: jnp.ndarray,
    eb: jnp.ndarray,
    acc: jnp.ndarray,
) -> jnp.ndarray:
    """Oracle for the fused receive-side kernel: unpack then dequant+reduce."""
    from repro.core import bitpack

    codes = bitpack.unpack(packed, bitwidth, acc.shape[1])
    return dequantize_reduce_ref(codes, anchor, eb, acc)


def unpack_reduce_repack_ref(
    packed: jnp.ndarray,
    bitwidth: jnp.ndarray,
    anchor: jnp.ndarray,
    eb_in: jnp.ndarray,
    acc: jnp.ndarray,
    eb_out: jnp.ndarray,
    capacity_words: int,
):
    """Oracle for the fused single-pass ring hop: the unfused composition
    decompress_reduce ∘ compress.  -> (packed_out, bw_out, anchor_out,
    updated f32); the fused kernel must reproduce the byte stream exactly.
    """
    x = unpack_dequantize_reduce_ref(packed, bitwidth, anchor, eb_in, acc)
    packed_out, bw_out, anchor_out = quantize_pack_ref(x, eb_out, capacity_words)
    return packed_out, bw_out, anchor_out, x


def attention_ref(q, k, v, *, causal=True, window=0):
    """Dense softmax-attention oracle for the flash kernel.

    q: (B, Sq, H, D); k/v: (B, Sk, H, D).  f32 math throughout.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / (d ** 0.5)
    if causal:
        qp = jnp.arange(sq)[:, None]
        kp = jnp.arange(sk)[None, :]
        mask = kp <= qp
        if window:
            mask &= kp > (qp - window)
        s = jnp.where(mask[None, None], s, -1e30)
    import jax

    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhqk,bkhd->bqhd", p, v.astype(jnp.float32)
    ).astype(q.dtype)
