"""Jit'd public wrappers around the Pallas kernels.

Handles flattening, block padding, backend selection (interpret=True off
TPU so the kernel *body* is what gets validated on CPU), and exposes the
flat-array API the compressor layer consumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import entropy as entropy_kernels
from repro.kernels import lorenzo

BLOCK = lorenzo.BLOCK
TILE_ROWS = lorenzo.TILE_ROWS


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def n_blocks_for(n: int) -> int:
    """Number of Lorenzo blocks (padded to the kernel's row-tile multiple)."""
    nb = -(-n // BLOCK)
    return -(-nb // TILE_ROWS) * TILE_ROWS


def to_blocks(x: jnp.ndarray) -> jnp.ndarray:
    """Flatten + zero-pad an arbitrary f32 array to (n_blocks, BLOCK)."""
    flat = x.reshape(-1).astype(jnp.float32)
    nb = n_blocks_for(flat.shape[0])
    padded = jnp.zeros((nb * BLOCK,), jnp.float32).at[: flat.shape[0]].set(flat)
    return padded.reshape(nb, BLOCK)


def from_blocks(x2d: jnp.ndarray, n: int) -> jnp.ndarray:
    return x2d.reshape(-1)[:n]


def quantize(x2d: jnp.ndarray, eb):
    """-> (codes uint32 (nb, B), bitwidth int32 (nb,), anchor int32 (nb,))."""
    eb = jnp.asarray(eb, jnp.float32)
    return lorenzo.quantize(x2d, eb, interpret=_interpret())


def dequantize(codes: jnp.ndarray, anchor: jnp.ndarray, eb) -> jnp.ndarray:
    eb = jnp.asarray(eb, jnp.float32)
    return lorenzo.dequantize(codes, anchor, eb, interpret=_interpret())


def dequantize_reduce(
    codes: jnp.ndarray, anchor: jnp.ndarray, eb, acc: jnp.ndarray
) -> jnp.ndarray:
    eb = jnp.asarray(eb, jnp.float32)
    return lorenzo.dequantize_reduce(codes, anchor, eb, acc, interpret=_interpret())


def quantize_pack(x2d: jnp.ndarray, eb, capacity_words: int):
    """Fused f32 -> packed wire words (single pallas_call, no codes array).

    -> (packed uint32 (capacity_words,), bw int32 (nb,), anchor int32 (nb,)).
    Byte-identical to ``bitpack.pack(*quantize(x2d, eb)[:2], capacity)``.
    """
    eb = jnp.asarray(eb, jnp.float32)
    return lorenzo.quantize_pack(
        x2d, eb, int(capacity_words), interpret=_interpret()
    )


def unpack_dequantize(
    packed: jnp.ndarray, bitwidth: jnp.ndarray, anchor: jnp.ndarray, eb
) -> jnp.ndarray:
    """Fused packed words -> decompressed f32 (nb, BLOCK), no accumulator."""
    eb = jnp.asarray(eb, jnp.float32)
    return lorenzo.unpack_dequantize(
        packed, bitwidth, anchor, eb, interpret=_interpret()
    )


def unpack_dequantize_reduce(
    packed: jnp.ndarray, bitwidth: jnp.ndarray, anchor: jnp.ndarray, eb,
    acc2d: jnp.ndarray,
) -> jnp.ndarray:
    """Fused packed words + acc -> acc + decompressed f32 (nb, BLOCK)."""
    eb = jnp.asarray(eb, jnp.float32)
    return lorenzo.unpack_dequantize_reduce(
        packed, bitwidth, anchor, eb, acc2d, interpret=_interpret()
    )


def entropy_quantize_pack(
    x2d: jnp.ndarray, eb, capacity_words: int, *, lossless: bool = False
):
    """Fused f32 -> entropy-coded wire words (DESIGN.md §10).

    -> (packed uint32 (capacity_words,), desc int32 (nb,), anchor int32
    (nb,)) where ``desc`` packs the four per-sub-block widths.  Byte-
    identical to ``core.entropy.pack(core.entropy.encode_blocks(...))``.
    """
    eb = jnp.asarray(eb, jnp.float32)
    return entropy_kernels.quantize_pack(
        x2d, eb, int(capacity_words), lossless=lossless, interpret=_interpret()
    )


def entropy_unpack_dequantize(
    packed: jnp.ndarray, desc: jnp.ndarray, anchor: jnp.ndarray, eb, *,
    lossless: bool = False,
) -> jnp.ndarray:
    """Fused entropy wire words -> decompressed f32 (nb, BLOCK)."""
    eb = jnp.asarray(eb, jnp.float32)
    return entropy_kernels.unpack_dequantize(
        packed, desc, anchor, eb, lossless=lossless, interpret=_interpret()
    )


def entropy_unpack_dequantize_reduce(
    packed: jnp.ndarray, desc: jnp.ndarray, anchor: jnp.ndarray, eb,
    acc2d: jnp.ndarray, *, lossless: bool = False,
) -> jnp.ndarray:
    """Fused entropy wire words + acc -> acc + decompressed f32 (nb, BLOCK)."""
    eb = jnp.asarray(eb, jnp.float32)
    return entropy_kernels.unpack_dequantize_reduce(
        packed, desc, anchor, eb, acc2d, lossless=lossless,
        interpret=_interpret(),
    )


def unpack_reduce_repack(
    packed: jnp.ndarray, bitwidth: jnp.ndarray, anchor: jnp.ndarray, eb_in,
    acc2d: jnp.ndarray, eb_out, capacity_words: int, *, emit_f32: bool = False,
):
    """Single-pass ring hop: received wire stream + local f32 chunk -> the
    next hop's wire stream (packed_out, bw_out, anchor_out[, updated f32]).

    Byte-identical to ``quantize_pack(unpack_dequantize_reduce(...))``; the
    f32 intermediate stays in VMEM unless ``emit_f32``.
    """
    eb_in = jnp.asarray(eb_in, jnp.float32)
    eb_out = jnp.asarray(eb_out, jnp.float32)
    return lorenzo.unpack_reduce_repack(
        packed, bitwidth, anchor, eb_in, acc2d, eb_out, int(capacity_words),
        emit_f32=emit_f32, interpret=_interpret(),
    )
