"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes; capture memory/cost analysis + collective bytes and the
scan-corrected §Roofline terms.

MUST run as its own process (the os.environ line below executes before any
jax initialization — smoke tests and benches must still see 1 device):

    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-20b \
        --shape train_4k [--multi-pod] [--grad-gz redoub] [--fsdp-gz] \
        [--remat full|none] [--out results/dryrun]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.collectives import GZConfig
from repro.launch import costing, hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import INPUT_SHAPES, decode_specs, train_specs
from repro.launch.training import make_serve_step, make_setup, make_train_step
from repro.models.parallel import param_shapes


def _opt_shapes(pshapes):
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, pshapes),
        "nu": jax.tree.map(f32, pshapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lower_cost(cfg, shape, mesh, *, grad_gz=None, fsdp_gz=None, remat="full",
               unroll: int = 1, want_mem: bool = False, fsdp: bool = True,
               cache_dtype="float32", policy: str = "auto") -> dict:
    """Lower+compile one configuration; return raw cost terms."""
    setup = make_setup(cfg, mesh, grad_gz=grad_gz, fsdp_gz=fsdp_gz, remat=remat,
                       fsdp=fsdp, grad_policy=policy)
    if unroll != 1:
        setup = dataclasses.replace(
            setup, ctx=dataclasses.replace(setup.ctx, scan_unroll=unroll)
        )
        setup = dataclasses.replace(
            setup, model=type(setup.model)(cfg, setup.ctx)
        )
    pshapes = param_shapes(setup.defs)
    t0 = time.time()
    if shape.kind == "train":
        batch, bspecs = train_specs(cfg, shape, mesh)
        step = make_train_step(setup, bspecs)
        lowered = step.lower(pshapes, _opt_shapes(pshapes), batch)
    else:
        cache, cspecs, tokens, tspec, plan = decode_specs(
            cfg, shape, mesh, setup.model, cache_dtype=jnp.dtype(cache_dtype))
        step = make_serve_step(setup, cspecs, tspec, plan)
        pos = jax.ShapeDtypeStruct((1,), jnp.int32)
        lowered = step.lower(pshapes, cache, tokens, pos)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    cost = compiled.cost_analysis() or {}
    coll = hlo_stats.collective_bytes(compiled.as_text())
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "hbm": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll.get("total", 0)),
        "coll_by_kind": {k: v for k, v in coll.items() if k != "_counts"},
        "coll_counts": coll.get("_counts", {}),
        "t_lower": t_lower,
        "t_compile": t_compile,
    }
    if want_mem:
        out["mem"] = _mem_dict(compiled.memory_analysis())
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            grad_gz: str | None = None, fsdp_gz: bool = False,
            remat: str = "full", eb: float = 1e-4,
            capacity_factor: float = 0.6, skip_correction: bool = False,
            fsdp: bool = True, mla_dense: bool = False,
            cache_dtype: str = "float32", parallel_block: bool = False,
            loss_chunk: int = 0, moe_gz_eb: float = 0.0,
            policy: str = "auto") -> dict:
    cfg = registry.get(arch)
    if mla_dense:
        cfg = dataclasses.replace(cfg, mla_chunk=0)
    if parallel_block:
        cfg = dataclasses.replace(cfg, parallel_block=True)
    if loss_chunk:
        cfg = dataclasses.replace(cfg, loss_chunk=loss_chunk)
    if moe_gz_eb:
        cfg = dataclasses.replace(cfg, moe_dispatch_gz_eb=moe_gz_eb)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))

    gz = GZConfig(eb=eb, algo=grad_gz, capacity_factor=capacity_factor) \
        if grad_gz else None
    fgz = GZConfig(eb=eb, algo="ring", capacity_factor=capacity_factor) \
        if fsdp_gz else None
    kw = dict(grad_gz=gz, fsdp_gz=fgz, remat=remat, fsdp=fsdp,
              cache_dtype=cache_dtype, policy=policy)

    main = lower_cost(cfg, shape, mesh, want_mem=True, **kw)

    if skip_correction:
        corrected = {k: main[k] for k in ("flops", "hbm", "coll")}
        extra = {"detail": "skipped"}
    else:
        extra = costing.corrections(
            cfg, lambda c, u: lower_cost(c, shape, mesh, unroll=u, **kw)
        )
        corrected = costing.apply_corrections(main, extra)

    roof = hlo_stats.roofline_terms(
        corrected["flops"], corrected["hbm"], corrected["coll"], 1
    )

    n = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens_total = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens_total / chips
    else:
        model_flops = 2 * n_active * shape.global_batch / chips

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "grad_gz": grad_gz,
        "fsdp_gz": fsdp_gz,
        "fsdp": fsdp,
        "mla_dense": mla_dense,
        "cache_dtype": cache_dtype,
        "parallel_block": parallel_block,
        "loss_chunk": loss_chunk,
        "remat": remat,
        "lower_s": round(main["t_lower"], 2),
        "compile_s": round(main["t_compile"], 2),
        "reported": {k: main[k] for k in ("flops", "hbm", "coll")},
        "scan_correction": {
            k: v for k, v in extra.items() if k != "detail"
        },
        "corrected": corrected,
        "collective_by_kind_once": main["coll_by_kind"],
        "collective_counts_once": main["coll_counts"],
        "memory_analysis": main.get("mem", {}),
        "roofline": roof,
        "params": n,
        "active_params": n_active,
        "model_flops_per_device": model_flops,
        "useful_flops_frac": (
            model_flops / corrected["flops"] if corrected["flops"] else None
        ),
    }


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.arch_ids())
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--grad-gz", default=None,
                    choices=["auto", "redoub", "ring", "intring"])
    ap.add_argument("--policy", default="auto",
                    choices=["auto", "paper", "throughput", "accuracy"],
                    help="communicator plan policy (core/comm.py) used "
                         "when --grad-gz auto leaves the algorithm open")
    ap.add_argument("--fsdp-gz", action="store_true")
    ap.add_argument("--remat", default="full", choices=["full", "none"])
    ap.add_argument("--eb", type=float, default=1e-4)
    ap.add_argument("--capacity-factor", type=float, default=0.6)
    ap.add_argument("--skip-correction", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate params over data (weights-resident serving)")
    ap.add_argument("--mla-dense", action="store_true",
                    help="dense (unchunked) MLA attention — §Perf H2 baseline")
    ap.add_argument("--cache-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--parallel-block", action="store_true",
                    help="PaLM-style parallel attn+MLP: one TP psum/layer")
    ap.add_argument("--loss-chunk", type=int, default=0,
                    help="sequence-chunked vocab loss (0 = one-shot)")
    ap.add_argument("--moe-gz-eb", type=float, default=0.0,
                    help="compress the MoE dispatch all_to_all at this eb")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    res = run_one(
        args.arch, args.shape, multi_pod=args.multi_pod,
        grad_gz=args.grad_gz, fsdp_gz=args.fsdp_gz, remat=args.remat,
        eb=args.eb, capacity_factor=args.capacity_factor,
        skip_correction=args.skip_correction, fsdp=not args.no_fsdp,
        mla_dense=args.mla_dense, cache_dtype=args.cache_dtype,
        parallel_block=args.parallel_block, loss_chunk=args.loss_chunk,
        moe_gz_eb=args.moe_gz_eb, policy=args.policy,
    )
    os.makedirs(args.out, exist_ok=True)
    mesh_tag = "multi" if args.multi_pod else "single"
    gz_tag = f"_gz-{args.grad_gz}" if args.grad_gz else ""
    fz_tag = "_fsdpgz" if args.fsdp_gz else ""
    tag = f"_{args.tag}" if args.tag else ""
    path = os.path.join(
        args.out,
        f"{args.arch}_{args.shape}_{mesh_tag}{gz_tag}{fz_tag}{tag}.json",
    )
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    print(json.dumps({k: res[k] for k in
                      ("arch", "shape", "mesh", "compile_s", "corrected",
                       "roofline", "useful_flops_frac")}, indent=1))
    print(f"\nwritten: {path}")


if __name__ == "__main__":
    main()
