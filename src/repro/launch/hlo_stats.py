"""Extract roofline terms from lowered/compiled XLA artifacts.

``collective_bytes`` parses the optimized HLO text and sums the result
shapes of every collective op (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, including their async -start forms).
Result-shape accounting is recorded in EXPERIMENTS.md §Roofline: for
all-reduce it equals the payload, for all-gather the received bytes, for
reduce-scatter the post-reduce shard — a consistent, reproducible proxy
for wire traffic per device.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "roofline_terms", "HW_V5E"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:%|\S+ = )?(?P<shapes>.*?)\s"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind result bytes summed over the module (per device)."""
    out: dict = defaultdict(int)
    counts: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        if "-done(" in line:
            continue  # -done carries the same payload as -start
        total = 0
        for dtype, dims in _SHAPE_RE.findall(m.group("shapes")):
            if dtype in _DTYPE_BYTES:
                total += _shape_bytes(dtype, dims)
        out[op] += total
        counts[op] += 1
    out = dict(out)
    out["_counts"] = dict(counts)
    out["total"] = sum(v for k, v in out.items() if not k.startswith("_") and k != "total")
    return out


# TPU v5e constants (per chip) — from the assignment brief.
HW_V5E = {
    "peak_flops": 197e12,   # bf16
    "hbm_bw": 819e9,        # bytes/s
    "ici_bw": 50e9,         # bytes/s/link
}


def roofline_terms(
    flops: float, hbm_bytes: float, coll_bytes: float, chips: int, hw=HW_V5E
) -> dict:
    """The three §Roofline terms, in seconds.

    ``flops``/``hbm_bytes`` are totals for the module across all chips
    (XLA cost_analysis of the SPMD module is per-device — callers pass
    per-device values with chips=1, or totals with the real chip count;
    we use per-device values with chips=1 everywhere for consistency).
    """
    compute = flops / (chips * hw["peak_flops"])
    memory = hbm_bytes / (chips * hw["hbm_bw"])
    collective = coll_bytes / (chips * hw["ici_bw"])
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
    }
