"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets --xla_force_host_platform_device_count=512 before
any jax initialization; smoke tests see 1 device).
"""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_hier_mesh",
    "mesh_axis_sizes",
    "dp_axes_of",
]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_hier_mesh(
    n_nodes: int | None = None,
    gpus_per_node: int | None = None,
    *,
    axis_names: tuple = ("node", "local"),
    devices=None,
):
    """Carve the device list into a two-level ``node × local`` mesh.

    Devices are laid out node-major (``devices.reshape(n_nodes, L)``), so
    consecutive devices share a node — matching how multi-host runtimes
    enumerate local devices first, and making the ``local`` axis the
    fast NVLink/ICI hop and ``node`` the slow fabric hop.  Both extents
    are arbitrary (the remainder/trimmed-slab machinery handles non-pow2
    sizes per axis); missing extents are inferred from the device count.
    """
    if devices is None:
        devices = jax.devices()
    devices = list(devices)
    total = len(devices)
    if n_nodes is None and gpus_per_node is None:
        raise ValueError("give n_nodes and/or gpus_per_node")
    if n_nodes is None:
        n_nodes = total // gpus_per_node
    if gpus_per_node is None:
        gpus_per_node = total // n_nodes
    if n_nodes * gpus_per_node != total:
        raise ValueError(
            f"{n_nodes} nodes x {gpus_per_node} gpus != {total} devices"
        )
    import numpy as np

    grid = np.asarray(devices).reshape(n_nodes, gpus_per_node)
    return jax.sharding.Mesh(grid, axis_names)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes_of(mesh) -> tuple:
    return tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))
