"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets --xla_force_host_platform_device_count=512 before
any jax initialization; smoke tests see 1 device).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_axis_sizes", "dp_axes_of"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes_of(mesh) -> tuple:
    return tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))
