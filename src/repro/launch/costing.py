"""Differential scan-body costing.

XLA's ``cost_analysis()`` (and the HLO text) count a ``while`` body ONCE
regardless of trip count (verified empirically: a lax.scan over 4 and over
8 matmul layers reports identical flops).  Roofline terms for an L-layer
model are therefore corrected differentially:

  * lower the FULL config (scan as while; body counted once per scan), and
  * lower tiny 1- and 2-layer variants of the same config with the scans
    fully UNROLLED (ctx.scan_unroll high -> no while in the program);
    body_cost = cost(2 layers) - cost(1 layer), exactly — including the
    real fwd+bwd structure, remat recompute, FSDP gathers and TP
    collectives of a production layer;
  * corrected = reported_full + (executed_bodies - counted_bodies) * body.

Variant configs per family:
  dense/moe/mla/vlm/audio/ssm:  n_layers in {1, 2}
  hybrid:                        a pure-SSM variant (the scanned body IS the
                                 ssm block; shared attn blocks are python-
                                 unrolled and already counted in full)
  encdec:                        vary dec and enc depths independently
"""
from __future__ import annotations

import dataclasses

__all__ = ["corrections", "apply_corrections"]


def _variants(cfg):
    """[(key, cfg_1layer, cfg_2layer, executed, counted)] per scan family."""
    r = dataclasses.replace
    if cfg.family == "hybrid":
        k = cfg.attn_every
        n_groups = cfg.n_layers // k
        rem = cfg.n_layers - n_groups * k
        executed = cfg.n_layers
        counted = n_groups + (1 if rem else 0)
        ssm1 = r(cfg, family="ssm", attn_every=0, shared_attn=False,
                 n_layers=1, n_heads=0, n_kv_heads=0, d_ff=0)
        ssm2 = r(ssm1, n_layers=2)
        return [("main", ssm1, ssm2, executed, counted)]
    if cfg.family == "encdec":
        base = r(cfg, n_layers=1, n_enc_layers=1)
        dec2 = r(cfg, n_layers=2, n_enc_layers=1)
        enc2 = r(cfg, n_layers=1, n_enc_layers=2)
        return [
            ("dec", base, dec2, cfg.n_layers, 1),
            ("enc", base, enc2, cfg.n_enc_layers, 1),
        ]
    return [("main", r(cfg, n_layers=1), r(cfg, n_layers=2), cfg.n_layers, 1)]


def corrections(cfg, lower_fn) -> dict:
    """``lower_fn(cfg, unroll)`` -> {"flops","hbm","coll"} raw costs.

    Returns {"flops": extra, "hbm": extra, "coll": extra, "detail": ...}.
    """
    extra = {"flops": 0.0, "hbm": 0.0, "coll": 0.0}
    detail = {}
    for key, c1, c2, executed, counted in _variants(cfg):
        a = lower_fn(c1, 64)
        b = lower_fn(c2, 64)
        body = {k: max(b[k] - a[k], 0.0) for k in extra}
        mult = executed - counted
        for k in extra:
            extra[k] += mult * body[k]
        detail[key] = {"body": body, "executed": executed, "counted": counted}
    extra["detail"] = detail
    return extra


def apply_corrections(reported: dict, extra: dict) -> dict:
    return {k: reported[k] + extra[k] for k in ("flops", "hbm", "coll")}
