"""train_step / serve_step builders — where gZCCL meets the training loop.

The returned step functions are jax.jit-able with explicit in/out
shardings (the dry-run lowers exactly these).  Everything inside is one
shard_map body over the production mesh:

  * forward/backward with FSDP param gathers (optionally gZ-compressed
    allgather; its custom_vjp makes the gradient reduce-scatter compressed
    too — the [29] pattern with gZ error control),
  * the grad-sync rule validated in tests/_mp_model_parallel_child.py:
    psum every grad leaf over each mesh axis ABSENT from its spec; the
    differentiated loss is pre-scaled by 1/(tp * n_dp) to cancel
    shard_map's sum-over-ranks semantics,
  * cross-pod / small-leaf gradient reduction through per-axis
    ``GZCommunicator``s (the paper's headline collective behind the
    plan-then-execute surface of core/comm.py) when a GZConfig is set,
  * AdamW with sharded f32 moments.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.collectives import GZConfig
from repro.core.comm import GZCommunicator
from repro.core.grad_sync import SyncConfig
from repro.models.attention import KVCacheSpec
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.models.parallel import ParallelCtx, param_specs, param_shapes
from repro.core.shmap import shard_map
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainSetup", "make_setup", "make_train_step", "make_serve_step"]


@dataclasses.dataclass(frozen=True)
class TrainSetup:
    cfg: ModelConfig
    ctx: ParallelCtx
    model: Model
    mesh: object
    defs: dict
    specs: dict
    opt: AdamWConfig
    grad_gz: Optional[GZConfig]  # gz knobs for the dp-axis grad allreduce
    # resolve-once communicators, one per data-parallel axis, bound to the
    # mesh axis sizes at setup time (plan resolution is a cache hit inside
    # the traced step body) — empty when gradient sync is plain psum
    grad_comms: tuple = ()
    # GradScaler-style degraded-step skip: when True, a train step whose
    # gradient sync reports overflow or non-finite input keeps the OLD
    # params/opt state (jnp.where merge, donation-safe) and flags it in
    # metrics["skipped"] instead of applying a corrupted update.  Mostly
    # useful with on_overflow="flag"; with "fallback" the values are
    # already exact and steps are never skipped for overflow alone.
    skip_on_overflow: bool = False

    def opt_specs(self):
        return {
            "mu": self.specs,
            "nu": self.specs,
            "step": P(),
        }

    def named(self, spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )


def _strip_axis(spec: P, ax: str) -> P:
    def strip(entry):
        if entry == ax:
            return None
        if isinstance(entry, tuple):
            kept = tuple(e for e in entry if e != ax)
            return kept if kept else None
        return entry

    return P(*(strip(e) for e in tuple(spec)))


def make_setup(
    cfg: ModelConfig,
    mesh,
    *,
    opt: AdamWConfig = AdamWConfig(),
    fsdp_gz: Optional[GZConfig] = None,
    grad_gz: Optional[GZConfig] = None,
    grad_policy: str = "auto",
    remat: str = "full",
    fsdp: bool = True,
    skip_on_overflow: bool = False,
) -> TrainSetup:
    """``fsdp=False`` replicates parameters over the data axis (no per-layer
    gathers) — the weights-resident serving mode (§Perf hillclimb 1).

    ``grad_policy`` names the communicator plan policy ("auto" | "paper" |
    "throughput" | "accuracy" — core/comm.py) used when ``grad_gz`` leaves
    the algorithm choice open.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))
    grad_comms = ()
    if grad_gz is not None:
        grad_comms = tuple(
            (ax, GZCommunicator.for_config(
                ax, grad_gz, policy=grad_policy, axis_size=sizes.get(ax, 1)))
            for ax in dp_axes
        )
    fsdp_sync = SyncConfig(gz=fsdp_gz, relative_eb=False) if fsdp_gz else None
    ctx = ParallelCtx(
        tp_axis="model",
        fsdp_axis="data",
        dp_axes=dp_axes,
        tp_size=sizes.get("model", 1),
        fsdp_size=sizes.get("data", 1) if fsdp else 1,
        fsdp_sync=fsdp_sync,
        remat=remat,
    )
    model = Model(cfg, ctx)
    defs = model.param_defs()
    if not fsdp:
        defs = jax.tree.map(
            lambda d: dataclasses.replace(d, spec=_strip_axis(d.spec, "data")),
            defs,
            is_leaf=lambda x: hasattr(x, "spec") and hasattr(x, "init"),
        )
    return TrainSetup(
        cfg=cfg, ctx=ctx, model=model, mesh=mesh, defs=defs,
        specs=param_specs(defs), opt=opt, grad_gz=grad_gz,
        grad_comms=grad_comms, skip_on_overflow=skip_on_overflow,
    )


def _axes_in_spec(spec: P) -> set:
    return set(jax.tree.leaves(tuple(spec)))


def _sync_grads(grads, specs, mesh_axes, grad_comms: dict):
    """psum each leaf over every mesh axis absent from its spec.

    Reductions over dp axes with a bound communicator go through the
    compressed ``comm.allreduce`` (plan pre-resolved at setup time); the
    tiny "model"-axis cases stay psum.  Returns ``(grads, degraded)``
    where ``degraded`` ORs every leaf's overflow/nonfinite health bit
    (False scalar when every reduction is plain psum).
    """
    # A mutable cell: jax.tree.map's per-leaf callback can't return two
    # things without restructuring every caller, so the health bit
    # accumulates on the side (trace-safe — it's just op building).
    flag = [jnp.zeros((), jnp.bool_)]

    def sync(g, s):
        present = _axes_in_spec(s)
        for ax in mesh_axes:
            if ax in present:
                continue
            comm = grad_comms.get(ax)
            if comm is not None:
                res = comm.allreduce(g)
                g = res.value
                flag[0] = flag[0] | res.overflow | res.nonfinite
            else:
                g = lax.psum(g, ax)
        return g

    out = jax.tree.map(sync, grads, specs)
    return out, flag[0]


def _skip_merge(degraded, new_tree, old_tree):
    """Keep ``old_tree`` wherever this step degraded (replicated bool
    scalar predicate), else take ``new_tree`` — the GradScaler-style skip.
    Elementwise ``jnp.where`` (not lax.cond) so both sides stay donatable
    and the merge vectorizes into the update itself."""
    return jax.tree.map(
        lambda new, old: jnp.where(degraded, old, new), new_tree, old_tree
    )


def _global_grad_norm(grads, specs, sizes) -> jnp.ndarray:
    """Exact global norm of the synced (logical) gradient: local sum of
    squares per leaf / replication factor, psum'd over the whole mesh."""
    total = jnp.float32(0.0)
    mesh_axes = list(sizes)
    for g, s in zip(jax.tree.leaves(grads), jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))):
        present = _axes_in_spec(s)
        rep = 1
        for ax in mesh_axes:
            if ax not in present:
                rep *= sizes[ax]
        total = total + jnp.sum(jnp.square(g.astype(jnp.float32))) / rep
    for ax in mesh_axes:
        total = lax.psum(total, ax)
    return jnp.sqrt(total)


def make_train_step(setup: TrainSetup, batch_specs):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    cfg, ctx, model = setup.cfg, setup.ctx, setup.model
    sizes = dict(zip(setup.mesh.axis_names, setup.mesh.devices.shape))
    mesh_axes = tuple(setup.mesh.axis_names)
    n_dp = 1
    for ax in ctx.dp_axes:
        n_dp *= sizes[ax]
    scale = 1.0 / (ctx.tp_size * n_dp)
    specs = setup.specs

    def body(params, opt_state, batch):
        def scaled_loss(p):
            return model.loss_fn(p, batch) * scale

        loss, grads = jax.value_and_grad(scaled_loss)(params)
        loss = loss / scale
        for ax in ctx.dp_axes:
            loss = lax.pmean(loss, ax)
        grads, degraded = _sync_grads(
            grads, specs, mesh_axes, dict(setup.grad_comms)
        )
        # Each health bit is replicated over its OWN dp axis only; make
        # the skip predicate globally consistent before it gates state.
        degraded = lax.psum(degraded.astype(jnp.int32), mesh_axes) > 0
        gnorm = _global_grad_norm(grads, specs, sizes)
        new_params, new_opt, om = adamw_update(
            params, grads, opt_state, setup.opt, grad_norm=gnorm
        )
        skipped = jnp.zeros((), jnp.bool_)
        if setup.skip_on_overflow:
            new_params = _skip_merge(degraded, new_params, params)
            new_opt = _skip_merge(degraded, new_opt, opt_state)
            skipped = degraded
        metrics = {
            "loss": loss, "gnorm": om["gnorm"], "lr": om["lr"],
            "skipped": skipped,
        }
        return new_params, new_opt, metrics

    ospecs = setup.opt_specs()
    mspecs = {"loss": P(), "gnorm": P(), "lr": P(), "skipped": P()}
    step = shard_map(
        body,
        mesh=setup.mesh,
        in_specs=(specs, ospecs, batch_specs),
        out_specs=(specs, ospecs, mspecs),
    )
    return jax.jit(
        step,
        in_shardings=(setup.named(specs), setup.named(ospecs),
                      setup.named(batch_specs)),
        out_shardings=(setup.named(specs), setup.named(ospecs),
                       setup.named(mspecs)),
        donate_argnums=(0, 1),
    )


def make_serve_step(setup: TrainSetup, cache_specs, tokens_spec, plan: KVCacheSpec):
    """Returns step(params, cache, tokens, pos) -> (logits, new_cache)."""
    model = setup.model
    specs = setup.specs
    v = setup.cfg.padded_vocab()

    def body(params, cache, tokens, pos):
        logits, new_cache = model.decode_fn(params, cache, tokens, pos[0], plan)
        return logits, new_cache

    logits_spec = P(*(tuple(tokens_spec)[:1] + (None, None)))
    step = shard_map(
        body,
        mesh=setup.mesh,
        in_specs=(specs, cache_specs, tokens_spec, P(None)),
        out_specs=(logits_spec, cache_specs),
    )
    return jax.jit(
        step,
        in_shardings=(
            setup.named(specs),
            setup.named(cache_specs),
            NamedSharding(setup.mesh, tokens_spec),
            NamedSharding(setup.mesh, P(None)),
        ),
        donate_argnums=(1,),
    )
