"""train_step / serve_step builders — where gZCCL meets the training loop.

The returned step functions are jax.jit-able with explicit in/out
shardings (the dry-run lowers exactly these).  Everything inside is one
shard_map body over the production mesh:

  * forward/backward with FSDP param gathers (optionally gZ-compressed
    allgather; its custom_vjp makes the gradient reduce-scatter compressed
    too — the [29] pattern with gZ error control),
  * the grad-sync rule validated in tests/_mp_model_parallel_child.py:
    psum every grad leaf over each mesh axis ABSENT from its spec; the
    differentiated loss is pre-scaled by 1/(tp * n_dp) to cancel
    shard_map's sum-over-ranks semantics,
  * cross-pod / small-leaf gradient reduction through per-axis
    ``GZCommunicator``s (the paper's headline collective behind the
    plan-then-execute surface of core/comm.py) when a GZConfig is set,
  * AdamW with sharded f32 moments.

Backward-overlapped bucketed sync (ISSUE 9, ``overlap_sync=True``):
instead of one post-hoc ``_sync_grads`` pass after backward completes,
parameter leaves are grouped by sync signature (which mesh axes their
gradient must reduce over), packed last-layer-first into size-targeted
buckets, and each bucket is wrapped in an identity ``custom_vjp`` hook
whose BACKWARD performs that bucket's reduction.  The hook boundary is
where XLA's scheduler sees the collective become ready — as soon as the
bucket's cotangents exist, while the rest of backward is still running —
so comm overlaps compute.  Health flags ride the cotangent of a chained
scalar token (the only dataflow out of a custom_vjp backward is a
cotangent), and ``metrics["overlap_modeled"]`` reports the cost model's
``BucketPlan.overlap_efficiency`` for the configured bucket size.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import cost_model
from repro.core.collectives import GZConfig
from repro.core.comm import GZCommunicator
from repro.core.grad_sync import SyncConfig
from repro.models.attention import KVCacheSpec
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.models.parallel import ParallelCtx, param_specs, param_shapes
from repro.core.shmap import shard_map
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainSetup", "make_setup", "make_train_step", "make_serve_step"]


@dataclasses.dataclass(frozen=True)
class TrainSetup:
    cfg: ModelConfig
    ctx: ParallelCtx
    model: Model
    mesh: object
    defs: dict
    specs: dict
    opt: AdamWConfig
    grad_gz: Optional[GZConfig]  # gz knobs for the dp-axis grad allreduce
    # resolve-once communicators, one per data-parallel axis, bound to the
    # mesh axis sizes at setup time (plan resolution is a cache hit inside
    # the traced step body) — empty when gradient sync is plain psum
    grad_comms: tuple = ()
    # GradScaler-style degraded-step skip: when True, a train step whose
    # gradient sync reports overflow or non-finite input keeps the OLD
    # params/opt state (jnp.where merge, donation-safe) and flags it in
    # metrics["skipped"] instead of applying a corrupted update.  Mostly
    # useful with on_overflow="flag"; with "fallback" the values are
    # already exact and steps are never skipped for overflow alone.
    skip_on_overflow: bool = False
    # ISSUE 9 bucketed-overlap knobs: sync each gradient bucket from a
    # custom_vjp hook inside backward (instead of one post-hoc pass)...
    overlap_sync: bool = False
    # ...packing whole leaves last-layer-first into buckets of about this
    # many f32 bytes (0 never reaches here: make_setup resolves auto to
    # the BucketPlan's choice)...
    bucket_bytes: int = 16 * 1024 * 1024
    # ...with the modeled schedule (cost_model.BucketPlan) for
    # metrics["overlap_modeled"]; None when grad sync is plain psum or
    # single-rank.
    overlap_plan: Optional[cost_model.BucketPlan] = None

    def opt_specs(self):
        return {
            "mu": self.specs,
            "nu": self.specs,
            "step": P(),
        }

    def named(self, spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )


def _strip_axis(spec: P, ax: str) -> P:
    def strip(entry):
        if entry == ax:
            return None
        if isinstance(entry, tuple):
            kept = tuple(e for e in entry if e != ax)
            return kept if kept else None
        return entry

    return P(*(strip(e) for e in tuple(spec)))


def _tree_param_count(defs) -> int:
    total = 0
    for s in jax.tree.leaves(param_shapes(defs)):
        size = 1
        for d in s.shape:
            size *= int(d)
        total += size
    return total


def make_setup(
    cfg: ModelConfig,
    mesh,
    *,
    opt: AdamWConfig = AdamWConfig(),
    fsdp_gz: Optional[GZConfig] = None,
    grad_gz: Optional[GZConfig] = None,
    grad_policy: str = "auto",
    remat: str = "full",
    fsdp: bool = True,
    skip_on_overflow: bool = False,
    overlap_sync: bool = False,
    bucket_bytes: int = 0,
    overlap_tokens: int = 4096,
    overlap_hw: Optional[cost_model.Hardware] = None,
) -> TrainSetup:
    """``fsdp=False`` replicates parameters over the data axis (no per-layer
    gathers) — the weights-resident serving mode (§Perf hillclimb 1).

    ``grad_policy`` names the communicator plan policy ("auto" | "paper" |
    "throughput" | "accuracy" — core/comm.py) used when ``grad_gz`` leaves
    the algorithm choice open.

    ``overlap_sync`` turns on the per-bucket backward hooks;
    ``bucket_bytes == 0`` asks ``cost_model.best_bucket_plan`` to co-plan
    the bucket size with the ring pipeline depth at ``overlap_hw``
    (default the calibrated A100/Slingshot point) for a step of
    ``overlap_tokens`` tokens; > 0 forces the size.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))
    grad_comms = ()
    if grad_gz is not None:
        grad_comms = tuple(
            (ax, GZCommunicator.for_config(
                ax, grad_gz, policy=grad_policy, axis_size=sizes.get(ax, 1)))
            for ax in dp_axes
        )
    fsdp_sync = None
    if fsdp_gz:
        # mark_degraded rides skip_on_overflow: with a skip handler the
        # NaN-marked cotangent of a degraded sharded-axis reduce-scatter
        # is caught by _sync_grads' per-leaf probe; without one a NaN
        # step would be worse than a flagged lossy one.
        fsdp_sync = SyncConfig(gz=fsdp_gz, relative_eb=False,
                               mark_degraded=skip_on_overflow)
    ctx = ParallelCtx(
        tp_axis="model",
        fsdp_axis="data",
        dp_axes=dp_axes,
        tp_size=sizes.get("model", 1),
        fsdp_size=sizes.get("data", 1) if fsdp else 1,
        fsdp_sync=fsdp_sync,
        remat=remat,
    )
    model = Model(cfg, ctx)
    defs = model.param_defs()
    if not fsdp:
        defs = jax.tree.map(
            lambda d: dataclasses.replace(d, spec=_strip_axis(d.spec, "data")),
            defs,
            is_leaf=lambda x: hasattr(x, "spec") and hasattr(x, "init"),
        )
    n_dp = 1
    for ax in dp_axes:
        n_dp *= sizes.get(ax, 1)
    overlap_plan = None
    if grad_gz is not None and n_dp > 1:
        n_params = _tree_param_count(defs)
        overlap_plan = cost_model.best_bucket_plan(
            overlap_hw or cost_model.A100_SLINGSHOT,
            tree_bytes=4.0 * n_params,
            backward_flops=4.0 * n_params * overlap_tokens,
            n=n_dp,
        )
    if bucket_bytes <= 0:
        bucket_bytes = (overlap_plan.bucket_bytes if overlap_plan
                        else SyncConfig().bucket_bytes)
    return TrainSetup(
        cfg=cfg, ctx=ctx, model=model, mesh=mesh, defs=defs,
        specs=param_specs(defs), opt=opt, grad_gz=grad_gz,
        grad_comms=grad_comms, skip_on_overflow=skip_on_overflow,
        overlap_sync=overlap_sync, bucket_bytes=bucket_bytes,
        overlap_plan=overlap_plan,
    )


def _axes_in_spec(spec: P) -> set:
    return set(jax.tree.leaves(tuple(spec)))


def _sync_grads(grads, specs, mesh_axes, grad_comms: dict):
    """psum each leaf over every mesh axis absent from its spec.

    Reductions over dp axes with a bound communicator go through the
    compressed ``comm.allreduce`` (plan pre-resolved at setup time); the
    tiny "model"-axis cases stay psum.  Returns ``(grads, degraded)``
    where ``degraded`` ORs every leaf's health bit.

    EVERY leaf contributes a bit, not only the ones routed through a dp
    communicator (the ISSUE 9 satellite): a leaf sharded over the fsdp
    axis arrives here already reduce-scattered by ``fsdp_all_gather``'s
    backward — its overflow rides in as a NaN mark
    (``SyncConfig.mark_degraded``), and the per-leaf nonfinite probe
    below is what delivers it (and any plain non-finite gradient on a
    psum-only path) to ``skip_on_overflow``.
    """
    # A mutable cell: jax.tree.map's per-leaf callback can't return two
    # things without restructuring every caller, so the health bit
    # accumulates on the side (trace-safe — it's just op building).
    flag = [jnp.zeros((), jnp.bool_)]

    def sync(g, s):
        present = _axes_in_spec(s)
        flag[0] = flag[0] | jnp.any(~jnp.isfinite(g))
        for ax in mesh_axes:
            if ax in present:
                continue
            comm = grad_comms.get(ax)
            if comm is not None:
                res = comm.allreduce(g)
                g = res.value
                flag[0] = flag[0] | res.overflow | res.nonfinite
            else:
                g = lax.psum(g, ax)
        return g

    out = jax.tree.map(sync, grads, specs)
    return out, flag[0]


# ---------------------------------------------------------------------------
# Backward-overlapped bucketed sync (ISSUE 9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _BucketMeta:
    """Static description of one bucket hook (hashable: custom_vjp keys
    its nondiff args).  ``ops`` is the leaves' shared sync signature —
    ((axis, communicator-or-None), ...) over the mesh axes ABSENT from
    their specs, in mesh order, exactly the reduction _sync_grads would
    have applied post-hoc."""

    ops: tuple
    shapes: tuple
    dtypes: tuple


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _bucket_hook(meta: _BucketMeta, leaves, token):
    """Identity on ``(leaves, token)``; the custom_vjp BACKWARD performs
    this bucket's gradient reduction the moment its cotangents exist, so
    XLA can overlap the collective with the rest of backward.  The health
    flag leaves the backward as the token's cotangent (the only dataflow
    channel out), chained across hooks so grad-of-token accumulates every
    bucket's bit."""
    return leaves, token


def _bucket_hook_fwd(meta, leaves, token):
    return (leaves, token), None


def _bucket_hook_bwd(meta, _res, ct):
    gs, g_token = ct
    flat = [g.astype(jnp.float32).reshape(-1) for g in gs]
    vec = flat[0] if len(flat) == 1 else jnp.concatenate(flat)
    # Per-leaf nonfinite probe (the _sync_grads satellite, hook edition):
    # catches NaN-marked fsdp reduce-scatter cotangents even when this
    # bucket needs no collective of its own.
    flag = jnp.any(~jnp.isfinite(vec))
    for ax, comm in meta.ops:
        if comm is None:
            vec = lax.psum(vec, ax)
        else:
            res = comm.allreduce(vec)
            vec = res.value
            flag = flag | res.overflow | res.nonfinite
    outs, off = [], 0
    for shape, dt in zip(meta.shapes, meta.dtypes):
        size = 1
        for d in shape:
            size *= int(d)
        outs.append(vec[off:off + size].reshape(shape).astype(dt))
        off += size
    return tuple(outs), g_token + flag.astype(g_token.dtype)


_bucket_hook.defvjp(_bucket_hook_fwd, _bucket_hook_bwd)


def _install_bucket_hooks(params, specs, mesh_axes, grad_comms: dict,
                          bucket_bytes: int, token):
    """Wrap every param leaf in a per-bucket sync hook.

    Leaves are grouped by sync signature (identical reduction sequence —
    a bucket's concatenated payload must mean ONE collective), then
    packed greedily into ~``bucket_bytes`` f32 buckets walking the
    flatten order BACKWARD: the tree's tail (loss-side parameters) gets
    the first buckets, matching the order backward completes cotangents.
    Returns ``(hooked_params, token_out, n_buckets)``.
    """
    leaves, treedef = jax.tree.flatten(params)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    groups: dict = {}
    for i, spec in enumerate(spec_leaves):
        present = _axes_in_spec(spec)
        ops = tuple((ax, grad_comms.get(ax)) for ax in mesh_axes
                    if ax not in present)
        groups.setdefault(ops, []).append(i)
    new_leaves = list(leaves)
    n_buckets = 0
    for ops, idxs in groups.items():
        bucket: list = []
        pending = 0
        for i in reversed(idxs):  # last-layer-first
            bucket.append(i)
            pending += int(leaves[i].size) * 4
            if pending < bucket_bytes and i != idxs[0]:
                continue
            meta = _BucketMeta(
                ops=ops,
                shapes=tuple(leaves[j].shape for j in bucket),
                dtypes=tuple(str(leaves[j].dtype) for j in bucket),
            )
            outs, token = _bucket_hook(
                meta, tuple(new_leaves[j] for j in bucket), token
            )
            for j, o in zip(bucket, outs):
                new_leaves[j] = o
            n_buckets += 1
            bucket, pending = [], 0
    return jax.tree.unflatten(treedef, new_leaves), token, n_buckets


def _skip_merge(degraded, new_tree, old_tree):
    """Keep ``old_tree`` wherever this step degraded (replicated bool
    scalar predicate), else take ``new_tree`` — the GradScaler-style skip.
    Elementwise ``jnp.where`` (not lax.cond) so both sides stay donatable
    and the merge vectorizes into the update itself."""
    return jax.tree.map(
        lambda new, old: jnp.where(degraded, old, new), new_tree, old_tree
    )


def _global_grad_norm(grads, specs, sizes) -> jnp.ndarray:
    """Exact global norm of the synced (logical) gradient: local sum of
    squares per leaf / replication factor, psum'd over the whole mesh."""
    total = jnp.float32(0.0)
    mesh_axes = list(sizes)
    for g, s in zip(jax.tree.leaves(grads), jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))):
        present = _axes_in_spec(s)
        rep = 1
        for ax in mesh_axes:
            if ax not in present:
                rep *= sizes[ax]
        total = total + jnp.sum(jnp.square(g.astype(jnp.float32))) / rep
    for ax in mesh_axes:
        total = lax.psum(total, ax)
    return jnp.sqrt(total)


def make_train_step(setup: TrainSetup, batch_specs):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    cfg, ctx, model = setup.cfg, setup.ctx, setup.model
    sizes = dict(zip(setup.mesh.axis_names, setup.mesh.devices.shape))
    mesh_axes = tuple(setup.mesh.axis_names)
    n_dp = 1
    for ax in ctx.dp_axes:
        n_dp *= sizes[ax]
    scale = 1.0 / (ctx.tp_size * n_dp)
    specs = setup.specs
    grad_comms = dict(setup.grad_comms)
    overlap_modeled = float(
        setup.overlap_plan.overlap_efficiency
        if (setup.overlap_sync and setup.overlap_plan is not None) else 0.0
    )

    def body(params, opt_state, batch):
        if setup.overlap_sync:
            token0 = jnp.zeros((), jnp.float32)

            def scaled_loss(p, tok):
                p, tok_out, _ = _install_bucket_hooks(
                    p, specs, mesh_axes, grad_comms,
                    setup.bucket_bytes, tok,
                )
                # 0.0 * tok_out gives the token chain a real cotangent
                # edge without perturbing the loss: every hook backward
                # then adds its bucket's health bit to grad-of-token.
                return model.loss_fn(p, batch) * scale + 0.0 * tok_out

            loss, (grads, g_token) = jax.value_and_grad(
                scaled_loss, argnums=(0, 1)
            )(params, token0)
            degraded = g_token > 0
        else:
            def scaled_loss(p):
                return model.loss_fn(p, batch) * scale

            loss, grads = jax.value_and_grad(scaled_loss)(params)
            grads, degraded = _sync_grads(
                grads, specs, mesh_axes, grad_comms
            )
        loss = loss / scale
        for ax in ctx.dp_axes:
            loss = lax.pmean(loss, ax)
        # Each health bit is replicated over its OWN dp axis only; make
        # the skip predicate globally consistent before it gates state.
        degraded = lax.psum(degraded.astype(jnp.int32), mesh_axes) > 0
        gnorm = _global_grad_norm(grads, specs, sizes)
        new_params, new_opt, om = adamw_update(
            params, grads, opt_state, setup.opt, grad_norm=gnorm
        )
        skipped = jnp.zeros((), jnp.bool_)
        if setup.skip_on_overflow:
            new_params = _skip_merge(degraded, new_params, params)
            new_opt = _skip_merge(degraded, new_opt, opt_state)
            skipped = degraded
        metrics = {
            "loss": loss, "gnorm": om["gnorm"], "lr": om["lr"],
            "skipped": skipped,
            "overlap_modeled": jnp.full((), overlap_modeled, jnp.float32),
        }
        return new_params, new_opt, metrics

    ospecs = setup.opt_specs()
    mspecs = {"loss": P(), "gnorm": P(), "lr": P(), "skipped": P(),
              "overlap_modeled": P()}
    step = shard_map(
        body,
        mesh=setup.mesh,
        in_specs=(specs, ospecs, batch_specs),
        out_specs=(specs, ospecs, mspecs),
    )
    return jax.jit(
        step,
        in_shardings=(setup.named(specs), setup.named(ospecs),
                      setup.named(batch_specs)),
        out_shardings=(setup.named(specs), setup.named(ospecs),
                       setup.named(mspecs)),
        donate_argnums=(0, 1),
    )


def make_serve_step(setup: TrainSetup, cache_specs, tokens_spec, plan: KVCacheSpec):
    """Returns step(params, cache, tokens, pos) -> (logits, new_cache)."""
    model = setup.model
    specs = setup.specs
    v = setup.cfg.padded_vocab()

    def body(params, cache, tokens, pos):
        logits, new_cache = model.decode_fn(params, cache, tokens, pos[0], plan)
        return logits, new_cache

    logits_spec = P(*(tuple(tokens_spec)[:1] + (None, None)))
    step = shard_map(
        body,
        mesh=setup.mesh,
        in_specs=(specs, cache_specs, tokens_spec, P(None)),
        out_specs=(logits_spec, cache_specs),
    )
    return jax.jit(
        step,
        in_shardings=(
            setup.named(specs),
            setup.named(cache_specs),
            NamedSharding(setup.mesh, tokens_spec),
            NamedSharding(setup.mesh, P(None)),
        ),
        donate_argnums=(1,),
    )
