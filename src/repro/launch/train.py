"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-8b --smoke \
        --steps 50 --batch 8 --seq 128 [--grad-gz redoub] [--eb 1e-4]

On this CPU container it trains the reduced (smoke) configs for real —
a few hundred steps of a ~100M-class model is examples/quickstart.py.
On a TPU pod the same driver runs the full configs (mesh from
make_production_mesh).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint
from repro.configs import registry
from repro.core.collectives import GZConfig
from repro.data.pipeline import SyntheticStream
from repro.launch.shapes import InputShape, train_specs
from repro.launch.training import make_setup, make_train_step
from repro.models.parallel import init_params
from repro.optim.adamw import AdamWConfig, adamw_init


def train(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b", choices=registry.arch_ids())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-gz", default=None,
                    choices=["auto", "redoub", "ring", "intring"])
    ap.add_argument("--policy", default="auto",
                    choices=["auto", "paper", "throughput", "accuracy"],
                    help="communicator plan policy when --grad-gz leaves "
                         "the algorithm open (core/comm.py)")
    ap.add_argument("--eb", type=float, default=1e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch, smoke=args.smoke)
    n_dev = len(jax.devices())
    # widest (data, model) factorization available on this host
    data = 1
    while data * 2 <= n_dev and args.batch % (data * 2) == 0 and (n_dev // (data * 2)) * (data * 2) == n_dev:
        data *= 2
    model_par = 1
    mesh = jax.make_mesh((data, model_par), ("data", "model"))

    gz = GZConfig(eb=args.eb, algo=args.grad_gz) if args.grad_gz else None
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1))
    setup = make_setup(cfg, mesh, opt=opt, grad_gz=gz, grad_policy=args.policy)
    shape = InputShape("cli", args.seq, args.batch, "train")
    _, bspecs = train_specs(cfg, shape, mesh)
    step_fn = make_train_step(setup, bspecs)

    params = init_params(setup.defs, jax.random.key(args.seed))
    opt_state = adamw_init(params)
    stream = SyntheticStream(cfg, args.batch, args.seq, seed=args.seed)

    print(f"arch={cfg.arch_id} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"grad_gz={args.grad_gz}")
    losses = []
    t0 = time.time()
    for step, batch in zip(range(args.steps), stream):
        params, opt_state, m = step_fn(params, opt_state, batch)
        loss = float(m["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {loss:.4f} gnorm {float(m['gnorm']):.3f} "
                  f"lr {float(m['lr']):.2e} ({dt:.1f}s)")
        if args.ckpt_dir and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            d = checkpoint.save(args.ckpt_dir, step + 1,
                                {"params": params, "opt": opt_state})
            print(f"  ckpt -> {d}")
    assert np.isfinite(losses).all(), "NaN loss"
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    train()
