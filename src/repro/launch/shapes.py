"""Assigned input shapes + ShapeDtypeStruct input specs for the dry-run.

INPUT_SHAPES are the four assigned (seq_len, global_batch) points.  Decode
shapes lower ``serve_step`` (ONE token against a seq_len KV cache);
long_500k additionally requires a sub-quadratic path: SSM/hybrid run their
recurrent state, attention archs run the sliding-window variant
(window=8192) — see DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import KVCacheSpec
from repro.models.config import ModelConfig

__all__ = ["InputShape", "INPUT_SHAPES", "train_specs", "decode_plan", "decode_specs"]

LONG_WINDOW = 8192


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "train"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def _batch_tree(cfg: ModelConfig, b: int, s: int):
    s_text = s - (cfg.n_prefix if cfg.family in ("vlm", "audio") else 0)
    tree = {
        "tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s_text), jnp.int32),
    }
    if cfg.family in ("vlm", "audio") and cfg.n_prefix:
        tree["prefix"] = jax.ShapeDtypeStruct((b, cfg.n_prefix, cfg.d_model),
                                              jnp.float32)
    if cfg.family == "encdec":
        tree["enc_input"] = jax.ShapeDtypeStruct((b, cfg.n_prefix, cfg.d_model),
                                                 jnp.float32)
    return tree


def train_specs(cfg: ModelConfig, shape: InputShape, mesh):
    """(batch ShapeDtypeStructs, batch PartitionSpecs) for a train shape.

    prefill_32k is lowered as the forward pass of train_step machinery
    (prefill IS a forward pass); global batch is sharded over the dp axes.
    """
    dp = tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))
    tree = _batch_tree(cfg, shape.global_batch, shape.seq_len)
    specs = jax.tree.map(
        lambda a: P(*((dp,) + (None,) * (len(a.shape) - 1))), tree
    )
    return tree, specs


def decode_plan(cfg: ModelConfig, shape: InputShape, mesh) -> KVCacheSpec:
    """Decide batch-sharding vs context-parallel for a decode shape."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_total = sizes.get("data", 1) * sizes.get("pod", 1)
    window = 0
    if shape.seq_len > 100_000 and cfg.family not in ("ssm",):
        window = LONG_WINDOW  # sub-quadratic sliding-window variant
    if shape.global_batch >= dp_total:
        return KVCacheSpec(s_total=shape.seq_len, cp_axis=None, cp_size=1,
                           window=window)
    # batch too small to fill dp: context-parallel the cache over "data"
    return KVCacheSpec(
        s_total=shape.seq_len,
        cp_axis="data",
        cp_size=sizes.get("data", 1),
        window=window,
    )


def decode_specs(cfg: ModelConfig, shape: InputShape, mesh, model,
                 cache_dtype=jnp.float32):
    """(inputs ShapeDtypeStructs, PartitionSpecs) for serve_step.

    Returns (cache_tree, cache_specs, tokens, tokens_spec, plan) with GLOBAL
    shapes (batch un-sharded, cache context dim global).  ``cache_dtype``
    applies to the k/v entries (bf16 halves cache HBM + flash-decode reads
    — §Perf H1 iteration 2); latent/state entries stay f32.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))
    dp_total = 1
    for ax in dp:
        dp_total *= sizes[ax]
    plan = decode_plan(cfg, shape, mesh)
    tp = sizes.get("model", 1)
    batch_sharded = plan.cp_axis is None
    b_local = shape.global_batch // dp_total if batch_sharded else shape.global_batch
    local = model.cache_defs(b_local, plan)

    cache, specs = {}, {}
    for k, shp in local.items():
        shp = list(shp)
        spec = [None] * len(shp)
        if k in ("k", "v"):
            # (L, B, S_loc, kv_local, hd)
            if batch_sharded:
                shp[1] *= dp_total
                spec[1] = dp
            else:
                shp[2] *= plan.cp_size
                spec[2] = "data"
            shp[3] *= tp
            spec[3] = "model"
        elif k == "mla":
            if batch_sharded:
                shp[1] *= dp_total
                spec[1] = dp
        elif k in ("conv_x", "ssm"):
            if batch_sharded:
                shp[1] *= dp_total
                spec[1] = dp
            dim = 2 if k == "conv_x" else 2  # channel/head dim is TP-sharded
            last = {"conv_x": len(shp) - 1, "ssm": 2}[k]
            shp[last] *= tp
            spec[last] = "model"
        elif k == "conv_bc":
            if batch_sharded:
                shp[1] *= dp_total
                spec[1] = dp
        elif k == "enc_out":
            if batch_sharded:
                shp[0] *= dp_total
                spec[0] = dp
        dt = cache_dtype if k in ("k", "v") else jnp.float32
        cache[k] = jax.ShapeDtypeStruct(tuple(shp), dt)
        specs[k] = P(*spec)

    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tokens_spec = P(dp, None) if batch_sharded else P(None, None)
    return cache, specs, tokens, tokens_spec, plan
