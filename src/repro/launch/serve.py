"""Serving driver: batched greedy decode with a KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core.shmap import shard_map
from repro.launch.training import make_setup
from repro.models.attention import KVCacheSpec
from repro.models.parallel import init_params, param_specs


def serve(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m", choices=registry.arch_ids())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch, smoke=args.smoke)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    setup = make_setup(cfg, mesh)
    model = setup.model
    plan = KVCacheSpec(s_total=args.cache_len, cp_axis=None, cp_size=1)
    shapes = model.cache_defs(args.batch, plan)
    rng = np.random.default_rng(args.seed)
    cache = {k: jnp.zeros(v, jnp.float32) for k, v in shapes.items()}
    if "enc_out" in cache:
        cache["enc_out"] = jnp.asarray(
            rng.normal(0, 1, shapes["enc_out"]).astype(np.float32))

    specs = setup.specs
    cspecs = {k: P(*((None,) * len(v))) for k, v in shapes.items()}

    def body(p, c, t, pos):
        logits, nc = model.decode_fn(p, c, t, pos[0], plan)
        return logits, nc

    step = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(specs, cspecs, P(None, None), P(None)),
        out_specs=(P(None, None, None), cspecs),
    ))

    params = init_params(setup.defs, jax.random.key(args.seed))
    prompt = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(
        np.int32)

    # prefill token-by-token (decode-path prefill keeps one code path)
    t0 = time.time()
    tok = None
    out_tokens = []
    for i in range(args.prompt_len + args.gen):
        if i < args.prompt_len:
            tok = jnp.asarray(prompt[:, i : i + 1])
        logits, cache = step(params, cache, tok, jnp.asarray([i]))
        nxt = jnp.argmax(logits[:, :, : cfg.vocab], axis=-1).astype(jnp.int32)
        if i >= args.prompt_len - 1:
            tok = nxt
            out_tokens.append(np.asarray(nxt)[:, 0])
    dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    n_tok = args.batch * (args.prompt_len + args.gen)
    print(f"arch={cfg.arch_id} decoded {gen.shape[1]} tokens x{args.batch} "
          f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s incl. prefill)")
    print("sample:", gen[0][:16])
    assert np.isfinite(np.asarray(logits)).all()
    return gen


if __name__ == "__main__":
    serve()
