"""Synthetic data pipeline with gZ-Scatter batch distribution.

Deterministic synthetic token streams (zipf-ish unigram mix + shift
labels), plus modality-frontend stub embeddings for the VLM/audio archs.
The batch-distribution path demonstrates the paper's gZ-Scatter as the
data-plane collective: the root rank holds the global float features and
scatters compressed blocks down the binomial tree
(examples/data_scatter.py runs it on 8 virtual devices).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig

__all__ = ["SyntheticStream", "make_batch"]


@dataclasses.dataclass
class SyntheticStream:
    """Infinite deterministic batch stream for a given model config."""

    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        # zipf-ish unigram distribution — more realistic loss curves than
        # uniform tokens
        v = self.cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks**1.1
        self._p = p / p.sum()

    def __iter__(self):
        return self

    def __next__(self):
        return make_batch(self.cfg, self.batch, self.seq, self._rng, self._p)


def make_batch(cfg: ModelConfig, batch: int, seq: int, rng, p=None) -> dict:
    s_text = seq - (cfg.n_prefix if cfg.family in ("vlm", "audio") else 0)
    if p is not None:
        toks = rng.choice(cfg.vocab, size=(batch, s_text + 1), p=p).astype(np.int32)
    else:
        toks = rng.integers(0, cfg.vocab, (batch, s_text + 1)).astype(np.int32)
    out = {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:].copy(),
    }
    if cfg.family in ("vlm", "audio") and cfg.n_prefix:
        out["prefix"] = rng.normal(0, 1.0, (batch, cfg.n_prefix, cfg.d_model)).astype(
            np.float32
        )
    if cfg.family == "encdec":
        out["enc_input"] = rng.normal(
            0, 1.0, (batch, cfg.n_prefix, cfg.d_model)
        ).astype(np.float32)
    return out
