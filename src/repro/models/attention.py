"""GQA attention: training (flash-style chunked), prefill, and decode.

Tensor-parallel layout (rank-centric, inside shard_map):
  * q heads sharded over the TP axis (padded to a multiple of tp —
    zero-init extra heads, their out-proj rows are zero).
  * k/v projection weights replicated over TP (they are small); each rank
    *uses* only the kv heads its q heads need (``_local_kv``), so the
    decode KV cache IS sharded over TP (kv dim) and over the context-
    parallel axis (sequence dim) — flash-decoding with a partial-softmax
    psum combine.

Training attention is a pure-JAX flash pattern: lax.scan over kv chunks
with running (max, sumexp, acc) so the (S, S) score matrix never
materializes — required for prefill_32k to fit HBM.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rope
from repro.models.parallel import ParallelCtx

NEG = -1e30


def _local_kv(kv: jnp.ndarray, cfg: ModelConfig, ctx: ParallelCtx) -> jnp.ndarray:
    """Select this rank's kv heads from the full set: (..., n_kv, hd) ->
    (..., kv_local, hd)."""
    tp, n_kv = ctx.tp_size, cfg.n_kv_heads
    if tp == 1:
        return kv
    if n_kv >= tp:
        kv_local = n_kv // tp
        start = ctx.tp_index() * kv_local
        return lax.dynamic_slice_in_dim(kv, start, kv_local, axis=-2)
    # replication groups: tp/n_kv ranks share one kv head
    head = ctx.tp_index() // (tp // n_kv)
    return lax.dynamic_slice_in_dim(kv, head, 1, axis=-2)


def kv_local_heads(cfg: ModelConfig, tp: int) -> int:
    return max(cfg.n_kv_heads // tp, 1)


def _repeat_kv(kv: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, kv, hd) -> (B, S, kv*n_rep, hd)."""
    if n_rep == 1:
        return kv
    b, s, k, d = kv.shape
    return jnp.broadcast_to(kv[:, :, :, None, :], (b, s, k, n_rep, d)).reshape(
        b, s, k * n_rep, d
    )


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    chunk: int = 1024,
) -> jnp.ndarray:
    """Chunked-softmax attention, O(S) memory.

    q: (B, Sq, H, D); k, v: (B, Sk, H, D) (kv already repeated to H heads).
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill=0).
    ``window`` > 0 applies a sliding-window causal mask.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qf = q.astype(jnp.float32) * scale
    chunk = min(chunk, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kp = kp.reshape(b, n_chunks, chunk, h, d)
    vp = vp.reshape(b, n_chunks, chunk, h, d)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m, s, acc = carry
        kc, vc, c_idx = inp
        k_pos = c_idx * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kc.astype(jnp.float32))
        if causal:
            mask = k_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= k_pos[None, :] > (q_pos[:, None] - window)
        else:
            mask = jnp.ones((sq, chunk), bool)
        mask &= (k_pos < sk)[None, :]
        logits = jnp.where(mask[None, None], logits, NEG)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        s_new = s * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vc.astype(jnp.float32)
        )
        return (m_new, s_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG, jnp.float32)
    s0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, s, acc), _ = lax.scan(
        body,
        (m0, s0, a0),
        (
            jnp.moveaxis(kp, 1, 0),
            jnp.moveaxis(vp, 1, 0),
            jnp.arange(n_chunks),
        ),
    )
    out = acc / jnp.maximum(s, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, Sq, H, D)


@dataclasses.dataclass(frozen=True)
class AttnParamsShape:
    """Helper documenting the weight layout (see blocks.py for ParamDefs)."""


def attention_train(
    h: jnp.ndarray,
    w: dict,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    *,
    positions: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    cross_kv: jnp.ndarray | None = None,
    reduce: bool = True,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill).

    w: {"wq": (d, hp*hd/tp local), "wk": (d, n_kv*hd), "wv": same,
        "wo": (hp*hd/tp local, d)} — wq/wo are TP-sharded (local arrays),
    wk/wv replicated; all FSDP-sharded on the d dim (gathered here).
    ``cross_kv``: (B, S_enc, d) encoder output for cross-attention.
    """
    b, s, _ = h.shape
    hd = cfg.head_dim
    h_local = cfg.padded_heads(ctx.tp_size) // ctx.tp_size
    wq = ctx.gather(w["wq"], dim=0)
    wk = ctx.gather(w["wk"], dim=0)
    wv = ctx.gather(w["wv"], dim=0)
    wo = ctx.gather(w["wo"], dim=1)
    q = jnp.einsum("bsd,dh->bsh", h, wq).reshape(b, s, h_local, hd)
    kv_src = cross_kv if cross_kv is not None else h
    sk = kv_src.shape[1]
    k = jnp.einsum("bsd,dh->bsh", kv_src, wk).reshape(b, sk, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", kv_src, wv).reshape(b, sk, cfg.n_kv_heads, hd)
    if cross_kv is None:
        sin, cos = rope(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    k = _local_kv(k, cfg, ctx)
    v = _local_kv(v, cfg, ctx)
    n_rep = h_local // k.shape[-2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    if cfg.use_flash_kernel:
        from repro.kernels import flash_attn

        out = flash_attn.flash_attention(
            q, k, v, causal=causal and cross_kv is None, window=window,
            interpret=jax.default_backend() != "tpu",
        )
    else:
        out = flash_attention(
            q, k, v, causal=causal and cross_kv is None, window=window
        )
    out = out.reshape(b, s, h_local * hd)
    out = jnp.einsum("bsh,hd->bsd", out, wo)
    return ctx.tp_reduce(out) if reduce else out


# ---------------------------------------------------------------------------
# Decode (one token) with context-parallel KV cache — flash-decoding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Decode cache layout: (B_local, S_local, kv_local, hd) per rank.

    S (the cache context) is sharded over ``cp_axis`` (the "data" axis)
    when the batch cannot occupy it (long-context, small batch); kv heads
    are sharded over TP.  ``window`` > 0 means ring-buffer semantics.
    """

    s_total: int
    cp_axis: str | None
    cp_size: int
    window: int = 0

    @property
    def s_local(self) -> int:
        s = self.window if self.window else self.s_total
        return s // max(self.cp_size, 1)


def attention_decode(
    h: jnp.ndarray,
    w: dict,
    cache_k: jnp.ndarray,
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    spec: KVCacheSpec,
):
    """One-token attention against a (possibly context-parallel) KV cache.

    h: (B, 1, d). cache_k/v: (B, S_local, kv_local, hd).  pos: scalar int32
    — the absolute position of the incoming token.  Returns (out, new_k,
    new_v).  Combine across the context-parallel axis is the flash-decoding
    partial-softmax psum.
    """
    b = h.shape[0]
    hd = cfg.head_dim
    h_local = cfg.padded_heads(ctx.tp_size) // ctx.tp_size
    wq = ctx.gather(w["wq"], dim=0)
    wk = ctx.gather(w["wk"], dim=0)
    wv = ctx.gather(w["wv"], dim=0)
    wo = ctx.gather(w["wo"], dim=1)
    q = jnp.einsum("bsd,dh->bsh", h, wq).reshape(b, 1, h_local, hd)
    k_new = jnp.einsum("bsd,dh->bsh", h, wk).reshape(b, 1, cfg.n_kv_heads, hd)
    v_new = jnp.einsum("bsd,dh->bsh", h, wv).reshape(b, 1, cfg.n_kv_heads, hd)
    sin, cos = rope(pos[None], hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k_new = apply_rope(k_new, sin, cos)
    k_new = _local_kv(k_new, cfg, ctx)
    v_new = _local_kv(v_new, cfg, ctx)
    kv_local = k_new.shape[-2]

    # Which cache slot does this token land in, and is it mine?
    s_local = spec.s_local
    if spec.window:
        slot_global = pos % spec.window
    else:
        slot_global = pos
    cp_rank = (
        lax.axis_index(spec.cp_axis) if spec.cp_axis and spec.cp_size > 1 else 0
    )
    my_start = cp_rank * s_local
    slot_local = jnp.clip(slot_global - my_start, 0, s_local - 1)
    mine = (slot_global >= my_start) & (slot_global < my_start + s_local)
    upd_k = lax.dynamic_update_slice(
        cache_k, k_new.astype(cache_k.dtype), (0, slot_local, 0, 0)
    )
    upd_v = lax.dynamic_update_slice(
        cache_v, v_new.astype(cache_v.dtype), (0, slot_local, 0, 0)
    )
    new_k = jnp.where(mine, upd_k, cache_k)
    new_v = jnp.where(mine, upd_v, cache_v)

    # Validity of cache slots (global positions covered so far, incl. new).
    slot_ids = my_start + jnp.arange(s_local)
    if spec.window:
        # ring buffer: slot holds position p iff p = latest p' <= pos with
        # p' % window == slot; valid iff within the last `window` tokens.
        cycle = (pos // spec.window) * spec.window + slot_ids
        slot_pos = jnp.where(cycle <= pos, cycle, cycle - spec.window)
        valid = (slot_pos >= 0) & (slot_pos > pos - spec.window)
    else:
        slot_pos = slot_ids
        valid = slot_ids <= pos

    n_rep = h_local // kv_local
    kk = _repeat_kv(new_k, n_rep)  # (B, S_local, H_local, hd)
    vv = _repeat_kv(new_v, n_rep)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", (q * scale).astype(jnp.float32), kk.astype(jnp.float32)
    )  # (B, H, 1, S_local)
    logits = jnp.where(valid[None, None, None, :], logits, NEG)
    m_l = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m_l[..., None])
    s_l = jnp.sum(p, axis=-1)
    o_l = jnp.einsum("bhqk,bkhd->bhqd", p, vv.astype(jnp.float32))
    if spec.cp_axis and spec.cp_size > 1:
        m = lax.pmax(m_l, spec.cp_axis)
        corr = jnp.exp(m_l - m)
        s = lax.psum(s_l * corr, spec.cp_axis)
        o = lax.psum(o_l * corr[..., None], spec.cp_axis)
    else:
        m, s, o = m_l, s_l, o_l
    out = (o / jnp.maximum(s, 1e-30)[..., None]).astype(h.dtype)
    out = jnp.moveaxis(out, 1, 2).reshape(b, 1, h_local * hd)
    proj = ctx.tp_reduce(jnp.einsum("bsh,hd->bsd", out, wo))
    return proj, new_k, new_v
