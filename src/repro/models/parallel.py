"""Parallelism context + parameter-definition machinery.

Everything distributed in this framework is explicit shard_map: model code
is rank-centric, receives *local* parameter shards, and uses

  * ``ParallelCtx.tp_*``   — Megatron-style tensor parallel over "model",
  * ``fsdp_gather``        — ZeRO-3 gather over "data" (optionally through
                             the gZ compressed allgather via the per-axis
                             ``GZCommunicator`` — core/comm.py — the
                             paper's technique in the training hot path),
  * ``dp_axes``            — gradient-sync axes (("pod","data") multi-pod).

``ParamDef`` carries the GLOBAL shape, its PartitionSpec, and an init; the
launcher materializes globals, the dry-run builds ShapeDtypeStructs, and
shard_map in_specs come from the same tree — one source of truth.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.grad_sync import SyncConfig, fsdp_all_gather

__all__ = ["ParallelCtx", "ParamDef", "init_params", "param_specs", "param_shapes"]


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Static description of how the mesh axes are used."""

    tp_axis: str = "model"
    fsdp_axis: str = "data"
    dp_axes: tuple = ("data",)  # ("pod","data") on the multi-pod mesh
    tp_size: int = 1
    fsdp_size: int = 1
    # gZ compression on the FSDP param-gather / grad reduce-scatter path
    fsdp_sync: Optional[SyncConfig] = None
    # remat policy for the per-layer scan ("none"|"full"|"dots")
    remat: str = "full"
    # scan unroll factor; the dry-run's differential body costing sets this
    # high so 1- vs 2-layer lowerings contain no `while` (XLA cost_analysis
    # counts while bodies once — see launch/costing.py)
    scan_unroll: int = 1

    def gather(self, x: jnp.ndarray, dim: int = 0) -> jnp.ndarray:
        """FSDP all-gather of a parameter along ``dim`` (identity if 1)."""
        if self.fsdp_size == 1:
            return x
        if dim != 0:
            x = jnp.moveaxis(x, dim, 0)
        out = fsdp_all_gather(x, self.fsdp_axis, self.fsdp_sync)
        if dim != 0:
            out = jnp.moveaxis(out, 0, dim)
        return out

    def tp_reduce(self, x: jnp.ndarray) -> jnp.ndarray:
        """Row-parallel output reduction."""
        if self.tp_size == 1:
            return x
        return lax.psum(x, self.tp_axis)

    def tp_index(self):
        return lax.axis_index(self.tp_axis) if self.tp_size > 1 else 0


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Global-view definition of one parameter tensor."""

    shape: tuple
    spec: P
    init: str = "normal"  # normal | zeros | ones | scaled
    scale: float = 0.02
    dtype: str = "bfloat16"

    def initializer(self, key) -> jnp.ndarray:
        dt = jnp.dtype(self.dtype)
        if self.init == "zeros":
            return jnp.zeros(self.shape, dt)
        if self.init == "ones":
            return jnp.ones(self.shape, dt)
        if self.init == "scaled":
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            s = 1.0 / np.sqrt(fan_in)
            return (jax.random.normal(key, self.shape, jnp.float32) * s).astype(dt)
        return (
            jax.random.normal(key, self.shape, jnp.float32) * self.scale
        ).astype(dt)


def _is_def(x):
    return isinstance(x, ParamDef)


def init_params(defs, key):
    """Materialize a ParamDef tree into (global) arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [d.initializer(k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def param_specs(defs):
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=_is_def)


def param_shapes(defs):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        defs,
        is_leaf=_is_def,
    )
