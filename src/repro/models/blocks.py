"""Per-block ParamDef trees and apply functions for every family.

Shapes below are GLOBAL; PartitionSpecs encode TP ("model") and FSDP
("data") placement.  A leading L dim (stacked layers) is added by model.py
for scanned stacks — specs gain a leading None there.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention, mla, moe, ssm
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.parallel import ParallelCtx, ParamDef

__all__ = [
    "attn_defs",
    "mlp_defs",
    "moe_defs",
    "ssm_defs",
    "mla_defs",
    "dense_block",
    "moe_block",
    "ssm_block",
    "mla_block",
]


def _pd(shape, spec, init="scaled", dtype="bfloat16"):
    return ParamDef(shape=tuple(shape), spec=spec, init=init, dtype=dtype)


def attn_defs(cfg: ModelConfig, tp: int) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    hp = cfg.padded_heads(tp)
    return {
        "wq": _pd((d, hp * hd), P("data", "model")),
        "wk": _pd((d, cfg.n_kv_heads * hd), P("data", None)),
        "wv": _pd((d, cfg.n_kv_heads * hd), P("data", None)),
        "wo": _pd((hp * hd, d), P("model", "data")),
    }


def mlp_defs(cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "wi": _pd((d, ff), P("data", "model")),
        "wg": _pd((d, ff), P("data", "model")),
        "wo": _pd((ff, d), P("model", "data")),
    }


def moe_defs(cfg: ModelConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": _pd((d, e), P("data", None)),
        "wi": _pd((e, d, ff), P("model", "data", None)),
        "wg": _pd((e, d, ff), P("model", "data", None)),
        "wo": _pd((e, ff, d), P("model", None, "data")),
    }


def ssm_defs(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    w = s.conv_width
    return {
        "w_z": _pd((d, di), P("data", "model")),
        "w_x": _pd((d, di), P("data", "model")),
        "w_bc": _pd((d, 2 * s.d_state), P("data", None)),
        "w_dt": _pd((d, h), P("data", "model")),
        "conv_x": _pd((w, di), P(None, "model"), init="scaled"),
        "conv_bc": _pd((w, 2 * s.d_state), P(None, None), init="scaled"),
        "A_log": _pd((h,), P("model"), init="zeros", dtype="float32"),
        "D": _pd((h,), P("model"), init="ones", dtype="float32"),
        "dt_bias": _pd((h,), P("model"), init="zeros", dtype="float32"),
        "norm": _pd((di,), P("model"), init="ones"),
        "w_out": _pd((di, d), P("model", "data")),
    }


def mla_defs(cfg: ModelConfig, tp: int) -> dict:
    m = cfg.mla
    d = cfg.d_model
    hp = cfg.padded_heads(tp)
    return {
        "wq_a": _pd((d, m.q_lora_rank), P("data", None)),
        "wq_b": _pd(
            (m.q_lora_rank, hp * (m.qk_nope_head_dim + m.qk_rope_head_dim)),
            P(None, "model"),
        ),
        "wkv_a": _pd((d, m.kv_lora_rank + m.qk_rope_head_dim), P("data", None)),
        "wkv_b": _pd(
            (m.kv_lora_rank, hp * (m.qk_nope_head_dim + m.v_head_dim)),
            P(None, "model"),
        ),
        "wo": _pd((hp * m.v_head_dim, d), P("model", "data")),
    }


def norm_def(cfg: ModelConfig) -> ParamDef:
    return ParamDef(shape=(cfg.d_model,), spec=P(None), init="ones")


def _mlp(h, w, ctx: ParallelCtx, reduce: bool = True):
    wi = ctx.gather(w["wi"], dim=0)
    wg = ctx.gather(w["wg"], dim=0)
    wo = ctx.gather(w["wo"], dim=1)
    a = jnp.einsum("bsd,df->bsf", h, wg)
    a = a * jax.nn.sigmoid(a.astype(jnp.float32)).astype(a.dtype)
    b = jnp.einsum("bsd,df->bsf", h, wi)
    out = jnp.einsum("bsf,fd->bsd", a * b, wo)
    return ctx.tp_reduce(out) if reduce else out


def dense_block(h, w, cfg: ModelConfig, ctx: ParallelCtx, *, positions,
                causal=True, window=0, cross_kv=None):
    """Pre-norm attention + SwiGLU MLP block (dense / vlm / enc-dec).

    With cfg.parallel_block (PaLM-style): attention and MLP partials are
    summed BEFORE one shared TP psum — half the TP-collective bytes/layer.
    """
    if cfg.parallel_block and cross_kv is None:
        a = attention.attention_train(
            rms_norm(h, w["ln1"], cfg.norm_eps), w["attn"], cfg, ctx,
            positions=positions, causal=causal, window=window, reduce=False,
        )
        m = _mlp(rms_norm(h, w["ln2"], cfg.norm_eps), w["mlp"], ctx,
                 reduce=False)
        return h + ctx.tp_reduce(a + m)
    a = attention.attention_train(
        rms_norm(h, w["ln1"], cfg.norm_eps), w["attn"], cfg, ctx,
        positions=positions, causal=causal, window=window,
    )
    h = h + a
    if cross_kv is not None:
        c = attention.attention_train(
            rms_norm(h, w["ln_cross"], cfg.norm_eps), w["cross"], cfg, ctx,
            positions=positions, causal=False, cross_kv=cross_kv,
        )
        h = h + c
    m = _mlp(rms_norm(h, w["ln2"], cfg.norm_eps), w["mlp"], ctx)
    return h + m


def moe_block(h, w, cfg: ModelConfig, ctx: ParallelCtx, *, positions,
              causal=True, window=0):
    a = attention.attention_train(
        rms_norm(h, w["ln1"], cfg.norm_eps), w["attn"], cfg, ctx,
        positions=positions, causal=causal, window=window,
    )
    h = h + a
    dcomm = None
    if cfg.moe_dispatch_gz_eb:
        from repro.core.collectives import GZConfig
        from repro.core.comm import GZCommunicator

        # Memoized one-shot communicator bound to the TP axis: every layer
        # shares one instance and the dispatch plan is resolved once.
        dcomm = GZCommunicator.for_config(
            ctx.tp_axis,
            GZConfig(eb=cfg.moe_dispatch_gz_eb, capacity_factor=0.8),
        )
    m, aux = moe.moe_ffn(rms_norm(h, w["ln2"], cfg.norm_eps), w["moe"], cfg,
                         ctx, dispatch_comm=dcomm)
    return h + m, aux


def ssm_block(h, w, cfg: ModelConfig, ctx: ParallelCtx):
    y = ssm.ssm_train(rms_norm(h, w["ln1"], cfg.norm_eps), w["ssm"], cfg, ctx)
    return h + y


def mla_block(h, w, cfg: ModelConfig, ctx: ParallelCtx, *, positions):
    a = mla.mla_train(
        rms_norm(h, w["ln1"], cfg.norm_eps), w["mla"], cfg, ctx,
        positions=positions,
    )
    h = h + a
    m = _mlp(rms_norm(h, w["ln2"], cfg.norm_eps), w["mlp"], ctx)
    return h + m
