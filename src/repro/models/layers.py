"""Shared layers: RMSNorm, RoPE, vocab-parallel embedding and loss.

All functions are rank-centric shard_map body code operating on local
shards, parameterized by ParallelCtx.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.parallel import ParallelCtx

__all__ = [
    "rms_norm",
    "rope",
    "apply_rope",
    "embed_lookup",
    "vocab_parallel_logits",
    "vocab_parallel_xent",
    "gather_logits",
]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def rope(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple:
    """(sin, cos) tables for given positions: (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D); sin/cos: (S, D/2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :] if sin.ndim == 2 else sin
    c = cos[..., None, :] if cos.ndim == 2 else cos
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
    ).astype(x.dtype)


def _vocab_range(ctx: ParallelCtx, v_pad: int):
    v_local = v_pad // ctx.tp_size
    start = ctx.tp_index() * v_local
    return start, v_local


def embed_lookup(
    ids: jnp.ndarray, w_embed: jnp.ndarray, ctx: ParallelCtx
) -> jnp.ndarray:
    """Vocab-parallel embedding: w_embed local (v_local, d_local_fsdp).

    FSDP-gathers the feature dim, masks out-of-range ids, psums over TP.
    """
    w = ctx.gather(w_embed, dim=1)  # (v_local, d)
    v_local = w.shape[0]
    start = ctx.tp_index() * v_local
    local_ids = ids - start
    valid = (local_ids >= 0) & (local_ids < v_local)
    emb = jnp.take(w, jnp.clip(local_ids, 0, v_local - 1), axis=0)
    emb = jnp.where(valid[..., None], emb, 0)
    return ctx.tp_reduce(emb)


def vocab_parallel_logits(
    h: jnp.ndarray, w_unembed: jnp.ndarray, ctx: ParallelCtx
) -> jnp.ndarray:
    """h: (..., d); w_unembed local (d_fsdp_shard, v_local) -> local logits."""
    w = ctx.gather(w_unembed, dim=0)  # (d, v_local)
    return jnp.einsum("...d,dv->...v", h.astype(jnp.float32), w.astype(jnp.float32))


def vocab_parallel_xent(
    logits_local: jnp.ndarray,
    labels: jnp.ndarray,
    ctx: ParallelCtx,
    *,
    mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Cross-entropy over TP-sharded logits (Megatron vocab-parallel loss).

    logits_local: (B, S, v_local) f32; labels: (B, S) global ids.
    Returns mean NLL over (masked) positions, identical on all TP ranks.
    """
    v_local = logits_local.shape[-1]
    start = ctx.tp_index() * v_local
    # the max is only a numerical-stability shift — no grad flows through it
    # (stop_gradient BEFORE pmax: pmax has no differentiation rule)
    m = lax.stop_gradient(jnp.max(logits_local, axis=-1))
    if ctx.tp_size > 1:
        m = lax.pmax(m, ctx.tp_axis)
    z = jnp.sum(jnp.exp(logits_local - m[..., None]), axis=-1)
    if ctx.tp_size > 1:
        z = lax.psum(z, ctx.tp_axis)
    logz = jnp.log(z) + m
    local_label = labels - start
    valid = (local_label >= 0) & (local_label < v_local)
    picked = jnp.take_along_axis(
        logits_local,
        jnp.clip(local_label, 0, v_local - 1)[..., None],
        axis=-1,
    )[..., 0]
    picked = jnp.where(valid, picked, 0.0)
    picked = ctx.tp_reduce(picked)
    nll = logz - picked
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = jnp.float32(nll.size)
    return jnp.sum(nll) / denom


def chunked_vocab_xent(
    h: jnp.ndarray,
    w_unembed: jnp.ndarray,
    labels: jnp.ndarray,
    mask: jnp.ndarray,
    ctx: ParallelCtx,
    *,
    chunk: int = 1024,
) -> jnp.ndarray:
    """Sequence-chunked vocab-parallel loss (§Perf H2 iteration 3).

    The (B, S, v_local) f32 logits are the largest single activation for
    big-vocab archs.  This computes them one seq-chunk at a time under
    jax.checkpoint, so peak logits memory is (B, chunk, v_local); the
    unembed weight is gathered once outside the loop.  Returns mean NLL
    (identical math to vocab_parallel_xent).
    """
    b, s, _ = h.shape
    w = ctx.gather(w_unembed, dim=0)  # (d, v_local)
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)))
    mp = jnp.pad(mask, ((0, 0), (0, pad)))

    @jax.checkpoint
    def body(carry, inp):
        nll_sum, m_sum = carry
        hc, lc, mc = inp  # (B, chunk, ...)
        logits = jnp.einsum(
            "bsd,dv->bsv", hc.astype(jnp.float32), w.astype(jnp.float32)
        )
        v_local = logits.shape[-1]
        start = ctx.tp_index() * v_local
        m = lax.stop_gradient(jnp.max(logits, axis=-1))
        if ctx.tp_size > 1:
            m = lax.pmax(m, ctx.tp_axis)
        z = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)
        if ctx.tp_size > 1:
            z = lax.psum(z, ctx.tp_axis)
        logz = jnp.log(z) + m
        local_label = lc - start
        valid = (local_label >= 0) & (local_label < v_local)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local_label, 0, v_local - 1)[..., None], axis=-1
        )[..., 0]
        picked = ctx.tp_reduce(jnp.where(valid, picked, 0.0))
        nll = (logz - picked) * mc
        return (nll_sum + jnp.sum(nll), m_sum + jnp.sum(mc)), None

    xs = (
        hp.reshape(b, n_chunks, chunk, -1).swapaxes(0, 1),
        lp.reshape(b, n_chunks, chunk).swapaxes(0, 1),
        mp.reshape(b, n_chunks, chunk).swapaxes(0, 1),
    )
    (nll_sum, m_sum), _ = lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), xs)
    return nll_sum / jnp.maximum(m_sum, 1.0)


def gather_logits(logits_local: jnp.ndarray, ctx: ParallelCtx) -> jnp.ndarray:
    """All-gather TP-sharded logits into the full vocab (decode-time only —
    payload is (B, 1, v_local))."""
    if ctx.tp_size == 1:
        return logits_local
    g = lax.all_gather(logits_local, ctx.tp_axis, axis=logits_local.ndim - 1, tiled=True)
    return g
