"""Model configuration covering all assigned architecture families.

One dataclass describes dense GQA, MLA, MoE, SSM (Mamba2/SSD), hybrid
(Mamba2+shared-attention), encoder-decoder, and modality-frontend (VLM /
audio) stacks.  src/repro/configs/<arch>.py instantiate it with the exact
assigned hyperparameters plus a reduced smoke variant.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (MiniCPM3/DeepSeek-V2 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block parameters."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256  # SSD chunk length
    conv_width: int = 4

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # MoE
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0  # hybrid: one (shared) attention block every k layers
    shared_attn: bool = False  # zamba2: the attention block weights are shared
    # MLA
    mla: Optional[MLAConfig] = None
    # enc-dec
    n_enc_layers: int = 0  # family == encdec: encoder depth (n_layers = dec)
    # modality frontend stub: number of prefix embedding positions fed by
    # input_specs() (vision patches / audio frames)
    n_prefix: int = 0
    # attention variant
    sliding_window: int = 0  # 0 = full attention; >0 enables SW variant
    mla_chunk: int = 1024  # flash-chunked MLA; 0 = dense baseline (§Perf H2)
    loss_chunk: int = 0  # seq-chunked vocab loss; 0 = one-shot logits
    # >0 routes the MoE dispatch all_to_all through gz_all_to_all at this eb
    # (beyond-paper; pays at train shapes per benchmarks/moe_a2a_ablation)
    moe_dispatch_gz_eb: float = 0.0
    # use the Pallas flash-attention kernel (kernels/flash_attn.py) instead
    # of the pure-jnp chunked path; interpret-mode on CPU, real kernel on TPU
    use_flash_kernel: bool = False
    # PaLM-style parallel attention+MLP block: ONE TP psum per layer instead
    # of two (halves TP-collective bytes; changes the function — §Perf H3
    # beyond-paper variant, off for the faithful configs)
    parallel_block: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # citation for the assigned config (paper / model card)
    source: str = ""

    def __post_init__(self):
        if self.head_dim is None and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_decoder_only(self) -> bool:
        return self.family in ("dense", "moe", "ssm", "hybrid", "vlm")

    def padded_heads(self, tp: int) -> int:
        """q heads padded up to a multiple of tp (zero-init extras; their
        out-proj rows are zero so the function is unchanged — recorded in
        DESIGN.md hardware-adaptation notes)."""
        return -(-self.n_heads // tp) * tp if self.n_heads else 0

    def padded_vocab(self, quantum: int = 512) -> int:
        return -(-self.vocab // quantum) * quantum

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS = 6*N*D accounting."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim or (d // max(self.n_heads, 1))
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        per_attn = d * n_q + 2 * d * n_kv + n_q * d
        per_mlp = 3 * d * ff
        if self.family == "moe":
            per_mlp *= self.n_experts
        per_layer = per_attn + per_mlp
        if self.family == "ssm":
            di = self.ssm.d_inner(d)
            per_layer = d * (2 * di + 2 * self.ssm.d_state) + di * d + di
        if self.family == "hybrid":
            di = self.ssm.d_inner(d)
            per_layer = d * (2 * di + 2 * self.ssm.d_state) + di * d + di
        total = self.n_layers * per_layer + (self.n_enc_layers or 0) * per_layer
        total += 2 * v * d  # embed + unembed
        return int(total)

    def active_param_count(self) -> int:
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense = self.param_count() - self.n_layers * 3 * d * ff * self.n_experts
        return int(dense + self.n_layers * 3 * d * ff * self.top_k)
