"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 family).

The KV path is compressed into a small latent (kv_lora_rank) plus a
decoupled RoPE key; the decode cache stores ONLY (latent, k_rope) —
(B, S, r + dr) — which is the whole point of MLA.  Heads are TP-sharded
(padded); the latent projections are replicated over TP (they are small).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rope
from repro.models.parallel import ParallelCtx

NEG = -1e30

__all__ = ["mla_train", "mla_decode", "mla_cache_dims"]


def mla_cache_dims(cfg: ModelConfig) -> int:
    m = cfg.mla
    return m.kv_lora_rank + m.qk_rope_head_dim


def _heads_local(cfg: ModelConfig, tp: int) -> int:
    return cfg.padded_heads(tp) // tp


def _project(h, w, cfg: ModelConfig, ctx: ParallelCtx, positions):
    """Common q / latent projections.

    w keys: wq_a (d, q_lora) repl-TP, wq_b (q_lora, hl*(nope+rope) local-TP),
            wkv_a (d, kv_lora + rope_dim) repl-TP,
            wkv_b (kv_lora, hl*(nope+v) local-TP), wo (hl*v local-TP, d).
    """
    m = cfg.mla
    b, s, _ = h.shape
    hl = _heads_local(cfg, ctx.tp_size)
    wq_a = ctx.gather(w["wq_a"], dim=0)
    wq_b = w["wq_b"]  # replicated over the FSDP axis (small) — no gather
    wkv_a = ctx.gather(w["wkv_a"], dim=0)
    q_lat = jnp.einsum("bsd,dr->bsr", h, wq_a)
    q = jnp.einsum("bsr,rh->bsh", q_lat, wq_b).reshape(
        b, s, hl, m.qk_nope_head_dim + m.qk_rope_head_dim
    )
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    kv_all = jnp.einsum("bsd,dr->bsr", h, wkv_a)
    latent, k_rope = jnp.split(kv_all, [m.kv_lora_rank], axis=-1)
    sin, cos = rope(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)  # 1 shared rope head
    return q_nope, q_rope, latent, k_rope


def _attend(q_nope, q_rope, latent, k_rope, w, cfg, ctx, *, causal_offset=None,
            chunk: int = 1024):
    """Latent-space attention: scores from nope+rope parts, values from
    the latent via wkv_b (absorbed).

    Flash-style chunked over the kv/latent length so the (sq, sk) score
    matrix never materializes — at prefill_32k the dense form was 97 s of
    HBM traffic per step (§Perf hillclimb 2); the chunked form is O(sk)
    memory with identical math (running max/sum-exp accumulation).
    """
    m = cfg.mla
    b, sq, hl, _ = q_nope.shape
    sk = latent.shape[1]
    wkv_b = w["wkv_b"]  # (kv_lora, hl*(nope+v)) — replicated over FSDP
    wkv_b = wkv_b.reshape(m.kv_lora_rank, hl, m.qk_nope_head_dim + m.v_head_dim)
    wk_b = wkv_b[..., : m.qk_nope_head_dim]
    wv_b = wkv_b[..., m.qk_nope_head_dim :]
    # absorb k up-projection into q (the MLA trick): q_lat (b,sq,hl,r)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_lat = q_lat * scale
    q_rope = q_rope.astype(jnp.float32) * scale
    kr = k_rope[:, :, 0].astype(jnp.float32)
    lat = latent.astype(jnp.float32)

    if chunk == 0:  # dense baseline (§Perf H2 before-state), kept selectable
        scores = (
            jnp.einsum("bqhr,bkr->bhqk", q_lat, lat)
            + jnp.einsum("bqhr,bkr->bhqk", q_rope, kr)
        )
        if causal_offset is not None:
            qp = causal_offset + jnp.arange(sq)
            mask = jnp.arange(sk)[None, :] <= qp[:, None]
            scores = jnp.where(mask[None, None], scores, NEG)
        p = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhqk,bkr->bqhr", p, lat)
        out = jnp.einsum("bqhr,rhv->bqhv", o_lat, wv_b.astype(jnp.float32))
        wo = ctx.gather(w["wo"], dim=1)
        out = out.reshape(b, sq, hl * m.v_head_dim).astype(wo.dtype)
        return ctx.tp_reduce(jnp.einsum("bsh,hd->bsd", out, wo))

    chunk = min(chunk, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    lat_p = jnp.pad(lat, ((0, 0), (0, pad), (0, 0))).reshape(
        b, n_chunks, chunk, m.kv_lora_rank
    )
    kr_p = jnp.pad(kr, ((0, 0), (0, pad), (0, 0))).reshape(
        b, n_chunks, chunk, m.qk_rope_head_dim
    )
    qpos = (0 if causal_offset is None else causal_offset) + jnp.arange(sq)

    def body(carry, inp):
        mx, s, acc = carry
        lc, kc, c_idx = inp
        kpos = c_idx * chunk + jnp.arange(chunk)
        logits = (
            jnp.einsum("bqhr,bkr->bhqk", q_lat, lc)
            + jnp.einsum("bqhr,bkr->bhqk", q_rope, kc)
        )
        mask = (kpos < sk)[None, :]
        if causal_offset is not None:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        logits = jnp.where(mask[None, None], logits, NEG)
        m_new = jnp.maximum(mx, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(mx - m_new)
        s_new = s * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkr->bhqr", p, lc)
        return (m_new, s_new, acc_new), None

    m0 = jnp.full((b, hl, sq), NEG, jnp.float32)
    s0 = jnp.zeros((b, hl, sq), jnp.float32)
    a0 = jnp.zeros((b, hl, sq, m.kv_lora_rank), jnp.float32)
    (mx, s, acc), _ = jax.lax.scan(
        body, (m0, s0, a0),
        (jnp.moveaxis(lat_p, 1, 0), jnp.moveaxis(kr_p, 1, 0),
         jnp.arange(n_chunks)),
    )
    o_lat = jnp.moveaxis(acc / jnp.maximum(s, 1e-30)[..., None], 1, 2)
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat, wv_b.astype(jnp.float32))
    wo = ctx.gather(w["wo"], dim=1)
    out = out.reshape(b, sq, hl * m.v_head_dim).astype(wo.dtype)
    return ctx.tp_reduce(jnp.einsum("bsh,hd->bsd", out, wo))


def mla_train(h, w, cfg: ModelConfig, ctx: ParallelCtx, *, positions):
    q_nope, q_rope, latent, k_rope = _project(h, w, cfg, ctx, positions)
    return _attend(q_nope, q_rope, latent, k_rope, w, cfg, ctx,
                   causal_offset=0, chunk=cfg.mla_chunk)


def mla_decode(h, w, cache, pos, cfg: ModelConfig, ctx: ParallelCtx):
    """cache: (B, S, r + dr) latent+rope-key cache (replicated over TP —
    it is tiny; that replication is WHY MLA serves cheaply).
    Returns (out, new_cache)."""
    m = cfg.mla
    q_nope, q_rope, latent_new, k_rope_new = _project(h, w, cfg, ctx, pos[None])
    entry = jnp.concatenate([latent_new, k_rope_new[:, :, 0, :]], axis=-1)
    cache = lax.dynamic_update_slice(
        cache, entry.astype(cache.dtype), (0, pos, 0)
    )
    latent = cache[..., : m.kv_lora_rank]
    k_rope = cache[..., m.kv_lora_rank :][:, :, None, :]
    sk = cache.shape[1]
    # mask positions beyond pos via the causal_offset mechanism
    out = _attend(
        q_nope,
        q_rope,
        latent.astype(jnp.float32),
        k_rope.astype(jnp.float32),
        w,
        cfg,
        ctx,
        causal_offset=pos,
        chunk=cfg.mla_chunk,
    )
    return out, cache
