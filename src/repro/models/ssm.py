"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm in pure JAX:
  * within-chunk: quadratic "attention-like" form over the chunk,
  * across chunks: sequential state recurrence via lax.scan (S/chunk steps).

Heads are tensor-parallel over the "model" axis (B/C projections are
group-shared, n_groups=1, replicated); out-proj is row-parallel with a
psum.  Decode carries (conv_state, ssm_state) and is a single recurrence
step — no KV cache, which is what makes long_500k natural for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.parallel import ParallelCtx

__all__ = ["ssm_train", "ssm_decode", "ssm_state_shapes"]


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _softplus(x):
    return jnp.logaddexp(x, 0.0)


def _tp_mean_sq(y: jnp.ndarray, ctx: ParallelCtx) -> jnp.ndarray:
    """Mean of y**2 over the (TP-sharded) last dim, psum'd to the global
    d_inner so every rank normalizes identically."""
    ss = jnp.sum(y * y, axis=-1, keepdims=True)
    n = jnp.float32(y.shape[-1])
    if ctx.tp_size > 1:
        ss = lax.psum(ss, ctx.tp_axis)
        n = n * ctx.tp_size
    return ss / n


def _proj_sizes(cfg: ModelConfig, tp: int):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    h = s.n_heads(cfg.d_model)
    assert h % tp == 0, f"ssm heads {h} must divide tp {tp}"
    h_local = h // tp
    di_local = h_local * s.head_dim
    return di, h, h_local, di_local


def _in_proj(h, w, cfg: ModelConfig, ctx: ParallelCtx):
    """Input projections, each with its own TP layout:

      w_z, w_x:  (d, di)  TP-sharded on the output dim (head-parallel)
      w_bc:      (d, 2*d_state) replicated over TP (group-shared, n_groups=1)
      w_dt:      (d, H)   TP-sharded (per-head dt)

    (A fused in_proj cannot mix sharded and replicated column blocks — this
    split is the TP adaptation recorded in DESIGN.md.)
    Returns local (z, x, B, C, dt).
    """
    s = cfg.ssm
    w_z = ctx.gather(w["w_z"], dim=0)
    w_x = ctx.gather(w["w_x"], dim=0)
    w_bc = ctx.gather(w["w_bc"], dim=0)
    w_dt = ctx.gather(w["w_dt"], dim=0)
    z = jnp.einsum("bsd,dk->bsk", h, w_z)
    xs = jnp.einsum("bsd,dk->bsk", h, w_x)
    bcm = jnp.einsum("bsd,dk->bsk", h, w_bc)
    bmat, cmat = jnp.split(bcm, 2, axis=-1)
    dt = jnp.einsum("bsd,dk->bsk", h, w_dt)
    return z, xs, bmat, cmat, dt


def _conv_step(x_bc, conv_w, conv_state):
    """Depthwise causal conv (width W) one step: x_bc (B, C), state (B, W-1, C)."""
    window = jnp.concatenate([conv_state, x_bc[:, None, :]], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", window, conv_w)
    return _silu(out), window[:, 1:, :]


def _conv_seq(x, conv_w):
    """Causal depthwise conv over a sequence: x (B, S, C), conv_w (W, C)."""
    w = conv_w.shape[0]
    pads = [jnp.pad(x, ((0, 0), (w - 1 - i, 0), (0, 0)))[:, : x.shape[1], :]
            for i in range(w)]
    out = sum(p * conv_w[i] for i, p in enumerate(pads))
    return _silu(out)


def ssm_train(h, w, cfg: ModelConfig, ctx: ParallelCtx):
    """Full-sequence SSD. h: (B, S, d_model) -> (B, S, d_model).

    w: {"w_in": (d, K_local), "conv": (W, conv_ch_local), "A_log": (h_local,),
        "D": (h_local,), "dt_bias": (h_local,), "norm": (di_local,),
        "w_out": (di_local, d)}
    """
    s = cfg.ssm
    b, slen, _ = h.shape
    _, _, h_local, di_local = _proj_sizes(cfg, ctx.tp_size)
    p = s.head_dim
    n = s.d_state
    z, xs, bmat, cmat, dt = _in_proj(h, w, cfg, ctx)
    # depthwise conv over (x | B | C) channels; conv_x is TP-local,
    # conv_bc replicated — concat matches the channel layout
    conv_w = jnp.concatenate([w["conv_x"], w["conv_bc"]], axis=1)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    xbc = _conv_seq(xbc, conv_w)
    xs, bmat, cmat = jnp.split(xbc, [di_local, di_local + n], axis=-1)
    x = xs.reshape(b, slen, h_local, p)
    dt = _softplus(dt.astype(jnp.float32) + w["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(w["A_log"].astype(jnp.float32))  # (h_local,)
    da = dt * a  # (B, S, h_local) negative

    q = s.chunk
    n_chunks = -(-slen // q)
    pad = n_chunks * q - slen

    def padq(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    xc = padq(x).reshape(b, n_chunks, q, h_local, p)
    bc = padq(bmat).reshape(b, n_chunks, q, n).astype(jnp.float32)
    cc = padq(cmat).reshape(b, n_chunks, q, n).astype(jnp.float32)
    dac = padq(da).reshape(b, n_chunks, q, h_local)
    dtc = padq(dt).reshape(b, n_chunks, q, h_local)

    lc = jnp.cumsum(dac, axis=2)  # within-chunk cumulative log decay
    # within-chunk (diagonal block) term.  Mask BEFORE the exp: for j > i
    # the exponent lc_i - lc_j = -sum(da over (i, j]) is >= 0 and grows
    # with the decay magnitude, so exp overflows to inf once the trained
    # dt/A push any within-chunk decay past ~88 — and inf * 0 (the causal
    # mask) is NaN, which is exactly the mamba2 step-3 divergence.  With
    # -inf substituted first, exp gives an exact 0 and the masked entries
    # contribute nothing to value or gradient.
    iota_i = jnp.arange(q)
    causal = iota_i[:, None] >= iota_i[None, :]
    seg = lc[:, :, :, None, :] - lc[:, :, None, :, :]  # (b, nc, q_i, q_j, h)
    att = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -jnp.inf))
    cb = jnp.einsum("bkin,bkjn->bkij", cc, bc)  # (b, nc, q, q)
    w_att = cb[:, :, :, :, None] * att
    y_diag = jnp.einsum(
        "bkijh,bkjh,bkjhp->bkihp", w_att, dtc, xc.astype(jnp.float32)
    )

    # chunk-local end states: (b, nc, h, p, n)
    decay_to_end = jnp.exp(lc[:, :, -1:, :] - lc)  # (b, nc, q, h)
    s_loc = jnp.einsum(
        "bkjh,bkjh,bkjhp,bkjn->bkhpn",
        decay_to_end,
        dtc,
        xc.astype(jnp.float32),
        bc,
    )
    chunk_decay = jnp.exp(jnp.sum(dac, axis=2))  # (b, nc, h)

    def scan_body(state, inp):
        s_local, dec = inp  # (b, h, p, n), (b, h)
        new = state * dec[:, :, None, None] + s_local
        return new, state  # emit the state ENTERING this chunk

    init = jnp.zeros((b, h_local, p, n), jnp.float32)
    _, s_in = lax.scan(
        scan_body,
        init,
        (jnp.moveaxis(s_loc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    s_in = jnp.moveaxis(s_in, 0, 1)  # (b, nc, h, p, n) state before chunk
    y_inter = jnp.einsum(
        "bkin,bkih,bkhpn->bkihp", cc, jnp.exp(lc), s_in
    )
    y = y_diag + y_inter  # (b, nc, q, h, p)
    y = y.reshape(b, n_chunks * q, h_local, p)[:, :slen]
    y = y + w["D"].astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, slen, di_local)
    # gated RMSNorm (Mamba2 style) — d_inner is TP-sharded, so the second
    # moment needs a psum to match the single-device model
    y = y * _silu(z.astype(jnp.float32))
    var = _tp_mean_sq(y, ctx)
    y = y * lax.rsqrt(var + cfg.norm_eps) * w["norm"].astype(jnp.float32)
    w_out = ctx.gather(w["w_out"], dim=1)
    out = jnp.einsum("bsk,kd->bsd", y.astype(h.dtype), w_out)
    return ctx.tp_reduce(out)


def ssm_state_shapes(cfg: ModelConfig, tp: int, batch_local: int):
    """Decode-cache shapes per layer: (conv_state, ssm_state)."""
    s = cfg.ssm
    _, _, h_local, di_local = _proj_sizes(cfg, tp)
    conv_ch = di_local + 2 * s.d_state
    return (
        (batch_local, s.conv_width - 1, conv_ch),
        (batch_local, h_local, s.head_dim, s.d_state),
    )


def ssm_decode(h, w, conv_state, ssm_state, cfg: ModelConfig, ctx: ParallelCtx):
    """One-token SSD recurrence. h: (B, 1, d). Returns (out, new_conv, new_ssm)."""
    s = cfg.ssm
    b = h.shape[0]
    _, _, h_local, di_local = _proj_sizes(cfg, ctx.tp_size)
    p, n = s.head_dim, s.d_state
    z, xs, bmat, cmat, dt = _in_proj(h, w, cfg, ctx)
    conv_w = jnp.concatenate([w["conv_x"], w["conv_bc"]], axis=1)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)[:, 0]  # (B, C)
    xbc, new_conv = _conv_step(xbc, conv_w, conv_state)
    xs, bmat, cmat = jnp.split(xbc, [di_local, di_local + n], axis=-1)
    x = xs.reshape(b, h_local, p).astype(jnp.float32)
    dt = _softplus(dt[:, 0].astype(jnp.float32) + w["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(w["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)  # (B, h_local)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)
    new_ssm = ssm_state * da[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, x, bmat
    )
    y = jnp.einsum("bn,bhpn->bhp", cmat, new_ssm)
    y = y + w["D"].astype(jnp.float32)[None, :, None] * x
    y = y.reshape(b, 1, di_local)
    y = y * _silu(z.astype(jnp.float32))
    var = _tp_mean_sq(y, ctx)
    y = y * lax.rsqrt(var + cfg.norm_eps) * w["norm"].astype(jnp.float32)
    w_out = ctx.gather(w["w_out"], dim=1)
    out = jnp.einsum("bsk,kd->bsd", y.astype(h.dtype), w_out)
    return ctx.tp_reduce(out), new_conv, new_ssm
