"""Model assembly: parameter trees, train loss, and one-token decode for
every assigned architecture family.

All apply code is rank-centric shard_map body code.  Layer stacks are
``lax.scan`` over stacked parameters (leading L dim) with optional remat —
required to keep 95-layer compiles tractable.

Cache layout notes (decode):
  * attention kv:   (L, B, S_loc, kv_eff, hd)   S_loc context-parallel when
                    the batch cannot fill the data axis (KVCacheSpec)
  * MLA latent:     (L, B, S, r + rope_dim)     tiny, replicated over TP
  * SSD state:      (L, B, H_loc, p, n) + conv states (x | bc split because
                    their TP layouts differ)
  * hybrid:         SSD caches + one kv cache per shared-attn application
  * enc-dec:        decoder self kv + the encoder output (cross-attention
                    recomputes k/v from it — S_enc is small)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import attention, blocks, mla as mla_mod, moe as moe_mod, ssm as ssm_mod
from repro.models.attention import KVCacheSpec
from repro.models.config import ModelConfig
from repro.models.layers import (
    chunked_vocab_xent,
    embed_lookup,
    gather_logits,
    rms_norm,
    vocab_parallel_logits,
    vocab_parallel_xent,
)
from repro.models.parallel import ParallelCtx, ParamDef

MOE_AUX_COEF = 0.01


def _stack(defs, L: int):
    """Add a leading stacked-layer dim to every ParamDef in a tree."""

    def one(d: ParamDef) -> ParamDef:
        return dataclasses.replace(
            d, shape=(L,) + d.shape, spec=P(*((None,) + tuple(d.spec)))
        )

    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _norm(cfg):
    return blocks.norm_def(cfg)


class Model:
    """One class covers all families; family dispatch is internal."""

    def __init__(self, cfg: ModelConfig, ctx: ParallelCtx):
        self.cfg = cfg
        self.ctx = ctx

    # ---------------- parameter definitions ----------------

    def _block_defs(self, *, cross: bool = False) -> dict:
        cfg, tp = self.cfg, self.ctx.tp_size
        fam = cfg.family
        if fam in ("dense", "vlm", "audio", "encdec"):
            d = {
                "ln1": _norm(cfg),
                "ln2": _norm(cfg),
                "attn": blocks.attn_defs(cfg, tp),
                "mlp": blocks.mlp_defs(cfg),
            }
            if cfg.mla is not None:
                d = {
                    "ln1": _norm(cfg),
                    "ln2": _norm(cfg),
                    "mla": blocks.mla_defs(cfg, tp),
                    "mlp": blocks.mlp_defs(cfg),
                }
            if cross:
                d["ln_cross"] = _norm(cfg)
                d["cross"] = blocks.attn_defs(cfg, tp)
            return d
        if fam == "moe":
            return {
                "ln1": _norm(cfg),
                "ln2": _norm(cfg),
                "attn": blocks.attn_defs(cfg, tp),
                "moe": blocks.moe_defs(cfg),
            }
        if fam == "ssm":
            return {"ln1": _norm(cfg), "ssm": blocks.ssm_defs(cfg)}
        if fam == "hybrid":
            return {"ln1": _norm(cfg), "ssm": blocks.ssm_defs(cfg)}
        raise ValueError(fam)

    def param_defs(self) -> dict:
        cfg = self.cfg
        v = cfg.padded_vocab()
        d = cfg.d_model
        defs: dict[str, Any] = {
            "embed": ParamDef((v, d), P("model", "data"), init="normal"),
            "unembed": ParamDef((d, v), P("data", "model"), init="scaled"),
            "final_norm": _norm(cfg),
            "blocks": _stack(self._block_defs(cross=cfg.family == "encdec"),
                             cfg.n_layers),
        }
        if cfg.family == "encdec":
            enc = {
                "ln1": _norm(cfg),
                "ln2": _norm(cfg),
                "attn": blocks.attn_defs(cfg, self.ctx.tp_size),
                "mlp": blocks.mlp_defs(cfg),
            }
            defs["enc_blocks"] = _stack(enc, cfg.n_enc_layers)
            defs["enc_norm"] = _norm(cfg)
        if cfg.family == "hybrid" and cfg.attn_every:
            # zamba2: ONE shared attention+mlp block applied every k layers
            defs["shared_attn"] = {
                "ln1": _norm(cfg),
                "ln2": _norm(cfg),
                "attn": blocks.attn_defs(cfg, self.ctx.tp_size),
                "mlp": blocks.mlp_defs(cfg),
            }
        return defs

    # ---------------- training forward / loss ----------------

    def _scan(self, h, stacked, body, with_aux: bool = False):
        ctx = self.ctx

        def f(carry, wl):
            if with_aux:
                out, aux = body(carry, wl)
                return out, aux
            return body(carry, wl), None

        if ctx.remat != "none":
            f = jax.checkpoint(f)
        h, auxs = lax.scan(f, h, stacked, unroll=ctx.scan_unroll)
        return (h, jnp.sum(auxs)) if with_aux else (h, None)

    def _backbone(self, h, params, *, positions, window=0, cross_kv=None):
        """Run the decoder/backbone stack over hidden states h."""
        cfg, ctx = self.cfg, self.ctx
        fam = cfg.family
        aux = jnp.float32(0.0)
        if fam in ("dense", "vlm", "audio") and cfg.mla is None:
            h, _ = self._scan(
                h,
                params["blocks"],
                lambda hh, wl: blocks.dense_block(
                    hh, wl, cfg, ctx, positions=positions, window=window
                ),
            )
        elif cfg.mla is not None:
            h, _ = self._scan(
                h,
                params["blocks"],
                lambda hh, wl: blocks.mla_block(hh, wl, cfg, ctx, positions=positions),
            )
        elif fam == "moe":
            h, aux = self._scan(
                h,
                params["blocks"],
                lambda hh, wl: blocks.moe_block(
                    hh, wl, cfg, ctx, positions=positions, window=window
                ),
                with_aux=True,
            )
        elif fam == "ssm":
            h, _ = self._scan(
                h, params["blocks"], lambda hh, wl: blocks.ssm_block(hh, wl, cfg, ctx)
            )
        elif fam == "hybrid":
            h = self._hybrid_train(h, params, positions=positions, window=window)
        elif fam == "encdec":
            h, _ = self._scan(
                h,
                params["blocks"],
                lambda hh, wl: blocks.dense_block(
                    hh, wl, cfg, ctx, positions=positions, cross_kv=cross_kv
                ),
            )
        else:
            raise ValueError(fam)
        return h, aux

    def _hybrid_train(self, h, params, *, positions, window=0):
        cfg, ctx = self.cfg, self.ctx
        k = cfg.attn_every
        n_groups = cfg.n_layers // k
        sa = params["shared_attn"]
        for g in range(n_groups):
            grp = jax.tree.map(lambda p: p[g * k : (g + 1) * k], params["blocks"])
            h, _ = self._scan(
                h, grp, lambda hh, wl: blocks.ssm_block(hh, wl, cfg, ctx)
            )
            h = blocks.dense_block(
                h, sa, cfg, ctx, positions=positions, window=window
            )
        rem = cfg.n_layers - n_groups * k
        if rem:
            grp = jax.tree.map(lambda p: p[-rem:], params["blocks"])
            h, _ = self._scan(
                h, grp, lambda hh, wl: blocks.ssm_block(hh, wl, cfg, ctx)
            )
        return h

    def _encode(self, params, enc_input):
        cfg, ctx = self.cfg, self.ctx
        positions = jnp.arange(enc_input.shape[1])
        h, _ = self._scan(
            enc_input.astype(jnp.dtype(cfg.dtype)),
            params["enc_blocks"],
            lambda hh, wl: blocks.dense_block(
                hh, wl, cfg, ctx, positions=positions, causal=False
            ),
        )
        return rms_norm(h, params["enc_norm"], cfg.norm_eps)

    def loss_fn(self, params, batch) -> jnp.ndarray:
        """batch: tokens (B,S), labels (B,S) [-1 = masked], optional
        prefix (B,n_prefix,d) [vlm/audio], enc_input (B,S_enc,d) [encdec]."""
        cfg, ctx = self.cfg, self.ctx
        tokens = batch["tokens"]
        h = embed_lookup(tokens, params["embed"], ctx)
        cross_kv = None
        if cfg.family == "encdec":
            cross_kv = self._encode(params, batch["enc_input"])
        if cfg.n_prefix and cfg.family in ("vlm", "audio"):
            prefix = batch["prefix"].astype(h.dtype)
            h = jnp.concatenate([prefix, h], axis=1)
        positions = jnp.arange(h.shape[1])
        h, aux = self._backbone(
            h, params, positions=positions, cross_kv=cross_kv,
            window=cfg.sliding_window if cfg.sliding_window else 0,
        )
        if cfg.n_prefix and cfg.family in ("vlm", "audio"):
            h = h[:, cfg.n_prefix :]
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        if cfg.loss_chunk:
            loss = chunked_vocab_xent(
                h, params["unembed"], jnp.maximum(labels, 0), mask, ctx,
                chunk=cfg.loss_chunk,
            )
        else:
            logits = vocab_parallel_logits(h, params["unembed"], ctx)
            loss = vocab_parallel_xent(logits, jnp.maximum(labels, 0), ctx,
                                       mask=mask)
        if cfg.family == "moe":
            loss = loss + MOE_AUX_COEF * aux / cfg.n_layers
        return loss

    # ---------------- costing hooks (see launch/costing.py) ----------------

    def block_apply(self, h, wl, *, positions, kind: str = "main"):
        """Apply ONE layer (family dispatch) — used by the dry-run's
        differential scan-body costing (XLA counts while bodies once)."""
        cfg, ctx = self.cfg, self.ctx
        if kind == "enc":
            return blocks.dense_block(h, wl, cfg, ctx, positions=positions,
                                      causal=False)
        if cfg.family == "encdec":
            # cross_kv the same length as the encoder output
            cross = jnp.zeros((h.shape[0], cfg.n_prefix or 128, cfg.d_model),
                              h.dtype)
            return blocks.dense_block(h, wl, cfg, ctx, positions=positions,
                                      cross_kv=cross)
        if cfg.mla is not None:
            return blocks.mla_block(h, wl, cfg, ctx, positions=positions)
        if cfg.family == "moe":
            out, _ = blocks.moe_block(h, wl, cfg, ctx, positions=positions)
            return out
        if cfg.family in ("ssm", "hybrid"):
            return blocks.ssm_block(h, wl, cfg, ctx)
        return blocks.dense_block(h, wl, cfg, ctx, positions=positions,
                                  window=cfg.sliding_window)

    def scan_trip_counts(self) -> list:
        """[(kind, trip_count, bodies_in_program)] for cost correction."""
        cfg = self.cfg
        if cfg.family == "hybrid":
            k = cfg.attn_every
            n_groups = cfg.n_layers // k
            return [("main", k, n_groups)]
        out = [("main", cfg.n_layers, 1)]
        if cfg.family == "encdec":
            out.append(("enc", cfg.n_enc_layers, 1))
        return out

    def block_defs_for(self, kind: str) -> dict:
        if kind == "enc":
            return {
                "ln1": _norm(self.cfg),
                "ln2": _norm(self.cfg),
                "attn": blocks.attn_defs(self.cfg, self.ctx.tp_size),
                "mlp": blocks.mlp_defs(self.cfg),
            }
        return self._block_defs(cross=self.cfg.family == "encdec")

    # ---------------- decode (one token) ----------------

    def cache_defs(self, batch_local: int, spec: KVCacheSpec) -> dict:
        """LOCAL cache shapes (the launcher maps them to global + specs)."""
        cfg, tp = self.cfg, self.ctx.tp_size
        L = cfg.n_layers
        hd = cfg.head_dim
        kvl = attention.kv_local_heads(cfg, tp)
        sl = spec.s_local
        out: dict[str, Any] = {}
        if cfg.mla is not None:
            out["mla"] = (L, batch_local, spec.s_total, mla_mod.mla_cache_dims(cfg))
            return out
        if cfg.family in ("dense", "vlm", "audio", "moe"):
            out["k"] = (L, batch_local, sl, kvl, hd)
            out["v"] = (L, batch_local, sl, kvl, hd)
            return out
        if cfg.family == "ssm":
            conv, state = ssm_mod.ssm_state_shapes(cfg, tp, batch_local)
            di_l = cfg.ssm.d_inner(cfg.d_model) // tp
            out["conv_x"] = (L,) + conv[:-1] + (di_l,)
            out["conv_bc"] = (L,) + conv[:-1] + (2 * cfg.ssm.d_state,)
            out["ssm"] = (L,) + state
            return out
        if cfg.family == "hybrid":
            conv, state = ssm_mod.ssm_state_shapes(cfg, tp, batch_local)
            di_l = cfg.ssm.d_inner(cfg.d_model) // tp
            n_groups = cfg.n_layers // cfg.attn_every
            out["conv_x"] = (L,) + conv[:-1] + (di_l,)
            out["conv_bc"] = (L,) + conv[:-1] + (2 * cfg.ssm.d_state,)
            out["ssm"] = (L,) + state
            out["k"] = (n_groups, batch_local, sl, kvl, hd)
            out["v"] = (n_groups, batch_local, sl, kvl, hd)
            return out
        if cfg.family == "encdec":
            out["k"] = (L, batch_local, sl, kvl, hd)
            out["v"] = (L, batch_local, sl, kvl, hd)
            out["enc_out"] = (batch_local, cfg.n_prefix or 128, cfg.d_model)
            return out
        raise ValueError(cfg.family)

    def decode_fn(self, params, cache, tokens, pos, spec: KVCacheSpec):
        """One decode step.  tokens: (B, 1) int32; pos: scalar int32.

        Returns (logits (B, 1, V_pad), new_cache).
        """
        cfg, ctx = self.cfg, self.ctx
        h = embed_lookup(tokens, params["embed"], ctx)
        fam = cfg.family

        def attn_layer(hh, wl, ck, cv):
            a, nk, nv = attention.attention_decode(
                rms_norm(hh, wl["ln1"], cfg.norm_eps), wl["attn"], ck, cv,
                pos, cfg, ctx, spec,
            )
            return hh + a, nk, nv

        new_cache = dict(cache)
        if fam in ("dense", "vlm", "audio", "moe") and cfg.mla is None:

            def step(hh, xs):
                wl, ck, cv = xs
                hh, nk, nv = attn_layer(hh, wl, ck, cv)
                if fam == "moe":
                    m, _ = moe_mod.moe_ffn(
                        rms_norm(hh, wl["ln2"], cfg.norm_eps), wl["moe"], cfg, ctx
                    )
                else:
                    m = blocks._mlp(
                        rms_norm(hh, wl["ln2"], cfg.norm_eps), wl["mlp"], ctx
                    )
                return hh + m, (nk, nv)

            h, (nk, nv) = lax.scan(
                step, h, (params["blocks"], cache["k"], cache["v"]),
                unroll=ctx.scan_unroll,
            )
            new_cache["k"], new_cache["v"] = nk, nv
        elif cfg.mla is not None:

            def step(hh, xs):
                wl, cl = xs
                a, ncl = mla_mod.mla_decode(
                    rms_norm(hh, wl["ln1"], cfg.norm_eps), wl["mla"], cl, pos,
                    cfg, ctx,
                )
                hh = hh + a
                m = blocks._mlp(rms_norm(hh, wl["ln2"], cfg.norm_eps), wl["mlp"], ctx)
                return hh + m, ncl

            h, ncl = lax.scan(step, h, (params["blocks"], cache["mla"]),
                              unroll=ctx.scan_unroll)
            new_cache["mla"] = ncl
        elif fam == "ssm":

            def step(hh, xs):
                wl, cx, cbc, cs = xs
                di_l = cx.shape[-1]
                y, nconv, nssm = ssm_mod.ssm_decode(
                    rms_norm(hh, wl["ln1"], cfg.norm_eps), wl["ssm"],
                    jnp.concatenate([cx, cbc], axis=-1), cs, cfg, ctx,
                )
                return hh + y, (nconv[..., :di_l], nconv[..., di_l:], nssm)

            h, (ncx, ncbc, nssm) = lax.scan(
                step, h,
                (params["blocks"], cache["conv_x"], cache["conv_bc"], cache["ssm"]),
                unroll=ctx.scan_unroll,
            )
            new_cache["conv_x"], new_cache["conv_bc"], new_cache["ssm"] = (
                ncx, ncbc, nssm,
            )
        elif fam == "hybrid":
            h, new_cache = self._hybrid_decode(params, cache, h, pos, spec)
        elif fam == "encdec":
            enc_out = cache["enc_out"].astype(h.dtype)

            def step(hh, xs):
                wl, ck, cv = xs
                hh, nk, nv = attn_layer(hh, wl, ck, cv)
                c = attention.attention_train(
                    rms_norm(hh, wl["ln_cross"], cfg.norm_eps), wl["cross"],
                    cfg, ctx, positions=pos[None], causal=False,
                    cross_kv=enc_out,
                )
                hh = hh + c
                m = blocks._mlp(rms_norm(hh, wl["ln2"], cfg.norm_eps), wl["mlp"], ctx)
                return hh + m, (nk, nv)

            h, (nk, nv) = lax.scan(
                step, h, (params["blocks"], cache["k"], cache["v"]),
                unroll=ctx.scan_unroll,
            )
            new_cache["k"], new_cache["v"] = nk, nv
        else:
            raise ValueError(fam)

        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = vocab_parallel_logits(h, params["unembed"], ctx)
        return gather_logits(logits, ctx), new_cache

    def _hybrid_decode(self, params, cache, h, pos, spec: KVCacheSpec):
        cfg, ctx = self.cfg, self.ctx
        k = cfg.attn_every
        n_groups = cfg.n_layers // k
        sa = params["shared_attn"]
        new_cache = dict(cache)
        ncx, ncbc, nssm = [], [], []
        nk, nv = [], []

        def ssm_step(hh, xs):
            wl, cx, cbc, cs = xs
            di_l = cx.shape[-1]
            y, nconv, nss = ssm_mod.ssm_decode(
                rms_norm(hh, wl["ln1"], cfg.norm_eps), wl["ssm"],
                jnp.concatenate([cx, cbc], axis=-1), cs, cfg, ctx,
            )
            return hh + y, (nconv[..., :di_l], nconv[..., di_l:], nss)

        for g in range(n_groups):
            sl = slice(g * k, (g + 1) * k)
            grp = jax.tree.map(lambda p: p[sl], params["blocks"])
            h, (cx, cbc, cs) = lax.scan(
                ssm_step, h,
                (grp, cache["conv_x"][sl], cache["conv_bc"][sl], cache["ssm"][sl]),
                unroll=ctx.scan_unroll,
            )
            ncx.append(cx)
            ncbc.append(cbc)
            nssm.append(cs)
            a, gk, gv = attention.attention_decode(
                rms_norm(h, sa["ln1"], cfg.norm_eps), sa["attn"],
                cache["k"][g], cache["v"][g], pos, cfg, ctx, spec,
            )
            h = h + a
            m = blocks._mlp(rms_norm(h, sa["ln2"], cfg.norm_eps), sa["mlp"], ctx)
            h = h + m
            nk.append(gk)
            nv.append(gv)
        rem = cfg.n_layers - n_groups * k
        if rem:
            grp = jax.tree.map(lambda p: p[-rem:], params["blocks"])
            h, (cx, cbc, cs) = lax.scan(
                ssm_step, h,
                (grp, cache["conv_x"][-rem:], cache["conv_bc"][-rem:],
                 cache["ssm"][-rem:]),
                unroll=ctx.scan_unroll,
            )
            ncx.append(cx)
            ncbc.append(cbc)
            nssm.append(cs)
        new_cache["conv_x"] = jnp.concatenate(ncx)
        new_cache["conv_bc"] = jnp.concatenate(ncbc)
        new_cache["ssm"] = jnp.concatenate(nssm)
        new_cache["k"] = jnp.stack(nk)
        new_cache["v"] = jnp.stack(nv)
        return h, new_cache
