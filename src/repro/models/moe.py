"""Mixture-of-Experts layer with expert parallelism over the TP axis.

Top-k routing with capacity (Switch/GShard style), einsum dispatch, and
``lax.all_to_all`` over the "model" axis to ship token slots to their
expert's rank (experts_per_rank = E / tp).  The router's load-balance aux
loss is returned to the caller.

Note on gZCCL applicability (DESIGN.md §4): the dispatch all_to_all stays
uncompressed by default; the size-dependent ablation
(benchmarks/moe_a2a_ablation.py) shows compression pays at train shapes
and hurts at decode — pass a ``dispatch_comm=GZCommunicator(...)`` bound
to the TP axis to route the dispatch through the compressed all-to-all
(one lossy hop, eb control, plan resolved once per payload shape).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.comm import GZCommunicator
from repro.models.config import ModelConfig
from repro.models.parallel import ParallelCtx

__all__ = ["moe_ffn", "moe_capacity"]


def moe_capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(-(-cap // 8) * 8, 8)


def _silu(x):
    return x * jax.nn.sigmoid(x)


def moe_ffn(
    h: jnp.ndarray,
    w: dict,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    dispatch_comm: Optional[GZCommunicator] = None,
):
    """h: (B, S, d) local tokens.

    w: {"router": (d, E) replicated-TP / FSDP dim0,
        "wi", "wg": (E_local, d, ff), "wo": (E_local, ff, d)} — expert
    weights sharded over TP on the EXPERT dim (expert parallel), FSDP on d.
    Returns (out (B,S,d), aux_loss scalar).
    """
    b, s, d = h.shape
    e = cfg.n_experts
    tp = ctx.tp_size
    assert e % tp == 0, f"experts {e} must divide over tp {tp}"
    e_local = e // tp
    t_full = b * s
    x_full = h.reshape(t_full, d)
    # Token slicing: activations are replicated over TP, so each TP rank
    # routes only its 1/tp slice (otherwise every expert would process each
    # token tp times — a 16x useful-flops bug caught by the dry-run).
    if tp > 1:
        t_pad = -(-t_full // tp) * tp  # decode can have t_full < tp
        if t_pad != t_full:
            x_full = jnp.concatenate(
                [x_full, jnp.zeros((t_pad - t_full, d), x_full.dtype)], axis=0
            )
        t = t_pad // tp
        start = ctx.tp_index() * t
        x = lax.dynamic_slice_in_dim(x_full, start, t, axis=0)
    else:
        t = t_full
        x = x_full

    router = ctx.gather(w["router"], dim=0)  # (d, E)
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k gating with capacity
    cap = moe_capacity(t, cfg)
    gate_vals, gate_idx = lax.top_k(probs, cfg.top_k)  # (t, k)
    if cfg.top_k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # scatter/gather dispatch — O(t*k) memory, never materializes a
    # (t, e, cap) tensor (that is 5e12 elements at production scale)
    tk = t * cfg.top_k
    e_flat = gate_idx.reshape(tk)  # expert of each (token, k) slot
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.float32)  # (tk, e) — small
    pos_all = jnp.cumsum(onehot, axis=0) - 1.0  # position counters per expert
    pos = jnp.take_along_axis(pos_all, e_flat[:, None], axis=1)[:, 0]  # (tk,)
    keep = pos < cap
    pos = jnp.where(keep, pos, cap - 1).astype(jnp.int32)
    tok_idx = jnp.arange(tk) // cfg.top_k
    gate_flat = gate_vals.reshape(tk) * keep.astype(gate_vals.dtype)

    expert_in = jnp.zeros((e, cap, d), jnp.float32)
    expert_in = expert_in.at[e_flat, pos].add(
        x.astype(jnp.float32)[tok_idx] * keep[:, None].astype(jnp.float32)
    )

    if tp > 1:
        # ship slots to expert owners: (e, cap, d) -> (e_local, tp*cap, d)
        # (tiled: split the expert dim across ranks, stack received slots
        # along the capacity dim in rank order).  With dispatch_comm the
        # payload goes through the compressed all-to-all (the ablation in
        # benchmarks/moe_a2a_ablation.py models a ~1.7x win at train
        # shapes; exactly one lossy hop with eb control).
        if dispatch_comm is not None and e_local == 1:
            expert_in = dispatch_comm.all_to_all(
                expert_in.reshape(tp, cap * d)
            ).value.reshape(e_local, tp * cap, d)
        else:
            expert_in = lax.all_to_all(
                expert_in, ctx.tp_axis, split_axis=0, concat_axis=1, tiled=True
            )
    else:
        expert_in = expert_in.reshape(e_local, cap, d)

    wi = ctx.gather(w["wi"], dim=1)  # (e_local, d, ff)
    wg = ctx.gather(w["wg"], dim=1)
    wo = ctx.gather(w["wo"], dim=2)  # (e_local, ff, d)
    hmid = _silu(jnp.einsum("ecd,edf->ecf", expert_in, wg.astype(jnp.float32)))
    hmid = hmid * jnp.einsum("ecd,edf->ecf", expert_in, wi.astype(jnp.float32))
    expert_out = jnp.einsum("ecf,efd->ecd", hmid, wo.astype(jnp.float32))

    if tp > 1:
        if dispatch_comm is not None and e_local == 1:
            expert_out = dispatch_comm.all_to_all(
                expert_out.reshape(tp, cap * d)
            ).value.reshape(e, cap, d)
        else:
            expert_out = lax.all_to_all(
                expert_out, ctx.tp_axis, split_axis=1, concat_axis=0, tiled=True
            )
    else:
        expert_out = expert_out.reshape(e, cap, d)

    y_slots = expert_out[e_flat, pos]  # (tk, d) gather back
    y = (y_slots * gate_flat[:, None]).reshape(t, cfg.top_k, d).sum(axis=1)
    if tp > 1:
        # reassemble the full token range from the per-rank slices
        y = lax.all_gather(y, ctx.tp_axis, axis=0, tiled=True)[:t_full]
    out = y.reshape(b, s, d)

    # Switch-style load-balance loss (top-1 assignment share vs router mass)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out.astype(h.dtype), aux
