"""Minimal sharded-pytree checkpointing (local filesystem, npz-per-leaf).

Saves each leaf as a .npy under a directory keyed by its tree path, plus a
manifest.  Works for params + optimizer state + step counters.  Restore
validates shapes/dtypes against the live tree.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step"]


def _key(path) -> str:
    s = jax.tree_util.keystr(path)
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", s).strip("_")


def save(ckpt_dir: str, step: int, tree) -> str:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {}
    for path, leaf in leaves:
        k = _key(path)
        arr = np.asarray(leaf)
        dtype = str(arr.dtype)
        if dtype == "bfloat16":  # numpy can't round-trip ml_dtypes natively
            arr = arr.view(np.uint16)
        np.save(os.path.join(d, k + ".npy"), arr)
        manifest[k] = {"shape": list(arr.shape), "dtype": dtype}
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f, indent=1)
    return d


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir) if n.startswith("step_")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like):
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]
    import ml_dtypes

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in paths:
        k = _key(path)
        arr = np.load(os.path.join(d, k + ".npy"))
        if manifest[k]["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        want = tuple(np.shape(leaf))
        assert tuple(arr.shape) == want, f"{k}: ckpt {arr.shape} != live {want}"
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
