"""Static schedule-authority check (ISSUE 10 satellite).

``core/schedule.py`` is THE source of every ``lax.ppermute`` perm: the
builders author (sender, receiver) routes once, ``Schedule.perm(k)`` /
``schedule.ring_perm`` / ``schedule.tree_plan`` hand them to the execute
layer, and the generic walkers forward them as opaque values.  This
script fails CI if anyone reintroduces an ad-hoc route — the drift class
the Schedule IR exists to make structurally impossible.

Two AST rules over ``src/repro`` (``core/schedule.py`` itself exempt):

  1. a ``ppermute(...)`` call whose perm argument (3rd positional or
     ``perm=`` keyword) is CONSTRUCTED AT THE CALL SITE — a list/tuple
     display, comprehension, or generator — instead of a name flowing
     from the schedule module;
  2. an assignment binding a name matching ``perm``/``*_perm``/``perms``
     to such an inline construction.

Constructions that merely REPACKAGE authority output — they reference
``sched``/``schedule`` or its route accessors (``perm``, ``ring_perm``,
``tree_plan``, ...) inside, e.g. ``[sched.perm(k) for k in range(s)]``
— are clean: wrapping is not authoring.

A deliberate exception (currently only the PR 4 padded-tree byte-parity
oracle in collectives.py) carries the allowlist comment

    # schedule-authority: allow — <reason>

on the offending line or one of the two lines above it.

Usage: python scripts/check_schedule_authority.py [--root src/repro]
Exit 0 when clean; exit 1 listing every violation.
"""
from __future__ import annotations

import argparse
import ast
import pathlib
import re
import sys

ALLOW = "schedule-authority: allow"
AUTHORITY = "core/schedule.py"  # the one module allowed to author routes

INLINE_NODES = (ast.List, ast.Tuple, ast.ListComp, ast.GeneratorExp,
                ast.SetComp)
PERM_NAME = re.compile(r"(^|_)perms?$")


def _is_inline_perm(node: ast.AST) -> bool:
    """Constructed-at-the-call-site route values: displays/comprehensions
    (possibly wrapped in a tuple()/list() cast or concatenated)."""
    if isinstance(node, INLINE_NODES):
        return True
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        return _is_inline_perm(node.left) or _is_inline_perm(node.right)
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("list", "tuple", "sorted", "reversed"):
        return bool(node.args) and _is_inline_perm(node.args[0])
    return False


_AUTHORITY_NAMES = {"sched", "schedule"}
_AUTHORITY_ATTRS = {"perm", "ring_perm", "tree_plan", "binomial_slab_table",
                    "redoub_layout", "rounds", "route_table"}


def _flows_from_authority(node: ast.AST) -> bool:
    """True when the construction merely repackages routes the schedule
    module authored (e.g. ``[sched.perm(k) for k in ...]``) — wrapping
    or slicing authority output is not authoring."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in _AUTHORITY_NAMES:
            return True
        if isinstance(sub, ast.Attribute) and sub.attr in _AUTHORITY_ATTRS:
            return True
    return False


def _allowed(lines, lineno: int) -> bool:
    lo = max(0, lineno - 3)  # the line itself or the two above it
    return any(ALLOW in ln for ln in lines[lo:lineno])


def _perm_arg(call: ast.Call):
    """The route argument of a ppermute(x, axis_name, perm) call."""
    for kw in call.keywords:
        if kw.arg == "perm":
            return kw.value
    if len(call.args) >= 3:
        return call.args[2]
    return None


def check_file(path: pathlib.Path, rel: str) -> list:
    src = path.read_text()
    lines = src.splitlines()
    tree = ast.parse(src, filename=str(path))
    bad = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else "")
            if name != "ppermute":
                continue
            arg = _perm_arg(node)
            if arg is not None and _is_inline_perm(arg) \
                    and not _flows_from_authority(arg) \
                    and not _allowed(lines, node.lineno):
                bad.append((rel, node.lineno,
                            "ppermute perm constructed at the call site"))
        elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            named = [t.id for t in targets
                     if isinstance(t, ast.Name) and PERM_NAME.search(t.id)]
            value = node.value
            if named and value is not None and _is_inline_perm(value) \
                    and not _flows_from_authority(value) \
                    and not _allowed(lines, node.lineno):
                bad.append((rel, node.lineno,
                            f"route table '{named[0]}' authored outside "
                            f"{AUTHORITY}"))
    return bad


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="src/repro",
                    help="package root to scan (default src/repro)")
    args = ap.parse_args()
    root = pathlib.Path(args.root)
    if not root.is_dir():
        print(f"::error::schedule-authority: no such root {root}")
        return 1
    violations = []
    n_files = 0
    for path in sorted(root.rglob("*.py")):
        rel = path.as_posix()
        if rel.endswith(AUTHORITY):
            continue  # the authority itself
        n_files += 1
        violations += check_file(path, rel)
    for rel, lineno, msg in violations:
        print(f"::error file={rel},line={lineno}::schedule-authority: {msg} "
              f"(route tables live in {AUTHORITY}; a deliberate exception "
              f"needs '# {ALLOW} — <reason>')")
    if violations:
        return 1
    print(f"schedule-authority: {n_files} files clean — every ppermute perm "
          f"flows from {AUTHORITY}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
