"""Render the §Dry-run / §Roofline tables from results/dryrun/*.json.

    python scripts/roofline_table.py [results/dryrun] > table.md
"""
from __future__ import annotations

import json
import os
import sys

ARCH_ORDER = [
    "seamless-m4t-medium", "llama4-scout-17b-a16e", "zamba2-2.7b",
    "minitron-8b", "minicpm3-4b", "mamba2-780m", "internlm2-20b",
    "deepseek-67b", "phi3.5-moe-42b-a6.6b", "internvl2-26b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    for unit, div in [("GB", 1e9), ("MB", 1e6), ("KB", 1e3)]:
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rows = {}
    for fn in os.listdir(d):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(d, fn)) as f:
            r = json.load(f)
        key = (r["arch"], r["shape"], r["mesh"],
               r.get("grad_gz"), r.get("fsdp_gz"), fn)
        rows[key] = r

    print("### Single-pod (16x16) roofline baselines\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "HLO flops/dev | HBM/dev | coll B/dev | useful frac | "
          "peak temp | compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = next(
                (v for k, v in rows.items()
                 if k[0] == arch and k[1] == shape and k[2] == "16x16"
                 and k[3] is None and not k[4]),
                None,
            )
            if r is None:
                print(f"| {arch} | {shape} | MISSING | | | | | | | | | |")
                continue
            ro = r["roofline"]
            uf = r.get("useful_flops_frac")
            temp = r.get("memory_analysis", {}).get("temp_size_in_bytes", 0)
            print(
                f"| {arch} | {shape} | {fmt_s(ro['compute_s'])} | "
                f"{fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} | "
                f"**{ro['dominant']}** | {r['corrected']['flops']:.2e} | "
                f"{fmt_b(r['corrected']['hbm'])} | "
                f"{fmt_b(r['corrected']['coll'])} | "
                f"{uf:.3f} | {fmt_b(temp)} | {r['compile_s']:.0f} |"
            )

    print("\n### Multi-pod (2x16x16) lowering proof\n")
    print("| arch | shape | compiled | collective kinds (counted-once) | compile s |")
    print("|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = next(
                (v for k, v in rows.items()
                 if k[0] == arch and k[1] == shape and k[2] == "2x16x16"),
                None,
            )
            if r is None:
                print(f"| {arch} | {shape} | MISSING | | |")
                continue
            kinds = ", ".join(
                f"{k}x{v}" for k, v in sorted(
                    r.get("collective_counts_once", {}).items())
            )
            print(f"| {arch} | {shape} | yes | {kinds} | {r['compile_s']:.0f} |")


if __name__ == "__main__":
    main()
