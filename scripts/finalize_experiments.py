"""Splice generated tables (dry-run, roofline, perf) into EXPERIMENTS.md."""
from __future__ import annotations

import io
import json
import os
import sys
from contextlib import redirect_stdout

sys.path.insert(0, "scripts")
import roofline_table  # noqa: E402


def dryrun_tables() -> str:
    buf = io.StringIO()
    with redirect_stdout(buf):
        sys.argv = ["roofline_table.py", "results/dryrun"]
        roofline_table.main()
    return buf.getvalue()


def _load(tag):
    p = f"results/dryrun/{tag}.json"
    return json.load(open(p)) if os.path.exists(p) else None


def perf_log() -> str:
    rows = []

    def add(title, base_tag, steps):
        base = _load(base_tag)
        out = [f"\n### {title}\n"]
        if base is None:
            return "\n(missing baseline)\n"
        b = base["roofline"]
        out.append(
            f"Baseline `{base_tag}`: compute {b['compute_s']:.4f}s, "
            f"memory {b['memory_s']:.4f}s, collective {b['collective_s']:.4f}s "
            f"— dominant **{b['dominant']}**, useful-FLOPs "
            f"{base['useful_flops_frac']:.4f}.\n"
        )
        prev = base
        for hyp, tag, verdict_hint in steps:
            r = _load(tag)
            if r is None:
                out.append(f"* `{tag}`: MISSING\n")
                continue
            ro, po = r["roofline"], prev["roofline"]
            out.append(
                f"* **hypothesis:** {hyp}\n"
                f"  **change:** `{tag.split('_single_')[-1]}` → "
                f"compute {po['compute_s']:.4f}→{ro['compute_s']:.4f}s, "
                f"memory {po['memory_s']:.4f}→{ro['memory_s']:.4f}s, "
                f"collective {po['collective_s']:.4f}→{ro['collective_s']:.4f}s, "
                f"useful-FLOPs {prev['useful_flops_frac']:.4f}→"
                f"{r['useful_flops_frac']:.4f}.\n"
                f"  **verdict:** {verdict_hint}\n"
            )
            prev = r
        return "".join(out)

    s = ""
    s += add(
        "H1 — deepseek-67b / long_500k (most collective-bound)",
        "deepseek-67b_long_500k_single",
        [
            (
                "the 0.457 s collective term is per-token FSDP weight "
                "gathers (95 layers × all-gather over data for ONE token); "
                "serving should keep weights resident (params fit: 67B bf16 "
                "/ 16 TP = 8.4 GB/chip)",
                "deepseek-67b_long_500k_single_h1-nofsdp",
                "CONFIRMED — collective 0.457s→0.0001s (~4000x); dominant "
                "term flips to memory; end-to-end roofline bound 0.457s→"
                "0.125s (3.7x).",
            ),
            (
                "remaining memory term includes KV reads; bf16 cache should "
                "halve cache traffic",
                "deepseek-67b_long_500k_single_h1-nofsdp-bf16cache",
                "REFUTED — memory 0.1246s→0.1244s (<1%): with an 8192-token "
                "sliding window the cache is tiny next to the per-token "
                "weight reads; weight traffic dominates. (Lesson: quantize "
                "weights, not the cache, for long-context decode.)",
            ),
        ],
    )
    s += add(
        "H2 — minicpm3-4b / prefill_32k (worst useful-FLOPs fraction)",
        "minicpm3-4b_prefill_32k_single",
        [
            (
                "dense MLA materializes (B,H,32768,32768) scores; "
                "flash-chunking the latent attention (napkin: scores are "
                "~86 GB f32 per layer vs ~0.4 GB/chunk) should collapse the "
                "memory term and the remat-recompute flops",
                "minicpm3-4b_prefill_32k_single_h2-chunked",
                "CONFIRMED — memory 97.6s→25.3s (3.9x), compute 9.97s→1.36s "
                "(7.4x — the dense scores were recomputed under remat), "
                "useful-FLOPs 0.063→0.465.",
            ),
            (
                "with the attention now O(S) memory, full remat is pure "
                "overhead: dropping it removes the recompute AND the "
                "re-gathers of FSDP weights in the bwd pass",
                "minicpm3-4b_prefill_32k_single_h2-chunked-noremat",
                "CONFIRMED — compute 1.36s→1.05s, collective 4.23s→3.40s "
                "(bwd re-gathers gone), memory 25.3s→24.6s; useful-FLOPs "
                "0.60.  Next candidate (not yet applied): sequence-chunked "
                "vocab-parallel loss — the (B,S,V_local) f32 logits are the "
                "largest remaining single tensor.",
            ),
        ],
    )
    base3 = _load("deepseek-67b_train_4k_single")
    s += add(
        "H3 — deepseek-67b / train_4k (the paper's technique on the "
        "gradient path)",
        "deepseek-67b_train_4k_single",
        [
            (
                "PAPER-FAITHFUL: route FSDP grad reduce-scatter + param "
                "allgather and small-leaf grad allreduce through gZ "
                "(ReDoub for allreduce, ring for gather/scatter, eb 1e-4, "
                "capacity 0.6); wire bytes should scale with the capacity "
                "factor (0.6x f32 = 2.4 B/elem vs 2 B/elem bf16 psum — "
                "napkin says roughly break-even on wire, the win is "
                "compression headroom)",
                "deepseek-67b_train_4k_single_gz-redoub_fsdpgz_h3-paper-redoub",
                "see numbers — static capacity provisioning means XLA moves "
                "capacity bytes; the TRUE compressed payload (nwords) is "
                "what a ragged transport moves (DESIGN.md §2.1).",
            ),
            (
                "PAPER-FAITHFUL (Ring): same but ring allreduce for grads",
                "deepseek-67b_train_4k_single_gz-ring_fsdpgz_h3-paper-ring",
                "ring vs redoub wire comparison on the collective term.",
            ),
            (
                "BEYOND-PAPER: intring (single quantization, bitwise "
                "rank-consistent) + capacity 0.25 (4 bits/weight-grad "
                "effective) — should cut the collective term vs baseline "
                "while FIXING the paper's rank-divergence",
                "deepseek-67b_train_4k_single_gz-intring_fsdpgz_h3-beyond-intring",
                "PARTIALLY REFUTED — collective 20.99s→20.53s (2.2%): HLO "
                "inspection showed TP *activation* psums are ~93% of the "
                "collective term on this mesh; the weight-gather/grad bytes "
                "the paper's technique compresses are the remaining ~7%. "
                "Lesson: at tp=16 with per-layer FSDP gathers inside the "
                "scan, gradient compression is not where train-step "
                "collective time lives — which redirects the next "
                "hypothesis at the activations themselves.",
            ),
            (
                "BEYOND-PAPER (structural, from the refuted hypothesis): "
                "PaLM-style parallel attention+MLP blocks sum both partials "
                "before ONE shared TP psum per layer — napkin: halves "
                "activation-psum bytes fwd and bwd",
                "deepseek-67b_train_4k_single_h3b-parallelblock",
                "CONFIRMED — collective 20.99s→8.75s (2.4x: bwd transposes "
                "halve too), memory 43.4s→36.8s, useful-FLOPs 0.575→0.586. "
                "Note this changes the function (recorded as an opt-in "
                "`parallel_block` variant, off for the faithful configs).",
            ),
        ],
    )
    return s


def main():
    md = open("EXPERIMENTS.md").read()
    md = md.replace("<!-- DRYRUN_TABLES -->", dryrun_tables())
    md = md.replace("<!-- PERF_LOG -->", perf_log())
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
