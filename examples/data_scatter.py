"""gZ-Scatter as the data plane: the root rank holds a global float batch
(e.g. precomputed embeddings / science fields) and distributes per-rank
shards through the compressed binomial tree (paper §3.3.4, Fig. 5).

    PYTHONPATH=src python examples/data_scatter.py
"""
from __future__ import annotations

import os
import sys

if os.environ.get("XLA_FLAGS", "").find("device_count") < 0:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.collectives import GZConfig
from repro.core.comm import GZCommunicator
from repro.core.shmap import shard_map

N = 8
CHUNK = 64 * 1024


def main():
    mesh = jax.make_mesh((N,), ("x",))
    rng = np.random.default_rng(0)
    full = np.cumsum(rng.normal(0, 0.01, N * CHUNK)).astype(np.float32)
    xin = np.zeros((N, N * CHUNK), np.float32)
    xin[0] = full  # only the root's row is significant

    # Bind the axis + knobs once; the frozen Plan (per-stage eb, capacity,
    # wire accounting) is resolved outside the traced region (DESIGN.md §5).
    comm = GZCommunicator("x", config=GZConfig(eb=1e-4, capacity_factor=0.6),
                          axis_size=N)

    def body(x):
        res = comm.scatter(x[0])
        return res.value, res.overflow[None]

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("x", None),),
                          out_specs=(P("x"), P("x"))))
    out, ovf = f(xin)
    out = np.asarray(out).reshape(N, CHUNK)
    assert not np.asarray(ovf).any(), "capacity overflow"
    err = np.abs(out - full.reshape(N, CHUNK)).max()
    plan = comm.plan("scatter", N * CHUNK)
    print(f"scattered {full.nbytes/1e6:.1f} MB to {N} ranks, "
          f"max err {err:.2e} (eb=1e-4)")
    print(f"plan: algo={plan.algo} wire={plan.wire_bytes/1e6:.2f} MB/rank "
          f"provisioned-ratio {plan.ratio:.1f}x")
    assert err <= 1e-4 + np.abs(full).max() * 2e-7
    print("every rank received its chunk through ONE lossy hop")


if __name__ == "__main__":
    main()
