"""gZCCL in the training loop: train the same model twice on a 2x4 mesh —
once with plain psum gradient sync, once with gZ-Allreduce (ReDoub) — and
show the loss curves match while the synced gradient bytes shrink by the
measured compression ratio.

    PYTHONPATH=src python examples/compressed_training.py
"""
from __future__ import annotations

import os
import sys

if os.environ.get("XLA_FLAGS", "").find("device_count") < 0:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, "src")

import numpy as np
import jax

from repro.configs import registry
from repro.core.collectives import GZConfig
from repro.data.pipeline import SyntheticStream
from repro.launch.shapes import InputShape, train_specs
from repro.launch.training import make_setup, make_train_step
from repro.models.parallel import init_params
from repro.optim.adamw import AdamWConfig, adamw_init

STEPS, BATCH, SEQ = 30, 8, 128


def run(grad_gz):
    cfg = registry.get("minitron-8b", smoke=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    opt = AdamWConfig(lr=6e-4, total_steps=STEPS, warmup_steps=3)
    # make_setup binds a resolve-once GZCommunicator to the "data" axis
    # (core/comm.py); the gradient allreduce plan is memoized, not
    # re-derived inside the jitted step
    setup = make_setup(cfg, mesh, opt=opt, grad_gz=grad_gz)
    shape = InputShape("ex", SEQ, BATCH, "train")
    _, bspecs = train_specs(cfg, shape, mesh)
    step_fn = make_train_step(setup, bspecs)
    params = init_params(setup.defs, jax.random.key(0))
    opt_state = adamw_init(params)
    stream = SyntheticStream(cfg, BATCH, SEQ, seed=0)
    losses = []
    for _, batch in zip(range(STEPS), stream):
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
    return np.array(losses)


def main():
    base = run(None)
    gz = run(GZConfig(eb=1e-5, algo="redoub", capacity_factor=1.2,
                      worst_case_budget=False))
    print("step   psum-loss   gz-redoub-loss")
    for i in range(0, STEPS, 5):
        print(f"{i:4d}   {base[i]:9.4f}   {gz[i]:9.4f}")
    drift = np.abs(base - gz).max()
    print(f"\nmax loss drift over {STEPS} steps: {drift:.4f}")
    assert gz[-1] < gz[0] - 0.3, "compressed-sync run failed to learn"
    assert drift < 0.5, "compressed sync diverged from exact sync"
    print("gZ-compressed gradient sync tracks exact psum training.")


if __name__ == "__main__":
    main()
