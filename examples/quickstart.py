"""Quickstart: train a ~100M-param dense model for a few hundred steps on
CPU with gZCCL-compressed gradient sync (the paper's collective in the
training hot path), then greedy-decode a few tokens from the trained
checkpoint.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

sys.path.insert(0, "src")

from repro.core.collectives import GZConfig
from repro.core.shmap import shard_map
from repro.data.pipeline import SyntheticStream
from repro.launch.shapes import InputShape, train_specs
from repro.launch.training import make_setup, make_train_step
from repro.models.attention import KVCacheSpec
from repro.models.config import ModelConfig
from repro.models.parallel import init_params
from repro.optim.adamw import AdamWConfig, adamw_init


def model_100m() -> ModelConfig:
    """~100M-param GQA decoder (internlm2-family reduced depth/width)."""
    return ModelConfig(
        arch_id="quickstart-100m",
        family="dense",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        d_ff=2048,
        vocab=32000,
        source="quickstart",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = model_100m()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt = AdamWConfig(lr=6e-4, total_steps=args.steps,
                      warmup_steps=args.steps // 10)
    # gradient sync through the paper's recursive-doubling gZ-Allreduce;
    # make_setup binds one resolve-once GZCommunicator per dp axis
    # (core/comm.py) — pass grad_policy="auto"/"paper"/"throughput"/
    # "accuracy" to change how open choices are planned
    setup = make_setup(cfg, mesh, opt=opt,
                       grad_gz=GZConfig(eb=1e-5, algo="redoub"),
                       grad_policy="auto")
    shape = InputShape("quickstart", args.seq, args.batch, "train")
    _, bspecs = train_specs(cfg, shape, mesh)
    step_fn = make_train_step(setup, bspecs)

    params = init_params(setup.defs, jax.random.key(0))
    opt_state = adamw_init(params)
    stream = SyntheticStream(cfg, args.batch, args.seq, seed=0)
    print(f"{cfg.arch_id}: {cfg.param_count()/1e6:.0f}M params, "
          f"{args.steps} steps of batch {args.batch} x seq {args.seq}")
    t0 = time.time()
    first = None
    for step, batch in zip(range(args.steps), stream):
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step == 0:
            first = float(m["loss"])
        if step % 20 == 0 or step == args.steps - 1:
            print(f"  step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"({time.time()-t0:.0f}s)")
    final = float(m["loss"])
    print(f"loss {first:.3f} -> {final:.3f} "
          f"({'OK: learning' if final < first - 0.5 else 'WARN: check lr'})")

    # greedy decode with the trained weights
    model = setup.model
    plan = KVCacheSpec(s_total=64, cp_axis=None, cp_size=1)
    shapes = model.cache_defs(2, plan)
    cache = {k: jnp.zeros(v, jnp.float32) for k, v in shapes.items()}
    specs = setup.specs
    cspecs = {k: P(*((None,) * len(v))) for k, v in shapes.items()}
    dstep = jax.jit(shard_map(
        lambda p, c, t, pos: model.decode_fn(p, c, t, pos[0], plan),
        mesh=mesh, in_specs=(specs, cspecs, P(None, None), P(None)),
        out_specs=(P(None, None, None), cspecs),
    ))
    tok = jnp.asarray([[1], [2]], jnp.int32)
    outs = []
    for i in range(16):
        logits, cache = dstep(params, cache, tok, jnp.asarray([i]))
        tok = jnp.argmax(logits[:, :, : cfg.vocab], -1).astype(jnp.int32)
        outs.append(int(tok[0, 0]))
    print("decoded:", outs)


if __name__ == "__main__":
    main()
