"""The paper's own application (§4.5): image stacking via gZ-Allreduce.

Runs the REAL shard_map gZ-Allreduce on 8 virtual host devices (this
script re-execs itself with the device-count flag), stacks 8 noisy
observations of a scene, and reports PSNR / NRMSE of each algorithm's
stacked image vs the exact sum — the Fig. 13 / Table 2 quality analysis.

    PYTHONPATH=src python examples/image_stacking.py
"""
from __future__ import annotations

import os
import sys

if os.environ.get("XLA_FLAGS", "").find("device_count") < 0:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.benchutil import noisy_images
from repro.core.collectives import GZConfig
from repro.core.comm import GZCommunicator
from repro.core.shmap import shard_map

N, H, W = 8, 256, 256


def psnr(a, b):
    mse = float(np.mean((a - b) ** 2))
    rng = float(a.max() - a.min())
    return 10 * np.log10(rng * rng / mse)


def main():
    mesh = jax.make_mesh((N,), ("x",))
    imgs = np.stack(noisy_images(N, H, W, seed=1)).reshape(N, H * W)
    exact = imgs.sum(axis=0).reshape(H, W)
    eb = 1e-4 * float(np.abs(exact).max())

    for algo in ["redoub", "ring", "intring"]:
        comm = GZCommunicator(
            "x",
            config=GZConfig(eb=eb, algo=algo, capacity_factor=1.2,
                            worst_case_budget=False),
            axis_size=N,
        )

        def body(x):
            return comm.allreduce(x[0]).value[None]

        f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("x", None),),
                              out_specs=P("x", None)))
        out = np.asarray(f(imgs))[0].reshape(H, W)
        p = psnr(exact, out)
        nrmse = float(np.sqrt(np.mean((exact - out) ** 2))
                      / (exact.max() - exact.min()))
        print(f"gZ-Allreduce ({algo:8s}): PSNR {p:6.2f} dB   NRMSE {nrmse:.2e}")
        assert p > 45, "reconstruction quality regression"
    print("stacked image quality matches the paper's accuracy-aware claims")


if __name__ == "__main__":
    main()
