"""Fig. 2 / Table 2 breakdown analog: where the time goes per algorithm.

Modeled phase shares (compression / communication / reduction / other) for
CPRP2P, C-Coll, gZ-Ring and gZ-ReDoub at the paper's 64-GPU, 646 MB point.
Reproduces the paper's observations: CPRP2P dominated by CPR; C-Coll
dominated by host-device staging (~45%); gZ-Ring CPR-heavy (84% in
Table 2); gZ-ReDoub balanced between CPR and comm.
"""
from __future__ import annotations

import math

from repro.core import cost_model as cm

HW = cm.A100_SLINGSHOT
R = 30.0
D = 646e6
N = 64


def _shares(cmpr, comm, redu, stage=0.0):
    tot = cmpr + comm + redu + stage
    return (
        f"cmpr={cmpr/tot:.1%};comm={comm/tot:.1%};redu={redu/tot:.1%};"
        f"other={stage/tot:.1%}", tot
    )


def run(csv_rows: list):
    ch = D / N
    # CPRP2P: compress+decompress around every hop
    cmpr = 2 * (N - 1) * (cm.t_compress(ch, HW) + cm.t_decompress(ch, HW))
    comm = 2 * (N - 1) * cm.t_net(ch / R, HW)
    redu = (N - 1) * cm.t_reduce(ch, HW)
    s, tot = _shares(cmpr, comm, redu)
    csv_rows.append(("fig2_breakdown_cprp2p", tot * 1e6, s))

    # C-Coll: adds PCIe staging
    stage = 2 * (N - 1) * 2 * ch / (HW.pcie_gbps * 1e9 / 8)
    cmpr = N * cm.t_compress(ch, HW) + (2 * N - 2) * cm.t_decompress(ch, HW)
    comm = 2 * (N - 1) * cm.t_net(ch / R, HW)
    s, tot = _shares(cmpr, comm, redu, stage)
    csv_rows.append(("fig2_breakdown_ccoll", tot * 1e6, s))

    # gZ-Ring (Table 2: cmpr-dominated)
    cmpr = N * cm.t_compress(ch, HW) + (2 * N - 2) * cm.t_decompress(ch, HW)
    comm = 2 * (N - 1) * cm.t_net(ch / R, HW)
    s, tot = _shares(cmpr, comm, redu)
    csv_rows.append(("table2_breakdown_gz_ring", tot * 1e6, s))

    # gZ-ReDoub (Table 2: cmpr ~43%, comm ~46%)
    k = math.ceil(math.log2(N))
    cmpr = k * (cm.t_compress(D, HW) + cm.t_decompress(D, HW))
    comm = k * cm.t_net(D / R, HW)
    redu = k * cm.t_reduce(D, HW)
    s, tot = _shares(cmpr, comm, redu)
    csv_rows.append(("table2_breakdown_gz_redoub", tot * 1e6, s))
