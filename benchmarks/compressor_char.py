"""Fig. 3 analog: compressor characterization vs input size.

Measures REAL wall-time of the (interpret-mode) Pallas compressor on this
CPU for the utilization-curve SHAPE, and reports the calibrated cost-model
values for A100/cuSZp and TPU-v5e beside it.  The paper's observation —
per-byte cost explodes below the saturation size — must hold in all three
columns.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cost_model as cm
from repro.core.compressor import ErrorBoundedLorenzo

SIZES_MB = [0.25, 0.5, 1, 2, 5, 10, 20, 40]


def run(csv_rows: list):
    comp = ErrorBoundedLorenzo(capacity_factor=1.1)
    rng = np.random.default_rng(0)
    for mb in SIZES_MB:
        n = int(mb * 1e6 / 4)
        x = jnp.asarray(np.cumsum(rng.normal(0, 0.01, n)).astype(np.float32))
        c = comp.compress(x, 1e-4)  # warm the jit cache
        jax.block_until_ready(c.packed)
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            c = comp.compress(x, 1e-4)
            jax.block_until_ready(c.packed)
        t_cmp = (time.perf_counter() - t0) / reps
        y = comp.decompress(c)
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(reps):
            y = comp.decompress(c)
            jax.block_until_ready(y)
        t_dec = (time.perf_counter() - t0) / reps
        ratio = (n * 4) / float(np.asarray(c.payload_bytes()))
        csv_rows.append(
            (
                f"fig3_compress_{mb}MB",
                t_cmp * 1e6,
                f"ratio={ratio:.1f};dec_us={t_dec*1e6:.0f};"
                f"model_a100_us={cm.t_compress(mb*1e6, cm.A100_SLINGSHOT)*1e6:.0f};"
                f"model_v5e_us={cm.t_compress(mb*1e6, cm.TPU_V5E)*1e6:.0f}",
            )
        )
    # the paper's qualitative claim: per-byte cost is monotonically worse
    # for smaller inputs (checked on the calibrated model; the CPU interp
    # numbers are indicative only)
    per_byte = [cm.t_compress(mb * 1e6, cm.A100_SLINGSHOT) / (mb * 1e6)
                for mb in SIZES_MB]
    assert per_byte == sorted(per_byte, reverse=True)
