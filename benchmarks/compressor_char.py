"""Fig. 3 analog: compressor characterization vs input size.

Measures REAL wall-time of the (interpret-mode) Pallas compressor on this
CPU for the utilization-curve SHAPE, and reports the calibrated cost-model
values for A100/cuSZp and TPU-v5e beside it.  The paper's observation —
per-byte cost explodes below the saturation size — must hold in all three
columns.

Also emits a fused-vs-unfused microbenchmark (single-pass quantize_pack
vs quantize + jnp bitpack, and the receive-side equivalents) and records
the result to benchmarks/BENCH_compress.json so future PRs have a perf
trajectory to compare against (CPU-interpret numbers are indicative of op
count / memory traffic, not TPU wall-clock).
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.benchutil import time_it as _time_it

from repro.core import cost_model as cm
from repro.core.compressor import ErrorBoundedLorenzo

SIZES_MB = [0.25, 0.5, 1, 2, 5, 10, 20, 40]
# CPU-interpret caveat: the fused pack kernel's resident output window is
# round-tripped per grid step by the interpreter (it stays in VMEM on TPU),
# so fused COMPRESS wall-clock on CPU is pessimistic; the fused receive
# side (no big resident output) shows the real op-count win (~2x).
FUSED_SIZES_MB = [1, 4]
BASELINE_PATH = pathlib.Path(__file__).parent / "BENCH_compress.json"


def run_fused_vs_unfused(csv_rows: list, record_baseline: bool = True) -> dict:
    """Fused single-pass pipeline vs the two-pass composition.

    ``record_baseline=False`` measures without overwriting the committed
    BENCH_compress.json (the CI regression check compares against it).
    """
    rng = np.random.default_rng(1)
    record = {}
    for mb in FUSED_SIZES_MB:
        n = int(mb * 1e6 / 4)
        x = jnp.asarray(np.cumsum(rng.normal(0, 0.01, n)).astype(np.float32))
        acc = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
        results = {}
        for fused in (False, True):
            comp = ErrorBoundedLorenzo(capacity_factor=1.1, fused=fused)
            c = comp.compress(x, 1e-4)
            t_cmp = _time_it(lambda: comp.compress(x, 1e-4).packed, reps=5)
            t_red = _time_it(lambda: comp.decompress_reduce(c, acc), reps=5)
            key = "fused" if fused else "unfused"
            results[key] = {"compress_us": t_cmp * 1e6,
                            "decompress_reduce_us": t_red * 1e6}
        speed_c = results["unfused"]["compress_us"] / results["fused"]["compress_us"]
        speed_r = (results["unfused"]["decompress_reduce_us"]
                   / results["fused"]["decompress_reduce_us"])
        record[f"{mb}MB"] = results
        csv_rows.append(
            (
                f"fused_vs_unfused_{mb}MB",
                results["fused"]["compress_us"],
                f"unfused_us={results['unfused']['compress_us']:.0f};"
                f"compress_speedup={speed_c:.2f}x;"
                f"decred_speedup={speed_r:.2f}x",
            )
        )
    if record_baseline:
        BASELINE_PATH.write_text(
            json.dumps(
                {
                    "backend": jax.default_backend(),
                    "note": "CPU interpret-mode; op-count/memory-traffic proxy",
                    "fused_vs_unfused": record,
                },
                indent=2,
            )
            + "\n"
        )
    return record


def run(csv_rows: list):
    # The Fig.3 sweep characterizes the utilization curve, not the fusion;
    # the two-pass path keeps CPU-interpret wall-clock comparable to the
    # recorded history (see run_fused_vs_unfused for the fused comparison).
    comp = ErrorBoundedLorenzo(capacity_factor=1.1, fused=False)
    rng = np.random.default_rng(0)
    for mb in SIZES_MB:
        n = int(mb * 1e6 / 4)
        x = jnp.asarray(np.cumsum(rng.normal(0, 0.01, n)).astype(np.float32))
        c = comp.compress(x, 1e-4)  # warm the jit cache
        jax.block_until_ready(c.packed)
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            c = comp.compress(x, 1e-4)
            jax.block_until_ready(c.packed)
        t_cmp = (time.perf_counter() - t0) / reps
        y = comp.decompress(c)
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(reps):
            y = comp.decompress(c)
            jax.block_until_ready(y)
        t_dec = (time.perf_counter() - t0) / reps
        ratio = (n * 4) / float(np.asarray(c.payload_bytes()))
        csv_rows.append(
            (
                f"fig3_compress_{mb}MB",
                t_cmp * 1e6,
                f"ratio={ratio:.1f};dec_us={t_dec*1e6:.0f};"
                f"model_a100_us={cm.t_compress(mb*1e6, cm.A100_SLINGSHOT)*1e6:.0f};"
                f"model_v5e_us={cm.t_compress(mb*1e6, cm.TPU_V5E)*1e6:.0f}",
            )
        )
    # the paper's qualitative claim: per-byte cost is monotonically worse
    # for smaller inputs (checked on the calibrated model; the CPU interp
    # numbers are indicative only)
    per_byte = [cm.t_compress(mb * 1e6, cm.A100_SLINGSHOT) / (mb * 1e6)
                for mb in SIZES_MB]
    assert per_byte == sorted(per_byte, reverse=True)

    run_fused_vs_unfused(csv_rows)
