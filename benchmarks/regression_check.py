"""CI perf-regression check: rerun the fused-vs-unfused and per-hop
microbenchmarks and compare against the committed baselines
(benchmarks/BENCH_compress.json, benchmarks/BENCH_hop.json).

Absolute wall-clock is machine-specific (the baselines were recorded on a
dev box, CI runs elsewhere), so the comparison is on the MACHINE-
INDEPENDENT fused/unfused time ratio per metric: host speed cancels, and
a ratio that worsens by more than THRESHOLD (default 20%) means the fused
path lost ground structurally (op count / memory traffic), not that the
runner is slow.  Regressions are reported as GitHub ``::warning::``
annotations (report-only by default; ``--strict`` exits nonzero).  The
structural per-hop kernel count (2 -> 1) cannot be timing noise and is
always fatal: ``hop_bench.run`` asserts it before returning.

Usage: PYTHONPATH=src python -m benchmarks.regression_check [--strict]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

THRESHOLD = 0.20


def check_step_count_consistency() -> None:
    """Plan-layer wire accounting and the cost model must agree on step
    counts for EVERY axis size (the PR 4 floor-vs-ceil regression: plans
    under-reported non-power-of-two wire bytes and mis-ranked algorithms).
    Structural, not timing — always fatal, like the kernel-count assert.
    The single authoritative loop lives next to the accounting it guards
    (comm.assert_step_count_consistency); tests/test_comm.py runs it too.
    """
    from repro.core.comm import assert_step_count_consistency

    assert_step_count_consistency()
    print("step-count consistency: plan accounting == cost model for n in 2..33")


def check_schedule_authority(here: pathlib.Path) -> None:
    """Static single-authority gate (ISSUE 10): every lax.ppermute perm in
    src/repro must flow from core/schedule.py's route tables.  Runs the
    same AST scan CI runs (scripts/check_schedule_authority.py) so a
    local ``python -m benchmarks.regression_check`` catches ad-hoc routes
    before push.  Structural — always fatal.
    """
    import subprocess

    script = here.parent / "scripts" / "check_schedule_authority.py"
    root = here.parent / "src" / "repro"
    proc = subprocess.run(
        [sys.executable, str(script), "--root", str(root)],
        capture_output=True, text=True,
    )
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        print("::error::schedule-authority static check failed (see above)")
        sys.exit(1)


def check_schedule_wire_parity() -> None:
    """Single wire authority (ISSUE 10): replaying the plan's route table
    hop by hop (simulator.sim_wire_bytes measures each entry's container
    with jax.eval_shape of the REAL compressor) must reproduce the plan's
    provisioned ``wire_bytes`` EXACTLY, for every op, flat algorithm, and
    the hierarchical path.  The executed ``CollectiveResult.wire_bytes``
    reads the same plan field, so this pins sim == priced == executed;
    the multi-device children assert the executed leg on a real mesh.
    Structural schedule arithmetic, not timing — always fatal.
    """
    from repro.core import cost_model, simulator
    from repro.core.collectives import GZConfig
    from repro.core.comm import GZCommunicator, GZHierCommunicator

    checked = 0
    for op, algo in (("allreduce", "ring"), ("allreduce", "redoub"),
                     ("allreduce", "intring"), ("reduce_scatter", "auto"),
                     ("allgather", "auto"), ("scatter", "auto"),
                     ("broadcast", "auto"), ("all_to_all", "auto")):
        for n in (2, 6, 9):
            for elems in (4096, 70000):
                cfg = GZConfig(eb=1e-3, algo=algo)
                plan = GZCommunicator("i", axis_size=n, config=cfg).plan(
                    op, (elems,), "float32")
                sim = simulator.sim_wire_bytes(plan)
                if sim != plan.wire_bytes:
                    print(f"::error::schedule wire parity: table replay "
                          f"({sim}) != plan.wire_bytes ({plan.wire_bytes}) "
                          f"for {op}/{plan.algo} n={n} elems={elems}")
                    sys.exit(1)
                checked += 1
    for topo in ((2, 3), (3, 2), (2, 2)):
        for hw in (cost_model.TPU_V5E, cost_model.A100_SLINGSHOT):
            c = GZHierCommunicator("node", "local", config=GZConfig(eb=1e-3),
                                   hw=hw, topology=topo)
            plan = c.plan((70000,), "float32")
            sim = simulator.sim_wire_bytes(plan)
            priced = (plan.flat_plan.wire_bytes if plan.flat
                      else plan.intra_wire_bytes + plan.inter_wire_bytes)
            if sim != priced:
                print(f"::error::schedule wire parity (hier): table replay "
                      f"({sim}) != priced wire ({priced}) for "
                      f"topology={topo} hw={hw.name} flat={plan.flat}")
                sys.exit(1)
            checked += 1
    print(f"schedule wire parity: table replay == plan.wire_bytes exactly "
          f"for {checked} plan(s) (flat ops x n x elems + hier topologies)")


def check_scatter_wire(here: pathlib.Path) -> None:
    """Provisioned scatter wire vs the committed BENCH_scatter.json.

    ``chunk_streams``/``wire_bytes`` are STATIC schedule quantities (the
    trimmed-slab table, not wall-clock), so the comparison is exact and
    any increase is FATAL regardless of ``--strict`` — shipping padding
    chunks again (the PR 4 virtual-tree waste this baseline pins at n-1
    root streams for every n, pow2 or not) is a structural regression
    that must never ride in under the >20% timing threshold.
    """
    from benchmarks import scatter_bench

    base_path = here / "BENCH_scatter.json"
    if not base_path.exists():
        # A missing baseline must not read as "no regression" — this gate
        # is fatal by design (run benchmarks/run.py to record it).
        print(f"::error::scatter wire baseline missing: {base_path}")
        sys.exit(1)
    base = json.loads(base_path.read_text())["scatter"]
    now = scatter_bench.run([], record_baseline=False)
    bad = []
    for n, rec in sorted(base.items(), key=lambda kv: int(kv[0])):
        cur = now.get(n)
        if cur is None:
            bad.append(f"n={n}: baseline row missing from current run")
            continue
        for key in ("chunk_streams", "wire_bytes"):
            if cur[key] > rec[key]:
                bad.append(
                    f"n={n}: {key} grew {rec[key]} -> {cur[key]} "
                    f"(padding chunks back on the wire?)")
    if bad:
        for msg in bad:
            print(f"::error::scatter wire regression: {msg}")
        sys.exit(1)
    print(f"scatter wire: provisioned root streams/bytes match baseline "
          f"for n in {sorted(int(k) for k in base)}")


def check_hier_wire(here: pathlib.Path) -> None:
    """Inter-node wire of the two-level plans vs the committed
    BENCH_hier.json.

    ``hier_inter_wire_bytes`` is a STATIC plan quantity (the provisioned
    streams the inter sub-plan ships across the node fabric — the scarce
    resource the hierarchy exists to spend well), so the comparison is
    EXACT and any growth is fatal regardless of ``--strict``: a planner
    change that quietly moves more bytes across nodes is a structural
    regression that must not ride in under the timing threshold.  The
    bench itself also asserts the ISSUE 6 acceptance invariant (hier
    strictly below flat on wire and modeled time at >= 8 devices).
    """
    from benchmarks import hier_bench

    base_path = here / "BENCH_hier.json"
    if not base_path.exists():
        # A missing baseline must not read as "no regression".
        print(f"::error::hier wire baseline missing: {base_path}")
        sys.exit(1)
    base = json.loads(base_path.read_text())["hier"]
    now = hier_bench.run([], record_baseline=False)
    bad = []
    for topo, rec in sorted(base.items()):
        cur = now.get(topo)
        if cur is None:
            bad.append(f"{topo}: baseline row missing from current run")
            continue
        if cur["hier_inter_wire_bytes"] != rec["hier_inter_wire_bytes"]:
            bad.append(
                f"{topo}: hier_inter_wire_bytes changed "
                f"{rec['hier_inter_wire_bytes']} -> "
                f"{cur['hier_inter_wire_bytes']}"
                + (" (GROWTH)" if cur["hier_inter_wire_bytes"]
                   > rec["hier_inter_wire_bytes"] else
                   " (re-record the baseline if intended)"))
        if cur["flat"] != rec["flat"]:
            bad.append(f"{topo}: flat-vs-hier resolution flipped "
                       f"{rec['flat']} -> {cur['flat']}")
    if bad:
        for msg in bad:
            print(f"::error::hier wire regression: {msg}")
        sys.exit(1)
    print(f"hier wire: inter-node provisioned bytes match baseline for "
          f"topologies {sorted(base)}")


def check_faults_overhead(here: pathlib.Path) -> None:
    """Degradation-path pricing of the resolved plans vs the committed
    BENCH_faults.json.

    Every field is a STATIC plan/model quantity (provisioned wire bytes
    of the compressed schedule and its lossless fallback, modeled
    fallback time — no wall-clock), so the comparison is EXACT and any
    drift is fatal regardless of ``--strict``: a planner change that
    silently inflates the fallback schedule, or stops provisioning the
    raw payload it must be able to ship losslessly, is a structural
    regression on the ISSUE 7 degradation contract and must not hide
    inside a timing threshold.
    """
    from benchmarks import faults_bench

    base_path = here / "BENCH_faults.json"
    if not base_path.exists():
        # A missing baseline must not read as "no regression".
        print(f"::error::faults overhead baseline missing: {base_path}")
        sys.exit(1)
    base = json.loads(base_path.read_text())["faults"]
    now = faults_bench.run([], record_baseline=False)
    bad = []
    for key, rec in sorted(base.items()):
        cur = now.get(key)
        if cur is None:
            bad.append(f"{key}: baseline row missing from current run")
            continue
        for field, want in sorted(rec.items()):
            got = cur.get(field)
            if got != want:
                bad.append(f"{key}.{field}: {want} -> {got} "
                           f"(re-record the baseline if intended)")
    if bad:
        for msg in bad:
            print(f"::error::faults overhead regression: {msg}")
        sys.exit(1)
    print(f"faults overhead: fallback wire/pricing match baseline for "
          f"{len(base)} (op, axis-size) points")


def check_gradsync(here: pathlib.Path) -> None:
    """Bucketed grad-sync provisioning vs the committed BENCH_gradsync.json.

    Every compared field is a STATIC plan/model quantity — the co-planned
    bucket size, bucket count, per-bucket and total provisioned wire
    bytes, and the modeled schedule times (deterministic functions of the
    calibrated Hardware point, no wall-clock) — so the comparison is
    EXACT and any drift is fatal regardless of ``--strict``.  Wire GROWTH
    in particular is the structural regression this gate exists for: a
    planner or ledger change that quietly ships more gradient bytes per
    step must not ride in under a timing threshold.  The bench itself
    asserts the ISSUE 9 acceptance invariant (modeled overlapped step
    strictly below serial backward+sync for every recorded model size).
    """
    from benchmarks import gradsync_bench

    base_path = here / "BENCH_gradsync.json"
    if not base_path.exists():
        # A missing baseline must not read as "no regression".
        print(f"::error::gradsync baseline missing: {base_path}")
        sys.exit(1)
    base = json.loads(base_path.read_text())["gradsync"]
    now = gradsync_bench.run([], record_baseline=False)
    bad = []
    for name, rec in sorted(base.items()):
        cur = now.get(name)
        if cur is None:
            bad.append(f"{name}: baseline model size missing from current run")
            continue
        for field, want in sorted(rec.items()):
            got = cur.get(field)
            if got != want:
                grew = (field.endswith("wire_bytes") and isinstance(got, int)
                        and got > want)
                bad.append(f"{name}.{field}: {want} -> {got} "
                           + ("(WIRE GROWTH)" if grew else
                              "(re-record the baseline if intended)"))
    if bad:
        for msg in bad:
            print(f"::error::gradsync regression: {msg}")
        sys.exit(1)
    print(f"gradsync: bucket plan/wire/schedule match baseline for model "
          f"sizes {sorted(base)}")


def check_codec_ratio(here: pathlib.Path) -> None:
    """Per-codec wire ratio vs the committed BENCH_codec.json.

    ``payload_bytes``/``ratio`` are deterministic given (data, eb) — the
    bench compresses a fixed-seed tensor — so the comparison is EXACT and
    any drift is fatal regardless of ``--strict``: an entropy-stage or
    provisioning change that quietly fattens the wire (or a registry edit
    that silently swaps a codec's compressor) is a structural regression
    on the ISSUE 8 contract and must not hide inside a timing threshold.
    Wall-clock fields (``*_us``) are machine-specific and excluded.
    """
    from benchmarks import codec_bench

    base_path = here / "BENCH_codec.json"
    if not base_path.exists():
        # A missing baseline must not read as "no regression".
        print(f"::error::codec ratio baseline missing: {base_path}")
        sys.exit(1)
    base = json.loads(base_path.read_text())["codec"]
    now = codec_bench.run([], record_baseline=False)
    bad = []
    for name, rec in sorted(base.items()):
        cur = now.get(name)
        if cur is None:
            bad.append(f"{name}: baseline codec missing from current run")
            continue
        for field, want in sorted(rec.items()):
            if field.endswith("_us"):
                continue  # wall-clock: machine-specific, not comparable
            got = cur.get(field)
            if got != want:
                bad.append(f"{name}.{field}: {want} -> {got} "
                           f"(re-record the baseline if intended)")
    if bad:
        for msg in bad:
            print(f"::error::codec ratio regression: {msg}")
        sys.exit(1)
    print(f"codec ratio: payload/ratio match baseline for codecs "
          f"{sorted(base)}")


def _ratios(record):
    """{size: {fused metric: fused_us / reference_us}} for a benchmark
    record shaped {size: {"fused": {..._us}, "unfused"|"two_kernel": {...}}}.
    """
    out = {}
    for size, rec in record.items():
        ref_key = "unfused" if "unfused" in rec else "two_kernel"
        if "fused" not in rec or ref_key not in rec:
            continue
        for metric, fused_us in rec["fused"].items():
            if not metric.endswith("us"):
                continue
            ref_us = float(rec[ref_key].get(metric, 0.0))
            if ref_us > 0:
                out[f"{size}/{metric}"] = float(fused_us) / ref_us
    return out


def _compare(name, baseline, current, threshold):
    base, cur = _ratios(baseline), _ratios(current)
    regressions = []
    for path, base_ratio in sorted(base.items()):
        if path not in cur:
            # A silently vanished metric must not read as "no regression".
            print(f"::warning::{name}:{path}: baseline metric missing from "
                  f"current run (renamed or dropped?)")
            continue
        rel = cur[path] / base_ratio
        status = "REGRESSION" if rel > 1 + threshold else "ok"
        print(f"{name}:{path}: fused/ref ratio baseline={base_ratio:.2f} "
              f"current={cur[path]:.2f} ({rel:.2f}x) {status}")
        if rel > 1 + threshold:
            regressions.append((f"{name}:{path}", rel))
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on ratio regressions (default: report)")
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    args = ap.parse_args()

    here = pathlib.Path(__file__).parent
    from benchmarks import compressor_char, hop_bench

    # Structural invariants, independent of timing noise: fatal on mismatch.
    check_step_count_consistency()
    check_schedule_authority(here)
    check_schedule_wire_parity()
    check_scatter_wire(here)
    check_hier_wire(here)
    check_faults_overhead(here)
    check_codec_ratio(here)
    check_gradsync(here)

    regressions = []

    compress_base = json.loads((here / "BENCH_compress.json").read_text())
    compress_now = compressor_char.run_fused_vs_unfused(
        [], record_baseline=False
    )
    regressions += _compare(
        "compress", compress_base["fused_vs_unfused"], compress_now,
        args.threshold,
    )

    # run() asserts the structural 1-kernel-per-fused-hop contract — that
    # check must fire even when no baseline exists to compare against.
    hop_now = hop_bench.run([], record_baseline=False)
    hop_path = here / "BENCH_hop.json"
    if hop_path.exists():
        hop_base = json.loads(hop_path.read_text())
        regressions += _compare("hop", hop_base["hop"], hop_now, args.threshold)

    for path, rel in regressions:
        print(f"::warning::fused-path ratio regression >"
              f"{args.threshold:.0%} at {path}: {rel:.2f}x baseline "
              f"(interpret-mode wall-clock is noisy — treat as indicative; "
              f"the kernel-count assert above is the authoritative signal)")
    if regressions and args.strict:
        sys.exit(1)
    print(f"{len(regressions)} regression(s) above "
          f"{args.threshold:.0%} threshold")


if __name__ == "__main__":
    main()
