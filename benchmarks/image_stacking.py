"""Table 2 / Fig. 13 analog: image-stacking application.

Image stacking IS an Allreduce of float images (paper §4.5).  We run the
REAL algorithms through the N-rank simulator (16 ranks), measure
reconstruction quality (PSNR / NRMSE) of the stacked image vs the exact
sum, and report the modeled performance breakdown (compression / comm /
reduction shares) like Table 2.
"""
from __future__ import annotations

import numpy as np

from benchmarks.benchutil import noisy_images
from repro.core import cost_model as cm
from repro.core.collectives import GZConfig
from repro.core.simulator import (
    sim_allreduce_intring,
    sim_allreduce_redoub,
    sim_allreduce_ring,
)

N_RANKS = 16       # ranks for the REAL simulator run (accuracy analysis)
N_MODEL = 512      # the paper's scale for the modeled performance columns
H = W = 512


def psnr(a, b):
    mse = float(np.mean((a - b) ** 2))
    rng = float(a.max() - a.min())
    return 10 * np.log10(rng * rng / mse) if mse else np.inf


def nrmse(a, b):
    return float(np.sqrt(np.mean((a - b) ** 2)) / (a.max() - a.min()))


def run(csv_rows: list):
    xs = noisy_images(N_RANKS, H, W, seed=3)
    exact = np.sum(xs, axis=0)
    eb = 1e-4 * float(np.abs(exact).max())
    flat = [x.reshape(-1) for x in xs]

    algos = {
        "redoub": sim_allreduce_redoub,
        "ring": sim_allreduce_ring,
        "intring": sim_allreduce_intring,
    }
    D = exact.nbytes
    hw = cm.A100_SLINGSHOT
    model_t = {
        "redoub": cm.allreduce_redoub_gz(D, N_MODEL, 30, hw),
        "ring": cm.allreduce_ring_gz(D, N_MODEL, 30, hw),
        "intring": cm.allreduce_intring_gz(D, N_MODEL, 30, hw),
    }
    cray = cm.allreduce_uncompressed_ring(D, N_MODEL, hw) * 2.2
    nccl = cm.allreduce_uncompressed_ring(D, N_MODEL, hw)

    for name, fn in algos.items():
        cfg = GZConfig(eb=eb, capacity_factor=1.2, worst_case_budget=False)
        outs = fn(flat, cfg)
        img = outs[0].reshape(H, W)
        p = psnr(exact, img)
        e = nrmse(exact, img)
        t = model_t[name]
        csv_rows.append(
            (
                f"table2_stacking_{name}",
                t * 1e6,
                f"psnr={p:.2f};nrmse={e:.2e};"
                f"speedup_vs_cray={cray/t:.2f};speedup_vs_nccl={nccl/t:.2f}",
            )
        )
        # paper: PSNR ~57 dB at eb 1e-4; require high-quality reconstruction
        assert p > 45.0, (name, p)
