"""Degradation-path cost benchmark (DESIGN.md §9).

For each (op, axis size) this resolves the SAME frozen plan production
resolves — with ``on_overflow="fallback"`` so the plan carries its
lossless degradation target — and records the STATIC quantities that
price a degraded call:

  * ``compressed_wire_bytes``  — the provisioned compressed schedule wire
    (what every healthy call ships);
  * ``fallback_wire_bytes``    — the raw f32 payload the lossless
    re-execute moves (compression ratio forfeited);
  * ``wire_overhead``          — fallback / compressed wire: the byte
    multiple a degraded call ships on top of the compressed streams (the
    overflow is only known once the streams have been exchanged, so a
    degraded call pays both);
  * ``t_fallback_us``          — ``cost_model.fallback_time`` on the
    calibrated A100/Slingshot point.

For allreduce — the only op whose COMPRESSED schedule the cost model
prices (the same functions the policies rank) — it additionally records
``t_compressed_us``, ``degraded_call_overhead`` (t_fallback /
t_compressed) and ``expected_us_at_p1e-3``
(``cost_model.expected_collective_time`` at a 0.1% degradation rate):
the numbers that show a rare fallback costs ~nothing while a hot one
forfeits the compression win.

All static plan/model quantities — no wall-clock — so the committed
BENCH_faults.json baseline is compared EXACTLY by
``regression_check.check_faults_overhead`` and any drift is fatal: a
planner change that silently inflates the fallback (or prices it into
oblivion) cannot hide inside timing noise.
"""
from __future__ import annotations

import json
import pathlib

from repro.core import comm
from repro.core import cost_model as cm

HW = cm.A100_SLINGSHOT
RATIO = 20.0
D_MB = 64  # per-rank payload: gradient-sync-sized
OPS = ("allreduce", "reduce_scatter", "allgather", "scatter", "broadcast")
NS = (4, 8, 16)
P_DEGRADED = 1e-3
BASELINE_PATH = pathlib.Path(__file__).parent / "BENCH_faults.json"


def plan_record(op: str, n_ranks: int, n_elems: int) -> dict:
    plan = comm._resolve_plan(
        op, n_elems, "float32", n_ranks, 1e-4,
        policy="auto", requested_algo=None, requested_chunks=0,
        capacity_factor=0.6, worst_case_budget=True, fused=True,
        fused_hop=True, ratio=RATIO, hw=HW,
        on_overflow="fallback", verify_streams=False,
    )
    fb = plan.fallback
    assert fb is not None and fb.op == op, plan
    t_fb = fb.t_model
    rec = {
        "algo": plan.algo,
        "compressed_wire_bytes": plan.wire_bytes,
        "fallback_wire_bytes": fb.wire_bytes,
        "fallback_kind": fb.kind,
        "wire_overhead": round(fb.wire_bytes / plan.wire_bytes, 4),
        "t_fallback_us": round(t_fb * 1e6, 2),
    }
    if op == "allreduce":
        t_comp = comm._allreduce_model_time(
            plan.algo, plan.nbytes, n_ranks, RATIO, HW,
            plan.pipeline_chunks, True,
        )
        rec["t_compressed_us"] = round(t_comp * 1e6, 2)
        rec["degraded_call_overhead"] = round(t_fb / t_comp, 4)
        rec["expected_us_at_p1e-3"] = round(
            cm.expected_collective_time(t_comp, t_fb, P_DEGRADED) * 1e6, 2
        )
    return rec


def run(csv_rows: list, record_baseline: bool = True) -> dict:
    n_elems = int(D_MB * 1e6 / 4)
    record = {}
    for op in OPS:
        for n in NS:
            rec = plan_record(op, n, n_elems)
            # The fallback must genuinely be the uncompressed payload —
            # a "lossless fallback" that still quotes compressed bytes
            # would be the silent-corruption hazard wearing a new hat.
            assert rec["fallback_wire_bytes"] == n_elems * 4, (op, n, rec)
            assert rec["t_fallback_us"] > 0.0, (op, n, rec)
            key = f"{op}@{n}"
            record[key] = rec
            derived = (f"wire_overhead={rec['wire_overhead']}x,"
                       f"kind={rec['fallback_kind']}")
            if "expected_us_at_p1e-3" in rec:
                derived += (f",degraded_overhead="
                            f"{rec['degraded_call_overhead']}x,"
                            f"expected_us_p{P_DEGRADED}="
                            f"{rec['expected_us_at_p1e-3']}")
            csv_rows.append(
                (f"faults_{op}_{D_MB}MB_n{n}", rec["t_fallback_us"], derived)
            )
    if record_baseline:
        BASELINE_PATH.write_text(
            json.dumps({"faults": record}, indent=1, sort_keys=True) + "\n"
        )
    return record
