"""Figs. 7/9/10 analogs: Allreduce — gZ variants vs NCCL/Cray-MPI models.

Two parts:
  1. REAL execution: the shard_map gz_allreduce on 8 virtual host devices
     (measured compressed payload bytes + verified error) — run via
     subprocess so the device count doesn't leak into other benches.
  2. MODELED wall-time (calibrated cost model, A100/Slingshot-10): the
     paper's message-size sweep (Fig. 9) and GPU-count sweep (Fig. 10),
     reporting speedups of gZ-ReDoub/gZ-Ring over the NCCL and Cray MPI
     analogs, plus the beyond-paper intring.
"""
from __future__ import annotations

from repro.core import cost_model as cm

HW = cm.A100_SLINGSHOT
RATIO = 60.0  # paper Table 1 reports 46-94x on RTM data at 1e-4


def run(csv_rows: list):
    # Fig 9: message-size sweep at 64 GPUs
    n = 64
    for mb in [50, 100, 200, 400, 600]:
        d = mb * 1e6
        nccl = cm.allreduce_uncompressed_ring(d, n, HW)
        cray = nccl * 2.2  # paper: Cray MPI trails NCCL by ~2-5x at scale
        redoub = cm.allreduce_redoub_gz(d, n, RATIO, HW)
        ring = cm.allreduce_ring_gz(d, n, RATIO, HW)
        intring = cm.allreduce_intring_gz(d, n, RATIO, HW)
        csv_rows.append(
            (
                f"fig9_allreduce_{mb}MB_64gpu",
                redoub * 1e6,
                f"speedup_vs_nccl={nccl/redoub:.2f};"
                f"speedup_vs_cray={cray/redoub:.2f};"
                f"ring_us={ring*1e6:.0f};intring_us={intring*1e6:.0f}",
            )
        )
    # Fig 10: GPU-count sweep at 646 MB
    d = 646e6
    for n in [8, 16, 32, 64, 128, 256, 512]:
        nccl = cm.allreduce_uncompressed_ring(d, n, HW)
        redoub = cm.allreduce_redoub_gz(d, n, RATIO, HW)
        ring = cm.allreduce_ring_gz(d, n, RATIO, HW)
        csv_rows.append(
            (
                f"fig10_allreduce_646MB_{n}gpu",
                redoub * 1e6,
                f"speedup_vs_nccl={nccl/redoub:.2f};"
                f"ring_vs_nccl={nccl/ring:.2f}",
            )
        )
    # paper-claim checks (direction + magnitude band)
    n, d = 512, 646e6
    s = cm.allreduce_uncompressed_ring(d, n, HW) / cm.allreduce_redoub_gz(
        d, n, RATIO, HW
    )
    # our alpha-beta model is conservative at 512 (paper: 4.5x; redoub wire
    # grows log2(N)*D here) — require the win, not the paper's constant
    assert s > 1.2, f"ReDoub should beat the NCCL analog at 512 ({s:.2f})"
    s64 = cm.allreduce_uncompressed_ring(d, 64, HW) / cm.allreduce_redoub_gz(
        d, 64, RATIO, HW
    )
    assert s64 > 1.8, s64
    # ring's scalability collapse (paper: worst at 512)
    assert cm.allreduce_ring_gz(d, 512, RATIO, HW) > cm.allreduce_ring_gz(
        d, 64, RATIO, HW
    )
    # Fig 2 analog: prior-work baselines
    for name, fn in [
        ("cprp2p", cm.allreduce_cprp2p),
        ("ccoll", cm.allreduce_ccoll),
    ]:
        t = fn(d, 64, RATIO, HW)
        gz = cm.allreduce_ring_gz(d, 64, RATIO, HW)
        csv_rows.append(
            (f"fig2_{name}_646MB_64gpu", t * 1e6, f"vs_gz_ring={t/gz:.2f}x")
        )
