"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per the repo convention);
derived packs the figure-specific metrics (speedups, ratios, PSNR...).
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (
    allreduce_bench,
    breakdown,
    codec_bench,
    compressor_char,
    faults_bench,
    gradsync_bench,
    hier_bench,
    hop_bench,
    image_stacking,
    moe_a2a_ablation,
    scatter_bench,
    table1_ratio,
)

MODULES = [
    ("fig3_compressor_characterization", compressor_char),
    ("fig2_breakdown", breakdown),
    ("fig7_9_10_allreduce", allreduce_bench),
    ("fig11_12_scatter", scatter_bench),
    ("issue6_hier_allreduce", hier_bench),
    ("table1_compression_ratio", table1_ratio),
    ("table2_fig13_image_stacking", image_stacking),
    ("beyond_moe_a2a_ablation", moe_a2a_ablation),
    ("issue2_fused_hop", hop_bench),
    ("issue7_faults", faults_bench),
    ("issue8_codecs", codec_bench),
    ("issue9_gradsync", gradsync_bench),
]


def main() -> None:
    rows = []
    failed = []
    for name, mod in MODULES:
        try:
            mod.run(rows)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
