"""Per-hop microbenchmark: single-pass fused ring hop vs the PR 1
two-kernel composition (ISSUE 2 acceptance).

Two metrics per payload size:

  * ``pallas_calls`` — kernel invocations per intermediate ring hop,
    counted structurally in the jaxpr (2 for decompress_reduce + compress,
    1 for the fused ``decompress_reduce_compress``).  This is the number
    that matters on hardware: each invocation is a dispatch + pipeline
    fill AND an HBM round-trip boundary for the f32 intermediate.
  * ``us`` — CPU interpret-mode wall-clock (op-count / memory-traffic
    proxy, not TPU time; same caveat as BENCH_compress.json).

Records benchmarks/BENCH_hop.json so future PRs have a per-hop perf
trajectory, and ASSERTS the structural 2 -> 1 kernel-count win (that part
is exact, not a timing).
"""
from __future__ import annotations

import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.benchutil import time_it as _time_it
from repro.core.compressor import ErrorBoundedLorenzo

SIZES_MB = [1, 4]
BASELINE_PATH = pathlib.Path(__file__).parent / "BENCH_hop.json"


def count_pallas_calls(fn, *args) -> int:
    """Structural kernel-invocation count: pallas_call eqns in the jaxpr,
    recursing through pjit/scan/cond sub-jaxprs."""
    def _subjaxprs(v):
        if isinstance(v, (tuple, list)):
            for item in v:
                yield from _subjaxprs(item)
        elif hasattr(v, "jaxpr"):  # ClosedJaxpr
            yield v.jaxpr
        elif hasattr(v, "eqns"):  # raw Jaxpr
            yield v

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    n += walk(sub)
        return n

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def run(csv_rows: list, record_baseline: bool = True) -> dict:
    rng = np.random.default_rng(2)
    comp = ErrorBoundedLorenzo(capacity_factor=1.1, fused=True)
    eb = 1e-4
    record = {}
    for mb in SIZES_MB:
        n = int(mb * 1e6 / 4)
        x = jnp.asarray(np.cumsum(rng.normal(0, 0.01, n)).astype(np.float32))
        acc = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
        c = comp.compress(x, eb)

        def two_kernel_hop(c=c, acc=acc):
            updated = comp.decompress_reduce(c, acc)
            return comp.compress(updated, c.eb).packed

        def fused_hop(c=c, acc=acc):
            return comp.decompress_reduce_compress(c, acc)[0].packed

        calls_two = count_pallas_calls(two_kernel_hop)
        calls_fused = count_pallas_calls(fused_hop)
        # The structural contract — exact, independent of timing noise.
        assert calls_two == 2, calls_two
        assert calls_fused == 1, calls_fused

        t_two = _time_it(two_kernel_hop, reps=5)
        t_fused = _time_it(fused_hop, reps=5)
        record[f"{mb}MB"] = {
            "two_kernel": {"us": t_two * 1e6, "pallas_calls": calls_two},
            "fused": {"us": t_fused * 1e6, "pallas_calls": calls_fused},
        }
        csv_rows.append(
            (
                f"hop_fused_{mb}MB",
                t_fused * 1e6,
                f"two_kernel_us={t_two*1e6:.0f};"
                f"kernels_per_hop={calls_fused}(was {calls_two});"
                f"speedup={t_two/t_fused:.2f}x",
            )
        )
    if record_baseline:
        BASELINE_PATH.write_text(
            json.dumps(
                {
                    "backend": jax.default_backend(),
                    "note": "CPU interpret-mode; pallas_calls is the "
                            "structural kernel count per intermediate ring hop",
                    "hop": record,
                },
                indent=2,
            )
            + "\n"
        )
    return record
