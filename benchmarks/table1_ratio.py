"""Table 1 analog: compression ratio + PSNR vs error bound on RTM-like data.

The paper's two RTM datasets are proprietary SEG/EAGE Overthrust sims; we
generate synthetic 3D wavefields with matched spectral character (layered
velocity + band-limited wave packets) at the paper's two grid sizes, then
report CPR and PSNR at ABS in {1e-3, 1e-4, 1e-5} like Table 1.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.compressor import ErrorBoundedLorenzo


def rtm_like_field(shape, seed=0) -> np.ndarray:
    """Band-limited 3D wavefield: smooth layers + oscillatory packets."""
    rng = np.random.default_rng(seed)
    z = np.linspace(0, 1, shape[0])[:, None, None]
    x = np.linspace(0, 1, shape[1])[None, :, None]
    y = np.linspace(0, 1, shape[2])[None, None, :]
    # RTM wavefields are SPARSE: localized wavefront shells over a
    # near-zero background (that sparsity is where cuSZp's 46-94x comes
    # from — zero-delta blocks pack at 0-1 bits).
    field = np.zeros(np.broadcast_shapes(z.shape, x.shape, y.shape))
    for i in range(2):
        c = rng.random(3) * 0.6 + 0.2
        r = np.sqrt((z - c[0]) ** 2 + (x - c[1]) ** 2 + (y - c[2]) ** 2)
        shell = np.exp(-((r - 0.12) ** 2) / (2 * 0.018**2))  # wavefront shell
        field += shell * np.sin(40 * r + i)
    field += rng.normal(0, 2e-6, shape)  # sensor noise floor (quiet zone)
    return field.astype(np.float32)


def psnr(a: np.ndarray, b: np.ndarray) -> float:
    mse = float(np.mean((a - b) ** 2))
    rng = float(a.max() - a.min())
    return 10 * np.log10(rng * rng / mse) if mse else np.inf


SETTINGS = {
    # paper grids: 449x449x235 and 849x849x235 — scaled to CPU-feasible
    # proportional grids (same aspect ratio / spectral content)
    "sim1": (160, 160, 96),
    "sim2": (288, 288, 96),
}


def run(csv_rows: list):
    comp = ErrorBoundedLorenzo(capacity_factor=1.1)
    for name, shape in SETTINGS.items():
        x = rtm_like_field(shape, seed=hash(name) % 2**31)
        flat = jnp.asarray(x.reshape(-1))
        for eb_rel in [1e-3, 1e-4, 1e-5]:
            eb = eb_rel * float(np.abs(x).max())
            c = comp.compress(flat, eb)
            y = np.asarray(comp.decompress(c)).reshape(shape)
            ratio = x.nbytes / float(np.asarray(c.payload_bytes()))
            p = psnr(x, y)
            err = float(np.abs(x - y).max())
            assert err <= eb * 1.001 + np.abs(x).max() * 2e-7
            csv_rows.append(
                (
                    f"table1_{name}_abs{eb_rel:.0e}",
                    ratio,
                    f"psnr={p:.2f};max_err={err:.2e};eb={eb:.2e}",
                )
            )
