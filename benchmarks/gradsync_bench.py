"""Bucketed gradient-sync benchmark (ISSUE 9): the backward-overlap
co-planner at the calibrated A100/Slingshot point, for two model sizes.

``cost_model.best_bucket_plan`` picks (bucket_bytes, ring pipeline depth)
jointly so per-bucket codec+wire work hides under BOTH the remaining
backward FLOPs and the previous bucket's wire time.  This bench resolves
the SAME frozen per-bucket plan production resolves (one Plan serves
every bucket — uniform ledger payloads) and records, per model size:

  * the chosen ``bucket_bytes`` / ``n_buckets`` / ``pipeline_chunks``,
  * ``per_bucket_wire_bytes`` and the whole-tree total — static plan
    provisioning, compared EXACTLY by ``regression_check.py`` (growth is
    fatal: a planner change that quietly ships more gradient bytes
    cannot hide inside timing noise),
  * modeled overlapped vs serial (backward + sync) step seconds and the
    resulting ``overlap_efficiency``.

The ISSUE 9 acceptance criterion — modeled overlapped step time STRICTLY
below serial backward+sync for >= 2 model sizes — is asserted on every
run.
"""
from __future__ import annotations

import json
import pathlib

from repro.core import cost_model as cm
from repro.core.comm import _resolve_plan

HW = cm.A100_SLINGSHOT
RATIO = 20.0
N = 8            # data-parallel degree
TOKENS = 4096    # tokens per step for the backward-FLOPs estimate
MODELS = {
    "125M": 125e6,
    "1.3B": 1.3e9,
}
BASELINE_PATH = pathlib.Path(__file__).parent / "BENCH_gradsync.json"


def bucket_record(n_params: float) -> dict:
    tree_bytes = 4.0 * n_params
    backward_flops = 4.0 * n_params * TOKENS
    bp = cm.best_bucket_plan(HW, tree_bytes, backward_flops, N, RATIO)
    bucket_elems = bp.bucket_bytes // 4
    plan = _resolve_plan(
        "allreduce", bucket_elems, "float32", N, 1e-4,
        policy="auto", requested_algo=None,
        requested_chunks=bp.pipeline_chunks,
        capacity_factor=0.6, worst_case_budget=False, fused=True,
        fused_hop=True, ratio=RATIO, hw=HW,
    )
    return {
        "n_params": int(n_params),
        "bucket_bytes": bp.bucket_bytes,
        "n_buckets": bp.n_buckets,
        "pipeline_chunks": bp.pipeline_chunks,
        "algo": plan.algo,
        "per_bucket_wire_bytes": plan.wire_bytes,
        "total_wire_bytes": plan.wire_bytes * bp.n_buckets,
        "t_backward_ms": round(bp.t_backward * 1e3, 3),
        "t_sync_ms": round(bp.t_sync_total * 1e3, 3),
        "t_serial_ms": round(bp.t_serial * 1e3, 3),
        "t_overlapped_ms": round(bp.t_overlapped * 1e3, 3),
        "overlap_efficiency": round(bp.overlap_efficiency, 4),
    }


def run(csv_rows: list, record_baseline: bool = True) -> dict:
    assert HW.compute_tflops > 0, (
        "the calibrated A100 point must carry a compute rate — without it "
        "backward is modeled free and overlap cannot be priced"
    )
    record = {}
    for name, n_params in MODELS.items():
        rec = bucket_record(n_params)
        # ISSUE 9 acceptance: strictly below serial for every recorded size.
        assert rec["t_overlapped_ms"] < rec["t_serial_ms"], (name, rec)
        assert rec["n_buckets"] >= 2, (name, rec)
        record[name] = rec
        csv_rows.append(
            (f"gradsync_overlap_{name}_n{N}",
             rec["t_overlapped_ms"] * 1e3,
             f"serial_us={rec['t_serial_ms'] * 1e3:.0f},"
             f"buckets={rec['n_buckets']}x{rec['bucket_bytes'] >> 20}MiB,"
             f"eff={rec['overlap_efficiency']:.3f}")
        )
    if record_baseline:
        BASELINE_PATH.write_text(
            json.dumps({"gradsync": record}, indent=1, sort_keys=True) + "\n"
        )
    return record
