"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import time

import numpy as np


def time_it(fn, reps: int = 3) -> float:
    """Min seconds per call after one warmup (jit cache + async drain).

    Min-of-reps, not mean: scheduler noise only ever ADDS time, so the
    minimum is the stable estimator a cross-run ratio check can trust.
    """
    import jax

    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def noisy_images(n: int, h: int, w: int, seed: int = 0) -> list:
    """n noisy observations of the same smooth scene (stacking input)."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64) / max(h, w)
    scene = (
        np.sin(9 * xx + 3 * yy)
        + 0.6 * np.cos(14 * yy - 4 * xx * xx)
        + np.exp(-((xx - 0.5) ** 2 + (yy - 0.4) ** 2) * 12)
    )
    return [
        (scene + rng.normal(0, 0.15, (h, w))).astype(np.float32)
        for _ in range(n)
    ]
