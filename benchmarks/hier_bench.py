"""Two-level topology benchmark: flat compressed ring vs the planned
hierarchical allreduce on the calibrated A100/Slingshot point (ISSUE 6).

The paper's 512-GPU numbers live where NVLink is ~48x the node fabric, so
compression only pays on the slow hop.  This bench resolves the SAME
frozen plans production resolves (``comm._resolve_hier_plan``) at
node×local topologies 2×4 / 3×4 / 4×8 and records, per topology:

  * ``flat_inter_wire_bytes``  — the single-axis plan's provisioned
    per-rank send bytes; in node-major rank order a node-boundary rank's
    EVERY send crosses the fabric, so this is what the flat schedule
    puts on the scarce link.
  * ``hier_inter_wire_bytes``  — the inter sub-plan's provisioned bytes
    (the compressed allreduce of the 1/L shard across nodes — the only
    traffic that leaves a node under the two-level schedule).
  * modeled times of both paths per the per-link cost model.

These are STATIC plan quantities (schedule structure, not wall-clock), so
``regression_check.py`` compares the inter-node wire EXACTLY and treats
any growth as fatal — a planner change that quietly ships more bytes
across nodes cannot hide inside timing noise.  The acceptance invariant
(hier strictly less inter wire AND lower modeled time than the flat
compressed ring at >= 8 devices with intra:inter >= 4:1) is asserted on
every run.
"""
from __future__ import annotations

import json
import pathlib

from repro.core import cost_model as cm
from repro.core.comm import _resolve_hier_plan

HW = cm.A100_SLINGSHOT
RATIO = 20.0
D_MB = 64  # per-rank message: a gradient-sync-sized payload
TOPOLOGIES = [(2, 4), (3, 4), (4, 8)]
BASELINE_PATH = pathlib.Path(__file__).parent / "BENCH_hier.json"


def plan_record(topology: tuple, n_elems: int) -> dict:
    """Resolve the production hier plan for one topology and reduce it to
    the static record the baseline pins."""
    plan = _resolve_hier_plan(
        "allreduce", n_elems, "float32", topology, 1e-4,
        policy="auto", requested_algo=None, requested_chunks=0,
        capacity_factor=0.6, worst_case_budget=True, fused=True,
        fused_hop=True, ratio=RATIO, hw=HW,
    )
    return {
        "flat": plan.flat,
        "flat_algo": plan.flat_plan.algo,
        "inter_algo": plan.inter.algo if plan.inter else None,
        "flat_inter_wire_bytes": plan.flat_plan.wire_bytes,
        "hier_inter_wire_bytes": plan.inter_wire_bytes,
        "intra_wire_bytes": plan.intra_wire_bytes,
        "t_flat_us": round(plan.t_flat * 1e6, 2),
        "t_hier_us": round(plan.t_model * 1e6, 2),
    }


def run(csv_rows: list, record_baseline: bool = True) -> dict:
    assert HW.link_asymmetry() >= 4.0, (
        "the calibrated A100 point must model the >= 4:1 link asymmetry "
        f"regime; got {HW.link_asymmetry():.1f}:1"
    )
    n_elems = int(D_MB * 1e6 / 4)
    record = {}
    for topology in TOPOLOGIES:
        n_nodes, local = topology
        rec = plan_record(topology, n_elems)
        # Acceptance invariant: at >= 8 devices under real asymmetry, the
        # hierarchy strictly beats the flat compressed ring on BOTH the
        # scarce wire and the modeled clock.
        if n_nodes * local >= 8:
            assert not rec["flat"], f"{topology}: planner chose flat"
            assert rec["hier_inter_wire_bytes"] < rec["flat_inter_wire_bytes"], topology
            assert rec["t_hier_us"] < rec["t_flat_us"], topology
        key = f"{n_nodes}x{local}"
        record[key] = rec
        csv_rows.append(
            (f"hier_allreduce_{D_MB}MB_{key}", rec["t_hier_us"],
             f"flat_us={rec['t_flat_us']},"
             f"inter_wire_reduction="
             f"{rec['flat_inter_wire_bytes'] / rec['hier_inter_wire_bytes']:.2f}x")
        )
    if record_baseline:
        BASELINE_PATH.write_text(
            json.dumps({"hier": record}, indent=1, sort_keys=True) + "\n"
        )
    return record
