"""Per-codec wire ratio + throughput benchmark (DESIGN.md §10).

For every registered wire codec this compresses the SAME smooth standard
tensor (the cumsum random walk every calibration and codec test uses, at
the default eb) and records:

  * ``payload_bytes`` / ``ratio`` — TRUE shipped bytes via the
    container's ``payload_bytes()`` and the resulting compression ratio.
    Deterministic given (data, eb), so the committed BENCH_codec.json
    baseline is compared EXACTLY by ``regression_check.check_codec_ratio``
    and any ratio loss is fatal: an entropy-stage change that quietly
    fattens the wire cannot hide inside timing noise.
  * ``compress_us`` / ``decompress_us`` — wall-clock per call
    (machine-specific, excluded from the exact comparison).

The run itself asserts the ISSUE 8 acceptance inequality — the entropy
codec's measured ratio is STRICTLY higher than the dense bitpack on
smooth tensors — and that every lossy codec round-trips within eb while
the exact codecs round-trip bitwise.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import codecs

EB = 1e-4
N_ELEMS = 1 << 16
REPS = 3
BASELINE_PATH = pathlib.Path(__file__).parent / "BENCH_codec.json"


def smooth_tensor(n: int, seed: int = 0) -> jnp.ndarray:
    """The standard smooth benchmark tensor: a cumulative random walk —
    small Lorenzo deltas, the regime compressed collectives target."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.cumsum(rng.normal(0, 0.01, n)), jnp.float32)


def _time_us(fn, reps: int = REPS) -> float:
    jax.block_until_ready(fn())  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def codec_record(name: str, x: jnp.ndarray) -> dict:
    comp = codecs.build_compressor(name, capacity_factor=0.6, fused=True)
    spec = codecs.get_codec(name)
    c = comp.compress(x, EB)
    assert not bool(c.overflowed()), name
    y = comp.decompress(c)
    if spec.lossy:
        err = float(jnp.max(jnp.abs(y - x)))
        # eb plus one f32 ulp at the tensor's magnitude: the reconstruction
        # rounds anchor + code*2eb once in f32.
        ulp = float(jnp.max(jnp.abs(x))) * np.finfo(np.float32).eps
        assert err <= EB + ulp, (name, err)
    else:
        np.testing.assert_array_equal(
            np.asarray(x).view(np.uint32), np.asarray(y).view(np.uint32)
        )
    payload = int(jax.device_get(c.payload_bytes()))
    return {
        "payload_bytes": payload,
        "ratio": round(x.size * 4 / max(payload, 1), 4),
        "lossy": spec.lossy,
        "fused_hop": spec.fused_hop,
        "compress_us": round(_time_us(lambda: comp.compress(x, EB)), 2),
        "decompress_us": round(_time_us(lambda: comp.decompress(c)), 2),
    }


def run(csv_rows: list, record_baseline: bool = True) -> dict:
    x = smooth_tensor(N_ELEMS)
    record = {}
    for name in codecs.codec_names():
        rec = codec_record(name, x)
        record[name] = rec
        csv_rows.append((
            f"codec_{name}_{N_ELEMS >> 8}KB",
            rec["compress_us"],
            f"ratio={rec['ratio']}x,payload={rec['payload_bytes']}B,"
            f"decompress_us={rec['decompress_us']}",
        ))
    # ISSUE 8 acceptance: the entropy trim buys strictly more ratio than
    # the dense bitpack on smooth tensors (same quantized codes, shorter
    # wire) — and never less, on ANY data, by construction.
    assert record["lorenzo+entropy"]["ratio"] > record["lorenzo"]["ratio"], (
        record["lorenzo+entropy"], record["lorenzo"],
    )
    # Control codec sanity: passthrough ships exactly the raw words plus
    # the container metadata (2 words per 256-block + the nwords word).
    meta = 2 * (N_ELEMS // 256) * 4 + 8
    assert record["passthrough"]["payload_bytes"] == N_ELEMS * 4 + meta, record
    if record_baseline:
        BASELINE_PATH.write_text(
            json.dumps({"codec": record}, indent=1, sort_keys=True) + "\n"
        )
    return record
