"""Figs. 11/12 analogs: gZ-Scatter vs Cray-MPI-model binomial scatter."""
from __future__ import annotations

from repro.core import cost_model as cm

HW = cm.A100_SLINGSHOT
RATIO = 60.0


def run(csv_rows: list):
    # Fig 11: message sizes at 64 GPUs
    for mb in [50, 100, 200, 400, 600]:
        d = mb * 1e6
        gz = cm.scatter_binomial_gz(d, 64, RATIO, HW)
        base = cm.scatter_uncompressed_binomial(d, 64, HW)
        csv_rows.append(
            (f"fig11_scatter_{mb}MB_64gpu", gz * 1e6,
             f"speedup_vs_cray={base/gz:.2f}")
        )
    # Fig 12: GPU counts at 646 MB
    d = 646e6
    speedups = {}
    for n in [8, 16, 32, 64, 128, 256, 512]:
        gz = cm.scatter_binomial_gz(d, n, RATIO, HW)
        base = cm.scatter_uncompressed_binomial(d, n, HW)
        speedups[n] = base / gz
        csv_rows.append(
            (f"fig12_scatter_646MB_{n}gpu", gz * 1e6,
             f"speedup_vs_cray={base/gz:.2f}")
        )
    # paper shape: speedup rises then falls with GPU count, always > 1
    assert all(s > 1 for s in speedups.values())
