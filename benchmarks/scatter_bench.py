"""Figs. 11/12 analogs: gZ-Scatter vs Cray-MPI-model binomial scatter.

PR 5 (trimmed-slab scatter) additions: the sweep includes NON-power-of-two
GPU counts (9, 24, 96) — the pricing path the pow2-only sweep never
exercised, and exactly where the padded virtual tree used to ship
2**ceil(log2 n) - 1 chunk streams for n-1 chunks of data.  The run
records ``benchmarks/BENCH_scatter.json`` with the per-n provisioned root
wire (chunk streams + bytes for the Fig. 12 message size): those are
STATIC schedule quantities, not timings, so ``regression_check.py``
compares them exactly and treats any increase as fatal — reintroducing
padding chunks cannot hide inside timing noise.
"""
from __future__ import annotations

import json
import pathlib

from repro.core import cost_model as cm
from repro.core.comm import _wire_accounting

HW = cm.A100_SLINGSHOT
RATIO = 60.0
FIG12_MB = 646
# Fig 12 pow2 sweep + the non-pow2 counts the padded tree over-provisioned
# worst (9 -> 7/16 slots padded, 24 -> 8/32, 96 -> 32/128).
GPU_COUNTS = [8, 9, 16, 24, 32, 64, 96, 128, 256, 512]
BASELINE_PATH = pathlib.Path(__file__).parent / "BENCH_scatter.json"


def wire_record(n: int, d_bytes: float) -> dict:
    """Static provisioned-wire record for one axis size: what the plan
    layer reports for a scatter of ``d_bytes`` over ``n`` ranks."""
    n_elems = int(d_bytes / 4)
    _, wire, raw = _wire_accounting("scatter", "binomial", n_elems, n, 0.6, 1)
    return {
        "chunk_streams": cm.scatter_root_chunk_streams(n),
        "wire_bytes": wire,
        "provisioned_ratio": round(raw / wire, 4),
    }


def run(csv_rows: list, record_baseline: bool = True) -> dict:
    # Fig 11: message sizes at 64 GPUs
    for mb in [50, 100, 200, 400, 600]:
        d = mb * 1e6
        gz = cm.scatter_binomial_gz(d, 64, RATIO, HW)
        base = cm.scatter_uncompressed_binomial(d, 64, HW)
        csv_rows.append(
            (f"fig11_scatter_{mb}MB_64gpu", gz * 1e6,
             f"speedup_vs_cray={base/gz:.2f}")
        )
    # Fig 12: GPU counts at 646 MB — pow2 AND non-pow2 rows
    d = FIG12_MB * 1e6
    record = {}
    speedups = {}
    for n in GPU_COUNTS:
        gz = cm.scatter_binomial_gz(d, n, RATIO, HW)
        base = cm.scatter_uncompressed_binomial(d, n, HW)
        speedups[n] = base / gz
        rec = wire_record(n, d)
        rec["gz_us"] = round(gz * 1e6, 2)
        rec["speedup_vs_cray"] = round(base / gz, 4)
        record[str(n)] = rec
        csv_rows.append(
            (f"fig12_scatter_{FIG12_MB}MB_{n}gpu", gz * 1e6,
             f"speedup_vs_cray={base/gz:.2f},"
             f"chunk_streams={rec['chunk_streams']}")
        )
    # paper shape: speedup rises then falls with GPU count, always > 1
    assert all(s > 1 for s in speedups.values())
    # trimmed schedule: the root provisions exactly n-1 chunk streams at
    # EVERY n — the padded virtual tree's 2**ceil(log2 n)-1 is gone.
    for n in GPU_COUNTS:
        assert record[str(n)]["chunk_streams"] == n - 1, n
    if record_baseline:
        BASELINE_PATH.write_text(
            json.dumps({"scatter": record}, indent=1, sort_keys=True) + "\n"
        )
    return record
