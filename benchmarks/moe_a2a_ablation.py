"""Beyond-paper ablation: should the MoE expert all-to-all be compressed?

This applies the paper's own algorithm-design metric (total compression
cost at the actual per-invocation payload size, §3.3.3) to a collective
the paper never studied.  Setup mirrors llama4-scout train_4k on the
16x16 mesh: per device, per layer, the dispatch all_to_all ships
(e_local x cap x d_model) f32 activation slots to 16 expert ranks.

Verdict (asserted, and it REFUTED our initial assumption): at TRAIN
shapes the per-hop slot buffers are ~6.5 MB and the batched compress is
saturated, so even a modest 3x activation ratio wins (~1.7x); at DECODE
shapes the payloads are KB-scale, the compressor is utilization-starved,
and compression loses badly.  Same size-dependent reasoning that drives
the paper's Ring/ReDoub crossover, applied to a collective the paper
never studied — and the answer is shape-dependent, not a blanket no.
(The default implementation keeps the dispatch uncompressed; this study
marks compressed train-time dispatch as the next beyond-paper feature.)
"""
from __future__ import annotations

from repro.core import cost_model as cm

HW = cm.TPU_V5E
ACT_RATIO = 3.0  # measured-ish ratio for bf16/f32 activations at eb 1e-4


def _point(csv_rows, name, tokens_per_rank, d_model=5120):
    cap = max(int(tokens_per_rank * 1.25 / 16) + 1, 8)
    payload = cap * d_model * 4  # one expert-rank's slot buffer, f32
    n_hops = 15
    t_raw = n_hops * cm.t_net(payload, HW)
    t_gz = (
        cm.t_compress(payload * 16, HW)  # batched compress of all slots
        + n_hops * cm.t_net(payload / ACT_RATIO, HW)
        + cm.t_decompress(payload * 16, HW)
    )
    csv_rows.append(
        (f"moe_a2a_{name}_raw", t_raw * 1e6,
         f"payload_per_hop={payload/1e6:.3f}MB")
    )
    csv_rows.append(
        (f"moe_a2a_{name}_gz", t_gz * 1e6,
         f"ratio={ACT_RATIO};gz_vs_raw={t_gz/t_raw:.2f}x")
    )
    return t_raw, t_gz


def run(csv_rows: list):
    # train_4k: 65536 tokens/device, sliced over tp=16
    raw_t, gz_t = _point(csv_rows, "train4k", 65536 // 16)
    # decode: 8 tokens/device (batch 128 / 16 data ranks)
    raw_d, gz_d = _point(csv_rows, "decode", 8)
    # the framework's size-dependent verdicts:
    assert gz_t < raw_t, "train-shape dispatch SHOULD benefit at ratio 3"
    assert gz_d > raw_d, "decode-shape dispatch should NOT be compressed"
